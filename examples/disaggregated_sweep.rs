//! End-to-end driver: the full CoroAMU evaluation pipeline on a real
//! (small) workload suite — every Table II benchmark, all five
//! configurations, across the paper's far-memory latency sweep, fanned
//! over a worker pool, each run validated against its native oracle, with
//! the AOT-artifact cross-check when `artifacts/` is built.
//!
//! This exercises all three layers end to end and reports the paper's
//! headline metric (Fig. 12 speedups). Recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example disaggregated_sweep [-- --scale full]`

use coroamu::benchmarks::Scale;
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::coordinator::{lookup, pool, run_matrix, Job};
use coroamu::runtime;
use coroamu::util::cli::Args;
use coroamu::util::table::{geomean, speedup, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let scale = match args.get_or("scale", "small") {
        "full" => Scale::Full,
        "tiny" => Scale::Tiny,
        _ => Scale::Small,
    };
    let latencies = [100.0, 200.0, 400.0, 800.0];
    let benches: Vec<String> = coroamu::benchmarks::all().iter().map(|b| b.spec().name.to_string()).collect();

    // 1) Simulation matrix.
    let mut jobs = Vec::new();
    for lat in latencies {
        let cfg = SimConfig::nh_g().with_far_latency_ns(lat);
        for b in &benches {
            for (v, tasks) in [
                (Variant::Serial, 1usize),
                (Variant::Coroutine, 16),
                (Variant::CoroAmuS, 32),
                (Variant::CoroAmuD, 96),
                (Variant::CoroAmuFull, 96),
            ] {
                jobs.push(Job {
                    bench: b.clone(),
                    variant: v,
                    tasks,
                    cfg: cfg.clone(),
                    scale,
                    seed: 42,
                    key: format!("{lat}"),
                });
            }
        }
    }
    let n = jobs.len();
    eprintln!("running {n} simulations on {} threads...", pool::default_threads());
    let t0 = std::time::Instant::now();
    let rs = run_matrix(jobs, pool::default_threads())?;
    eprintln!("done in {:.1}s (every run oracle-checked)", t0.elapsed().as_secs_f64());

    // 2) Report speedups per latency.
    for lat in latencies {
        let key = format!("{lat}");
        let mut t = Table::new(
            format!("Speedup vs serial @ {lat} ns far latency"),
            &["bench", "Coroutine", "CoroAMU-S", "CoroAMU-D", "CoroAMU-Full"],
        );
        let mut full_col = Vec::new();
        for b in &benches {
            let serial = lookup(&rs, b, Variant::Serial, &key).unwrap().stats.cycles as f64;
            let sp = |v: Variant| serial / lookup(&rs, b, v, &key).unwrap().stats.cycles as f64;
            full_col.push(sp(Variant::CoroAmuFull));
            t.row(vec![
                b.clone(),
                speedup(sp(Variant::Coroutine)),
                speedup(sp(Variant::CoroAmuS)),
                speedup(sp(Variant::CoroAmuD)),
                speedup(sp(Variant::CoroAmuFull)),
            ]);
        }
        t.row(vec!["geomean".into(), "".into(), "".into(), "".into(), speedup(geomean(&full_col))]);
        t.print();
    }

    // 3) Three-layer cross-check against the AOT golden models.
    if runtime::artifacts_available() {
        let rt = runtime::Runtime::cpu()?;
        for b in runtime::oracle::GOLDEN_BENCHES {
            runtime::oracle::check_against_artifact(&rt, b, Variant::CoroAmuFull)?;
        }
        println!("\nPJRT cross-check: simulator memory == AOT JAX/Pallas golden models (4/4).");
    } else {
        println!("\n(artifacts/ not built; run `make artifacts` for the PJRT cross-check)");
    }
    Ok(())
}
