//! End-to-end driver: the full CoroAMU evaluation pipeline on a real
//! (small) workload suite — every Table II benchmark, all five
//! configurations, across the paper's far-memory latency sweep, fanned
//! over a worker pool by one `Engine` session, each run validated against
//! its native oracle, with the AOT-artifact cross-check when `artifacts/`
//! is built.
//!
//! The single session means each (benchmark, variant) kernel compiles once
//! for the whole 4-latency matrix. This exercises all three layers end to
//! end and reports the paper's headline metric (Fig. 12 speedups).
//! Recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example disaggregated_sweep [-- --scale full]`

use coroamu::benchmarks::Scale;
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::coordinator::pool;
use coroamu::engine::{lookup, Engine, RunRequest};
use coroamu::runtime;
use coroamu::util::cli::Args;
use coroamu::util::table::{geomean, speedup, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let scale = match args.get_or("scale", "small") {
        "full" => Scale::Full,
        "tiny" => Scale::Tiny,
        _ => Scale::Small,
    };
    let latencies = [100.0, 200.0, 400.0, 800.0];
    let benches: Vec<String> = coroamu::benchmarks::all().iter().map(|b| b.spec().name.to_string()).collect();

    // 1) Simulation matrix through one engine session.
    let engine = Engine::new(SimConfig::nh_g());
    let mut matrix = Vec::new();
    for lat in latencies {
        for b in &benches {
            for (v, tasks) in [
                (Variant::Serial, 1usize),
                (Variant::Coroutine, 16),
                (Variant::CoroAmuS, 32),
                (Variant::CoroAmuD, 96),
                (Variant::CoroAmuFull, 96),
            ] {
                matrix.push(
                    RunRequest::new(b.clone(), v)
                        .tasks(tasks)
                        .scale(scale)
                        .seed(42)
                        .key(format!("{lat}"))
                        .latency_ns(lat),
                );
            }
        }
    }
    let n = matrix.len();
    eprintln!("running {n} simulations on {} threads...", pool::default_threads());
    let t0 = std::time::Instant::now();
    let rs = engine.sweep(&matrix, pool::default_threads())?;
    let cs = engine.cache_stats();
    eprintln!(
        "done in {:.1}s (every run oracle-checked; {} kernel compilations served {} runs)",
        t0.elapsed().as_secs_f64(),
        cs.misses,
        n
    );

    // 2) Report speedups per latency.
    for lat in latencies {
        let key = format!("{lat}");
        let mut t = Table::new(
            format!("Speedup vs serial @ {lat} ns far latency"),
            &["bench", "Coroutine", "CoroAMU-S", "CoroAMU-D", "CoroAMU-Full"],
        );
        let mut full_col = Vec::new();
        for b in &benches {
            let serial = lookup(&rs, b, Variant::Serial, &key).unwrap().stats.cycles as f64;
            let sp = |v: Variant| serial / lookup(&rs, b, v, &key).unwrap().stats.cycles as f64;
            full_col.push(sp(Variant::CoroAmuFull));
            t.row(vec![
                b.clone(),
                speedup(sp(Variant::Coroutine)),
                speedup(sp(Variant::CoroAmuS)),
                speedup(sp(Variant::CoroAmuD)),
                speedup(sp(Variant::CoroAmuFull)),
            ]);
        }
        t.row(vec!["geomean".into(), "".into(), "".into(), "".into(), speedup(geomean(&full_col))]);
        t.print();
    }

    // 3) Three-layer cross-check against the AOT golden models. Artifacts
    // may exist while the runtime is stubbed out (default build): report,
    // don't abort the sweep that already succeeded.
    if !runtime::artifacts_available() {
        println!("\n(artifacts/ not built; run `make artifacts` for the PJRT cross-check)");
        return Ok(());
    }
    match runtime::Runtime::cpu() {
        Ok(rt) => {
            for b in runtime::oracle::GOLDEN_BENCHES {
                runtime::oracle::check_against_artifact(&rt, b, Variant::CoroAmuFull)?;
            }
            println!("\nPJRT cross-check: simulator memory == AOT JAX/Pallas golden models (4/4).");
        }
        Err(e) => println!("\n(PJRT cross-check skipped: {e:#})"),
    }
    Ok(())
}
