//! Quickstart: open an `Engine` session, run one pragma-annotated kernel
//! (GUPS) through all five of the paper's configurations on the NH-G model
//! at 200 ns far-memory latency (each run oracle-checked), and print the
//! comparison.
//!
//! Run: `cargo run --release --example quickstart`

use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::util::table::{speedup, Table};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(SimConfig::nh_g().with_far_latency_ns(200.0));
    let cfg = engine.config();
    println!("CoroAMU quickstart — GUPS on {} @ {} ns far memory\n", cfg.name, cfg.mem.far_latency_ns);

    let mut t = Table::new(
        "GUPS: five configurations (oracle-checked)",
        &["variant", "cycles", "dyn instrs", "IPC", "far MLP", "switches", "speedup"],
    );
    let mut serial_cycles = 0u64;
    for v in Variant::ALL {
        let tasks = if v.needs_amu() { 96 } else { 32 };
        let r = engine.run(RunRequest::new("gups", v).tasks(tasks))?;
        let st = &r.stats;
        if v == Variant::Serial {
            serial_cycles = st.cycles;
        }
        t.row(vec![
            v.label().into(),
            st.cycles.to_string(),
            st.dyn_instrs.to_string(),
            format!("{:.2}", st.ipc()),
            format!("{:.1}", st.far_mlp),
            st.switches.to_string(),
            speedup(serial_cycles as f64 / st.cycles as f64),
        ]);
    }
    t.print();
    let cs = engine.cache_stats();
    println!("All five variants passed the native oracle (identical table contents).");
    println!("Kernel cache: {} compilations, {} hits this session.", cs.misses, cs.hits);
    println!("Next: `coroamu report --fig 12` regenerates the paper's headline figure.");
    Ok(())
}
