//! Quickstart: compile one pragma-annotated kernel (GUPS) into all five of
//! the paper's configurations, simulate them on the NH-G model at 200 ns
//! far-memory latency, validate results, and print the comparison.
//!
//! Run: `cargo run --release --example quickstart`

use coroamu::benchmarks::{self, Scale};
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::util::table::{speedup, Table};

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig::nh_g().with_far_latency_ns(200.0);
    println!("CoroAMU quickstart — GUPS on {} @ {} ns far memory\n", cfg.name, cfg.mem.far_latency_ns);

    let bench = benchmarks::by_name("gups").unwrap();
    let mut t = Table::new(
        "GUPS: five configurations (oracle-checked)",
        &["variant", "cycles", "dyn instrs", "IPC", "far MLP", "switches", "speedup"],
    );
    let mut serial_cycles = 0u64;
    for v in Variant::ALL {
        let inst = bench.instance(Scale::Small, 42)?;
        let tasks = if v.needs_amu() { 96 } else { 32 };
        let st = benchmarks::execute(&cfg, inst, v, tasks)?;
        if v == Variant::Serial {
            serial_cycles = st.cycles;
        }
        t.row(vec![
            v.label().into(),
            st.cycles.to_string(),
            st.dyn_instrs.to_string(),
            format!("{:.2}", st.ipc()),
            format!("{:.1}", st.far_mlp),
            st.switches.to_string(),
            speedup(serial_cycles as f64 / st.cycles as f64),
        ]);
    }
    t.print();
    println!("All five variants passed the native oracle (identical table contents).");
    println!("Next: `coroamu report --fig 12` regenerates the paper's headline figure.");
    Ok(())
}
