//! Hash-join deep dive (paper Listing 1): shows what the compiler does to
//! the probe loop — suspension sites, variable classification, coarse
//! coalescing of the bucket fetch — and how each mechanism moves the
//! needle at 400 ns far-memory latency, all through one `Engine` session.
//!
//! Run: `cargo run --release --example hashjoin_coroutines`

use coroamu::benchmarks;
use coroamu::compiler::analysis::{analyze, vs_len};
use coroamu::compiler::ast::VarClass;
use coroamu::compiler::codegen::{CodegenOpts, SchedKind};
use coroamu::compiler::{coalesce, Variant};
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::util::table::Table;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(SimConfig::nh_g().with_far_latency_ns(400.0));
    let cfg = engine.config();
    let kernel = benchmarks::hj::kernel();

    // --- What AsyncMark sees -------------------------------------------
    let an = analyze(&kernel)?;
    println!("HJ probe loop: {} suspension sites (remote accesses)", an.sites.len());
    for (v, name) in kernel.var_names.iter().enumerate() {
        let cls = an.class(v as u32);
        if cls != VarClass::Private {
            println!("  var {name:<8} -> {cls:?} (bypasses coroutine context)");
        }
    }
    let live = an.sites.iter().map(|s| vs_len(s.live_after)).max().unwrap_or(0);
    println!("  max live-across-suspension set: {live} vars");

    // --- What the coalescer does ---------------------------------------
    let plan = coalesce::plan(&an, cfg.amu.max_group, cfg.amu.max_coarse_bytes as u32);
    for g in &plan.groups {
        println!(
            "  coalesce group: {:?} x{} ({} switch(es) saved per visit)",
            g.kind,
            g.members.len(),
            g.members.len() - 1
        );
    }
    println!();

    // --- Measured effect -----------------------------------------------
    let mut t = Table::new(
        "HJ @400ns: mechanism ablation",
        &["config", "cycles", "switches", "ctx ops/switch", "speedup vs serial"],
    );
    let serial = engine.run(RunRequest::new("hj", Variant::Serial).tasks(1))?.stats.cycles;
    let base = CodegenOpts {
        sched: SchedKind::Bafin,
        context_opt: false,
        coalesce: false,
        generic_frame: false,
        num_tasks: 96,
    };
    for (name, opts) in [
        ("serial", CodegenOpts::serial()),
        ("hand coroutine (static)", CodegenOpts::hand_coroutine(32)),
        ("bafin, basic codegen", base.clone()),
        ("+ context selection", CodegenOpts { context_opt: true, ..base.clone() }),
        ("+ request coalescing", CodegenOpts { context_opt: true, coalesce: true, ..base }),
    ] {
        let req = RunRequest::new("hj", Variant::CoroAmuFull).opts(opts, name);
        let st = engine.run(req)?.stats;
        t.row(vec![
            name.into(),
            st.cycles.to_string(),
            st.switches.to_string(),
            format!("{:.1}", st.ctx_ops_per_switch()),
            format!("{:.2}x", serial as f64 / st.cycles as f64),
        ]);
    }
    t.print();
    Ok(())
}
