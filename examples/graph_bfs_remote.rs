//! BFS over a disaggregated graph: expands the largest BFS frontier of a
//! synthetic power-law-ish graph whose CSR arrays and level tree live in
//! far memory, across the latency sweep — the paper's best-case irregular
//! workload (GUPS aside). One `Engine` session serves the whole sweep, so
//! each variant's kernel compiles exactly once across all four latencies.
//!
//! Run: `cargo run --release --example graph_bfs_remote`

use coroamu::benchmarks::{bfs, Scale};
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::util::table::{speedup, Table};

fn main() -> anyhow::Result<()> {
    let (nodes, edges) = bfs::sizes(Scale::Small);
    let g = bfs::gen_graph(nodes, edges, 42);
    println!(
        "graph: {} nodes, {} directed edges, expanding level {} frontier ({} nodes)\n",
        nodes,
        g.elist.len(),
        g.next_level,
        g.frontier.len()
    );

    let engine = Engine::new(SimConfig::nh_g());
    let mut t = Table::new(
        "BFS level expansion: speedup vs serial across far-memory latency",
        &["latency", "Coroutine", "CoroAMU-S", "CoroAMU-D", "CoroAMU-Full", "Full far-MLP"],
    );
    for lat in [100.0, 200.0, 400.0, 800.0] {
        let run = |v: Variant, tasks: usize| -> anyhow::Result<coroamu::sim::RunStats> {
            Ok(engine.run(RunRequest::new("bfs", v).tasks(tasks).latency_ns(lat))?.stats)
        };
        let serial = run(Variant::Serial, 1)?.cycles as f64;
        let hand = serial / run(Variant::Coroutine, 16)?.cycles as f64;
        let s = serial / run(Variant::CoroAmuS, 32)?.cycles as f64;
        let d = serial / run(Variant::CoroAmuD, 96)?.cycles as f64;
        let full_stats = run(Variant::CoroAmuFull, 96)?;
        let full = serial / full_stats.cycles as f64;
        t.row(vec![
            format!("{lat} ns"),
            speedup(hand),
            speedup(s),
            speedup(d),
            speedup(full),
            format!("{:.1}", full_stats.far_mlp),
        ]);
    }
    t.print();
    let cs = engine.cache_stats();
    println!("levels array validated against the native BFS oracle for every run.");
    println!("({} kernel compilations served {} runs.)", cs.misses, cs.misses + cs.hits);
    Ok(())
}
