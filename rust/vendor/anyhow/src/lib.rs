//! Minimal, API-compatible shim for the subset of [`anyhow`] that coroamu
//! uses. The build environment has no network/registry access, so the real
//! crate cannot be fetched; this path dependency keeps `use anyhow::...`
//! call sites untouched while remaining fully self-contained.
//!
//! Covered surface:
//! * [`Error`] / [`Result`] (with the `E = Error` default),
//! * [`anyhow!`], [`bail!`], [`ensure!`] (format-string forms),
//! * [`Context::context`] / [`Context::with_context`] on `Result` and
//!   `Option`,
//! * `{e}` / `{e:#}` formatting (both render the full context chain,
//!   outermost first, joined by `": "` — the same shape the real crate
//!   produces for `{:#}`).
//!
//! Not covered (unused here): downcasting, backtraces, source() chains.

use std::fmt;

/// A string-backed error with an outermost-first context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context layer, like `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors the real crate: any std error converts via `?`. `Error` itself
// deliberately does not implement `std::error::Error`, which is what makes
// this blanket impl coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, like `anyhow::Context`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fail().unwrap_err();
        assert_eq!(format!("{e}"), "inner 42");
        assert_eq!(format!("{e:#}"), "inner 42");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<()> = fail().context("outer");
        assert_eq!(format!("{:#}", r.unwrap_err()), "outer: inner 42");
        let r: Result<()> = fail().with_context(|| format!("outer {}", 1));
        assert_eq!(format!("{:#}", r.unwrap_err()), "outer 1: inner 42");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(format!("{}", none.context("missing").unwrap_err()), "missing");
        assert_eq!(Some(7).context("missing").unwrap(), 7);
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: i64) -> Result<i64> {
            ensure!(v > 0, "v = {v}, want positive");
            Ok(v)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(format!("{}", check(-1).unwrap_err()), "v = -1, want positive");
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
