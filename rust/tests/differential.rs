//! Differential suite for the decode-once execution pipeline: the
//! decoded interpreter must be bit-identical — cycles, every stat
//! bucket, and the final memory image — to the reference tree-walking
//! interpreter, for all five compile variants. Also pins that sweeps
//! with dataset reuse reproduce fresh-engine results exactly, and
//! records the simulated-MIPS perf trajectory in BENCH_sim.json.

use coroamu::benchmarks::{self, Scale};
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::sim::fabric::FabricKind;
use coroamu::sim::sched::SchedPolicyKind;
use coroamu::sim::{self, MemImage};

/// Run `bench` under `variant` on all three interpreter paths —
/// decoded with superop fusion on (the session default), decoded with
/// fusion off, and the tree-walking reference — from identical
/// snapshots, and assert bit-identical stats + memory, then run the
/// benchmark's native oracle on every final image.
fn assert_paths_agree(bench: &str, variant: Variant, scale: Scale, seed: u64) {
    assert_paths_agree_under(SimConfig::nh_g(), bench, variant, scale, seed)
}

/// [`assert_paths_agree`] under an explicit configuration (the policy
/// differential runs every `SchedPolicyKind` through here).
fn assert_paths_agree_under(
    session_cfg: SimConfig,
    bench: &str,
    variant: Variant,
    scale: Scale,
    seed: u64,
) {
    let engine = Engine::new(session_cfg);
    let b = benchmarks::by_name(bench).unwrap();
    let inst = b.instance(scale, seed).unwrap();
    let opts = variant.opts(inst.default_tasks);
    let prepared = engine.prepare_kernel(&inst.kernel, &opts).unwrap();
    let cfg = engine.config();
    assert!(cfg.fuse_superops, "the session default must exercise fusion");
    let cfg_unfused = cfg.clone().with_fuse(false);
    let mem_unfused = inst.mem.snapshot();
    let mem_ref = inst.mem.snapshot();
    let mut pd = sim::link(cfg, &prepared.ck, inst.mem, &inst.params);
    let mut pu = sim::link(&cfg_unfused, &prepared.ck, mem_unfused, &inst.params);
    let mut pr = sim::link(cfg, &prepared.ck, mem_ref, &inst.params);
    // The serial lowering provably contains a compare→br loop head
    // (adjacent, dependent), so fusion must engage there; other variants'
    // lowered shapes are not guaranteed to place fusible pairs adjacently
    // and only need to stay bit-identical.
    if variant == Variant::Serial {
        assert!(pd.decoded.fused_pairs > 0, "{bench}/Serial: fusion found no pairs");
    }
    assert_eq!(pu.decoded.fused_pairs, 0, "unfused lowering must not fuse");
    let sd = sim::run(cfg, &mut pd)
        .unwrap_or_else(|e| panic!("{bench}/{}: fused path failed: {e:#}", variant.label()));
    let su = sim::run(&cfg_unfused, &mut pu)
        .unwrap_or_else(|e| panic!("{bench}/{}: unfused path failed: {e:#}", variant.label()));
    let sr = sim::run_reference(cfg, &mut pr)
        .unwrap_or_else(|e| panic!("{bench}/{}: reference path failed: {e:#}", variant.label()));
    assert_eq!(sd.cycles, sr.cycles, "{bench}/{}: cycles diverge", variant.label());
    assert_eq!(sd, su, "{bench}/{}: fused vs unfused stats diverge", variant.label());
    assert_eq!(sd, sr, "{bench}/{}: stats diverge", variant.label());
    assert_identical_memory(&pd.mem, &pu.mem, bench, variant);
    assert_identical_memory(&pd.mem, &pr.mem, bench, variant);
    (inst.check)(&pd.mem)
        .unwrap_or_else(|e| panic!("{bench}/{}: decoded image fails oracle: {e:#}", variant.label()));
    (inst.check)(&pu.mem)
        .unwrap_or_else(|e| panic!("{bench}/{}: unfused image fails oracle: {e:#}", variant.label()));
    (inst.check)(&pr.mem)
        .unwrap_or_else(|e| panic!("{bench}/{}: reference image fails oracle: {e:#}", variant.label()));
}

fn assert_identical_memory(a: &MemImage, b: &MemImage, bench: &str, variant: Variant) {
    assert_eq!(a.regions.len(), b.regions.len(), "{bench}/{}: region count", variant.label());
    for (ra, rb) in a.regions.iter().zip(b.regions.iter()) {
        assert_eq!(ra.name, rb.name);
        assert_eq!(ra.base, rb.base);
        assert_eq!(
            ra.data, rb.data,
            "{bench}/{}: memory diverges in region {}",
            variant.label(),
            ra.name
        );
    }
}

/// The acceptance differential: all five compile variants, identical
/// cycles/stats/memory between the decoded and reference interpreters.
#[test]
fn gups_five_variants_bit_identical() {
    for v in Variant::ALL {
        assert_paths_agree("gups", v, Scale::Small, 7);
    }
}

/// Same equivalence on an irregular-graph workload (pointer-chasing BFS
/// exercises bafin/getfin scheduling and the SPM copy paths harder).
#[test]
fn bfs_five_variants_bit_identical() {
    for v in Variant::ALL {
        assert_paths_agree("bfs", v, Scale::Tiny, 11);
    }
}

/// Atomics + await/asignal lock hand-off path (IS histogram) agrees too.
#[test]
fn is_dynamic_variants_bit_identical() {
    for v in [Variant::Serial, Variant::CoroAmuD, Variant::CoroAmuFull] {
        assert_paths_agree("is", v, Scale::Tiny, 3);
    }
}

/// The scheduler-subsystem differential: every policy runs decoded-fused,
/// decoded-unfused and reference with bit-identical cycles/stats/memory,
/// on both the getfin (ITTAGE dispatch) and bafin (BTQ) scheduler shapes.
/// Tiny scale keeps the 4-policy x 2-variant x 3-path matrix fast; the
/// nightly workflow reruns it alongside the cranked-up proptests.
#[test]
fn all_policies_three_paths_bit_identical() {
    for policy in SchedPolicyKind::ALL {
        let cfg = SimConfig::nh_g().with_sched_policy(policy);
        for v in [Variant::CoroAmuD, Variant::CoroAmuFull] {
            assert_paths_agree_under(cfg.clone(), "gups", v, Scale::Tiny, 5);
        }
    }
}

/// The fabric-subsystem acceptance differential: the default fabric
/// (`FixedDelay`, replacing the hardwired far `Channel`) must be
/// bit-identical to the seed behavior — all 5 compile variants, all
/// three interpreter paths (decoded-fused / decoded-unfused /
/// reference), cycles + every stat + memory — and an explicitly
/// selected `FixedDelay` must match the untouched default exactly.
/// (Identity to pre-fabric builds holds at exactly-representable
/// bandwidths like the NH-G 16 B/cycle used here; the fixed-point
/// clock deliberately rounds differently at inexact ones — DESIGN §9.)
#[test]
fn fixed_delay_fabric_is_bit_identical_to_seed() {
    for v in Variant::ALL {
        // Three paths under the explicit FixedDelay fabric.
        assert_paths_agree_under(
            SimConfig::nh_g().with_fabric(FabricKind::FixedDelay),
            "gups",
            v,
            Scale::Tiny,
            7,
        );
        // Explicit selection == the session default, stat for stat.
        let req = || RunRequest::new("gups", v).scale(Scale::Tiny).seed(7);
        let base = Engine::new(SimConfig::nh_g()).run(req()).unwrap();
        let fixed =
            Engine::new(SimConfig::nh_g()).run(req().fabric(FabricKind::FixedDelay)).unwrap();
        assert_eq!(
            base.stats,
            fixed.stats,
            "{}: explicit FixedDelay diverges from the pre-fabric default",
            v.label()
        );
    }
}

/// Every fabric backend keeps the three interpreter paths bit-identical:
/// fabrics move completion times, and all paths must move together, on
/// both the getfin (CoroAMU-D) and bafin (CoroAMU-Full) scheduler shapes.
#[test]
fn all_fabrics_three_paths_bit_identical() {
    for fabric in FabricKind::ALL {
        let cfg = SimConfig::nh_g().with_fabric(fabric);
        for v in [Variant::CoroAmuD, Variant::CoroAmuFull] {
            assert_paths_agree_under(cfg.clone(), "gups", v, Scale::Tiny, 5);
        }
    }
}

/// Property: every fabric backend is deterministic across (a) repeated
/// runs through one engine (each run restores the dataset from a
/// copy-on-write snapshot) and (b) a fresh engine with the same seed —
/// including the `dist` backend's seeded latency draws. Rotates fabrics,
/// policies and latency points by case; the nightly workflow cranks the
/// case count (PROPTEST_CASES) to cover the full product.
#[test]
fn proptest_fabrics_deterministic_across_restore_and_reruns() {
    use coroamu::util::proptest::{check, env_cases, Config};
    check(
        Config { cases: env_cases(10), ..Config::default() },
        |g| g.rng.next_u64(),
        |seed: &u64| {
            let fabric = FabricKind::ALL[(*seed % 4) as usize];
            let policy = SchedPolicyKind::ALL[((*seed >> 2) % 4) as usize];
            let lat = [200.0, 800.0][((*seed >> 4) % 2) as usize];
            let cfg = SimConfig::nh_g().with_fabric(fabric).with_sched_policy(policy);
            let req = || {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .seed(seed % 5)
                    .latency_ns(lat)
            };
            let engine = Engine::new(cfg.clone());
            let a = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            let b = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != b {
                return Err(format!(
                    "{}/{}: snapshot-restore rerun diverges",
                    fabric.label(),
                    policy.label()
                ));
            }
            let fresh = Engine::new(cfg).run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != fresh {
                return Err(format!(
                    "{}/{}: fresh engine with the same seed diverges",
                    fabric.label(),
                    policy.label()
                ));
            }
            Ok(())
        },
    );
}

/// The fault-subsystem acceptance differential: with fault injection
/// off — whether left at the default, pinned in the session config, or
/// requested per-run as an explicit `FaultConfig::off()` — the simulator
/// is bit-identical to the seed: all five compile variants, all three
/// interpreter paths (decoded-fused / decoded-unfused / reference),
/// cycles + every stat + memory. Off is structural (the `FaultyFabric`
/// decorator is never even constructed), and this pins it.
#[test]
fn faults_off_is_bit_identical_to_seed() {
    use coroamu::sim::faults::FaultConfig;
    for v in Variant::ALL {
        // Three paths under an explicitly pinned faults-off session.
        assert_paths_agree_under(
            SimConfig::nh_g().with_faults(FaultConfig::off()),
            "gups",
            v,
            Scale::Tiny,
            7,
        );
        // Explicit request == the session default, stat for stat.
        let req = || RunRequest::new("gups", v).scale(Scale::Tiny).seed(7);
        let base = Engine::new(SimConfig::nh_g()).run(req()).unwrap();
        let off = Engine::new(SimConfig::nh_g()).run(req().faults(FaultConfig::off())).unwrap();
        assert_eq!(
            base.stats,
            off.stats,
            "{}: explicit faults=off diverges from the fault-free default",
            v.label()
        );
        assert_eq!(base.stats.faults, "", "{}: fault-free run annotated", v.label());
    }
}

/// Property: every fault spec is a deterministic replay function across
/// (a) repeated runs through one engine (dataset restored from the COW
/// snapshot) and (b) a fresh engine with the same seed — on every fabric
/// backend and resume policy. Rotates spec, fabric and policy by case;
/// the nightly workflow cranks the case count (PROPTEST_CASES).
#[test]
fn proptest_faults_deterministic_across_restore_and_reruns() {
    use coroamu::sim::faults::FaultConfig;
    use coroamu::util::proptest::{check, env_cases, Config};
    let specs = [
        FaultConfig::mild(),
        FaultConfig::heavy(),
        FaultConfig::nack(0.1),
        FaultConfig::blackout(),
    ];
    check(
        Config { cases: env_cases(10), ..Config::default() },
        |g| g.rng.next_u64(),
        |seed: &u64| {
            let spec = specs[(*seed % 4) as usize];
            let fabric = FabricKind::ALL[((*seed >> 2) % 4) as usize];
            let policy = SchedPolicyKind::ALL[((*seed >> 4) % 4) as usize];
            let cfg = SimConfig::nh_g().with_fabric(fabric).with_sched_policy(policy);
            let req = || {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .seed(seed % 5)
                    .faults(spec)
            };
            let tag = || format!("{}/{}/{}", spec.label(), fabric.label(), policy.label());
            let engine = Engine::new(cfg.clone());
            let a = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a.faults != spec.label() {
                return Err(format!("{}: ran as '{}'", tag(), a.faults));
            }
            let b = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != b {
                return Err(format!("{}: snapshot-restore rerun diverges", tag()));
            }
            let fresh = Engine::new(cfg).run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != fresh {
                return Err(format!("{}: fresh engine with the same seed diverges", tag()));
            }
            Ok(())
        },
    );
}

/// Acceptance: under the heavy chaos preset — NACKs, spikes, degradation
/// windows, blackouts and timeouts all at once — every request still
/// completes via retry or the slow path, the run terminates, and the
/// final image passes the benchmark's native oracle. No faulted run may
/// wedge the AMU.
#[test]
fn heavy_faults_complete_via_retry_or_slow_path() {
    use coroamu::sim::faults::FaultConfig;
    for v in [Variant::Serial, Variant::CoroAmuD, Variant::CoroAmuFull] {
        let rep = Engine::new(SimConfig::nh_g())
            .run(
                RunRequest::new("gups", v)
                    .scale(Scale::Tiny)
                    .seed(7)
                    .faults(FaultConfig::heavy()),
            )
            .unwrap_or_else(|e| panic!("{}: heavy faults wedged the run: {e:#}", v.label()));
        let st = &rep.stats;
        assert_eq!(st.faults, "heavy", "{}: spec not recorded", v.label());
        assert!(
            st.fault_nacks + st.fault_timeouts + st.fault_degraded_cycles > 0,
            "{}: heavy preset injected nothing",
            v.label()
        );
        assert!(
            st.fault_retries + st.fault_slow_path > 0,
            "{}: injected faults never exercised the resilience machinery",
            v.label()
        );
        assert!(st.fault_max_stall > 0, "{}: stall accounting missing", v.label());
    }
}

/// The service-subsystem acceptance differential: with the open-loop
/// replay off — whether left at the default, pinned in the session
/// config, or requested per-run as an explicit `ServiceConfig::off()` —
/// the simulator is bit-identical to the seed: all five compile
/// variants, all three interpreter paths (decoded-fused /
/// decoded-unfused / reference), cycles + every stat + memory. Off is
/// structural (`simulate` returns before touching the run), and this
/// pins it.
#[test]
fn service_off_is_bit_identical_to_seed() {
    use coroamu::sim::service::ServiceConfig;
    for v in Variant::ALL {
        // Three paths under an explicitly pinned service-off session.
        assert_paths_agree_under(
            SimConfig::nh_g().with_service(ServiceConfig::off()),
            "gups",
            v,
            Scale::Tiny,
            7,
        );
        // Explicit request == the session default, stat for stat.
        let req = || RunRequest::new("gups", v).scale(Scale::Tiny).seed(7);
        let base = Engine::new(SimConfig::nh_g()).run(req()).unwrap();
        let off = Engine::new(SimConfig::nh_g()).run(req().service(ServiceConfig::off())).unwrap();
        assert_eq!(
            base.stats,
            off.stats,
            "{}: explicit service=off diverges from the batch default",
            v.label()
        );
        assert_eq!(base.stats.service, "", "{}: batch run annotated", v.label());
        assert_eq!(base.stats.svc_offered, 0, "{}: batch run offered requests", v.label());
    }
}

/// Property: every service spec is a deterministic replay function
/// across (a) repeated runs through one engine (dataset restored from
/// the COW snapshot) and (b) a fresh engine with the same seed — with
/// the fabric, faults and policy axes rotated underneath it (they all
/// move the calibrated cost, and the replay must follow
/// deterministically). The nightly workflow cranks PROPTEST_CASES.
#[test]
fn proptest_service_deterministic_across_restore_and_reruns() {
    use coroamu::sim::faults::FaultConfig;
    use coroamu::sim::service::ServiceConfig;
    use coroamu::util::proptest::{check, env_cases, Config};
    let specs = [
        ServiceConfig::steady(),
        ServiceConfig::knee(),
        ServiceConfig::overload(),
        ServiceConfig::burst(),
    ];
    check(
        Config { cases: env_cases(10), ..Config::default() },
        |g| g.rng.next_u64(),
        |seed: &u64| {
            let svc = specs[(*seed % 4) as usize];
            let fabric = FabricKind::ALL[((*seed >> 2) % 4) as usize];
            let policy = SchedPolicyKind::ALL[((*seed >> 4) % 4) as usize];
            let faults = [FaultConfig::off(), FaultConfig::mild()][((*seed >> 6) % 2) as usize];
            let cfg = SimConfig::nh_g().with_fabric(fabric).with_sched_policy(policy);
            let req = || {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .seed(seed % 5)
                    .faults(faults)
                    .service(svc)
            };
            let tag = || {
                format!(
                    "{}/{}/{}/{}",
                    svc.label(),
                    fabric.label(),
                    faults.label(),
                    policy.label()
                )
            };
            let engine = Engine::new(cfg.clone());
            let a = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a.service != svc.label() {
                return Err(format!("{}: ran as '{}'", tag(), a.service));
            }
            if a.svc_offered != svc.requests as u64 {
                return Err(format!("{}: offered {} of {}", tag(), a.svc_offered, svc.requests));
            }
            if a.svc_offered != a.svc_accepted + a.svc_rejected {
                return Err(format!("{}: admission accounting leaks requests", tag()));
            }
            let b = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != b {
                return Err(format!("{}: snapshot-restore rerun diverges", tag()));
            }
            let fresh = Engine::new(cfg).run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != fresh {
                return Err(format!("{}: fresh engine with the same seed diverges", tag()));
            }
            Ok(())
        },
    );
}

/// Acceptance: the overload axis composed with heavy chaos — offered
/// load far past the knee while the fabric NACKs, spikes and blacks
/// out — still completes with no wedged coroutine, and the robustness
/// layer visibly engages: at 5× capacity the bounded admission queue
/// must reject requests (backpressure is structural there), while the
/// shedding-off ablation has to blow deadlines instead.
#[test]
fn overload_with_heavy_faults_sheds_and_completes() {
    use coroamu::sim::faults::FaultConfig;
    use coroamu::sim::service::ServiceConfig;
    let svc = ServiceConfig::parse("load:500").unwrap();
    let run = |svc: ServiceConfig| {
        Engine::new(SimConfig::nh_g())
            .run(
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .seed(7)
                    .faults(FaultConfig::heavy())
                    .service(svc),
            )
            .unwrap_or_else(|e| panic!("overload + heavy faults wedged the run: {e:#}"))
            .stats
    };
    let st = run(svc);
    assert_eq!(st.faults, "heavy");
    assert_eq!(st.service, "load:500");
    assert!(
        st.fault_nacks + st.fault_timeouts + st.fault_degraded_cycles > 0,
        "heavy preset injected nothing"
    );
    assert_eq!(st.svc_offered, svc.requests as u64, "every request accounted");
    assert_eq!(st.svc_offered, st.svc_accepted + st.svc_rejected);
    assert!(st.svc_rejected > 0, "5x the degraded capacity must shed via backpressure");
    assert!(st.svc_goodput > 0, "shedding must preserve useful work under chaos");
    assert_eq!(st.svc_timed_out, 0, "admitted requests meet the default deadline geometry");
    // The ablation arm: shedding off turns the same offered load into
    // deadline misses on an unbounded queue.
    let st = run(ServiceConfig { shed: false, ..svc });
    assert_eq!(st.svc_rejected, 0, "no admission control without shedding");
    assert!(st.svc_timed_out > 0, "unbounded queueing must blow the deadline");
}

/// The cluster-subsystem acceptance differential: `cores = 1` — whether
/// left at the default, pinned in the session config, or requested
/// per-run — is the plain single-core simulator, bit for bit. All five
/// compile variants run the three interpreter paths (decoded-fused /
/// decoded-unfused / reference) under an explicit `cores = 1` session,
/// and an explicit `.cores(1)` request must match the untouched default
/// stat for stat (including the all-default cluster annotations).
#[test]
fn cores_eq_1_is_bit_identical_to_seed() {
    for v in Variant::ALL {
        // Three paths under an explicitly pinned single-core cluster.
        assert_paths_agree_under(SimConfig::nh_g().with_cores(1), "gups", v, Scale::Tiny, 7);
        // Explicit request == the session default, stat for stat.
        let req = || RunRequest::new("gups", v).scale(Scale::Tiny).seed(7);
        let base = Engine::new(SimConfig::nh_g()).run(req()).unwrap();
        let one = Engine::new(SimConfig::nh_g()).run(req().cores(1)).unwrap();
        assert_eq!(
            base.stats,
            one.stats,
            "{}: explicit cores=1 diverges from the pre-cluster default",
            v.label()
        );
        assert_eq!(one.stats.cluster_cores, 0, "{}: single-core path annotated", v.label());
    }
}

/// Property: multi-core cluster runs are deterministic across (a)
/// repeated runs through one engine (per-core programs restored from
/// the COW dataset snapshot) and (b) a fresh engine with the same seed.
/// Rotates core count, fabric and policy by case; the nightly workflow
/// cranks the case count (PROPTEST_CASES) over the full product.
#[test]
fn proptest_clusters_deterministic_across_restore_and_reruns() {
    use coroamu::util::proptest::{check, env_cases, Config};
    check(
        Config { cases: env_cases(8), ..Config::default() },
        |g| g.rng.next_u64(),
        |seed: &u64| {
            let cores = [2u32, 3, 4][(*seed % 3) as usize];
            let fabric = FabricKind::ALL[((*seed >> 2) % 4) as usize];
            let policy = SchedPolicyKind::ALL[((*seed >> 4) % 4) as usize];
            let cfg = SimConfig::nh_g().with_fabric(fabric).with_sched_policy(policy);
            let req = || {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .seed(seed % 5)
                    .cores(cores)
            };
            let tag = || format!("{}c/{}/{}", cores, fabric.label(), policy.label());
            let engine = Engine::new(cfg.clone());
            let a = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a.cluster_cores != cores {
                return Err(format!("{}: ran {} cores", tag(), a.cluster_cores));
            }
            let b = engine.run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != b {
                return Err(format!("{}: snapshot-restore rerun diverges", tag()));
            }
            let fresh = Engine::new(cfg).run(req()).map_err(|e| format!("{e:#}"))?.stats;
            if a != fresh {
                return Err(format!("{}: fresh engine with the same seed diverges", tag()));
            }
            Ok(())
        },
    );
}

/// The tracing-subsystem acceptance differential: with tracing off —
/// whether left at the default, pinned in the session config, or
/// requested per-run as an explicit `TraceConfig::off()` — the
/// simulator is bit-identical to the seed: all five compile variants,
/// all three interpreter paths (decoded-fused / decoded-unfused /
/// reference), cycles + every stat + memory. Off is structural (the
/// `Tracer` is never constructed), and this pins it.
#[test]
fn trace_off_is_bit_identical_to_seed() {
    use coroamu::sim::trace::TraceConfig;
    for v in Variant::ALL {
        // Three paths under an explicitly pinned trace-off session.
        assert_paths_agree_under(
            SimConfig::nh_g().with_trace(TraceConfig::off()),
            "gups",
            v,
            Scale::Tiny,
            7,
        );
        // Explicit request == the session default, stat for stat.
        let req = || RunRequest::new("gups", v).scale(Scale::Tiny).seed(7);
        let base = Engine::new(SimConfig::nh_g()).run(req()).unwrap();
        let off = Engine::new(SimConfig::nh_g()).run(req().trace(TraceConfig::off())).unwrap();
        assert_eq!(
            base.stats,
            off.stats,
            "{}: explicit trace=off diverges from the untraced default",
            v.label()
        );
        assert_eq!(base.stats.trace_events, 0, "{}: untraced run counted events", v.label());
        assert_eq!(base.stats.trace_dropped, 0, "{}: untraced run dropped events", v.label());
        // And the traced entry point with tracing off builds no tracer.
        let (rep, trace) = Engine::new(SimConfig::nh_g()).run_traced(req()).unwrap();
        assert!(trace.is_none(), "{}: untraced run built a tracer", v.label());
        assert_eq!(rep.stats, base.stats, "{}: run_traced(off) diverges", v.label());
    }
}

/// Property: tracing is a pure observer and a deterministic one — the
/// traced run's stats (minus the trace counters) match the untraced run
/// bit for bit, and the event stream is byte-identical across (a)
/// repeated runs through one engine (dataset restored from the COW
/// snapshot) and (b) a fresh engine with the same seed. Rotates fabric,
/// policy and faults by case; the nightly workflow cranks the case
/// count (PROPTEST_CASES).
#[test]
fn proptest_trace_deterministic_across_restore_and_reruns() {
    use coroamu::sim::faults::FaultConfig;
    use coroamu::sim::trace::TraceConfig;
    use coroamu::util::proptest::{check, env_cases, Config};
    check(
        Config { cases: env_cases(8), ..Config::default() },
        |g| g.rng.next_u64(),
        |seed: &u64| {
            let fabric = FabricKind::ALL[(*seed % 4) as usize];
            let policy = SchedPolicyKind::ALL[((*seed >> 2) % 4) as usize];
            let faults = [FaultConfig::off(), FaultConfig::mild()][((*seed >> 4) % 2) as usize];
            let cfg = SimConfig::nh_g().with_fabric(fabric).with_sched_policy(policy);
            let req = |trace: bool| {
                let r = RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .seed(seed % 5)
                    .faults(faults);
                if trace {
                    r.trace(TraceConfig::on())
                } else {
                    r
                }
            };
            let tag = || format!("{}/{}/{}", fabric.label(), policy.label(), faults.label());
            let engine = Engine::new(cfg.clone());
            let (a, ta) = engine.run_traced(req(true)).map_err(|e| format!("{e:#}"))?;
            let ta = ta.ok_or_else(|| format!("{}: traced run returned no trace", tag()))?;
            if a.stats.trace_events != ta.total || a.stats.trace_dropped != ta.dropped {
                return Err(format!("{}: stats/trace event accounting disagrees", tag()));
            }
            let (b, tb) = engine.run_traced(req(true)).map_err(|e| format!("{e:#}"))?;
            let tb = tb.ok_or_else(|| format!("{}: rerun returned no trace", tag()))?;
            if a.stats != b.stats {
                return Err(format!("{}: snapshot-restore rerun diverges", tag()));
            }
            if ta.event_log() != tb.event_log() {
                return Err(format!("{}: event stream diverges across reruns", tag()));
            }
            let (f, tf) = Engine::new(cfg).run_traced(req(true)).map_err(|e| format!("{e:#}"))?;
            let tf = tf.ok_or_else(|| format!("{}: fresh engine returned no trace", tag()))?;
            if a.stats != f.stats {
                return Err(format!("{}: fresh engine with the same seed diverges", tag()));
            }
            if ta.event_log() != tf.event_log() {
                return Err(format!("{}: event stream diverges on a fresh engine", tag()));
            }
            // Pure observer: stripping the trace counters reproduces the
            // untraced stats exactly.
            let mut masked = a.stats.clone();
            masked.trace_events = 0;
            masked.trace_dropped = 0;
            let plain = engine.run(req(false)).map_err(|e| format!("{e:#}"))?;
            if masked != plain.stats {
                return Err(format!("{}: tracing perturbed the simulation", tag()));
            }
            Ok(())
        },
    );
}

/// Pin that memory-guided prediction coverage is a property of the
/// scheduler policy (§IV-A as refactored into `sim::sched`):
/// * ArrivalOrder + bafin — the paper's configuration — keeps zero
///   indirect mispredicts AND zero bafin mispredicts;
/// * Fifo + getfin keeps the software scheduler's indirect dispatch
///   mispredicting through ITTAGE;
/// * Fifo + bafin loses the BTQ oracle (software static order is not
///   derivable from Finished-Queue state at fetch).
#[test]
fn prediction_coverage_is_a_policy_property() {
    let run = |variant: Variant, policy: SchedPolicyKind| {
        Engine::new(SimConfig::nh_g().with_sched_policy(policy))
            .run(RunRequest::new("gups", variant).scale(Scale::Small).seed(7))
            .unwrap()
            .stats
    };
    let arrival_bafin = run(Variant::CoroAmuFull, SchedPolicyKind::ArrivalOrder);
    assert_eq!(arrival_bafin.indirect_mispredicts, 0, "bafin scheduler has no indirect jumps");
    assert_eq!(arrival_bafin.bafin_mispredicts, 0, "memory-guided policy keeps the BTQ oracle");
    assert!(arrival_bafin.bafins_taken > 0);

    let fifo_getfin = run(Variant::CoroAmuD, SchedPolicyKind::Fifo);
    assert!(fifo_getfin.indirect_mispredicts > 0, "getfin dispatch must keep mispredicting");
    assert!(fifo_getfin.sched_indirect_jumps > 0);
    assert!(fifo_getfin.sched_indirect_mispredicts > 0, "scheduler-attributed stream recorded");

    let fifo_bafin = run(Variant::CoroAmuFull, SchedPolicyKind::Fifo);
    assert!(fifo_bafin.bafin_mispredicts > 0, "software static order breaks the BTQ oracle");
    assert_eq!(fifo_bafin.bafin_mispredicts, fifo_bafin.bafins_taken, "every dispatch uncovered");
}

/// Sweep-level dataset reuse is invisible to results: every point of a
/// latency sweep through one engine (datasets restored from the COW
/// cache) matches a fresh engine that materializes its own dataset.
#[test]
fn sweep_with_dataset_reuse_matches_fresh_runs() {
    let engine = Engine::new(SimConfig::nh_g());
    let matrix: Vec<RunRequest> = [150.0, 300.0, 600.0]
        .iter()
        .map(|l| {
            RunRequest::new("gups", Variant::CoroAmuFull)
                .scale(Scale::Tiny)
                .latency_ns(*l)
                .key(format!("{l}"))
        })
        .collect();
    let rs = engine.sweep(&matrix, 3).unwrap();
    assert_eq!(engine.dataset_stats().misses, 1, "one dataset build for the whole sweep");
    for (req, rep) in matrix.iter().zip(&rs) {
        let fresh = Engine::new(SimConfig::nh_g()).run(req.clone()).unwrap();
        assert_eq!(
            rep.stats, fresh.stats,
            "sweep point {} diverges from a fresh engine",
            req.key
        );
    }
}

/// The sweep-store acceptance invariant: a heterogeneous grid (latency,
/// policy, fabric, cores, faults and service cells) served from the
/// persistent store is bit-identical to fresh simulation; the second
/// session simulates nothing; and a corrupted cell is quarantined and
/// re-simulated rather than trusted.
#[test]
fn store_served_cells_are_bit_identical_to_fresh_runs() {
    use coroamu::engine::store::Store;
    use coroamu::sim::faults::FaultConfig;
    use coroamu::sim::service::ServiceConfig;
    let dir = std::env::temp_dir().join(format!("coroamu-diff-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk = |v: Variant| RunRequest::new("gups", v).scale(Scale::Tiny).seed(9);
    let matrix = vec![
        mk(Variant::Serial).key("serial"),
        mk(Variant::CoroAmuFull).latency_ns(400.0).key("lat"),
        mk(Variant::CoroAmuFull).policy(SchedPolicyKind::LatencyAware).key("policy"),
        mk(Variant::CoroAmuFull).fabric(FabricKind::Queued { depth: 8 }).key("fabric"),
        mk(Variant::CoroAmuFull).cores(4).key("cores"),
        mk(Variant::CoroAmuFull).faults(FaultConfig::mild()).key("faults"),
        mk(Variant::CoroAmuFull).service(ServiceConfig::knee()).key("service"),
    ];
    let cold = Engine::new(SimConfig::nh_g()).with_store(Store::open(&dir).unwrap());
    let first = cold.sweep(&matrix, 3).unwrap();
    assert!(first.iter().all(|r| !r.store_hit), "cold sweep has nothing to serve");

    // Second session: the plan is all hits, nothing compiles or
    // simulates, and every cell is bit-identical to both the first pass
    // and a store-less fresh engine.
    let warm = Engine::new(SimConfig::nh_g()).with_store(Store::open(&dir).unwrap());
    let plan = warm.plan(&matrix).unwrap();
    assert_eq!((plan.hits.len(), plan.misses.len()), (matrix.len(), 0));
    let second = warm.sweep(&matrix, 3).unwrap();
    assert_eq!(warm.cache_stats().misses, 0, "store-served sweep must not compile");
    for ((req, a), b) in matrix.iter().zip(&first).zip(&second) {
        assert!(b.store_hit, "{}: expected a store hit", req.key);
        assert_eq!(a.stats, b.stats, "{}: store round-trip diverges", req.key);
        let fresh = Engine::new(SimConfig::nh_g()).run(req.clone()).unwrap();
        assert_eq!(b.stats, fresh.stats, "{}: store diverges from a fresh run", req.key);
    }

    // Corrupt one cell on disk: the next sweep re-simulates that cell
    // (and only reproduces the same numbers) instead of trusting it.
    let fp = warm.cell_fingerprint(&matrix[3]).unwrap();
    std::fs::write(dir.join(format!("{fp:016x}.cell")), "coroamu-store v1\ngarbage\n").unwrap();
    let third =
        Engine::new(SimConfig::nh_g()).with_store(Store::open(&dir).unwrap()).sweep(&matrix, 3).unwrap();
    assert!(!third[3].store_hit, "corrupt cell must re-simulate");
    assert!(third.iter().enumerate().all(|(i, r)| i == 3 || r.store_hit));
    assert_eq!(third[3].stats, second[3].stats, "re-simulation reproduces the cell");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Throughput smoke: measure simulated-MIPS per sweep point on the
/// decoded path (dataset cache + decode-once interpreter) against the
/// pre-change shape (per-point instance rebuild + reference
/// interpreter), and record the numbers in BENCH_sim.json at the repo
/// root. `cargo bench --bench simulator -- sim_mips` records the
/// release-mode numbers over the same schema; this smoke keeps the file
/// and the speedup invariant alive under plain `cargo test`.
#[test]
fn sim_mips_smoke_records_bench_json() {
    use coroamu::util::benchkit::{build_mode, Bench, Sample};
    use std::time::Instant;
    let scale = Scale::Small;
    let seed = 42u64;
    let iters = 4u32;

    let engine = Engine::new(SimConfig::nh_g());
    let req = || RunRequest::new("gups", Variant::CoroAmuFull).scale(scale).seed(seed);
    // Warm the kernel + dataset caches (the sweep steady state).
    let instrs = engine.run(req()).unwrap().stats.dyn_instrs as f64;
    let cfg = engine.config().clone();

    let measure_decoded = || -> Vec<f64> {
        (0..iters)
            .map(|_| {
                let t0 = Instant::now();
                let r = engine.run(req()).unwrap();
                assert_eq!(r.stats.dyn_instrs as f64, instrs);
                t0.elapsed().as_nanos() as f64
            })
            .collect()
    };
    let measure_reference = || -> Vec<f64> {
        (0..iters)
            .map(|_| {
                let t0 = Instant::now();
                let b = benchmarks::by_name("gups").unwrap();
                let inst = b.instance(scale, seed).unwrap();
                let prepared = engine
                    .prepare_kernel(&inst.kernel, &Variant::CoroAmuFull.opts(inst.default_tasks))
                    .unwrap();
                let mut prog = sim::link(&cfg, &prepared.ck, inst.mem, &inst.params);
                let st = sim::run_reference(&cfg, &mut prog).unwrap();
                (inst.check)(&prog.mem).unwrap();
                assert_eq!(st.dyn_instrs as f64, instrs, "paths simulate the same stream");
                t0.elapsed().as_nanos() as f64
            })
            .collect()
    };

    // Best-of timing, re-measured up to 3 times: the suite runs under a
    // parallel test harness, so a single noisy attempt must not fail the
    // build — only a consistently slower decoded path should.
    let (mut dec_ns, mut ref_ns) = (Vec::new(), Vec::new());
    for attempt in 0..3 {
        dec_ns = measure_decoded();
        ref_ns = measure_reference();
        let ratio = best(&ref_ns) / best(&dec_ns);
        if ratio >= 1.05 {
            break;
        }
        println!("sim_mips smoke: attempt {attempt} noisy (ratio {ratio:.2}), re-measuring");
    }
    let (dec_best, ref_best) = (best(&dec_ns), best(&ref_ns));
    let dec_mips = instrs / (dec_best / 1e9) / 1e6;
    let ref_mips = instrs / (ref_best / 1e9) / 1e6;
    println!(
        "sim_mips smoke ({}): decoded {dec_mips:.2} MIPS, reference {ref_mips:.2} MIPS ({:.2}x)",
        build_mode(),
        dec_mips / ref_mips
    );

    // Record the trajectory through benchkit's serializer (one schema for
    // bench + test writers). The bench binary owns the release-mode file:
    // this smoke only writes debug-mode numbers, and never over a
    // release-mode recording, so `cargo bench` results are never
    // clobbered by any flavor of `cargo test`.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    let release_recorded = std::fs::read_to_string(&path)
        .map(|s| s.contains("\"mode\": \"release\""))
        .unwrap_or(false);
    if build_mode() == "debug" && !release_recorded {
        let mut rec = Bench::for_recording();
        for (name, times) in [
            ("sim_mips/gups/CoroAMU-Full/decoded", &dec_ns),
            ("sim_mips/gups/CoroAMU-Full/reference", &ref_ns),
        ] {
            rec.samples.push(sample_from(name, times, instrs));
        }
        rec.write_json(&path).unwrap();
    }

    // The hard speedup gate only applies under optimization — the real
    // acceptance invariant is defined on the release-mode bench, and a
    // debug-mode suite on a loaded runner must not flake the build.
    if cfg!(debug_assertions) {
        if dec_mips <= ref_mips * 1.05 {
            println!(
                "WARNING: debug-mode smoke shows no decode-once speedup \
                 ({dec_mips:.2} vs {ref_mips:.2} MIPS); check `cargo bench -- sim_mips`"
            );
        }
    } else {
        assert!(
            dec_mips > ref_mips * 1.05,
            "decode-once pipeline must beat the pre-change path: {dec_mips:.2} vs {ref_mips:.2} simulated MIPS"
        );
    }

    fn best(times: &[f64]) -> f64 {
        times.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    fn sample_from(name: &str, times: &[f64], work: f64) -> Sample {
        let mut sorted = times.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
        Sample {
            name: name.to_string(),
            iters: sorted.len() as u32,
            mean_ns: mean,
            median_ns: sorted[sorted.len() / 2],
            min_ns: sorted[0],
            max_ns: *sorted.last().unwrap(),
            throughput: Some((work / (mean / 1e9), "instr")),
        }
    }
}
