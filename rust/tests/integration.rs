//! Cross-module integration tests: the Engine facade over
//! compiler -> simulator -> oracle across the full benchmark registry,
//! harness smoke tests, and property-based invariants on the
//! coordinator/compiler/simulator substrates.

use coroamu::benchmarks::{self, Instance, Scale};
use coroamu::compiler::analysis::{self, vs_contains, vs_iter};
use coroamu::compiler::ast::*;
use coroamu::compiler::{coalesce, Variant};
use coroamu::config::SimConfig;
use coroamu::engine::{lookup, Engine, RunRequest};
use coroamu::harness::{self, FigOpts};
use coroamu::ir::{AddrSpace, AluOp, Width};
use coroamu::sim::MemImage;
use coroamu::util::proptest::Gen;

/// Every benchmark, every variant, Tiny scale: oracle must pass. One
/// engine session per config; each (bench, variant) kernel compiles once.
#[test]
fn every_benchmark_every_variant_oracle_checked() {
    let engine = Engine::new(SimConfig::nh_g());
    for b in benchmarks::all() {
        for v in Variant::ALL {
            let name = b.spec().name;
            let tasks = if v.needs_amu() { 64 } else { 16 };
            engine
                .run(RunRequest::new(name, v).tasks(tasks).scale(Scale::Tiny).seed(7))
                .unwrap_or_else(|e| panic!("{} under {}: {e:#}", name, v.label()));
        }
    }
    let cs = engine.cache_stats();
    assert_eq!(cs.misses as usize, cs.entries);
    assert_eq!(
        cs.entries,
        benchmarks::all().len() * Variant::ALL.len(),
        "one compilation per (bench, variant)"
    );
}

/// Benchmarks also run on the Skylake preset (no AMU): the static
/// variants must work there; AMU variants are not applicable.
#[test]
fn skylake_preset_runs_static_variants() {
    let engine = Engine::new(SimConfig::skylake());
    for b in benchmarks::all() {
        for v in [Variant::Serial, Variant::Coroutine, Variant::CoroAmuS] {
            let name = b.spec().name;
            engine
                .run(RunRequest::new(name, v).tasks(8).scale(Scale::Tiny).seed(3))
                .unwrap_or_else(|e| panic!("{} under {}: {e:#}", name, v.label()));
        }
    }
}

/// All eight figures generate on Tiny scale without panicking.
#[test]
fn all_figures_generate_on_tiny() {
    let opts = FigOpts {
        scale: Scale::Tiny,
        threads: 1,
        seed: 1,
        only: vec!["gups".into(), "stream".into()],
    };
    for f in harness::ALL_FIGURES {
        let tables = harness::figure(f, &opts).unwrap_or_else(|e| panic!("fig {f}: {e:#}"));
        assert!(!tables.is_empty(), "figure {f} produced no tables");
        for t in tables {
            assert!(!t.render().is_empty());
        }
    }
}

/// Config round-trip: load a config file with overrides.
#[test]
fn config_file_roundtrip() {
    let path = "/tmp/coroamu_test_cfg.toml";
    std::fs::write(
        path,
        "preset = \"nh-g\"\nname = \"custom\"\n[core]\nrob_entries = 192\n[mem]\nfar_latency_ns = 555\n",
    )
    .unwrap();
    let cfg = SimConfig::load_file(path).unwrap();
    assert_eq!(cfg.name, "custom");
    assert_eq!(cfg.core.rob_entries, 192);
    assert_eq!(cfg.mem.far_latency_ns, 555.0);
}

/// Property: engine runs are deterministic — same request, same stats —
/// across repeated runs and across independent sessions.
#[test]
fn runs_are_deterministic() {
    let engine = Engine::new(SimConfig::nh_g());
    let req = || RunRequest::new("bs", Variant::CoroAmuFull).tasks(32).scale(Scale::Tiny).seed(5);
    let a = engine.run(req()).unwrap().stats;
    let b = engine.run(req()).unwrap().stats;
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.dyn_instrs, b.dyn_instrs);
    assert_eq!(a.switches, b.switches);
    // A fresh session (cold kernel cache) produces identical numbers.
    let c = Engine::new(SimConfig::nh_g()).run(req()).unwrap().stats;
    assert_eq!(a, c, "stats must be bit-identical across sessions");
}

// --- Engine cache + sweep contract ------------------------------------

/// The API-redesign acceptance test: a five-variant sweep over one
/// benchmark performs exactly five kernel compilations regardless of how
/// many (latency, seed) points it runs.
#[test]
fn five_variant_sweep_compiles_exactly_five_kernels() {
    let engine = Engine::new(SimConfig::nh_g());
    let variants = [
        (Variant::Serial, 1usize),
        (Variant::Coroutine, 16),
        (Variant::CoroAmuS, 16),
        (Variant::CoroAmuD, 64),
        (Variant::CoroAmuFull, 64),
    ];
    let mut matrix = Vec::new();
    for lat in [100.0, 200.0, 400.0] {
        for seed in [1u64, 2] {
            for (v, tasks) in variants {
                matrix.push(
                    RunRequest::new("gups", v)
                        .tasks(tasks)
                        .scale(Scale::Tiny)
                        .seed(seed)
                        .key(format!("{lat}/{seed}"))
                        .latency_ns(lat),
                );
            }
        }
    }
    let rs = engine.sweep(&matrix, 4).unwrap();
    assert_eq!(rs.len(), 3 * 2 * 5);
    let cs = engine.cache_stats();
    assert_eq!(cs.misses, 5, "each variant's kernel compiles exactly once");
    assert_eq!(cs.hits, (3 * 2 * 5) - 5, "every other point reuses the cache");
    assert_eq!(cs.entries, 5);
    // Exactly one report per variant carries the compile; the rest are hits.
    let compiles = rs.iter().filter(|r| !r.cache_hit).count();
    assert_eq!(compiles, 5);
}

/// engine.sweep end-to-end smoke test at Tiny scale: results come back in
/// matrix order, lookup works, oracle runs on every cell.
#[test]
fn engine_sweep_smoke_tiny() {
    let engine = Engine::new(SimConfig::nh_g());
    let matrix: Vec<RunRequest> = ["gups", "stream", "bs"]
        .iter()
        .flat_map(|b| {
            [Variant::Serial, Variant::CoroAmuFull]
                .iter()
                .map(|v| RunRequest::new(*b, *v).scale(Scale::Tiny).key("smoke"))
                .collect::<Vec<_>>()
        })
        .collect();
    let rs = engine.sweep(&matrix, 3).unwrap();
    assert_eq!(rs.len(), 6);
    for (req, rep) in matrix.iter().zip(rs.iter()) {
        assert_eq!(req.bench, rep.bench, "sweep preserves matrix order");
        assert_eq!(req.variant, rep.variant);
        assert!(rep.stats.cycles > 0);
    }
    let serial = lookup(&rs, "gups", Variant::Serial, "smoke").unwrap();
    let full = lookup(&rs, "gups", Variant::CoroAmuFull, "smoke").unwrap();
    assert!(serial.stats.cycles >= full.stats.cycles / 100, "sanity");
    // A failing cell aborts the sweep with the request named.
    let bad = vec![RunRequest::new("nope", Variant::Serial)];
    let err = engine.sweep(&bad, 1).unwrap_err();
    assert!(format!("{err:#}").contains("nope"));
}

// --- Property-based invariants ----------------------------------------

/// Build a random straight-line kernel of remote loads with random
/// dependence structure (some loads' addresses use earlier loads' values).
fn random_load_kernel(g: &mut Gen) -> (Kernel, Vec<bool>) {
    let nloads = g.usize_in(2, 7);
    let mut kb = KernelBuilder::new("prop");
    let p = kb.param_ptr("p", AddrSpace::Remote);
    let n = kb.param_val("n");
    kb.trip(n);
    let vars: Vec<VarId> = (0..nloads).map(|i| kb.var(&format!("v{i}"))).collect();
    let mut dependent = vec![false; nloads];
    for i in 0..nloads {
        // Depend on an earlier load's value with ~40% probability.
        let addr = if i > 0 && g.usize_in(0, 10) < 4 {
            let j = g.usize_in(0, i);
            dependent[i] = true;
            Expr::add(Expr::Param(p), Expr::shl(Expr::Var(vars[j]), Expr::Imm(3)))
        } else {
            Expr::add(
                Expr::Param(p),
                Expr::add(Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3)), Expr::Imm(g.i64_in(0, 64) * 8)),
            )
        };
        kb.load(vars[i], addr, Width::W8);
    }
    (kb.finish(), dependent)
}

/// Property (§III-C safety): coalesce groups never contain a member whose
/// address depends on another member's loaded value.
#[test]
fn coalescer_never_groups_dependent_loads() {
    for seed in 0..300u64 {
        let mut g = Gen::new(seed, 8);
        let (k, _) = random_load_kernel(&mut g);
        let an = analysis::analyze(&k).unwrap();
        let plan = coalesce::plan(&an, 8, 4096);
        for grp in &plan.groups {
            let mut defs = 0u64;
            for (i, m) in grp.members.iter().enumerate() {
                let site = &an.sites[*m];
                if i > 0 {
                    assert_eq!(
                        site.addr_deps & defs,
                        0,
                        "seed {seed}: member site {m} depends on earlier member defs\n{k:?}"
                    );
                }
                if let Some(d) = site.def {
                    defs |= 1 << d;
                }
            }
        }
    }
}

/// Property: every variant of a random load kernel executes and leaves
/// memory identical to the serial variant (loads only — no write races).
/// All runs route through one engine session.
#[test]
fn random_kernels_agree_across_variants() {
    let engine = Engine::new(SimConfig::nh_g());
    for seed in 0..40u64 {
        let mut g = Gen::new(seed ^ 0xABCD, 8);
        let (k, _) = random_load_kernel(&mut g);
        let words = 4096u64;
        let run = |variant: Variant| {
            let mut mem = MemImage::new();
            let p = mem.alloc("p", AddrSpace::Remote, words * 8 + 4096);
            for j in 0..words {
                // Values stay in-bounds as indices: v & 511.
                mem.write(p + j * 8, Width::W8, (j as i64 * 7) % 512).unwrap();
            }
            let inst = Instance {
                kernel: k.clone(),
                mem,
                params: vec![p as i64, 50],
                check: std::sync::Arc::new(|_| Ok(())),
                default_tasks: 16,
            };
            let r = engine.run_instance(inst, &variant.opts(16)).unwrap();
            (r.stats.dyn_instrs, r.stats.cycles)
        };
        let (serial_i, _) = run(Variant::Serial);
        for v in [Variant::CoroAmuS, Variant::CoroAmuD, Variant::CoroAmuFull] {
            let (vi, vc) = run(v);
            assert!(vi >= serial_i, "seed {seed}: {} executed fewer instrs than serial", v.label());
            assert!(vc > 0);
        }
    }
}

/// Property: context selection is monotone — the optimized save set is a
/// subset of the basic one, at every site of every benchmark kernel.
#[test]
fn context_selection_is_monotone_subset() {
    for b in benchmarks::all() {
        let inst = b.instance(Scale::Tiny, 11).unwrap();
        let an = match analysis::analyze(&inst.kernel) {
            Ok(a) => a,
            Err(_) => continue,
        };
        for site in &an.sites {
            let basic = an.saved_vars(site, false);
            let opt = an.saved_vars(site, true);
            assert_eq!(opt & !basic, 0, "{}: optimized set not a subset at site {}", b.spec().name, site.id);
            for v in vs_iter(opt) {
                assert!(vs_contains(basic, v));
            }
        }
    }
}

/// Failure injection: AMU misuse is rejected, not miscomputed.
#[test]
fn amu_misuse_rejected() {
    use coroamu::sim::amu::Amu;
    let mut amu = Amu::new(8, 1);
    assert!(amu.asignal(3, 0).is_err(), "asignal without await must fail");
    amu.await_register(3, 0, 0).unwrap();
    assert!(amu.await_register(3, 0, 0).is_err(), "double await must fail");
    assert!(amu.aset(1, 0).is_err(), "aset n=0 must fail");
    amu.aset(1, 2).unwrap();
    assert!(amu.aset(1, 2).is_err(), "nested aset on same id must fail");
}

/// Sequential-variable misuse is a compile error (surfaced through
/// `Engine::prepare_kernel`), not silent corruption.
#[test]
fn sequential_var_misuse_rejected() {
    let mut kb = KernelBuilder::new("seqbad");
    let p = kb.param_ptr("p", AddrSpace::Remote);
    let n = kb.param_val("n");
    kb.trip(n);
    let s = kb.var("s");
    let v = kb.var("v");
    kb.sequential_var(s);
    // Writes the sequential var *before* a remote access: unsupported
    // (only a trailing serialized-update tail can touch it).
    kb.let_(s, Expr::Imm(1)).load(
        v,
        Expr::add(Expr::Param(p), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))),
        Width::W8,
    );
    let k = kb.finish();
    let engine = Engine::new(SimConfig::nh_g());
    assert!(engine.prepare_kernel(&k, &Variant::CoroAmuFull.opts(8)).is_err());
}

/// The atomic lock hand-off preserves exactness under heavy contention:
/// all keys hash to ONE bucket.
#[test]
fn atomic_handoff_under_max_contention() {
    let mut kb = KernelBuilder::new("contend");
    let keys = kb.param_ptr("keys", AddrSpace::Remote);
    let hist = kb.param_ptr("hist", AddrSpace::Remote);
    let n = kb.param_val("n");
    kb.trip(n);
    let kvar = kb.var("k");
    kb.load(
        kvar,
        Expr::add(Expr::Param(keys), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))),
        Width::W8,
    )
    .atomic_rmw(
        AluOp::Add,
        Expr::add(Expr::Param(hist), Expr::shl(Expr::Var(kvar), Expr::Imm(3))),
        Expr::Imm(1),
        Width::W8,
    );
    let k = kb.finish();
    let engine = Engine::new(SimConfig::nh_g());
    let trip = 300i64;
    for v in [Variant::Serial, Variant::CoroAmuD, Variant::CoroAmuFull] {
        let mut mem = MemImage::new();
        let kb_ = mem.alloc("keys", AddrSpace::Remote, trip as u64 * 8);
        let hb = mem.alloc("hist", AddrSpace::Remote, 64);
        for i in 0..trip as u64 {
            mem.write(kb_ + i * 8, Width::W8, 3).unwrap(); // ALL to bucket 3
        }
        let inst = Instance {
            kernel: k.clone(),
            mem,
            params: vec![kb_ as i64, hb as i64, trip],
            check: std::sync::Arc::new(|_| Ok(())),
            default_tasks: 64,
        };
        let r = engine.run_instance(inst, &v.opts(64)).unwrap();
        let got = r.mem.read(hb + 3 * 8, Width::W8).unwrap();
        assert_eq!(got, trip, "{}: lost updates under contention", v.label());
        if v.needs_amu() {
            assert!(r.stats.awaits > 0, "{}: expected lock waits under total contention", v.label());
        }
    }
}

/// Nested coroutines (§III-F): a callee with a remote access, called from
/// the pragma loop, under the dynamic schedulers.
#[test]
fn nested_coroutine_roundtrip() {
    // child(ptr, idx): return p[idx] (remote load inside the callee).
    let mut kb = KernelBuilder::new("nested");
    let p = kb.param_ptr("p", AddrSpace::Remote);
    let out = kb.param_ptr("out", AddrSpace::Local);
    let n = kb.param_val("n");
    kb.trip(n);
    let r = kb.var("r");
    let child = kb.callee(NestedFn {
        name: "child".into(),
        params: vec![
            Param { name: "cp".into(), kind: ParamKind::Ptr(AddrSpace::Remote) },
            Param { name: "ci".into(), kind: ParamKind::Value },
        ],
        body: vec![Stmt::Load {
            var: 0,
            addr: Expr::add(Expr::Param(0), Expr::shl(Expr::Param(1), Expr::Imm(3))),
            width: Width::W8,
        }],
        ret_var: Some(0),
        nvars: 1,
    });
    kb.push(Stmt::Call { callee: child, args: vec![Expr::Param(p), Expr::Var(ITER_VAR)], ret: Some(r) })
        .store(
            Expr::Var(r),
            Expr::add(Expr::Param(out), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))),
            Width::W8,
        );
    let k = kb.finish();
    let engine = Engine::new(SimConfig::nh_g());
    let trip = 100u64;
    for v in [Variant::Serial, Variant::CoroAmuS, Variant::CoroAmuD, Variant::CoroAmuFull] {
        let mut mem = MemImage::new();
        let pb = mem.alloc("p", AddrSpace::Remote, trip * 8);
        let ob = mem.alloc("out", AddrSpace::Local, trip * 8);
        for i in 0..trip {
            mem.write(pb + i * 8, Width::W8, (i * i) as i64).unwrap();
        }
        let inst = Instance {
            kernel: k.clone(),
            mem,
            params: vec![pb as i64, ob as i64, trip as i64],
            check: std::sync::Arc::new(|_| Ok(())),
            default_tasks: 16,
        };
        let run = engine.run_instance(inst, &v.opts(16)).unwrap();
        for i in 0..trip {
            let got = run.mem.read(ob + i * 8, Width::W8).unwrap();
            assert_eq!(got, (i * i) as i64, "{} out[{i}]", v.label());
        }
        if v.needs_amu() {
            assert!(run.stats.awaits > 0, "{}: nested calls should use await/asignal", v.label());
        }
    }
}
