//! # CoroAMU reproduction
//!
//! A from-scratch reproduction of *"CoroAMU: Unleashing Memory-Driven
//! Coroutines through Latency-Aware Decoupled Operations"* (PACT 2025):
//! a memory-centric coroutine compiler over an SSA-lite IR ([`ir`],
//! [`compiler`]), a cycle-approximate model of the XiangShan NH-G core with
//! the enhanced Asynchronous Memory Unit ([`sim`]), the paper's eight
//! benchmarks ([`benchmarks`]), and the evaluation coordinator + figure
//! harness ([`coordinator`], [`harness`]).
//!
//! The Rust side is Layer 3 of the rust+JAX+Pallas stack; Layers 1/2 live
//! in `python/compile` and are AOT-lowered to `artifacts/*.hlo.txt`, which
//! [`runtime`] loads through PJRT to cross-validate the simulator's
//! functional outputs. See `DESIGN.md` (repo root) for the full inventory.
//!
//! The public entry point is [`engine`]: an [`engine::Engine`] session owns
//! the compile → link → simulate → oracle pipeline behind a compiled-kernel
//! cache, so callers never chain the stages by hand.

pub mod benchmarks;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod harness;
pub mod ir;
pub mod runtime;
pub mod sim;
pub mod util;
