//! CoroIR structural verifier. Run after every compiler pass in debug
//! builds and by tests; catches dangling block references, out-of-range
//! registers, and malformed AMU sequences.

use super::*;
use anyhow::{bail, Result};

pub fn verify(f: &Function) -> Result<()> {
    if f.blocks.is_empty() {
        bail!("function {} has no blocks", f.name);
    }
    if f.entry as usize >= f.blocks.len() {
        bail!("entry bb{} out of range", f.entry);
    }
    let nb = f.blocks.len() as u32;
    let check_bb = |b: BlockId, what: &str| -> Result<()> {
        if b >= nb {
            bail!("{}: dangling block reference bb{} (of {})", what, b, nb);
        }
        Ok(())
    };
    let check_reg = |r: Reg, what: &str| -> Result<()> {
        if r >= f.nregs {
            bail!("{}: register r{} out of range (nregs={})", what, r, f.nregs);
        }
        Ok(())
    };
    let check_op = |o: &Operand, what: &str| -> Result<()> {
        if let Operand::Reg(r) = o {
            check_reg(*r, what)?;
        }
        Ok(())
    };

    for (bi, blk) in f.blocks.iter().enumerate() {
        let ctx = |i: usize| format!("{}:bb{}[{}]", f.name, bi, i);
        for (ii, inst) in blk.insts.iter().enumerate() {
            let mut uses = Vec::new();
            inst.uses(&mut uses);
            for r in uses {
                check_reg(r, &ctx(ii))?;
            }
            if let Some(d) = inst.def() {
                check_reg(d, &ctx(ii))?;
            }
            match inst {
                Inst::Aload { bytes, resume, .. } | Inst::Astore { bytes, resume, .. } => {
                    check_bb(*resume, &ctx(ii))?;
                    if *bytes == 0 {
                        bail!("{}: zero-byte AMU transfer", ctx(ii));
                    }
                    if *bytes > 4096 {
                        bail!("{}: AMU transfer {} exceeds 4KB granularity limit", ctx(ii), bytes);
                    }
                }
                Inst::Await { resume, .. } => check_bb(*resume, &ctx(ii))?,
                Inst::Load { width, .. } | Inst::Store { width, .. } | Inst::AtomicRmw { width, .. } => {
                    let _ = width; // widths are enum-constrained
                }
                _ => {}
            }
        }
        let tctx = format!("{}:bb{}:term", f.name, bi);
        match &blk.term {
            Term::Br { cond, then_, else_ } => {
                check_op(cond, &tctx)?;
                check_bb(*then_, &tctx)?;
                check_bb(*else_, &tctx)?;
            }
            Term::Jmp(t) => check_bb(*t, &tctx)?,
            Term::IndirectJmp { target } => check_op(target, &tctx)?,
            Term::Bafin { handler_dst, id_dst, fallthrough } => {
                check_reg(*handler_dst, &tctx)?;
                check_reg(*id_dst, &tctx)?;
                check_bb(*fallthrough, &tctx)?;
            }
            Term::Halt => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;

    #[test]
    fn valid_function_passes() {
        let mut b = FuncBuilder::new("ok");
        let r = b.imm(1);
        let t = b.new_block("t", CodeTag::Compute);
        b.br(Operand::Reg(r), t, t);
        b.switch_to(t);
        b.halt();
        verify(&b.build()).unwrap();
    }

    #[test]
    fn dangling_block_caught() {
        let f = Function {
            name: "bad".into(),
            entry: 0,
            nregs: 1,
            blocks: vec![Block {
                name: "b".into(),
                tag: CodeTag::Compute,
                insts: vec![],
                term: Term::Jmp(9),
            }],
        };
        assert!(verify(&f).is_err());
    }

    #[test]
    fn out_of_range_reg_caught() {
        let f = Function {
            name: "bad".into(),
            entry: 0,
            nregs: 1,
            blocks: vec![Block {
                name: "b".into(),
                tag: CodeTag::Compute,
                insts: vec![Inst::Alu {
                    op: AluOp::Add,
                    dst: 5,
                    a: Operand::Imm(0),
                    b: Operand::Imm(0),
                }],
                term: Term::Halt,
            }],
        };
        assert!(verify(&f).is_err());
    }

    #[test]
    fn zero_byte_aload_caught() {
        let f = Function {
            name: "bad".into(),
            entry: 0,
            nregs: 1,
            blocks: vec![Block {
                name: "b".into(),
                tag: CodeTag::Compute,
                insts: vec![Inst::Aload {
                    id: Operand::Imm(0),
                    base: Operand::Imm(0),
                    off: 0,
                    bytes: 0,
                    spm_off: 0,
                    resume: 0,
                }],
                term: Term::Halt,
            }],
        };
        assert!(verify(&f).is_err());
    }
}
