//! CoroIR — the SSA-lite virtual-register IR the CoroAMU compiler targets.
//!
//! This plays the role LLVM IR plays in the paper: the AsyncMark/AsyncSplit
//! passes (`crate::compiler`) lower annotated loop kernels to CoroIR control
//! flow, and the NH-G simulator (`crate::sim`) executes CoroIR directly —
//! each instruction models one machine instruction of the (RV64 + AMI
//! extension) target.
//!
//! Values are untyped 64-bit words; float ops interpret them as f64 bits.
//! Memory operations carry an [`AddrSpace`] (the paper uses LLVM address
//! spaces to distinguish remote regions, §III-G) and blocks carry a
//! [`CodeTag`] used for the cycle-attribution breakdowns of Figs 3/14.

pub mod builder;
pub mod printer;
pub mod verify;

/// Virtual register index.
pub type Reg = u32;

/// Basic block index within a [`Function`].
pub type BlockId = u32;

/// Address spaces. `Remote` models disaggregated/far memory (the paper's
/// `remote_alloc` / `_builtin_is_remote` annotations); `Spm` is the
/// AMU scratchpad carved out of L2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AddrSpace {
    Local,
    Remote,
    Spm,
}

/// Code-region tag for stall/cycle attribution (Figs 3 and 14).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodeTag {
    /// Original loop-body computation.
    Compute,
    /// Scheduler blocks (poll + dispatch next coroutine).
    Scheduler,
    /// Context save/restore around suspension points.
    CtxSwitch,
    /// One-time setup (alloca/init blocks).
    Init,
    /// Coroutine lifecycle management (return block, launch, recycle).
    Lifecycle,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    Sra,
    /// Set-if-less-than (signed): dst = (a < b) as i64.
    Slt,
    /// Set-if-less-than (unsigned).
    SltU,
    Seq,
    Sne,
    Min,
    Max,
    /// A single-instruction mixing hash (models the benchmark's inlined
    /// hash function, e.g. multiplicative hashing in HJ/GUPS).
    Hash,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaluOp {
    FAdd,
    FSub,
    FMul,
    FDiv,
    FMin,
    FMax,
    /// dst = (a < b) as i64 (comparison on f64 bits).
    FLt,
    /// Convert i64 -> f64 bits.
    IToF,
    /// Convert f64 bits -> i64 (truncating).
    FToI,
}

/// Access width in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Width {
    W1,
    W2,
    W4,
    W8,
}

impl Width {
    pub fn bytes(self) -> u32 {
        match self {
            Width::W1 => 1,
            Width::W2 => 2,
            Width::W4 => 4,
            Width::W8 => 8,
        }
    }
}

/// Instruction operand: register or immediate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Operand {
    Reg(Reg),
    Imm(i64),
}

impl Operand {
    pub fn as_reg(self) -> Option<Reg> {
        match self {
            Operand::Reg(r) => Some(r),
            Operand::Imm(_) => None,
        }
    }
}

/// Non-terminator instructions.
#[derive(Debug, Clone, PartialEq)]
pub enum Inst {
    Alu { op: AluOp, dst: Reg, a: Operand, b: Operand },
    Falu { op: FaluOp, dst: Reg, a: Operand, b: Operand },
    Load { dst: Reg, base: Operand, off: i64, width: Width, space: AddrSpace },
    Store { val: Operand, base: Operand, off: i64, width: Width, space: AddrSpace },
    /// Atomic read-modify-write `dst = old; [base+off] = old op val`.
    AtomicRmw { op: AluOp, dst: Reg, val: Operand, base: Operand, off: i64, width: Width, space: AddrSpace },
    /// Software prefetch into the cache hierarchy (non-binding, occupies an
    /// MSHR while in flight — the static-scheduler issue interface).
    Prefetch { base: Operand, off: i64, space: AddrSpace },
    /// AMU decoupled load: move `bytes` from `[base+off]` (remote) into the
    /// SPM slot for `id` at byte offset `spm_off` (sub-slot placement for
    /// aggregated requests, §IV-B). `resume` is the coroutine resumption
    /// block bound to the request (encoded in high-order address bits on
    /// real hardware, §III-D); consumed by `bafin`.
    Aload { id: Operand, base: Operand, off: i64, bytes: u32, spm_off: u32, resume: BlockId },
    /// AMU decoupled store: move `bytes` from the SPM slot for `id` (at
    /// `spm_off`) to `[base+off]` (remote).
    Astore { id: Operand, base: Operand, off: i64, bytes: u32, spm_off: u32, resume: BlockId },
    /// Bind the next `n` aload/astore requests to `id`; completion is
    /// reported only when all have finished (§III-C / §IV-B).
    Aset { id: Operand, n: Operand },
    /// Poll the Finished Queue: dst = completed id, or -1 if none.
    Getfin { dst: Reg },
    /// Configure the handler-array base/size hardware registers (§III-D).
    Aconfig { base: Operand, size: Operand },
    /// Register `id` as hung (non-access request-table entry, §IV-C).
    /// `resume` is where the coroutine continues once signalled.
    Await { id: Operand, resume: BlockId },
    /// Complete a pending `await` with this id, making it visible to
    /// getfin/bafin.
    Asignal { id: Operand },
}

/// Block terminators.
#[derive(Debug, Clone, PartialEq)]
pub enum Term {
    /// Conditional branch: taken (to `then_`) iff `cond != 0`.
    Br { cond: Operand, then_: BlockId, else_: BlockId },
    Jmp(BlockId),
    /// Indirect jump: `target` holds a BlockId as an integer value. The
    /// dynamic getfin scheduler and the static FIFO scheduler both resume
    /// coroutines through this — the mispredict-prone jump of §III-D.
    IndirectJmp { target: Operand },
    /// `bafin`: if the Finished Queue holds a completed id, pop it, write
    /// the handler address (aconfig base + id*size) into `handler_dst`,
    /// write the id into `id_dst`, and jump to the request's bound resume
    /// block; otherwise fall through. Predicted via the BPT oracle.
    Bafin { handler_dst: Reg, id_dst: Reg, fallthrough: BlockId },
    /// End of program.
    Halt,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub name: String,
    pub tag: CodeTag,
    pub insts: Vec<Inst>,
    pub term: Term,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Function {
    pub name: String,
    pub blocks: Vec<Block>,
    pub entry: BlockId,
    /// Number of virtual registers (registers are dense `0..nregs`).
    pub nregs: u32,
}

impl Function {
    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id as usize]
    }

    /// Successor blocks of `id` (indirect jumps contribute no static edges).
    pub fn successors(&self, id: BlockId) -> Vec<BlockId> {
        match &self.blocks[id as usize].term {
            Term::Br { then_, else_, .. } => vec![*then_, *else_],
            Term::Jmp(t) => vec![*t],
            Term::IndirectJmp { .. } => vec![],
            Term::Bafin { fallthrough, .. } => vec![*fallthrough],
            Term::Halt => vec![],
        }
    }

    /// Total static instruction count (terminators count as one each).
    pub fn static_len(&self) -> usize {
        self.blocks.iter().map(|b| b.insts.len() + 1).sum()
    }
}

impl Inst {
    /// Registers read by this instruction.
    pub fn uses(&self, out: &mut Vec<Reg>) {
        let mut op = |o: &Operand| {
            if let Operand::Reg(r) = o {
                out.push(*r);
            }
        };
        match self {
            Inst::Alu { a, b, .. } | Inst::Falu { a, b, .. } => {
                op(a);
                op(b);
            }
            Inst::Load { base, .. } | Inst::Prefetch { base, .. } => op(base),
            Inst::Store { val, base, .. } => {
                op(val);
                op(base);
            }
            Inst::AtomicRmw { val, base, .. } => {
                op(val);
                op(base);
            }
            Inst::Aload { id, base, .. } | Inst::Astore { id, base, .. } => {
                op(id);
                op(base);
            }
            Inst::Aset { id, n } => {
                op(id);
                op(n);
            }
            Inst::Getfin { .. } => {}
            Inst::Aconfig { base, size } => {
                op(base);
                op(size);
            }
            Inst::Await { id, .. } | Inst::Asignal { id } => op(id),
        }
    }

    /// Register written by this instruction, if any.
    pub fn def(&self) -> Option<Reg> {
        match self {
            Inst::Alu { dst, .. }
            | Inst::Falu { dst, .. }
            | Inst::Load { dst, .. }
            | Inst::AtomicRmw { dst, .. }
            | Inst::Getfin { dst } => Some(*dst),
            _ => None,
        }
    }

    /// Whether this is a memory-subsystem operation (for LSQ accounting).
    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Inst::Load { .. } | Inst::Store { .. } | Inst::AtomicRmw { .. } | Inst::Prefetch { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add(dst: Reg, a: Operand, b: Operand) -> Inst {
        Inst::Alu { op: AluOp::Add, dst, a, b }
    }

    #[test]
    fn uses_and_defs() {
        let i = add(3, Operand::Reg(1), Operand::Imm(5));
        let mut u = vec![];
        i.uses(&mut u);
        assert_eq!(u, vec![1]);
        assert_eq!(i.def(), Some(3));

        let s = Inst::Store {
            val: Operand::Reg(2),
            base: Operand::Reg(4),
            off: 8,
            width: Width::W8,
            space: AddrSpace::Remote,
        };
        let mut u = vec![];
        s.uses(&mut u);
        assert_eq!(u, vec![2, 4]);
        assert_eq!(s.def(), None);
        assert!(s.is_mem());
    }

    #[test]
    fn successors() {
        let f = Function {
            name: "t".into(),
            entry: 0,
            nregs: 1,
            blocks: vec![
                Block {
                    name: "b0".into(),
                    tag: CodeTag::Compute,
                    insts: vec![],
                    term: Term::Br { cond: Operand::Reg(0), then_: 1, else_: 2 },
                },
                Block { name: "b1".into(), tag: CodeTag::Compute, insts: vec![], term: Term::Jmp(2) },
                Block { name: "b2".into(), tag: CodeTag::Compute, insts: vec![], term: Term::Halt },
            ],
        };
        assert_eq!(f.successors(0), vec![1, 2]);
        assert_eq!(f.successors(1), vec![2]);
        assert!(f.successors(2).is_empty());
        assert_eq!(f.static_len(), 3);
    }

    #[test]
    fn width_bytes() {
        assert_eq!(Width::W1.bytes(), 1);
        assert_eq!(Width::W8.bytes(), 8);
    }
}
