//! Human-readable CoroIR disassembly (for debugging and golden tests).

use super::*;
use std::fmt::Write;

fn op_str(o: &Operand) -> String {
    match o {
        Operand::Reg(r) => format!("r{r}"),
        Operand::Imm(v) => format!("{v}"),
    }
}

fn space_str(s: AddrSpace) -> &'static str {
    match s {
        AddrSpace::Local => "local",
        AddrSpace::Remote => "remote",
        AddrSpace::Spm => "spm",
    }
}

pub fn inst_to_string(i: &Inst) -> String {
    match i {
        Inst::Alu { op, dst, a, b } => format!("r{dst} = {op:?} {}, {}", op_str(a), op_str(b)),
        Inst::Falu { op, dst, a, b } => format!("r{dst} = {op:?} {}, {}", op_str(a), op_str(b)),
        Inst::Load { dst, base, off, width, space } => {
            format!("r{dst} = load.{} {}[{}+{off}]", width.bytes(), space_str(*space), op_str(base))
        }
        Inst::Store { val, base, off, width, space } => {
            format!("store.{} {} -> {}[{}+{off}]", width.bytes(), op_str(val), space_str(*space), op_str(base))
        }
        Inst::AtomicRmw { op, dst, val, base, off, width, space } => {
            let w = width.bytes();
            let sp = space_str(*space);
            let b = op_str(base);
            let v = op_str(val);
            format!("r{dst} = atomic.{op:?}.{w} {sp}[{b}+{off}], {v}")
        }
        Inst::Prefetch { base, off, space } => {
            format!("prefetch {}[{}+{off}]", space_str(*space), op_str(base))
        }
        Inst::Aload { id, base, off, bytes, spm_off, resume } => {
            format!("aload id={} [{}+{off}] bytes={bytes} spm+{spm_off} resume=bb{resume}", op_str(id), op_str(base))
        }
        Inst::Astore { id, base, off, bytes, spm_off, resume } => {
            format!("astore id={} [{}+{off}] bytes={bytes} spm+{spm_off} resume=bb{resume}", op_str(id), op_str(base))
        }
        Inst::Aset { id, n } => format!("aset id={} n={}", op_str(id), op_str(n)),
        Inst::Getfin { dst } => format!("r{dst} = getfin"),
        Inst::Aconfig { base, size } => format!("aconfig base={} size={}", op_str(base), op_str(size)),
        Inst::Await { id, resume } => format!("await id={} resume=bb{resume}", op_str(id)),
        Inst::Asignal { id } => format!("asignal id={}", op_str(id)),
    }
}

pub fn term_to_string(t: &Term) -> String {
    match t {
        Term::Br { cond, then_, else_ } => format!("br {} ? bb{then_} : bb{else_}", op_str(cond)),
        Term::Jmp(t) => format!("jmp bb{t}"),
        Term::IndirectJmp { target } => format!("ijmp {}", op_str(target)),
        Term::Bafin { handler_dst, id_dst, fallthrough } => {
            format!("bafin hdl->r{handler_dst} id->r{id_dst} else bb{fallthrough}")
        }
        Term::Halt => "halt".into(),
    }
}

pub fn function_to_string(f: &Function) -> String {
    let mut out = String::new();
    writeln!(out, "fn {} (entry=bb{}, regs={})", f.name, f.entry, f.nregs).unwrap();
    for (i, b) in f.blocks.iter().enumerate() {
        writeln!(out, "bb{i} <{}> [{:?}]:", b.name, b.tag).unwrap();
        for inst in &b.insts {
            writeln!(out, "  {}", inst_to_string(inst)).unwrap();
        }
        writeln!(out, "  {}", term_to_string(&b.term)).unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;

    #[test]
    fn prints_all_forms() {
        let mut b = FuncBuilder::new("p");
        let r = b.imm(7);
        let x = b.load(Operand::Reg(r), 8, Width::W8, AddrSpace::Remote);
        b.store(Operand::Reg(x), Operand::Reg(r), 0, Width::W4, AddrSpace::Local);
        b.push(Inst::Prefetch { base: Operand::Reg(r), off: 0, space: AddrSpace::Remote });
        b.push(Inst::Aload { id: Operand::Imm(3), base: Operand::Reg(r), off: 0, bytes: 64, spm_off: 0, resume: 0 });
        b.push(Inst::Aset { id: Operand::Imm(3), n: Operand::Imm(2) });
        b.push(Inst::Getfin { dst: x });
        b.push(Inst::Await { id: Operand::Imm(1), resume: 0 });
        b.push(Inst::Asignal { id: Operand::Imm(1) });
        b.halt();
        let s = function_to_string(&b.build());
        for needle in ["aload", "aset", "getfin", "await", "asignal", "prefetch", "load.8 remote", "halt"] {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }
}
