//! Imperative builder API for constructing CoroIR functions.

use super::*;

/// Builder for a [`Function`]. Blocks are created up-front (possibly as
/// forward references) and filled in any order; the builder tracks a
/// current insertion block.
pub struct FuncBuilder {
    name: String,
    blocks: Vec<Block>,
    sealed: Vec<bool>,
    cur: BlockId,
    next_reg: Reg,
}

impl FuncBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        let entry = Block {
            name: "entry".into(),
            tag: CodeTag::Init,
            insts: Vec::new(),
            term: Term::Halt,
        };
        Self {
            name: name.into(),
            blocks: vec![entry],
            sealed: vec![false],
            cur: 0,
            next_reg: 0,
        }
    }

    pub fn entry(&self) -> BlockId {
        0
    }

    pub fn reg(&mut self) -> Reg {
        let r = self.next_reg;
        self.next_reg += 1;
        r
    }

    pub fn new_block(&mut self, name: impl Into<String>, tag: CodeTag) -> BlockId {
        self.blocks.push(Block { name: name.into(), tag, insts: Vec::new(), term: Term::Halt });
        self.sealed.push(false);
        (self.blocks.len() - 1) as BlockId
    }

    pub fn switch_to(&mut self, b: BlockId) {
        assert!(!self.sealed[b as usize], "block {b} already sealed");
        self.cur = b;
    }

    pub fn current(&self) -> BlockId {
        self.cur
    }

    pub fn current_tag(&self) -> CodeTag {
        self.blocks[self.cur as usize].tag
    }

    pub fn push(&mut self, inst: Inst) {
        assert!(!self.sealed[self.cur as usize], "pushing into sealed block {}", self.cur);
        self.blocks[self.cur as usize].insts.push(inst);
    }

    /// Seal the current block with a terminator.
    pub fn terminate(&mut self, term: Term) {
        assert!(!self.sealed[self.cur as usize], "block {} already sealed", self.cur);
        self.blocks[self.cur as usize].term = term;
        self.sealed[self.cur as usize] = true;
    }

    // ----- convenience emitters -----

    pub fn alu(&mut self, op: AluOp, a: Operand, b: Operand) -> Reg {
        let dst = self.reg();
        self.push(Inst::Alu { op, dst, a, b });
        dst
    }

    pub fn alu_into(&mut self, dst: Reg, op: AluOp, a: Operand, b: Operand) {
        self.push(Inst::Alu { op, dst, a, b });
    }

    pub fn falu(&mut self, op: FaluOp, a: Operand, b: Operand) -> Reg {
        let dst = self.reg();
        self.push(Inst::Falu { op, dst, a, b });
        dst
    }

    pub fn mov(&mut self, dst: Reg, v: Operand) {
        self.push(Inst::Alu { op: AluOp::Add, dst, a: v, b: Operand::Imm(0) });
    }

    pub fn imm(&mut self, v: i64) -> Reg {
        let dst = self.reg();
        self.mov(dst, Operand::Imm(v));
        dst
    }

    pub fn load(&mut self, base: Operand, off: i64, width: Width, space: AddrSpace) -> Reg {
        let dst = self.reg();
        self.push(Inst::Load { dst, base, off, width, space });
        dst
    }

    pub fn load_into(&mut self, dst: Reg, base: Operand, off: i64, width: Width, space: AddrSpace) {
        self.push(Inst::Load { dst, base, off, width, space });
    }

    pub fn store(&mut self, val: Operand, base: Operand, off: i64, width: Width, space: AddrSpace) {
        self.push(Inst::Store { val, base, off, width, space });
    }

    pub fn jmp(&mut self, target: BlockId) {
        self.terminate(Term::Jmp(target));
    }

    pub fn br(&mut self, cond: Operand, then_: BlockId, else_: BlockId) {
        self.terminate(Term::Br { cond, then_, else_ });
    }

    pub fn halt(&mut self) {
        self.terminate(Term::Halt);
    }

    /// Finish construction. Panics if any block lacks a terminator.
    pub fn build(self) -> Function {
        for (i, sealed) in self.sealed.iter().enumerate() {
            assert!(*sealed, "block {} ({}) was never terminated", i, self.blocks[i].name);
        }
        Function { name: self.name, blocks: self.blocks, entry: 0, nregs: self.next_reg }
    }

    /// Number of registers allocated so far.
    pub fn reg_count(&self) -> u32 {
        self.next_reg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_simple_loop() {
        // i = 0; while (i < 10) i++;
        let mut b = FuncBuilder::new("loop10");
        let i = b.imm(0);
        let head = b.new_block("head", CodeTag::Compute);
        let body = b.new_block("body", CodeTag::Compute);
        let exit = b.new_block("exit", CodeTag::Compute);
        b.jmp(head);
        b.switch_to(head);
        let c = b.alu(AluOp::Slt, Operand::Reg(i), Operand::Imm(10));
        b.br(Operand::Reg(c), body, exit);
        b.switch_to(body);
        b.alu_into(i, AluOp::Add, Operand::Reg(i), Operand::Imm(1));
        b.jmp(head);
        b.switch_to(exit);
        b.halt();
        let f = b.build();
        assert_eq!(f.blocks.len(), 4);
        assert_eq!(f.successors(1), vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "never terminated")]
    fn unterminated_block_panics() {
        let mut b = FuncBuilder::new("bad");
        let _x = b.new_block("x", CodeTag::Compute);
        b.halt(); // entry terminated, "x" not
        b.build();
    }

    #[test]
    #[should_panic(expected = "already sealed")]
    fn double_terminate_panics() {
        let mut b = FuncBuilder::new("bad");
        b.halt();
        b.halt();
    }

    #[test]
    fn regs_are_dense() {
        let mut b = FuncBuilder::new("r");
        let r0 = b.reg();
        let r1 = b.reg();
        assert_eq!((r0, r1), (0, 1));
        b.halt();
        assert_eq!(b.build().nregs, 2);
    }
}
