//! SLO-aware request serving under overload (`sim::service`).
//!
//! Every benchmark in this repo is a batch kernel; this module is the
//! open-loop *service* view of the same kernel: a deterministic seeded
//! arrival process (exponential inter-arrival gaps, optionally modulated
//! by an on/off burst window — [`crate::util::rng::Exp`] /
//! [`crate::util::rng::BurstyExp`]) offers timestamped requests — each a
//! Zipf-skewed multi-key probe of the kernel's keyspace — into a
//! **bounded admission queue** drained by a pool of handler coroutines.
//!
//! Service mode is a simulate-time axis like latency/policy/fabric/
//! cores/faults before it: the ordinary batch run executes unchanged
//! (the compiled bench kernel *is* the request handler, compiled once
//! through the kernel cache against the dataset cache), and its result
//! is the **calibration**: `capacity_cost = cycles / tasks_completed`
//! is the per-request service cost under the active (latency, policy,
//! fabric, faults) configuration — heavy faults inflate the cost and
//! move the saturation knee, which is exactly the latency-aware
//! coupling the service figures need. [`simulate`] then replays a
//! deterministic discrete-event queueing run over that cost and writes
//! the `svc_*` counters into [`RunStats`].
//!
//! Offered load is expressed as **percent of measured capacity**, so
//! the knee is self-normalizing: `load:100` offers exactly the
//! calibrated service rate, `load:200` is 2× the knee, independent of
//! which fabric/fault/policy combination produced the cost.
//!
//! The robustness layer (`shed = true`, the default) is the headline:
//!
//! * **Backpressure**: a request arriving at a full admission queue is
//!   rejected outright (`svc_rejected`).
//! * **Expired-in-queue shedding**: an admitted request whose deadline
//!   has already passed when a handler would pick it up is shed without
//!   service (`svc_shed_expired`).
//! * **Degraded mode**: an occupancy detector samples the queue once
//!   per arrival; `hysteresis` consecutive samples at or above the high
//!   watermark trip the server into degraded mode — handlers switch to
//!   a cheap-path handler at a quarter of the full cost — and
//!   `hysteresis` consecutive samples at or below the low watermark
//!   recover it. Spells and cheap-path serves are counted.
//!
//! Goodput (served **and** met the deadline) is kept strictly separate
//! from throughput: `svc_goodput` vs `svc_served`, with
//! `svc_timed_out` the served-too-late remainder. Sojourn percentiles
//! (p50/p99/p99.9) come from a [`LatencyHist`] sized to cover the
//! worst-case backlog, so shed-off collapse stays measurable.
//!
//! With `shed = false` the whole robustness layer is off — unbounded
//! queue, no expiry, no degraded mode — the ablation arm that shows
//! collapsing goodput and unbounded queue growth past the knee.
//!
//! Determinism: arrivals, key draws and the event loop are pure
//! functions of (`ServiceConfig`, calibrated cost). Key draws are
//! consumed at arrival in issue order regardless of the admission
//! outcome, so a rejection never shifts later draws. Service-off runs
//! never construct any of this — bit-identity to the seed is by
//! construction, pinned by `service_off_is_bit_identical_to_seed`.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

use super::fabric::LatencyHist;
use super::stats::RunStats;
use super::trace::{EventKind, Trace};
use crate::util::rng::{BurstyExp, Exp, Rng, Zipf};

/// Seed of the arrival/key stream when none is configured.
pub const DEFAULT_SERVICE_SEED: u64 = 0x5EED_5E81;

/// Service-mode configuration: the offered-load axis plus the knobs of
/// the robustness layer. `load_pct == 0` means service mode is off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceConfig {
    /// Offered load as a percent of the calibrated capacity
    /// (100 = at the saturation knee; 0 = service mode off).
    pub load_pct: u32,
    /// Total offered arrivals.
    pub requests: u32,
    /// Admission-queue capacity (bounded only while `shed` is on).
    pub queue_cap: u32,
    /// Per-request deadline, as a multiple of the calibrated cost.
    pub deadline_mult: u32,
    /// Handler-coroutine fanout. Each of the `fanout` handlers serves a
    /// request in `fanout × cost` cycles, so aggregate capacity stays
    /// `1/cost` regardless of fanout (matching the calibration run).
    pub fanout: u32,
    /// Master switch of the robustness layer: bounded queue +
    /// queue-full rejection + expired-in-queue shedding + the degraded-
    /// mode overload detector. Off = plain unbounded open-loop FIFO.
    pub shed: bool,
    /// Burst rate multiplier inside the on-window (1 = plain Poisson).
    pub burst_factor: u32,
    /// On-window share of each burst period, percent (only meaningful
    /// when `burst_factor > 1`).
    pub burst_duty_pct: u32,
    /// Burst period, in units of the mean inter-arrival gap.
    pub burst_period: u32,
    /// Keys probed per request.
    pub keys: u32,
    /// Zipf exponent of the key draw.
    pub theta: f64,
    /// Number of distinct keys.
    pub keyspace: u64,
    /// Keys `< hot_keys` form the hot set: a request whose every key is
    /// hot is served at half cost (cache-resident probe).
    pub hot_keys: u64,
    /// Degraded-mode trip watermark, percent of `queue_cap`.
    pub degrade_hi_pct: u32,
    /// Degraded-mode recovery watermark, percent of `queue_cap`.
    pub degrade_lo_pct: u32,
    /// Consecutive occupancy samples required to trip or recover.
    pub hysteresis: u32,
    /// Seed of the arrival/key stream.
    pub seed: u64,
}

impl ServiceConfig {
    /// Shared defaults of every preset. The geometry is chosen so the
    /// robustness layer is *sound* at the defaults: with `queue_cap` 8,
    /// `fanout` 4 and `deadline_mult` 16, the worst-case sojourn of an
    /// admitted request is `(ceil(8/4) + 1) × 4 × cost = 12 × cost`,
    /// strictly inside the deadline — so with shedding on, every
    /// admitted request meets its SLO and overload shows up as
    /// backpressure rejections, not as silent timeout collapse.
    fn base() -> ServiceConfig {
        ServiceConfig {
            load_pct: 0,
            requests: 2000,
            queue_cap: 8,
            deadline_mult: 16,
            fanout: 4,
            shed: true,
            burst_factor: 1,
            burst_duty_pct: 25,
            burst_period: 64,
            keys: 4,
            theta: 0.99,
            keyspace: 65_536,
            hot_keys: 256,
            degrade_hi_pct: 60,
            degrade_lo_pct: 25,
            hysteresis: 3,
            seed: DEFAULT_SERVICE_SEED,
        }
    }

    /// Service mode off (the default everywhere).
    pub fn off() -> ServiceConfig {
        Self::base()
    }

    /// Comfortable utilization: 60% of the knee.
    pub fn steady() -> ServiceConfig {
        ServiceConfig { load_pct: 60, ..Self::base() }
    }

    /// Exactly at the measured saturation knee.
    pub fn knee() -> ServiceConfig {
        ServiceConfig { load_pct: 100, ..Self::base() }
    }

    /// 2× the knee: the graceful-degradation acceptance point.
    pub fn overload() -> ServiceConfig {
        ServiceConfig { load_pct: 200, ..Self::base() }
    }

    /// Bursty near-saturation traffic: 90% average load, but the
    /// on-window runs 4× faster — transient overload inside a run that
    /// is sustainable on average.
    pub fn burst() -> ServiceConfig {
        ServiceConfig { load_pct: 90, burst_factor: 4, ..Self::base() }
    }

    pub fn enabled(&self) -> bool {
        self.load_pct > 0
    }

    /// Parse a CLI spec: `off|steady|knee|overload|burst|load:PCT`.
    pub fn parse(spec: &str) -> Result<ServiceConfig> {
        let s = spec.trim();
        Ok(match s {
            "off" => Self::off(),
            "steady" => Self::steady(),
            "knee" => Self::knee(),
            "overload" => Self::overload(),
            "burst" => Self::burst(),
            _ => {
                if let Some(v) = s.strip_prefix("load:") {
                    let pct: u32 = v
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad load percent '{v}' in service spec"))?;
                    ensure!(pct > 0, "service load:PCT must be positive (0 is spelled 'off')");
                    ServiceConfig { load_pct: pct, ..Self::steady() }
                } else {
                    return Err(crate::util::keyed::unknown_key::<Self>(spec));
                }
            }
        })
    }

    /// Canonical label, round-tripping through [`ServiceConfig::parse`]
    /// for every preset and plain `load:PCT` spec; key-by-key TOML
    /// assemblies that match no spec report as `custom`.
    pub fn label(&self) -> String {
        for (cfg, name) in [
            (Self::off(), "off"),
            (Self::steady(), "steady"),
            (Self::knee(), "knee"),
            (Self::overload(), "overload"),
            (Self::burst(), "burst"),
        ] {
            if *self == cfg {
                return name.to_string();
            }
        }
        if *self == (ServiceConfig { load_pct: self.load_pct, ..Self::steady() }) {
            return format!("load:{}", self.load_pct);
        }
        "custom".to_string()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.load_pct <= 10_000, "service.load must be <= 10000 (percent of capacity)");
        ensure!(
            (1..=1_000_000).contains(&self.requests),
            "service.requests must be in [1, 1000000]"
        );
        ensure!((1..=1 << 20).contains(&self.queue_cap), "service.queue_cap must be in [1, 2^20]");
        ensure!(
            (1..=1 << 20).contains(&self.deadline_mult),
            "service.deadline must be in [1, 2^20]"
        );
        ensure!((1..=4096).contains(&self.fanout), "service.fanout must be in [1, 4096]");
        ensure!(
            (1..=1024).contains(&self.burst_factor),
            "service.burst_factor must be in [1, 1024]"
        );
        if self.burst_factor > 1 {
            ensure!(
                (1..=99).contains(&self.burst_duty_pct),
                "service.burst_duty must be in [1, 99] (percent of the period)"
            );
            ensure!(
                (2..=1 << 20).contains(&self.burst_period),
                "service.burst_period must be in [2, 2^20] mean gaps"
            );
        }
        ensure!((1..=64).contains(&self.keys), "service.keys must be in [1, 64]");
        ensure!(
            self.theta > 0.0 && self.theta <= 10.0 && (self.theta - 1.0).abs() > 1e-9,
            "service.theta must be in (0, 10] and != 1"
        );
        ensure!(self.keyspace >= 2, "service.keyspace must be >= 2");
        ensure!(
            self.hot_keys >= 1 && self.hot_keys <= self.keyspace,
            "service.hot_keys must be in [1, keyspace]"
        );
        ensure!(
            (1..=100).contains(&self.degrade_hi_pct),
            "service.degrade_hi must be in [1, 100] (percent of queue_cap)"
        );
        ensure!(
            self.degrade_lo_pct < self.degrade_hi_pct,
            "service.degrade_lo must be below service.degrade_hi"
        );
        ensure!((1..=1024).contains(&self.hysteresis), "service.hysteresis must be in [1, 1024]");
        Ok(())
    }
}

impl crate::util::keyed::Keyed for ServiceConfig {
    const AXIS: &'static str = "service spec";
    const EXPECTED: &'static str = "off, steady, knee, overload, burst, load:PCT";

    fn parse_keyed(s: &str) -> Result<Self> {
        ServiceConfig::parse(s)
    }

    fn label_keyed(&self) -> String {
        self.label()
    }

    /// The named presets (`load:PCT` covers the continuum between them).
    fn all_keyed() -> Vec<Self> {
        vec![Self::off(), Self::steady(), Self::knee(), Self::overload(), Self::burst()]
    }
}

/// Strict goodput-vs-throughput accounting of one service replay. Every
/// field is an exact integer; [`simulate`] copies them into the
/// `svc_*` fields of [`RunStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServiceStats {
    /// Calibrated per-request cost (cycles) — the saturation knee.
    pub capacity_cost: u64,
    pub offered: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub shed_expired: u64,
    pub served: u64,
    pub goodput: u64,
    pub timed_out: u64,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
    pub max_queue: u64,
    pub degraded_served: u64,
    pub degraded_spells: u64,
}

/// The calibrated per-request service cost of a batch run: mean cycles
/// per completed task under the active (latency, policy, fabric,
/// faults) configuration; never 0 so it can serve as a divisor and a
/// rate.
pub fn capacity_cost(stats: &RunStats) -> u64 {
    (stats.cycles / stats.tasks_completed.max(1)).max(1)
}

struct Req {
    arrival: u64,
    deadline: u64,
    hot: bool,
}

struct Costs {
    full: u64,
    hot: u64,
    cheap: u64,
}

/// Hand every request a free handler can start no later than `now` to
/// the earliest-free handler (lowest index wins ties, so the loop is
/// deterministic), shedding admitted requests whose deadline already
/// expired in the queue when the robustness layer is on. Terminates:
/// each iteration pops one queued request or breaks.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    now: u64,
    servers: &mut [u64],
    queue: &mut VecDeque<Req>,
    degraded: bool,
    costs: &Costs,
    shed: bool,
    st: &mut ServiceStats,
    hist: &mut LatencyHist,
) {
    loop {
        let Some(head) = queue.front() else { break };
        let (mut idx, mut free) = (0usize, servers[0]);
        for (i, &f) in servers.iter().enumerate().skip(1) {
            if f < free {
                idx = i;
                free = f;
            }
        }
        let start = free.max(head.arrival);
        if start > now {
            break;
        }
        let req = queue.pop_front().unwrap();
        if shed && start > req.deadline {
            st.shed_expired += 1;
            continue;
        }
        let cost = if degraded {
            costs.cheap
        } else if req.hot {
            costs.hot
        } else {
            costs.full
        };
        let fin = start + cost * servers.len() as u64;
        servers[idx] = fin;
        hist.record(fin - req.arrival);
        st.served += 1;
        if degraded {
            st.degraded_served += 1;
        }
        if fin <= req.deadline {
            st.goodput += 1;
        } else {
            st.timed_out += 1;
        }
    }
}

/// Replay the open-loop service run over the calibrated cost of the
/// batch run whose stats are in `stats`, then write the `svc_*`
/// counters back into it. A disabled config is a strict no-op. Always
/// terminates: the arrival loop is bounded by `requests` and the final
/// drain strictly shrinks the queue — no handler can wedge.
pub fn simulate(svc: &ServiceConfig, stats: &mut RunStats) -> ServiceStats {
    simulate_traced(svc, stats, None)
}

/// [`simulate`] with an optional trace sink: admission-control
/// transitions (reject, shed-expired, degrade enter/exit) are pushed
/// as service-class events on the arrival clock (DESIGN.md §14). The
/// `None` path is exactly `simulate` — the replay itself never reads
/// the tracer, so traced and untraced runs produce identical `svc_*`
/// counters by construction.
pub fn simulate_traced(
    svc: &ServiceConfig,
    stats: &mut RunStats,
    mut trace: Option<&mut Trace>,
) -> ServiceStats {
    let mut st = ServiceStats::default();
    if !svc.enabled() {
        return st;
    }
    let cost_full = capacity_cost(stats);
    let costs =
        Costs { full: cost_full, hot: (cost_full / 2).max(1), cheap: (cost_full / 4).max(1) };
    // load_pct percent of capacity 1/cost => mean gap = cost * 100/load.
    let mean_gap = cost_full as f64 * 100.0 / svc.load_pct as f64;
    let exp = Exp::new(mean_gap);
    let bursty = (svc.burst_factor > 1).then(|| {
        BurstyExp::new(
            mean_gap,
            svc.burst_period as f64 * mean_gap,
            svc.burst_duty_pct as f64 / 100.0,
            svc.burst_factor as f64,
        )
    });
    let zipf = Zipf::new(svc.keyspace, svc.theta);
    let mut rng = Rng::new(svc.seed);
    let mut servers = vec![0u64; svc.fanout as usize];
    let mut queue: VecDeque<Req> = VecDeque::new();
    // Under shed-off overload the backlog can approach the whole
    // offered volume; size the sojourn histogram to cover it.
    let mut hist = LatencyHist::covering(
        cost_full.saturating_mul(svc.fanout as u64 + svc.requests as u64).max(1),
    );
    let deadline_len = cost_full.saturating_mul(svc.deadline_mult as u64);
    let hi = (svc.queue_cap as u64 * svc.degrade_hi_pct as u64 / 100).max(1);
    let lo = svc.queue_cap as u64 * svc.degrade_lo_pct as u64 / 100;
    let mut degraded = false;
    let mut above = 0u32;
    let mut below = 0u32;
    let mut clock = 0.0f64;
    for _ in 0..svc.requests {
        let gap = match &bursty {
            Some(b) => b.sample(clock, &mut rng),
            None => exp.sample(&mut rng),
        };
        clock += gap;
        let at = clock as u64;
        // Key draws happen at arrival in issue order regardless of the
        // admission outcome: a rejection never shifts later draws, so
        // the stream is a pure function of the offered sequence.
        let mut hot = true;
        for _ in 0..svc.keys {
            if zipf.sample(&mut rng) >= svc.hot_keys {
                hot = false;
            }
        }
        st.offered += 1;
        // Handlers that freed up since the last arrival take queued work
        // first (under the detector state that prevailed then).
        let shed0 = st.shed_expired;
        dispatch(at, &mut servers, &mut queue, degraded, &costs, svc.shed, &mut st, &mut hist);
        if svc.shed && queue.len() as u64 >= svc.queue_cap as u64 {
            st.rejected += 1;
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(at, 0, EventKind::SvcReject);
            }
        } else {
            st.accepted += 1;
            queue.push_back(Req {
                arrival: at,
                deadline: at.saturating_add(deadline_len),
                hot,
            });
            st.max_queue = st.max_queue.max(queue.len() as u64);
            dispatch(at, &mut servers, &mut queue, degraded, &costs, svc.shed, &mut st, &mut hist);
        }
        if let Some(tr) = trace.as_deref_mut() {
            for _ in shed0..st.shed_expired {
                tr.push(at, 0, EventKind::SvcShedExpired);
            }
        }
        // Overload detector: one occupancy sample per arrival, tripped
        // and recovered through `hysteresis` consecutive samples.
        if svc.shed {
            let occ = queue.len() as u64;
            if degraded {
                if occ <= lo {
                    below += 1;
                    if below >= svc.hysteresis {
                        degraded = false;
                        below = 0;
                        if let Some(tr) = trace.as_deref_mut() {
                            tr.push(at, 0, EventKind::SvcDegradeExit);
                        }
                    }
                } else {
                    below = 0;
                }
            } else if occ >= hi {
                above += 1;
                if above >= svc.hysteresis {
                    degraded = true;
                    st.degraded_spells += 1;
                    above = 0;
                    if let Some(tr) = trace.as_deref_mut() {
                        tr.push(at, 0, EventKind::SvcDegradeEnter);
                    }
                }
            } else {
                above = 0;
            }
        }
    }
    // Drain: every still-queued request is served or shed.
    let shed0 = st.shed_expired;
    let drain_t = clock as u64;
    dispatch(u64::MAX, &mut servers, &mut queue, degraded, &costs, svc.shed, &mut st, &mut hist);
    if let Some(tr) = trace.as_deref_mut() {
        for _ in shed0..st.shed_expired {
            tr.push(drain_t, 0, EventKind::SvcShedExpired);
        }
    }
    st.capacity_cost = cost_full;
    st.p50 = hist.percentile(0.50);
    st.p99 = hist.percentile(0.99);
    st.p999 = hist.percentile(0.999);
    stats.service = svc.label();
    stats.svc_capacity_cost = st.capacity_cost;
    stats.svc_offered = st.offered;
    stats.svc_accepted = st.accepted;
    stats.svc_rejected = st.rejected;
    stats.svc_shed_expired = st.shed_expired;
    stats.svc_served = st.served;
    stats.svc_goodput = st.goodput;
    stats.svc_timed_out = st.timed_out;
    stats.svc_p50 = st.p50;
    stats.svc_p99 = st.p99;
    stats.svc_p999 = st.p999;
    stats.svc_max_queue = st.max_queue;
    stats.svc_degraded_served = st.degraded_served;
    stats.svc_degraded_spells = st.degraded_spells;
    if let Some(tr) = trace {
        stats.trace_events = tr.total;
        stats.trace_dropped = tr.dropped;
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A calibration run with a per-request cost of exactly 1000 cycles.
    fn base_stats() -> RunStats {
        RunStats { cycles: 1_000_000, tasks_completed: 1000, ..Default::default() }
    }

    fn run(cfg: &ServiceConfig) -> ServiceStats {
        let mut s = base_stats();
        simulate(cfg, &mut s)
    }

    #[test]
    fn traced_replay_is_invisible_to_counters_and_accounts_transitions() {
        use crate::sim::stats::StallBuckets;
        use crate::sim::trace::{TraceConfig, Tracer};
        let cfg = ServiceConfig::parse("overload").unwrap();
        let mut plain = base_stats();
        let st_plain = simulate(&cfg, &mut plain);
        let mut traced = base_stats();
        let mut trace =
            Tracer::new(TraceConfig::on()).harvest(0, &StallBuckets::default(), "fifo", "fixed");
        let st_traced = simulate_traced(&cfg, &mut traced, Some(&mut trace));
        assert_eq!(st_plain, st_traced, "tracing must not perturb the replay");
        let count = |want: fn(&EventKind) -> bool| {
            trace.events.iter().filter(|e| want(&e.kind)).count() as u64
        };
        assert!(st_traced.rejected > 0, "overload preset must exercise rejection");
        assert_eq!(count(|k| matches!(k, EventKind::SvcReject)), st_traced.rejected);
        assert_eq!(count(|k| matches!(k, EventKind::SvcShedExpired)), st_traced.shed_expired);
        assert_eq!(count(|k| matches!(k, EventKind::SvcDegradeEnter)), st_traced.degraded_spells);
        assert_eq!(traced.trace_events, trace.total, "stats must track post-hoc service pushes");
    }

    fn assert_conservation(st: &ServiceStats, cfg: &ServiceConfig) {
        assert_eq!(st.offered, cfg.requests as u64, "every arrival is offered");
        assert_eq!(st.offered, st.accepted + st.rejected, "admission partitions offered");
        assert_eq!(st.accepted, st.served + st.shed_expired, "drain partitions accepted");
        assert_eq!(st.served, st.goodput + st.timed_out, "deadline partitions served");
        assert!(st.p50 <= st.p99 && st.p99 <= st.p999, "percentiles must be monotone");
    }

    #[test]
    fn preset_specs_parse_and_label_round_trip() {
        for spec in ["off", "steady", "knee", "overload", "burst", "load:150"] {
            let cfg = ServiceConfig::parse(spec).unwrap();
            assert_eq!(cfg.label(), spec, "label must round-trip through parse");
        }
        assert_eq!(ServiceConfig::parse("load:60").unwrap().label(), "steady");
        assert!(!ServiceConfig::off().enabled());
        assert!(ServiceConfig::overload().enabled());
        let mut custom = ServiceConfig::knee();
        custom.queue_cap = 32;
        assert_eq!(custom.label(), "custom");
        assert!(ServiceConfig::parse("bogus").is_err());
        assert!(ServiceConfig::parse("load:abc").is_err());
        assert!(ServiceConfig::parse("load:0").is_err());
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let cases: Vec<(ServiceConfig, &str)> = vec![
            (ServiceConfig { requests: 0, ..ServiceConfig::knee() }, "service.requests"),
            (ServiceConfig { queue_cap: 0, ..ServiceConfig::knee() }, "service.queue_cap"),
            (ServiceConfig { deadline_mult: 0, ..ServiceConfig::knee() }, "service.deadline"),
            (ServiceConfig { fanout: 0, ..ServiceConfig::knee() }, "service.fanout"),
            (ServiceConfig { load_pct: 20_000, ..ServiceConfig::knee() }, "service.load"),
            (ServiceConfig { theta: 1.0, ..ServiceConfig::knee() }, "service.theta"),
            (ServiceConfig { keys: 0, ..ServiceConfig::knee() }, "service.keys"),
            (ServiceConfig { hot_keys: 0, ..ServiceConfig::knee() }, "service.hot_keys"),
            (
                ServiceConfig { degrade_lo_pct: 80, ..ServiceConfig::knee() },
                "service.degrade_lo",
            ),
            (ServiceConfig { hysteresis: 0, ..ServiceConfig::knee() }, "service.hysteresis"),
            (
                ServiceConfig { burst_factor: 4, burst_duty_pct: 0, ..ServiceConfig::knee() },
                "service.burst_duty",
            ),
            (
                ServiceConfig { burst_factor: 4, burst_period: 1, ..ServiceConfig::knee() },
                "service.burst_period",
            ),
        ];
        for (cfg, needle) in cases {
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "expected '{needle}' in '{err}'");
        }
        for preset in [
            ServiceConfig::off(),
            ServiceConfig::steady(),
            ServiceConfig::knee(),
            ServiceConfig::overload(),
            ServiceConfig::burst(),
        ] {
            preset.validate().unwrap();
        }
    }

    #[test]
    fn off_simulate_is_a_total_noop() {
        let mut s = base_stats();
        let before = s.clone();
        let st = simulate(&ServiceConfig::off(), &mut s);
        assert_eq!(st, ServiceStats::default());
        assert_eq!(s, before, "service off must not touch the stats");
    }

    #[test]
    fn capacity_cost_is_pinned() {
        assert_eq!(capacity_cost(&base_stats()), 1000);
        assert_eq!(capacity_cost(&RunStats::default()), 1, "degenerate runs cost 1, never 0");
        let odd = RunStats { cycles: 10, tasks_completed: 3, ..Default::default() };
        assert_eq!(capacity_cost(&odd), 3);
    }

    #[test]
    fn simulate_is_deterministic_and_conserving() {
        for cfg in [
            ServiceConfig::steady(),
            ServiceConfig::knee(),
            ServiceConfig::overload(),
            ServiceConfig::burst(),
        ] {
            let mut a = base_stats();
            let mut b = base_stats();
            let sa = simulate(&cfg, &mut a);
            let sb = simulate(&cfg, &mut b);
            assert_eq!(sa, sb, "replay must be bit-identical ({})", cfg.label());
            assert_eq!(a, b);
            assert_eq!(a.service, cfg.label());
            assert_eq!(a.svc_capacity_cost, 1000);
            assert_conservation(&sa, &cfg);
        }
    }

    /// The acceptance pin: at 2× the measured knee, shedding ON keeps
    /// goodput >= 80% of peak with a structurally bounded p99 sojourn,
    /// while shedding OFF collapses — goodput craters and the queue
    /// grows without bound.
    #[test]
    fn graceful_degradation_at_twice_the_knee() {
        let cfg = ServiceConfig::overload();
        let peak = run(&ServiceConfig::steady()).goodput.max(run(&ServiceConfig::knee()).goodput);
        let over = run(&cfg);
        assert!(
            over.goodput * 10 >= peak * 8,
            "shed-on goodput {} must hold >= 80% of peak {}",
            over.goodput,
            peak
        );
        // Bounded sojourn: cap/fanout/deadline geometry bounds any
        // admitted request at (ceil(cap/fanout)+1) * fanout * cost.
        let cost = 1000u64;
        let rounds = (cfg.queue_cap as u64 + cfg.fanout as u64 - 1) / cfg.fanout as u64;
        let bound = (rounds + 1) * cfg.fanout as u64 * cost;
        assert!(over.p99 <= bound, "p99 {} must stay under {bound}", over.p99);
        assert!(over.max_queue <= cfg.queue_cap as u64, "queue must stay bounded");
        assert!(
            over.rejected + over.shed_expired + over.degraded_spells > 0,
            "the robustness layer must visibly engage at 2x the knee"
        );
        assert_conservation(&over, &cfg);

        let noshed = ServiceConfig { shed: false, ..cfg };
        let ns = run(&noshed);
        assert!(
            ns.goodput * 2 < peak,
            "shed-off goodput {} must collapse below half of peak {}",
            ns.goodput,
            peak
        );
        assert!(
            ns.max_queue > 4 * cfg.queue_cap as u64,
            "shed-off queue {} must grow far past the bounded cap",
            ns.max_queue
        );
        assert!(ns.timed_out > 0, "shed-off overload must blow deadlines");
        assert_eq!(ns.rejected, 0, "without shedding nothing is rejected");
        assert_eq!(ns.shed_expired, 0);
        assert_eq!(ns.accepted, ns.offered);
        assert!(ns.p99 >= over.p99, "unbounded queueing cannot beat the bounded p99");
        assert_conservation(&ns, &noshed);
    }

    #[test]
    fn overload_trips_degraded_mode() {
        let over = run(&ServiceConfig::overload());
        assert!(over.degraded_spells >= 2, "2x load must trip and re-trip the detector");
        assert!(over.degraded_served > 0, "degraded spells must serve on the cheap path");
        let steady = run(&ServiceConfig::steady());
        assert!(
            steady.degraded_spells <= over.degraded_spells,
            "comfortable load cannot out-trip overload"
        );
    }

    /// A deadline tighter than a single full service time forces both
    /// robustness outcomes deterministically: the very first served
    /// request already finishes past its deadline (fanout × cost > 1 ×
    /// cost), and queued requests at 3× load wait past expiry before a
    /// handler reaches them.
    #[test]
    fn deadline_pressure_sheds_and_times_out() {
        let cfg = ServiceConfig {
            deadline_mult: 1,
            ..ServiceConfig::parse("load:300").unwrap()
        };
        let st = run(&cfg);
        assert!(st.timed_out > 0, "a 1x-cost deadline cannot be met by a 4x-cost handler");
        assert!(st.shed_expired > 0, "queued requests at 3x load must expire in queue");
        assert_conservation(&st, &cfg);
    }

    #[test]
    fn burst_preset_stresses_the_queue() {
        let cfg = ServiceConfig::burst();
        let burst = run(&cfg);
        // ~90% average load is sustainable, but the 4x on-windows offer
        // ~3.6x capacity for a sixteenth of each period: the detector
        // must trip during bursts even though the average is under the
        // knee, and the cheap path must absorb some of each burst.
        assert!(burst.degraded_spells >= 1, "4x on-window bursts must trip the detector");
        assert!(burst.degraded_served > 0, "burst absorption runs on the cheap path");
        assert!(burst.goodput > 0);
        assert_conservation(&burst, &cfg);
    }

    /// Offered load past the degraded-mode ceiling (cheap path = 4×
    /// capacity) structurally overruns the bounded queue: the server
    /// cannot serve more than ~4/5 of a 5× offered stream, so
    /// backpressure rejections are guaranteed, not probabilistic.
    #[test]
    fn far_past_the_knee_rejections_are_structural() {
        let cfg = ServiceConfig::parse("load:500").unwrap();
        let st = run(&cfg);
        assert!(st.rejected > 0, "5x load must overrun even the cheap path");
        assert!(st.goodput > 0, "admitted requests still meet their deadlines");
        assert_eq!(st.timed_out, 0, "default geometry: admitted => on time");
        assert_conservation(&st, &cfg);
    }

    /// The calibrated cost scales the whole replay: doubling the cost
    /// doubles the deadline, the gaps and the sojourns, but the
    /// counters (a pure function of load ratios) stay in the same
    /// regime.
    #[test]
    fn counters_are_load_relative_not_cost_absolute() {
        let cfg = ServiceConfig::overload();
        let a = run(&cfg);
        let mut big = RunStats { cycles: 4_000_000, tasks_completed: 1000, ..Default::default() };
        let b = simulate(&cfg, &mut big);
        assert_eq!(b.capacity_cost, 4000);
        assert_eq!(a.offered, b.offered);
        // Same seed, same gap *ratios*: admission decisions follow the
        // same pattern, so the regime (shedding engaged, queue bounded)
        // is preserved even though absolute cycle values scale.
        assert!(b.rejected + b.shed_expired + b.degraded_spells > 0);
        assert!(b.max_queue <= cfg.queue_cap as u64);
    }
}
