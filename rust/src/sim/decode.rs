//! Decode-once lowering: CoroIR [`Function`]s flattened into a dense
//! micro-op array the interpreter walks without per-instruction enum
//! plumbing.
//!
//! The reference interpreter re-derives everything per dynamic
//! instruction: it chases the block vector, matches the nested `Inst`
//! enum, builds operand slices for readiness checks, and looks up ALU
//! latencies. At `Program` link time this module resolves all of that
//! once per *static* instruction: operands become [`Src`] slots (register
//! index or inlined immediate), per-op latencies and block tags are
//! precomputed, and terminators become ordinary micro-ops whose targets
//! are indices into the same flat array. The hot loop in
//! [`super::interp`] is then a program-counter walk over `ops`.

use crate::ir::*;

/// Sentinel register index marking an immediate [`Src`].
pub const NO_REG: u32 = u32::MAX;

/// A pre-resolved operand: register slot or inlined immediate.
#[derive(Debug, Clone, Copy)]
pub struct Src {
    /// Register index, or [`NO_REG`] for an immediate.
    pub reg: u32,
    pub imm: i64,
}

impl Src {
    fn of(o: Operand) -> Src {
        match o {
            Operand::Reg(r) => Src { reg: r, imm: 0 },
            Operand::Imm(v) => Src { reg: NO_REG, imm: v },
        }
    }

    /// Current value of the operand.
    #[inline(always)]
    pub fn value(self, regs: &[i64]) -> i64 {
        if self.reg == NO_REG {
            self.imm
        } else {
            regs[self.reg as usize]
        }
    }
}

/// Micro-op payload. Operands common to most ops live in [`UOp::a`] /
/// [`UOp::b`]; the mapping per kind is documented on each variant.
#[derive(Debug, Clone, Copy)]
pub enum UKind {
    /// a, b = operands; latency precomputed.
    Alu { op: AluOp, dst: Reg, lat: u64 },
    /// a, b = operands; latency precomputed.
    Falu { op: FaluOp, dst: Reg, lat: u64 },
    /// a = base.
    Load { dst: Reg, off: i64, width: Width },
    /// a = val, b = base.
    Store { off: i64, width: Width },
    /// a = val, b = base.
    AtomicRmw { op: AluOp, dst: Reg, off: i64, width: Width },
    /// a = base.
    Prefetch { off: i64 },
    /// a = id, b = base.
    Aload { off: i64, bytes: u32, spm_off: u32, resume: BlockId },
    /// a = id, b = base.
    Astore { off: i64, bytes: u32, spm_off: u32, resume: BlockId },
    /// a = id, b = n.
    Aset,
    Getfin { dst: Reg },
    /// a = base, b = size.
    Aconfig,
    /// a = id.
    Await { resume: BlockId },
    /// a = id.
    Asignal,
    // ---- terminators ----
    /// a = cond.
    Br { then_: BlockId, else_: BlockId },
    Jmp { target: BlockId },
    /// a = target (holds a BlockId as a value).
    IndirectJmp,
    Bafin { handler_dst: Reg, id_dst: Reg, fallthrough: BlockId },
    Halt,
}

/// One pre-decoded micro-op: payload plus everything the timing loop
/// would otherwise re-derive from the enclosing block.
#[derive(Debug, Clone, Copy)]
pub struct UOp {
    pub kind: UKind,
    pub a: Src,
    pub b: Src,
    /// Source block (branch-history keys + error context).
    pub bb: BlockId,
    pub tag: CodeTag,
    /// Precomputed `tag == CodeTag::CtxSwitch` (ctx-traffic accounting).
    pub is_ctx: bool,
}

/// A [`Function`] lowered to a flat micro-op array. Block ids survive as
/// indices into [`DecodedFunc::block_start`], so dynamic targets
/// (indirect jumps, AMU resume blocks) translate with one array load.
#[derive(Debug, Clone)]
pub struct DecodedFunc {
    pub name: String,
    pub ops: Vec<UOp>,
    /// BlockId -> index of that block's first op in `ops`.
    pub block_start: Vec<u32>,
    pub entry: BlockId,
}

impl DecodedFunc {
    /// Flat-array index of a block's first op.
    #[inline(always)]
    pub fn start_of(&self, bb: BlockId) -> usize {
        self.block_start[bb as usize] as usize
    }
}

/// Integer-op execute latency (single source of truth — the reference
/// interpreter reads the same table, so the two paths cannot drift).
pub(crate) fn alu_latency(op: AluOp) -> u64 {
    match op {
        AluOp::Mul => 3,
        AluOp::Div | AluOp::Rem => 20,
        AluOp::Hash => 3,
        _ => 1,
    }
}

/// Float-op execute latency; see [`alu_latency`].
pub(crate) fn falu_latency(op: FaluOp) -> u64 {
    match op {
        FaluOp::FDiv => 18,
        FaluOp::IToF | FaluOp::FToI => 2,
        _ => 4,
    }
}

const IMM0: Src = Src { reg: NO_REG, imm: 0 };

/// Lower `f` into its decode-once form. O(static instructions); called
/// once per [`super::Program`] construction.
pub fn decode(f: &Function) -> DecodedFunc {
    let mut ops = Vec::with_capacity(f.static_len());
    let mut block_start = Vec::with_capacity(f.blocks.len());
    for (bi, blk) in f.blocks.iter().enumerate() {
        let bb = bi as BlockId;
        let tag = blk.tag;
        let is_ctx = tag == CodeTag::CtxSwitch;
        block_start.push(ops.len() as u32);
        let uop = |kind: UKind, a: Src, b: Src| UOp { kind, a, b, bb, tag, is_ctx };
        for inst in &blk.insts {
            ops.push(match inst {
                Inst::Alu { op, dst, a, b } => uop(
                    UKind::Alu { op: *op, dst: *dst, lat: alu_latency(*op) },
                    Src::of(*a),
                    Src::of(*b),
                ),
                Inst::Falu { op, dst, a, b } => uop(
                    UKind::Falu { op: *op, dst: *dst, lat: falu_latency(*op) },
                    Src::of(*a),
                    Src::of(*b),
                ),
                Inst::Load { dst, base, off, width, space: _ } => uop(
                    UKind::Load { dst: *dst, off: *off, width: *width },
                    Src::of(*base),
                    IMM0,
                ),
                Inst::Store { val, base, off, width, space: _ } => uop(
                    UKind::Store { off: *off, width: *width },
                    Src::of(*val),
                    Src::of(*base),
                ),
                Inst::AtomicRmw { op, dst, val, base, off, width, space: _ } => uop(
                    UKind::AtomicRmw { op: *op, dst: *dst, off: *off, width: *width },
                    Src::of(*val),
                    Src::of(*base),
                ),
                Inst::Prefetch { base, off, space: _ } => {
                    uop(UKind::Prefetch { off: *off }, Src::of(*base), IMM0)
                }
                Inst::Aload { id, base, off, bytes, spm_off, resume } => uop(
                    UKind::Aload { off: *off, bytes: *bytes, spm_off: *spm_off, resume: *resume },
                    Src::of(*id),
                    Src::of(*base),
                ),
                Inst::Astore { id, base, off, bytes, spm_off, resume } => uop(
                    UKind::Astore { off: *off, bytes: *bytes, spm_off: *spm_off, resume: *resume },
                    Src::of(*id),
                    Src::of(*base),
                ),
                Inst::Aset { id, n } => uop(UKind::Aset, Src::of(*id), Src::of(*n)),
                Inst::Getfin { dst } => uop(UKind::Getfin { dst: *dst }, IMM0, IMM0),
                Inst::Aconfig { base, size } => {
                    uop(UKind::Aconfig, Src::of(*base), Src::of(*size))
                }
                Inst::Await { id, resume } => {
                    uop(UKind::Await { resume: *resume }, Src::of(*id), IMM0)
                }
                Inst::Asignal { id } => uop(UKind::Asignal, Src::of(*id), IMM0),
            });
        }
        ops.push(match &blk.term {
            Term::Br { cond, then_, else_ } => {
                uop(UKind::Br { then_: *then_, else_: *else_ }, Src::of(*cond), IMM0)
            }
            Term::Jmp(t) => uop(UKind::Jmp { target: *t }, IMM0, IMM0),
            Term::IndirectJmp { target } => uop(UKind::IndirectJmp, Src::of(*target), IMM0),
            Term::Bafin { handler_dst, id_dst, fallthrough } => uop(
                UKind::Bafin {
                    handler_dst: *handler_dst,
                    id_dst: *id_dst,
                    fallthrough: *fallthrough,
                },
                IMM0,
                IMM0,
            ),
            Term::Halt => uop(UKind::Halt, IMM0, IMM0),
        });
    }
    DecodedFunc { name: f.name.clone(), ops, block_start, entry: f.entry }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::Operand::{Imm, Reg as R};

    #[test]
    fn decode_flattens_blocks_with_inline_terminators() {
        let mut b = FuncBuilder::new("d");
        let x = b.reg();
        b.mov(x, Imm(5));
        let next = b.new_block("next", CodeTag::Scheduler);
        b.jmp(next);
        b.switch_to(next);
        let y = b.alu(AluOp::Mul, R(x), Imm(3));
        let _ = y;
        b.halt();
        let f = b.build();
        let d = decode(&f);
        // entry: mov + jmp; next: mul + halt.
        assert_eq!(d.ops.len(), f.static_len());
        assert_eq!(d.block_start, vec![0, 2]);
        assert_eq!(d.start_of(1), 2);
        assert!(matches!(d.ops[1].kind, UKind::Jmp { target: 1 }));
        match d.ops[2].kind {
            UKind::Alu { op: AluOp::Mul, lat, .. } => assert_eq!(lat, 3, "mul latency precomputed"),
            ref k => panic!("expected mul, got {k:?}"),
        }
        assert_eq!(d.ops[2].tag, CodeTag::Scheduler);
        assert_eq!(d.ops[2].bb, 1);
        assert!(matches!(d.ops[3].kind, UKind::Halt));
    }

    #[test]
    fn src_resolves_imm_and_reg() {
        let regs = [10i64, 20];
        assert_eq!(Src { reg: NO_REG, imm: -7 }.value(&regs), -7);
        assert_eq!(Src { reg: 1, imm: 0 }.value(&regs), 20);
    }

    #[test]
    fn ctx_flag_precomputed() {
        let mut b = FuncBuilder::new("c");
        let ctx = b.new_block("ctx", CodeTag::CtxSwitch);
        b.jmp(ctx);
        b.switch_to(ctx);
        let v = b.load(Imm(0x1000_0000), 0, Width::W8, AddrSpace::Local);
        let _ = v;
        b.halt();
        let d = decode(&b.build());
        let load = d.ops.iter().find(|o| matches!(o.kind, UKind::Load { .. })).unwrap();
        assert!(load.is_ctx);
        assert!(!d.ops[0].is_ctx);
    }
}
