//! Decode-once lowering: CoroIR [`Function`]s flattened into a dense
//! micro-op array the interpreter walks without per-instruction enum
//! plumbing.
//!
//! The reference interpreter re-derives everything per dynamic
//! instruction: it chases the block vector, matches the nested `Inst`
//! enum, builds operand slices for readiness checks, and looks up ALU
//! latencies. At `Program` link time this module resolves all of that
//! once per *static* instruction: operands become [`Src`] slots (register
//! index or inlined immediate), per-op latencies and block tags are
//! precomputed, and terminators become ordinary micro-ops whose targets
//! are indices into the same flat array. The hot loop in
//! [`super::interp`] is then a program-counter walk over `ops`.

use crate::ir::*;

/// Sentinel register index marking an immediate [`Src`].
pub const NO_REG: u32 = u32::MAX;

/// A pre-resolved operand: register slot or inlined immediate.
#[derive(Debug, Clone, Copy)]
pub struct Src {
    /// Register index, or [`NO_REG`] for an immediate.
    pub reg: u32,
    pub imm: i64,
}

impl Src {
    fn of(o: Operand) -> Src {
        match o {
            Operand::Reg(r) => Src { reg: r, imm: 0 },
            Operand::Imm(v) => Src { reg: NO_REG, imm: v },
        }
    }

    /// Current value of the operand.
    #[inline(always)]
    pub fn value(self, regs: &[i64]) -> i64 {
        if self.reg == NO_REG {
            self.imm
        } else {
            regs[self.reg as usize]
        }
    }
}

/// Micro-op payload. Operands common to most ops live in [`UOp::a`] /
/// [`UOp::b`]; the mapping per kind is documented on each variant.
#[derive(Debug, Clone, Copy)]
pub enum UKind {
    /// a, b = operands; latency precomputed.
    Alu { op: AluOp, dst: Reg, lat: u64 },
    /// a, b = operands; latency precomputed.
    Falu { op: FaluOp, dst: Reg, lat: u64 },
    /// a = base.
    Load { dst: Reg, off: i64, width: Width },
    /// a = val, b = base.
    Store { off: i64, width: Width },
    /// a = val, b = base.
    AtomicRmw { op: AluOp, dst: Reg, off: i64, width: Width },
    /// a = base.
    Prefetch { off: i64 },
    /// a = id, b = base.
    Aload { off: i64, bytes: u32, spm_off: u32, resume: BlockId },
    /// a = id, b = base.
    Astore { off: i64, bytes: u32, spm_off: u32, resume: BlockId },
    /// a = id, b = n.
    Aset,
    Getfin { dst: Reg },
    /// a = base, b = size.
    Aconfig,
    /// a = id.
    Await { resume: BlockId },
    /// a = id.
    Asignal,
    // ---- terminators ----
    /// a = cond.
    Br { then_: BlockId, else_: BlockId },
    Jmp { target: BlockId },
    /// a = target (holds a BlockId as a value).
    IndirectJmp,
    Bafin { handler_dst: Reg, id_dst: Reg, fallthrough: BlockId },
    Halt,
    // ---- superops (decode-time peephole fusion, `decode_with`) ----
    //
    // Each fused variant stands for TWO adjacent micro-ops of the same
    // block where the second consumes the first ALU's destination. The
    // handler performs *both* ops' dispatch/ROB/scoreboard accounting
    // inline — two `Core::dispatch` calls, two commits, both register
    // writes — so simulated timing and stats are bit-identical to the
    // unfused pair; only the interpreter's per-op overhead (op fetch,
    // match dispatch, operand re-decode, scoreboard re-read) is halved.
    // In every fused variant `UOp::a` / `UOp::b` are the first ALU's
    // operands; the second op's extra operands live in the payload.
    /// Alu feeding a dependent Alu (`a2`/`b2` = second op's operands).
    FusedAluAlu { op1: AluOp, dst1: Reg, lat1: u64, op2: AluOp, dst2: Reg, lat2: u64, a2: Src, b2: Src },
    /// Address-gen Alu feeding a Load whose base is the Alu destination.
    FusedAluLoad { op: AluOp, dst: Reg, lat: u64, ld_dst: Reg, off: i64, width: Width },
    /// Alu feeding a Store (as value and/or base address).
    FusedAluStore { op: AluOp, dst: Reg, lat: u64, off: i64, width: Width, val: Src, base: Src },
    /// Compare (any Alu) feeding the block's conditional branch.
    FusedAluBr { op: AluOp, dst: Reg, lat: u64, then_: BlockId, else_: BlockId },
    /// Alu with both operands immediate, folded at decode time.
    AluConst { dst: Reg, val: i64, lat: u64 },
}

/// One pre-decoded micro-op: payload plus everything the timing loop
/// would otherwise re-derive from the enclosing block.
#[derive(Debug, Clone, Copy)]
pub struct UOp {
    pub kind: UKind,
    pub a: Src,
    pub b: Src,
    /// Source block (branch-history keys + error context).
    pub bb: BlockId,
    pub tag: CodeTag,
    /// Precomputed `tag == CodeTag::CtxSwitch` (ctx-traffic accounting).
    pub is_ctx: bool,
    /// Precomputed `tag == CodeTag::Scheduler` (switch accounting + the
    /// scheduler-attributed ITTAGE stream, `sim::sched`).
    pub is_sched: bool,
}

/// A [`Function`] lowered to a flat micro-op array. Block ids survive as
/// indices into [`DecodedFunc::block_start`], so dynamic targets
/// (indirect jumps, AMU resume blocks) translate with one array load.
#[derive(Debug, Clone)]
pub struct DecodedFunc {
    pub name: String,
    pub ops: Vec<UOp>,
    /// BlockId -> index of that block's first op in `ops`.
    pub block_start: Vec<u32>,
    pub entry: BlockId,
    /// Superop pairs formed by the fusion peephole (0 when unfused).
    pub fused_pairs: u32,
}

impl DecodedFunc {
    /// Flat-array index of a block's first op.
    #[inline(always)]
    pub fn start_of(&self, bb: BlockId) -> usize {
        self.block_start[bb as usize] as usize
    }
}

/// Integer-op execute latency (single source of truth — the reference
/// interpreter reads the same table, so the two paths cannot drift).
pub(crate) fn alu_latency(op: AluOp) -> u64 {
    match op {
        AluOp::Mul => 3,
        AluOp::Div | AluOp::Rem => 20,
        AluOp::Hash => 3,
        _ => 1,
    }
}

/// Float-op execute latency; see [`alu_latency`].
pub(crate) fn falu_latency(op: FaluOp) -> u64 {
    match op {
        FaluOp::FDiv => 18,
        FaluOp::IToF | FaluOp::FToI => 2,
        _ => 4,
    }
}

const IMM0: Src = Src { reg: NO_REG, imm: 0 };

/// Lower `f` into its decode-once form without superop fusion. The
/// unfused lowering is the differential baseline for the fusion knob;
/// see [`decode_with`].
pub fn decode(f: &Function) -> DecodedFunc {
    decode_with(f, false)
}

/// Lower `f` into its decode-once form. O(static instructions); called
/// once per [`super::Program`] construction.
///
/// With `fuse` set, a peephole pass runs over each block after lowering
/// and fuses adjacent dependent pairs into superop [`UKind`] variants
/// (Alu→Alu, addr-gen Alu→Load/Store, compare→Br) and constant-folds
/// Alu ops whose operands are both immediates. Fusion never crosses a
/// block boundary, so every branch/resume target remains a valid op
/// index, and the fused handlers replay both constituent ops' timing
/// accounting exactly — `fuse` on/off is invisible in cycles, stats and
/// memory (pinned by the differential suite).
pub fn decode_with(f: &Function, fuse: bool) -> DecodedFunc {
    let mut ops = Vec::with_capacity(f.static_len());
    let mut block_start = Vec::with_capacity(f.blocks.len());
    let mut fused_pairs = 0u32;
    let mut scratch: Vec<UOp> = Vec::new();
    for (bi, blk) in f.blocks.iter().enumerate() {
        let bb = bi as BlockId;
        let tag = blk.tag;
        let is_ctx = tag == CodeTag::CtxSwitch;
        let is_sched = tag == CodeTag::Scheduler;
        block_start.push(ops.len() as u32);
        scratch.clear();
        let uop = |kind: UKind, a: Src, b: Src| UOp { kind, a, b, bb, tag, is_ctx, is_sched };
        for inst in &blk.insts {
            scratch.push(match inst {
                Inst::Alu { op, dst, a, b } => uop(
                    UKind::Alu { op: *op, dst: *dst, lat: alu_latency(*op) },
                    Src::of(*a),
                    Src::of(*b),
                ),
                Inst::Falu { op, dst, a, b } => uop(
                    UKind::Falu { op: *op, dst: *dst, lat: falu_latency(*op) },
                    Src::of(*a),
                    Src::of(*b),
                ),
                Inst::Load { dst, base, off, width, space: _ } => uop(
                    UKind::Load { dst: *dst, off: *off, width: *width },
                    Src::of(*base),
                    IMM0,
                ),
                Inst::Store { val, base, off, width, space: _ } => uop(
                    UKind::Store { off: *off, width: *width },
                    Src::of(*val),
                    Src::of(*base),
                ),
                Inst::AtomicRmw { op, dst, val, base, off, width, space: _ } => uop(
                    UKind::AtomicRmw { op: *op, dst: *dst, off: *off, width: *width },
                    Src::of(*val),
                    Src::of(*base),
                ),
                Inst::Prefetch { base, off, space: _ } => {
                    uop(UKind::Prefetch { off: *off }, Src::of(*base), IMM0)
                }
                Inst::Aload { id, base, off, bytes, spm_off, resume } => uop(
                    UKind::Aload { off: *off, bytes: *bytes, spm_off: *spm_off, resume: *resume },
                    Src::of(*id),
                    Src::of(*base),
                ),
                Inst::Astore { id, base, off, bytes, spm_off, resume } => uop(
                    UKind::Astore { off: *off, bytes: *bytes, spm_off: *spm_off, resume: *resume },
                    Src::of(*id),
                    Src::of(*base),
                ),
                Inst::Aset { id, n } => uop(UKind::Aset, Src::of(*id), Src::of(*n)),
                Inst::Getfin { dst } => uop(UKind::Getfin { dst: *dst }, IMM0, IMM0),
                Inst::Aconfig { base, size } => {
                    uop(UKind::Aconfig, Src::of(*base), Src::of(*size))
                }
                Inst::Await { id, resume } => {
                    uop(UKind::Await { resume: *resume }, Src::of(*id), IMM0)
                }
                Inst::Asignal { id } => uop(UKind::Asignal, Src::of(*id), IMM0),
            });
        }
        scratch.push(match &blk.term {
            Term::Br { cond, then_, else_ } => {
                uop(UKind::Br { then_: *then_, else_: *else_ }, Src::of(*cond), IMM0)
            }
            Term::Jmp(t) => uop(UKind::Jmp { target: *t }, IMM0, IMM0),
            Term::IndirectJmp { target } => uop(UKind::IndirectJmp, Src::of(*target), IMM0),
            Term::Bafin { handler_dst, id_dst, fallthrough } => uop(
                UKind::Bafin {
                    handler_dst: *handler_dst,
                    id_dst: *id_dst,
                    fallthrough: *fallthrough,
                },
                IMM0,
                IMM0,
            ),
            Term::Halt => uop(UKind::Halt, IMM0, IMM0),
        });
        if fuse {
            fused_pairs += fuse_block(&scratch, &mut ops);
        } else {
            ops.extend_from_slice(&scratch);
        }
    }
    DecodedFunc { name: f.name.clone(), ops, block_start, entry: f.entry, fused_pairs }
}

/// Peephole over one lowered block: constant-fold immediate-only ALU
/// ops, then greedily fuse adjacent dependent pairs (left to right,
/// non-overlapping). Returns the number of pairs formed.
fn fuse_block(block: &[UOp], out: &mut Vec<UOp>) -> u32 {
    let mut pairs = 0u32;
    let mut i = 0;
    while i < block.len() {
        let cur = fold_const(block[i]);
        if i + 1 < block.len() {
            if let Some(fused) = try_fuse(&cur, &block[i + 1]) {
                out.push(fused);
                pairs += 1;
                i += 2;
                continue;
            }
        }
        out.push(cur);
        i += 1;
    }
    pairs
}

/// Alu with both operands immediate → [`UKind::AluConst`], evaluated at
/// decode time through the interpreter's own [`super::interp::alu_eval`]
/// so folded values cannot diverge. Timing is unchanged: an
/// immediate-only op executes at its dispatch cycle either way.
fn fold_const(op: UOp) -> UOp {
    if let UKind::Alu { op: aop, dst, lat } = op.kind {
        if op.a.reg == NO_REG && op.b.reg == NO_REG {
            let val = super::interp::alu_eval(aop, op.a.imm, op.b.imm);
            return UOp { kind: UKind::AluConst { dst, val, lat }, ..op };
        }
    }
    op
}

/// Fuse `p` (an ALU op) with its block successor `n` when `n` consumes
/// `p`'s destination. The pair stays within one block (callers only
/// hand in same-block neighbours), so no branch target can land between
/// the two halves.
fn try_fuse(p: &UOp, n: &UOp) -> Option<UOp> {
    let UKind::Alu { op, dst, lat } = p.kind else { return None };
    debug_assert_eq!(p.bb, n.bb, "fusion must not cross blocks");
    let kind = match n.kind {
        UKind::Alu { op: op2, dst: dst2, lat: lat2 } if n.a.reg == dst || n.b.reg == dst => {
            UKind::FusedAluAlu { op1: op, dst1: dst, lat1: lat, op2, dst2, lat2, a2: n.a, b2: n.b }
        }
        UKind::Load { dst: ld_dst, off, width } if n.a.reg == dst => {
            UKind::FusedAluLoad { op, dst, lat, ld_dst, off, width }
        }
        UKind::Store { off, width } if n.a.reg == dst || n.b.reg == dst => {
            UKind::FusedAluStore { op, dst, lat, off, width, val: n.a, base: n.b }
        }
        UKind::Br { then_, else_ } if n.a.reg == dst => {
            UKind::FusedAluBr { op, dst, lat, then_, else_ }
        }
        _ => return None,
    };
    Some(UOp { kind, ..*p })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::Operand::{Imm, Reg as R};

    #[test]
    fn decode_flattens_blocks_with_inline_terminators() {
        let mut b = FuncBuilder::new("d");
        let x = b.reg();
        b.mov(x, Imm(5));
        let next = b.new_block("next", CodeTag::Scheduler);
        b.jmp(next);
        b.switch_to(next);
        let y = b.alu(AluOp::Mul, R(x), Imm(3));
        let _ = y;
        b.halt();
        let f = b.build();
        let d = decode(&f);
        // entry: mov + jmp; next: mul + halt.
        assert_eq!(d.ops.len(), f.static_len());
        assert_eq!(d.block_start, vec![0, 2]);
        assert_eq!(d.start_of(1), 2);
        assert!(matches!(d.ops[1].kind, UKind::Jmp { target: 1 }));
        match d.ops[2].kind {
            UKind::Alu { op: AluOp::Mul, lat, .. } => assert_eq!(lat, 3, "mul latency precomputed"),
            ref k => panic!("expected mul, got {k:?}"),
        }
        assert_eq!(d.ops[2].tag, CodeTag::Scheduler);
        assert!(d.ops[2].is_sched, "scheduler flag precomputed");
        assert!(!d.ops[0].is_sched);
        assert_eq!(d.ops[2].bb, 1);
        assert!(matches!(d.ops[3].kind, UKind::Halt));
    }

    #[test]
    fn src_resolves_imm_and_reg() {
        let regs = [10i64, 20];
        assert_eq!(Src { reg: NO_REG, imm: -7 }.value(&regs), -7);
        assert_eq!(Src { reg: 1, imm: 0 }.value(&regs), 20);
    }

    /// The canonical GUPS-shaped block: addr-gen chain + load + store +
    /// loop bookkeeping. Fusion must form the expected superops and
    /// leave every block start pointing at a real op.
    #[test]
    fn fusion_forms_superops_on_addr_gen_chains() {
        let mut b = FuncBuilder::new("f");
        let pb = b.reg();
        let i = b.reg();
        b.mov(i, Imm(0)); // imm+imm -> AluConst
        let head = b.new_block("head", CodeTag::Compute);
        let body = b.new_block("body", CodeTag::Compute);
        let exit = b.new_block("exit", CodeTag::Compute);
        b.jmp(head);
        b.switch_to(head);
        let c = b.alu(AluOp::Slt, R(i), Imm(100));
        b.br(R(c), body, exit); // cmp -> br fuses
        b.switch_to(body);
        let off = b.alu(AluOp::Shl, R(i), Imm(3));
        let addr = b.alu(AluOp::Add, R(pb), R(off)); // shl -> add fuses
        let v = b.load(R(addr), 0, Width::W8, AddrSpace::Remote); // (unpaired: addr taken)
        let sv = b.alu(AluOp::Xor, R(v), R(i));
        b.store(R(sv), R(addr), 0, Width::W8, AddrSpace::Remote); // xor -> store fuses
        b.alu_into(i, AluOp::Add, R(i), Imm(1));
        b.jmp(body); // placeholder target; structure is what matters
        b.switch_to(exit);
        b.halt();
        let f = b.build();
        let unfused = decode_with(&f, false);
        let fused = decode_with(&f, true);
        assert_eq!(unfused.fused_pairs, 0);
        assert!(fused.fused_pairs >= 3, "expected >=3 pairs, got {}", fused.fused_pairs);
        assert_eq!(
            fused.ops.len() + fused.fused_pairs as usize,
            unfused.ops.len(),
            "every pair shortens the array by exactly one"
        );
        assert!(fused.ops.iter().any(|o| matches!(o.kind, UKind::AluConst { val: 0, .. })));
        assert!(fused.ops.iter().any(|o| matches!(o.kind, UKind::FusedAluBr { .. })));
        assert!(fused.ops.iter().any(|o| matches!(o.kind, UKind::FusedAluAlu { .. })));
        assert!(fused.ops.iter().any(|o| matches!(o.kind, UKind::FusedAluStore { .. })));
        // Block starts remain in-bounds and block-aligned.
        for (bi, &s) in fused.block_start.iter().enumerate() {
            assert!((s as usize) < fused.ops.len());
            assert_eq!(fused.ops[s as usize].bb, bi as BlockId);
        }
    }

    #[test]
    fn fusion_pairs_alu_with_dependent_load() {
        let mut b = FuncBuilder::new("l");
        let pb = b.reg();
        let addr = b.alu(AluOp::Add, R(pb), Imm(8));
        let v = b.load(R(addr), 0, Width::W8, AddrSpace::Remote);
        let _ = v;
        b.halt();
        let d = decode_with(&b.build(), true);
        assert_eq!(d.fused_pairs, 1);
        assert!(matches!(d.ops[0].kind, UKind::FusedAluLoad { off: 0, .. }));
        // Independent neighbours must NOT fuse.
        let mut b2 = FuncBuilder::new("nl");
        let p1 = b2.reg();
        let p2 = b2.reg();
        let x = b2.alu(AluOp::Add, R(p1), Imm(1));
        let _ = x;
        let v2 = b2.load(R(p2), 0, Width::W8, AddrSpace::Remote);
        let _ = v2;
        b2.halt();
        let d2 = decode_with(&b2.build(), true);
        assert_eq!(d2.fused_pairs, 0, "load base is not the alu dst");
    }

    #[test]
    fn const_fold_uses_interpreter_semantics() {
        // Div-by-zero folds to the interpreter's defined -1, not a trap.
        let mut b = FuncBuilder::new("cf");
        let q = b.alu(AluOp::Div, Imm(7), Imm(0));
        let _ = q;
        b.halt();
        let d = decode_with(&b.build(), true);
        match d.ops[0].kind {
            UKind::AluConst { val, .. } => assert_eq!(val, -1),
            ref k => panic!("expected AluConst, got {k:?}"),
        }
    }

    #[test]
    fn ctx_flag_precomputed() {
        let mut b = FuncBuilder::new("c");
        let ctx = b.new_block("ctx", CodeTag::CtxSwitch);
        b.jmp(ctx);
        b.switch_to(ctx);
        let v = b.load(Imm(0x1000_0000), 0, Width::W8, AddrSpace::Local);
        let _ = v;
        b.halt();
        let d = decode(&b.build());
        let load = d.ops.iter().find(|o| matches!(o.kind, UKind::Load { .. })).unwrap();
        assert!(load.is_ctx);
        assert!(!d.ops[0].is_ctx);
    }
}
