//! Pluggable far-memory fabric models.
//!
//! The paper evaluates CoroAMU against an FPGA rig that emulates
//! disaggregation with a fixed-latency delayer plus a bandwidth regulator
//! (Fig. 10), measured at exactly two latency points. Real disaggregated
//! fabrics add the effects that rig abstracts away — queuing and
//! congestion at the interconnect, latency variance between pools, and
//! tiering in front of the far pool (the open challenges catalogued by
//! the memory-disaggregation literature). This module turns the far tier
//! behind [`MemSys`](super::memsys::MemSys) into a first-class, sweepable
//! axis: a [`FabricModel`] trait with four backends selected by
//! [`FabricKind`] (mirroring `SchedPolicyKind`):
//!
//! * [`FixedDelay`] — the paper's delayer + regulator, the default,
//!   bit-identical to the pre-subsystem `Channel` at every bandwidth
//!   with an exact binary representation — all the power-of-two
//!   B/cycle settings the paper sweeps, including the NH-G default
//!   (pinned by the differential suite); at other bandwidths (the
//!   Skylake preset's 24 B/cycle) the integer clock below differs from
//!   the old `f64` accumulation by deliberate sub-cycle rounding;
//! * [`Queued`] — a link with a finite request queue, serialization
//!   delay, and occupancy-proportional congestion, so burst MLP inflates
//!   tail latency;
//! * [`Distributed`] — deterministic per-request latency draws
//!   (uniform, or bimodal near-pool vs. far-pool), seeded through
//!   [`util::rng`](crate::util::rng) so runs stay exactly reproducible;
//! * [`Tiered`] — a page-granular hot-page cache in front of the far
//!   pool with LRU promotion and dirty-page writeback, so locality-rich
//!   kernels diverge from streaming ones.
//!
//! All timing is integer: wire serialization is accounted in fixed-point
//! cycles ([`FP_SHIFT`]), so completions are bit-identical across
//! platforms — no accumulated `f64` drift (the old `Channel::next_free`
//! hazard). Latency percentiles come from a fixed-resolution histogram
//! ([`LatencyHist`]), also exact and allocation-free after construction.
//!
//! The fetch-time caveat of the §IV-A bafin oracle is unchanged by any
//! backend: fabrics only move request *completions*; visibility is still
//! decided against the asking cycle (see `DESIGN.md` §9).

use super::cache::LINE_BYTES;
use super::memsys::AccessKind;
use super::stats::IntervalUnion;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;

/// Identity of the core (requester) behind a fabric request. Single-core
/// paths pass 0; `sim::cluster` assigns one id per core so occupancy
/// stalls and hot-page behavior are attributable per requester.
pub type CoreId = u32;

/// Fixed-point shift for wire-serialization accounting: one cycle is
/// `1 << FP_SHIFT` (1024) fixed-point units. Chosen so every bandwidth
/// the paper sweeps (1-32 B/cycle) keeps sub-0.1% rounding error while
/// all arithmetic stays in `u64` (3e9 cycles << 10 is far below 2^63).
pub const FP_SHIFT: u32 = 10;

/// Page granularity of the [`Tiered`] hot cache: 4 KB = 64 lines.
pub const PAGE_SHIFT: u32 = 12;
pub const PAGE_LINES: u64 = 1 << (PAGE_SHIFT - 6);

/// Default request-queue depth for `queued` (deliberately shallower than
/// the AMU Request Table, so decoupled MLP actually hits backpressure).
pub const DEFAULT_QUEUE_DEPTH: u32 = 16;

/// Default hot-page capacity for `tiered` (64 pages = 256 KB of near
/// cache in front of the far pool).
pub const DEFAULT_HOT_PAGES: u32 = 64;

/// Latency distribution shapes for the [`Distributed`] backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dist {
    /// Uniform in `[base/2, 3*base/2]` — jitter around the delayer point.
    Uniform,
    /// Near-pool (`0.7x base`, 3/4 of requests) vs. far-pool (`2.5x
    /// base`, 1/4) — the two-tier pool split of rack-scale fabrics.
    Bimodal,
}

impl Dist {
    pub fn label(self) -> &'static str {
        match self {
            Dist::Uniform => "uniform",
            Dist::Bimodal => "bimodal",
        }
    }

    pub fn parse(s: &str) -> Result<Dist> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "uniform" => Dist::Uniform,
            "bimodal" => Dist::Bimodal,
            other => bail!("unknown latency distribution '{other}' (uniform|bimodal)"),
        })
    }
}

/// Selector for the concrete fabric backends, carried by
/// `SimConfig::mem.fabric` and swept by the engine/harness. The default
/// ([`FixedDelay`]) reproduces the pre-subsystem far channel bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FabricKind {
    /// The paper's FPGA rig: fixed pipe latency + bandwidth regulator.
    FixedDelay,
    /// Finite request queue (`depth` entries) + congestion: each queued
    /// request ahead of an issue adds switching delay, so bursts inflate
    /// the tail.
    Queued { depth: u32 },
    /// Deterministic per-request latency draws from `dist`.
    Distributed { dist: Dist },
    /// Hot-page cache (`pages` 4 KB pages, LRU) in front of the far pool.
    Tiered { pages: u32 },
}

impl Default for FabricKind {
    fn default() -> Self {
        FabricKind::FixedDelay
    }
}

impl crate::util::keyed::Keyed for FabricKind {
    const AXIS: &'static str = "fabric";
    const EXPECTED: &'static str = "fixed, queued[:N], dist[:uniform|bimodal], tiered[:N]";

    fn parse_keyed(s: &str) -> Result<Self> {
        FabricKind::parse(s)
    }

    fn label_keyed(&self) -> String {
        self.label()
    }

    fn all_keyed() -> Vec<Self> {
        FabricKind::ALL.to_vec()
    }
}

impl FabricKind {
    /// The canonical sweep axis (`coroamu report --fabric`).
    pub const ALL: [FabricKind; 4] = [
        FabricKind::FixedDelay,
        FabricKind::Queued { depth: DEFAULT_QUEUE_DEPTH },
        FabricKind::Distributed { dist: Dist::Bimodal },
        FabricKind::Tiered { pages: DEFAULT_HOT_PAGES },
    ];

    /// Display label (CLI, tables, `RunStats::fabric`).
    pub fn label(self) -> String {
        match self {
            FabricKind::FixedDelay => "fixed".into(),
            FabricKind::Queued { depth } => format!("queued:{depth}"),
            FabricKind::Distributed { dist } => format!("dist:{}", dist.label()),
            FabricKind::Tiered { pages } => format!("tiered:{pages}"),
        }
    }

    /// Parse a CLI/TOML spelling: `fixed` (or `fixed-delay`, `delayer`),
    /// `queued[:DEPTH]`, `dist[:uniform|bimodal]` (or `distributed`),
    /// `tiered[:PAGES]`.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        if let Some(n) = s.strip_prefix("queued:") {
            let n: u32 = match n.parse() {
                Ok(v) if v > 0 => v,
                _ => bail!("queued:DEPTH needs a positive integer, got '{n}'"),
            };
            return Ok(FabricKind::Queued { depth: n });
        }
        if let Some(n) = s.strip_prefix("tiered:") {
            let n: u32 = match n.parse() {
                Ok(v) if v > 0 => v,
                _ => bail!("tiered:PAGES needs a positive integer, got '{n}'"),
            };
            return Ok(FabricKind::Tiered { pages: n });
        }
        if let Some(d) = s.strip_prefix("dist:").or_else(|| s.strip_prefix("distributed:")) {
            return Ok(FabricKind::Distributed { dist: Dist::parse(d)? });
        }
        Ok(match s.as_str() {
            "fixed" | "fixed-delay" | "delayer" => FabricKind::FixedDelay,
            "queued" => FabricKind::Queued { depth: DEFAULT_QUEUE_DEPTH },
            "dist" | "distributed" => FabricKind::Distributed { dist: Dist::Bimodal },
            "tiered" => FabricKind::Tiered { pages: DEFAULT_HOT_PAGES },
            other => return Err(crate::util::keyed::unknown_key::<Self>(other)),
        })
    }

    /// Instantiate the concrete backend. `latency` is the base far-pool
    /// latency in cycles, `bytes_per_cycle` the regulator setting,
    /// `window` the MLP accumulator's reorder tolerance (see
    /// [`IntervalUnion::with_window`]), `seed` the deterministic source
    /// for the [`Distributed`] draws.
    pub fn build(
        self,
        latency: u64,
        bytes_per_cycle: f64,
        record: bool,
        window: usize,
        seed: u64,
    ) -> Box<dyn FabricModel> {
        let link = Link::new(latency, bytes_per_cycle, record, window);
        match self {
            FabricKind::FixedDelay => Box::new(FixedDelay { link }),
            FabricKind::Queued { depth } => Box::new(Queued {
                depth: depth.max(1) as usize,
                // Per-queued-request switching delay: a full default
                // queue doubles the base latency — strong enough that
                // burst MLP visibly fattens the tail, weak enough that
                // decoupling still wins.
                cong_per_req: (latency >> 4).max(1),
                link,
                inflight: Vec::with_capacity(depth.max(1) as usize),
                max_inflight: 0,
                queue_stall_cycles: 0,
                req_stalls: Vec::new(),
            }),
            FabricKind::Distributed { dist } => {
                Box::new(Distributed { link, dist, rng: Rng::new(seed) })
            }
            FabricKind::Tiered { pages } => Box::new(Tiered {
                near_latency: (link.latency / 4).max(1),
                link,
                cap: pages.max(1) as usize,
                hot: HashMap::new(),
                tick: 0,
                hot_hits: 0,
                hot_misses: 0,
                writebacks: 0,
                req_hits: Vec::new(),
            }),
        }
    }
}

/// Per-run fabric counters, surfaced through `RunStats`. All fields are
/// deterministic, so the differential suite compares them bit-for-bit
/// like every other stat.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FabricStats {
    /// Active backend label (`FabricKind::label`).
    pub kind: String,
    /// Requests issued to the far tier (demand fills, prefetch fills and
    /// AMU transfers alike).
    pub requests: u64,
    /// Peak request-queue occupancy (only the `queued` backend models a
    /// finite queue; 0 elsewhere).
    pub max_inflight: u64,
    /// Cycles requests waited for a queue slot (congestion backpressure).
    pub queue_stall_cycles: u64,
    /// Far-request latency percentiles, at [`LatencyHist`] resolution.
    pub lat_p50: u64,
    pub lat_p99: u64,
    /// Hot-page cache behavior (`tiered` only; 0 elsewhere).
    pub hot_hits: u64,
    pub hot_misses: u64,
    pub writebacks: u64,
    /// Fault-injection resilience counters, overlaid by the
    /// [`FaultyFabric`](super::faults::FaultyFabric) decorator; all zero
    /// (and `faults` empty) on a fault-free run, so faults-off stats stay
    /// bit-comparable with pre-fault builds.
    pub faults: String,
    pub fault_nacks: u64,
    pub fault_retries: u64,
    pub fault_retry_cycles: u64,
    pub fault_timeouts: u64,
    pub fault_degraded_cycles: u64,
    pub fault_slow_path: u64,
    pub fault_max_stall: u64,
    /// Per-requester breakdown, indexed by [`CoreId`]. Single-core runs
    /// have exactly one entry (requester 0); `sim::cluster` reads one
    /// slot per core for fairness accounting.
    pub requesters: Vec<RequesterStats>,
}

impl FabricStats {
    /// Hot-page hit fraction (0 when the backend has no page cache).
    pub fn hot_hit_rate(&self) -> f64 {
        let total = self.hot_hits + self.hot_misses;
        if total == 0 {
            0.0
        } else {
            self.hot_hits as f64 / total as f64
        }
    }

    /// The breakdown slot for `core`, zero-filled when the core never
    /// touched the fabric (a core can finish without a single far miss).
    pub fn requester(&self, core: CoreId) -> RequesterStats {
        self.requesters.get(core as usize).cloned().unwrap_or_default()
    }
}

/// One requester's share of the fabric traffic (satellite of the cluster
/// subsystem: `Queued` stalls and `Tiered` hot hits are attributed to the
/// core that issued the request, so per-core fairness is exact).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequesterStats {
    /// Requests this core issued to the fabric.
    pub requests: u64,
    /// Observed request-latency percentiles for this core alone.
    pub lat_p50: u64,
    pub lat_p99: u64,
    /// Cycles this core's requests waited for a queue slot (`queued`).
    pub queue_stall_cycles: u64,
    /// Hot-page hits this core enjoyed (`tiered`).
    pub hot_hits: u64,
    /// Fault-injection retries and slow-path completions charged to this
    /// core's requests (`sim::faults`; 0 on fault-free runs).
    pub fault_retries: u64,
    pub fault_slow_path: u64,
}

/// Cheap live counters for the tracing layer (DESIGN.md §14): read-only
/// snapshots of whatever a backend already tracks, with no strings or
/// percentile scans (unlike the end-of-run [`FabricStats`]). Fields a
/// backend does not model stay zero. Fault counters are overlaid by
/// `sim::faults::FaultyFabric`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FabricGauges {
    /// Requests issued so far.
    pub requests: u64,
    /// Requests currently occupying queue slots (`queued`; approximate —
    /// completed-but-unreaped slots count until the next issue reaps).
    pub inflight: u64,
    /// Cumulative queue-full wait cycles (`queued`).
    pub queue_stalls: u64,
    /// Cumulative hot-page hits/misses (`tiered`).
    pub hot_hits: u64,
    pub hot_misses: u64,
    /// Cumulative fault-injection counters (`sim::faults` overlay).
    pub nacks: u64,
    pub retries: u64,
    pub timeouts: u64,
    pub slow_path: u64,
}

/// A far-memory fabric backend. `issue` is the single timing entry
/// point: a request of `lines` cache lines at byte address `addr`,
/// issued at cycle `t`, returns its completion cycle. Backends are
/// deterministic functions of the issue stream (plus their construction
/// seed), which is what keeps the decoded/reference interpreter paths
/// bit-identical under every backend.
pub trait FabricModel: fmt::Debug + Send {
    /// The kind this backend was built from (provenance / labels).
    fn kind(&self) -> FabricKind;

    /// Issue a request; returns the completion cycle (`>= t`).
    /// `requester` identifies the issuing core for per-requester stat
    /// attribution only — it never changes timing, so single-core paths
    /// (which always pass 0) are bit-identical to the pre-cluster trait.
    fn issue(&mut self, t: u64, addr: u64, lines: u64, kind: AccessKind, requester: CoreId)
        -> u64;

    /// Lines that actually crossed the far wire (hot-page hits excluded).
    fn lines_transferred(&self) -> u64;

    /// Average in-flight requests over the busy period, and the busy
    /// fraction of `total_cycles` (Fig. 16's MLP metric).
    fn mlp(&self, total_cycles: u64) -> (f64, f64);

    /// Per-request counters for `RunStats` / the fabric report.
    fn stats(&self) -> FabricStats;

    /// Cheap live counters for trace sampling. Default: all zero, for
    /// backends with nothing interesting to gauge.
    fn gauges(&self) -> FabricGauges {
        FabricGauges::default()
    }
}

/// Fixed-resolution latency histogram: 8-cycle buckets over 32 K cycles
/// by default (overflow clamps into the last bucket). Percentiles return
/// the lower edge of the covering bucket, so they are exact integers
/// independent of platform and request count. Consumers whose values
/// span far past 32 K cycles — service sojourn times under overload can
/// reach millions of cycles — pick a coarser geometry with
/// [`LatencyHist::with_bucket_shift`] or [`LatencyHist::covering`].
#[derive(Clone)]
pub struct LatencyHist {
    counts: Vec<u32>,
    total: u64,
    shift: u32,
}

const HIST_BUCKET_SHIFT: u32 = 3;
const HIST_BUCKETS: usize = 4096;
/// Largest supported bucket shift: 4096 buckets of 2^40 cycles cover any
/// simulated duration this repo can produce.
const HIST_MAX_SHIFT: u32 = 40;

impl LatencyHist {
    pub fn new() -> LatencyHist {
        Self::with_bucket_shift(HIST_BUCKET_SHIFT)
    }

    /// A histogram with `2^shift`-cycle buckets (same 4096-bucket
    /// storage, so range = `4096 << shift` before the overflow clamp).
    pub fn with_bucket_shift(shift: u32) -> LatencyHist {
        assert!(shift <= HIST_MAX_SHIFT, "bucket shift {shift} exceeds {HIST_MAX_SHIFT}");
        LatencyHist { counts: vec![0; HIST_BUCKETS], total: 0, shift }
    }

    /// The smallest-bucket histogram whose range still covers `span`
    /// cycles (at least the default geometry; clamped at the maximum
    /// shift for absurd spans).
    pub fn covering(span: u64) -> LatencyHist {
        let mut shift = HIST_BUCKET_SHIFT;
        while shift < HIST_MAX_SHIFT && ((HIST_BUCKETS as u64) << shift) < span {
            shift += 1;
        }
        Self::with_bucket_shift(shift)
    }

    pub fn record(&mut self, latency: u64) {
        let idx = ((latency >> self.shift) as usize).min(HIST_BUCKETS - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Lower edge of the bucket holding the `p`-quantile request
    /// (`p` in `[0, 1]`); 0 when empty. The empty case is guarded
    /// explicitly (no recorded buckets means nothing to divide by or
    /// index into), and the overflow fallthrough derives the last edge
    /// from the actual bucket count, so a degenerate histogram can never
    /// index past its own storage.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.counts.is_empty() || self.total == 0 {
            return 0;
        }
        let target = ((p * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c as u64;
            if cum >= target {
                return (i as u64) << self.shift;
            }
        }
        ((self.counts.len() - 1) as u64) << self.shift
    }

    /// Number of recorded samples (0 for a fresh or empty histogram).
    pub fn count(&self) -> u64 {
        self.total
    }
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for LatencyHist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LatencyHist")
            .field("total", &self.total)
            .field("p50", &self.percentile(0.50))
            .field("p99", &self.percentile(0.99))
            .finish()
    }
}

/// The shared wire: fixed-point serialization (bandwidth regulator),
/// MLP interval accounting, and the latency histogram. Every backend
/// owns one; the backends differ in what latency they stack on top and
/// which requests touch the wire at all.
#[derive(Debug)]
struct Link {
    /// Base pipe latency in cycles.
    latency: u64,
    /// Wire occupancy per 64 B line, fixed-point (`cycles << FP_SHIFT`).
    fp_per_line: u64,
    /// Fixed-point next-free cycle of the serialization stage. Integer
    /// accumulation — bit-identical across platforms (no `f64` drift).
    next_free_fp: u64,
    lines: u64,
    requests: u64,
    union: IntervalUnion,
    record: bool,
    hist: LatencyHist,
    /// Per-requester request counts and latency histograms, grown on
    /// demand (index = [`CoreId`]; single-core runs only ever touch 0).
    per_req: Vec<(u64, LatencyHist)>,
}

impl Link {
    fn new(latency: u64, bytes_per_cycle: f64, record: bool, window: usize) -> Link {
        let fp_per_line =
            (((LINE_BYTES << FP_SHIFT) as f64) / bytes_per_cycle.max(0.01)).round() as u64;
        Link {
            latency,
            fp_per_line,
            next_free_fp: 0,
            lines: 0,
            requests: 0,
            union: IntervalUnion::with_window(window),
            record,
            hist: LatencyHist::new(),
            per_req: Vec::new(),
        }
    }

    /// Serialize `lines` onto the wire no earlier than `t`; the request
    /// completes `lat` cycles after its transfer finishes.
    fn push(&mut self, t: u64, lines: u64, lat: u64, requester: CoreId) -> u64 {
        self.push_from(t, t, lines, lat, requester)
    }

    /// Like [`Link::push`], but the wire is entered no earlier than
    /// `start` while latency accounting (MLP interval, histogram) runs
    /// from the original issue cycle `issued` — so queue waits ahead of
    /// the wire show up in the observed request latency.
    fn push_from(&mut self, issued: u64, start: u64, lines: u64, lat: u64, requester: CoreId) -> u64 {
        debug_assert!(start >= issued);
        let start_fp = (start << FP_SHIFT).max(self.next_free_fp);
        let end_fp = start_fp + self.fp_per_line * lines;
        self.next_free_fp = end_fp;
        self.lines += lines;
        let completion = (end_fp >> FP_SHIFT) + lat;
        self.note(issued, completion, requester);
        completion
    }

    /// A request served without touching the far wire (hot-page hit):
    /// fixed latency, no serialization, no far lines.
    fn bypass(&mut self, t: u64, lat: u64, requester: CoreId) -> u64 {
        let completion = t + lat;
        self.note(t, completion, requester);
        completion
    }

    /// Charge wire occupancy from `t` with no waiter: page-promotion
    /// streaming and writeback traffic.
    fn occupy(&mut self, t: u64, lines: u64) {
        if lines == 0 {
            return;
        }
        let start_fp = (t << FP_SHIFT).max(self.next_free_fp);
        self.next_free_fp = start_fp + self.fp_per_line * lines;
        self.lines += lines;
    }

    fn note(&mut self, t: u64, completion: u64, requester: CoreId) {
        self.requests += 1;
        if self.record {
            self.union.push(t, completion);
        }
        self.hist.record(completion - t);
        let slot = requester as usize;
        if self.per_req.len() <= slot {
            self.per_req.resize_with(slot + 1, || (0, LatencyHist::new()));
        }
        self.per_req[slot].0 += 1;
        self.per_req[slot].1.record(completion - t);
    }

    fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        if self.union.count() == 0 || total_cycles == 0 {
            return (0.0, 0.0);
        }
        let busy = self.union.busy();
        (
            self.union.integral() as f64 / busy.max(1) as f64,
            busy as f64 / total_cycles as f64,
        )
    }

    fn base_stats(&self, kind: FabricKind) -> FabricStats {
        FabricStats {
            kind: kind.label(),
            requests: self.requests,
            lat_p50: self.hist.percentile(0.50),
            lat_p99: self.hist.percentile(0.99),
            requesters: self
                .per_req
                .iter()
                .map(|(n, hist)| RequesterStats {
                    requests: *n,
                    lat_p50: hist.percentile(0.50),
                    lat_p99: hist.percentile(0.99),
                    ..RequesterStats::default()
                })
                .collect(),
            ..FabricStats::default()
        }
    }
}

/// Grow a per-requester stats vector so `slot` is addressable (backends
/// overlay their own per-requester counters on [`Link::base_stats`];
/// `sim::faults` overlays its retry/slow-path attribution the same way).
pub(crate) fn ensure_requester(v: &mut Vec<RequesterStats>, slot: usize) -> &mut RequesterStats {
    if v.len() <= slot {
        v.resize_with(slot + 1, RequesterStats::default);
    }
    &mut v[slot]
}

/// See [`FabricKind::FixedDelay`]. Same arithmetic as the pre-subsystem
/// `Channel`, with the serialization clock in fixed point.
#[derive(Debug)]
pub struct FixedDelay {
    link: Link,
}

impl FabricModel for FixedDelay {
    fn kind(&self) -> FabricKind {
        FabricKind::FixedDelay
    }

    fn issue(&mut self, t: u64, _addr: u64, lines: u64, _kind: AccessKind, requester: CoreId) -> u64 {
        let lat = self.link.latency;
        self.link.push(t, lines, lat, requester)
    }

    fn lines_transferred(&self) -> u64 {
        self.link.lines
    }

    fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        self.link.mlp(total_cycles)
    }

    fn stats(&self) -> FabricStats {
        self.link.base_stats(self.kind())
    }

    fn gauges(&self) -> FabricGauges {
        FabricGauges { requests: self.link.requests, ..FabricGauges::default() }
    }
}

/// See [`FabricKind::Queued`]. The finite request queue holds every
/// in-flight request from issue to completion; a request arriving at a
/// full queue waits for the earliest release (backpressure), and every
/// request pays a switching delay per queued request ahead of it, so a
/// burst of decoupled MLP inflates its own tail latency.
#[derive(Debug)]
pub struct Queued {
    depth: usize,
    link: Link,
    /// Extra cycles of queuing delay per in-flight request ahead of us.
    cong_per_req: u64,
    /// Completion times of requests occupying queue slots.
    inflight: Vec<u64>,
    max_inflight: u64,
    queue_stall_cycles: u64,
    /// Queue-slot wait cycles attributed to the requester that waited.
    req_stalls: Vec<u64>,
}

impl FabricModel for Queued {
    fn kind(&self) -> FabricKind {
        FabricKind::Queued { depth: self.depth as u32 }
    }

    fn issue(&mut self, t: u64, _addr: u64, lines: u64, _kind: AccessKind, requester: CoreId) -> u64 {
        self.inflight.retain(|&r| r > t);
        let start = if self.inflight.len() < self.depth {
            t
        } else {
            // Queue full: wait for the earliest in-flight completion.
            let (idx, &earliest) = self
                .inflight
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| **r)
                .expect("nonempty");
            self.inflight.swap_remove(idx);
            self.queue_stall_cycles += earliest - t;
            let slot = requester as usize;
            if self.req_stalls.len() <= slot {
                self.req_stalls.resize(slot + 1, 0);
            }
            self.req_stalls[slot] += earliest - t;
            earliest
        };
        let congestion = self.inflight.len() as u64 * self.cong_per_req;
        let lat = self.link.latency + congestion;
        let completion = self.link.push_from(t, start, lines, lat, requester);
        self.inflight.push(completion);
        self.max_inflight = self.max_inflight.max(self.inflight.len() as u64);
        completion
    }

    fn lines_transferred(&self) -> u64 {
        self.link.lines
    }

    fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        self.link.mlp(total_cycles)
    }

    fn stats(&self) -> FabricStats {
        let mut st = FabricStats {
            max_inflight: self.max_inflight,
            queue_stall_cycles: self.queue_stall_cycles,
            ..self.link.base_stats(self.kind())
        };
        for (slot, &stall) in self.req_stalls.iter().enumerate() {
            ensure_requester(&mut st.requesters, slot).queue_stall_cycles = stall;
        }
        st
    }

    fn gauges(&self) -> FabricGauges {
        FabricGauges {
            requests: self.link.requests,
            inflight: self.inflight.len() as u64,
            queue_stalls: self.queue_stall_cycles,
            ..FabricGauges::default()
        }
    }
}

/// See [`FabricKind::Distributed`]. Per-request latency draws from a
/// seeded [`Rng`]: the k-th request always gets the k-th draw, so the
/// decoded and reference interpreters (which issue identical request
/// streams) see identical timing, and a re-run with the same seed is
/// bit-identical.
#[derive(Debug)]
pub struct Distributed {
    link: Link,
    dist: Dist,
    rng: Rng,
}

impl Distributed {
    fn draw(&mut self) -> u64 {
        let base = self.link.latency;
        match self.dist {
            Dist::Uniform => base / 2 + self.rng.below(base.max(1) + 1),
            Dist::Bimodal => {
                if self.rng.below(4) == 0 {
                    base * 5 / 2
                } else {
                    base * 7 / 10
                }
            }
        }
    }
}

impl FabricModel for Distributed {
    fn kind(&self) -> FabricKind {
        FabricKind::Distributed { dist: self.dist }
    }

    fn issue(&mut self, t: u64, _addr: u64, lines: u64, _kind: AccessKind, requester: CoreId) -> u64 {
        let lat = self.draw();
        self.link.push(t, lines, lat, requester)
    }

    fn lines_transferred(&self) -> u64 {
        self.link.lines
    }

    fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        self.link.mlp(total_cycles)
    }

    fn stats(&self) -> FabricStats {
        self.link.base_stats(self.kind())
    }

    fn gauges(&self) -> FabricGauges {
        FabricGauges { requests: self.link.requests, ..FabricGauges::default() }
    }
}

/// See [`FabricKind::Tiered`]. A page-granular near cache in front of
/// the far pool: hits are served at a quarter of the far latency without
/// touching the wire; misses promote the whole page (requested lines
/// critical-first at full latency, the rest streaming behind as wire
/// occupancy) and evict the LRU page, writing it back over the wire when
/// dirty. Transfers are attributed to the page of their first byte
/// (coarse AMU transfers are page-aligned in practice; the abstraction
/// is documented in DESIGN.md §9).
#[derive(Debug)]
pub struct Tiered {
    link: Link,
    near_latency: u64,
    cap: usize,
    /// page -> (LRU stamp, dirty). Stamps are unique (one per issue), so
    /// LRU eviction is deterministic despite the hash map.
    hot: HashMap<u64, (u64, bool)>,
    tick: u64,
    hot_hits: u64,
    hot_misses: u64,
    writebacks: u64,
    /// Hot-page hits attributed to the requester that enjoyed them.
    req_hits: Vec<u64>,
}

impl FabricModel for Tiered {
    fn kind(&self) -> FabricKind {
        FabricKind::Tiered { pages: self.cap as u32 }
    }

    fn issue(&mut self, t: u64, addr: u64, lines: u64, kind: AccessKind, requester: CoreId) -> u64 {
        let page = addr >> PAGE_SHIFT;
        self.tick += 1;
        let dirties = matches!(kind, AccessKind::Store | AccessKind::Atomic);
        if let Some(entry) = self.hot.get_mut(&page) {
            entry.0 = self.tick;
            entry.1 |= dirties;
            self.hot_hits += 1;
            let slot = requester as usize;
            if self.req_hits.len() <= slot {
                self.req_hits.resize(slot + 1, 0);
            }
            self.req_hits[slot] += 1;
            let lat = self.near_latency;
            return self.link.bypass(t, lat, requester);
        }
        self.hot_misses += 1;
        // Critical lines first at full far latency; the rest of the page
        // streams behind, charging the wire.
        let lat = self.link.latency;
        let completion = self.link.push(t, lines, lat, requester);
        self.link.occupy(t, PAGE_LINES.saturating_sub(lines));
        if self.hot.len() >= self.cap {
            let (&victim, &(_, dirty)) =
                self.hot.iter().min_by_key(|(_, (stamp, _))| *stamp).expect("nonempty");
            if dirty {
                self.writebacks += 1;
                self.link.occupy(t, PAGE_LINES);
            }
            self.hot.remove(&victim);
        }
        self.hot.insert(page, (self.tick, dirties));
        completion
    }

    fn lines_transferred(&self) -> u64 {
        self.link.lines
    }

    fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        self.link.mlp(total_cycles)
    }

    fn stats(&self) -> FabricStats {
        let mut st = FabricStats {
            hot_hits: self.hot_hits,
            hot_misses: self.hot_misses,
            writebacks: self.writebacks,
            ..self.link.base_stats(self.kind())
        };
        for (slot, &hits) in self.req_hits.iter().enumerate() {
            ensure_requester(&mut st.requesters, slot).hot_hits = hits;
        }
        st
    }

    fn gauges(&self) -> FabricGauges {
        FabricGauges {
            requests: self.link.requests,
            hot_hits: self.hot_hits,
            hot_misses: self.hot_misses,
            ..FabricGauges::default()
        }
    }
}

/// A requester-tagged handle on a fabric backend, shareable between the
/// [`MemSys`](super::memsys::MemSys) instances of a cluster. Cloning the
/// handle (via [`SharedFabric::for_core`]) shares the underlying backend;
/// every issue through a handle carries that handle's [`CoreId`]. The
/// single-core path wraps a private backend with requester 0, so its
/// arithmetic is untouched. `Rc<RefCell<..>>` is deliberate: a simulation
/// (all its cores included) runs on one worker thread; the handle is
/// created, used, and dropped there.
#[derive(Debug, Clone)]
pub struct SharedFabric {
    inner: Rc<RefCell<Box<dyn FabricModel>>>,
    requester: CoreId,
}

impl SharedFabric {
    /// Wrap a backend for a single requester (id 0).
    pub fn new(model: Box<dyn FabricModel>) -> SharedFabric {
        SharedFabric { inner: Rc::new(RefCell::new(model)), requester: 0 }
    }

    /// A handle on the same backend that issues as `requester`.
    pub fn for_core(&self, requester: CoreId) -> SharedFabric {
        SharedFabric { inner: Rc::clone(&self.inner), requester }
    }

    /// The requester id this handle stamps on its issues.
    pub fn requester(&self) -> CoreId {
        self.requester
    }

    pub fn issue(&self, t: u64, addr: u64, lines: u64, kind: AccessKind) -> u64 {
        self.inner.borrow_mut().issue(t, addr, lines, kind, self.requester)
    }

    pub fn kind(&self) -> FabricKind {
        self.inner.borrow().kind()
    }

    pub fn lines_transferred(&self) -> u64 {
        self.inner.borrow().lines_transferred()
    }

    pub fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        self.inner.borrow().mlp(total_cycles)
    }

    pub fn stats(&self) -> FabricStats {
        self.inner.borrow().stats()
    }

    /// Cheap live counters for the tracing layer.
    pub fn gauges(&self) -> FabricGauges {
        self.inner.borrow().gauges()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fab(kind: FabricKind, latency: u64, bw: f64) -> Box<dyn FabricModel> {
        kind.build(latency, bw, true, 64, 0xFEED)
    }

    #[test]
    fn kind_roundtrip_and_labels() {
        for k in FabricKind::ALL {
            assert_eq!(FabricKind::parse(&k.label()).unwrap(), k, "label parses back for {k:?}");
            let built = k.build(100, 16.0, true, 8, 1);
            assert_eq!(built.kind(), k, "build/kind roundtrip for {k:?}");
        }
        assert_eq!(FabricKind::parse("fixed-delay").unwrap(), FabricKind::FixedDelay);
        assert_eq!(FabricKind::parse("delayer").unwrap(), FabricKind::FixedDelay);
        assert_eq!(FabricKind::parse("queued:8").unwrap(), FabricKind::Queued { depth: 8 });
        assert_eq!(
            FabricKind::parse("dist:uniform").unwrap(),
            FabricKind::Distributed { dist: Dist::Uniform }
        );
        assert_eq!(
            FabricKind::parse("distributed").unwrap(),
            FabricKind::Distributed { dist: Dist::Bimodal }
        );
        assert_eq!(FabricKind::parse("tiered:256").unwrap(), FabricKind::Tiered { pages: 256 });
        assert!(FabricKind::parse("queued:0").is_err());
        assert!(FabricKind::parse("tiered:0").is_err());
        assert!(FabricKind::parse("dist:zipf").is_err());
        assert!(FabricKind::parse("optical").is_err());
        assert_eq!(FabricKind::default(), FabricKind::FixedDelay);
    }

    /// The default backend must reproduce the pre-subsystem `Channel`
    /// arithmetic exactly: 100-cycle latency, 16 B/cycle = 4 cycles per
    /// line, two back-to-back requests at t=0 complete at 104 and 108.
    #[test]
    fn fixed_delay_matches_legacy_channel_arithmetic() {
        let mut f = fab(FabricKind::FixedDelay, 100, 16.0);
        assert_eq!(f.issue(0, 0, 1, AccessKind::Load, 0), 104);
        assert_eq!(f.issue(0, 64, 1, AccessKind::Load, 0), 108);
        let (mlp, busy) = f.mlp(108);
        assert!((mlp - 212.0 / 108.0).abs() < 1e-12, "mlp {mlp}");
        assert!((busy - 1.0).abs() < 1e-12, "busy {busy}");
        assert_eq!(f.lines_transferred(), 2);
        let st = f.stats();
        assert_eq!(st.requests, 2);
        assert_eq!((st.lat_p50, st.lat_p99), (104, 104), "8-cycle buckets: 104 and 108 share one");
        assert_eq!((st.max_inflight, st.hot_hits, st.queue_stall_cycles), (0, 0, 0));
    }

    /// Satellite pin: serialization accounting is integer fixed-point.
    /// At 24 B/cycle (not representable in binary floating point) a long
    /// back-to-back run lands on exactly these cycles on every platform:
    /// fp_per_line = round(64*1024/24) = 2731, so the k-th completion is
    /// (k*2731 >> 10) + latency.
    #[test]
    fn long_run_serialization_is_bit_exact_fixed_point() {
        let mut f = fab(FabricKind::FixedDelay, 100, 24.0);
        let mut last = 0;
        for _ in 0..1000 {
            last = f.issue(0, 0, 1, AccessKind::Load, 0);
        }
        assert_eq!(last, (1000u64 * 2731 >> FP_SHIFT) + 100);
        assert_eq!(last, 2666 + 100);
        // Spot-check an early completion too: k=3 -> (8193 >> 10) + 100.
        let mut g = fab(FabricKind::FixedDelay, 100, 24.0);
        g.issue(0, 0, 1, AccessKind::Load, 0);
        g.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(g.issue(0, 0, 1, AccessKind::Load, 0), 8 + 100);
    }

    #[test]
    fn queued_backpressure_and_congestion_inflate_the_tail() {
        // Depth 2, base latency 100, 16 B/cycle, cong = 100>>4 = 6/queued.
        let mut f = fab(FabricKind::Queued { depth: 2 }, 100, 16.0);
        // First request: empty queue, no congestion: 4 + 100.
        let c1 = f.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(c1, 104);
        // Second: one ahead in the queue: 8 + 100 + 6.
        let c2 = f.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(c2, 114);
        // Third at t=0: queue full, waits for c1=104, then one ahead.
        let c3 = f.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(c3, 104 + 4 + 100 + 6);
        let st = f.stats();
        assert_eq!(st.queue_stall_cycles, 104);
        assert_eq!(st.max_inflight, 2);
        assert!(st.lat_p99 >= st.lat_p50, "congestion fattens the tail");
    }

    #[test]
    fn distributed_draws_are_deterministic_and_bounded() {
        let a: Vec<u64> = {
            let mut f = fab(FabricKind::Distributed { dist: Dist::Bimodal }, 600, 16.0);
            (0..200).map(|_| f.issue(0, 0, 1, AccessKind::Load, 0)).collect()
        };
        let b: Vec<u64> = {
            let mut f = fab(FabricKind::Distributed { dist: Dist::Bimodal }, 600, 16.0);
            (0..200).map(|_| f.issue(0, 0, 1, AccessKind::Load, 0)).collect()
        };
        assert_eq!(a, b, "same seed, same stream, same completions");
        // A different seed draws a different sequence.
        let mut c = FabricKind::Distributed { dist: Dist::Bimodal }.build(600, 16.0, true, 64, 7);
        let cs: Vec<u64> = (0..200).map(|_| c.issue(0, 0, 1, AccessKind::Load, 0)).collect();
        assert_ne!(a, cs);
        // Bimodal at base 600: latency component is 420 (near) or 1500
        // (far), both classes must appear in 200 draws.
        let mut f = fab(FabricKind::Distributed { dist: Dist::Bimodal }, 600, 16.0);
        let mut near = 0;
        let mut far = 0;
        for k in 0..200u64 {
            let t = k * 1000; // spaced out: no serialization carryover
            let lat = f.issue(t, 0, 1, AccessKind::Load, 0) - t - 4;
            match lat {
                420 => near += 1,
                1500 => far += 1,
                other => panic!("unexpected bimodal latency {other}"),
            }
        }
        assert!(near > far, "near pool takes 3/4 of draws ({near} vs {far})");
        assert!(far > 0);
        // Uniform stays within [base/2, 3*base/2].
        let mut u = fab(FabricKind::Distributed { dist: Dist::Uniform }, 600, 16.0);
        for k in 0..200u64 {
            let t = k * 1000;
            let lat = u.issue(t, 0, 1, AccessKind::Load, 0) - t - 4;
            assert!((300..=900).contains(&lat), "uniform draw {lat} out of range");
        }
    }

    #[test]
    fn tiered_hits_after_promotion_and_writes_back_dirty_pages() {
        // 2-page cache, latency 100 -> near latency 25.
        let mut f = fab(FabricKind::Tiered { pages: 2 }, 100, 16.0);
        // Miss on page 0: full latency + whole-page promotion traffic.
        let c = f.issue(0, 0x0000, 1, AccessKind::Load, 0);
        assert_eq!(c, 104);
        assert_eq!(f.lines_transferred(), PAGE_LINES, "promotion streams the whole page");
        // Hit on the same page: near latency, no wire traffic.
        let c2 = f.issue(1000, 0x0040, 1, AccessKind::Load, 0);
        assert_eq!(c2, 1025);
        assert_eq!(f.lines_transferred(), PAGE_LINES);
        // Dirty page 1, then evict it by touching pages 2 and 3:
        // the eviction writes the page back (wire traffic, counted).
        f.issue(2000, 0x1000, 1, AccessKind::Store, 0); // page 1 (dirty)
        f.issue(3000, 0x2000, 1, AccessKind::Load, 0); // page 2: evicts LRU page 0 (clean)
        let before = f.lines_transferred();
        f.issue(4000, 0x3000, 1, AccessKind::Load, 0); // page 3: evicts page 1 (dirty)
        let st = f.stats();
        assert_eq!(st.hot_hits, 1);
        assert_eq!(st.hot_misses, 4);
        assert_eq!(st.writebacks, 1, "only the dirty page writes back");
        assert_eq!(
            f.lines_transferred() - before,
            PAGE_LINES + PAGE_LINES,
            "promotion + dirty writeback both cross the wire"
        );
        assert!(st.hot_hit_rate() > 0.0 && st.hot_hit_rate() < 1.0);
    }

    #[test]
    fn tiered_lru_keeps_the_hot_page() {
        let mut f = fab(FabricKind::Tiered { pages: 2 }, 100, 16.0);
        f.issue(0, 0x0000, 1, AccessKind::Load, 0); // page 0
        f.issue(100, 0x1000, 1, AccessKind::Load, 0); // page 1
        f.issue(200, 0x0000, 1, AccessKind::Load, 0); // hit page 0 (refreshes LRU)
        f.issue(300, 0x2000, 1, AccessKind::Load, 0); // page 2: evicts page 1
        let c = f.issue(400, 0x0000, 1, AccessKind::Load, 0); // page 0 still hot
        assert_eq!(c, 425, "page 0 survived the eviction");
        assert_eq!(f.stats().hot_hits, 2);
    }

    #[test]
    fn latency_hist_percentiles_are_exact_bucket_edges() {
        let mut h = LatencyHist::new();
        for _ in 0..99 {
            h.record(600); // bucket 75 -> edge 600
        }
        h.record(30000); // bucket 3750 -> edge 30000
        assert_eq!(h.percentile(0.50), 600);
        assert_eq!(h.percentile(0.99), 600);
        assert_eq!(h.percentile(1.0), 30000);
        assert_eq!(h.count(), 100);
        // Overflow clamps to the last bucket's edge.
        h.record(1 << 40);
        assert_eq!(h.percentile(1.0), ((HIST_BUCKETS - 1) as u64) << HIST_BUCKET_SHIFT);
        assert_eq!(LatencyHist::new().percentile(0.5), 0);
    }

    /// Satellite pin: the empty histogram is a defined value (0) at every
    /// quantile — no division by or indexing past zero recorded buckets —
    /// and `count` reports 0 rather than anything derived.
    #[test]
    fn latency_hist_empty_edge_is_pinned() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.percentile(p), 0, "empty histogram must answer 0 at p={p}");
        }
        let d = LatencyHist::default();
        assert_eq!((d.count(), d.percentile(1.0)), (0, 0));
    }

    /// Satellite pin: a single-sample histogram answers that sample's
    /// bucket edge at every quantile, including the p=0 degenerate point
    /// (the clamp keeps the target at least 1, never 0).
    #[test]
    fn latency_hist_single_bucket_edge_is_pinned() {
        let mut h = LatencyHist::new();
        h.record(13); // bucket 1 -> lower edge 8
        assert_eq!(h.count(), 1);
        for p in [0.0, 0.5, 1.0] {
            assert_eq!(h.percentile(p), 8, "single sample must answer its bucket edge at p={p}");
        }
        // A zero-latency sample lands in bucket 0: edge 0, but counted.
        let mut z = LatencyHist::new();
        z.record(0);
        assert_eq!((z.count(), z.percentile(1.0)), (1, 0));
    }

    /// A coarser bucket shift extends the range past the default 32 K
    /// clamp: values the 8-cycle geometry would flatten into the last
    /// bucket stay distinguishable, and edges are exact multiples of the
    /// bucket width.
    #[test]
    fn latency_hist_bucket_shift_extends_range() {
        let mut h = LatencyHist::with_bucket_shift(9); // 512-cycle buckets, ~2 M range
        for _ in 0..99 {
            h.record(1024); // bucket 2 -> edge 1024
        }
        h.record(1_000_000); // bucket 1953 -> edge 999_936
        assert_eq!(h.percentile(0.50), 1024);
        assert_eq!(h.percentile(1.0), (1_000_000u64 >> 9) << 9);
        // `covering` picks the smallest geometry that fits the span.
        let c = LatencyHist::covering(2_000_000);
        let mut c2 = c.clone();
        c2.record(1_999_999);
        assert_eq!(c2.percentile(1.0), (1_999_999u64 >> 9) << 9);
        // Tiny spans keep the default 8-cycle buckets.
        let mut d = LatencyHist::covering(100);
        d.record(13);
        assert_eq!(d.percentile(1.0), 8);
    }

    /// Every backend is a pure function of (construction params, issue
    /// stream): replaying the same stream gives identical completions
    /// and stats — the property the differential suite relies on.
    #[test]
    fn backends_are_deterministic_replay_functions() {
        use crate::util::rng::Rng;
        for k in FabricKind::ALL {
            let mut rng = Rng::new(42);
            let stream: Vec<(u64, u64, u64)> = (0..500)
                .scan(0u64, |t, _| {
                    *t += rng.below(20);
                    Some((*t, rng.below(1 << 20) * 64, 1 + rng.below(4)))
                })
                .collect();
            let run = |stream: &[(u64, u64, u64)]| {
                let mut f = k.build(600, 16.0, true, 64, 99);
                let cs: Vec<u64> = stream
                    .iter()
                    .map(|&(t, a, l)| f.issue(t, a, l, AccessKind::Load, 0))
                    .collect();
                (cs, f.stats(), f.lines_transferred())
            };
            let a = run(&stream);
            let b = run(&stream);
            assert_eq!(a, b, "{}: replay diverged", k.label());
            assert_eq!(a.1.requests, 500, "{}: all requests counted", k.label());
            assert!(a.0.iter().zip(&stream).all(|(c, (t, _, _))| c >= t), "completions >= issue");
        }
    }

    /// Requester ids are attribution-only: the completion stream is
    /// independent of which core issues, and the per-requester breakdown
    /// partitions the totals exactly.
    #[test]
    fn requester_ids_never_change_timing_and_partition_the_stats() {
        for k in FabricKind::ALL {
            let run = |tag: fn(u64) -> CoreId| {
                let mut f = k.build(600, 16.0, true, 64, 99);
                let cs: Vec<u64> = (0..300u64)
                    .map(|i| f.issue(i * 3, (i % 7) << PAGE_SHIFT, 1, AccessKind::Load, tag(i)))
                    .collect();
                (cs, f.stats())
            };
            let (solo, solo_st) = run(|_| 0);
            let (split, split_st) = run(|i| (i % 3) as CoreId);
            assert_eq!(solo, split, "{}: requester id leaked into timing", k.label());
            assert_eq!(solo_st.requests, split_st.requests);
            assert_eq!(solo_st.requesters.len(), 1, "single requester -> one slot");
            assert_eq!(solo_st.requesters[0].requests, 300);
            assert_eq!(split_st.requesters.len(), 3);
            let per: u64 = split_st.requesters.iter().map(|r| r.requests).sum();
            assert_eq!(per, 300, "{}: breakdown partitions requests", k.label());
            let stalls: u64 = split_st.requesters.iter().map(|r| r.queue_stall_cycles).sum();
            assert_eq!(stalls, split_st.queue_stall_cycles, "{}: stall partition", k.label());
            let hits: u64 = split_st.requesters.iter().map(|r| r.hot_hits).sum();
            assert_eq!(hits, split_st.hot_hits, "{}: hot-hit partition", k.label());
            // Out-of-range lookups are zero-filled, not a panic.
            assert_eq!(split_st.requester(17), RequesterStats::default());
        }
    }

    /// `SharedFabric` handles share one backend: issues through per-core
    /// handles serialize on the same wire and land in distinct slots.
    #[test]
    fn shared_fabric_handles_share_the_wire_and_tag_requesters() {
        let shared = SharedFabric::new(FabricKind::FixedDelay.build(100, 16.0, true, 64, 1));
        let c0 = shared.for_core(0);
        let c1 = shared.for_core(1);
        assert_eq!(c0.issue(0, 0, 1, AccessKind::Load), 104);
        // Core 1 queues behind core 0 on the same serialization stage.
        assert_eq!(c1.issue(0, 64, 1, AccessKind::Load), 108);
        let st = shared.stats();
        assert_eq!(st.requests, 2);
        assert_eq!((st.requester(0).requests, st.requester(1).requests), (1, 1));
        assert_eq!((c0.requester(), c1.requester()), (0, 1));
        assert_eq!(shared.lines_transferred(), 2);
        assert_eq!(shared.kind(), FabricKind::FixedDelay);
    }
}
