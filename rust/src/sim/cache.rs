//! Set-associative cache model with MSHR occupancy and fill timestamps.
//!
//! The timing model is analytic (no global event loop): each access at
//! cycle `t` returns a data-ready cycle. Lines are installed eagerly with a
//! `ready` stamp equal to their fill-completion cycle, so a demand access
//! that races an in-flight prefetch pays exactly the residual latency —
//! the effect that caps software-prefetch scheduling (§II-B, Fig. 2).
//! MSHRs are modelled as a bounded multiset of release times: a miss that
//! finds all MSHRs busy waits for the earliest release (the resource
//! contention that limits MLP in Fig. 16).

use super::slots::SlotQueue;
use crate::config::CacheLevelConfig;

pub const LINE_SHIFT: u64 = 6;
pub const LINE_BYTES: u64 = 64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    Hit,
    Miss,
}

#[derive(Debug)]
pub struct Cache {
    sets: u64,
    ways: usize,
    latency: u64,
    /// tags\[set*ways+way\]: (line_addr << 1) | valid.
    tags: Vec<u64>,
    /// LRU stamps (global counter).
    stamps: Vec<u64>,
    /// Fill-completion cycle per way.
    ready: Vec<u64>,
    tick: u64,
    /// MSHRs: fixed-size release-time slot pool (no per-miss allocation).
    mshr: SlotQueue,
    pub stat_hits: u64,
    pub stat_misses: u64,
    pub stat_mshr_stall_cycles: u64,
}

impl Cache {
    pub fn new(cfg: &CacheLevelConfig) -> Self {
        let sets = cfg.sets() as u64;
        let ways = cfg.ways;
        Cache {
            sets,
            ways,
            latency: cfg.latency_cycles,
            tags: vec![0; (sets as usize) * ways],
            stamps: vec![0; (sets as usize) * ways],
            ready: vec![0; (sets as usize) * ways],
            tick: 0,
            mshr: SlotQueue::new(cfg.mshrs),
            stat_hits: 0,
            stat_misses: 0,
            stat_mshr_stall_cycles: 0,
        }
    }

    pub fn latency(&self) -> u64 {
        self.latency
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        ((line ^ (line >> 13)) & (self.sets - 1)) as usize
    }

    /// Probe for `line` at cycle `t`. On hit returns the cycle the data is
    /// available (>= t; racing an in-flight fill pays the residual).
    pub fn probe(&mut self, line: u64, t: u64) -> Option<u64> {
        let s = self.set_of(line);
        let base = s * self.ways;
        let key = (line << 1) | 1;
        for w in 0..self.ways {
            if self.tags[base + w] == key {
                self.tick += 1;
                self.stamps[base + w] = self.tick;
                self.stat_hits += 1;
                return Some(t.max(self.ready[base + w]) + self.latency);
            }
        }
        self.stat_misses += 1;
        None
    }

    /// Install `line` with fill completion `ready_at` (LRU victim).
    pub fn install(&mut self, line: u64, ready_at: u64) {
        let s = self.set_of(line);
        let base = s * self.ways;
        let key = (line << 1) | 1;
        // Already present (e.g. racing fills): refresh.
        for w in 0..self.ways {
            if self.tags[base + w] == key {
                self.ready[base + w] = self.ready[base + w].min(ready_at);
                return;
            }
        }
        let mut victim = 0;
        let mut best = u64::MAX;
        for w in 0..self.ways {
            if self.tags[base + w] & 1 == 0 {
                victim = w;
                break;
            }
            if self.stamps[base + w] < best {
                best = self.stamps[base + w];
                victim = w;
            }
        }
        self.tick += 1;
        self.tags[base + victim] = key;
        self.stamps[base + victim] = self.tick;
        self.ready[base + victim] = ready_at;
    }

    /// Acquire an MSHR at cycle `t`; returns the cycle the miss can be
    /// issued downstream (>= t, delayed if all MSHRs busy). The MSHR is
    /// held until `release` (passed later via [`Cache::mshr_hold`]).
    pub fn mshr_acquire(&mut self, t: u64) -> u64 {
        let (grant, stall) = self.mshr.acquire(t);
        self.stat_mshr_stall_cycles += stall;
        grant
    }

    /// Record that the MSHR acquired last is held until `release`.
    pub fn mshr_hold(&mut self, release: u64) {
        self.mshr.hold(release);
    }

    /// Current occupied MSHRs at cycle `t` (for MLP accounting).
    pub fn mshr_busy(&mut self, t: u64) -> usize {
        self.mshr.busy_gc(t)
    }
}

/// Best-Offset prefetcher (Michaud, HPCA'16), simplified: a recent-request
/// table and a scored offset list; on each L2 fill we test whether
/// line-offset was requested recently, and the best-scoring offset drives
/// next-line prefetches. Captures the streaming benefit the paper's NH-G
/// L2 BOP gives STREAM/lbm/IS serial runs.
#[derive(Debug)]
pub struct BestOffset {
    offsets: Vec<i64>,
    scores: Vec<u32>,
    rr: Vec<u64>,
    cursor: usize,
    round: u32,
    best: i64,
    best_score: u32,
}

const RR_SIZE: usize = 256;
const BOP_MAX_SCORE: u32 = 31;
const BOP_ROUND: u32 = 100;

impl BestOffset {
    pub fn new() -> Self {
        BestOffset {
            offsets: vec![1, 2, 3, 4, 5, 6, 8, 12, 16, 24, 32],
            scores: vec![0; 11],
            rr: vec![u64::MAX; RR_SIZE],
            cursor: 0,
            round: 0,
            best: 1,
            best_score: 0,
        }
    }

    fn rr_insert(&mut self, line: u64) {
        let idx = (line as usize ^ (line >> 8) as usize) & (RR_SIZE - 1);
        self.rr[idx] = line;
    }

    fn rr_hit(&self, line: u64) -> bool {
        let idx = (line as usize ^ (line >> 8) as usize) & (RR_SIZE - 1);
        self.rr[idx] == line
    }

    /// Called on every L2 demand access (miss path). Returns the offset to
    /// prefetch with, if the prefetcher is currently confident.
    pub fn access(&mut self, line: u64) -> Option<i64> {
        // Test the current candidate offset.
        let cand = self.offsets[self.cursor];
        if line >= cand as u64 && self.rr_hit(line - cand as u64) {
            self.scores[self.cursor] += 1;
            if self.scores[self.cursor] >= BOP_MAX_SCORE {
                self.best = cand;
                self.best_score = self.scores[self.cursor];
                self.scores.iter_mut().for_each(|s| *s = 0);
                self.round = 0;
            }
        }
        self.cursor = (self.cursor + 1) % self.offsets.len();
        self.round += 1;
        if self.round >= BOP_ROUND * self.offsets.len() as u32 {
            // End of learning round: adopt the best scorer.
            if let Some((i, s)) = self.scores.iter().enumerate().max_by_key(|(_, s)| **s) {
                if *s >= 8 {
                    self.best = self.offsets[i];
                    self.best_score = *s;
                } else {
                    self.best_score = 0; // low confidence: stop prefetching
                }
            }
            self.scores.iter_mut().for_each(|s| *s = 0);
            self.round = 0;
        }
        self.rr_insert(line);
        (self.best_score >= 8).then_some(self.best)
    }
}

impl Default for BestOffset {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheLevelConfig;

    fn small() -> Cache {
        Cache::new(&CacheLevelConfig { size_kb: 4, ways: 2, line_bytes: 64, latency_cycles: 3, mshrs: 2 })
    }

    #[test]
    fn probe_miss_then_hit() {
        let mut c = small();
        assert!(c.probe(100, 0).is_none());
        c.install(100, 50);
        // Access before fill completes: pays residual.
        assert_eq!(c.probe(100, 10), Some(50 + 3));
        // After fill: plain latency.
        assert_eq!(c.probe(100, 90), Some(93));
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = small(); // 4KB/2w/64B = 32 sets; lines mapping to set0: multiples of 32 (pre-hash)
        // With the XOR index hash, just find three lines in the same set.
        let mut same_set = vec![];
        let mut l = 0u64;
        while same_set.len() < 3 {
            if c.set_of(l) == c.set_of(7) && l != 7 {
                same_set.push(l);
            }
            l += 1;
        }
        c.install(7, 0);
        c.install(same_set[0], 0);
        assert!(c.probe(7, 10).is_some());
        // Installing a third in the set evicts LRU = same_set[0].
        c.install(same_set[1], 0);
        assert!(c.probe(same_set[0], 20).is_none());
    }

    #[test]
    fn mshr_contention_delays() {
        let mut c = small(); // 2 MSHRs
        assert_eq!(c.mshr_acquire(10), 10);
        c.mshr_hold(100);
        assert_eq!(c.mshr_acquire(10), 10);
        c.mshr_hold(120);
        // Third miss must wait for the earliest release (100).
        assert_eq!(c.mshr_acquire(10), 100);
        assert_eq!(c.stat_mshr_stall_cycles, 90);
    }

    #[test]
    fn mshrs_expire() {
        let mut c = small();
        assert_eq!(c.mshr_acquire(0), 0);
        c.mshr_hold(50);
        assert_eq!(c.mshr_acquire(0), 0);
        c.mshr_hold(60);
        assert_eq!(c.mshr_busy(55), 1);
        assert_eq!(c.mshr_acquire(70), 70);
        c.mshr_hold(80);
        assert_eq!(c.stat_mshr_stall_cycles, 0);
    }

    #[test]
    fn bop_learns_unit_stride() {
        let mut b = BestOffset::new();
        let mut fired = 0;
        for i in 0..20_000u64 {
            if b.access(i).is_some() {
                fired += 1;
            }
        }
        assert!(fired > 1000, "BOP never gained confidence on a perfect stream (fired={fired})");
    }

    #[test]
    fn bop_stays_quiet_on_random() {
        let mut b = BestOffset::new();
        let mut rng = crate::util::rng::Rng::new(9);
        let mut fired = 0;
        for _ in 0..20_000 {
            if b.access(rng.next_u64() >> 20).is_some() {
                fired += 1;
            }
        }
        let frac = fired as f64 / 20_000.0;
        assert!(frac < 0.2, "BOP fired on {frac} of random accesses");
    }
}
