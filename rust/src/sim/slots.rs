//! Fixed-size release-time slot pools for bounded hardware resources
//! (MSHRs, load/store queues).
//!
//! The previous implementation kept a `Vec<u64>` of release cycles per
//! resource and, when the queue looked full, `retain`ed expired entries
//! and linear-scanned for the minimum — correct, but the push/retain
//! churn showed up in the interpreter hot loop and the `Vec` is one more
//! heap object per resource. [`SlotQueue`] replaces it with a fixed slot
//! array threaded by a free list: acquire/hold are O(1) off the fast
//! path, expiry and min-scan are O(cap) only when the pool is actually
//! full (exactly when the old code paid its `retain` + min scan), and
//! nothing allocates after construction.
//!
//! The semantics are bit-for-bit those of the old queue: an entry is
//! live while `release > t` for the probing cycle `t`, expired entries
//! are only collected when the pool looks full (or on an explicit
//! [`SlotQueue::busy_gc`] probe), and a full pool grants at the earliest
//! release among live entries. Timing-transparency of the swap is pinned
//! by the differential suite.

const NONE: u32 = u32::MAX;

/// A bounded pool of release times with acquire/hold alternation:
/// [`SlotQueue::acquire`] reserves a slot (possibly stalling until the
/// earliest release when all slots are live), and the following
/// [`SlotQueue::hold`] publishes the reservation's release cycle.
#[derive(Debug)]
pub struct SlotQueue {
    /// Per-slot release cycle + 1; 0 marks a free slot.
    rel: Box<[u64]>,
    /// Free-list threading: `next[i]` = next free slot after `i`.
    next: Box<[u32]>,
    free_head: u32,
    /// Number of live slots (`rel[i] != 0`).
    occupied: usize,
    /// Slot reserved by the last `acquire`, to be filled by `hold`.
    reserved: u32,
}

impl SlotQueue {
    pub fn new(cap: usize) -> SlotQueue {
        assert!(cap > 0, "SlotQueue capacity must be nonzero");
        let next: Vec<u32> =
            (0..cap).map(|i| if i + 1 < cap { i as u32 + 1 } else { NONE }).collect();
        SlotQueue {
            rel: vec![0u64; cap].into_boxed_slice(),
            next: next.into_boxed_slice(),
            free_head: 0,
            occupied: 0,
            reserved: NONE,
        }
    }

    pub fn cap(&self) -> usize {
        self.rel.len()
    }

    fn free_slot(&mut self, i: usize) {
        self.rel[i] = 0;
        self.next[i] = self.free_head;
        self.free_head = i as u32;
        self.occupied -= 1;
    }

    /// Collect entries whose release has passed (`release <= t`). Called
    /// only when the pool looks full, mirroring the old retain-on-full.
    fn expire(&mut self, t: u64) {
        for i in 0..self.rel.len() {
            let r = self.rel[i];
            if r != 0 && r - 1 <= t {
                self.free_slot(i);
            }
        }
    }

    /// Reserve a slot at cycle `t`. Returns `(grant, stall)`: the cycle
    /// the slot is available and the stall the caller should attribute
    /// (`grant - t`, 0 on the fast path). The reservation is completed by
    /// the next [`SlotQueue::hold`].
    pub fn acquire(&mut self, t: u64) -> (u64, u64) {
        debug_assert_eq!(self.reserved, NONE, "acquire without intervening hold");
        if self.occupied == self.cap() {
            self.expire(t);
        }
        if self.free_head != NONE {
            self.reserved = self.free_head;
            self.free_head = self.next[self.reserved as usize];
            return (t, 0);
        }
        // Every slot holds a live entry: wait for the earliest release.
        let mut mi = 0usize;
        let mut mv = self.rel[0];
        for (i, &r) in self.rel.iter().enumerate().skip(1) {
            if r < mv {
                mv = r;
                mi = i;
            }
        }
        self.rel[mi] = 0;
        self.occupied -= 1;
        self.reserved = mi as u32;
        let earliest = mv - 1;
        (earliest, earliest - t)
    }

    /// Publish the reservation made by the last [`SlotQueue::acquire`]:
    /// the slot is held until `release`.
    pub fn hold(&mut self, release: u64) {
        debug_assert_ne!(self.reserved, NONE, "hold without acquire");
        let i = self.reserved as usize;
        self.reserved = NONE;
        self.rel[i] = release.saturating_add(1);
        self.occupied += 1;
    }

    /// Live entries at cycle `t`, collecting expired ones (the old
    /// mutating `retain` probe).
    pub fn busy_gc(&mut self, t: u64) -> usize {
        self.expire(t);
        self.occupied
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_path_grants_immediately() {
        let mut q = SlotQueue::new(2);
        assert_eq!(q.acquire(10), (10, 0));
        q.hold(100);
        assert_eq!(q.acquire(10), (10, 0));
        q.hold(120);
        assert_eq!(q.busy_gc(10), 2);
    }

    #[test]
    fn full_pool_stalls_until_earliest_release() {
        let mut q = SlotQueue::new(2);
        q.acquire(10);
        q.hold(100);
        q.acquire(10);
        q.hold(120);
        // Third acquire at t=10: both slots live, earliest release 100.
        assert_eq!(q.acquire(10), (100, 90));
        q.hold(250);
        // The popped slot was replaced: live entries are {120, 250}.
        assert_eq!(q.busy_gc(119), 2);
        assert_eq!(q.busy_gc(120), 1);
        assert_eq!(q.busy_gc(250), 0);
    }

    #[test]
    fn expired_entries_are_collected_when_full() {
        let mut q = SlotQueue::new(2);
        q.acquire(0);
        q.hold(50);
        q.acquire(0);
        q.hold(60);
        // At t=55 the 50-release slot has expired: no stall.
        assert_eq!(q.acquire(55), (55, 0));
        q.hold(200);
        assert_eq!(q.busy_gc(55), 2, "60 and 200 still live");
    }

    #[test]
    fn capacity_reached_after_churn() {
        let mut q = SlotQueue::new(3);
        for k in 0..50u64 {
            let (g, _) = q.acquire(k);
            q.hold(g + 5);
        }
        // Pool never exceeds capacity and still grants correctly.
        assert!(q.busy_gc(49) <= 3);
    }
}
