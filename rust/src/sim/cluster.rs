//! Multi-core cluster simulation: N `Core`+`Amu` pairs contending on
//! ONE shared far-memory fabric (`[cluster]` in TOML, `--cores` on the
//! CLI, `RunRequest::cores(..)` in the engine).
//!
//! This models the disaggregated-memory deployment the paper's FPGA rig
//! emulates: each compute node owns its pipeline, branch predictors,
//! private cache hierarchy and AMU, while every far-memory access rides
//! the same fabric into a shared memory pool. Contention, per-core
//! fairness and bandwidth saturation therefore emerge only at the
//! fabric — exactly where the disaggregation literature places them —
//! and the `Queued`/`Tiered` backends from `sim::fabric` finally see
//! more than one requester.
//!
//! ## Shared-clock interleave semantics
//!
//! Every core runs its own [`Stepper`] (the same decode-once execution
//! path the single-core simulator uses; `sim::interp`). The cluster
//! advances whichever non-halted core has the smallest local clock
//! ([`Stepper::now`], the dispatch-cycle estimate), breaking ties by
//! lowest core id. This keeps cross-core fabric arbitration causal —
//! a core can never observe fabric state from another core's *future* —
//! while staying completely deterministic: the interleave order is a
//! pure function of the per-core clocks, which are themselves pure
//! functions of the (deterministic) per-core simulations. Snapshot
//! restores and fresh-engine reruns replay bit-identically (pinned by
//! the differential suite).
//!
//! With one core the loop degenerates to `while !halted { step() }`,
//! which is exactly the single-core driver — `cores = 1` is therefore
//! bit-identical to the pre-cluster simulator by construction (cycles,
//! stats and memory; also pinned by the differential suite).
//!
//! Cores are homogeneous in microarchitecture but may run heterogeneous
//! scheduler policies (`[cluster] policies`, `SimConfig::core_policy`).
//! Each core executes its own copy of the program against its own
//! memory image; only fabric *timing* is shared, so results stay
//! order-independent and every core's image passes the benchmark
//! oracle.

use anyhow::{ensure, Result};

use super::fabric::{CoreId, SharedFabric};
use super::interp::{Program, Stepper};
use super::memsys::MemSys;
use super::stats::RunStats;
use super::trace::Trace;
use crate::config::SimConfig;

/// Jain's fairness index over per-core fabric stall cycles:
/// `(Σx)² / (n·Σx²)`. 1.0 = perfectly even, `1/n` = one core absorbs
/// everything. A cluster where *no* core stalled is perfectly fair by
/// definition (1.0) rather than undefined.
fn jain_fairness(xs: &[u64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().map(|&x| x as f64).sum();
    if sum == 0.0 {
        return 1.0;
    }
    let sum_sq: f64 = xs.iter().map(|&x| (x as f64) * (x as f64)).sum();
    (sum * sum) / (n * sum_sq)
}

/// Execute one program per core, interleaved on a shared clock against
/// one shared fabric, and fold the per-core results into a single
/// cluster-aggregate [`RunStats`].
///
/// `progs[i]` is core `i`'s program (its memory image is mutated in
/// place, like [`super::interp::run`]); `cfg.core_policy(i)` selects
/// core `i`'s scheduler. The shared fabric is built from `cfg`'s
/// `[mem.fabric]` selection with its latency-reorder window scaled by
/// the core count, so MLP accounting stays exact under the combined
/// in-flight depth of all requesters.
///
/// Aggregate semantics: `cycles` is the slowest core (makespan);
/// instruction/event counters are summed; fabric totals come from the
/// shared fabric itself; `core_*` vectors carry the per-core breakdown
/// (requester-id attributed on the fabric side); `cluster_fairness` is
/// Jain's index over per-core fabric queue-stall cycles.
pub fn run_cluster(cfg: &SimConfig, progs: &mut [Program]) -> Result<RunStats> {
    run_cluster_traced(cfg, progs).map(|(stats, _)| stats)
}

/// Like [`run_cluster`], but also returns the merged per-core [`Trace`]
/// when `cfg.trace.enabled` — events concatenated in core order (each
/// event carries its core id), aggregates summed, top-N re-ranked over
/// the whole cluster. [`run_cluster`] delegates here.
pub fn run_cluster_traced(
    cfg: &SimConfig,
    progs: &mut [Program],
) -> Result<(RunStats, Option<Trace>)> {
    ensure!(!progs.is_empty(), "cluster needs at least one core/program");
    let n = progs.len();
    // Like `MemSys::new`, the shared fabric goes through
    // `faults::build_far`, so `[mem.fabric.faults]` composes with
    // clusters automatically — one fault-injecting decorator in front of
    // the one shared pool, its draws consumed in the deterministic
    // interleave order.
    let shared = SharedFabric::new(super::faults::build_far(cfg, MemSys::far_window(cfg) * n));
    // Per-core configs differ only in the effective scheduler policy;
    // the microarchitecture (and thus every private-cache geometry) is
    // homogeneous.
    let core_cfgs: Vec<SimConfig> = (0..n)
        .map(|i| {
            let mut c = cfg.clone();
            c.sched_policy = cfg.core_policy(i);
            c
        })
        .collect();
    let mut steppers: Vec<Stepper> = core_cfgs
        .iter()
        .zip(progs.iter_mut())
        .enumerate()
        .map(|(i, (ccfg, prog))| {
            let msys = MemSys::with_far(ccfg, shared.for_core(i as CoreId));
            Stepper::with_msys(ccfg, prog, msys)
        })
        .collect();
    // Shared-clock interleave: always advance the furthest-behind
    // non-halted core; ties go to the lowest core id (strict `<`).
    loop {
        let mut next: Option<(u64, usize)> = None;
        for (i, s) in steppers.iter().enumerate() {
            if s.halted() {
                continue;
            }
            let t = s.now();
            if next.map_or(true, |(bt, _)| t < bt) {
                next = Some((t, i));
            }
        }
        let Some((_, i)) = next else { break };
        steppers[i].step()?;
    }
    let (per_core, traces): (Vec<RunStats>, Vec<Option<Trace>>) =
        steppers.into_iter().map(Stepper::finish_traced).unzip();
    let agg = aggregate(per_core, &shared);
    super::faults::check_strict(cfg, &agg)?;
    let trace = if cfg.trace.enabled {
        let parts: Vec<Trace> = traces.into_iter().flatten().collect();
        if parts.is_empty() { None } else { Some(Trace::merge(parts, agg.cycles)) }
    } else {
        None
    };
    Ok((agg, trace))
}

/// Fold per-core stats plus the shared fabric's totals into one
/// cluster-aggregate [`RunStats`].
fn aggregate(per_core: Vec<RunStats>, shared: &SharedFabric) -> RunStats {
    let n = per_core.len();
    let mut agg = per_core[0].clone();
    for s in &per_core[1..] {
        // Makespan + capacity peaks.
        agg.cycles = agg.cycles.max(s.cycles);
        agg.amu_max_inflight = agg.amu_max_inflight.max(s.amu_max_inflight);
        // Everything countable sums across cores.
        agg.dyn_instrs += s.dyn_instrs;
        for k in 0..agg.dyn_by_tag.len() {
            agg.dyn_by_tag[k] += s.dyn_by_tag[k];
        }
        agg.stalls.remote_mem += s.stalls.remote_mem;
        agg.stalls.local_mem += s.stalls.local_mem;
        agg.stalls.mispredict += s.stalls.mispredict;
        agg.stalls.backpressure += s.stalls.backpressure;
        agg.cond_branches += s.cond_branches;
        agg.cond_mispredicts += s.cond_mispredicts;
        agg.indirect_jumps += s.indirect_jumps;
        agg.indirect_mispredicts += s.indirect_mispredicts;
        agg.bafins_taken += s.bafins_taken;
        agg.bafins_fallthrough += s.bafins_fallthrough;
        agg.bafin_mispredicts += s.bafin_mispredicts;
        agg.loads += s.loads;
        agg.stores += s.stores;
        agg.prefetches += s.prefetches;
        agg.l1_hits += s.l1_hits;
        agg.l1_misses += s.l1_misses;
        agg.aloads += s.aloads;
        agg.astores += s.astores;
        agg.awaits += s.awaits;
        agg.switches += s.switches;
        agg.ctx_ops += s.ctx_ops;
        agg.tasks_completed += s.tasks_completed;
        agg.sched_polls += s.sched_polls;
        agg.sched_picks += s.sched_picks;
        agg.sched_holds += s.sched_holds;
        agg.sched_indirect_jumps += s.sched_indirect_jumps;
        agg.sched_indirect_mispredicts += s.sched_indirect_mispredicts;
        agg.trace_events += s.trace_events;
        agg.trace_dropped += s.trace_dropped;
        if s.sched_policy != agg.sched_policy {
            agg.sched_policy = "mixed".into();
        }
    }
    // Fabric totals come from the one shared instance (each core's
    // harvest already saw the same shared state; re-harvesting here
    // evaluates MLP/busy over the cluster makespan instead of a single
    // core's cycles).
    let fs = shared.stats();
    agg.far_lines = shared.lines_transferred();
    let (mlp, busy) = shared.mlp(agg.cycles);
    agg.far_mlp = mlp;
    agg.far_busy_frac = busy;
    agg.fabric = fs.kind.clone();
    agg.fabric_requests = fs.requests;
    agg.fabric_max_inflight = fs.max_inflight;
    agg.fabric_queue_stalls = fs.queue_stall_cycles;
    agg.fabric_p50 = fs.lat_p50;
    agg.fabric_p99 = fs.lat_p99;
    agg.fabric_hot_hits = fs.hot_hits;
    agg.fabric_hot_misses = fs.hot_misses;
    agg.fabric_writebacks = fs.writebacks;
    agg.faults = fs.faults.clone();
    agg.fault_nacks = fs.fault_nacks;
    agg.fault_retries = fs.fault_retries;
    agg.fault_retry_cycles = fs.fault_retry_cycles;
    agg.fault_timeouts = fs.fault_timeouts;
    agg.fault_degraded_cycles = fs.fault_degraded_cycles;
    agg.fault_slow_path = fs.fault_slow_path;
    agg.fault_max_stall = fs.fault_max_stall;
    // Per-core breakdown + fairness (requester-id attributed).
    agg.cluster_cores = n as u32;
    agg.core_cycles = per_core.iter().map(|s| s.cycles).collect();
    agg.core_instrs = per_core.iter().map(|s| s.dyn_instrs).collect();
    agg.core_fabric_requests = Vec::with_capacity(n);
    agg.core_fabric_p50 = Vec::with_capacity(n);
    agg.core_fabric_p99 = Vec::with_capacity(n);
    agg.core_fabric_stalls = Vec::with_capacity(n);
    agg.core_fault_retries = Vec::with_capacity(n);
    agg.core_fault_slow_path = Vec::with_capacity(n);
    for i in 0..n {
        let r = fs.requester(i as CoreId);
        agg.core_fabric_requests.push(r.requests);
        agg.core_fabric_p50.push(r.lat_p50);
        agg.core_fabric_p99.push(r.lat_p99);
        agg.core_fabric_stalls.push(r.queue_stall_cycles);
        agg.core_fault_retries.push(r.fault_retries);
        agg.core_fault_slow_path.push(r.fault_slow_path);
    }
    agg.cluster_fairness = jain_fairness(&agg.core_fabric_stalls);
    agg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::{self, Scale};
    use crate::compiler::{codegen, Variant};
    use crate::sim::fabric::FabricKind;
    use crate::sim::sched::SchedPolicyKind;
    use crate::sim::{self, MemImage};

    /// Link one fresh per-core program for `bench` under `cfg`, exactly
    /// as the engine would (same codegen options, same dataset seed).
    fn linked(cfg: &SimConfig, bench: &str, scale: Scale, seed: u64, variant: Variant) -> Program {
        let b = benchmarks::by_name(bench).unwrap();
        let inst = b.instance(scale, seed).unwrap();
        let opts = variant.opts(inst.default_tasks);
        let ck = codegen::compile(&inst.kernel, &opts, &cfg.amu).unwrap();
        sim::link(cfg, &ck, inst.mem, &inst.params)
    }

    fn image_bytes(mem: &MemImage) -> Vec<(String, Vec<u8>)> {
        mem.regions.iter().map(|r| (r.name.clone(), r.data.clone())).collect()
    }

    #[test]
    fn one_core_cluster_is_bit_identical_to_run() {
        // The degenerate interleave must replay the single-core driver
        // exactly: cycles, every stat bucket, and the memory image.
        let cfg = SimConfig::nh_g();
        let mut plain_prog = linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull);
        let plain = sim::run(&cfg, &mut plain_prog).unwrap();
        let mut cluster_prog = linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull);
        let mut agg = run_cluster(&cfg, std::slice::from_mut(&mut cluster_prog)).unwrap();
        assert_eq!(agg.cluster_cores, 1);
        assert_eq!(agg.core_cycles, vec![plain.cycles]);
        assert_eq!(agg.core_instrs, vec![plain.dyn_instrs]);
        assert_eq!(agg.core_fabric_requests, vec![plain.fabric_requests]);
        assert_eq!(agg.cluster_fairness, 1.0, "single core with no stalls is trivially fair");
        assert_eq!(image_bytes(&cluster_prog.mem), image_bytes(&plain_prog.mem));
        // Strip the cluster-only annotations; everything else must be
        // bit-identical to the plain path.
        agg.cluster_cores = 0;
        agg.core_cycles.clear();
        agg.core_instrs.clear();
        agg.core_fabric_requests.clear();
        agg.core_fabric_p50.clear();
        agg.core_fabric_p99.clear();
        agg.core_fabric_stalls.clear();
        agg.core_fault_retries.clear();
        agg.core_fault_slow_path.clear();
        agg.cluster_fairness = 0.0;
        assert_eq!(agg, plain);
    }

    #[test]
    fn cluster_runs_are_deterministic_and_attribute_per_core() {
        let cfg = SimConfig::nh_g().with_fabric(FabricKind::Queued { depth: 8 }).with_cores(2);
        let run_once = || {
            let mut progs = vec![
                linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
                linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
            ];
            let agg = run_cluster(&cfg, &mut progs).unwrap();
            let imgs: Vec<_> = progs.iter().map(|p| image_bytes(&p.mem)).collect();
            (agg, imgs)
        };
        let (a, ia) = run_once();
        let (b, ib) = run_once();
        assert_eq!(a, b, "cluster interleave must be deterministic");
        assert_eq!(ia, ib);
        // Both cores ran the same program; results are order-independent.
        assert_eq!(ia[0], ia[1], "cores diverged functionally");
        assert_eq!(a.cluster_cores, 2);
        assert_eq!(a.core_cycles.len(), 2);
        assert_eq!(*a.core_cycles.iter().max().unwrap(), a.cycles, "makespan = slowest core");
        assert_eq!(
            a.core_fabric_requests.iter().sum::<u64>(),
            a.fabric_requests,
            "requester attribution must partition the shared totals"
        );
        assert!(a.core_fabric_requests.iter().all(|&r| r > 0), "both cores reached the fabric");
        assert!(a.cluster_fairness > 0.0 && a.cluster_fairness <= 1.0);
    }

    #[test]
    fn shared_queued_fabric_makes_cores_contend() {
        // Two cores into one depth-limited queue must be slower than one
        // core owning it, and the congestion must show up as queue
        // stalls and a fatter tail.
        let cfg = SimConfig::nh_g().with_fabric(FabricKind::Queued { depth: 8 });
        let mut solo_prog = linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull);
        let solo = run_cluster(&cfg, std::slice::from_mut(&mut solo_prog)).unwrap();
        let mut progs = vec![
            linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
            linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
        ];
        let duo = run_cluster(&cfg, &mut progs).unwrap();
        assert!(
            duo.cycles > solo.cycles,
            "shared-fabric contention must cost cycles ({} vs {})",
            duo.cycles,
            solo.cycles
        );
        assert!(
            duo.fabric_queue_stalls > solo.fabric_queue_stalls,
            "a second requester must add queue backpressure ({} vs {})",
            duo.fabric_queue_stalls,
            solo.fabric_queue_stalls
        );
        assert!(
            duo.fabric_p99 >= solo.fabric_p99,
            "contention must not thin the latency tail ({} vs {})",
            duo.fabric_p99,
            solo.fabric_p99
        );
    }

    #[test]
    fn heterogeneous_policies_run_per_core_and_label_as_mixed() {
        let mut cfg = SimConfig::nh_g().with_cores(2);
        cfg.cluster.policies =
            Some(vec![SchedPolicyKind::ArrivalOrder, SchedPolicyKind::LatencyAware]);
        cfg.validate().unwrap();
        assert_eq!(cfg.core_policy(0), SchedPolicyKind::ArrivalOrder);
        assert_eq!(cfg.core_policy(1), SchedPolicyKind::LatencyAware);
        let mut progs = vec![
            linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
            linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
        ];
        let agg = run_cluster(&cfg, &mut progs).unwrap();
        assert_eq!(agg.sched_policy, "mixed");
        assert_eq!(image_bytes(&progs[0].mem), image_bytes(&progs[1].mem));
        assert!(agg.sched_picks > 0);
    }

    #[test]
    fn stall_free_cluster_aggregate_pins_fairness_to_one() {
        // Satellite: the all-zero-stalls case must surface as exactly
        // 1.0 in the *aggregate* too, not just the index function — two
        // cores on an unconstrained fixed delayer never queue-stall, so
        // the fairness column renders as perfectly fair by definition.
        let cfg = SimConfig::nh_g().with_cores(2);
        let mut progs = vec![
            linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
            linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
        ];
        let agg = run_cluster(&cfg, &mut progs).unwrap();
        assert_eq!(agg.core_fabric_stalls, vec![0, 0], "fixed delayer never backpressures");
        assert_eq!(agg.cluster_fairness, 1.0);
    }

    #[test]
    fn faulted_cluster_is_deterministic_and_attributes_per_core() {
        // Chaos on the shared fabric: two cores under the heavy preset
        // must replay bit-identically (the fault draws ride the
        // deterministic interleave), complete functionally, and the
        // per-core retry/slow-path attribution must partition the
        // shared totals.
        let cfg = SimConfig::nh_g()
            .with_fabric(FabricKind::Queued { depth: 8 })
            .with_faults(crate::sim::faults::FaultConfig::heavy())
            .with_cores(2);
        let run_once = || {
            let mut progs = vec![
                linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
                linked(&cfg, "gups", Scale::Tiny, 7, Variant::CoroAmuFull),
            ];
            let agg = run_cluster(&cfg, &mut progs).unwrap();
            let imgs: Vec<_> = progs.iter().map(|p| image_bytes(&p.mem)).collect();
            (agg, imgs)
        };
        let (a, ia) = run_once();
        let (b, ib) = run_once();
        assert_eq!(a, b, "faulted cluster interleave must be deterministic");
        assert_eq!(ia, ib);
        assert_eq!(ia[0], ia[1], "faults changed results across cores");
        assert_eq!(a.faults, "heavy");
        assert!(a.fault_nacks > 0, "heavy chaos on a cluster produced no NACKs");
        assert_eq!(a.core_fault_retries.len(), 2);
        assert_eq!(
            a.core_fault_retries.iter().sum::<u64>(),
            a.fault_retries,
            "retry attribution must partition the shared totals"
        );
        assert_eq!(a.core_fault_slow_path.iter().sum::<u64>(), a.fault_slow_path);
    }

    #[test]
    fn jain_fairness_index_shape() {
        assert_eq!(jain_fairness(&[0, 0, 0]), 1.0, "no stalls anywhere = fair");
        assert_eq!(jain_fairness(&[5, 5, 5, 5]), 1.0);
        let skewed = jain_fairness(&[100, 0, 0, 0]);
        assert!((skewed - 0.25).abs() < 1e-12, "one-core pileup = 1/n, got {skewed}");
        let mid = jain_fairness(&[3, 1]);
        assert!(mid > 0.5 && mid < 1.0, "partial skew lands strictly between, got {mid}");
    }
}
