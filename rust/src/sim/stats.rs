//! Per-run statistics: everything the paper's figures consume.

use crate::ir::CodeTag;

/// Where dispatch-stall cycles went (Figs 3 and 14 buckets).
/// `PartialEq` compares exact values — deterministic runs produce
/// bit-identical buckets, which the differential suite relies on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StallBuckets {
    /// Waiting on a remote-memory access at the ROB head.
    pub remote_mem: f64,
    /// Waiting on local-memory accesses (incl. context switching traffic).
    pub local_mem: f64,
    /// Branch-misprediction redirect penalties.
    pub mispredict: f64,
    /// Load/store-queue or AMU issue backpressure.
    pub backpressure: f64,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunStats {
    /// Total simulated cycles (last retirement).
    pub cycles: u64,
    /// Dynamic instructions, total and per block tag.
    pub dyn_instrs: u64,
    pub dyn_by_tag: [u64; 5],
    pub stalls: StallBuckets,
    // Branch statistics.
    pub cond_branches: u64,
    pub cond_mispredicts: u64,
    pub indirect_jumps: u64,
    pub indirect_mispredicts: u64,
    pub bafins_taken: u64,
    pub bafins_fallthrough: u64,
    pub bafin_mispredicts: u64,
    // Memory statistics.
    pub loads: u64,
    pub stores: u64,
    pub prefetches: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub far_lines: u64,
    pub far_mlp: f64,
    pub far_busy_frac: f64,
    // AMU.
    pub aloads: u64,
    pub astores: u64,
    pub amu_max_inflight: usize,
    pub awaits: u64,
    // Coroutine runtime.
    pub switches: u64,
    pub ctx_ops: u64,
    pub tasks_completed: u64,
    // Scheduler policy (sim::sched): which policy ordered the Finished
    // Queue, and how it behaved. Deterministic like everything else here,
    // so the differential suite compares them bit-for-bit too.
    /// Label of the active policy (`SchedPolicyKind::label`).
    pub sched_policy: String,
    /// Finished-Queue polls (getfin/bafin asks, incl. empty-queue).
    pub sched_polls: u64,
    /// Polls the policy answered with a coroutine resume.
    pub sched_picks: u64,
    /// Polls deferred although a completion was visible (FIFO
    /// head-of-line blocking, batched-wakeup coalescing).
    pub sched_holds: u64,
    /// Scheduler-attributed indirect jumps (getfin-style dispatch)
    /// and their ITTAGE mispredicts — the coverage axis the policy
    /// controls (memory-guided vs learnable-static target streams).
    pub sched_indirect_jumps: u64,
    pub sched_indirect_mispredicts: u64,
    // Far-memory fabric (sim::fabric): which backend served the far
    // tier and how it behaved. Deterministic like everything else here,
    // so the differential suite compares them bit-for-bit too.
    /// Label of the active fabric (`FabricKind::label`).
    pub fabric: String,
    /// Requests the far tier served (fills, prefetch fills, AMU
    /// transfers).
    pub fabric_requests: u64,
    /// Peak request-queue occupancy (`queued` backend; 0 elsewhere).
    pub fabric_max_inflight: u64,
    /// Cycles requests waited for a queue slot (congestion backpressure).
    pub fabric_queue_stalls: u64,
    /// Far-request latency percentiles (8-cycle bucket resolution).
    pub fabric_p50: u64,
    pub fabric_p99: u64,
    /// Hot-page cache behavior (`tiered` backend; 0 elsewhere).
    pub fabric_hot_hits: u64,
    pub fabric_hot_misses: u64,
    pub fabric_writebacks: u64,
    // Multi-core cluster (sim::cluster): per-core breakdowns plus the
    // aggregates the scaling figures consume. Single-core runs leave all
    // of these at their defaults (0 / empty / 0.0), so the differential
    // suite's bit-equality over `RunStats` is unaffected by the cluster
    // subsystem existing.
    /// Number of cores that produced this run (0 = plain single-core
    /// path, which never goes through `sim::cluster`).
    pub cluster_cores: u32,
    /// Per-core total cycles (aggregate `cycles` = the slowest core).
    pub core_cycles: Vec<u64>,
    /// Per-core dynamic instruction counts.
    pub core_instrs: Vec<u64>,
    /// Per-core shared-fabric request counts (requester-id attributed).
    pub core_fabric_requests: Vec<u64>,
    /// Per-core shared-fabric latency percentiles.
    pub core_fabric_p50: Vec<u64>,
    pub core_fabric_p99: Vec<u64>,
    /// Per-core queue-stall cycles on the shared fabric (the fairness
    /// denominator).
    pub core_fabric_stalls: Vec<u64>,
    /// Jain's fairness index over `core_fabric_stalls`
    /// ((Σx)² / (n·Σx²); 1.0 = perfectly even, 1/n = one core eats
    /// everything; defined as 1.0 when no core stalled at all).
    /// 0.0 on single-core runs (no cluster).
    pub cluster_fairness: f64,
    // -- Fault injection (sim::faults): resilience counters from the
    // FaultyFabric decorator. Fault-free runs (the default) leave all of
    // these at their defaults (empty label / 0), so bit-equality over
    // `RunStats` is unaffected by the fault subsystem existing.
    /// Label of the active fault spec (`FaultConfig::label`; empty when
    /// faults are off).
    pub faults: String,
    /// Attempts NACKed (transient failures + blackout windows).
    pub fault_nacks: u64,
    /// Retries charged (bounded by the per-request budget).
    pub fault_retries: u64,
    /// Cycles spent in exponential backoff across all retries.
    pub fault_retry_cycles: u64,
    /// Attempts abandoned at the per-request timeout.
    pub fault_timeouts: u64,
    /// Extra service cycles charged inside degradation windows.
    pub fault_degraded_cycles: u64,
    /// Requests that exhausted the retry budget and completed via the
    /// slow path (a hard error under `faults.strict`).
    pub fault_slow_path: u64,
    /// Worst issue-to-completion stall of any single far request.
    pub fault_max_stall: u64,
    /// Per-core retry / slow-path attribution on cluster runs
    /// (requester-id attributed; empty on single-core runs).
    pub core_fault_retries: Vec<u64>,
    pub core_fault_slow_path: Vec<u64>,
    // -- Service mode (sim::service): the open-loop request-serving layer
    // replayed over this run's calibrated per-request cost. Service-off
    // runs (the default) leave all of these at their defaults (empty
    // label / 0), so bit-equality over `RunStats` is unaffected by the
    // service subsystem existing.
    /// Label of the active service spec (`ServiceConfig::label`; empty
    /// when service mode is off).
    pub service: String,
    /// Calibrated per-request cost in cycles (the saturation knee:
    /// `cycles / tasks_completed` of the underlying batch run).
    pub svc_capacity_cost: u64,
    /// Requests the arrival process offered.
    pub svc_offered: u64,
    /// Requests admitted to the queue.
    pub svc_accepted: u64,
    /// Requests rejected at a full admission queue (backpressure).
    pub svc_rejected: u64,
    /// Admitted requests shed at dispatch because their deadline had
    /// already expired in the queue.
    pub svc_shed_expired: u64,
    /// Requests actually served by a handler.
    pub svc_served: u64,
    /// Served requests that met their deadline (the SLO numerator).
    pub svc_goodput: u64,
    /// Served requests that finished past their deadline.
    pub svc_timed_out: u64,
    /// Sojourn-time percentiles (arrival -> completion, histogram
    /// bucket resolution).
    pub svc_p50: u64,
    pub svc_p99: u64,
    pub svc_p999: u64,
    /// Peak admission-queue occupancy.
    pub svc_max_queue: u64,
    /// Requests served on the cheap path while the overload detector
    /// held the server in degraded mode.
    pub svc_degraded_served: u64,
    /// Times the overload detector tripped into degraded mode.
    pub svc_degraded_spells: u64,
    /// Trace events observed / dropped at ring overflow (DESIGN.md §14;
    /// both zero — hence bit-identical — when tracing is off).
    pub trace_events: u64,
    pub trace_dropped: u64,
}

/// Default reorder window of [`IntervalUnion`] (see
/// [`IntervalUnion::with_window`] for how channels size it to their
/// actual in-flight depth).
const UNION_WINDOW: usize = 64;

/// Exact online interval-union accumulator with fixed memory.
///
/// Replaces the old per-channel `Vec<(issue, completion)>` that grew by
/// one entry per far-memory request and was cloned + sorted on every
/// MLP report. The accumulator keeps the running `integral` (Σ lengths,
/// order-independent) and folds intervals into a running union through
/// a bounded reorder window kept as a min-heap: once the window fills,
/// each push flushes the minimum-start pending interval into the union
/// (O(log window), no allocation). As long as every arrival is within
/// `window` pushes of its start-sorted position the flush order equals
/// the fully-sorted order and the result is bit-identical to the old
/// clone-and-sort; the channel sizes the window to its maximum
/// simultaneous in-flight request count (AMU request table + MSHRs +
/// margin), which bounds exactly that skew. A straggler beyond the
/// window can still extend the open run backward; only one disjointly
/// *before* the open run would be bridged into it. Both interpreter
/// paths feed identical request streams through this same accumulator,
/// so the differential suite's bit-identity is unconditional.
#[derive(Debug, Clone)]
pub struct IntervalUnion {
    /// Σ (end - start) over all pushed intervals.
    integral: u64,
    /// Union length of fully-merged (closed) runs.
    closed: u64,
    /// The open run still being extended, as (start, end).
    cur: Option<(u64, u64)>,
    /// Min-heap (by (start, end)) of pending intervals awaiting flush.
    /// Capacity is reserved once at construction; steady state never
    /// allocates.
    pending: Vec<(u64, u64)>,
    window: usize,
    count: u64,
}

impl Default for IntervalUnion {
    fn default() -> Self {
        Self::new()
    }
}

impl IntervalUnion {
    pub fn new() -> IntervalUnion {
        Self::with_window(UNION_WINDOW)
    }

    /// An accumulator whose reorder window holds `window` intervals.
    /// Exactness vs the sort-everything oracle is guaranteed while no
    /// interval arrives more than `window` pushes after an interval
    /// with a larger start — callers should size this to the maximum
    /// number of simultaneously in-flight requests.
    pub fn with_window(window: usize) -> IntervalUnion {
        let window = window.max(1);
        IntervalUnion {
            integral: 0,
            closed: 0,
            cur: None,
            pending: Vec::with_capacity(window),
            window,
            count: 0,
        }
    }

    /// Record one interval. O(log window) once saturated; no heap
    /// allocation after construction.
    pub fn push(&mut self, start: u64, end: u64) {
        debug_assert!(end >= start, "inverted interval {start}..{end}");
        self.integral += end - start;
        self.count += 1;
        let iv = (start, end);
        if self.pending.len() < self.window {
            self.pending.push(iv);
            self.sift_up(self.pending.len() - 1);
            return;
        }
        // Window full: flush the minimum of (pending ∪ {iv}).
        let root = self.pending[0];
        if iv < root {
            // The incoming interval is itself the minimum.
            Self::merge(&mut self.closed, &mut self.cur, iv);
        } else {
            self.pending[0] = iv;
            self.sift_down(0);
            Self::merge(&mut self.closed, &mut self.cur, root);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.pending[i] < self.pending[parent] {
                self.pending.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.pending.len();
        loop {
            let l = 2 * i + 1;
            let r = l + 1;
            let mut smallest = i;
            if l < n && self.pending[l] < self.pending[smallest] {
                smallest = l;
            }
            if r < n && self.pending[r] < self.pending[smallest] {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.pending.swap(i, smallest);
            i = smallest;
        }
    }

    fn merge(closed: &mut u64, cur: &mut Option<(u64, u64)>, (s, e): (u64, u64)) {
        match *cur {
            None => *cur = Some((s, e)),
            Some((cs, ce)) => {
                if s > ce {
                    *closed += ce - cs;
                    *cur = Some((s, e));
                } else {
                    // In-window reordering can hand us an interval that
                    // starts before the open run; extend it backward.
                    *cur = Some((cs.min(s), ce.max(e)));
                }
            }
        }
    }

    /// Total union (busy) length. Flushes a sorted copy of the pending
    /// window into the union; called once per report, not per request,
    /// so its O(window log window) copy+sort is off the hot path.
    pub fn busy(&self) -> u64 {
        let mut tmp = self.pending.clone();
        tmp.sort_unstable();
        let mut closed = self.closed;
        let mut cur = self.cur;
        for &iv in &tmp {
            Self::merge(&mut closed, &mut cur, iv);
        }
        closed + cur.map_or(0, |(s, e)| e - s)
    }

    /// Σ interval lengths (the MLP numerator).
    pub fn integral(&self) -> u64 {
        self.integral
    }

    /// Number of intervals pushed.
    pub fn count(&self) -> u64 {
        self.count
    }
}

pub fn tag_index(t: CodeTag) -> usize {
    match t {
        CodeTag::Compute => 0,
        CodeTag::Scheduler => 1,
        CodeTag::CtxSwitch => 2,
        CodeTag::Init => 3,
        CodeTag::Lifecycle => 4,
    }
}

pub const TAG_NAMES: [&str; 5] = ["compute", "scheduler", "ctxswitch", "init", "lifecycle"];

impl RunStats {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.dyn_instrs as f64 / self.cycles as f64
        }
    }

    /// Context load/stores per scheduler switch (Fig. 15 right axis).
    pub fn ctx_ops_per_switch(&self) -> f64 {
        if self.switches == 0 {
            0.0
        } else {
            self.ctx_ops as f64 / self.switches as f64
        }
    }

    /// Cycle breakdown for Figs 3/14: (compute+width, local, remote,
    /// scheduler overhead incl. lifecycle, mispredict), normalized shares.
    pub fn cycle_breakdown(&self) -> [(String, f64); 5] {
        let total = self.cycles.max(1) as f64;
        let stall_sum = self.stalls.remote_mem + self.stalls.local_mem + self.stalls.mispredict + self.stalls.backpressure;
        let base = (total - stall_sum).max(0.0);
        // Split base-issue cycles across tags by dynamic instruction share.
        let di = self.dyn_instrs.max(1) as f64;
        let sched_share = (self.dyn_by_tag[1] + self.dyn_by_tag[4]) as f64 / di;
        let ctx_share = self.dyn_by_tag[2] as f64 / di;
        let compute = base * (1.0 - sched_share - ctx_share);
        [
            ("compute".into(), compute / total),
            ("local/ctx".into(), (self.stalls.local_mem + base * ctx_share) / total),
            ("remote".into(), self.stalls.remote_mem / total),
            ("scheduler".into(), (base * sched_share + self.stalls.backpressure) / total),
            ("mispredict".into(), self.stalls.mispredict / total),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums_to_one() {
        let s = RunStats {
            cycles: 1000,
            dyn_instrs: 800,
            dyn_by_tag: [400, 200, 100, 50, 50],
            stalls: StallBuckets { remote_mem: 300.0, local_mem: 100.0, mispredict: 50.0, backpressure: 25.0 },
            ..Default::default()
        };
        let b = s.cycle_breakdown();
        let sum: f64 = b.iter().map(|(_, v)| v).sum();
        assert!((sum - 1.0).abs() < 1e-9, "breakdown sums to {sum}");
        assert!(b.iter().all(|(_, v)| *v >= 0.0));
    }

    /// Reference union: the old clone-and-sort merge, kept here as the
    /// oracle the online accumulator is pinned against.
    fn brute_union(iv: &[(u64, u64)]) -> (u64, u64) {
        if iv.is_empty() {
            return (0, 0);
        }
        let mut v = iv.to_vec();
        v.sort_unstable();
        let mut busy = 0u64;
        let mut integral = 0u64;
        let (mut cs, mut ce) = v[0];
        for &(s, e) in &v {
            integral += e - s;
            if s > ce {
                busy += ce - cs;
                cs = s;
                ce = e;
            } else {
                ce = ce.max(e);
            }
        }
        busy += ce - cs;
        (integral, busy)
    }

    #[test]
    fn interval_union_hand_computed() {
        // Disjoint + overlapping + contained, in order:
        //   [0,10) ∪ [5,20) ∪ [30,40) ∪ [32,35) = [0,20) ∪ [30,40) → 30
        let mut u = IntervalUnion::new();
        for (s, e) in [(0, 10), (5, 20), (30, 40), (32, 35)] {
            u.push(s, e);
        }
        assert_eq!(u.integral(), 10 + 15 + 10 + 3);
        assert_eq!(u.busy(), 30);
        assert_eq!(u.count(), 4);
    }

    #[test]
    fn interval_union_out_of_order_issue() {
        // Out-of-order arrival (the MSHR-overlap pattern): a later-issued
        // request completes first and is pushed first.
        let iv = [(100u64, 700u64), (40, 600), (90, 95), (800, 900), (750, 820)];
        let mut u = IntervalUnion::new();
        for &(s, e) in &iv {
            u.push(s, e);
        }
        // Union: [40,700) ∪ [750,900) = 660 + 150 = 810.
        assert_eq!(u.busy(), 810);
        assert_eq!((u.integral(), u.busy()), brute_union(&iv));
    }

    #[test]
    fn interval_union_empty_and_single() {
        let u = IntervalUnion::new();
        assert_eq!((u.integral(), u.busy(), u.count()), (0, 0, 0));
        let mut u = IntervalUnion::new();
        u.push(7, 7); // zero-length interval
        assert_eq!((u.integral(), u.busy()), (0, 0));
        u.push(10, 25);
        assert_eq!((u.integral(), u.busy()), (15, 15));
    }

    #[test]
    fn interval_union_tiny_window_stays_exact_in_order() {
        // Window 2, sorted arrival: exact regardless of window size.
        // Exercises both heap paths (replace-root and incoming-is-min).
        let iv = [(0u64, 5u64), (3, 8), (20, 21), (22, 30), (25, 40), (100, 101)];
        let mut u = IntervalUnion::with_window(2);
        for &(s, e) in &iv {
            u.push(s, e);
        }
        assert_eq!((u.integral(), u.busy()), brute_union(&iv));
        // Union: [0,8) ∪ [20,21) ∪ [22,40) ∪ [100,101) = 8+1+18+1 = 28.
        assert_eq!(u.busy(), 28);
    }

    #[test]
    fn interval_union_matches_brute_force_past_window() {
        // Many more intervals than the reorder window, with bounded
        // local shuffling — the accumulator must agree with the old
        // clone-and-sort exactly while holding O(1) state.
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
        let mut iv: Vec<(u64, u64)> = Vec::new();
        let mut t = 0u64;
        for _ in 0..1000 {
            t += rng.below(50);
            let len = 1 + rng.below(400);
            iv.push((t, t + len));
        }
        // Shuffle each run of 32 (within the 64-entry window).
        for chunk in iv.chunks_mut(32) {
            let n = chunk.len() as u64;
            for i in (1..chunk.len()).rev() {
                chunk.swap(i, rng.below(n.min(i as u64 + 1)) as usize);
            }
        }
        let mut u = IntervalUnion::new();
        for &(s, e) in &iv {
            u.push(s, e);
        }
        assert_eq!((u.integral(), u.busy()), brute_union(&iv));
        assert_eq!(u.count(), 1000);
    }

    #[test]
    fn ipc_and_ratios() {
        let mut s = RunStats::default();
        assert_eq!(s.ipc(), 0.0);
        s.cycles = 100;
        s.dyn_instrs = 250;
        assert!((s.ipc() - 2.5).abs() < 1e-12);
        s.switches = 10;
        s.ctx_ops = 35;
        assert!((s.ctx_ops_per_switch() - 3.5).abs() < 1e-12);
    }
}
