//! Per-run statistics: everything the paper's figures consume.

use crate::ir::CodeTag;

/// Where dispatch-stall cycles went (Figs 3 and 14 buckets).
/// `PartialEq` compares exact values — deterministic runs produce
/// bit-identical buckets, which the differential suite relies on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StallBuckets {
    /// Waiting on a remote-memory access at the ROB head.
    pub remote_mem: f64,
    /// Waiting on local-memory accesses (incl. context switching traffic).
    pub local_mem: f64,
    /// Branch-misprediction redirect penalties.
    pub mispredict: f64,
    /// Load/store-queue or AMU issue backpressure.
    pub backpressure: f64,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunStats {
    /// Total simulated cycles (last retirement).
    pub cycles: u64,
    /// Dynamic instructions, total and per block tag.
    pub dyn_instrs: u64,
    pub dyn_by_tag: [u64; 5],
    pub stalls: StallBuckets,
    // Branch statistics.
    pub cond_branches: u64,
    pub cond_mispredicts: u64,
    pub indirect_jumps: u64,
    pub indirect_mispredicts: u64,
    pub bafins_taken: u64,
    pub bafins_fallthrough: u64,
    pub bafin_mispredicts: u64,
    // Memory statistics.
    pub loads: u64,
    pub stores: u64,
    pub prefetches: u64,
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub far_lines: u64,
    pub far_mlp: f64,
    pub far_busy_frac: f64,
    // AMU.
    pub aloads: u64,
    pub astores: u64,
    pub amu_max_inflight: usize,
    pub awaits: u64,
    // Coroutine runtime.
    pub switches: u64,
    pub ctx_ops: u64,
    pub tasks_completed: u64,
}

pub fn tag_index(t: CodeTag) -> usize {
    match t {
        CodeTag::Compute => 0,
        CodeTag::Scheduler => 1,
        CodeTag::CtxSwitch => 2,
        CodeTag::Init => 3,
        CodeTag::Lifecycle => 4,
    }
}

pub const TAG_NAMES: [&str; 5] = ["compute", "scheduler", "ctxswitch", "init", "lifecycle"];

impl RunStats {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.dyn_instrs as f64 / self.cycles as f64
        }
    }

    /// Context load/stores per scheduler switch (Fig. 15 right axis).
    pub fn ctx_ops_per_switch(&self) -> f64 {
        if self.switches == 0 {
            0.0
        } else {
            self.ctx_ops as f64 / self.switches as f64
        }
    }

    /// Cycle breakdown for Figs 3/14: (compute+width, local, remote,
    /// scheduler overhead incl. lifecycle, mispredict), normalized shares.
    pub fn cycle_breakdown(&self) -> [(String, f64); 5] {
        let total = self.cycles.max(1) as f64;
        let stall_sum = self.stalls.remote_mem + self.stalls.local_mem + self.stalls.mispredict + self.stalls.backpressure;
        let base = (total - stall_sum).max(0.0);
        // Split base-issue cycles across tags by dynamic instruction share.
        let di = self.dyn_instrs.max(1) as f64;
        let sched_share = (self.dyn_by_tag[1] + self.dyn_by_tag[4]) as f64 / di;
        let ctx_share = self.dyn_by_tag[2] as f64 / di;
        let compute = base * (1.0 - sched_share - ctx_share);
        [
            ("compute".into(), compute / total),
            ("local/ctx".into(), (self.stalls.local_mem + base * ctx_share) / total),
            ("remote".into(), self.stalls.remote_mem / total),
            ("scheduler".into(), (base * sched_share + self.stalls.backpressure) / total),
            ("mispredict".into(), self.stalls.mispredict / total),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums_to_one() {
        let s = RunStats {
            cycles: 1000,
            dyn_instrs: 800,
            dyn_by_tag: [400, 200, 100, 50, 50],
            stalls: StallBuckets { remote_mem: 300.0, local_mem: 100.0, mispredict: 50.0, backpressure: 25.0 },
            ..Default::default()
        };
        let b = s.cycle_breakdown();
        let sum: f64 = b.iter().map(|(_, v)| v).sum();
        assert!((sum - 1.0).abs() < 1e-9, "breakdown sums to {sum}");
        assert!(b.iter().all(|(_, v)| *v >= 0.0));
    }

    #[test]
    fn ipc_and_ratios() {
        let mut s = RunStats::default();
        assert_eq!(s.ipc(), 0.0);
        s.cycles = 100;
        s.dyn_instrs = 250;
        assert!((s.ipc() - 2.5).abs() < 1e-12);
        s.switches = 10;
        s.ctx_ops = 35;
        assert!((s.ctx_ops_per_switch() - 3.5).abs() < 1e-12);
    }
}
