//! Cycle-level event tracing and stall attribution (DESIGN.md §14).
//!
//! Opt-in, deterministic, zero-overhead-when-off telemetry for the
//! simulator. A [`Tracer`] is carried as `Option<Box<Tracer>>` by the
//! interpreter `Machine`, so the off path constructs nothing and stays
//! bit-identical by construction (pinned in the differential suite).
//!
//! Event classes (filterable via `[trace] classes`):
//! - `coro`    coroutine lifecycle: spawn / suspend / resume / finish
//! - `amu`     AMU request issue→complete with addr class and latency
//! - `sched`   scheduler decisions: pick / hold
//! - `fabric`  queue-depth + hot-page counter samples every N cycles
//! - `fault`   nack / retry / timeout / slow-path deltas
//! - `service` admission reject / shed / degraded-mode transitions
//!
//! Two sinks: [`chrome_json`] (Chrome trace-event JSON, loadable in
//! Perfetto; written atomically like the store) and [`render_profile`]
//! (terminal report: per-coroutine stall attribution, top-N tail
//! latency requests, queue-occupancy sparkline).
//!
//! Determinism: events are emitted at points that are themselves
//! deterministic functions of the simulated execution, counter samples
//! fire on a fixed cycle grid, and all aggregate maps are `BTreeMap`s —
//! two runs of the same seed produce byte-identical event logs
//! (`Trace::event_log`), pinned by the differential suite.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::fabric::FabricGauges;
use super::stats::StallBuckets;

/// Default counter-sample period in cycles.
pub const DEFAULT_SAMPLE_EVERY: u64 = 4096;
/// Default ring capacity (retained events).
pub const DEFAULT_RING_CAP: usize = 1 << 16;
/// How many tail-latency requests the profile keeps.
pub const TOP_REQUESTS: usize = 16;
/// Pseudo coroutine id for cycles outside any coroutine (main thread).
pub const MAIN_CORO: i64 = i64::MIN;

// ---------------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------------

/// Bitmask of event classes to record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceClasses(pub u8);

impl TraceClasses {
    pub const CORO: u8 = 1 << 0;
    pub const AMU: u8 = 1 << 1;
    pub const SCHED: u8 = 1 << 2;
    pub const FABRIC: u8 = 1 << 3;
    pub const FAULT: u8 = 1 << 4;
    pub const SERVICE: u8 = 1 << 5;
    const NAMES: [(&'static str, u8); 6] = [
        ("coro", Self::CORO),
        ("amu", Self::AMU),
        ("sched", Self::SCHED),
        ("fabric", Self::FABRIC),
        ("fault", Self::FAULT),
        ("service", Self::SERVICE),
    ];

    pub fn all() -> TraceClasses {
        TraceClasses(0x3f)
    }

    #[inline]
    pub fn has(self, class: u8) -> bool {
        self.0 & class != 0
    }

    /// Parse a comma-separated class list ("coro,amu" / "all").
    pub fn parse(s: &str) -> Result<TraceClasses> {
        let s = s.trim();
        if s.is_empty() || s == "all" {
            return Ok(Self::all());
        }
        let mut mask = 0u8;
        for part in s.split(',') {
            let part = part.trim();
            match Self::NAMES.iter().find(|(n, _)| *n == part) {
                Some((_, bit)) => mask |= bit,
                None => bail!(
                    "unknown trace class '{part}' (known: {}, or 'all')",
                    Self::NAMES.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                ),
            }
        }
        Ok(TraceClasses(mask))
    }

    pub fn label(self) -> String {
        if self == Self::all() {
            return "all".into();
        }
        let names: Vec<&str> =
            Self::NAMES.iter().filter(|(_, b)| self.has(*b)).map(|(n, _)| *n).collect();
        names.join(",")
    }
}

/// `[trace]` section of [`crate::config::SimConfig`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceConfig {
    /// Master switch. When false the simulator constructs no tracer state.
    pub enabled: bool,
    /// Counter-sample period in cycles (fabric/AMU occupancy gauges).
    pub sample_every: u64,
    /// Max events retained; overflow increments `trace_dropped`.
    pub ring_cap: usize,
    /// Which event classes to record.
    pub classes: TraceClasses,
}

impl TraceConfig {
    pub fn off() -> TraceConfig {
        TraceConfig {
            enabled: false,
            sample_every: DEFAULT_SAMPLE_EVERY,
            ring_cap: DEFAULT_RING_CAP,
            classes: TraceClasses::all(),
        }
    }

    pub fn on() -> TraceConfig {
        TraceConfig { enabled: true, ..Self::off() }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn label(&self) -> String {
        if !self.enabled {
            return "off".into();
        }
        format!(
            "on(sample={},cap={},classes={})",
            self.sample_every,
            self.ring_cap,
            self.classes.label()
        )
    }

    pub fn validate(&self) -> Result<()> {
        if self.sample_every == 0 {
            bail!("[trace] sample_every must be >= 1");
        }
        if self.ring_cap == 0 {
            bail!("[trace] ring_cap must be >= 1");
        }
        if self.ring_cap > (1 << 24) {
            bail!("[trace] ring_cap {} too large (max {})", self.ring_cap, 1usize << 24);
        }
        Ok(())
    }
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self::off()
    }
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// Address class of an AMU request (mirrors `ir::AddrSpace`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AddrClass {
    Local,
    Remote,
    Spm,
}

impl AddrClass {
    pub fn name(self) -> &'static str {
        match self {
            AddrClass::Local => "local",
            AddrClass::Remote => "remote",
            AddrClass::Spm => "spm",
        }
    }
}

/// A compact trace event. `Copy` so the ring is a flat `Vec`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// First AMU transfer observed for this coroutine id.
    CoroSpawn { id: i64 },
    /// Context switched away from this coroutine.
    CoroSuspend { id: i64 },
    /// Context switched into this coroutine.
    CoroResume { id: i64 },
    /// Program halted while this coroutine was current.
    CoroFinish { id: i64 },
    /// AMU request issue→complete (latency = done - issue).
    AmuReq { id: i64, issue: u64, done: u64, store: bool, class: AddrClass, lines: u64 },
    /// Scheduler picked this coroutine from the finished queue.
    SchedPick { id: i64 },
    /// Scheduler saw visible completions but deferred them (policy hold).
    SchedHold { held: u64 },
    /// Periodic counter sample (fabric occupancy + AMU slots in flight).
    Sample {
        inflight: u64,
        queue_stalls: u64,
        hot_hits: u64,
        hot_misses: u64,
        amu_inflight: u64,
    },
    /// Fault-injection deltas since the previous check.
    FaultNack { n: u64 },
    FaultRetry { n: u64 },
    FaultTimeout { n: u64 },
    FaultSlowPath { n: u64 },
    /// Service-mode admission/degradation transitions.
    SvcReject,
    SvcShedExpired,
    SvcDegradeEnter,
    SvcDegradeExit,
}

impl EventKind {
    fn class(&self) -> u8 {
        match self {
            EventKind::CoroSpawn { .. }
            | EventKind::CoroSuspend { .. }
            | EventKind::CoroResume { .. }
            | EventKind::CoroFinish { .. } => TraceClasses::CORO,
            EventKind::AmuReq { .. } => TraceClasses::AMU,
            EventKind::SchedPick { .. } | EventKind::SchedHold { .. } => TraceClasses::SCHED,
            EventKind::Sample { .. } => TraceClasses::FABRIC,
            EventKind::FaultNack { .. }
            | EventKind::FaultRetry { .. }
            | EventKind::FaultTimeout { .. }
            | EventKind::FaultSlowPath { .. } => TraceClasses::FAULT,
            EventKind::SvcReject
            | EventKind::SvcShedExpired
            | EventKind::SvcDegradeEnter
            | EventKind::SvcDegradeExit => TraceClasses::SERVICE,
        }
    }
}

/// One recorded event: cycle, originating core, payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub t: u64,
    pub core: u32,
    pub kind: EventKind,
}

// ---------------------------------------------------------------------------
// Per-coroutine stall attribution
// ---------------------------------------------------------------------------

/// Aggregated per-coroutine profile row. Kept outside the event ring so
/// the attribution stays exact even when the ring overflows.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CoroProf {
    /// Times the coroutine was resumed (context switches into it).
    pub resumes: u64,
    /// Total cycles attributed to this coroutine's segments.
    pub cycles: f64,
    /// Cycles not covered by any stall bucket (useful work + overlap).
    pub compute: f64,
    /// Stall-bucket deltas accrued during this coroutine's segments.
    pub remote_mem: f64,
    pub local_mem: f64,
    pub mispredict: f64,
    pub backpressure: f64,
    /// AMU requests issued on behalf of this id, and their summed latency.
    pub reqs: u64,
    pub req_latency: u64,
}

impl CoroProf {
    pub fn stall_total(&self) -> f64 {
        self.remote_mem + self.local_mem + self.mispredict + self.backpressure
    }
}

/// A tail-latency request kept for the profile's top-N table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReqRecord {
    pub core: u32,
    pub id: i64,
    pub issue: u64,
    pub done: u64,
    /// Issue order, for deterministic tie-breaking.
    pub seq: u64,
}

impl ReqRecord {
    pub fn latency(&self) -> u64 {
        self.done - self.issue
    }
}

// ---------------------------------------------------------------------------
// Tracer (live, carried by the interpreter)
// ---------------------------------------------------------------------------

/// Live trace recorder. Constructed only when `TraceConfig::enabled`;
/// the off path carries `None` and allocates nothing.
#[derive(Debug)]
pub struct Tracer {
    cfg: TraceConfig,
    core: u32,
    events: Vec<Event>,
    total: u64,
    dropped: u64,
    // --- stall attribution state ---
    /// Coroutine the core is currently running ([`MAIN_CORO`] = none).
    cur: i64,
    seg_start_cycles: u64,
    seg_start_stalls: StallBuckets,
    attrib: BTreeMap<i64, CoroProf>,
    // --- sampling state ---
    next_sample: u64,
    last_gauges: FabricGauges,
    // --- top-N tail latency ---
    top: Vec<ReqRecord>,
    req_seq: u64,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Box<Tracer> {
        Self::for_core(cfg, 0)
    }

    pub fn for_core(cfg: TraceConfig, core: u32) -> Box<Tracer> {
        Box::new(Tracer {
            cfg,
            core,
            events: Vec::with_capacity(cfg.ring_cap.min(4096)),
            total: 0,
            dropped: 0,
            cur: MAIN_CORO,
            seg_start_cycles: 0,
            seg_start_stalls: StallBuckets::default(),
            attrib: BTreeMap::new(),
            next_sample: cfg.sample_every,
            last_gauges: FabricGauges::default(),
            top: Vec::with_capacity(TOP_REQUESTS + 1),
            req_seq: 0,
        })
    }

    fn emit(&mut self, t: u64, kind: EventKind) {
        if !self.cfg.classes.has(kind.class()) {
            return;
        }
        self.total += 1;
        if self.events.len() < self.cfg.ring_cap {
            self.events.push(Event { t, core: self.core, kind });
        } else {
            self.dropped += 1;
        }
    }

    /// Close the open attribution segment `[seg_start, now)` against the
    /// core's cumulative stall buckets and charge it to `self.cur`.
    fn close_segment(&mut self, now: u64, stalls: &StallBuckets) {
        let interval = now.saturating_sub(self.seg_start_cycles) as f64;
        let d_remote = stalls.remote_mem - self.seg_start_stalls.remote_mem;
        let d_local = stalls.local_mem - self.seg_start_stalls.local_mem;
        let d_mis = stalls.mispredict - self.seg_start_stalls.mispredict;
        let d_back = stalls.backpressure - self.seg_start_stalls.backpressure;
        let p = self.attrib.entry(self.cur).or_default();
        p.cycles += interval;
        p.remote_mem += d_remote;
        p.local_mem += d_local;
        p.mispredict += d_mis;
        p.backpressure += d_back;
        p.compute += (interval - (d_remote + d_local + d_mis + d_back)).max(0.0);
        self.seg_start_cycles = now;
        self.seg_start_stalls = *stalls;
    }

    /// Context switch at cycle `t`: attribute the closing segment, record
    /// suspend of the old coroutine and resume of `next` (None = back to
    /// the main/scheduler context).
    pub fn on_switch(&mut self, t: u64, core_cycles: u64, stalls: &StallBuckets, next: Option<i64>) {
        self.close_segment(core_cycles, stalls);
        if self.cur != MAIN_CORO {
            let id = self.cur;
            self.emit(t, EventKind::CoroSuspend { id });
        }
        match next {
            Some(id) => {
                self.emit(t, EventKind::CoroResume { id });
                self.attrib.entry(id).or_default().resumes += 1;
                self.cur = id;
            }
            None => self.cur = MAIN_CORO,
        }
    }

    /// AMU transfer issued for coroutine `id` at `issue`, completing at
    /// `done`. Emits the spawn event on first sight of the id.
    pub fn on_transfer(
        &mut self,
        id: i64,
        issue: u64,
        done: u64,
        store: bool,
        class: AddrClass,
        lines: u64,
    ) {
        if !self.attrib.contains_key(&id) {
            self.attrib.insert(id, CoroProf::default());
            self.emit(issue, EventKind::CoroSpawn { id });
        }
        self.emit(issue, EventKind::AmuReq { id, issue, done, store, class, lines });
        let p = self.attrib.get_mut(&id).expect("inserted above");
        p.reqs += 1;
        p.req_latency += done.saturating_sub(issue);
        self.note_req(id, issue, done);
    }

    fn note_req(&mut self, id: i64, issue: u64, done: u64) {
        let rec = ReqRecord { core: self.core, id, issue, done, seq: self.req_seq };
        self.req_seq += 1;
        let lat = rec.latency();
        if self.top.len() >= TOP_REQUESTS
            && self.top.last().map(|r| lat <= r.latency()).unwrap_or(false)
        {
            return;
        }
        self.top.push(rec);
        // Longest first; earlier issue order wins ties (deterministic).
        self.top.sort_by(|a, b| b.latency().cmp(&a.latency()).then(a.seq.cmp(&b.seq)));
        self.top.truncate(TOP_REQUESTS);
    }

    /// Scheduler outcome at cycle `t`: a pick, or a hold (completions
    /// were visible but the policy deferred them).
    pub fn on_sched(&mut self, t: u64, picked: Option<i64>, held: u64) {
        match picked {
            Some(id) => self.emit(t, EventKind::SchedPick { id }),
            None if held > 0 => self.emit(t, EventKind::SchedHold { held }),
            None => {}
        }
    }

    /// Cheap check: is a counter sample due at `now`? One branch on the
    /// traced path; the untraced path never reaches it.
    #[inline]
    pub fn sample_due(&self, now: u64) -> bool {
        now >= self.next_sample
    }

    /// Record a counter sample and fold in fault-counter deltas.
    pub fn sample(&mut self, now: u64, gauges: FabricGauges, amu_inflight: u64) {
        self.emit(
            now,
            EventKind::Sample {
                inflight: gauges.inflight,
                queue_stalls: gauges.queue_stalls,
                hot_hits: gauges.hot_hits,
                hot_misses: gauges.hot_misses,
                amu_inflight,
            },
        );
        self.fault_deltas(now, &gauges);
        self.last_gauges = gauges;
        // Advance to the next grid point strictly after `now`.
        let step = self.cfg.sample_every;
        self.next_sample = (now / step + 1) * step;
    }

    /// Emit fault-counter deltas since the last check (used both at
    /// sample points and after AMU issues on faulty fabrics).
    pub fn on_fault_check(&mut self, t: u64, gauges: FabricGauges) {
        self.fault_deltas(t, &gauges);
        self.last_gauges = gauges;
    }

    fn fault_deltas(&mut self, t: u64, g: &FabricGauges) {
        let last = self.last_gauges;
        if g.nacks > last.nacks {
            self.emit(t, EventKind::FaultNack { n: g.nacks - last.nacks });
        }
        if g.retries > last.retries {
            self.emit(t, EventKind::FaultRetry { n: g.retries - last.retries });
        }
        if g.timeouts > last.timeouts {
            self.emit(t, EventKind::FaultTimeout { n: g.timeouts - last.timeouts });
        }
        if g.slow_path > last.slow_path {
            self.emit(t, EventKind::FaultSlowPath { n: g.slow_path - last.slow_path });
        }
    }

    /// Finish: close the last segment at `cycles`, mark the current
    /// coroutine finished, and turn the live state into a [`Trace`].
    pub fn harvest(
        mut self: Box<Self>,
        cycles: u64,
        stalls: &StallBuckets,
        policy: &str,
        fabric: &str,
    ) -> Trace {
        self.close_segment(cycles, stalls);
        if self.cur != MAIN_CORO {
            let id = self.cur;
            self.emit(cycles, EventKind::CoroFinish { id });
        }
        let mut profile: Vec<CoroRow> = self
            .attrib
            .iter()
            .map(|(&id, &prof)| CoroRow { core: self.core, id, prof })
            .collect();
        sort_profile(&mut profile);
        Trace {
            policy: policy.to_string(),
            fabric: fabric.to_string(),
            cycles,
            cores: 1,
            classes: self.cfg.classes,
            ring_cap: self.cfg.ring_cap,
            events: self.events,
            total: self.total,
            dropped: self.dropped,
            profile,
            top: self.top,
        }
    }
}

fn sort_profile(rows: &mut [CoroRow]) {
    // Heaviest first; (core, id) breaks ties deterministically.
    rows.sort_by(|a, b| {
        b.prof
            .cycles
            .partial_cmp(&a.prof.cycles)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.core.cmp(&b.core))
            .then(a.id.cmp(&b.id))
    });
}

// ---------------------------------------------------------------------------
// Trace artifact
// ---------------------------------------------------------------------------

/// One profile row: a coroutine on a core with its attribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoroRow {
    pub core: u32,
    pub id: i64,
    pub prof: CoroProf,
}

impl CoroRow {
    pub fn name(&self) -> String {
        if self.id == MAIN_CORO {
            format!("c{}:(main)", self.core)
        } else {
            format!("c{}:{}", self.core, self.id)
        }
    }
}

/// Harvested trace: the final artifact returned by traced runs.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub policy: String,
    pub fabric: String,
    /// Total simulated cycles (makespan for clusters).
    pub cycles: u64,
    pub cores: u32,
    pub classes: TraceClasses,
    pub ring_cap: usize,
    pub events: Vec<Event>,
    /// Events observed (retained + dropped).
    pub total: u64,
    pub dropped: u64,
    pub profile: Vec<CoroRow>,
    pub top: Vec<ReqRecord>,
}

impl Trace {
    /// Merge per-core traces from a cluster run (events concatenated in
    /// core order, aggregates summed, top-N re-ranked).
    pub fn merge(parts: Vec<Trace>, makespan: u64) -> Trace {
        let mut it = parts.into_iter();
        let mut out = it.next().expect("merge of at least one trace");
        out.cycles = makespan;
        for part in it {
            out.cores += part.cores;
            out.total += part.total;
            out.dropped += part.dropped;
            out.events.extend(part.events);
            out.profile.extend(part.profile);
            out.top.extend(part.top);
        }
        sort_profile(&mut out.profile);
        out.top.sort_by(|a, b| {
            b.latency()
                .cmp(&a.latency())
                .then(a.core.cmp(&b.core))
                .then(a.seq.cmp(&b.seq))
        });
        out.top.truncate(TOP_REQUESTS);
        out
    }

    /// Append a post-hoc event (service replay), honoring the class
    /// filter and ring accounting of the original run.
    pub fn push(&mut self, t: u64, core: u32, kind: EventKind) {
        if !self.classes.has(kind.class()) {
            return;
        }
        self.total += 1;
        if self.events.len() < self.ring_cap {
            self.events.push(Event { t, core, kind });
        } else {
            self.dropped += 1;
        }
    }

    /// Deterministic textual rendering of the event stream — one line
    /// per event. Byte-identical across runs of the same seed.
    pub fn event_log(&self) -> String {
        let mut s = String::with_capacity(self.events.len() * 48);
        for e in &self.events {
            let _ = writeln!(s, "{} c{} {:?}", e.t, e.core, e.kind);
        }
        s
    }

    /// Fraction of the run's stall cycles that the per-coroutine profile
    /// accounts for (1.0 by construction for single-core runs).
    pub fn stall_coverage(&self, stats_stall_total: f64) -> f64 {
        if stats_stall_total <= 0.0 {
            return 1.0;
        }
        let attributed: f64 = self.profile.iter().map(|r| r.prof.stall_total()).sum();
        (attributed / stats_stall_total).min(1.0)
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event JSON sink
// ---------------------------------------------------------------------------

/// Reserved Perfetto track (tid) ids, away from plausible coroutine ids.
const TID_AMU: i64 = 1_000_000_000;
const TID_SCHED: i64 = 1_000_000_001;
const TID_FAULT: i64 = 1_000_000_002;
const TID_SERVICE: i64 = 1_000_000_003;

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

struct ChromeWriter {
    out: String,
    first: bool,
}

impl ChromeWriter {
    fn new() -> ChromeWriter {
        ChromeWriter { out: String::from("{\"traceEvents\":[\n"), first: true }
    }

    fn push(&mut self, ev: String) {
        if !self.first {
            self.out.push_str(",\n");
        }
        self.first = false;
        self.out.push_str(&ev);
    }

    fn meta(&mut self, pid: u32, tid: Option<i64>, key: &str, name: &str) {
        let tid_field = tid.map(|t| format!(",\"tid\":{t}")).unwrap_or_default();
        self.push(format!(
            "{{\"ph\":\"M\",\"pid\":{pid}{tid_field},\"name\":\"{key}\",\"args\":{{\"name\":\"{}\"}}}}",
            json_escape(name)
        ));
    }

    fn finish(mut self, display_unit_note: &str) -> String {
        self.out.push_str("\n],\"displayTimeUnit\":\"ms\",\"otherData\":{\"note\":\"");
        self.out.push_str(&json_escape(display_unit_note));
        self.out.push_str("\"}}\n");
        self.out
    }
}

/// Render a [`Trace`] as Chrome trace-event JSON (one pid per core, one
/// tid per coroutine plus reserved channel tracks; 1 µs == 1 cycle).
pub fn chrome_json(trace: &Trace) -> String {
    let mut w = ChromeWriter::new();
    // Metadata: name each core process and the reserved tracks.
    let mut seen_cores: Vec<u32> = trace.events.iter().map(|e| e.core).collect();
    seen_cores.sort_unstable();
    seen_cores.dedup();
    if seen_cores.is_empty() {
        seen_cores.push(0);
    }
    for &core in &seen_cores {
        w.meta(core, None, "process_name", &format!("core {core}"));
        w.meta(core, Some(TID_AMU), "thread_name", "amu/fabric");
        w.meta(core, Some(TID_SCHED), "thread_name", "scheduler");
        w.meta(core, Some(TID_FAULT), "thread_name", "faults");
        w.meta(core, Some(TID_SERVICE), "thread_name", "service");
    }
    // X slices for coroutine residency: pair Resume with Suspend/Finish.
    let mut open: BTreeMap<u32, (i64, u64)> = BTreeMap::new();
    for e in &trace.events {
        let (pid, ts) = (e.core, e.t);
        match e.kind {
            EventKind::CoroResume { id } => {
                open.insert(pid, (id, ts));
            }
            EventKind::CoroSuspend { id } | EventKind::CoroFinish { id } => {
                if let Some((open_id, t0)) = open.remove(&pid) {
                    if open_id == id {
                        w.push(format!(
                            "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{id},\"ts\":{t0},\"dur\":{},\"name\":\"coro {id}\",\"cat\":\"coro\"}}",
                            ts.saturating_sub(t0)
                        ));
                    }
                }
            }
            EventKind::CoroSpawn { id } => {
                w.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{id},\"ts\":{ts},\"name\":\"spawn\",\"s\":\"t\",\"cat\":\"coro\"}}"
                ));
            }
            EventKind::AmuReq { id, issue, done, store, class, lines } => {
                let name = if store { "astore" } else { "aload" };
                w.push(format!(
                    "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{TID_AMU},\"ts\":{issue},\"dur\":{},\"name\":\"{name}\",\"cat\":\"amu\",\"args\":{{\"coro\":{id},\"class\":\"{}\",\"lines\":{lines}}}}}",
                    done.saturating_sub(issue),
                    class.name()
                ));
            }
            EventKind::SchedPick { id } => {
                w.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{TID_SCHED},\"ts\":{ts},\"name\":\"pick\",\"s\":\"t\",\"cat\":\"sched\",\"args\":{{\"coro\":{id}}}}}"
                ));
            }
            EventKind::SchedHold { held } => {
                w.push(format!(
                    "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{TID_SCHED},\"ts\":{ts},\"name\":\"hold\",\"s\":\"t\",\"cat\":\"sched\",\"args\":{{\"held\":{held}}}}}"
                ));
            }
            EventKind::Sample { inflight, queue_stalls, hot_hits, hot_misses, amu_inflight } => {
                w.push(format!(
                    "{{\"ph\":\"C\",\"pid\":{pid},\"ts\":{ts},\"name\":\"fabric\",\"cat\":\"fabric\",\"args\":{{\"inflight\":{inflight},\"queue_stalls\":{queue_stalls},\"hot_hits\":{hot_hits},\"hot_misses\":{hot_misses},\"amu_inflight\":{amu_inflight}}}}}"
                ));
            }
            EventKind::FaultNack { n } => w.push(fault_instant(pid, ts, "nack", n)),
            EventKind::FaultRetry { n } => w.push(fault_instant(pid, ts, "retry", n)),
            EventKind::FaultTimeout { n } => w.push(fault_instant(pid, ts, "timeout", n)),
            EventKind::FaultSlowPath { n } => w.push(fault_instant(pid, ts, "slow_path", n)),
            EventKind::SvcReject => w.push(svc_instant(pid, ts, "reject")),
            EventKind::SvcShedExpired => w.push(svc_instant(pid, ts, "shed_expired")),
            EventKind::SvcDegradeEnter => w.push(svc_instant(pid, ts, "degrade_enter")),
            EventKind::SvcDegradeExit => w.push(svc_instant(pid, ts, "degrade_exit")),
        }
    }
    w.finish(&format!(
        "coroamu trace: policy={} fabric={} cycles={} events={} dropped={} (ts unit: 1us == 1 cycle)",
        trace.policy, trace.fabric, trace.cycles, trace.total, trace.dropped
    ))
}

fn fault_instant(pid: u32, ts: u64, name: &str, n: u64) -> String {
    format!(
        "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{TID_FAULT},\"ts\":{ts},\"name\":\"{name}\",\"s\":\"t\",\"cat\":\"fault\",\"args\":{{\"n\":{n}}}}}"
    )
}

fn svc_instant(pid: u32, ts: u64, name: &str) -> String {
    format!(
        "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{TID_SERVICE},\"ts\":{ts},\"name\":\"{name}\",\"s\":\"t\",\"cat\":\"service\"}}"
    )
}

/// Write the Chrome JSON atomically (tmp + rename, like the store).
pub fn write_chrome_json(trace: &Trace, path: &Path) -> Result<()> {
    let json = chrome_json(trace);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, json).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Terminal profile report
// ---------------------------------------------------------------------------

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

fn sparkline(values: &[u64], width: usize) -> String {
    if values.is_empty() {
        return "(no samples)".into();
    }
    // Bucket samples down to `width` columns (max within each bucket).
    let cols = width.min(values.len()).max(1);
    let mut maxes = vec![0u64; cols];
    for (i, &v) in values.iter().enumerate() {
        let c = i * cols / values.len();
        maxes[c] = maxes[c].max(v);
    }
    let peak = maxes.iter().copied().max().unwrap_or(0).max(1);
    maxes
        .iter()
        .map(|&v| SPARK[((v * (SPARK.len() as u64 - 1)) / peak) as usize])
        .collect()
}

fn timeline_bar(issue: u64, done: u64, span: u64, width: usize) -> String {
    let span = span.max(1);
    let start = (issue.min(span) as usize * width) / span as usize;
    let end = ((done.min(span) as usize * width) / span as usize).max(start + 1).min(width);
    let mut bar = String::with_capacity(width);
    for i in 0..width {
        bar.push(if i >= start && i < end { '█' } else { '·' });
    }
    bar
}

/// Render the in-terminal profile: stall attribution per coroutine,
/// top-N tail-latency requests with a run-relative timeline, and a
/// queue-occupancy sparkline from the periodic samples.
pub fn render_profile(trace: &Trace) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "trace profile: policy={} fabric={} cores={} cycles={} events={} dropped={}",
        trace.policy, trace.fabric, trace.cores, trace.cycles, trace.total, trace.dropped
    );
    // --- per-coroutine stall attribution ---
    let total_cycles: f64 = trace.profile.iter().map(|r| r.prof.cycles).sum();
    let _ = writeln!(s, "\nper-coroutine stall attribution (cycles):");
    let _ = writeln!(
        s,
        "{:>12} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>7}",
        "coro", "resumes", "cycles", "compute", "local", "remote", "backpr", "mispred", "share"
    );
    const MAX_ROWS: usize = 32;
    for row in trace.profile.iter().take(MAX_ROWS) {
        let p = &row.prof;
        let share = if total_cycles > 0.0 { 100.0 * p.cycles / total_cycles } else { 0.0 };
        let _ = writeln!(
            s,
            "{:>12} {:>8} {:>10.0} {:>10.0} {:>10.0} {:>10.0} {:>10.0} {:>10.0} {:>6.1}%",
            row.name(),
            p.resumes,
            p.cycles,
            p.compute,
            p.local_mem,
            p.remote_mem,
            p.backpressure,
            p.mispredict,
            share
        );
    }
    if trace.profile.len() > MAX_ROWS {
        let _ = writeln!(s, "  ... {} more coroutines", trace.profile.len() - MAX_ROWS);
    }
    let attributed: f64 = trace.profile.iter().map(|r| r.prof.stall_total()).sum();
    let _ = writeln!(
        s,
        "attributed {:.0} stall cycles across {} coroutine rows ({:.0} total cycles tracked)",
        attributed,
        trace.profile.len(),
        total_cycles
    );
    // --- top-N tail latency ---
    if !trace.top.is_empty() {
        let _ = writeln!(s, "\ntop {} tail-latency AMU requests:", trace.top.len());
        let _ = writeln!(
            s,
            "{:>4} {:>12} {:>12} {:>12} {:>9}  timeline",
            "#", "coro", "issue", "done", "latency"
        );
        for (i, r) in trace.top.iter().enumerate() {
            let _ = writeln!(
                s,
                "{:>4} {:>12} {:>12} {:>12} {:>9}  [{}]",
                i + 1,
                format!("c{}:{}", r.core, r.id),
                r.issue,
                r.done,
                r.latency(),
                timeline_bar(r.issue, r.done, trace.cycles, 40)
            );
        }
    }
    // --- queue occupancy sparkline (per core) ---
    let mut cores: Vec<u32> = trace.events.iter().map(|e| e.core).collect();
    cores.sort_unstable();
    cores.dedup();
    for &core in &cores {
        let depths: Vec<u64> = trace
            .events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Sample { inflight, .. } if e.core == core => Some(inflight),
                _ => None,
            })
            .collect();
        if !depths.is_empty() {
            let peak = depths.iter().copied().max().unwrap_or(0);
            let _ = writeln!(
                s,
                "\nfabric queue occupancy (core {core}, {} samples, peak {}):\n  {}",
                depths.len(),
                peak,
                sparkline(&depths, 64)
            );
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(cap: usize) -> TraceConfig {
        TraceConfig { enabled: true, sample_every: 16, ring_cap: cap, classes: TraceClasses::all() }
    }

    #[test]
    fn classes_parse_roundtrip() {
        assert_eq!(TraceClasses::parse("all").unwrap(), TraceClasses::all());
        assert_eq!(TraceClasses::parse("").unwrap(), TraceClasses::all());
        let c = TraceClasses::parse("coro, amu").unwrap();
        assert!(c.has(TraceClasses::CORO) && c.has(TraceClasses::AMU));
        assert!(!c.has(TraceClasses::SCHED));
        assert_eq!(c.label(), "coro,amu");
        assert!(TraceClasses::parse("bogus").is_err());
        assert_eq!(TraceClasses::all().label(), "all");
    }

    #[test]
    fn config_validate_and_label() {
        assert!(TraceConfig::off().validate().is_ok());
        assert!(TraceConfig::on().validate().is_ok());
        let mut c = TraceConfig::on();
        c.sample_every = 0;
        assert!(c.validate().is_err());
        c = TraceConfig::on();
        c.ring_cap = 0;
        assert!(c.validate().is_err());
        c = TraceConfig::on();
        c.ring_cap = (1 << 24) + 1;
        assert!(c.validate().is_err());
        assert_eq!(TraceConfig::off().label(), "off");
        assert!(TraceConfig::on().label().starts_with("on("));
    }

    #[test]
    fn ring_overflow_accounting() {
        let mut tr = Tracer::new(tiny_cfg(4));
        for i in 0..10u64 {
            tr.on_transfer(i as i64, i * 10, i * 10 + 5, false, AddrClass::Remote, 1);
        }
        // Each transfer emits CoroSpawn + AmuReq = 20 events total; 4 retained.
        assert_eq!(tr.total, 20);
        assert_eq!(tr.events.len(), 4);
        assert_eq!(tr.dropped, 16);
        let trace = tr.harvest(200, &StallBuckets::default(), "fifo", "fixed");
        assert_eq!(trace.total, 20);
        assert_eq!(trace.dropped, 16);
        assert_eq!(trace.events.len(), 4);
        // Aggregates stay exact despite the overflow.
        assert_eq!(trace.profile.iter().map(|r| r.prof.reqs).sum::<u64>(), 10);
    }

    #[test]
    fn class_filter_suppresses_events() {
        let mut cfg = tiny_cfg(64);
        cfg.classes = TraceClasses::parse("sched").unwrap();
        let mut tr = Tracer::new(cfg);
        tr.on_transfer(1, 0, 5, false, AddrClass::Remote, 1); // coro+amu: filtered
        tr.on_sched(6, Some(1), 0); // sched: kept
        assert_eq!(tr.total, 1);
        assert_eq!(tr.events.len(), 1);
        assert!(matches!(tr.events[0].kind, EventKind::SchedPick { id: 1 }));
    }

    #[test]
    fn attribution_closes_segments_exactly() {
        let mut tr = Tracer::new(tiny_cfg(256));
        let mut st = StallBuckets::default();
        // main runs [0,100): 30 remote stall.
        st.remote_mem = 30.0;
        tr.on_switch(100, 100, &st, Some(7));
        // coro 7 runs [100,250): +50 local stall.
        st.local_mem = 50.0;
        tr.on_switch(250, 250, &st, Some(8));
        // coro 8 runs [250,300): no extra stalls.
        let trace = tr.harvest(300, &st, "arrival", "queued");
        let total: f64 = trace.profile.iter().map(|r| r.prof.cycles).sum();
        assert_eq!(total, 300.0);
        let main = trace.profile.iter().find(|r| r.id == MAIN_CORO).unwrap();
        assert_eq!(main.prof.remote_mem, 30.0);
        assert_eq!(main.prof.compute, 70.0);
        let c7 = trace.profile.iter().find(|r| r.id == 7).unwrap();
        assert_eq!(c7.prof.local_mem, 50.0);
        assert_eq!(c7.prof.cycles, 150.0);
        assert_eq!(c7.prof.resumes, 1);
        let c8 = trace.profile.iter().find(|r| r.id == 8).unwrap();
        assert_eq!(c8.prof.cycles, 50.0);
        // 100% of stall cycles attributed.
        assert_eq!(trace.stall_coverage(80.0), 1.0);
    }

    #[test]
    fn sampling_grid_and_fault_deltas() {
        let mut tr = Tracer::new(tiny_cfg(256));
        assert!(!tr.sample_due(15));
        assert!(tr.sample_due(16));
        let mut g = FabricGauges { inflight: 3, ..FabricGauges::default() };
        tr.sample(17, g, 2);
        assert_eq!(tr.next_sample, 32);
        g.nacks = 4;
        g.retries = 2;
        tr.sample(40, g, 0);
        assert_eq!(tr.next_sample, 48);
        let kinds: Vec<u8> = tr.events.iter().map(|e| e.kind.class()).collect();
        assert!(kinds.contains(&TraceClasses::FABRIC));
        assert!(kinds.contains(&TraceClasses::FAULT));
        let nack = tr
            .events
            .iter()
            .find_map(|e| match e.kind {
                EventKind::FaultNack { n } => Some(n),
                _ => None,
            })
            .unwrap();
        assert_eq!(nack, 4);
    }

    #[test]
    fn top_n_keeps_longest_with_deterministic_ties() {
        let mut tr = Tracer::new(tiny_cfg(1 << 12));
        for i in 0..100u64 {
            // latencies 0..100; ties impossible here, then add tied pair.
            tr.on_transfer(1, i, i + i, false, AddrClass::Remote, 1);
        }
        tr.on_transfer(2, 1000, 1099, false, AddrClass::Remote, 1);
        tr.on_transfer(3, 2000, 2099, false, AddrClass::Remote, 1);
        let trace = tr.harvest(3000, &StallBuckets::default(), "fifo", "fixed");
        assert_eq!(trace.top.len(), TOP_REQUESTS);
        assert_eq!(trace.top[0].latency(), 99);
        // Earlier issue (lower seq) wins the 99-latency tie.
        assert!(trace.top[0].issue == 99 || trace.top[0].seq < trace.top[1].seq);
        let lats: Vec<u64> = trace.top.iter().map(|r| r.latency()).collect();
        let mut sorted = lats.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(lats, sorted);
    }

    #[test]
    fn chrome_json_well_formed() {
        let mut tr = Tracer::new(tiny_cfg(256));
        let st = StallBuckets::default();
        tr.on_switch(10, 10, &st, Some(5));
        tr.on_transfer(5, 12, 40, false, AddrClass::Remote, 2);
        tr.on_transfer(5, 13, 20, true, AddrClass::Local, 1);
        tr.on_sched(41, Some(5), 0);
        tr.on_sched(42, None, 3);
        tr.sample(48, FabricGauges { inflight: 1, ..FabricGauges::default() }, 1);
        tr.on_switch(60, 60, &st, None);
        let mut trace = tr.harvest(100, &st, "fifo", "queued");
        trace.push(120, 0, EventKind::SvcReject);
        trace.push(130, 0, EventKind::SvcDegradeEnter);
        let json = chrome_json(&trace);
        // Structure: single top-level object with a traceEvents array.
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("}"));
        // Balanced braces/brackets (no string in our output contains them).
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        // No trailing comma before the array close.
        assert!(!json.contains(",\n]"));
        // Expected phases and tracks present.
        for needle in [
            "\"ph\":\"X\"",
            "\"ph\":\"C\"",
            "\"ph\":\"i\"",
            "\"ph\":\"M\"",
            "\"name\":\"aload\"",
            "\"name\":\"astore\"",
            "\"name\":\"coro 5\"",
            "\"name\":\"pick\"",
            "\"name\":\"hold\"",
            "\"name\":\"reject\"",
            "\"name\":\"degrade_enter\"",
            "\"class\":\"remote\"",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
    }

    #[test]
    fn chrome_json_write_is_atomic_and_loadable() {
        let dir = std::env::temp_dir().join(format!("coroamu_trace_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tr = Tracer::new(tiny_cfg(16));
        let trace = tr.harvest(10, &StallBuckets::default(), "fifo", "fixed");
        let path = dir.join("out.json");
        write_chrome_json(&trace, &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("{\"traceEvents\":["));
        assert!(!path.with_extension("json.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn event_log_is_deterministic_text() {
        let build = || {
            let mut tr = Tracer::new(tiny_cfg(64));
            tr.on_transfer(3, 5, 25, false, AddrClass::Remote, 1);
            tr.on_sched(26, Some(3), 0);
            tr.harvest(50, &StallBuckets::default(), "fifo", "fixed")
        };
        let (a, b) = (build(), build());
        assert_eq!(a.event_log(), b.event_log());
        assert!(a.event_log().lines().count() == a.events.len());
        assert!(a == b);
    }

    #[test]
    fn merge_concatenates_and_reranks() {
        let mk = |core: u32, lat: u64| {
            let mut tr = Tracer::for_core(tiny_cfg(64), core);
            tr.on_transfer(1, 0, lat, false, AddrClass::Remote, 1);
            tr.harvest(lat + 10, &StallBuckets::default(), "fifo", "queued")
        };
        let merged = Trace::merge(vec![mk(0, 50), mk(1, 90)], 100);
        assert_eq!(merged.cores, 2);
        assert_eq!(merged.cycles, 100);
        assert_eq!(merged.total, 4); // 2 spawns + 2 reqs
        assert_eq!(merged.top[0].core, 1);
        assert_eq!(merged.top[0].latency(), 90);
        assert_eq!(merged.profile.len(), 4); // (main)+coro per core
    }

    #[test]
    fn profile_report_renders() {
        let mut tr = Tracer::new(tiny_cfg(256));
        let mut st = StallBuckets::default();
        tr.on_switch(10, 10, &st, Some(1));
        tr.on_transfer(1, 11, 61, false, AddrClass::Remote, 4);
        st.remote_mem = 40.0;
        tr.sample(16, FabricGauges { inflight: 5, ..FabricGauges::default() }, 3);
        tr.sample(32, FabricGauges { inflight: 2, ..FabricGauges::default() }, 1);
        let trace = tr.harvest(100, &st, "latency", "tiered");
        let report = render_profile(&trace);
        assert!(report.contains("per-coroutine stall attribution"));
        assert!(report.contains("tail-latency AMU requests"));
        assert!(report.contains("queue occupancy"));
        assert!(report.contains("(main)"));
        assert!(report.contains("policy=latency"));
    }

    #[test]
    fn sparkline_and_timeline_shapes() {
        assert_eq!(sparkline(&[], 8), "(no samples)");
        let line = sparkline(&[0, 1, 2, 3, 4, 5, 6, 7], 8);
        assert_eq!(line.chars().count(), 8);
        assert_eq!(line.chars().next().unwrap(), SPARK[0]);
        assert_eq!(line.chars().last().unwrap(), SPARK[7]);
        let bar = timeline_bar(10, 20, 40, 40);
        assert_eq!(bar.chars().count(), 40);
        assert!(bar.contains('█') && bar.contains('·'));
    }
}
