//! Cycle-approximate simulator of the NH-G core (XiangShan NANHU, Table I)
//! with the enhanced AMU, plus a Skylake-like preset for the paper's Intel
//! compiler experiments.
//!
//! Composition: [`interp`] (functional CoroIR execution) drives
//! [`core`] (dataflow + ROB pipeline spine), [`memsys`] (L1/L2/L3 + MSHRs
//! + a pluggable far tier), [`fabric`] (far-memory fabric backends:
//! fixed delayer, queued/congested link, latency distributions, tiered
//! hot-page cache — `SimConfig::mem.fabric`), [`bpu`]
//! (TAGE/ITTAGE/BPT), [`amu`] (Request Table / Finished Queue / groups /
//! await-asignal), [`sched`] (pluggable coroutine-resume policies over
//! the Finished Queue, `SimConfig::sched_policy`) and [`faults`]
//! (deterministic fault injection on the far fabric plus timeout/retry
//! resilience, `SimConfig::mem.fabric.faults`) and [`service`] (the
//! SLO-aware open-loop request-serving layer replayed over a run's
//! calibrated per-request cost, `SimConfig::service`) and [`trace`]
//! (opt-in cycle-level event tracing + stall attribution,
//! `SimConfig::trace`). See `DESIGN.md` §1 (repo root) for the
//! substitution argument, §8 for the scheduler subsystem, §9 for the
//! fabric subsystem, §11 for fault injection, §12 for service mode and
//! §14 for tracing.

pub mod amu;
pub mod bpu;
pub mod cache;
pub mod cluster;
pub mod core;
pub mod decode;
pub mod fabric;
pub mod faults;
pub mod interp;
pub mod mem;
pub mod memsys;
pub mod sched;
pub mod service;
pub mod slots;
pub mod stats;
pub mod trace;

pub use decode::DecodedFunc;
pub use fabric::FabricKind;
pub use faults::FaultConfig;
pub use interp::{mix64, run, run_reference, run_traced, Program};
pub use mem::MemImage;
pub use sched::SchedPolicyKind;
pub use service::ServiceConfig;
pub use stats::RunStats;
pub use trace::{Trace, TraceConfig};

use crate::compiler::CompiledKernel;
use crate::config::SimConfig;
use crate::ir::AddrSpace;

/// Assemble a runnable [`Program`] from a compiled kernel: allocates the
/// runtime areas (handler array, queues, lock tables) and the SPM region,
/// binds their base addresses plus the kernel parameters, and lowers the
/// function to its decode-once micro-op form ([`decode`]).
pub fn link(
    cfg: &SimConfig,
    ck: &CompiledKernel,
    mut mem: MemImage,
    param_values: &[i64],
) -> Program {
    assert_eq!(param_values.len(), ck.param_regs.len(), "param count mismatch");
    let mut reg_init: Vec<(u32, i64)> = ck
        .param_regs
        .iter()
        .zip(param_values.iter())
        .map(|(r, v)| (*r, *v))
        .collect();
    for area in &ck.areas {
        let base = mem.alloc(&format!("rt.{}", area.name), AddrSpace::Local, area.bytes.max(8));
        reg_init.push((area.reg, base as i64));
    }
    let mut spm_base_reg = None;
    if let Some(sr) = ck.spm_base_reg {
        let bytes = (cfg.amu.spm_kb.max(1) as u64) * 1024;
        let need = ck.ids_used as u64 * ck.spm_slot_bytes.max(64) as u64;
        let base = mem.alloc("spm", AddrSpace::Spm, bytes.max(need));
        reg_init.push((sr, base as i64));
        spm_base_reg = Some(sr);
    }
    Program::new(
        ck.func.clone(),
        mem,
        reg_init,
        ck.spm_slot_bytes.max(64),
        spm_base_reg,
        3_000_000_000,
        cfg.fuse_superops,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Instance;
    use crate::compiler::ast::*;
    use crate::compiler::Variant;
    use crate::engine::Engine;
    use crate::ir::Width;

    /// End-to-end: a GUPS-like kernel compiled in all five variants must
    /// produce identical memory contents and sensible relative timing.
    /// Written with the fluent [`KernelBuilder`] statement helpers, so it
    /// reads like the paper's pragma-annotated loop.
    fn gups_kernel() -> Kernel {
        let mut kb = KernelBuilder::new("gups_e2e");
        let tab = kb.param_ptr("tab", AddrSpace::Remote);
        let mask = kb.param_val("mask");
        let n = kb.param_val("n");
        kb.trip(n);
        let idx = kb.var("idx");
        let v = kb.var("v");
        let addr = Expr::add(Expr::Param(tab), Expr::shl(Expr::Var(idx), Expr::Imm(3)));
        kb.num_tasks(32);
        // Bijective multiplicative permutation: collision-free random
        // scatter so every execution order gives identical memory.
        kb.let_(idx, Expr::and(Expr::mul(Expr::Var(ITER_VAR), Expr::Imm(0x9E37_79B9)), Expr::Param(mask)))
            .load(v, addr.clone(), Width::W8)
            .store(Expr::xor(Expr::Var(v), Expr::Var(idx)), addr, Width::W8);
        kb.finish()
    }

    fn run_variant_cfg(
        cfg: &SimConfig,
        variant: Variant,
        tasks: usize,
        n: i64,
        table_words: u64,
    ) -> (RunStats, Vec<i64>) {
        let engine = Engine::new(cfg.clone());
        let mut mem = MemImage::new();
        let tab = mem.alloc("tab", AddrSpace::Remote, table_words * 8);
        let inst = Instance {
            kernel: gups_kernel(),
            mem,
            params: vec![tab as i64, (table_words - 1) as i64, n],
            check: std::sync::Arc::new(|_| Ok(())),
            default_tasks: tasks,
        };
        let r = engine.run_instance(inst, &variant.opts(tasks)).unwrap();
        let out: Vec<i64> =
            (0..table_words).map(|i| r.mem.read(tab + i * 8, Width::W8).unwrap()).collect();
        (r.stats, out)
    }

    fn run_variant(variant: Variant, n: i64, table_words: u64) -> (RunStats, Vec<i64>) {
        run_variant_cfg(&SimConfig::nh_g(), variant, 32, n, table_words)
    }

    #[test]
    fn all_variants_agree_functionally() {
        // Indices are mix64-distinct for small n, so order cannot matter.
        let (_, serial) = run_variant(Variant::Serial, 64, 1 << 12);
        for v in [Variant::Coroutine, Variant::CoroAmuS, Variant::CoroAmuD, Variant::CoroAmuFull] {
            let (_, out) = run_variant(v, 64, 1 << 12);
            assert_eq!(out, serial, "{} diverges from serial", v.label());
        }
    }

    #[test]
    fn coroutines_beat_serial_on_latency_bound_gups() {
        let (s, _) = run_variant(Variant::Serial, 400, 1 << 16);
        let (f, _) = run_variant(Variant::CoroAmuFull, 400, 1 << 16);
        let speedup = s.cycles as f64 / f.cycles as f64;
        assert!(speedup > 1.5, "CoroAMU-Full speedup on GUPS was only {speedup:.2}x");
    }

    #[test]
    fn bafin_eliminates_scheduler_mispredicts() {
        let (d, _) = run_variant(Variant::CoroAmuD, 300, 1 << 14);
        let (f, _) = run_variant(Variant::CoroAmuFull, 300, 1 << 14);
        assert!(d.indirect_mispredicts > 0, "getfin scheduler should mispredict");
        assert_eq!(f.indirect_mispredicts, 0, "bafin scheduler has no indirect jumps");
        assert_eq!(f.bafin_mispredicts, 0, "bafin is oracle-predicted");
    }

    #[test]
    fn instruction_expansion_ordering_matches_fig13() {
        // Fig. 13 is measured at 100 ns latency with 96 coroutines and a
        // long-running loop (spin overhead amortized away).
        let cfg = SimConfig::nh_g().with_far_latency_ns(100.0);
        let (serial, _) = run_variant_cfg(&cfg, Variant::Serial, 96, 2000, 1 << 16);
        let (s, _) = run_variant_cfg(&cfg, Variant::CoroAmuS, 96, 2000, 1 << 16);
        let (d, _) = run_variant_cfg(&cfg, Variant::CoroAmuD, 96, 2000, 1 << 16);
        let (f, _) = run_variant_cfg(&cfg, Variant::CoroAmuFull, 96, 2000, 1 << 16);
        let base = serial.dyn_instrs as f64;
        let (es, ed, ef) = (s.dyn_instrs as f64 / base, d.dyn_instrs as f64 / base, f.dyn_instrs as f64 / base);
        assert!(es > 1.0 && ed > 1.0 && ef > 1.0);
        assert!(ef < ed, "Full ({ef:.2}x) should expand less than D ({ed:.2}x)");
    }

    #[test]
    fn default_policy_is_cycle_identical_to_explicit_arrival_order() {
        // The refactor's core invariant: extracting scheduling into
        // sim::sched must not move a single cycle under the default.
        let base = SimConfig::nh_g();
        assert_eq!(base.sched_policy, sched::SchedPolicyKind::ArrivalOrder);
        let explicit = base.clone().with_sched_policy(sched::SchedPolicyKind::ArrivalOrder);
        for v in [Variant::CoroAmuD, Variant::CoroAmuFull] {
            let (a, ma) = run_variant_cfg(&base, v, 32, 200, 1 << 14);
            let (b, mb) = run_variant_cfg(&explicit, v, 32, 200, 1 << 14);
            assert_eq!(a, b, "{}: explicit ArrivalOrder diverges", v.label());
            assert_eq!(ma, mb);
        }
    }

    #[test]
    fn policy_sweep_orders_latency_hiding() {
        // All four policies complete GUPS and the scheduling axis moves
        // cycles the way the ordering argument predicts: strict
        // suspension order (head-of-line blocking) cannot beat
        // memory-arrival order.
        let mut cycles = std::collections::HashMap::new();
        for k in sched::SchedPolicyKind::ALL {
            let cfg = SimConfig::nh_g().with_sched_policy(k);
            let (st, mem) = run_variant_cfg(&cfg, Variant::CoroAmuFull, 32, 300, 1 << 14);
            let (_, serial_mem) = run_variant_cfg(&cfg, Variant::Serial, 1, 300, 1 << 14);
            assert_eq!(mem, serial_mem, "{}: policy changed results", k.label());
            assert_eq!(st.sched_policy, k.label());
            assert!(st.sched_picks > 0, "{}: scheduler never resumed anyone", k.label());
            cycles.insert(k, st.cycles);
        }
        let fifo = cycles[&sched::SchedPolicyKind::Fifo];
        let arrival = cycles[&sched::SchedPolicyKind::ArrivalOrder];
        assert!(
            fifo >= arrival,
            "FIFO ({fifo}) must not beat arrival order ({arrival}) on latency-bound GUPS"
        );
    }

    #[test]
    fn fabric_backends_are_timing_only_knobs() {
        // Every fabric moves cycles, never results: memory contents under
        // each backend must match the serial baseline bit-for-bit, and
        // the fabric provenance must land in the stats.
        let (_, baseline) = run_variant(Variant::Serial, 64, 1 << 12);
        for f in fabric::FabricKind::ALL {
            let cfg = SimConfig::nh_g().with_fabric(f);
            let (st, out) = run_variant_cfg(&cfg, Variant::CoroAmuFull, 32, 64, 1 << 12);
            assert_eq!(out, baseline, "{}: fabric changed results", f.label());
            assert_eq!(st.fabric, f.label());
            assert!(st.fabric_requests > 0, "{}: far tier never exercised", f.label());
            assert!(st.fabric_p99 >= st.fabric_p50, "{}: percentiles inverted", f.label());
        }
        // The tiered backend must actually see page locality on the
        // scatter table (4 KB pages over a 32 KB table).
        let cfg = SimConfig::nh_g().with_fabric(fabric::FabricKind::Tiered { pages: 64 });
        let (st, _) = run_variant_cfg(&cfg, Variant::CoroAmuFull, 32, 200, 1 << 12);
        assert!(st.fabric_hot_hits > 0, "tiered fabric recorded no hot-page hits");
    }

    #[test]
    fn queued_fabric_throttles_decoupled_mlp() {
        // A 4-deep request queue with congestion must cap the AMU's MLP
        // well below the unconstrained delayer's on latency-bound GUPS.
        let open = SimConfig::nh_g();
        let (so, _) = run_variant_cfg(&open, Variant::CoroAmuFull, 32, 400, 1 << 16);
        let tight = SimConfig::nh_g().with_fabric(fabric::FabricKind::Queued { depth: 4 });
        let (st, _) = run_variant_cfg(&tight, Variant::CoroAmuFull, 32, 400, 1 << 16);
        assert!(
            st.cycles > so.cycles,
            "congestion must cost cycles ({} vs {})",
            st.cycles,
            so.cycles
        );
        assert!(st.fabric_queue_stalls > 0, "backpressure never engaged");
        assert!(
            st.fabric_p99 > so.fabric_p99,
            "burst MLP into a finite queue must fatten the tail ({} vs {})",
            st.fabric_p99,
            so.fabric_p99
        );
    }

    #[test]
    fn faults_are_timing_only_knobs() {
        // Fault injection moves cycles, never results: under every spec
        // memory contents must match the serial fault-free baseline
        // bit-for-bit, every coroutine completes (no wedging), and the
        // resilience counters land in the stats.
        let (_, baseline) = run_variant(Variant::Serial, 64, 1 << 12);
        for spec in ["mild", "heavy", "nack:20", "blackout"] {
            let fc = faults::FaultConfig::parse(spec).unwrap();
            let cfg = SimConfig::nh_g().with_faults(fc);
            let (st, out) = run_variant_cfg(&cfg, Variant::CoroAmuFull, 32, 64, 1 << 12);
            assert_eq!(out, baseline, "{spec}: faults changed results");
            assert_eq!(st.faults, spec, "{spec}: fault provenance missing from stats");
            assert!(
                st.fault_nacks + st.fault_timeouts + st.fault_retries > 0
                    || st.fault_max_stall > 0,
                "{spec}: chaos config produced zero fault events"
            );
        }
        // Heavy chaos costs cycles relative to the fault-free run.
        let clean = run_variant(Variant::CoroAmuFull, 200, 1 << 14).0;
        let chaotic_cfg = SimConfig::nh_g().with_faults(faults::FaultConfig::heavy());
        let (chaos, _) = run_variant_cfg(&chaotic_cfg, Variant::CoroAmuFull, 32, 200, 1 << 14);
        assert!(chaos.cycles > clean.cycles, "heavy faults must cost cycles");
        assert_eq!(clean.faults, "", "fault-free runs carry no fault label");
        assert_eq!(clean.fault_nacks + clean.fault_slow_path, 0);
    }

    #[test]
    fn strict_faults_fail_runs_that_needed_the_slow_path() {
        // nack:100 forces every far request onto the slow path; under
        // strict that must surface as a hard error, while the default
        // absorbs it gracefully.
        let mut fc = faults::FaultConfig::nack(1.0);
        let lenient = SimConfig::nh_g().with_faults(fc);
        let (st, out) = run_variant_cfg(&lenient, Variant::CoroAmuFull, 32, 32, 1 << 10);
        let (_, baseline) = run_variant(Variant::Serial, 32, 1 << 10);
        assert_eq!(out, baseline, "slow-path completions must not change results");
        assert!(st.fault_slow_path > 0);
        fc.strict = true;
        let strict = SimConfig::nh_g().with_faults(fc);
        let engine = Engine::new(strict);
        let mut mem = MemImage::new();
        let tab = mem.alloc("tab", AddrSpace::Remote, (1u64 << 10) * 8);
        let inst = Instance {
            kernel: gups_kernel(),
            mem,
            params: vec![tab as i64, ((1u64 << 10) - 1) as i64, 32],
            check: std::sync::Arc::new(|_| Ok(())),
            default_tasks: 32,
        };
        let err = engine
            .run_instance(inst, &Variant::CoroAmuFull.opts(32))
            .expect_err("strict must fail a run that exhausted retry budgets");
        assert!(err.to_string().contains("retry budget"), "{err}");
    }

    #[test]
    fn amu_mlp_exceeds_serial() {
        let (s, _) = run_variant(Variant::Serial, 600, 1 << 16);
        let (f, _) = run_variant(Variant::CoroAmuFull, 600, 1 << 16);
        assert!(
            f.far_mlp > s.far_mlp * 1.5,
            "decoupled MLP {:.1} should exceed serial {:.1}",
            f.far_mlp,
            s.far_mlp
        );
    }
}
