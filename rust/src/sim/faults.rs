//! Deterministic fault injection for the far fabric, plus the AMU-side
//! resilience semantics that survive it.
//!
//! Every backend in [`sim::fabric`](super::fabric) is fault-free, but
//! failure resilience is a named open challenge for disaggregated
//! memory: remote pools suffer transient NACKs, latency storms, link
//! degradation and outright blackouts that compute nodes must survive
//! (Maruf & Chowdhury; Yelam). This module models exactly those four
//! fault classes as a *decorator*: [`FaultyFabric`] wraps any
//! [`FabricModel`] (so it composes with all four backends and with
//! `SharedFabric`/clusters) and perturbs the request stream with draws
//! from a seeded [`Rng`](crate::util::rng::Rng):
//!
//! * **Transient NACKs** — each attempt fails outright with probability
//!   `nack_pct` (the request never reaches the wire);
//! * **Latency spikes** — a seeded fraction `spike_pct` of served
//!   requests completes `spike_mult`× later (incast / straggler storms);
//! * **Degradation windows** — during the last `degrade_len` cycles of
//!   every `degrade_period`, effective service collapses by
//!   `degrade_factor` (link flaps, background reconstruction traffic);
//! * **Blackouts** — during the last `blackout_len` cycles of every
//!   `blackout_period`, every issue NACKs (pool failover).
//!
//! Paired with the fault classes are the requester-side resilience
//! semantics the AMU stack relies on (`sim/amu.rs` / `sim/memsys.rs`):
//! a per-request **timeout** (`timeout` cycles; a completion that would
//! land later is abandoned and re-issued), **bounded retry** with
//! deterministic exponential backoff (`backoff << attempt`, at most
//! `retries` retries), and **graceful degradation** — a request that
//! exhausts its budget completes via a configurable slow-path penalty
//! (`slow_path` cycles; think RPC to a replica) instead of wedging the
//! coroutine. Every `issue` therefore returns a finite completion cycle
//! by construction: the AMU's analytic-completion contract (and its
//! request-table slot reclamation) is preserved under arbitrary fault
//! rates. Under `strict`, a run that needed the slow path fails after
//! the fact ([`check_strict`]) instead of silently absorbing the hit.
//!
//! **Determinism.** All draws come from one generator seeded by
//! `faults.seed`, consumed in issue order (the k-th attempt takes the
//! next draws), and the windows are pure functions of the issue cycle —
//! so a faulted run is a pure function of (config, issue stream), and
//! snapshot-restores, fresh-engine reruns and cluster interleaves replay
//! bit-identically (pinned by the differential suite). Faults default
//! off, and the off path never constructs the decorator at all
//! ([`build_far`]), so fault-free runs are bit-identical to pre-fault
//! builds by construction.

use super::fabric::{ensure_requester, CoreId, FabricGauges, FabricKind, FabricModel, FabricStats};
use super::memsys::AccessKind;
use super::stats::RunStats;
use crate::config::SimConfig;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};

/// Default seed for the fault-injection draws (TOML `faults.seed`).
pub const DEFAULT_FAULT_SEED: u64 = 0x5EED_FA17;

/// Cap on the exponential-backoff shift (and thus on `retries`): keeps
/// `backoff << attempt` far from overflow at any sane configuration.
pub const MAX_RETRIES: u32 = 16;

/// Fault-injection configuration (`[mem.fabric.faults]` in TOML,
/// `--faults SPEC` on the CLI, `RunRequest::faults(..)` in the engine).
/// The default is **off** — all classes disabled — which must stay
/// bit-identical to a build without this module.
///
/// Probabilities are fractions in `[0, 1]`; periods/lengths/timeouts are
/// cycles. `degrade`/`blackout` windows occupy the *last* `len` cycles
/// of each `period`, so the start of a run is never inside a window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Per-attempt transient-failure probability (0 = off).
    pub nack_pct: f64,
    /// Fraction of served requests hit by a latency spike (0 = off).
    pub spike_pct: f64,
    /// Latency multiplier for spiked requests.
    pub spike_mult: u32,
    /// Degradation-window cadence (0 = off) and length in cycles.
    pub degrade_period: u64,
    pub degrade_len: u64,
    /// Latency inflation inside a degradation window (the bandwidth
    /// collapse, charged as service-time inflation).
    pub degrade_factor: u32,
    /// Blackout-window cadence (0 = off) and length in cycles.
    pub blackout_period: u64,
    pub blackout_len: u64,
    /// Per-request timeout (0 = off): a completion later than
    /// `issue + timeout` is abandoned and retried.
    pub timeout: u64,
    /// Retry budget after the first attempt.
    pub retries: u32,
    /// Base backoff; retry k waits `backoff << k` cycles.
    pub backoff: u64,
    /// Slow-path completion penalty once the budget is exhausted.
    pub slow_path: u64,
    /// Hard-fail the run if any request needed the slow path.
    pub strict: bool,
    /// Seed for the fault draws.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self::off()
    }
}

/// Format a fraction as the percentage spelling `parse` accepts.
fn fmt_pct(p: f64) -> String {
    let v = p * 100.0;
    if (v - v.round()).abs() < 1e-9 {
        format!("{:.0}", v.round())
    } else {
        format!("{v}")
    }
}

fn parse_pct(p: &str) -> Result<f64> {
    let p = p.strip_suffix('%').unwrap_or(p);
    match p.parse::<f64>() {
        Ok(v) if v > 0.0 && v <= 100.0 => Ok(v / 100.0),
        _ => bail!("fault percentage must be in (0, 100], got '{p}'"),
    }
}

impl FaultConfig {
    /// Everything disabled — the session default, bit-identical to a
    /// fault-free build (the decorator is never constructed).
    pub fn off() -> Self {
        FaultConfig {
            nack_pct: 0.0,
            spike_pct: 0.0,
            spike_mult: 1,
            degrade_period: 0,
            degrade_len: 0,
            degrade_factor: 1,
            blackout_period: 0,
            blackout_len: 0,
            timeout: 0,
            retries: 0,
            backoff: 0,
            slow_path: 0,
            strict: false,
            seed: DEFAULT_FAULT_SEED,
        }
    }

    /// Occasional transient failures and small spikes: 1% NACKs, 5% of
    /// requests 4× slower, 3 retries at 64-cycle base backoff.
    pub fn mild() -> Self {
        FaultConfig {
            nack_pct: 0.01,
            spike_pct: 0.05,
            spike_mult: 4,
            retries: 3,
            backoff: 64,
            slow_path: 16_384,
            ..Self::off()
        }
    }

    /// The chaos point: 5% NACKs, 15% of requests 8× slower, periodic
    /// 4× degradation windows, periodic blackouts, and a 32 Ki-cycle
    /// request timeout.
    pub fn heavy() -> Self {
        FaultConfig {
            nack_pct: 0.05,
            spike_pct: 0.15,
            spike_mult: 8,
            degrade_period: 65_536,
            degrade_len: 16_384,
            degrade_factor: 4,
            blackout_period: 262_144,
            blackout_len: 8_192,
            timeout: 32_768,
            retries: 4,
            backoff: 128,
            slow_path: 32_768,
            ..Self::off()
        }
    }

    /// Transient NACKs only, at fraction `p` (`nack:PCT` on the CLI).
    pub fn nack(p: f64) -> Self {
        FaultConfig { nack_pct: p, retries: 3, backoff: 64, slow_path: 16_384, ..Self::off() }
    }

    /// Latency spikes only, on fraction `p` of requests at 8×, with a
    /// timeout that catches the worst of them (`spike:PCT`).
    pub fn spike(p: f64) -> Self {
        FaultConfig {
            spike_pct: p,
            spike_mult: 8,
            timeout: 16_384,
            retries: 2,
            backoff: 64,
            slow_path: 32_768,
            ..Self::off()
        }
    }

    /// Periodic degradation windows only (`degrade`).
    pub fn degrade() -> Self {
        FaultConfig {
            degrade_period: 65_536,
            degrade_len: 16_384,
            degrade_factor: 4,
            ..Self::off()
        }
    }

    /// Periodic blackout windows only (`blackout`).
    pub fn blackout() -> Self {
        FaultConfig {
            blackout_period: 131_072,
            blackout_len: 8_192,
            retries: 4,
            backoff: 256,
            slow_path: 16_384,
            ..Self::off()
        }
    }

    /// Whether any fault class (or the timeout) is active — i.e. whether
    /// [`build_far`] wraps the backend at all.
    pub fn enabled(&self) -> bool {
        self.nack_pct > 0.0
            || self.spike_pct > 0.0
            || self.degrade_period > 0
            || self.blackout_period > 0
            || self.timeout > 0
    }

    /// Parse a CLI/TOML spec:
    /// `off|mild|heavy|degrade|blackout|nack:PCT|spike:PCT`.
    pub fn parse(s: &str) -> Result<FaultConfig> {
        let s = s.trim().to_ascii_lowercase();
        if let Some(p) = s.strip_prefix("nack:") {
            return Ok(Self::nack(parse_pct(p)?));
        }
        if let Some(p) = s.strip_prefix("spike:") {
            return Ok(Self::spike(parse_pct(p)?));
        }
        Ok(match s.as_str() {
            "off" | "none" => Self::off(),
            "mild" => Self::mild(),
            "heavy" => Self::heavy(),
            "degrade" => Self::degrade(),
            "blackout" => Self::blackout(),
            other => return Err(crate::util::keyed::unknown_key::<Self>(other)),
        })
    }

    /// Display label (CLI, tables, `RunStats::faults`). Round-trips
    /// through [`FaultConfig::parse`] for every parseable spec; a config
    /// assembled key-by-key in TOML that matches no spec is `custom`.
    pub fn label(&self) -> String {
        if !self.enabled() {
            return "off".into();
        }
        if *self == Self::mild() {
            return "mild".into();
        }
        if *self == Self::heavy() {
            return "heavy".into();
        }
        if *self == Self::nack(self.nack_pct) {
            return format!("nack:{}", fmt_pct(self.nack_pct));
        }
        if *self == Self::spike(self.spike_pct) {
            return format!("spike:{}", fmt_pct(self.spike_pct));
        }
        if *self == Self::degrade() {
            return "degrade".into();
        }
        if *self == Self::blackout() {
            return "blackout".into();
        }
        "custom".into()
    }

    /// Reject configurations the injector cannot execute sensibly
    /// (called from `SimConfig::validate` with the full key path).
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [("nack", self.nack_pct), ("spike", self.spike_pct)] {
            ensure!(
                p.is_finite() && (0.0..=1.0).contains(&p),
                "mem.fabric.faults.{name} must be a fraction in [0, 1], got {p}"
            );
        }
        ensure!(self.spike_mult >= 1, "mem.fabric.faults.spike_mult must be >= 1");
        ensure!(self.degrade_factor >= 1, "mem.fabric.faults.degrade_factor must be >= 1");
        for (name, period, len) in [
            ("degrade", self.degrade_period, self.degrade_len),
            ("blackout", self.blackout_period, self.blackout_len),
        ] {
            if period > 0 {
                ensure!(
                    len >= 1 && len <= period,
                    "mem.fabric.faults.{name}_len must be in [1, {name}_period] \
                     (period {period}, len {len})"
                );
            }
        }
        ensure!(
            self.retries <= MAX_RETRIES,
            "mem.fabric.faults.retries must be <= {MAX_RETRIES}, got {}",
            self.retries
        );
        Ok(())
    }
}

impl crate::util::keyed::Keyed for FaultConfig {
    const AXIS: &'static str = "fault spec";
    const EXPECTED: &'static str = "off, mild, heavy, degrade, blackout, nack:PCT, spike:PCT";

    fn parse_keyed(s: &str) -> Result<Self> {
        FaultConfig::parse(s)
    }

    fn label_keyed(&self) -> String {
        self.label()
    }

    /// The named presets (the parameterized `nack:PCT`/`spike:PCT` forms
    /// are represented by their CLI defaults).
    fn all_keyed() -> Vec<Self> {
        vec![
            Self::off(),
            Self::mild(),
            Self::heavy(),
            Self::degrade(),
            Self::blackout(),
        ]
    }
}

/// Is `t` inside the periodic window occupying the last `len` cycles of
/// each `period`? (`period == 0` disables the window entirely.)
fn in_window(t: u64, period: u64, len: u64) -> bool {
    period > 0 && t % period >= period - len.min(period)
}

/// The fault-injecting decorator. Wraps any [`FabricModel`] and runs the
/// full timeout/retry/backoff/slow-path loop around the inner backend,
/// so every `issue` returns a finite completion cycle — no coroutine can
/// wedge on a faulted request, regardless of fault rates. Retried
/// attempts that reached the wire count as real inner-fabric requests
/// (retransmissions consume fabric resources), while NACKed attempts
/// never touch it.
#[derive(Debug)]
pub struct FaultyFabric {
    inner: Box<dyn FabricModel>,
    cfg: FaultConfig,
    rng: Rng,
    nacks: u64,
    retries: u64,
    retry_cycles: u64,
    timeouts: u64,
    degraded_cycles: u64,
    slow_path: u64,
    max_stall: u64,
    /// Per-requester (retries, slow-path completions) attribution.
    per_req: Vec<(u64, u64)>,
}

impl FaultyFabric {
    pub fn new(inner: Box<dyn FabricModel>, cfg: FaultConfig) -> FaultyFabric {
        FaultyFabric {
            inner,
            rng: Rng::new(cfg.seed),
            cfg,
            nacks: 0,
            retries: 0,
            retry_cycles: 0,
            timeouts: 0,
            degraded_cycles: 0,
            slow_path: 0,
            max_stall: 0,
            per_req: Vec::new(),
        }
    }

    fn per_req(&mut self, requester: CoreId) -> &mut (u64, u64) {
        let slot = requester as usize;
        if self.per_req.len() <= slot {
            self.per_req.resize(slot + 1, (0, 0));
        }
        &mut self.per_req[slot]
    }

    /// Charge the deterministic exponential backoff for retry number
    /// `attempt` and return the wait.
    fn backoff(&mut self, attempt: u32, requester: CoreId) -> u64 {
        let wait = self.cfg.backoff.max(1) << attempt.min(MAX_RETRIES);
        self.retries += 1;
        self.retry_cycles += wait;
        self.per_req(requester).0 += 1;
        wait
    }

    /// Graceful degradation: the retry budget is exhausted, so the
    /// request completes via the slow-path penalty from cycle `at`.
    fn slow_path_complete(&mut self, at: u64, requester: CoreId) -> u64 {
        self.slow_path += 1;
        self.per_req(requester).1 += 1;
        at + self.cfg.slow_path.max(1)
    }
}

impl FabricModel for FaultyFabric {
    fn kind(&self) -> FabricKind {
        self.inner.kind()
    }

    fn issue(&mut self, t: u64, addr: u64, lines: u64, kind: AccessKind, requester: CoreId) -> u64 {
        let cfg = self.cfg;
        let mut attempt: u32 = 0;
        // Cycle the current attempt issues at (advances with each
        // timeout wait and backoff).
        let mut at = t;
        let completion = loop {
            // NACK classes first: a blackout window fails every issue;
            // otherwise the transient-failure draw decides. Neither
            // reaches the inner fabric.
            let nacked = in_window(at, cfg.blackout_period, cfg.blackout_len)
                || (cfg.nack_pct > 0.0 && self.rng.f64() < cfg.nack_pct);
            if nacked {
                self.nacks += 1;
                if attempt >= cfg.retries {
                    break self.slow_path_complete(at, requester);
                }
                at += self.backoff(attempt, requester);
                attempt += 1;
                continue;
            }
            let mut done = self.inner.issue(at, addr, lines, kind, requester);
            if cfg.spike_pct > 0.0 && self.rng.f64() < cfg.spike_pct {
                done += (done - at) * (cfg.spike_mult.max(1) as u64 - 1);
            }
            if in_window(at, cfg.degrade_period, cfg.degrade_len) {
                let extra = (done - at) * (cfg.degrade_factor.max(1) as u64 - 1);
                self.degraded_cycles += extra;
                done += extra;
            }
            if cfg.timeout > 0 && done - at > cfg.timeout {
                // The requester gave up waiting at the timeout; the
                // abandoned attempt still consumed inner-fabric
                // resources (it was on the wire).
                self.timeouts += 1;
                if attempt >= cfg.retries {
                    break self.slow_path_complete(at + cfg.timeout, requester);
                }
                at += cfg.timeout;
                at += self.backoff(attempt, requester);
                attempt += 1;
                continue;
            }
            break done;
        };
        self.max_stall = self.max_stall.max(completion - t);
        completion
    }

    fn lines_transferred(&self) -> u64 {
        self.inner.lines_transferred()
    }

    fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        self.inner.mlp(total_cycles)
    }

    fn stats(&self) -> FabricStats {
        let mut st = self.inner.stats();
        st.faults = self.cfg.label();
        st.fault_nacks = self.nacks;
        st.fault_retries = self.retries;
        st.fault_retry_cycles = self.retry_cycles;
        st.fault_timeouts = self.timeouts;
        st.fault_degraded_cycles = self.degraded_cycles;
        st.fault_slow_path = self.slow_path;
        st.fault_max_stall = self.max_stall;
        for (slot, &(retries, slow)) in self.per_req.iter().enumerate() {
            let r = ensure_requester(&mut st.requesters, slot);
            r.fault_retries = retries;
            r.fault_slow_path = slow;
        }
        st
    }

    fn gauges(&self) -> FabricGauges {
        FabricGauges {
            nacks: self.nacks,
            retries: self.retries,
            timeouts: self.timeouts,
            slow_path: self.slow_path,
            ..self.inner.gauges()
        }
    }
}

/// Build the far fabric `cfg` selects, wrapped in the fault decorator
/// exactly when `[mem.fabric.faults]` enables a fault class — the one
/// construction path `MemSys::new` and `sim::cluster` share, so
/// faults-off runs never construct the decorator (bit-identity by
/// construction) and clusters compose automatically.
pub fn build_far(cfg: &SimConfig, window: usize) -> Box<dyn FabricModel> {
    let inner = cfg.mem.fabric.kind.build(
        cfg.far_latency_cycles(),
        cfg.mem.far_bw_bytes_per_cycle,
        true,
        window,
        cfg.mem.fabric.seed,
    );
    let f = &cfg.mem.fabric.faults;
    if f.enabled() {
        Box::new(FaultyFabric::new(inner, *f))
    } else {
        inner
    }
}

/// Enforce `faults.strict` after a run: under strict mode a request that
/// exhausted its retry budget (and completed via the slow path) is a
/// hard error instead of a silently absorbed penalty.
pub fn check_strict(cfg: &SimConfig, stats: &RunStats) -> Result<()> {
    if cfg.mem.fabric.faults.strict && stats.fault_slow_path > 0 {
        bail!(
            "fault injection: {} far request(s) exhausted the retry budget \
             under [mem.fabric.faults] strict",
            stats.fault_slow_path
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::RequesterStats;

    fn inner(kind: FabricKind) -> Box<dyn FabricModel> {
        kind.build(100, 16.0, true, 64, 1)
    }

    #[test]
    fn spec_parse_label_roundtrip() {
        for spec in ["off", "mild", "heavy", "degrade", "blackout", "nack:2", "spike:15"] {
            let c = FaultConfig::parse(spec).unwrap();
            assert_eq!(c.label(), spec, "label must round-trip for {spec}");
            assert_eq!(FaultConfig::parse(&c.label()).unwrap(), c);
        }
        assert_eq!(FaultConfig::parse("none").unwrap(), FaultConfig::off());
        assert_eq!(FaultConfig::parse("nack:2%").unwrap(), FaultConfig::nack(0.02));
        assert!(!FaultConfig::off().enabled());
        assert!(FaultConfig::mild().enabled());
        assert!(FaultConfig::parse("storm").is_err());
        assert!(FaultConfig::parse("nack:0").is_err());
        assert!(FaultConfig::parse("nack:101").is_err());
        assert!(FaultConfig::parse("spike:lots").is_err());
        assert_eq!(FaultConfig::default(), FaultConfig::off());
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        assert!(FaultConfig::off().validate().is_ok());
        assert!(FaultConfig::heavy().validate().is_ok());
        let mut c = FaultConfig::off();
        c.nack_pct = 1.5;
        assert!(c.validate().is_err());
        let mut c = FaultConfig::off();
        c.spike_pct = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = FaultConfig::degrade();
        c.degrade_len = 0;
        assert!(c.validate().is_err(), "a period with no window length is meaningless");
        let mut c = FaultConfig::degrade();
        c.degrade_len = c.degrade_period + 1;
        assert!(c.validate().is_err(), "window longer than its period");
        let mut c = FaultConfig::blackout();
        c.retries = MAX_RETRIES + 1;
        assert!(c.validate().is_err());
        let mut c = FaultConfig::off();
        c.spike_mult = 0;
        assert!(c.validate().is_err());
    }

    /// The all-NACK worst case is fully pinned: with `nack_pct = 1`,
    /// retries 3 and base backoff 64, every request burns the whole
    /// budget (backoffs 64+128+256) and completes via the slow path —
    /// never touching the inner fabric and never wedging.
    #[test]
    fn all_nacks_exhaust_the_budget_onto_the_slow_path() {
        let mut f = FaultyFabric::new(inner(FabricKind::FixedDelay), FaultConfig::nack(1.0));
        let done = f.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(done, 64 + 128 + 256 + 16_384, "3 backoffs then the slow path");
        let st = f.stats();
        assert_eq!(st.fault_nacks, 4, "initial attempt + 3 retries all NACKed");
        assert_eq!(st.fault_retries, 3);
        assert_eq!(st.fault_retry_cycles, 448);
        assert_eq!(st.fault_slow_path, 1);
        assert_eq!(st.fault_max_stall, done);
        assert_eq!(st.requests, 0, "NACKed attempts never reach the wire");
        assert_eq!(f.lines_transferred(), 0);
        assert_eq!(st.faults, "nack:100");
    }

    /// Timeouts retry and then degrade gracefully: with a timeout below
    /// the backend's base latency every attempt is abandoned at
    /// `issue + timeout`, and the budget exhausts onto the slow path at
    /// a fully pinned cycle.
    #[test]
    fn timeouts_retry_then_take_the_slow_path() {
        let cfg = FaultConfig {
            timeout: 50,
            retries: 1,
            backoff: 16,
            slow_path: 1000,
            ..FaultConfig::off()
        };
        let mut f = FaultyFabric::new(inner(FabricKind::FixedDelay), cfg);
        // Attempt 0 at t=0 completes at 104 > 50: timeout, wait 50+16.
        // Attempt 1 at t=66 completes at 170 (104 past 66): timeout,
        // budget exhausted -> slow path from 66+50.
        let done = f.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(done, 66 + 50 + 1000);
        let st = f.stats();
        assert_eq!(st.fault_timeouts, 2);
        assert_eq!(st.fault_retries, 1);
        assert_eq!(st.fault_retry_cycles, 16);
        assert_eq!(st.fault_slow_path, 1);
        assert_eq!(st.requests, 2, "abandoned attempts still consumed the wire");
    }

    /// Blackout windows NACK everything inside them; requests outside
    /// pass through untouched (no NACK draw is even configured).
    #[test]
    fn blackout_windows_nack_and_clear_air_passes() {
        let cfg = FaultConfig::blackout(); // period 131072, last 8192 cycles
        let mut f = FaultyFabric::new(inner(FabricKind::FixedDelay), cfg);
        let clear = f.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(clear, 104, "outside the window the decorator is transparent");
        assert_eq!(f.stats().fault_nacks, 0);
        // Deep inside the window every retry lands in it too (total
        // backoff 256+512+1024+2048 < 8192), so the budget exhausts.
        let start = 131_072 - 8_192;
        let done = f.issue(start, 0, 1, AccessKind::Load, 0);
        assert_eq!(done, start + 256 + 512 + 1024 + 2048 + 16_384);
        let st = f.stats();
        assert_eq!(st.fault_nacks, 5);
        assert_eq!(st.fault_slow_path, 1);
        // Just before the window: untouched again.
        let ok = f.issue(40_000, 0, 1, AccessKind::Load, 0);
        assert_eq!(ok, 40_104);
    }

    /// Degradation windows inflate service time by the factor and charge
    /// the inflation to `fault_degraded_cycles`; outside the window the
    /// decorator is transparent.
    #[test]
    fn degrade_windows_inflate_and_count() {
        let mut f = FaultyFabric::new(inner(FabricKind::FixedDelay), FaultConfig::degrade());
        let clear = f.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(clear, 104);
        assert_eq!(f.stats().fault_degraded_cycles, 0);
        let start = 65_536 - 16_384; // window start
        let done = f.issue(start, 0, 1, AccessKind::Load, 0);
        assert_eq!(done, start + 104 * 4, "4x collapse inside the window");
        let st = f.stats();
        assert_eq!(st.fault_degraded_cycles, 104 * 3);
        assert_eq!(st.fault_nacks + st.fault_slow_path, 0, "degradation never NACKs");
    }

    /// Latency spikes hit the seeded fraction: with `spike:50` both
    /// spiked (8x) and clean completions appear, deterministically.
    #[test]
    fn spikes_hit_a_seeded_fraction_deterministically() {
        let run = || {
            let mut f = FaultyFabric::new(inner(FabricKind::FixedDelay), FaultConfig::spike(0.5));
            (0..100u64).map(|k| f.issue(k * 10_000, 0, 1, AccessKind::Load, 0) - k * 10_000).collect::<Vec<_>>()
        };
        let a = run();
        assert_eq!(a, run(), "same seed, same spikes");
        let clean = a.iter().filter(|&&l| l == 104).count();
        let spiked = a.iter().filter(|&&l| l == 104 * 8).count();
        assert_eq!(clean + spiked, 100, "every request is either clean or spiked 8x");
        assert!(clean > 10 && spiked > 10, "both classes present ({clean}/{spiked})");
    }

    /// The decorator composes with a stateful backend: inner queue stats
    /// survive the overlay, and per-requester fault attribution
    /// partitions the totals.
    #[test]
    fn decorator_composes_and_attributes_per_requester() {
        let cfg = FaultConfig::nack(1.0);
        let mut f = FaultyFabric::new(inner(FabricKind::Queued { depth: 2 }), cfg);
        f.issue(0, 0, 1, AccessKind::Load, 0);
        f.issue(0, 0, 1, AccessKind::Load, 1);
        let st = f.stats();
        assert_eq!(st.kind, "queued:2", "inner identity survives the overlay");
        assert_eq!(st.fault_slow_path, 2);
        assert_eq!(st.requester(0).fault_retries, 3);
        assert_eq!(st.requester(1).fault_slow_path, 1);
        let retries: u64 = st.requesters.iter().map(|r| r.fault_retries).sum();
        assert_eq!(retries, st.fault_retries, "retry attribution partitions the total");
        assert_eq!(st.requester(9), RequesterStats::default());
    }

    /// Replay determinism over every backend under the chaos preset:
    /// the faulted fabric stays a pure function of (config, stream).
    #[test]
    fn faulted_backends_are_deterministic_replay_functions() {
        use crate::util::rng::Rng;
        for k in FabricKind::ALL {
            let mut rng = Rng::new(7);
            let stream: Vec<(u64, u64)> = (0..300)
                .scan(0u64, |t, _| {
                    *t += rng.below(2_000);
                    Some((*t, rng.below(1 << 18) * 64))
                })
                .collect();
            let run = |stream: &[(u64, u64)]| {
                let mut f = FaultyFabric::new(k.build(600, 16.0, true, 64, 3), FaultConfig::heavy());
                let cs: Vec<u64> =
                    stream.iter().map(|&(t, a)| f.issue(t, a, 1, AccessKind::Load, 0)).collect();
                (cs, f.stats())
            };
            let a = run(&stream);
            let b = run(&stream);
            assert_eq!(a, b, "{}: faulted replay diverged", k.label());
            assert!(
                a.0.iter().zip(&stream).all(|(c, (t, _))| c > t),
                "{}: every completion is finite and after its issue",
                k.label()
            );
            assert!(a.1.fault_nacks > 0, "{}: heavy chaos must actually fault", k.label());
        }
    }

    /// `build_far` wraps exactly when faults are enabled: the off path
    /// returns the bare backend (bit-identity by construction).
    #[test]
    fn build_far_wraps_only_when_enabled() {
        let cfg = SimConfig::nh_g();
        let mut bare = build_far(&cfg, 64);
        bare.issue(0, 0, 1, AccessKind::Load, 0);
        assert_eq!(bare.stats().faults, "", "fault-free runs carry no fault label");
        let faulted_cfg = SimConfig::nh_g().with_faults(FaultConfig::mild());
        let mut wrapped = build_far(&faulted_cfg, 64);
        wrapped.issue(0, 0, 1, AccessKind::Load, 0);
        let st = wrapped.stats();
        assert_eq!(st.faults, "mild");
        assert_eq!(wrapped.kind(), FabricKind::FixedDelay, "inner kind shows through");
    }

    #[test]
    fn strict_mode_flags_slow_path_completions() {
        let cfg = SimConfig::nh_g();
        let mut stats = RunStats::default();
        assert!(check_strict(&cfg, &stats).is_ok());
        stats.fault_slow_path = 2;
        assert!(check_strict(&cfg, &stats).is_ok(), "strict off ignores slow paths");
        let mut strict = FaultConfig::mild();
        strict.strict = true;
        let cfg = SimConfig::nh_g().with_faults(strict);
        assert!(check_strict(&cfg, &RunStats::default()).is_ok());
        let err = check_strict(&cfg, &stats).unwrap_err().to_string();
        assert!(err.contains("retry budget"), "{err}");
        assert!(err.contains('2'), "{err}");
    }
}
