//! The core timing spine: a dataflow + ROB interval model of the NH-G
//! out-of-order pipeline.
//!
//! In-order dispatch at `dispatch_width`/cycle; per-register ready cycles
//! give dataflow execution times; in-order retirement bounded by
//! `rob_entries` couples dispatch to the oldest incomplete instruction —
//! which is how a windowful of independent remote misses overlaps (MLP)
//! while a dependent pointer chase serializes. Load/store queues and the
//! front-end redirect penalty complete the first-order picture. This is
//! the standard trace-driven interval approximation (cf. interval
//! simulation literature); DESIGN.md §1 argues why it preserves the
//! paper's effects.

use super::slots::SlotQueue;
use super::stats::{tag_index, RunStats};
use crate::config::CoreConfig;
use crate::ir::{CodeTag, Reg};

/// Why a ROB entry may block retirement (stall attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cause {
    Compute,
    LocalMem,
    RemoteMem,
    Backpressure,
}

#[derive(Debug, Clone, Copy)]
struct RobEntry {
    complete: u64,
    cause: Cause,
}

#[derive(Debug)]
pub struct Core {
    width: usize,
    retire_width: usize,
    rob_cap: usize,
    pub mispredict_penalty: u64,
    /// Front-end depth: fetch happens this many cycles before dispatch
    /// (used for the bafin fetch-time oracle).
    pub frontend_depth: u64,

    // Dispatch state.
    dispatch_cycle: u64,
    dispatched_this_cycle: usize,
    frontend_ready: u64,
    // Retirement state: fixed ring buffer (occupancy never exceeds
    // rob_cap, so no growth logic on the hot path).
    rob: Vec<RobEntry>,
    rob_head: usize,
    rob_len: usize,
    last_retire_cycle: u64,
    retired_this_cycle: usize,
    // Load/store queues: fixed-size release-time slot pools.
    lq: SlotQueue,
    sq: SlotQueue,
    // Register scoreboard.
    reg_ready: Vec<u64>,
    // High-water completion (program end time).
    pub max_complete: u64,
    pub stats: RunStats,
}

impl Core {
    pub fn new(cfg: &CoreConfig, nregs: u32) -> Self {
        Core {
            width: cfg.dispatch_width,
            retire_width: cfg.retire_width,
            rob_cap: cfg.rob_entries,
            mispredict_penalty: cfg.mispredict_penalty,
            frontend_depth: 5,
            dispatch_cycle: 0,
            dispatched_this_cycle: 0,
            frontend_ready: 0,
            rob: vec![RobEntry { complete: 0, cause: Cause::Compute }; cfg.rob_entries],
            rob_head: 0,
            rob_len: 0,
            last_retire_cycle: 0,
            retired_this_cycle: 0,
            lq: SlotQueue::new(cfg.load_queue),
            sq: SlotQueue::new(cfg.store_queue),
            reg_ready: vec![0; nregs as usize],
            max_complete: 0,
            stats: RunStats::default(),
        }
    }

    /// Retire the ROB head, honouring in-order retirement and retire
    /// width. Returns the cycle the slot frees.
    fn retire_one(&mut self) -> (u64, Cause) {
        debug_assert!(self.rob_len > 0, "retire from empty ROB");
        let head = self.rob[self.rob_head];
        self.rob_head += 1;
        if self.rob_head == self.rob_cap {
            self.rob_head = 0;
        }
        self.rob_len -= 1;
        let mut rc = head.complete.max(self.last_retire_cycle);
        if rc == self.last_retire_cycle {
            if self.retired_this_cycle >= self.retire_width {
                rc += 1;
                self.retired_this_cycle = 1;
            } else {
                self.retired_this_cycle += 1;
            }
        } else {
            self.retired_this_cycle = 1;
        }
        self.last_retire_cycle = rc;
        (rc, head.cause)
    }

    /// Reserve a dispatch slot for the next instruction of block `tag`;
    /// returns the dispatch cycle. Stall cycles are attributed.
    pub fn dispatch(&mut self, tag: CodeTag) -> u64 {
        // Width + front-end constraints.
        let mut c = self.dispatch_cycle.max(self.frontend_ready);
        if c == self.dispatch_cycle && self.dispatched_this_cycle >= self.width {
            c += 1;
        }
        // ROB occupancy.
        if self.rob_len >= self.rob_cap {
            let (free_at, cause) = self.retire_one();
            if free_at > c {
                let gap = (free_at - c) as f64;
                match cause {
                    Cause::RemoteMem => self.stats.stalls.remote_mem += gap,
                    Cause::LocalMem => self.stats.stalls.local_mem += gap,
                    Cause::Backpressure => self.stats.stalls.backpressure += gap,
                    Cause::Compute => {}
                }
                c = free_at;
            }
        }
        if c != self.dispatch_cycle {
            self.dispatch_cycle = c;
            self.dispatched_this_cycle = 1;
        } else {
            self.dispatched_this_cycle += 1;
        }
        self.stats.dyn_instrs += 1;
        self.stats.dyn_by_tag[tag_index(tag)] += 1;
        c
    }

    /// Earliest cycle the operands are all ready, at or after `c`.
    pub fn operands_ready(&self, c: u64, srcs: &[Reg]) -> u64 {
        let mut r = c;
        for s in srcs {
            r = r.max(self.reg_ready[*s as usize]);
        }
        r
    }

    /// Scoreboard ready cycle of a single register (decode-once hot path;
    /// avoids building an operand slice per dynamic instruction).
    #[inline(always)]
    pub fn ready_of(&self, r: Reg) -> u64 {
        self.reg_ready[r as usize]
    }

    /// Acquire a load-queue slot at `t` (delayed if full).
    pub fn lq_acquire(&mut self, t: u64) -> u64 {
        let (grant, stall) = self.lq.acquire(t);
        self.stats.stalls.backpressure += stall as f64;
        grant
    }

    /// Acquire a store-queue slot at `t`.
    pub fn sq_acquire(&mut self, t: u64) -> u64 {
        let (grant, stall) = self.sq.acquire(t);
        self.stats.stalls.backpressure += stall as f64;
        grant
    }

    pub fn lq_hold(&mut self, release: u64) {
        self.lq.hold(release);
    }

    pub fn sq_hold(&mut self, release: u64) {
        self.sq.hold(release);
    }

    /// Commit an instruction: completion time, destination write, ROB entry.
    #[inline]
    pub fn commit(&mut self, dst: Option<Reg>, complete: u64, cause: Cause) {
        if let Some(d) = dst {
            self.reg_ready[d as usize] = complete;
        }
        let mut tail = self.rob_head + self.rob_len;
        if tail >= self.rob_cap {
            tail -= self.rob_cap;
        }
        self.rob[tail] = RobEntry { complete, cause };
        self.rob_len += 1;
        if complete > self.max_complete {
            self.max_complete = complete;
        }
    }

    /// Apply a front-end redirect after a mispredicted branch resolving at
    /// `resolve`: fetch resumes after the penalty.
    pub fn redirect(&mut self, resolve: u64) {
        let resume = resolve + self.mispredict_penalty;
        if resume > self.frontend_ready {
            // Attribute the bubble (bounded by what the backend can absorb).
            let bubble = resume.saturating_sub(self.dispatch_cycle.max(self.frontend_ready));
            self.stats.stalls.mispredict += bubble as f64;
            self.frontend_ready = resume;
        }
    }

    /// Current dispatch-cycle estimate (used for fetch-time oracles).
    pub fn now(&self) -> u64 {
        self.dispatch_cycle.max(self.frontend_ready)
    }

    /// Finalize: drain the ROB and set total cycles.
    pub fn finish(&mut self) {
        while self.rob_len > 0 {
            self.retire_one();
        }
        self.stats.cycles = self.max_complete.max(self.last_retire_cycle).max(self.dispatch_cycle);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn core(nregs: u32) -> Core {
        Core::new(&SimConfig::nh_g().core, nregs)
    }

    #[test]
    fn width_limits_dispatch() {
        let mut c = core(4);
        let cycles: Vec<u64> = (0..8).map(|_| c.dispatch(CodeTag::Compute)).collect();
        // Width 4: first 4 in cycle 0, next 4 in cycle 1.
        assert_eq!(cycles, vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }

    #[test]
    fn rob_full_stalls_on_slow_head() {
        let mut c = core(4);
        // Fill the ROB with one slow (remote) instruction then fast ones.
        let d0 = c.dispatch(CodeTag::Compute);
        c.commit(None, d0 + 600, Cause::RemoteMem);
        for _ in 0..95 {
            let d = c.dispatch(CodeTag::Compute);
            c.commit(None, d + 1, Cause::Compute);
        }
        // ROB (96) now full; next dispatch waits for the remote head.
        let d = c.dispatch(CodeTag::Compute);
        assert!(d >= 600, "dispatch {d} should wait for remote head at 600");
        assert!(c.stats.stalls.remote_mem > 500.0);
    }

    #[test]
    fn independent_misses_overlap_within_window() {
        // 8 independent remote loads (600 cycles each) must overlap: the
        // last completes near 600 + epsilon, not 8*600.
        let mut c = core(16);
        let mut last = 0;
        for i in 0..8u32 {
            let d = c.dispatch(CodeTag::Compute);
            let done = d + 600;
            c.commit(Some(i), done, Cause::RemoteMem);
            last = done;
        }
        assert!(last < 700, "independent misses serialized: {last}");
    }

    #[test]
    fn dependent_chain_serializes() {
        let mut c = core(4);
        let mut done_prev = 0;
        for _ in 0..4 {
            let d = c.dispatch(CodeTag::Compute);
            let start = c.operands_ready(d, &[0]);
            let done = start + 600;
            c.commit(Some(0), done, Cause::RemoteMem);
            done_prev = done;
        }
        assert!(done_prev >= 2400, "dependent chain should serialize: {done_prev}");
    }

    #[test]
    fn redirect_blocks_frontend() {
        let mut c = core(4);
        let d = c.dispatch(CodeTag::Compute);
        c.commit(None, d + 1, Cause::Compute);
        c.redirect(d + 10);
        let d2 = c.dispatch(CodeTag::Compute);
        assert!(d2 >= d + 10 + c.mispredict_penalty);
        assert!(c.stats.stalls.mispredict > 0.0);
    }

    #[test]
    fn lq_backpressure() {
        let mut c = core(4);
        for _ in 0..32 {
            let t = c.lq_acquire(0);
            c.lq_hold(t + 1000);
        }
        let t = c.lq_acquire(0);
        assert_eq!(t, 1000, "33rd load waits for a LQ slot");
    }

    #[test]
    fn finish_drains() {
        let mut c = core(4);
        let d = c.dispatch(CodeTag::Compute);
        c.commit(None, d + 123, Cause::Compute);
        c.finish();
        assert!(c.stats.cycles >= 123);
        assert_eq!(c.stats.dyn_instrs, 1);
    }
}
