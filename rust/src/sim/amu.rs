//! The (enhanced) Asynchronous Memory Unit model (§II-C, §IV).
//!
//! Request Table entries track in-flight decoupled transfers (capacity =
//! SPM lines, paper: 512); the Finished Queue holds completed ids awaiting
//! `getfin`/`bafin`; `aset` groups aggregate multiple transfers under one
//! id with a completion counter (§IV-B); `await`/`asignal` reuse the same
//! structures as non-access requests (§IV-C). Timing is analytic: each
//! entry carries its completion cycle, and polls are answered relative to
//! the asking cycle (for `bafin`, the *fetch* cycle — the §IV-A oracle).

use crate::ir::BlockId;
use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy)]
struct FinEntry {
    ready: u64,
    id: i64,
    resume: BlockId,
}

#[derive(Debug, Clone, Copy)]
struct GroupState {
    remaining: u32,
    ready_max: u64,
    resume: BlockId,
}

#[derive(Debug)]
pub struct Amu {
    /// Request Table capacity (ids concurrently in flight).
    table_cap: usize,
    /// Completion times of in-flight transfers (slot release).
    slots: Vec<u64>,
    finished: Vec<FinEntry>,
    groups: HashMap<i64, GroupState>,
    /// Pending `await` registrations: id -> resume block.
    awaiting: HashMap<i64, BlockId>,
    /// Small fixed consume latency for getfin/asignal paths.
    unit_latency: u64,
    pub stat_aloads: u64,
    pub stat_astores: u64,
    pub stat_groups: u64,
    pub stat_awaits: u64,
    pub stat_asignals: u64,
    pub stat_issue_stall_cycles: u64,
    pub stat_max_inflight: usize,
}

impl Amu {
    pub fn new(table_cap: usize, unit_latency: u64) -> Self {
        Amu {
            table_cap: table_cap.max(1),
            slots: Vec::new(),
            finished: Vec::new(),
            groups: HashMap::new(),
            awaiting: HashMap::new(),
            unit_latency,
            stat_aloads: 0,
            stat_astores: 0,
            stat_groups: 0,
            stat_awaits: 0,
            stat_asignals: 0,
            stat_issue_stall_cycles: 0,
            stat_max_inflight: 0,
        }
    }

    /// Acquire a Request Table slot at cycle `t`; returns the actual issue
    /// cycle (>= t, delayed when the table is full).
    fn slot_acquire(&mut self, t: u64) -> u64 {
        self.slots.retain(|&r| r > t);
        self.stat_max_inflight = self.stat_max_inflight.max(self.slots.len() + 1);
        // NOTE: the retain here is load-bearing for the MLP statistic
        // (stat_max_inflight must see only live transfers), so no fast
        // path — the request table is bounded at 512 entries.
        if self.slots.len() < self.table_cap {
            return t;
        }
        let (idx, &earliest) =
            self.slots.iter().enumerate().min_by_key(|(_, r)| **r).expect("nonempty");
        self.slots.swap_remove(idx);
        self.stat_issue_stall_cycles += earliest - t;
        earliest
    }

    /// Begin an aggregation group: the next `n` transfers bound to `id`
    /// complete as one notification.
    pub fn aset(&mut self, id: i64, n: u32) -> Result<()> {
        if n == 0 {
            bail!("aset with n=0");
        }
        if self.groups.insert(id, GroupState { remaining: n, ready_max: 0, resume: 0 }).is_some() {
            bail!("aset on id {id} with a group already open");
        }
        self.stat_groups += 1;
        Ok(())
    }

    /// Record a transfer bound to `id` completing at `completion`; returns
    /// the issue cycle granted (slot acquisition may delay past `t`).
    /// `completion_of` maps the granted issue cycle to the transfer's
    /// completion (so channel bandwidth is charged from the true issue).
    pub fn transfer(
        &mut self,
        id: i64,
        resume: BlockId,
        t: u64,
        is_store: bool,
        completion_of: impl FnOnce(u64) -> u64,
    ) -> u64 {
        let issue = self.slot_acquire(t);
        let completion = completion_of(issue);
        self.slots.push(completion);
        if is_store {
            self.stat_astores += 1;
        } else {
            self.stat_aloads += 1;
        }
        match self.groups.get_mut(&id) {
            Some(g) => {
                g.remaining -= 1;
                g.ready_max = g.ready_max.max(completion);
                g.resume = resume;
                if g.remaining == 0 {
                    let g = self.groups.remove(&id).unwrap();
                    self.finished.push(FinEntry { ready: g.ready_max, id, resume: g.resume });
                }
            }
            None => self.finished.push(FinEntry { ready: completion, id, resume }),
        }
        issue
    }

    /// §IV-C: register `id` as hung (non-access Request Table entry).
    pub fn await_register(&mut self, id: i64, resume: BlockId) -> Result<()> {
        if self.awaiting.insert(id, resume).is_some() {
            bail!("await on id {id} already awaiting");
        }
        self.stat_awaits += 1;
        Ok(())
    }

    /// §IV-C: complete a pending await, making `id` visible to polls.
    pub fn asignal(&mut self, id: i64, t: u64) -> Result<()> {
        let Some(resume) = self.awaiting.remove(&id) else {
            bail!("asignal({id}) without matching await");
        };
        self.stat_asignals += 1;
        self.finished.push(FinEntry { ready: t + self.unit_latency, id, resume });
        Ok(())
    }

    /// Pop the oldest finished id whose completion is visible at cycle
    /// `t` (for `bafin`, `t` is the fetch cycle — §IV-A's oracle property).
    pub fn pop_finished(&mut self, t: u64) -> Option<(i64, BlockId)> {
        let mut best: Option<usize> = None;
        for (i, e) in self.finished.iter().enumerate() {
            if e.ready <= t && best.map(|b| e.ready < self.finished[b].ready).unwrap_or(true) {
                best = Some(i);
            }
        }
        best.map(|i| {
            let e = self.finished.remove(i);
            (e.id, e.resume)
        })
    }

    /// Ids currently in the request table (diagnostics).
    pub fn inflight(&mut self, t: u64) -> usize {
        self.slots.retain(|&r| r > t);
        self.slots.len()
    }

    /// Anything still pending (finished-but-unconsumed or awaiting)?
    pub fn quiescent(&self) -> bool {
        self.finished.is_empty() && self.awaiting.is_empty() && self.groups.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_completes_and_pops_in_ready_order() {
        let mut a = Amu::new(16, 2);
        a.transfer(0, 10, 0, false, |t| t + 600);
        a.transfer(1, 11, 0, false, |t| t + 300);
        assert_eq!(a.pop_finished(100), None, "nothing ready at cycle 100");
        assert_eq!(a.pop_finished(300), Some((1, 11)), "earliest-ready pops first");
        assert_eq!(a.pop_finished(1000), Some((0, 10)));
        assert_eq!(a.pop_finished(1000), None);
    }

    #[test]
    fn aset_group_completes_once_all_done() {
        let mut a = Amu::new(16, 2);
        a.aset(5, 3).unwrap();
        a.transfer(5, 20, 0, false, |t| t + 100);
        a.transfer(5, 20, 0, false, |t| t + 900);
        assert_eq!(a.pop_finished(500), None, "group incomplete");
        a.transfer(5, 20, 0, false, |t| t + 200);
        assert_eq!(a.pop_finished(899), None);
        assert_eq!(a.pop_finished(900), Some((5, 20)), "ready at max member completion");
    }

    #[test]
    fn request_table_backpressure() {
        let mut a = Amu::new(2, 2);
        a.transfer(0, 0, 0, false, |t| t + 100);
        a.transfer(1, 0, 0, false, |t| t + 200);
        // Third transfer stalls until id 0's slot frees at 100.
        let issue = a.transfer(2, 0, 0, false, |t| t + 100);
        assert_eq!(issue, 100);
        assert_eq!(a.stat_issue_stall_cycles, 100);
    }

    #[test]
    fn await_asignal_roundtrip() {
        let mut a = Amu::new(16, 2);
        a.await_register(7, 33).unwrap();
        assert_eq!(a.pop_finished(u64::MAX), None, "awaiting id is not ready");
        a.asignal(7, 50).unwrap();
        assert_eq!(a.pop_finished(51), None, "unit latency applies");
        assert_eq!(a.pop_finished(52), Some((7, 33)));
        assert!(a.asignal(7, 60).is_err(), "double signal");
    }

    #[test]
    fn bafin_oracle_is_fetch_relative() {
        // An entry completing between fetch and execute is invisible at
        // fetch: pop with the fetch cycle must not return it.
        let mut a = Amu::new(16, 0);
        a.transfer(3, 9, 0, false, |t| t + 50);
        assert_eq!(a.pop_finished(49), None);
        assert_eq!(a.pop_finished(50), Some((3, 9)));
    }

    #[test]
    fn quiescence() {
        let mut a = Amu::new(4, 1);
        assert!(a.quiescent());
        a.aset(1, 2).unwrap();
        assert!(!a.quiescent());
    }
}
