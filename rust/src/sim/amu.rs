//! The (enhanced) Asynchronous Memory Unit model (§II-C, §IV).
//!
//! Request Table entries track in-flight decoupled transfers (capacity =
//! SPM lines, paper: 512); the Finished Queue holds completed ids awaiting
//! `getfin`/`bafin`; `aset` groups aggregate multiple transfers under one
//! id with a completion counter (§IV-B); `await`/`asignal` reuse the same
//! structures as non-access requests (§IV-C). Timing is analytic: each
//! entry carries its completion cycle, and polls are answered relative to
//! the asking cycle (for `bafin`, the *fetch* cycle — the §IV-A oracle).
//!
//! *Which* finished id a poll returns is no longer hardwired: the queue
//! is policy-queried ([`super::sched::SchedPolicy`]), so the coroutine
//! resume order — suspension order, memory-arrival order, batched,
//! latency-aware — is a sweepable axis. The default policy
//! (`ArrivalOrder`) reproduces the old earliest-ready scan bit-for-bit.
//!
//! **Resilience contract.** The AMU's bookkeeping is analytic: a Request
//! Table slot is reclaimed at the completion cycle the memory system
//! returned at issue time, and a coroutine suspends until that cycle is
//! answered by a poll. Both therefore require every far request to
//! complete at a *finite* cycle. Under fault injection
//! ([`super::faults`]) that contract is preserved inside the fabric
//! decorator itself: timeouts, bounded retries with exponential backoff
//! and the slow-path fallback all resolve *before* `issue` returns, so
//! the AMU sees one (possibly very late) completion per transfer and no
//! coroutine can wedge on a faulted request — chaos moves completion
//! cycles, never the shape of the AMU's state machine.

use super::sched::{Pending, SchedPolicy, SchedPolicyKind};
use crate::ir::BlockId;
use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy)]
struct GroupState {
    remaining: u32,
    ready_max: u64,
    /// Earliest member issue (the group's suspension point for
    /// latency-aware scheduling).
    issue_min: u64,
    resume: BlockId,
}

#[derive(Debug, Clone, Copy)]
struct AwaitState {
    resume: BlockId,
    /// Registration cycle (the hung coroutine's suspension point).
    issue: u64,
}

#[derive(Debug)]
pub struct Amu {
    /// Request Table capacity (ids concurrently in flight).
    table_cap: usize,
    /// Completion times of in-flight transfers (slot release).
    slots: Vec<u64>,
    finished: Vec<Pending>,
    groups: HashMap<i64, GroupState>,
    /// Pending `await` registrations: id -> resume block + issue cycle.
    awaiting: HashMap<i64, AwaitState>,
    /// Resume-order policy over the Finished Queue.
    policy: Box<dyn SchedPolicy>,
    /// Monotone enqueue sequence (suspension/completion order key).
    next_seq: u64,
    /// Small fixed consume latency for getfin/asignal paths.
    unit_latency: u64,
    pub stat_aloads: u64,
    pub stat_astores: u64,
    pub stat_groups: u64,
    pub stat_awaits: u64,
    pub stat_asignals: u64,
    pub stat_issue_stall_cycles: u64,
    pub stat_max_inflight: usize,
    /// Finished-Queue polls (getfin/bafin asks, including empty-queue).
    pub stat_sched_polls: u64,
    /// Polls the policy answered with a resume.
    pub stat_sched_picks: u64,
    /// Polls the policy deferred although a completion was visible
    /// (FIFO head-of-line blocks, batched-wakeup coalescing holds).
    pub stat_sched_holds: u64,
}

impl Amu {
    /// An AMU under the default (`ArrivalOrder`) policy — the paper's
    /// native Finished-Queue order.
    pub fn new(table_cap: usize, unit_latency: u64) -> Self {
        Self::with_policy(table_cap, unit_latency, SchedPolicyKind::default().build())
    }

    /// An AMU whose Finished Queue is ordered by `policy`.
    pub fn with_policy(table_cap: usize, unit_latency: u64, policy: Box<dyn SchedPolicy>) -> Self {
        Amu {
            table_cap: table_cap.max(1),
            slots: Vec::new(),
            finished: Vec::new(),
            groups: HashMap::new(),
            awaiting: HashMap::new(),
            policy,
            next_seq: 0,
            unit_latency,
            stat_aloads: 0,
            stat_astores: 0,
            stat_groups: 0,
            stat_awaits: 0,
            stat_asignals: 0,
            stat_issue_stall_cycles: 0,
            stat_max_inflight: 0,
            stat_sched_polls: 0,
            stat_sched_picks: 0,
            stat_sched_holds: 0,
        }
    }

    /// The active policy's kind (provenance / BPU coverage wiring).
    pub fn policy_kind(&self) -> SchedPolicyKind {
        self.policy.kind()
    }

    /// Whether the active policy keeps the §IV-A BTQ oracle (see
    /// [`SchedPolicy::btq_guided`]).
    pub fn btq_guided(&self) -> bool {
        self.policy.btq_guided()
    }

    fn enqueue(&mut self, id: i64, ready: u64, issue: u64, resume: BlockId) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.finished.push(Pending { id, ready, issue, seq, resume });
        self.policy.on_complete(id, ready);
    }

    /// Acquire a Request Table slot at cycle `t`; returns the actual issue
    /// cycle (>= t, delayed when the table is full).
    fn slot_acquire(&mut self, t: u64) -> u64 {
        self.slots.retain(|&r| r > t);
        self.stat_max_inflight = self.stat_max_inflight.max(self.slots.len() + 1);
        // NOTE: the retain here is load-bearing for the MLP statistic
        // (stat_max_inflight must see only live transfers), so no fast
        // path — the request table is bounded at 512 entries.
        if self.slots.len() < self.table_cap {
            return t;
        }
        let (idx, &earliest) =
            self.slots.iter().enumerate().min_by_key(|(_, r)| **r).expect("nonempty");
        self.slots.swap_remove(idx);
        self.stat_issue_stall_cycles += earliest - t;
        earliest
    }

    /// Begin an aggregation group: the next `n` transfers bound to `id`
    /// complete as one notification.
    pub fn aset(&mut self, id: i64, n: u32) -> Result<()> {
        if n == 0 {
            bail!("aset with n=0");
        }
        let g = GroupState { remaining: n, ready_max: 0, issue_min: u64::MAX, resume: 0 };
        if self.groups.insert(id, g).is_some() {
            bail!("aset on id {id} with a group already open");
        }
        self.stat_groups += 1;
        Ok(())
    }

    /// Record a transfer bound to `id` completing at `completion`; returns
    /// the issue cycle granted (slot acquisition may delay past `t`).
    /// `completion_of` maps the granted issue cycle to the transfer's
    /// completion (so fabric bandwidth/queuing — `sim::fabric` — is
    /// charged from the true issue).
    pub fn transfer(
        &mut self,
        id: i64,
        resume: BlockId,
        t: u64,
        is_store: bool,
        completion_of: impl FnOnce(u64) -> u64,
    ) -> u64 {
        let issue = self.slot_acquire(t);
        let completion = completion_of(issue);
        self.slots.push(completion);
        self.policy.on_suspend(id, issue);
        if is_store {
            self.stat_astores += 1;
        } else {
            self.stat_aloads += 1;
        }
        match self.groups.get_mut(&id) {
            Some(g) => {
                g.remaining -= 1;
                g.ready_max = g.ready_max.max(completion);
                g.issue_min = g.issue_min.min(issue);
                g.resume = resume;
                if g.remaining == 0 {
                    let g = self.groups.remove(&id).unwrap();
                    self.enqueue(id, g.ready_max, g.issue_min, g.resume);
                }
            }
            None => self.enqueue(id, completion, issue, resume),
        }
        issue
    }

    /// §IV-C: register `id` as hung (non-access Request Table entry) at
    /// cycle `t`.
    pub fn await_register(&mut self, id: i64, resume: BlockId, t: u64) -> Result<()> {
        if self.awaiting.insert(id, AwaitState { resume, issue: t }).is_some() {
            bail!("await on id {id} already awaiting");
        }
        self.policy.on_suspend(id, t);
        self.stat_awaits += 1;
        Ok(())
    }

    /// §IV-C: complete a pending await, making `id` visible to polls.
    pub fn asignal(&mut self, id: i64, t: u64) -> Result<()> {
        let Some(st) = self.awaiting.remove(&id) else {
            bail!("asignal({id}) without matching await");
        };
        self.stat_asignals += 1;
        self.enqueue(id, t + self.unit_latency, st.issue, st.resume);
        Ok(())
    }

    /// Ask the scheduler policy for the next coroutine to resume at cycle
    /// `t` (for `bafin`, `t` is the fetch cycle — §IV-A's oracle
    /// property). Under the default `ArrivalOrder` policy this is exactly
    /// the historical oldest-ready pop.
    pub fn pop_finished(&mut self, t: u64) -> Option<(i64, BlockId)> {
        self.stat_sched_polls += 1;
        if self.finished.is_empty() {
            return None;
        }
        match self.policy.pick_next(&self.finished, t) {
            Some(i) => {
                let e = self.finished.remove(i);
                debug_assert!(e.ready <= t, "policy resumed id {} before its data arrived", e.id);
                self.stat_sched_picks += 1;
                Some((e.id, e.resume))
            }
            None => {
                if self.finished.iter().any(|e| e.ready <= t) {
                    self.stat_sched_holds += 1;
                }
                None
            }
        }
    }

    /// Ids currently in the request table (diagnostics).
    pub fn inflight(&mut self, t: u64) -> usize {
        self.slots.retain(|&r| r > t);
        self.slots.len()
    }

    /// Anything still pending (finished-but-unconsumed or awaiting)?
    pub fn quiescent(&self) -> bool {
        self.finished.is_empty() && self.awaiting.is_empty() && self.groups.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::sched::SchedPolicyKind as K;

    #[test]
    fn transfer_completes_and_pops_in_ready_order() {
        let mut a = Amu::new(16, 2);
        a.transfer(0, 10, 0, false, |t| t + 600);
        a.transfer(1, 11, 0, false, |t| t + 300);
        assert_eq!(a.pop_finished(100), None, "nothing ready at cycle 100");
        assert_eq!(a.pop_finished(300), Some((1, 11)), "earliest-ready pops first");
        assert_eq!(a.pop_finished(1000), Some((0, 10)));
        assert_eq!(a.pop_finished(1000), None);
        assert_eq!(a.stat_sched_picks, 2);
        assert_eq!(a.stat_sched_holds, 0, "arrival order never defers visible work");
    }

    #[test]
    fn aset_group_completes_once_all_done() {
        let mut a = Amu::new(16, 2);
        a.aset(5, 3).unwrap();
        a.transfer(5, 20, 0, false, |t| t + 100);
        a.transfer(5, 20, 0, false, |t| t + 900);
        assert_eq!(a.pop_finished(500), None, "group incomplete");
        a.transfer(5, 20, 0, false, |t| t + 200);
        assert_eq!(a.pop_finished(899), None);
        assert_eq!(a.pop_finished(900), Some((5, 20)), "ready at max member completion");
    }

    #[test]
    fn request_table_backpressure() {
        let mut a = Amu::new(2, 2);
        a.transfer(0, 0, 0, false, |t| t + 100);
        a.transfer(1, 0, 0, false, |t| t + 200);
        // Third transfer stalls until id 0's slot frees at 100.
        let issue = a.transfer(2, 0, 0, false, |t| t + 100);
        assert_eq!(issue, 100);
        assert_eq!(a.stat_issue_stall_cycles, 100);
    }

    #[test]
    fn await_asignal_roundtrip() {
        let mut a = Amu::new(16, 2);
        a.await_register(7, 33, 40).unwrap();
        assert_eq!(a.pop_finished(u64::MAX), None, "awaiting id is not ready");
        a.asignal(7, 50).unwrap();
        assert_eq!(a.pop_finished(51), None, "unit latency applies");
        assert_eq!(a.pop_finished(52), Some((7, 33)));
        assert!(a.asignal(7, 60).is_err(), "double signal");
    }

    #[test]
    fn bafin_oracle_is_fetch_relative() {
        // An entry completing between fetch and execute is invisible at
        // fetch: pop with the fetch cycle must not return it.
        let mut a = Amu::new(16, 0);
        a.transfer(3, 9, 0, false, |t| t + 50);
        assert_eq!(a.pop_finished(49), None);
        assert_eq!(a.pop_finished(50), Some((3, 9)));
    }

    #[test]
    fn quiescence() {
        let mut a = Amu::new(4, 1);
        assert!(a.quiescent());
        a.aset(1, 2).unwrap();
        assert!(!a.quiescent());
    }

    #[test]
    fn fifo_policy_blocks_behind_suspension_head() {
        let mut a = Amu::with_policy(16, 2, K::Fifo.build());
        a.transfer(0, 10, 0, false, |t| t + 900); // suspended first, arrives last
        a.transfer(1, 11, 0, false, |t| t + 100);
        assert_eq!(a.pop_finished(500), None, "younger arrival must not overtake");
        assert!(a.stat_sched_holds > 0, "the deferral is accounted");
        assert_eq!(a.pop_finished(900), Some((0, 10)), "head resumes in suspension order");
        assert_eq!(a.pop_finished(900), Some((1, 11)));
        assert!(!a.btq_guided());
    }

    #[test]
    fn batched_policy_coalesces_wakeups() {
        let mut a = Amu::with_policy(16, 2, K::BatchedWakeup(2).build());
        a.transfer(0, 10, 0, false, |t| t + 100);
        a.transfer(1, 11, 0, false, |t| t + 800);
        assert_eq!(a.pop_finished(200), None, "one visible < batch of two");
        assert_eq!(a.pop_finished(800), Some((0, 10)), "batch releases in arrival order");
        assert_eq!(a.pop_finished(800), Some((1, 11)), "tail of one drains immediately");
        assert!(a.btq_guided());
    }

    #[test]
    fn latency_aware_resumes_longest_suspended() {
        let mut a = Amu::with_policy(2, 2, K::LatencyAware.build());
        // Fill the table so the third transfer issues late (issue 100),
        // then make the late-issued one arrive first.
        a.transfer(0, 10, 0, false, |t| t + 400);
        a.transfer(1, 11, 0, false, |t| t + 100);
        a.transfer(2, 12, 0, false, |t| t + 150); // issue 100, ready 250
        assert_eq!(a.pop_finished(260), Some((1, 11)), "earliest-issued of the visible");
        // At 400 both id 0 (issue 0) and id 2 (issue 100) are visible:
        // the earliest-issued (longest-suspended) coroutine wins.
        assert_eq!(a.pop_finished(400), Some((0, 10)));
        assert_eq!(a.pop_finished(400), Some((2, 12)));
    }

    #[test]
    fn group_issue_is_earliest_member() {
        let mut a = Amu::with_policy(16, 2, K::LatencyAware.build());
        a.aset(5, 2).unwrap();
        a.transfer(5, 20, 30, false, |t| t + 100); // issue 30
        a.transfer(5, 20, 60, false, |t| t + 100); // issue 60
        a.transfer(9, 21, 40, false, |t| t + 500); // plain, issue 40
        // Both visible at 600: group's issue_min (30) beats 40.
        assert_eq!(a.pop_finished(600), Some((5, 20)));
        assert_eq!(a.pop_finished(600), Some((9, 21)));
    }
}
