//! Pluggable coroutine-scheduler policies for the AMU's Finished Queue.
//!
//! The paper's headline hardware-software claim is that the AMU "further
//! exploits dynamic coroutine schedulers": *which* suspended coroutine
//! resumes next — static suspension order, memory-arrival order, batched
//! wakeup — is the dominant lever on latency-hiding efficiency (cf.
//! CoroBase, VLDB 2021). Before this module that choice was hardwired
//! into the `Variant` lowering; now it is a first-class, sweepable axis:
//! the [`Amu`](super::amu::Amu) stores every outstanding completion as a
//! [`Pending`] entry and delegates the resume decision to a
//! [`SchedPolicy`], selected by [`SchedPolicyKind`] through
//! `SimConfig::sched_policy` / `RunRequest::policy(..)`.
//!
//! The policy also owns the *memory-guided prediction* property (§IV-A):
//! the BTQ can only carry a `bafin` target to the front end when the
//! resume order is decided by the AMU itself from Finished-Queue state
//! ([`SchedPolicy::btq_guided`]). A software-imposed static order
//! ([`Fifo`]) breaks that oracle, so `bafin` mispredicts under it while
//! the memory-guided policies keep the paper's zero-mispredict property
//! (pinned by the differential suite).

use crate::ir::BlockId;
use anyhow::{bail, Result};

/// Coroutine identity: the id bound to an AMU request (`aload`/`astore`/
/// `await`). Matches the `i64` register value the ISA carries.
pub type CoroId = i64;

/// One outstanding completion in the AMU's Finished Queue. Entries are
/// created at request time with their (analytic) completion cycle, so a
/// policy sees the whole in-flight set and filters visibility by
/// `ready <= now` itself.
#[derive(Debug, Clone, Copy)]
pub struct Pending {
    pub id: CoroId,
    /// Cycle the completion becomes visible to polls.
    pub ready: u64,
    /// Cycle the underlying request was issued (group entries carry the
    /// earliest member issue; awaits carry the registration cycle).
    pub issue: u64,
    /// Monotone enqueue sequence number (suspension order for plain
    /// transfers; completion order for groups and signalled awaits).
    pub seq: u64,
    /// Coroutine resume block, forwarded through the BTQ for `bafin`.
    pub resume: BlockId,
}

/// A coroutine-scheduling policy over the AMU's Finished Queue.
///
/// `pick_next` receives the full pending set (not just the visible
/// subset) so policies can make occupancy-aware decisions (batched
/// wakeup needs the total outstanding count); it must only return an
/// index whose entry has `ready <= now`. Returning `None` keeps the
/// scheduler spinning (`getfin` yields -1, `bafin` falls through).
pub trait SchedPolicy: std::fmt::Debug + Send {
    /// The kind this policy was built from (stats / provenance).
    fn kind(&self) -> SchedPolicyKind;

    /// A coroutine suspended: its request entered the Request Table (or
    /// an `await` registered) at cycle `issue`.
    fn on_suspend(&mut self, _id: CoroId, _issue: u64) {}

    /// A completion entered the Finished Queue, visible from `ready`.
    fn on_complete(&mut self, _id: CoroId, _ready: u64) {}

    /// Choose the index into `pending` of the coroutine to resume at
    /// cycle `now`, or `None` to defer. Entries with `ready > now` are
    /// not yet visible and must not be picked.
    fn pick_next(&mut self, pending: &[Pending], now: u64) -> Option<usize>;

    /// Whether the BTQ can deliver this policy's choice to the front end
    /// at fetch time (§IV-A). True for memory-guided policies the AMU
    /// hardware can evaluate from Finished-Queue state; false for
    /// software-imposed orders, which cost `bafin` its oracle coverage.
    fn btq_guided(&self) -> bool {
        true
    }
}

/// Selector for the concrete policies, carried by `SimConfig` and swept
/// by the engine/harness. The default ([`ArrivalOrder`]) reproduces the
/// pre-subsystem behavior bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedPolicyKind {
    /// Static suspension order (getfin-style software FIFO): the oldest
    /// suspended coroutine resumes first, even when a younger one's data
    /// arrived earlier (head-of-line blocking).
    Fifo,
    /// Memory-arrival order: earliest-completing entry first. This is
    /// the AMU's native Finished-Queue order and the default.
    ArrivalOrder,
    /// Coalesce up to N completions before resuming anyone, then drain
    /// that whole burst before coalescing again — trading wakeup latency
    /// for scheduler amortization (fewer, denser resume bursts). Falls
    /// back to "all outstanding" when fewer than N requests remain, so
    /// the tail always drains.
    BatchedWakeup(u32),
    /// Latency-aware decoupling: among visible completions, resume the
    /// coroutine whose request was issued earliest (longest-suspended
    /// first), approximating the paper's latency-aware issue order.
    LatencyAware,
}

impl Default for SchedPolicyKind {
    fn default() -> Self {
        SchedPolicyKind::ArrivalOrder
    }
}

/// Default coalescing factor for `batched` when no `:N` is given.
pub const DEFAULT_BATCH: u32 = 4;

impl SchedPolicyKind {
    /// The canonical sweep axis (the acceptance matrix).
    pub const ALL: [SchedPolicyKind; 4] = [
        SchedPolicyKind::Fifo,
        SchedPolicyKind::ArrivalOrder,
        SchedPolicyKind::BatchedWakeup(DEFAULT_BATCH),
        SchedPolicyKind::LatencyAware,
    ];

    /// Display label (CLI, tables, `RunStats::sched_policy`).
    pub fn label(self) -> String {
        match self {
            SchedPolicyKind::Fifo => "fifo".into(),
            SchedPolicyKind::ArrivalOrder => "arrival".into(),
            SchedPolicyKind::BatchedWakeup(n) => format!("batched:{n}"),
            SchedPolicyKind::LatencyAware => "latency".into(),
        }
    }

    /// Parse a CLI/TOML spelling: `fifo`, `arrival` (or `arrival-order`),
    /// `batched` (or `batched:N`), `latency` (or `latency-aware`).
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim().to_ascii_lowercase();
        if let Some(n) = s.strip_prefix("batched:") {
            let n: u32 = match n.parse() {
                Ok(v) if v > 0 => v,
                _ => bail!("batched:N needs a positive integer, got '{n}'"),
            };
            return Ok(SchedPolicyKind::BatchedWakeup(n));
        }
        Ok(match s.as_str() {
            "fifo" | "static" => SchedPolicyKind::Fifo,
            "arrival" | "arrival-order" | "bafin-order" => SchedPolicyKind::ArrivalOrder,
            "batched" | "batched-wakeup" => SchedPolicyKind::BatchedWakeup(DEFAULT_BATCH),
            "latency" | "latency-aware" => SchedPolicyKind::LatencyAware,
            other => return Err(crate::util::keyed::unknown_key::<Self>(other)),
        })
    }

    /// Instantiate the concrete policy.
    pub fn build(self) -> Box<dyn SchedPolicy> {
        match self {
            SchedPolicyKind::Fifo => Box::new(Fifo),
            SchedPolicyKind::ArrivalOrder => Box::new(ArrivalOrder),
            SchedPolicyKind::BatchedWakeup(n) => {
                Box::new(BatchedWakeup { batch: n.max(1) as usize, draining: 0 })
            }
            SchedPolicyKind::LatencyAware => Box::new(LatencyAware),
        }
    }
}

impl crate::util::keyed::Keyed for SchedPolicyKind {
    const AXIS: &'static str = "scheduler policy";
    const EXPECTED: &'static str = "fifo, arrival, batched[:N], latency";

    fn parse_keyed(s: &str) -> Result<Self> {
        SchedPolicyKind::parse(s)
    }

    fn label_keyed(&self) -> String {
        self.label()
    }

    fn all_keyed() -> Vec<Self> {
        SchedPolicyKind::ALL.to_vec()
    }
}

/// Index of the visible entry with the smallest `ready` cycle, first
/// index winning ties — exactly the pre-subsystem Finished-Queue scan,
/// kept as a free function so every arrival-ordered policy shares it.
fn earliest_ready(pending: &[Pending], now: u64) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, e) in pending.iter().enumerate() {
        if e.ready <= now && best.map(|b| e.ready < pending[b].ready).unwrap_or(true) {
            best = Some(i);
        }
    }
    best
}

/// See [`SchedPolicyKind::Fifo`].
#[derive(Debug, Default)]
pub struct Fifo;

impl SchedPolicy for Fifo {
    fn kind(&self) -> SchedPolicyKind {
        SchedPolicyKind::Fifo
    }

    fn pick_next(&mut self, pending: &[Pending], now: u64) -> Option<usize> {
        // Strict suspension order: the minimum-seq entry is the head; if
        // its data has not arrived, nobody resumes (head-of-line block).
        let (i, head) = pending.iter().enumerate().min_by_key(|(_, e)| e.seq)?;
        if head.ready <= now {
            Some(i)
        } else {
            None
        }
    }

    fn btq_guided(&self) -> bool {
        // A software static order is not derivable from Finished-Queue
        // state at fetch, so the BTQ cannot carry it (§IV-A breaks).
        false
    }
}

/// See [`SchedPolicyKind::ArrivalOrder`].
#[derive(Debug, Default)]
pub struct ArrivalOrder;

impl SchedPolicy for ArrivalOrder {
    fn kind(&self) -> SchedPolicyKind {
        SchedPolicyKind::ArrivalOrder
    }

    fn pick_next(&mut self, pending: &[Pending], now: u64) -> Option<usize> {
        earliest_ready(pending, now)
    }
}

/// See [`SchedPolicyKind::BatchedWakeup`]. Two phases: *coalesce* until
/// the visible count reaches the batch threshold, then *drain* that many
/// resumes without re-checking the threshold — otherwise each pick would
/// drop the visible count back below the bar and the policy would
/// degenerate to one resume per new arrival, adding latency with no
/// amortization.
#[derive(Debug)]
pub struct BatchedWakeup {
    batch: usize,
    /// Resumes left in the currently released burst (0 = coalescing).
    draining: usize,
}

impl SchedPolicy for BatchedWakeup {
    fn kind(&self) -> SchedPolicyKind {
        SchedPolicyKind::BatchedWakeup(self.batch as u32)
    }

    fn pick_next(&mut self, pending: &[Pending], now: u64) -> Option<usize> {
        if self.draining == 0 {
            let visible = pending.iter().filter(|e| e.ready <= now).count();
            // When fewer than `batch` requests remain outstanding the
            // batch can never fill; require them all so the tail drains.
            let threshold = self.batch.min(pending.len()).max(1);
            if visible < threshold {
                return None;
            }
            self.draining = visible;
        }
        match earliest_ready(pending, now) {
            Some(i) => {
                self.draining -= 1;
                Some(i)
            }
            None => {
                // A burst can evaporate between polls (bafin polls with
                // the *fetch* cycle, which may precede the poll that
                // released the burst): fall back to coalescing.
                self.draining = 0;
                None
            }
        }
    }
}

/// See [`SchedPolicyKind::LatencyAware`].
#[derive(Debug, Default)]
pub struct LatencyAware;

impl SchedPolicy for LatencyAware {
    fn kind(&self) -> SchedPolicyKind {
        SchedPolicyKind::LatencyAware
    }

    fn pick_next(&mut self, pending: &[Pending], now: u64) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in pending.iter().enumerate() {
            if e.ready > now {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => (e.issue, e.seq) < (pending[b].issue, pending[b].seq),
            };
            if better {
                best = Some(i);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(id: CoroId, ready: u64, issue: u64, seq: u64) -> Pending {
        Pending { id, ready, issue, seq, resume: id as BlockId }
    }

    #[test]
    fn arrival_order_matches_legacy_scan() {
        let mut p = ArrivalOrder;
        let q = [pend(0, 600, 0, 0), pend(1, 300, 10, 1), pend(2, 300, 20, 2)];
        assert_eq!(p.pick_next(&q, 100), None, "nothing visible yet");
        // Ties on ready break to the first index, like the old loop.
        assert_eq!(p.pick_next(&q, 1000), Some(1));
        assert_eq!(p.pick_next(&q, 300), Some(1));
    }

    #[test]
    fn fifo_blocks_on_suspension_head() {
        let mut p = Fifo;
        // Oldest suspension (seq 0) completes LAST: younger ready entries
        // must not overtake it.
        let q = [pend(7, 900, 0, 0), pend(8, 100, 5, 1), pend(9, 200, 6, 2)];
        assert_eq!(p.pick_next(&q, 500), None, "head-of-line block");
        assert_eq!(p.pick_next(&q, 900), Some(0));
        // Once the head drains, the next seq takes over.
        let q2 = [pend(8, 100, 5, 1), pend(9, 200, 6, 2)];
        assert_eq!(p.pick_next(&q2, 500), Some(0));
        assert!(!p.btq_guided(), "software static order loses BTQ coverage");
    }

    #[test]
    fn batched_wakeup_coalesces_then_drains_the_burst() {
        let mut p = BatchedWakeup { batch: 3, draining: 0 };
        let q = [pend(0, 100, 0, 0), pend(1, 200, 0, 1), pend(2, 900, 0, 2), pend(3, 950, 0, 3)];
        assert_eq!(p.pick_next(&q, 250), None, "2 visible < batch of 3");
        assert_eq!(p.pick_next(&q, 900), Some(0), "3 visible releases a burst");
        // The burst keeps draining even though the visible count is now
        // back under the threshold — no one-resume-per-arrival collapse.
        let q2 = [pend(1, 200, 0, 1), pend(2, 900, 0, 2), pend(3, 950, 0, 3)];
        assert_eq!(p.pick_next(&q2, 900), Some(0), "drain ignores the threshold");
        let q3 = [pend(2, 900, 0, 2), pend(3, 950, 0, 3)];
        assert_eq!(p.pick_next(&q3, 900), Some(0), "burst of 3 completes");
        // Burst exhausted: back to coalescing (1 outstanding -> need 1).
        let q4 = [pend(3, 950, 0, 3)];
        assert_eq!(p.pick_next(&q4, 940), None, "coalescing again after the burst");
        assert_eq!(p.pick_next(&q4, 950), Some(0));
    }

    #[test]
    fn batched_wakeup_tail_requires_all_outstanding() {
        // Fewer outstanding than the batch -> require all of them.
        let mut p = BatchedWakeup { batch: 3, draining: 0 };
        let tail = [pend(5, 400, 0, 4), pend(6, 800, 0, 5)];
        assert_eq!(p.pick_next(&tail, 500), None, "waits for the whole tail");
        assert_eq!(p.pick_next(&tail, 800), Some(0));
        let last = [pend(6, 800, 0, 5)];
        assert_eq!(p.pick_next(&last, 800), Some(0), "single leftover drains");
    }

    #[test]
    fn latency_aware_prefers_earliest_issue() {
        let mut p = LatencyAware;
        // id 1 arrived first but was issued later; id 0 suspended longest.
        let q = [pend(0, 500, 10, 0), pend(1, 300, 40, 1)];
        assert_eq!(p.pick_next(&q, 400), Some(1), "only visible entry wins");
        assert_eq!(p.pick_next(&q, 500), Some(0), "earliest issue wins once visible");
        // Issue ties break by seq.
        let t = [pend(2, 100, 5, 3), pend(3, 100, 5, 2)];
        assert_eq!(p.pick_next(&t, 100), Some(1));
    }

    #[test]
    fn kind_roundtrip_and_labels() {
        for k in SchedPolicyKind::ALL {
            assert_eq!(k.build().kind(), k, "build/kind roundtrip for {k:?}");
            assert_eq!(SchedPolicyKind::parse(&k.label()).unwrap(), k, "label parses back");
        }
        assert_eq!(SchedPolicyKind::parse("batched:8").unwrap(), SchedPolicyKind::BatchedWakeup(8));
        assert_eq!(SchedPolicyKind::parse("arrival-order").unwrap(), SchedPolicyKind::ArrivalOrder);
        assert_eq!(SchedPolicyKind::parse("latency-aware").unwrap(), SchedPolicyKind::LatencyAware);
        assert!(SchedPolicyKind::parse("round-robin").is_err());
        assert!(SchedPolicyKind::parse("batched:0").is_err());
        assert_eq!(SchedPolicyKind::default(), SchedPolicyKind::ArrivalOrder);
    }

    #[test]
    fn guidance_is_a_policy_property() {
        for k in SchedPolicyKind::ALL {
            let guided = k.build().btq_guided();
            assert_eq!(guided, k != SchedPolicyKind::Fifo, "{k:?}");
        }
    }
}
