//! Flat simulated memory: named regions mapped into a 64-bit address
//! space, each tagged Local / Remote / SPM. The benchmark harness
//! allocates datasets into regions; the interpreter and the timing model
//! translate addresses through the region table.

use crate::ir::{AddrSpace, Width};
use anyhow::{bail, Result};

/// Region base addresses by space (regions of one space are packed
/// consecutively above these bases, 4 KB aligned).
pub const LOCAL_BASE: u64 = 0x1000_0000;
pub const SPM_BASE: u64 = 0x4000_0000;
pub const REMOTE_BASE: u64 = 0x8000_0000;

#[derive(Debug)]
pub struct Region {
    pub name: String,
    pub base: u64,
    pub space: AddrSpace,
    pub data: Vec<u8>,
}

impl Region {
    pub fn end(&self) -> u64 {
        self.base + self.data.len() as u64
    }
}

#[derive(Debug, Default)]
pub struct MemImage {
    pub regions: Vec<Region>,
    next_local: u64,
    next_spm: u64,
    next_remote: u64,
    /// Last region hit (locality cache for translation).
    last: std::cell::Cell<usize>,
}

fn align4k(x: u64) -> u64 {
    (x + 4095) & !4095
}

impl MemImage {
    pub fn new() -> Self {
        Self {
            regions: Vec::new(),
            next_local: LOCAL_BASE,
            next_spm: SPM_BASE,
            next_remote: REMOTE_BASE,
            last: std::cell::Cell::new(0),
        }
    }

    /// Allocate a zeroed region; returns its base address.
    pub fn alloc(&mut self, name: &str, space: AddrSpace, bytes: u64) -> u64 {
        let base = match space {
            AddrSpace::Local => &mut self.next_local,
            AddrSpace::Spm => &mut self.next_spm,
            AddrSpace::Remote => &mut self.next_remote,
        };
        let addr = *base;
        *base = align4k(*base + bytes.max(1));
        self.regions.push(Region { name: name.into(), base: addr, space, data: vec![0u8; bytes as usize] });
        addr
    }

    #[inline]
    fn region_idx(&self, addr: u64) -> Option<usize> {
        let li = self.last.get();
        if let Some(r) = self.regions.get(li) {
            if addr >= r.base && addr < r.end() {
                return Some(li);
            }
        }
        for (i, r) in self.regions.iter().enumerate() {
            if addr >= r.base && addr < r.end() {
                self.last.set(i);
                return Some(i);
            }
        }
        None
    }

    /// Address space an address belongs to (for the timing model).
    #[inline]
    pub fn space_of(&self, addr: u64) -> Option<AddrSpace> {
        self.region_idx(addr).map(|i| self.regions[i].space)
    }

    pub fn read(&self, addr: u64, width: Width) -> Result<i64> {
        let Some(i) = self.region_idx(addr) else {
            bail!("read from unmapped address {addr:#x}");
        };
        let r = &self.regions[i];
        let off = (addr - r.base) as usize;
        let n = width.bytes() as usize;
        if off + n > r.data.len() {
            bail!("read past end of region {} at {addr:#x}", r.name);
        }
        let mut buf = [0u8; 8];
        buf[..n].copy_from_slice(&r.data[off..off + n]);
        let raw = u64::from_le_bytes(buf);
        // Sign-extend sub-word reads (RV64 LW/LH/LB semantics).
        Ok(match width {
            Width::W1 => raw as u8 as i8 as i64,
            Width::W2 => raw as u16 as i16 as i64,
            Width::W4 => raw as u32 as i32 as i64,
            Width::W8 => raw as i64,
        })
    }

    pub fn write(&mut self, addr: u64, width: Width, val: i64) -> Result<()> {
        let Some(i) = self.region_idx(addr) else {
            bail!("write to unmapped address {addr:#x}");
        };
        let r = &mut self.regions[i];
        let off = (addr - r.base) as usize;
        let n = width.bytes() as usize;
        if off + n > r.data.len() {
            bail!("write past end of region {} at {addr:#x}", r.name);
        }
        r.data[off..off + n].copy_from_slice(&(val as u64).to_le_bytes()[..n]);
        Ok(())
    }

    /// Bulk copy (AMU aload/astore transfers). Byte-exact.
    pub fn copy(&mut self, src: u64, dst: u64, bytes: u64) -> Result<()> {
        // Straightforward byte loop through the region API would be slow;
        // resolve both regions once.
        let Some(si) = self.region_idx(src) else { bail!("copy src unmapped {src:#x}") };
        let Some(di) = self.region_idx(dst) else { bail!("copy dst unmapped {dst:#x}") };
        let so = (src - self.regions[si].base) as usize;
        let do_ = (dst - self.regions[di].base) as usize;
        let n = bytes as usize;
        if so + n > self.regions[si].data.len() || do_ + n > self.regions[di].data.len() {
            bail!("copy out of bounds ({src:#x} -> {dst:#x}, {bytes}B)");
        }
        if si == di {
            self.regions[si].data.copy_within(so..so + n, do_);
        } else if si < di {
            let (l, r) = self.regions.split_at_mut(di);
            r[0].data[do_..do_ + n].copy_from_slice(&l[si].data[so..so + n]);
        } else {
            let (l, r) = self.regions.split_at_mut(si);
            l[di].data[do_..do_ + n].copy_from_slice(&r[0].data[so..so + n]);
        }
        Ok(())
    }

    /// Allocate a region and bulk-initialize it from i64 words (fast path
    /// for dataset construction; per-word `write` costs a region lookup).
    pub fn alloc_init_i64(&mut self, name: &str, space: AddrSpace, data: &[i64]) -> u64 {
        let base = self.alloc(name, space, (data.len() as u64) * 8);
        let r = self.regions.last_mut().expect("just allocated");
        for (chunk, v) in r.data.chunks_exact_mut(8).zip(data.iter()) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        base
    }

    /// Read a whole region back as i64 words.
    pub fn region_as_i64(&self, name: &str) -> Option<Vec<i64>> {
        let r = self.region(name)?;
        Some(
            r.data
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        )
    }

    /// Fill a region's bytes directly (dataset initialization).
    pub fn region_mut(&mut self, name: &str) -> Option<&mut Region> {
        self.regions.iter_mut().find(|r| r.name == name)
    }

    pub fn region(&self, name: &str) -> Option<&Region> {
        self.regions.iter().find(|r| r.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_read_write_roundtrip() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Remote, 64);
        assert!(a >= REMOTE_BASE);
        m.write(a + 8, Width::W8, -42).unwrap();
        assert_eq!(m.read(a + 8, Width::W8).unwrap(), -42);
        assert_eq!(m.space_of(a), Some(AddrSpace::Remote));
        assert_eq!(m.space_of(0xdead), None);
    }

    #[test]
    fn sign_extension() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Local, 16);
        m.write(a, Width::W4, -1).unwrap();
        assert_eq!(m.read(a, Width::W4).unwrap(), -1);
        m.write(a, Width::W1, 0xFF).unwrap();
        assert_eq!(m.read(a, Width::W1).unwrap(), -1);
    }

    #[test]
    fn oob_faults() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Local, 8);
        assert!(m.read(a + 8, Width::W8).is_err());
        assert!(m.write(a + 4, Width::W8, 0).is_err());
    }

    #[test]
    fn regions_do_not_overlap() {
        let mut m = MemImage::new();
        let a = m.alloc("a", AddrSpace::Remote, 5000);
        let b = m.alloc("b", AddrSpace::Remote, 100);
        assert!(b >= a + 5000);
        m.write(b, Width::W8, 7).unwrap();
        assert_eq!(m.read(a, Width::W8).unwrap(), 0);
    }

    #[test]
    fn copy_between_spaces() {
        let mut m = MemImage::new();
        let r = m.alloc("rem", AddrSpace::Remote, 128);
        let s = m.alloc("spm", AddrSpace::Spm, 128);
        for k in 0..16 {
            m.write(r + k * 8, Width::W8, k as i64 * 3).unwrap();
        }
        m.copy(r, s, 128).unwrap();
        assert_eq!(m.read(s + 40, Width::W8).unwrap(), 15);
    }
}
