//! Flat simulated memory: named regions mapped into a 64-bit address
//! space, each tagged Local / Remote / SPM. The benchmark harness
//! allocates datasets into regions; the interpreter and the timing model
//! translate addresses through the region table.
//!
//! Translation is O(1): the three address spaces live in disjoint base
//! bands (`LOCAL_BASE` / `SPM_BASE` / `REMOTE_BASE`), so a single band
//! compare recovers the space, and a per-space index (direct when the
//! space holds one region — the common case — else a binary search over
//! the sorted bases) recovers the region. [`MemImage::resolve`] performs
//! the whole translation in one step; the fused `*_ws` accessors hand the
//! interpreter the value *and* the space without a second lookup.
//!
//! Region bytes are copy-on-write (`Arc`-backed): [`MemImage::snapshot`]
//! is O(#regions), and a restored image only pays for the regions a run
//! actually writes. `Engine::sweep` leans on this to build each dataset
//! once and restore it per (latency, seed) point.

use crate::ir::{AddrSpace, Width};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Region base addresses by space (regions of one space are packed
/// consecutively above these bases, 4 KB aligned).
pub const LOCAL_BASE: u64 = 0x1000_0000;
pub const SPM_BASE: u64 = 0x4000_0000;
pub const REMOTE_BASE: u64 = 0x8000_0000;

#[derive(Debug, Clone)]
pub struct Region {
    pub name: String,
    pub base: u64,
    pub space: AddrSpace,
    /// Copy-on-write bytes: snapshots share the allocation until either
    /// side writes (mutate through [`Region::bytes_mut`]).
    pub data: Arc<Vec<u8>>,
}

impl Region {
    pub fn end(&self) -> u64 {
        self.base + self.data.len() as u64
    }

    /// Mutable view of the region bytes, unsharing from snapshots first.
    pub fn bytes_mut(&mut self) -> &mut Vec<u8> {
        Arc::make_mut(&mut self.data)
    }
}

#[inline]
fn space_slot(space: AddrSpace) -> usize {
    match space {
        AddrSpace::Local => 0,
        AddrSpace::Spm => 1,
        AddrSpace::Remote => 2,
    }
}

/// Sign-extend a little-endian raw load to i64 (RV64 LW/LH/LB semantics).
#[inline(always)]
fn sign_extend(raw: u64, width: Width) -> i64 {
    match width {
        Width::W1 => raw as u8 as i8 as i64,
        Width::W2 => raw as u16 as i16 as i64,
        Width::W4 => raw as u32 as i32 as i64,
        Width::W8 => raw as i64,
    }
}

/// The space whose base band contains `addr` (bands are disjoint by
/// construction, so this needs no table walk).
#[inline]
fn band_of(addr: u64) -> Option<AddrSpace> {
    if addr >= REMOTE_BASE {
        Some(AddrSpace::Remote)
    } else if addr >= SPM_BASE {
        Some(AddrSpace::Spm)
    } else if addr >= LOCAL_BASE {
        Some(AddrSpace::Local)
    } else {
        None
    }
}

#[derive(Debug, Clone)]
pub struct MemImage {
    pub regions: Vec<Region>,
    next_local: u64,
    next_spm: u64,
    next_remote: u64,
    /// Region indices per space, in base order (alloc bases only grow, so
    /// append order is sorted order).
    by_space: [Vec<u32>; 3],
    /// name -> region index (first allocation wins, matching the old
    /// linear-scan semantics for duplicate names).
    by_name: HashMap<String, u32>,
}

impl Default for MemImage {
    fn default() -> Self {
        Self::new()
    }
}

fn align4k(x: u64) -> u64 {
    (x + 4095) & !4095
}

impl MemImage {
    pub fn new() -> Self {
        Self {
            regions: Vec::new(),
            next_local: LOCAL_BASE,
            next_spm: SPM_BASE,
            next_remote: REMOTE_BASE,
            by_space: [Vec::new(), Vec::new(), Vec::new()],
            by_name: HashMap::new(),
        }
    }

    /// Cheap copy-on-write snapshot: O(#regions), sharing every region's
    /// bytes until either image writes them. Restoring a dataset for the
    /// next sweep point is `template.snapshot()` — no regeneration.
    pub fn snapshot(&self) -> MemImage {
        self.clone()
    }

    /// Allocate a zeroed region; returns its base address.
    ///
    /// Panics if the space's allocations would overflow its base band —
    /// band-derived translation ([`MemImage::resolve`]) depends on every
    /// region living inside its space's band, so crossing it must be a
    /// loud failure at alloc time, not silent misrouting later.
    pub fn alloc(&mut self, name: &str, space: AddrSpace, bytes: u64) -> u64 {
        let (base, limit) = match space {
            AddrSpace::Local => (&mut self.next_local, SPM_BASE),
            AddrSpace::Spm => (&mut self.next_spm, REMOTE_BASE),
            AddrSpace::Remote => (&mut self.next_remote, u64::MAX),
        };
        let addr = *base;
        *base = align4k(*base + bytes.max(1));
        assert!(
            *base <= limit,
            "region {name:?} overflows the {space:?} address band ({bytes} bytes at {addr:#x})"
        );
        let idx = self.regions.len() as u32;
        self.regions.push(Region {
            name: name.into(),
            base: addr,
            space,
            data: Arc::new(vec![0u8; bytes as usize]),
        });
        self.by_space[space_slot(space)].push(idx);
        self.by_name.entry(name.into()).or_insert(idx);
        addr
    }

    /// O(1) translation: region index, byte offset within it, and the
    /// address space — all from one lookup. The band compare picks the
    /// space; within a space, a single region (the common case) resolves
    /// directly and multiple regions binary-search their sorted bases.
    #[inline]
    pub fn resolve(&self, addr: u64) -> Option<(usize, usize, AddrSpace)> {
        let space = band_of(addr)?;
        let list = &self.by_space[space_slot(space)];
        let ri = match list.len() {
            0 => return None,
            1 => list[0] as usize,
            _ => {
                // Last region whose base is <= addr.
                let pos = list.partition_point(|&i| self.regions[i as usize].base <= addr);
                if pos == 0 {
                    return None;
                }
                list[pos - 1] as usize
            }
        };
        let r = &self.regions[ri];
        if addr < r.base || addr >= r.end() {
            return None;
        }
        Some((ri, (addr - r.base) as usize, space))
    }

    /// Address space an address belongs to (for the timing model).
    #[inline]
    pub fn space_of(&self, addr: u64) -> Option<AddrSpace> {
        self.resolve(addr).map(|(_, _, s)| s)
    }

    pub fn read(&self, addr: u64, width: Width) -> Result<i64> {
        self.read_ws(addr, width).map(|(v, _)| v)
    }

    /// Fused read: value plus the address space, one translation.
    ///
    /// `W8` (the dominant access width — pointers, i64 datasets) takes a
    /// fast lane that loads the eight bytes directly instead of staging
    /// them through the zeroed assembly buffer + sign-extension match of
    /// the generic path.
    #[inline]
    pub fn read_ws(&self, addr: u64, width: Width) -> Result<(i64, AddrSpace)> {
        let Some((i, off, space)) = self.resolve(addr) else {
            bail!("read from unmapped address {addr:#x}");
        };
        let r = &self.regions[i];
        if width == Width::W8 {
            if let Some(bytes) = r.data.get(off..off + 8) {
                return Ok((i64::from_le_bytes(bytes.try_into().unwrap()), space));
            }
            bail!("read past end of region {} at {addr:#x}", r.name);
        }
        let n = width.bytes() as usize;
        if off + n > r.data.len() {
            bail!("read past end of region {} at {addr:#x}", r.name);
        }
        let mut buf = [0u8; 8];
        buf[..n].copy_from_slice(&r.data[off..off + n]);
        Ok((sign_extend(u64::from_le_bytes(buf), width), space))
    }

    /// Fused read-modify-write: one translation covers both the load and
    /// the store of an AtomicRmw. Returns the *old* value plus the space.
    /// Error messages match a plain `read` so the decoded and reference
    /// interpreters fail identically.
    #[inline]
    pub fn rmw_ws(
        &mut self,
        addr: u64,
        width: Width,
        f: impl FnOnce(i64) -> i64,
    ) -> Result<(i64, AddrSpace)> {
        let Some((i, off, space)) = self.resolve(addr) else {
            bail!("read from unmapped address {addr:#x}");
        };
        let r = &mut self.regions[i];
        let n = width.bytes() as usize;
        if off + n > r.data.len() {
            bail!("read past end of region {} at {addr:#x}", r.name);
        }
        let mut buf = [0u8; 8];
        buf[..n].copy_from_slice(&r.data[off..off + n]);
        let old = sign_extend(u64::from_le_bytes(buf), width);
        let new = f(old);
        r.bytes_mut()[off..off + n].copy_from_slice(&(new as u64).to_le_bytes()[..n]);
        Ok((old, space))
    }

    pub fn write(&mut self, addr: u64, width: Width, val: i64) -> Result<()> {
        self.write_ws(addr, width, val).map(|_| ())
    }

    /// Fused write: performs the store and returns the address space.
    /// `W8` takes the same fast lane as [`MemImage::read_ws`]: a direct
    /// full-word store, no truncating slice-of-bytes assembly.
    #[inline]
    pub fn write_ws(&mut self, addr: u64, width: Width, val: i64) -> Result<AddrSpace> {
        let Some((i, off, space)) = self.resolve(addr) else {
            bail!("write to unmapped address {addr:#x}");
        };
        let r = &mut self.regions[i];
        if width == Width::W8 {
            if off + 8 > r.data.len() {
                bail!("write past end of region {} at {addr:#x}", r.name);
            }
            r.bytes_mut()[off..off + 8].copy_from_slice(&val.to_le_bytes());
            return Ok(space);
        }
        let n = width.bytes() as usize;
        if off + n > r.data.len() {
            bail!("write past end of region {} at {addr:#x}", r.name);
        }
        r.bytes_mut()[off..off + n].copy_from_slice(&(val as u64).to_le_bytes()[..n]);
        Ok(space)
    }

    /// Bulk copy (AMU aload/astore transfers). Byte-exact.
    pub fn copy(&mut self, src: u64, dst: u64, bytes: u64) -> Result<()> {
        self.copy_ws(src, dst, bytes).map(|_| ())
    }

    /// Fused bulk copy: returns the (source, destination) address spaces.
    pub fn copy_ws(&mut self, src: u64, dst: u64, bytes: u64) -> Result<(AddrSpace, AddrSpace)> {
        let Some((si, so, ss)) = self.resolve(src) else { bail!("copy src unmapped {src:#x}") };
        let Some((di, do_, ds)) = self.resolve(dst) else { bail!("copy dst unmapped {dst:#x}") };
        let n = bytes as usize;
        if so + n > self.regions[si].data.len() || do_ + n > self.regions[di].data.len() {
            bail!("copy out of bounds ({src:#x} -> {dst:#x}, {bytes}B)");
        }
        if si == di {
            self.regions[si].bytes_mut().copy_within(so..so + n, do_);
        } else {
            // Arc-clone the source bytes (pointer copy) so the borrow on
            // the destination region is unentangled from the source's.
            let src_data = self.regions[si].data.clone();
            self.regions[di].bytes_mut()[do_..do_ + n].copy_from_slice(&src_data[so..so + n]);
        }
        Ok((ss, ds))
    }

    /// Allocate a region and bulk-initialize it from i64 words (fast path
    /// for dataset construction; per-word `write` costs a region lookup).
    pub fn alloc_init_i64(&mut self, name: &str, space: AddrSpace, data: &[i64]) -> u64 {
        let base = self.alloc(name, space, (data.len() as u64) * 8);
        let r = self.regions.last_mut().expect("just allocated");
        for (chunk, v) in r.bytes_mut().chunks_exact_mut(8).zip(data.iter()) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        base
    }

    /// Read a whole region back as i64 words.
    pub fn region_as_i64(&self, name: &str) -> Option<Vec<i64>> {
        let r = self.region(name)?;
        Some(
            r.data
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
        )
    }

    /// Fill a region's bytes directly (dataset initialization).
    pub fn region_mut(&mut self, name: &str) -> Option<&mut Region> {
        let i = *self.by_name.get(name)?;
        self.regions.get_mut(i as usize)
    }

    pub fn region(&self, name: &str) -> Option<&Region> {
        let i = *self.by_name.get(name)?;
        self.regions.get(i as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_read_write_roundtrip() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Remote, 64);
        assert!(a >= REMOTE_BASE);
        m.write(a + 8, Width::W8, -42).unwrap();
        assert_eq!(m.read(a + 8, Width::W8).unwrap(), -42);
        assert_eq!(m.space_of(a), Some(AddrSpace::Remote));
        assert_eq!(m.space_of(0xdead), None);
    }

    #[test]
    fn sign_extension() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Local, 16);
        m.write(a, Width::W4, -1).unwrap();
        assert_eq!(m.read(a, Width::W4).unwrap(), -1);
        m.write(a, Width::W1, 0xFF).unwrap();
        assert_eq!(m.read(a, Width::W1).unwrap(), -1);
    }

    #[test]
    fn w8_fast_lane_matches_generic_and_faults() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Remote, 16);
        m.write(a, Width::W8, -12345).unwrap();
        assert_eq!(m.read(a, Width::W8).unwrap(), -12345);
        // Unaligned W8 within bounds still works (byte-addressed image).
        m.write(a + 3, Width::W8, 0x0102030405060708).unwrap();
        assert_eq!(m.read(a + 3, Width::W8).unwrap(), 0x0102030405060708);
        // One byte short of the region end faults, same as the generic path.
        assert!(m.read(a + 9, Width::W8).is_err());
        assert!(m.write(a + 9, Width::W8, 0).is_err());
    }

    #[test]
    fn oob_faults() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Local, 8);
        assert!(m.read(a + 8, Width::W8).is_err());
        assert!(m.write(a + 4, Width::W8, 0).is_err());
    }

    #[test]
    fn regions_do_not_overlap() {
        let mut m = MemImage::new();
        let a = m.alloc("a", AddrSpace::Remote, 5000);
        let b = m.alloc("b", AddrSpace::Remote, 100);
        assert!(b >= a + 5000);
        m.write(b, Width::W8, 7).unwrap();
        assert_eq!(m.read(a, Width::W8).unwrap(), 0);
    }

    #[test]
    fn copy_between_spaces() {
        let mut m = MemImage::new();
        let r = m.alloc("rem", AddrSpace::Remote, 128);
        let s = m.alloc("spm", AddrSpace::Spm, 128);
        for k in 0..16 {
            m.write(r + k * 8, Width::W8, k as i64 * 3).unwrap();
        }
        m.copy(r, s, 128).unwrap();
        assert_eq!(m.read(s + 40, Width::W8).unwrap(), 15);
    }

    #[test]
    fn resolve_is_fused_and_band_accurate() {
        let mut m = MemImage::new();
        let l = m.alloc("l", AddrSpace::Local, 64);
        let s = m.alloc("s", AddrSpace::Spm, 64);
        let r1 = m.alloc("r1", AddrSpace::Remote, 100);
        let r2 = m.alloc("r2", AddrSpace::Remote, 64);
        let r3 = m.alloc("r3", AddrSpace::Remote, 64);
        for (addr, want) in [
            (l, AddrSpace::Local),
            (s + 63, AddrSpace::Spm),
            (r1 + 99, AddrSpace::Remote),
            (r2 + 8, AddrSpace::Remote),
            (r3, AddrSpace::Remote),
        ] {
            let (ri, off, space) = m.resolve(addr).unwrap();
            assert_eq!(space, want);
            assert_eq!(m.regions[ri].base + off as u64, addr);
        }
        // Gaps between regions (4 KB alignment slack) are unmapped.
        assert!(m.resolve(r1 + 100).is_none(), "alignment slack must not resolve");
        assert!(m.resolve(LOCAL_BASE - 1).is_none());
        assert!(m.resolve(0).is_none());
        m.write(r2, Width::W8, 9).unwrap();
        assert_eq!(m.read_ws(r2, Width::W8).unwrap(), (9, AddrSpace::Remote));
    }

    #[test]
    fn rmw_is_one_lookup_and_matches_read_write() {
        let mut m = MemImage::new();
        let a = m.alloc("t", AddrSpace::Remote, 16);
        m.write(a, Width::W8, 40).unwrap();
        let (old, space) = m.rmw_ws(a, Width::W8, |v| v + 2).unwrap();
        assert_eq!((old, space), (40, AddrSpace::Remote));
        assert_eq!(m.read(a, Width::W8).unwrap(), 42);
        // Sub-word: sign-extended old value, truncated store.
        m.write(a, Width::W4, -5).unwrap();
        let (old4, _) = m.rmw_ws(a, Width::W4, |v| v - 1).unwrap();
        assert_eq!(old4, -5);
        assert_eq!(m.read(a, Width::W4).unwrap(), -6);
        // Errors match plain reads.
        assert!(m.rmw_ws(0xdead, Width::W8, |v| v).is_err());
        assert!(m.rmw_ws(a + 12, Width::W8, |v| v).is_err());
    }

    #[test]
    fn name_index_matches_first_allocation() {
        let mut m = MemImage::new();
        let a = m.alloc("x", AddrSpace::Remote, 32);
        let _b = m.alloc("x", AddrSpace::Remote, 32); // duplicate name
        assert_eq!(m.region("x").unwrap().base, a, "first allocation wins");
        assert!(m.region("nope").is_none());
        m.region_mut("x").unwrap().bytes_mut()[0] = 7;
        assert_eq!(m.read(a, Width::W1).unwrap(), 7);
        assert_eq!(m.region_as_i64("x").unwrap()[0], 7);
    }

    #[test]
    fn snapshot_is_cow() {
        let mut m = MemImage::new();
        let a = m.alloc("a", AddrSpace::Remote, 64);
        let b = m.alloc("b", AddrSpace::Remote, 64);
        m.write(a, Width::W8, 11).unwrap();
        m.write(b, Width::W8, 22).unwrap();
        let snap = m.snapshot();
        // Bytes shared until a write.
        assert!(Arc::ptr_eq(&m.regions[0].data, &snap.regions[0].data));
        m.write(a, Width::W8, 99).unwrap();
        assert_eq!(m.read(a, Width::W8).unwrap(), 99);
        assert_eq!(snap.read(a, Width::W8).unwrap(), 11, "snapshot unaffected by write");
        assert!(Arc::ptr_eq(&m.regions[1].data, &snap.regions[1].data), "untouched region still shared");
        // Restoring from the snapshot reproduces the original bytes and
        // layout (bases, cursors) exactly.
        let restored = snap.snapshot();
        assert_eq!(restored.read(a, Width::W8).unwrap(), 11);
        assert_eq!(restored.read(b, Width::W8).unwrap(), 22);
        let mut r2 = restored;
        let c = r2.alloc("c", AddrSpace::Remote, 8);
        let mut m2 = m.snapshot();
        assert_eq!(c, m2.alloc("c", AddrSpace::Remote, 8), "alloc cursors survive snapshot");
    }
}
