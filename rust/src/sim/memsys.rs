//! The memory system: L1D/L2/L3 + local DRAM + emulated far memory.
//!
//! The far tier is served by a pluggable [`FabricModel`] (`sim::fabric`):
//! the default [`FabricKind::FixedDelay`] reproduces the paper's FPGA
//! evaluation rig (Fig. 10) — a fixed-latency delayer plus a programmable
//! bandwidth regulator — bit-for-bit at every exactly-representable
//! bandwidth (see DESIGN.md §9 for the fixed-point rounding caveat at
//! inexact ones), while the `queued`, `dist` and
//! `tiered` backends open the congestion / variance / tiering scenario
//! axes of real disaggregated fabrics. The SPM region (AMU) is served at
//! L2 latency without tags or MSHRs. AMU transfers bypass the cache
//! hierarchy and MSHRs entirely — the architectural reason CoroAMU's MLP
//! scales past the MSHR-bound prefetching of Fig. 16.

use super::cache::{BestOffset, Cache, LINE_BYTES, LINE_SHIFT};
use super::fabric::{FabricKind, SharedFabric, FP_SHIFT};
use super::stats::IntervalUnion;
use crate::config::SimConfig;
use crate::ir::AddrSpace;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    Load,
    Store,
    Prefetch,
    Atomic,
}

/// A local-DRAM channel: fixed pipe latency + token-bucket bandwidth.
/// (The far tier uses a [`FabricModel`]; `FixedDelay` is this same
/// arithmetic.) Serialization is accounted in integer fixed-point
/// (`cycles << FP_SHIFT`), so long runs are bit-identical across
/// platforms — no accumulated `f64` drift.
#[derive(Debug)]
pub struct Channel {
    latency: u64,
    /// Fixed-point wire occupancy per 64B line (bandwidth regulator).
    fp_per_line: u64,
    /// Fixed-point next-free cycle of the serialization stage.
    next_free_fp: u64,
    pub lines_transferred: u64,
    /// Online (issue, completion) union/integral for MLP accounting —
    /// O(1) memory, no per-request allocation (see [`IntervalUnion`]).
    union: IntervalUnion,
    record: bool,
}

impl Channel {
    /// `window` sizes the MLP accumulator's reorder tolerance; pass the
    /// maximum number of simultaneously in-flight requests this channel
    /// can see (AMU request table + MSHRs + margin for the far tier).
    pub fn new(latency: u64, bytes_per_cycle: f64, record: bool, window: usize) -> Self {
        Channel {
            latency,
            fp_per_line: (((LINE_BYTES << FP_SHIFT) as f64) / bytes_per_cycle.max(0.01)).round()
                as u64,
            next_free_fp: 0,
            lines_transferred: 0,
            union: IntervalUnion::with_window(window),
            record,
        }
    }

    /// Issue a request of `lines` cache lines at cycle `t`; returns the
    /// completion cycle.
    pub fn request(&mut self, t: u64, lines: u64) -> u64 {
        let start_fp = (t << FP_SHIFT).max(self.next_free_fp);
        let end_fp = start_fp + self.fp_per_line * lines;
        self.next_free_fp = end_fp;
        self.lines_transferred += lines;
        let completion = (end_fp >> FP_SHIFT) + self.latency;
        if self.record {
            self.union.push(t, completion);
        }
        completion
    }

    /// Average in-flight requests over the busy period, and the busy
    /// fraction of `total_cycles` (Fig. 16's MLP metric). Reads the
    /// accumulator — O(reorder window), independent of request count.
    pub fn mlp(&self, total_cycles: u64) -> (f64, f64) {
        if self.union.count() == 0 || total_cycles == 0 {
            return (0.0, 0.0);
        }
        let busy = self.union.busy();
        (
            self.union.integral() as f64 / busy.max(1) as f64,
            busy as f64 / total_cycles as f64,
        )
    }
}

#[derive(Debug)]
pub struct MemSys {
    pub l1: Cache,
    pub l2: Cache,
    pub l3: Cache,
    bop: Option<BestOffset>,
    pub local: Channel,
    pub far: SharedFabric,
    spm_latency: u64,
}

impl MemSys {
    pub fn new(cfg: &SimConfig) -> Self {
        // `build_far` wraps the selected backend in the fault-injection
        // decorator exactly when `[mem.fabric.faults]` enables a fault
        // class — faults-off runs get the bare backend, so they stay
        // bit-identical to pre-fault builds by construction. The
        // timeout/retry/backoff/slow-path resilience loop lives inside
        // the decorator, so every far request this memory system (and
        // the AMU behind it) issues still completes at a finite cycle.
        let far = SharedFabric::new(super::faults::build_far(cfg, Self::far_window(cfg)));
        Self::with_far(cfg, far)
    }

    /// A memory system whose far tier is an externally owned fabric
    /// handle — how `sim::cluster` gives every core a private cache
    /// hierarchy and local channel in front of ONE shared far pool. The
    /// handle's requester id tags this core's traffic.
    pub fn with_far(cfg: &SimConfig, far: SharedFabric) -> Self {
        MemSys {
            l1: Cache::new(&cfg.l1d),
            l2: Cache::new(&cfg.l2),
            l3: Cache::new(&cfg.l3),
            bop: cfg.l2_bop.then(BestOffset::new),
            local: Channel::new(cfg.local_latency_cycles(), cfg.mem.local_bw_bytes_per_cycle, false, 1),
            far,
            spm_latency: cfg.l2.latency_cycles,
        }
    }

    /// The far fabric's reorder window must cover every request that
    /// can be in flight at once: AMU decoupled transfers (bounded by
    /// the Request Table, they bypass the caches entirely), demand
    /// fills (bounded by the L3 MSHRs), and BOP prefetch fills (which
    /// hold only an L2 MSHR on their way down), with slack for the
    /// ROB-induced issue-time skew of demand misses. (Cluster runs
    /// multiply this by the core count — N request tables can be in
    /// flight against the one shared fabric.)
    pub fn far_window(cfg: &SimConfig) -> usize {
        cfg.amu.request_table + cfg.l3.mshrs + cfg.l2.mshrs + 64
    }

    /// Which fabric serves the far tier (labels / reports).
    pub fn fabric_kind(&self) -> FabricKind {
        self.far.kind()
    }

    /// A demand/prefetch access through the cache hierarchy. Returns the
    /// data-ready cycle at the core.
    pub fn access(&mut self, addr: u64, space: AddrSpace, kind: AccessKind, t: u64) -> u64 {
        if space == AddrSpace::Spm {
            // SPM lives in the L2 array: fixed latency, no tags, no MSHRs.
            return t + self.spm_latency;
        }
        let line = addr >> LINE_SHIFT;
        if kind == AccessKind::Prefetch {
            // Software prefetch fills L2 (prefetcht1 semantics — what
            // AMAC/Cimple-style coroutine runtimes issue): it bypasses the
            // scarce L1 fill buffers, so prefetch MLP is bounded by the L2
            // MSHRs and the coroutine count rather than the ~10-16 L1
            // MSHRs that cap demand-miss overlap (§II-B / Fig 16).
            return self.prefetch_l2(line, space, t);
        }
        // L1
        if let Some(ready) = self.l1.probe(line, t) {
            return ready;
        }
        let t1 = self.l1.mshr_acquire(t);
        let t_l2 = t1 + self.l1.latency();
        // L2
        if let Some(ready) = self.l2.probe(line, t_l2) {
            self.l1.install(line, ready);
            self.l1.mshr_hold(ready);
            return ready;
        }
        // BOP observes L2 misses and prefetches into L2/L3.
        if let Some(off) = self.bop.as_mut().and_then(|b| b.access(line)) {
            let pline = line.wrapping_add(off as u64);
            if self.l2.probe(pline, t_l2).is_none() {
                let pt = self.l2.mshr_acquire(t_l2);
                let pready =
                    self.fill_from_below(pline, space, AccessKind::Prefetch, pt + self.l2.latency());
                self.l2.install(pline, pready);
                self.l2.mshr_hold(pready);
                self.l3.install(pline, pready);
            }
        }
        let t2 = self.l2.mshr_acquire(t_l2);
        let t_l3 = t2 + self.l2.latency();
        // L3
        if let Some(ready) = self.l3.probe(line, t_l3) {
            self.l2.install(line, ready);
            self.l2.mshr_hold(ready);
            self.l1.install(line, ready);
            self.l1.mshr_hold(ready);
            return ready;
        }
        let t3 = self.l3.mshr_acquire(t_l3);
        let ready = self.fill_from_below(line, space, kind, t3 + self.l3.latency());
        self.l3.install(line, ready);
        self.l3.mshr_hold(ready);
        self.l2.install(line, ready);
        self.l2.mshr_hold(ready);
        self.l1.install(line, ready);
        self.l1.mshr_hold(ready);
        ready
    }

    /// One line from the memory tier below the LLC: the far fabric for
    /// remote lines, the local channel otherwise. `kind` reaches the
    /// fabric so the tiered backend can track page dirtiness.
    fn fill_from_below(&mut self, line: u64, space: AddrSpace, kind: AccessKind, t: u64) -> u64 {
        match space {
            AddrSpace::Remote => self.far.issue(t, line << LINE_SHIFT, 1, kind),
            _ => self.local.request(t, 1),
        }
    }

    /// Non-binding prefetch into L2/L3 (no L1 involvement).
    fn prefetch_l2(&mut self, line: u64, space: AddrSpace, t: u64) -> u64 {
        let t_l2 = t + self.l1.latency(); // traverses the L1 pipe stage
        if let Some(ready) = self.l2.probe(line, t_l2) {
            return ready;
        }
        let t2 = self.l2.mshr_acquire(t_l2);
        let t_l3 = t2 + self.l2.latency();
        if let Some(ready) = self.l3.probe(line, t_l3) {
            self.l2.install(line, ready);
            self.l2.mshr_hold(ready);
            return ready;
        }
        let t3 = self.l3.mshr_acquire(t_l3);
        let ready = self.fill_from_below(line, space, AccessKind::Prefetch, t3 + self.l3.latency());
        self.l3.install(line, ready);
        self.l3.mshr_hold(ready);
        self.l2.install(line, ready);
        self.l2.mshr_hold(ready);
        ready
    }

    /// AMU decoupled transfer: `bytes` starting at `addr`, straight to the
    /// memory fabric (no caches, no MSHRs). `kind` distinguishes aload
    /// (Load) from astore (Store) for the tiered backend's dirty tracking.
    /// Returns completion cycle.
    pub fn amu_transfer(
        &mut self,
        addr: u64,
        bytes: u32,
        space: AddrSpace,
        kind: AccessKind,
        t: u64,
    ) -> u64 {
        let first = addr >> LINE_SHIFT;
        let last = (addr + bytes.max(1) as u64 - 1) >> LINE_SHIFT;
        let lines = last - first + 1;
        match space {
            AddrSpace::Remote => self.far.issue(t, addr, lines, kind),
            _ => self.local.request(t, lines),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::ir::AddrSpace::{Local, Remote, Spm};

    fn ms() -> MemSys {
        MemSys::new(&SimConfig::nh_g())
    }

    #[test]
    fn spm_is_l2_latency() {
        let mut m = ms();
        assert_eq!(m.access(0x4000_0000, Spm, AccessKind::Load, 100), 114);
    }

    #[test]
    fn cold_miss_pays_far_latency_then_hits() {
        let mut m = ms();
        let cfg = SimConfig::nh_g();
        let a = 0x8000_0000u64;
        let t0 = m.access(a, Remote, AccessKind::Load, 0);
        assert!(t0 >= cfg.far_latency_cycles(), "cold remote miss {t0} < far latency");
        // Same line now cached: near-L1 latency.
        let t1 = m.access(a + 8, Remote, AccessKind::Load, t0);
        assert_eq!(t1, t0 + cfg.l1d.latency_cycles);
    }

    #[test]
    fn local_faster_than_far() {
        let mut m = ms();
        let tl = m.access(0x1000_0000, Local, AccessKind::Load, 0);
        let tf = m.access(0x8000_0000, Remote, AccessKind::Load, 0);
        assert!(tl < tf);
    }

    #[test]
    fn default_fabric_is_the_fixed_delayer() {
        let m = ms();
        assert_eq!(m.fabric_kind(), FabricKind::FixedDelay);
        assert_eq!(m.far.stats().kind, "fixed");
    }

    #[test]
    fn prefetch_hides_latency() {
        let cfg = SimConfig::nh_g();
        let mut m = ms();
        let a = 0x8000_1000u64;
        let fill = m.access(a, Remote, AccessKind::Prefetch, 0);
        // Demand access after the fill: L2 hit (prefetcht1 fills L2, not L1).
        let t = m.access(a, Remote, AccessKind::Load, fill + 10);
        assert_eq!(t, fill + 10 + cfg.l1d.latency_cycles + cfg.l2.latency_cycles);
        // Demand racing the fill pays the residual, not the full trip.
        let mut m2 = ms();
        let fill2 = m2.access(a, Remote, AccessKind::Prefetch, 0);
        let t2 = m2.access(a, Remote, AccessKind::Load, 50);
        assert!(t2 >= fill2 && t2 < fill2 + 20, "t2={t2} fill2={fill2}");
    }

    #[test]
    fn prefetch_bypasses_l1_mshrs() {
        let mut m = ms();
        for k in 0..40 {
            m.access(0x8000_0000 + k * 64, Remote, AccessKind::Prefetch, 0);
        }
        assert_eq!(m.l1.mshr_busy(0), 0, "prefetches must not hold L1 fill buffers");
        assert!(m.l2.mshr_busy(0) > 0);
    }

    #[test]
    fn bandwidth_serializes_channel() {
        let mut ch = Channel::new(100, 16.0, true, 64); // 4 cycles per line
        let c1 = ch.request(0, 1);
        let c2 = ch.request(0, 1);
        assert_eq!(c1, 104);
        assert_eq!(c2, 108);
        let (mlp, busy) = ch.mlp(c2);
        assert!(mlp > 1.5, "two overlapped requests should give MLP ~2, got {mlp}");
        assert!(busy > 0.9);
    }

    /// Satellite pin: the channel clock is integer fixed-point — a long
    /// run at a bandwidth with no exact binary representation (24
    /// B/cycle: 2730.67 fp-units/line, rounded to 2731) lands on exactly
    /// these cycles on every platform. With the old `f64` accumulator
    /// this value depended on the platform's FP contraction behavior.
    #[test]
    fn long_run_channel_clock_is_bit_exact() {
        let mut ch = Channel::new(100, 24.0, false, 1);
        let mut last = 0;
        for _ in 0..100_000 {
            last = ch.request(0, 1);
        }
        assert_eq!(last, (100_000u64 * 2731 >> FP_SHIFT) + 100);
        assert_eq!(last, 266_699 + 100);
        assert_eq!(ch.lines_transferred, 100_000);
    }

    /// MLP/busy regression against hand-computed interval unions. With
    /// 100-cycle latency and 4 cycles/line, a request at `t` occupies
    /// `[t, start + 4·lines + 100)`.
    #[test]
    fn mlp_pinned_against_hand_computed_union() {
        let mut ch = Channel::new(100, 16.0, true, 64);
        // Two overlapped requests at t=0: intervals (0,104) and (0,108).
        // Union = 108, integral = 212.
        let c1 = ch.request(0, 1);
        let c2 = ch.request(0, 1);
        assert_eq!((c1, c2), (104, 108));
        let (mlp, busy) = ch.mlp(108);
        assert!((mlp - 212.0 / 108.0).abs() < 1e-12, "mlp {mlp}");
        assert!((busy - 1.0).abs() < 1e-12, "busy {busy}");
        // A third request after a gap: (500, 604). Union = 108 + 104.
        ch.request(500, 1);
        let (mlp, busy) = ch.mlp(1000);
        assert!((mlp - 316.0 / 212.0).abs() < 1e-12, "mlp {mlp}");
        assert!((busy - 212.0 / 1000.0).abs() < 1e-12, "busy {busy}");
    }

    /// Out-of-order issue times (a later request carries an earlier
    /// issue stamp, the in-flight-window pattern) still produce the
    /// exact union the old clone-and-sort computed.
    #[test]
    fn mlp_exact_under_out_of_order_issue() {
        let mut ch = Channel::new(100, 16.0, true, 64);
        // Issue stamps 200, 40, 190 in that arrival order. Transfer
        // serialization: starts 200, 204, 208 → completions 304, 308, 312.
        // Intervals: (200,304), (40,308), (190,312).
        // Union = [40,312) = 272; integral = 104 + 268 + 122 = 494.
        ch.request(200, 1);
        ch.request(40, 1);
        ch.request(190, 1);
        let (mlp, busy) = ch.mlp(312);
        assert!((mlp - 494.0 / 272.0).abs() < 1e-12, "mlp {mlp}");
        assert!((busy - 272.0 / 312.0).abs() < 1e-12, "busy {busy}");
    }

    #[test]
    fn unrecorded_channel_reports_zero_mlp() {
        let mut ch = Channel::new(100, 16.0, false, 64);
        ch.request(0, 1);
        assert_eq!(ch.mlp(1000), (0.0, 0.0));
    }

    #[test]
    fn amu_transfer_counts_lines() {
        let mut m = ms();
        let before = m.far.lines_transferred();
        m.amu_transfer(0x8000_0000 + 60, 8, Remote, AccessKind::Load, 0); // straddles 2 lines
        assert_eq!(m.far.lines_transferred() - before, 2);
        m.amu_transfer(0x8000_2000, 4096, Remote, AccessKind::Load, 0);
        assert_eq!(m.far.lines_transferred() - before, 2 + 64);
    }

    #[test]
    fn amu_bypasses_mshrs() {
        let mut m = ms();
        // Saturate with AMU transfers; cache MSHRs must stay free.
        for k in 0..100 {
            m.amu_transfer(0x8000_0000 + k * 64, 64, Remote, AccessKind::Load, 0);
        }
        assert_eq!(m.l1.mshr_busy(0), 0);
        assert_eq!(m.l2.mshr_busy(0), 0);
    }

    /// Swapping the far fabric changes timing, never the fill protocol:
    /// a tiered far pool still installs lines in every cache level, and
    /// a second access to the same line hits near the core.
    #[test]
    fn non_default_fabrics_slot_into_the_hierarchy() {
        for kind in FabricKind::ALL {
            let mut cfg = SimConfig::nh_g();
            cfg.mem.fabric.kind = kind;
            let mut m = MemSys::new(&cfg);
            let a = 0x8000_4000u64;
            let t0 = m.access(a, Remote, AccessKind::Load, 0);
            assert!(t0 > 0, "{}: completion must move time", kind.label());
            let t1 = m.access(a + 8, Remote, AccessKind::Load, t0);
            assert_eq!(t1, t0 + cfg.l1d.latency_cycles, "{}: L1 hit after fill", kind.label());
            assert!(m.far.stats().requests > 0, "{}: fabric saw the fill", kind.label());
        }
    }

    /// Two memory systems built over one `SharedFabric` contend on the
    /// same far wire (the cluster topology): private caches, shared pool,
    /// per-requester attribution.
    #[test]
    fn two_memsys_share_one_far_pool() {
        let cfg = SimConfig::nh_g();
        let shared = SharedFabric::new(cfg.mem.fabric.kind.build(
            cfg.far_latency_cycles(),
            cfg.mem.far_bw_bytes_per_cycle,
            true,
            MemSys::far_window(&cfg) * 2,
            cfg.mem.fabric.seed,
        ));
        let mut m0 = MemSys::with_far(&cfg, shared.for_core(0));
        let mut m1 = MemSys::with_far(&cfg, shared.for_core(1));
        let a = 0x8000_0000u64;
        let t0 = m0.access(a, Remote, AccessKind::Load, 0);
        // Same line, same cycle, other core: its private caches are cold
        // and its fill serializes behind core 0 on the shared wire.
        let t1 = m1.access(a, Remote, AccessKind::Load, 0);
        assert!(t1 > t0, "core 1's fill must queue behind core 0 ({t1} vs {t0})");
        let st = shared.stats();
        assert_eq!(st.requests, 2);
        assert_eq!((st.requester(0).requests, st.requester(1).requests), (1, 1));
        // Each core's own handle reports the shared totals.
        assert_eq!(m0.far.stats(), m1.far.stats());
    }
}
