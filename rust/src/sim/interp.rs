//! Functional interpreter for CoroIR, coupled to the timing model.
//!
//! Each dynamic instruction is executed for its architectural effect and
//! simultaneously passed through the [`Core`] dataflow/ROB spine, the
//! [`MemSys`] hierarchy, the BPU and the [`Amu`]. One CoroIR instruction
//! models one machine instruction.
//!
//! Two execution paths share the timing model:
//!
//! * [`run`] — the decode-once path: the [`Program`]'s pre-lowered
//!   [`DecodedFunc`] micro-op array is walked by program counter, with
//!   operands, latencies and block metadata resolved at link time
//!   (`sim::decode`). This is the hot path every figure sweep runs.
//! * [`run_reference`] — the original tree-walking interpreter over
//!   `Function`'s block/`Inst` enums, kept as the semantic baseline. The
//!   differential suite (`tests/differential.rs` and the proptest in this
//!   file's tests) pins that both paths produce bit-identical cycles,
//!   stats and memory images.

use super::amu::Amu;
use super::bpu::{BafinPredictTable, Ittage, Tage};
use super::core::{Cause, Core};
use super::decode::{alu_latency, decode_with, falu_latency, DecodedFunc, Src, UKind, NO_REG};
use super::mem::MemImage;
use super::memsys::{AccessKind, MemSys};
use super::stats::RunStats;
use super::trace::{AddrClass, Trace, Tracer};
use crate::config::SimConfig;
use crate::ir::*;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// A runnable program: compiled function + its decode-once lowering +
/// memory image + register bindings (params, runtime area bases, SPM
/// base). Construct through [`Program::new`], which performs the
/// link-time decode.
pub struct Program {
    pub func: Function,
    /// Decode-once lowering of `func` (shared so sweeps can clone the
    /// program cheaply).
    pub decoded: Arc<DecodedFunc>,
    pub mem: MemImage,
    pub reg_init: Vec<(Reg, i64)>,
    /// SPM slot stride for aload/astore placement (0 when no AMU).
    pub spm_slot_bytes: u32,
    /// Register holding the SPM base address, if any.
    pub spm_base_reg: Option<Reg>,
    /// Safety valve: abort after this many dynamic instructions.
    pub max_dyn_instrs: u64,
}

impl Program {
    /// Assemble a program, lowering `func` to its micro-op form once.
    /// `fuse` enables the decode-time superop peephole (see
    /// `sim::decode::decode_with`); it is timing-transparent, so the
    /// knob only trades decode work for interpreter throughput.
    pub fn new(
        func: Function,
        mem: MemImage,
        reg_init: Vec<(Reg, i64)>,
        spm_slot_bytes: u32,
        spm_base_reg: Option<Reg>,
        max_dyn_instrs: u64,
        fuse: bool,
    ) -> Program {
        let decoded = Arc::new(decode_with(&func, fuse));
        Program { func, decoded, mem, reg_init, spm_slot_bytes, spm_base_reg, max_dyn_instrs }
    }
}

/// Evaluate an integer op. `pub(crate)` because the decode-time
/// constant-folder reuses it, so folded results cannot drift from the
/// interpreter's semantics.
pub(crate) fn alu_eval(op: AluOp, a: i64, b: i64) -> i64 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Mul => a.wrapping_mul(b),
        AluOp::Div => {
            if b == 0 {
                -1
            } else {
                a.wrapping_div(b)
            }
        }
        AluOp::Rem => {
            if b == 0 {
                a
            } else {
                a.wrapping_rem(b)
            }
        }
        AluOp::And => a & b,
        AluOp::Or => a | b,
        AluOp::Xor => a ^ b,
        AluOp::Shl => a.wrapping_shl(b as u32 & 63),
        AluOp::Shr => ((a as u64).wrapping_shr(b as u32 & 63)) as i64,
        AluOp::Sra => a.wrapping_shr(b as u32 & 63),
        AluOp::Slt => (a < b) as i64,
        AluOp::SltU => ((a as u64) < (b as u64)) as i64,
        AluOp::Seq => (a == b) as i64,
        AluOp::Sne => (a != b) as i64,
        AluOp::Min => a.min(b),
        AluOp::Max => a.max(b),
        AluOp::Hash => mix64((a as u64) ^ (b as u64)) as i64,
    }
}

/// MurmurHash3 finalizer — replicated by the JAX oracle kernels
/// (`python/compile/kernels/ref.py::mix64`).
pub fn mix64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    x
}

fn falu_eval(op: FaluOp, a: i64, b: i64) -> i64 {
    let fa = f64::from_bits(a as u64);
    let fb = f64::from_bits(b as u64);
    let out = match op {
        FaluOp::FAdd => fa + fb,
        FaluOp::FSub => fa - fb,
        FaluOp::FMul => fa * fb,
        FaluOp::FDiv => fa / fb,
        FaluOp::FMin => fa.min(fb),
        FaluOp::FMax => fa.max(fb),
        FaluOp::FLt => return (fa < fb) as i64,
        FaluOp::IToF => return (a as f64).to_bits() as i64,
        FaluOp::FToI => return fa as i64,
    };
    out.to_bits() as i64
}

struct Machine<'p> {
    func: &'p Function,
    mem: &'p mut MemImage,
    regs: Vec<i64>,
    core: Core,
    msys: MemSys,
    tage: Tage,
    ittage: Ittage,
    bpt: BafinPredictTable,
    amu: Amu,
    aconfig_base: i64,
    aconfig_size: i64,
    spm_base: u64,
    spm_slot: u64,
    /// Cycle-level event tracer (DESIGN.md §14). `None` unless
    /// `cfg.trace.enabled` — the off path constructs no tracer state and
    /// every hook is a single `Option` check, so untraced runs stay
    /// bit-identical by construction.
    tracer: Option<Box<Tracer>>,
}

impl<'p> Machine<'p> {
    /// Shared setup for both execution paths: timing structures + the
    /// register file seeded from the link-time bindings. The scheduler
    /// policy (`cfg.sched_policy`) is instantiated here and handed to the
    /// AMU; the BPT learns whether that policy keeps the §IV-A BTQ oracle.
    fn new(cfg: &SimConfig, prog: &'p mut Program) -> Machine<'p> {
        Machine::with_msys(cfg, prog, MemSys::new(cfg))
    }

    /// Like [`Machine::new`] but over an externally built memory system —
    /// the cluster path injects a [`MemSys`] whose far tier is a shared,
    /// requester-tagged fabric handle.
    fn with_msys(cfg: &SimConfig, prog: &'p mut Program, msys: MemSys) -> Machine<'p> {
        let nregs = prog.func.nregs;
        let policy = cfg.sched_policy.build();
        let guided = policy.btq_guided();
        let tracer = if cfg.trace.enabled {
            Some(Tracer::for_core(cfg.trace, msys.far.requester()))
        } else {
            None
        };
        let mut m = Machine {
            func: &prog.func,
            regs: vec![0i64; nregs as usize],
            core: Core::new(&cfg.core, nregs),
            msys,
            tage: Tage::new(&cfg.bpu),
            ittage: Ittage::new(&cfg.bpu),
            bpt: BafinPredictTable::new(&cfg.bpu, guided),
            amu: Amu::with_policy(cfg.amu.request_table.max(1), cfg.l1d.latency_cycles, policy),
            aconfig_base: 0,
            aconfig_size: 0,
            spm_base: 0,
            spm_slot: prog.spm_slot_bytes.max(1) as u64,
            tracer,
            mem: &mut prog.mem,
        };
        for (r, v) in &prog.reg_init {
            m.regs[*r as usize] = *v;
        }
        if let Some(sr) = prog.spm_base_reg {
            m.spm_base = m.regs[sr as usize] as u64;
        }
        m
    }

    #[inline]
    fn val(&self, o: Operand) -> i64 {
        match o {
            Operand::Reg(r) => self.regs[r as usize],
            Operand::Imm(v) => v,
        }
    }

    #[inline]
    fn src_ready(&self, d: u64, ops: &[Operand]) -> u64 {
        let mut t = d;
        for o in ops {
            if let Operand::Reg(r) = o {
                t = t.max(self.core.operands_ready(d, &[*r]));
            }
        }
        t
    }

    /// Earliest cycle at or after `d` that decoded source `a` is ready.
    #[inline(always)]
    fn ready1(&self, d: u64, a: Src) -> u64 {
        if a.reg == NO_REG {
            d
        } else {
            d.max(self.core.ready_of(a.reg))
        }
    }

    #[inline(always)]
    fn ready2(&self, d: u64, a: Src, b: Src) -> u64 {
        self.ready1(self.ready1(d, a), b)
    }

    fn mem_cause(&self, space: AddrSpace) -> Cause {
        match space {
            AddrSpace::Remote => Cause::RemoteMem,
            _ => Cause::LocalMem,
        }
    }

    fn spm_addr(&self, id: i64, off: u32) -> u64 {
        self.spm_base + id as u64 * self.spm_slot + off as u64
    }

    // --- tracing hooks (DESIGN.md §14) -----------------------------------
    // Each hook is a no-op `Option` check when tracing is off; callers on
    // the hot path guard with `tracer.is_some()` where extra state would
    // otherwise be computed.

    /// Periodic counter sample if one is due at dispatch cycle `d`.
    #[inline]
    fn trace_sample(&mut self, d: u64) {
        if let Some(tr) = self.tracer.as_deref_mut() {
            if tr.sample_due(d) {
                let gauges = self.msys.far.gauges();
                let amu_inflight = self.amu.inflight(d) as u64;
                tr.sample(d, gauges, amu_inflight);
            }
        }
    }

    /// AMU transfer issued: spawn/request events + fault-counter deltas.
    fn trace_transfer(&mut self, id: i64, issue: u64, done: u64, store: bool, space: AddrSpace, bytes: u32) {
        if let Some(tr) = self.tracer.as_deref_mut() {
            let class = match space {
                AddrSpace::Remote => AddrClass::Remote,
                AddrSpace::Spm => AddrClass::Spm,
                AddrSpace::Local => AddrClass::Local,
            };
            let lines = (bytes as u64).div_ceil(64).max(1);
            tr.on_transfer(id, issue, done.max(issue), store, class, lines);
            tr.on_fault_check(issue, self.msys.far.gauges());
        }
    }

    /// Scheduler picked `id`: record the pick and the context switch.
    fn trace_pick(&mut self, t: u64, id: i64) {
        if let Some(tr) = self.tracer.as_deref_mut() {
            tr.on_sched(t, Some(id), 0);
            tr.on_switch(t, self.core.now(), &self.core.stats.stalls, Some(id));
        }
    }

    /// Scheduler came up empty; `holds_before` is `stat_sched_holds`
    /// sampled before the poll, so the delta says whether the policy
    /// deferred visible completions (hold) or none were ready.
    fn trace_hold(&mut self, t: u64, holds_before: u64) {
        if let Some(tr) = self.tracer.as_deref_mut() {
            let held = self.amu.stat_sched_holds.saturating_sub(holds_before);
            tr.on_sched(t, None, held);
        }
    }

    /// Drain the pipeline and collect the run statistics.
    fn finish(self) -> RunStats {
        self.finish_traced().0
    }

    /// Like [`Machine::finish`], but also harvests the tracer (if any)
    /// into a [`Trace`] artifact and accounts its event totals in stats.
    fn finish_traced(mut self) -> (RunStats, Option<Trace>) {
        let tracer = self.tracer.take();
        self.core.finish();
        let mut stats = std::mem::take(&mut self.core.stats);
        stats.l1_hits = self.msys.l1.stat_hits;
        stats.l1_misses = self.msys.l1.stat_misses;
        stats.far_lines = self.msys.far.lines_transferred();
        let (mlp, busy) = self.msys.far.mlp(stats.cycles);
        stats.far_mlp = mlp;
        stats.far_busy_frac = busy;
        let fs = self.msys.far.stats();
        stats.fabric = fs.kind;
        stats.fabric_requests = fs.requests;
        stats.fabric_max_inflight = fs.max_inflight;
        stats.fabric_queue_stalls = fs.queue_stall_cycles;
        stats.fabric_p50 = fs.lat_p50;
        stats.fabric_p99 = fs.lat_p99;
        stats.fabric_hot_hits = fs.hot_hits;
        stats.fabric_hot_misses = fs.hot_misses;
        stats.fabric_writebacks = fs.writebacks;
        stats.faults = fs.faults.clone();
        stats.fault_nacks = fs.fault_nacks;
        stats.fault_retries = fs.fault_retries;
        stats.fault_retry_cycles = fs.fault_retry_cycles;
        stats.fault_timeouts = fs.fault_timeouts;
        stats.fault_degraded_cycles = fs.fault_degraded_cycles;
        stats.fault_slow_path = fs.fault_slow_path;
        stats.fault_max_stall = fs.fault_max_stall;
        stats.aloads = self.amu.stat_aloads;
        stats.astores = self.amu.stat_astores;
        stats.amu_max_inflight = self.amu.stat_max_inflight;
        stats.sched_policy = self.amu.policy_kind().label();
        stats.sched_polls = self.amu.stat_sched_polls;
        stats.sched_picks = self.amu.stat_sched_picks;
        stats.sched_holds = self.amu.stat_sched_holds;
        stats.sched_indirect_jumps = self.ittage.stat_sched_lookups;
        stats.sched_indirect_mispredicts = self.ittage.stat_sched_mispredicts;
        let trace = tracer.map(|tr| {
            let t = tr.harvest(stats.cycles, &stats.stalls, &stats.sched_policy, &stats.fabric);
            stats.trace_events = t.total;
            stats.trace_dropped = t.dropped;
            t
        });
        (stats, trace)
    }
}

/// Single-stepping handle over the decode-once path. [`run`] drives it
/// to completion for the single-core simulator; `sim::cluster` holds one
/// per core and interleaves `step` calls on a shared clock (always
/// advancing the core whose local time is furthest behind). One `step`
/// executes exactly one decoded micro-op — a fused superop counts as one
/// step, exactly as it is one iteration of the pre-cluster loop — so the
/// single-core `while !halted { step }` loop replays the original
/// control flow instruction for instruction.
pub(crate) struct Stepper<'p> {
    m: Machine<'p>,
    dec: Arc<DecodedFunc>,
    pc: usize,
    budget: u64,
    halted: bool,
}

impl<'p> Stepper<'p> {
    pub(crate) fn new(cfg: &SimConfig, prog: &'p mut Program) -> Stepper<'p> {
        let msys = MemSys::new(cfg);
        Stepper::with_msys(cfg, prog, msys)
    }

    /// Cluster entry point: the memory system (private caches + shared
    /// far handle) is built by the caller.
    pub(crate) fn with_msys(cfg: &SimConfig, prog: &'p mut Program, msys: MemSys) -> Stepper<'p> {
        let dec = prog.decoded.clone();
        let budget = prog.max_dyn_instrs;
        let m = Machine::with_msys(cfg, prog, msys);
        let pc = dec.start_of(dec.entry);
        Stepper { m, dec, pc, budget, halted: false }
    }

    pub(crate) fn halted(&self) -> bool {
        self.halted
    }

    /// This core's local clock (dispatch-cycle estimate) — the cluster's
    /// interleave key.
    pub(crate) fn now(&self) -> u64 {
        self.m.core.now()
    }

    pub(crate) fn finish(self) -> RunStats {
        self.m.finish()
    }

    /// Finish and hand back the harvested trace alongside the stats.
    pub(crate) fn finish_traced(self) -> (RunStats, Option<Trace>) {
        self.m.finish_traced()
    }

    /// Execute one decoded micro-op. Must not be called after
    /// [`Stepper::halted`] turns true.
    #[inline]
    pub(crate) fn step(&mut self) -> Result<()> {
        let Stepper { m, dec, pc, budget, halted } = self;
        // Budget charge for the second half of a fused superop: the bail
        // message matches the per-op check below (same block, same name),
        // so a budget that expires mid-pair fails identically to the
        // unfused and reference paths.
        macro_rules! take_budget {
            ($op:expr) => {
                if *budget == 0 {
                    bail!("dynamic instruction budget exhausted in {} at bb{}", dec.name, $op.bb);
                }
                *budget -= 1;
            };
        }
        let op = &dec.ops[*pc];
        if *budget == 0 {
            bail!("dynamic instruction budget exhausted in {} at bb{}", dec.name, op.bb);
        }
        *budget -= 1;
        let d = m.core.dispatch(op.tag);
        if m.tracer.is_some() {
            m.trace_sample(d);
        }
        match op.kind {
            UKind::Alu { op: aop, dst, lat } => {
                let v = alu_eval(aop, op.a.value(&m.regs), op.b.value(&m.regs));
                m.regs[dst as usize] = v;
                let exec = m.ready2(d, op.a, op.b);
                m.core.commit(Some(dst), exec + lat, Cause::Compute);
                *pc += 1;
            }
            UKind::Falu { op: fop, dst, lat } => {
                let v = falu_eval(fop, op.a.value(&m.regs), op.b.value(&m.regs));
                m.regs[dst as usize] = v;
                let exec = m.ready2(d, op.a, op.b);
                m.core.commit(Some(dst), exec + lat, Cause::Compute);
                *pc += 1;
            }
            UKind::Load { dst, off, width } => {
                let addr = (op.a.value(&m.regs).wrapping_add(off)) as u64;
                let (v, space) = m
                    .mem
                    .read_ws(addr, width)
                    .with_context(|| format!("load in bb{}", op.bb))?;
                m.regs[dst as usize] = v;
                let exec = m.ready1(d, op.a);
                let t = m.core.lq_acquire(exec);
                let done = m.msys.access(addr, space, AccessKind::Load, t);
                m.core.lq_hold(done);
                m.core.commit(Some(dst), done, m.mem_cause(space));
                m.core.stats.loads += 1;
                if op.is_ctx {
                    m.core.stats.ctx_ops += 1;
                }
                *pc += 1;
            }
            UKind::Store { off, width } => {
                let addr = (op.b.value(&m.regs).wrapping_add(off)) as u64;
                let space = m
                    .mem
                    .write_ws(addr, width, op.a.value(&m.regs))
                    .with_context(|| format!("store in bb{}", op.bb))?;
                let exec = m.ready2(d, op.a, op.b);
                let t = m.core.sq_acquire(exec);
                let drain = m.msys.access(addr, space, AccessKind::Store, t);
                m.core.sq_hold(drain);
                // Stores retire once queued; drain happens behind.
                m.core.commit(None, exec + 1, Cause::Compute);
                m.core.stats.stores += 1;
                if op.is_ctx {
                    m.core.stats.ctx_ops += 1;
                }
                *pc += 1;
            }
            UKind::AtomicRmw { op: aop, dst, off, width } => {
                let addr = (op.b.value(&m.regs).wrapping_add(off)) as u64;
                let valv = op.a.value(&m.regs);
                let (old, space) = m.mem.rmw_ws(addr, width, |old| alu_eval(aop, old, valv))?;
                m.regs[dst as usize] = old;
                let exec = m.ready2(d, op.a, op.b);
                let t = m.core.lq_acquire(exec);
                // Atomics serialize: full round trip + write drain.
                let done = m.msys.access(addr, space, AccessKind::Atomic, t);
                let drain = m.msys.access(addr, space, AccessKind::Store, done);
                m.core.lq_hold(drain);
                m.core.commit(Some(dst), done, m.mem_cause(space));
                m.core.stats.loads += 1;
                m.core.stats.stores += 1;
                *pc += 1;
            }
            UKind::Prefetch { off } => {
                let addr = (op.a.value(&m.regs).wrapping_add(off)) as u64;
                let space = m.mem.space_of(addr).unwrap_or(AddrSpace::Local);
                let exec = m.ready1(d, op.a);
                // Non-binding, non-blocking; occupies MSHRs while the
                // fill is in flight.
                m.msys.access(addr, space, AccessKind::Prefetch, exec);
                m.core.commit(None, exec + 1, Cause::Compute);
                m.core.stats.prefetches += 1;
                *pc += 1;
            }
            UKind::Aload { off, bytes, spm_off, resume } => {
                let idv = op.a.value(&m.regs);
                let addr = (op.b.value(&m.regs).wrapping_add(off)) as u64;
                let spm_dst = m.spm_addr(idv, spm_off);
                let (space, _) = m
                    .mem
                    .copy_ws(addr, spm_dst, bytes as u64)
                    .with_context(|| format!("aload id={idv} in bb{}", op.bb))?;
                let exec = m.ready2(d, op.a, op.b);
                let msys = &mut m.msys;
                let mut done_t = 0u64;
                let issue = m.amu.transfer(idv, resume, exec, false, |t| {
                    done_t = msys.amu_transfer(addr, bytes, space, AccessKind::Load, t);
                    done_t
                });
                if m.tracer.is_some() {
                    m.trace_transfer(idv, issue, done_t, false, space, bytes);
                }
                m.core.commit(
                    None,
                    issue + 1,
                    if issue > exec { Cause::Backpressure } else { Cause::Compute },
                );
                *pc += 1;
            }
            UKind::Astore { off, bytes, spm_off, resume } => {
                let idv = op.a.value(&m.regs);
                let addr = (op.b.value(&m.regs).wrapping_add(off)) as u64;
                let spm_src = m.spm_addr(idv, spm_off);
                let (_, space) = m
                    .mem
                    .copy_ws(spm_src, addr, bytes as u64)
                    .with_context(|| format!("astore id={idv} in bb{}", op.bb))?;
                let exec = m.ready2(d, op.a, op.b);
                let msys = &mut m.msys;
                let mut done_t = 0u64;
                let issue = m.amu.transfer(idv, resume, exec, true, |t| {
                    done_t = msys.amu_transfer(addr, bytes, space, AccessKind::Store, t);
                    done_t
                });
                if m.tracer.is_some() {
                    m.trace_transfer(idv, issue, done_t, true, space, bytes);
                }
                m.core.commit(
                    None,
                    issue + 1,
                    if issue > exec { Cause::Backpressure } else { Cause::Compute },
                );
                *pc += 1;
            }
            UKind::Aset => {
                m.amu.aset(op.a.value(&m.regs), op.b.value(&m.regs) as u32)?;
                let exec = m.ready2(d, op.a, op.b);
                m.core.commit(None, exec + 1, Cause::Compute);
                *pc += 1;
            }
            UKind::Getfin { dst } => {
                let exec = d;
                let holds0 = if m.tracer.is_some() { m.amu.stat_sched_holds } else { 0 };
                let v = match m.amu.pop_finished(exec) {
                    Some((id, _resume)) => {
                        if m.tracer.is_some() {
                            m.trace_pick(exec, id);
                        }
                        id
                    }
                    None => {
                        if m.tracer.is_some() {
                            m.trace_hold(exec, holds0);
                        }
                        -1
                    }
                };
                m.regs[dst as usize] = v;
                m.core.commit(Some(dst), exec + 3, Cause::Compute);
                *pc += 1;
            }
            UKind::Aconfig => {
                m.aconfig_base = op.a.value(&m.regs);
                m.aconfig_size = op.b.value(&m.regs);
                let exec = m.ready2(d, op.a, op.b);
                m.core.commit(None, exec + 1, Cause::Compute);
                *pc += 1;
            }
            UKind::Await { resume } => {
                let exec = m.ready1(d, op.a);
                m.amu.await_register(op.a.value(&m.regs), resume, exec)?;
                m.core.commit(None, exec + 1, Cause::Compute);
                m.core.stats.awaits += 1;
                *pc += 1;
            }
            UKind::Asignal => {
                let exec = m.ready1(d, op.a);
                m.amu.asignal(op.a.value(&m.regs), exec)?;
                m.core.commit(None, exec + 1, Cause::Compute);
                *pc += 1;
            }
            // ---- terminators ----
            UKind::Br { then_, else_ } => {
                let taken = op.a.value(&m.regs) != 0;
                let exec = m.ready1(d, op.a);
                m.core.commit(None, exec + 1, Cause::Compute);
                m.core.stats.cond_branches += 1;
                if m.tage.predict_and_update(op.bb as u64, taken) {
                    m.core.stats.cond_mispredicts += 1;
                    m.core.redirect(exec + 1);
                }
                *pc = dec.start_of(if taken { then_ } else { else_ });
            }
            UKind::Jmp { target } => {
                m.core.commit(None, d + 1, Cause::Compute);
                *pc = dec.start_of(target);
            }
            UKind::IndirectJmp => {
                let tv = op.a.value(&m.regs);
                if tv < 0 || tv as usize >= dec.block_start.len() {
                    bail!("indirect jump to invalid block {tv} from bb{}", op.bb);
                }
                let exec = m.ready1(d, op.a);
                m.core.commit(None, exec + 1, Cause::Compute);
                m.core.stats.indirect_jumps += 1;
                if m.ittage.predict_and_update(op.bb as u64, tv as u64, op.is_sched) {
                    m.core.stats.indirect_mispredicts += 1;
                    m.core.redirect(exec + 1);
                }
                if op.is_sched {
                    m.core.stats.switches += 1;
                }
                *pc = dec.start_of(tv as BlockId);
            }
            UKind::Bafin { handler_dst, id_dst, fallthrough } => {
                // §IV-A oracle: outcome decided by the Finished-Queue state
                // at *fetch* time; the BTQ carries the id to the front end,
                // so a covered bafin never mispredicts.
                let fetch = d.saturating_sub(m.core.frontend_depth);
                let covered = m.bpt.covered(op.bb as u64);
                let holds0 = if m.tracer.is_some() { m.amu.stat_sched_holds } else { 0 };
                match m.amu.pop_finished(fetch) {
                    Some((id, resume)) => {
                        m.regs[id_dst as usize] = id;
                        m.regs[handler_dst as usize] =
                            m.aconfig_base.wrapping_add(id.wrapping_mul(m.aconfig_size));
                        m.core.commit(Some(handler_dst), d + 1, Cause::Compute);
                        m.core.stats.bafins_taken += 1;
                        m.core.stats.switches += 1;
                        if !covered {
                            m.core.stats.bafin_mispredicts += 1;
                            m.core.redirect(d + 1);
                        }
                        if m.tracer.is_some() {
                            m.trace_pick(d, id);
                        }
                        *pc = dec.start_of(resume);
                    }
                    None => {
                        m.core.commit(None, d + 1, Cause::Compute);
                        m.core.stats.bafins_fallthrough += 1;
                        if m.tracer.is_some() {
                            m.trace_hold(fetch, holds0);
                        }
                        *pc = dec.start_of(fallthrough);
                    }
                }
            }
            UKind::Halt => *halted = true,
            // ---- superops: both halves' accounting inline, in the exact
            // order the unfused pair would perform it. `d` is the first
            // half's dispatch cycle; the second half dispatches its own.
            UKind::FusedAluAlu { op1, dst1, lat1, op2, dst2, lat2, a2, b2 } => {
                let v1 = alu_eval(op1, op.a.value(&m.regs), op.b.value(&m.regs));
                m.regs[dst1 as usize] = v1;
                let exec1 = m.ready2(d, op.a, op.b);
                m.core.commit(Some(dst1), exec1 + lat1, Cause::Compute);
                take_budget!(op);
                let d2 = m.core.dispatch(op.tag);
                let v2 = alu_eval(op2, a2.value(&m.regs), b2.value(&m.regs));
                m.regs[dst2 as usize] = v2;
                let exec2 = m.ready2(d2, a2, b2);
                m.core.commit(Some(dst2), exec2 + lat2, Cause::Compute);
                *pc += 1;
            }
            UKind::FusedAluLoad { op: aop, dst, lat, ld_dst, off, width } => {
                let v1 = alu_eval(aop, op.a.value(&m.regs), op.b.value(&m.regs));
                m.regs[dst as usize] = v1;
                let exec1 = m.ready2(d, op.a, op.b);
                let addr_ready = exec1 + lat;
                m.core.commit(Some(dst), addr_ready, Cause::Compute);
                take_budget!(op);
                let d2 = m.core.dispatch(op.tag);
                // The load's base register IS the alu destination: its
                // value (v1) and ready cycle (addr_ready) are in hand, so
                // neither the register file nor the scoreboard is re-read.
                let addr = (v1.wrapping_add(off)) as u64;
                let (v2, space) = m
                    .mem
                    .read_ws(addr, width)
                    .with_context(|| format!("load in bb{}", op.bb))?;
                m.regs[ld_dst as usize] = v2;
                let exec2 = d2.max(addr_ready);
                let t = m.core.lq_acquire(exec2);
                let done = m.msys.access(addr, space, AccessKind::Load, t);
                m.core.lq_hold(done);
                m.core.commit(Some(ld_dst), done, m.mem_cause(space));
                m.core.stats.loads += 1;
                if op.is_ctx {
                    m.core.stats.ctx_ops += 1;
                }
                *pc += 1;
            }
            UKind::FusedAluStore { op: aop, dst, lat, off, width, val, base } => {
                let v1 = alu_eval(aop, op.a.value(&m.regs), op.b.value(&m.regs));
                m.regs[dst as usize] = v1;
                let exec1 = m.ready2(d, op.a, op.b);
                m.core.commit(Some(dst), exec1 + lat, Cause::Compute);
                take_budget!(op);
                let d2 = m.core.dispatch(op.tag);
                let addr = (base.value(&m.regs).wrapping_add(off)) as u64;
                let space = m
                    .mem
                    .write_ws(addr, width, val.value(&m.regs))
                    .with_context(|| format!("store in bb{}", op.bb))?;
                let exec2 = m.ready2(d2, val, base);
                let t = m.core.sq_acquire(exec2);
                let drain = m.msys.access(addr, space, AccessKind::Store, t);
                m.core.sq_hold(drain);
                // Stores retire once queued; drain happens behind.
                m.core.commit(None, exec2 + 1, Cause::Compute);
                m.core.stats.stores += 1;
                if op.is_ctx {
                    m.core.stats.ctx_ops += 1;
                }
                *pc += 1;
            }
            UKind::FusedAluBr { op: aop, dst, lat, then_, else_ } => {
                let v1 = alu_eval(aop, op.a.value(&m.regs), op.b.value(&m.regs));
                m.regs[dst as usize] = v1;
                let exec1 = m.ready2(d, op.a, op.b);
                let cond_ready = exec1 + lat;
                m.core.commit(Some(dst), cond_ready, Cause::Compute);
                take_budget!(op);
                let d2 = m.core.dispatch(op.tag);
                let taken = v1 != 0;
                let exec2 = d2.max(cond_ready);
                m.core.commit(None, exec2 + 1, Cause::Compute);
                m.core.stats.cond_branches += 1;
                if m.tage.predict_and_update(op.bb as u64, taken) {
                    m.core.stats.cond_mispredicts += 1;
                    m.core.redirect(exec2 + 1);
                }
                *pc = dec.start_of(if taken { then_ } else { else_ });
            }
            UKind::AluConst { dst, val, lat } => {
                // Both operands immediate: exec == dispatch, value folded
                // at decode time through the same alu_eval.
                m.regs[dst as usize] = val;
                m.core.commit(Some(dst), d + lat, Cause::Compute);
                *pc += 1;
            }
        }
        Ok(())
    }
}

/// Execute `prog` under `cfg` on the decode-once path; returns the run
/// statistics. The memory image is mutated in place (callers read
/// results out for validation). Semantically identical to
/// [`run_reference`] — the differential suite pins this.
pub fn run(cfg: &SimConfig, prog: &mut Program) -> Result<RunStats> {
    run_traced(cfg, prog).map(|(stats, _)| stats)
}

/// Like [`run`], but also returns the harvested [`Trace`] when
/// `cfg.trace.enabled` (`None` otherwise). [`run`] delegates here, so
/// untraced callers pay only a discarded `None`.
pub fn run_traced(cfg: &SimConfig, prog: &mut Program) -> Result<(RunStats, Option<Trace>)> {
    let mut s = Stepper::new(cfg, prog);
    while !s.halted() {
        s.step()?;
    }
    let (stats, trace) = s.finish_traced();
    super::faults::check_strict(cfg, &stats)?;
    Ok((stats, trace))
}

/// Execute `prog` on the reference (tree-walking) interpreter. This is
/// the pre-decode implementation, kept verbatim as the semantic baseline
/// for differential testing and as the "before" side of the simulator
/// throughput benchmark.
pub fn run_reference(cfg: &SimConfig, prog: &mut Program) -> Result<RunStats> {
    run_reference_traced(cfg, prog).map(|(stats, _)| stats)
}

/// Traced variant of the reference path: the same hooks fire at the
/// same architectural points as on the decoded path, so a traced
/// reference run produces its own deterministic event stream.
pub fn run_reference_traced(
    cfg: &SimConfig,
    prog: &mut Program,
) -> Result<(RunStats, Option<Trace>)> {
    let mut budget = prog.max_dyn_instrs;
    let mut m = Machine::new(cfg, prog);

    let mut bb: BlockId = m.func.entry;
    'outer: loop {
        let blk = &m.func.blocks[bb as usize];
        let tag = blk.tag;
        let is_ctx = tag == CodeTag::CtxSwitch;
        for inst in &blk.insts {
            if budget == 0 {
                bail!("dynamic instruction budget exhausted in {} at bb{}", m.func.name, bb);
            }
            budget -= 1;
            let d = m.core.dispatch(tag);
            if m.tracer.is_some() {
                m.trace_sample(d);
            }
            match inst {
                Inst::Alu { op, dst, a, b } => {
                    let v = alu_eval(*op, m.val(*a), m.val(*b));
                    m.regs[*dst as usize] = v;
                    let exec = m.src_ready(d, &[*a, *b]);
                    m.core.commit(Some(*dst), exec + alu_latency(*op), Cause::Compute);
                }
                Inst::Falu { op, dst, a, b } => {
                    let v = falu_eval(*op, m.val(*a), m.val(*b));
                    m.regs[*dst as usize] = v;
                    let exec = m.src_ready(d, &[*a, *b]);
                    m.core.commit(Some(*dst), exec + falu_latency(*op), Cause::Compute);
                }
                Inst::Load { dst, base, off, width, space: _ } => {
                    let addr = (m.val(*base).wrapping_add(*off)) as u64;
                    let v = m.mem.read(addr, *width).with_context(|| format!("load in bb{bb}"))?;
                    m.regs[*dst as usize] = v;
                    let space = m.mem.space_of(addr).unwrap_or(AddrSpace::Local);
                    let exec = m.src_ready(d, &[*base]);
                    let t = m.core.lq_acquire(exec);
                    let done = m.msys.access(addr, space, AccessKind::Load, t);
                    m.core.lq_hold(done);
                    m.core.commit(Some(*dst), done, m.mem_cause(space));
                    m.core.stats.loads += 1;
                    if is_ctx {
                        m.core.stats.ctx_ops += 1;
                    }
                }
                Inst::Store { val, base, off, width, space: _ } => {
                    let addr = (m.val(*base).wrapping_add(*off)) as u64;
                    m.mem.write(addr, *width, m.val(*val)).with_context(|| format!("store in bb{bb}"))?;
                    let space = m.mem.space_of(addr).unwrap_or(AddrSpace::Local);
                    let exec = m.src_ready(d, &[*val, *base]);
                    let t = m.core.sq_acquire(exec);
                    let drain = m.msys.access(addr, space, AccessKind::Store, t);
                    m.core.sq_hold(drain);
                    // Stores retire once queued; drain happens behind.
                    m.core.commit(None, exec + 1, Cause::Compute);
                    m.core.stats.stores += 1;
                    if is_ctx {
                        m.core.stats.ctx_ops += 1;
                    }
                }
                Inst::AtomicRmw { op, dst, val, base, off, width, space: _ } => {
                    let addr = (m.val(*base).wrapping_add(*off)) as u64;
                    let old = m.mem.read(addr, *width)?;
                    let new = alu_eval(*op, old, m.val(*val));
                    m.mem.write(addr, *width, new)?;
                    m.regs[*dst as usize] = old;
                    let space = m.mem.space_of(addr).unwrap_or(AddrSpace::Local);
                    let exec = m.src_ready(d, &[*val, *base]);
                    let t = m.core.lq_acquire(exec);
                    // Atomics serialize: full round trip + write drain.
                    let done = m.msys.access(addr, space, AccessKind::Atomic, t);
                    let drain = m.msys.access(addr, space, AccessKind::Store, done);
                    m.core.lq_hold(drain);
                    m.core.commit(Some(*dst), done, m.mem_cause(space));
                    m.core.stats.loads += 1;
                    m.core.stats.stores += 1;
                }
                Inst::Prefetch { base, off, space: _ } => {
                    let addr = (m.val(*base).wrapping_add(*off)) as u64;
                    let space = m.mem.space_of(addr).unwrap_or(AddrSpace::Local);
                    let exec = m.src_ready(d, &[*base]);
                    // Non-binding, non-blocking; occupies MSHRs while the
                    // fill is in flight.
                    m.msys.access(addr, space, AccessKind::Prefetch, exec);
                    m.core.commit(None, exec + 1, Cause::Compute);
                    m.core.stats.prefetches += 1;
                }
                Inst::Aload { id, base, off, bytes, spm_off, resume } => {
                    let idv = m.val(*id);
                    let addr = (m.val(*base).wrapping_add(*off)) as u64;
                    let spm_dst = m.spm_addr(idv, *spm_off);
                    m.mem
                        .copy(addr, spm_dst, *bytes as u64)
                        .with_context(|| format!("aload id={idv} in bb{bb}"))?;
                    let space = m.mem.space_of(addr).unwrap_or(AddrSpace::Remote);
                    let exec = m.src_ready(d, &[*id, *base]);
                    let msys = &mut m.msys;
                    let mut done_t = 0u64;
                    let issue = m.amu.transfer(idv, *resume, exec, false, |t| {
                        done_t = msys.amu_transfer(addr, *bytes, space, AccessKind::Load, t);
                        done_t
                    });
                    if m.tracer.is_some() {
                        m.trace_transfer(idv, issue, done_t, false, space, *bytes);
                    }
                    m.core.commit(None, issue + 1, if issue > exec { Cause::Backpressure } else { Cause::Compute });
                }
                Inst::Astore { id, base, off, bytes, spm_off, resume } => {
                    let idv = m.val(*id);
                    let addr = (m.val(*base).wrapping_add(*off)) as u64;
                    let spm_src = m.spm_addr(idv, *spm_off);
                    m.mem
                        .copy(spm_src, addr, *bytes as u64)
                        .with_context(|| format!("astore id={idv} in bb{bb}"))?;
                    let space = m.mem.space_of(addr).unwrap_or(AddrSpace::Remote);
                    let exec = m.src_ready(d, &[*id, *base]);
                    let msys = &mut m.msys;
                    let mut done_t = 0u64;
                    let issue = m.amu.transfer(idv, *resume, exec, true, |t| {
                        done_t = msys.amu_transfer(addr, *bytes, space, AccessKind::Store, t);
                        done_t
                    });
                    if m.tracer.is_some() {
                        m.trace_transfer(idv, issue, done_t, true, space, *bytes);
                    }
                    m.core.commit(None, issue + 1, if issue > exec { Cause::Backpressure } else { Cause::Compute });
                }
                Inst::Aset { id, n } => {
                    m.amu.aset(m.val(*id), m.val(*n) as u32)?;
                    let exec = m.src_ready(d, &[*id, *n]);
                    m.core.commit(None, exec + 1, Cause::Compute);
                }
                Inst::Getfin { dst } => {
                    let exec = d;
                    let holds0 = if m.tracer.is_some() { m.amu.stat_sched_holds } else { 0 };
                    let v = match m.amu.pop_finished(exec) {
                        Some((id, _resume)) => {
                            if m.tracer.is_some() {
                                m.trace_pick(exec, id);
                            }
                            id
                        }
                        None => {
                            if m.tracer.is_some() {
                                m.trace_hold(exec, holds0);
                            }
                            -1
                        }
                    };
                    m.regs[*dst as usize] = v;
                    m.core.commit(Some(*dst), exec + 3, Cause::Compute);
                }
                Inst::Aconfig { base, size } => {
                    m.aconfig_base = m.val(*base);
                    m.aconfig_size = m.val(*size);
                    let exec = m.src_ready(d, &[*base, *size]);
                    m.core.commit(None, exec + 1, Cause::Compute);
                }
                Inst::Await { id, resume } => {
                    let exec = m.src_ready(d, &[*id]);
                    m.amu.await_register(m.val(*id), *resume, exec)?;
                    m.core.commit(None, exec + 1, Cause::Compute);
                    m.core.stats.awaits += 1;
                }
                Inst::Asignal { id } => {
                    let exec = m.src_ready(d, &[*id]);
                    m.amu.asignal(m.val(*id), exec)?;
                    m.core.commit(None, exec + 1, Cause::Compute);
                }
            }
        }
        // Terminator.
        if budget == 0 {
            bail!("dynamic instruction budget exhausted in {} at bb{}", m.func.name, bb);
        }
        budget -= 1;
        let d = m.core.dispatch(tag);
        match &blk.term {
            Term::Br { cond, then_, else_ } => {
                let taken = m.val(*cond) != 0;
                let exec = m.src_ready(d, &[*cond]);
                m.core.commit(None, exec + 1, Cause::Compute);
                m.core.stats.cond_branches += 1;
                if m.tage.predict_and_update(bb as u64, taken) {
                    m.core.stats.cond_mispredicts += 1;
                    m.core.redirect(exec + 1);
                }
                bb = if taken { *then_ } else { *else_ };
            }
            Term::Jmp(t) => {
                m.core.commit(None, d + 1, Cause::Compute);
                bb = *t;
            }
            Term::IndirectJmp { target } => {
                let tv = m.val(*target);
                if tv < 0 || tv as usize >= m.func.blocks.len() {
                    bail!("indirect jump to invalid block {tv} from bb{bb}");
                }
                let exec = m.src_ready(d, &[*target]);
                m.core.commit(None, exec + 1, Cause::Compute);
                m.core.stats.indirect_jumps += 1;
                let sched = tag == CodeTag::Scheduler;
                if m.ittage.predict_and_update(bb as u64, tv as u64, sched) {
                    m.core.stats.indirect_mispredicts += 1;
                    m.core.redirect(exec + 1);
                }
                if sched {
                    m.core.stats.switches += 1;
                }
                bb = tv as BlockId;
            }
            Term::Bafin { handler_dst, id_dst, fallthrough } => {
                // §IV-A oracle: outcome decided by the Finished-Queue state
                // at *fetch* time; the BTQ carries the id to the front end,
                // so a covered bafin never mispredicts.
                let fetch = d.saturating_sub(m.core.frontend_depth);
                let covered = m.bpt.covered(bb as u64);
                let holds0 = if m.tracer.is_some() { m.amu.stat_sched_holds } else { 0 };
                match m.amu.pop_finished(fetch) {
                    Some((id, resume)) => {
                        m.regs[*id_dst as usize] = id;
                        m.regs[*handler_dst as usize] =
                            m.aconfig_base.wrapping_add(id.wrapping_mul(m.aconfig_size));
                        m.core.commit(Some(*handler_dst), d + 1, Cause::Compute);
                        m.core.stats.bafins_taken += 1;
                        m.core.stats.switches += 1;
                        if !covered {
                            m.core.stats.bafin_mispredicts += 1;
                            m.core.redirect(d + 1);
                        }
                        if m.tracer.is_some() {
                            m.trace_pick(d, id);
                        }
                        bb = resume;
                    }
                    None => {
                        m.core.commit(None, d + 1, Cause::Compute);
                        m.core.stats.bafins_fallthrough += 1;
                        if m.tracer.is_some() {
                            m.trace_hold(fetch, holds0);
                        }
                        bb = *fallthrough;
                    }
                }
            }
            Term::Halt => break 'outer,
        }
    }

    let (stats, trace) = m.finish_traced();
    super::faults::check_strict(cfg, &stats)?;
    Ok((stats, trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::Operand::{Imm, Reg as R};

    fn make_prog(f: Function, mem: MemImage, init: Vec<(Reg, i64)>, fuse: bool) -> Program {
        Program::new(f, mem, init, 64, None, 10_000_000, fuse)
    }

    /// Run on the decoded path (fused and unfused), then assert the
    /// reference path agrees bit-for-bit on stats and memory — the
    /// per-test differential check.
    fn run_simple(f: Function, mem: MemImage, init: Vec<(Reg, i64)>) -> (RunStats, MemImage) {
        let cfg = SimConfig::nh_g();
        let mut p = make_prog(f.clone(), mem.snapshot(), init.clone(), true);
        let st = run(&cfg, &mut p).unwrap();
        let mut pu = make_prog(f.clone(), mem.snapshot(), init.clone(), false);
        let st_u = run(&cfg, &mut pu).unwrap();
        assert_eq!(st, st_u, "fused and unfused decoded stats diverge");
        let mut pref = make_prog(f, mem, init, false);
        let st_ref = run_reference(&cfg, &mut pref).unwrap();
        assert_eq!(st, st_ref, "decoded and reference stats diverge");
        for (a, b) in p.mem.regions.iter().zip(pref.mem.regions.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.data, b.data, "memory diverges in region {}", a.name);
        }
        for (a, b) in pu.mem.regions.iter().zip(pref.mem.regions.iter()) {
            assert_eq!(a.data, b.data, "unfused memory diverges in region {}", a.name);
        }
        (st, p.mem)
    }

    /// sum = Σ a[i] for i in 0..n over remote a.
    fn sum_program(n: i64) -> (Function, MemImage, Vec<(Reg, i64)>, Reg, u64) {
        let mut mem = MemImage::new();
        let base = mem.alloc("a", AddrSpace::Remote, (n as u64) * 8);
        for i in 0..n {
            mem.write(base + (i as u64) * 8, Width::W8, i * 2).unwrap();
        }
        let mut b = FuncBuilder::new("sum");
        let pb = b.reg();
        let pn = b.reg();
        let acc = b.reg();
        let i = b.reg();
        b.mov(acc, Imm(0));
        b.mov(i, Imm(0));
        let head = b.new_block("head", CodeTag::Compute);
        let body = b.new_block("body", CodeTag::Compute);
        let exit = b.new_block("exit", CodeTag::Compute);
        b.jmp(head);
        b.switch_to(head);
        let c = b.alu(AluOp::Slt, R(i), R(pn));
        b.br(R(c), body, exit);
        b.switch_to(body);
        let off = b.alu(AluOp::Shl, R(i), Imm(3));
        let addr = b.alu(AluOp::Add, R(pb), R(off));
        let v = b.load(R(addr), 0, Width::W8, AddrSpace::Remote);
        b.alu_into(acc, AluOp::Add, R(acc), R(v));
        b.alu_into(i, AluOp::Add, R(i), Imm(1));
        b.jmp(head);
        b.switch_to(exit);
        b.halt();
        (b.build(), mem, vec![(pb, base as i64), (pn, n)], acc, base)
    }

    #[test]
    fn functional_sum_is_correct() {
        let (f, mem, init, _acc, base) = sum_program(100);
        let (st, mem2) = run_simple(f, mem, init);
        // Values unchanged; check a read-back and stats plausibility.
        assert_eq!(mem2.read(base + 99 * 8, Width::W8).unwrap(), 198);
        assert_eq!(st.loads, 100);
        assert!(st.cycles > 0);
        assert!(st.ipc() > 0.0);
    }

    #[test]
    fn streaming_load_faster_than_random_thanks_to_lines() {
        // Sequential 8B loads: 8 per line, so ~n/8 far fetches.
        let (f, mem, init, _, _) = sum_program(512);
        let (st, _) = run_simple(f, mem, init);
        assert!(
            st.far_lines <= 80,
            "512 sequential 8B loads should fetch ~64 lines, got {}",
            st.far_lines
        );
    }

    #[test]
    fn budget_guard_fires_on_both_paths() {
        let mut b = FuncBuilder::new("inf");
        let l = b.new_block("l", CodeTag::Compute);
        b.jmp(l);
        b.switch_to(l);
        b.jmp(l);
        let f = b.build();
        let mut p = Program::new(f.clone(), MemImage::new(), vec![], 64, None, 1000, true);
        assert!(run(&SimConfig::nh_g(), &mut p).is_err());
        let mut pref = Program::new(f, MemImage::new(), vec![], 64, None, 1000, false);
        assert!(run_reference(&SimConfig::nh_g(), &mut pref).is_err());
    }

    #[test]
    fn amu_roundtrip_via_ir() {
        // aload remote -> spm, load from spm, check value.
        let mut mem = MemImage::new();
        let rem = mem.alloc("r", AddrSpace::Remote, 64);
        let spm = mem.alloc("spm", AddrSpace::Spm, 4096);
        mem.write(rem + 16, Width::W8, 777).unwrap();
        let mut b2 = FuncBuilder::new("amu2");
        let pr = b2.reg();
        let ps = b2.reg();
        let sched = b2.new_block("sched", CodeTag::Scheduler);
        let got = b2.new_block("got", CodeTag::Compute);
        b2.push(Inst::Aconfig { base: R(ps), size: Imm(64) });
        b2.push(Inst::Aload { id: Imm(3), base: R(pr), off: 16, bytes: 8, spm_off: 8, resume: got });
        b2.jmp(sched);
        b2.switch_to(sched);
        let h = b2.reg();
        let idr = b2.reg();
        b2.terminate(Term::Bafin { handler_dst: h, id_dst: idr, fallthrough: sched });
        b2.switch_to(got);
        let soff = b2.alu(AluOp::Mul, R(idr), Imm(64));
        let sa = b2.alu(AluOp::Add, R(ps), R(soff));
        let v = b2.load(R(sa), 8, Width::W8, AddrSpace::Spm);
        let out = b2.alu(AluOp::Add, R(v), Imm(1));
        let _ = out;
        b2.halt();
        let f = b2.build();
        let init = vec![(pr, rem as i64), (ps, spm as i64)];
        let cfg = SimConfig::nh_g();
        let mut p = Program::new(f.clone(), mem.snapshot(), init.clone(), 64, Some(ps), 1_000_000, true);
        let st = run(&cfg, &mut p).unwrap();
        // Reference path must agree exactly (AMU timing included).
        let mut pref = Program::new(f, mem, init, 64, Some(ps), 1_000_000, false);
        let st_ref = run_reference(&cfg, &mut pref).unwrap();
        assert_eq!(st, st_ref, "decoded and reference stats diverge on the AMU path");
        assert_eq!(st.aloads, 1);
        assert_eq!(st.bafins_taken, 1);
        assert!(st.bafins_fallthrough > 0, "should spin while the transfer is in flight");
        assert_eq!(st.bafin_mispredicts, 0, "bafin is oracle-predicted");
        // Functional: SPM slot 3, offset 8 holds 777.
        assert_eq!(p.mem.read(p.mem.region("spm").unwrap().base + 3 * 64 + 8, Width::W8).unwrap(), 777);
    }

    #[test]
    fn mix64_reference_values() {
        // Pinned values — the Python oracle (ref.py::mix64) must match.
        assert_eq!(mix64(0), 0);
        assert_eq!(mix64(1), 0xb456bcfc34c2cb2c);
        assert_eq!(mix64(42), 0x810879608e4259cc);
        assert_eq!(mix64(0xdeadbeef), 0xd24bd59f862a1dac);
    }

    /// Property: random small IR kernels (loops of ALU ops, loads and
    /// stores with data-dependent addresses) produce bit-identical stats
    /// and memory across all four execution paths: reference,
    /// decoded-unfused, decoded-fused, and decoded-fused re-run from a
    /// copy-on-write snapshot restore.
    #[test]
    fn proptest_all_four_paths_agree() {
        use crate::util::proptest::{check, env_cases, Config};
        check(
            Config { cases: env_cases(48), ..Config::default() },
            |g| g.rng.next_u64(),
            |seed: &u64| {
                let (f, mem, init) = random_program(*seed);
                // Rotate through the scheduler policies AND the far
                // fabrics so every path combination also runs under
                // every policy and every fabric backend (the nightly
                // workflow cranks the case count, so the full product is
                // covered there). These kernels carry no AMU ops, so the
                // policy must be timing-invisible here; the fabric moves
                // timing but must move all four paths identically.
                let policy = crate::sim::sched::SchedPolicyKind::ALL[(*seed % 4) as usize];
                let fabric = crate::sim::fabric::FabricKind::ALL[((*seed >> 2) % 4) as usize];
                let cfg = SimConfig::nh_g().with_sched_policy(policy).with_fabric(fabric);
                let mut progs = [
                    Program::new(f.clone(), mem.snapshot(), init.clone(), 64, None, 200_000, false),
                    Program::new(f.clone(), mem.snapshot(), init.clone(), 64, None, 200_000, true),
                    Program::new(f.clone(), mem.snapshot(), init.clone(), 64, None, 200_000, true),
                    Program::new(f, mem, init, 64, None, 200_000, false),
                ];
                let [pu, pf, ps, pr] = &mut progs;
                let results = [
                    ("decoded-unfused", run(&cfg, pu)),
                    ("decoded-fused", run(&cfg, pf)),
                    ("fused-after-restore", run(&cfg, ps)),
                    ("reference", run_reference(&cfg, pr)),
                ];
                let n_ok = results.iter().filter(|(_, r)| r.is_ok()).count();
                if n_ok == 0 {
                    return Ok(()); // all paths reject identically-shaped inputs
                }
                if n_ok != results.len() {
                    let states: Vec<String> =
                        results.iter().map(|(n, r)| format!("{n} ok={}", r.is_ok())).collect();
                    return Err(format!("paths disagree on failure: {}", states.join(", ")));
                }
                let base = results[0].1.as_ref().unwrap();
                for (name, r) in &results[1..] {
                    let s = r.as_ref().unwrap();
                    if s != base {
                        return Err(format!(
                            "stats diverge ({name} vs decoded-unfused):\n  {s:?}\n  {base:?}"
                        ));
                    }
                }
                let [pu, pf, ps, pr] = &progs;
                for other in [pf, ps, pr] {
                    for (a, b) in pu.mem.regions.iter().zip(other.mem.regions.iter()) {
                        if a.data != b.data {
                            return Err(format!("memory diverges in region {}", a.name));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Deterministic random kernel: a bounded loop whose body mixes ALU
    /// ops, loads and stores over a small remote array, with addresses
    /// masked in-bounds so both paths always succeed.
    fn random_program(seed: u64) -> (Function, MemImage, Vec<(Reg, i64)>) {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let words: u64 = 64;
        let mut mem = MemImage::new();
        let base = mem.alloc("arr", AddrSpace::Remote, words * 8);
        for j in 0..words {
            mem.write(base + j * 8, Width::W8, (rng.next_u64() & 0xFFFF) as i64).unwrap();
        }
        let mut b = FuncBuilder::new("rand");
        let pb = b.reg();
        let pn = b.reg();
        let i = b.reg();
        b.mov(i, Imm(0));
        // A small pool of value registers the random body reads/writes.
        let pool: Vec<Reg> = (0..4).map(|_| b.reg()).collect();
        for (k, r) in pool.iter().enumerate() {
            b.mov(*r, Imm(k as i64 + 1));
        }
        let head = b.new_block("head", CodeTag::Compute);
        let body = b.new_block("body", CodeTag::Compute);
        let exit = b.new_block("exit", CodeTag::Compute);
        b.jmp(head);
        b.switch_to(head);
        let c = b.alu(AluOp::Slt, R(i), R(pn));
        b.br(R(c), body, exit);
        b.switch_to(body);
        let nops = 2 + (rng.below(6) as usize);
        let alu_ops = [AluOp::Add, AluOp::Sub, AluOp::Mul, AluOp::Xor, AluOp::And, AluOp::Or, AluOp::Hash, AluOp::Min];
        for _ in 0..nops {
            let dst = pool[rng.below(pool.len() as u64) as usize];
            match rng.below(4) {
                0 | 1 => {
                    let op = alu_ops[rng.below(alu_ops.len() as u64) as usize];
                    let a = pool[rng.below(pool.len() as u64) as usize];
                    let bo = if rng.bool() {
                        R(pool[rng.below(pool.len() as u64) as usize])
                    } else {
                        Imm(rng.below(100) as i64)
                    };
                    b.alu_into(dst, op, R(a), bo);
                }
                2 => {
                    // Load from a data-dependent, masked index.
                    let src = pool[rng.below(pool.len() as u64) as usize];
                    let idx = b.alu(AluOp::And, R(src), Imm((words - 1) as i64));
                    let off = b.alu(AluOp::Shl, R(idx), Imm(3));
                    let addr = b.alu(AluOp::Add, R(pb), R(off));
                    b.load_into(dst, R(addr), 0, Width::W8, AddrSpace::Remote);
                }
                _ => {
                    // Store a pool value to a masked index.
                    let sv = pool[rng.below(pool.len() as u64) as usize];
                    let si = pool[rng.below(pool.len() as u64) as usize];
                    let idx = b.alu(AluOp::And, R(si), Imm((words - 1) as i64));
                    let off = b.alu(AluOp::Shl, R(idx), Imm(3));
                    let addr = b.alu(AluOp::Add, R(pb), R(off));
                    b.store(R(sv), R(addr), 0, Width::W8, AddrSpace::Remote);
                }
            }
        }
        b.alu_into(i, AluOp::Add, R(i), Imm(1));
        b.jmp(head);
        b.switch_to(exit);
        b.halt();
        let trip = 4 + (rng.below(28) as i64);
        (b.build(), mem, vec![(pb, base as i64), (pn, trip)])
    }
}
