//! Branch prediction unit: TAGE (conditional), ITTAGE (indirect), and the
//! CoroAMU Bafin Predict Table (§IV-A).
//!
//! The predictors run on the dynamic stream: the simulator asks for a
//! prediction before resolving each branch, then trains with the actual
//! outcome. The scheduler's coroutine-resume indirect jump is what ITTAGE
//! faces in CoroAMU-D — with dynamically scheduled (memory-arrival-ordered)
//! targets it degrades to chance, producing the >15% mispredict overhead of
//! Fig. 14 that `bafin` then eliminates by consuming the Finished-Queue
//! oracle through the BTQ.

use crate::config::BpuConfig;

/// "PC" of a CoroIR branch: (block id, role). Good enough for indexing.
pub type Pc = u64;

#[derive(Debug, Clone, Copy)]
struct TageEntry {
    tag: u16,
    ctr: i8, // -4..3 (taken if >= 0)
    useful: u8,
}

#[derive(Debug)]
pub struct Tage {
    base: Vec<i8>, // bimodal
    tables: Vec<Vec<TageEntry>>,
    hist_lens: Vec<u32>,
    ghist: u64,
    log_entries: usize,
    pub stat_lookups: u64,
    pub stat_mispredicts: u64,
}

impl Tage {
    pub fn new(cfg: &BpuConfig) -> Self {
        let nt = cfg.tage_tables;
        let hist_lens = (0..nt).map(|i| 4u32 << i).collect();
        Tage {
            base: vec![0; 4096],
            tables: (0..nt)
                .map(|_| vec![TageEntry { tag: 0, ctr: 0, useful: 0 }; 1 << cfg.tage_log_entries])
                .collect(),
            hist_lens,
            ghist: 0,
            log_entries: cfg.tage_log_entries,
            stat_lookups: 0,
            stat_mispredicts: 0,
        }
    }

    fn fold(&self, pc: Pc, hlen: u32) -> (usize, u16) {
        let h = if hlen >= 64 { self.ghist } else { self.ghist & ((1u64 << hlen) - 1) };
        let mixed = pc ^ h ^ (h >> 17) ^ (h >> 31) ^ (pc << 7);
        let idx = (mixed ^ (mixed >> self.log_entries as u32 as u64)) as usize & ((1 << self.log_entries) - 1);
        let tag = ((mixed >> 13) & 0x3FF) as u16 | 1;
        (idx, tag)
    }

    fn predict_components(&self, pc: Pc) -> (Option<usize>, bool) {
        // Longest matching table wins.
        for ti in (0..self.tables.len()).rev() {
            let (idx, tag) = self.fold(pc, self.hist_lens[ti]);
            let e = &self.tables[ti][idx];
            if e.tag == tag {
                return (Some(ti), e.ctr >= 0);
            }
        }
        (None, self.base[pc as usize & 4095] >= 0)
    }

    /// Predict, train, and return whether the prediction was wrong.
    pub fn predict_and_update(&mut self, pc: Pc, taken: bool) -> bool {
        self.stat_lookups += 1;
        let (provider, pred) = self.predict_components(pc);
        let mispredict = pred != taken;
        if mispredict {
            self.stat_mispredicts += 1;
        }
        // Train provider (or base).
        match provider {
            Some(ti) => {
                let (idx, _) = self.fold(pc, self.hist_lens[ti]);
                let e = &mut self.tables[ti][idx];
                e.ctr = (e.ctr + if taken { 1 } else { -1 }).clamp(-4, 3);
                if !mispredict {
                    e.useful = e.useful.saturating_add(1);
                }
                // On mispredict, allocate in a longer table.
                if mispredict && ti + 1 < self.tables.len() {
                    let (aidx, atag) = self.fold(pc, self.hist_lens[ti + 1]);
                    let a = &mut self.tables[ti + 1][aidx];
                    if a.useful == 0 {
                        *a = TageEntry { tag: atag, ctr: if taken { 0 } else { -1 }, useful: 0 };
                    } else {
                        a.useful -= 1;
                    }
                }
            }
            None => {
                let b = &mut self.base[pc as usize & 4095];
                *b = (*b + if taken { 1 } else { -1 }).clamp(-2, 1);
                if mispredict && !self.tables.is_empty() {
                    let (aidx, atag) = self.fold(pc, self.hist_lens[0]);
                    let a = &mut self.tables[0][aidx];
                    if a.useful == 0 {
                        *a = TageEntry { tag: atag, ctr: if taken { 0 } else { -1 }, useful: 0 };
                    } else {
                        a.useful -= 1;
                    }
                }
            }
        }
        self.ghist = (self.ghist << 1) | taken as u64;
        mispredict
    }
}

#[derive(Debug, Clone, Copy)]
struct ItEntry {
    tag: u16,
    target: u64,
    conf: i8,
}

/// ITTAGE-lite: tagged target tables with geometric histories + a
/// PC-indexed last-target base table.
#[derive(Debug)]
pub struct Ittage {
    base: Vec<u64>,
    tables: Vec<Vec<ItEntry>>,
    hist_lens: Vec<u32>,
    /// Path history of recent indirect targets.
    thist: u64,
    log_entries: usize,
    pub stat_lookups: u64,
    pub stat_mispredicts: u64,
    /// Subset of lookups/mispredicts on *scheduler* indirect jumps (the
    /// coroutine-resume dispatch) — the Fig. 14 overhead the scheduler
    /// policy controls: a static-order policy produces a learnable
    /// target stream, a memory-arrival one degrades ITTAGE to chance.
    pub stat_sched_lookups: u64,
    pub stat_sched_mispredicts: u64,
}

impl Ittage {
    pub fn new(cfg: &BpuConfig) -> Self {
        let nt = 3;
        Ittage {
            base: vec![u64::MAX; 1024],
            tables: (0..nt)
                .map(|_| vec![ItEntry { tag: 0, target: u64::MAX, conf: 0 }; 1 << cfg.ittage_log_entries])
                .collect(),
            hist_lens: vec![4, 12, 32],
            thist: 0,
            log_entries: cfg.ittage_log_entries,
            stat_lookups: 0,
            stat_mispredicts: 0,
            stat_sched_lookups: 0,
            stat_sched_mispredicts: 0,
        }
    }

    fn fold(&self, pc: Pc, hlen: u32) -> (usize, u16) {
        let h = if hlen >= 64 { self.thist } else { self.thist & ((1u64 << hlen) - 1) };
        let mixed = pc.wrapping_mul(0x9E37_79B9) ^ h ^ (h >> 11) ^ (h >> 23);
        let idx = (mixed ^ (mixed >> self.log_entries as u32 as u64)) as usize & ((1 << self.log_entries) - 1);
        let tag = ((mixed >> 15) & 0x3FF) as u16 | 1;
        (idx, tag)
    }

    /// Predict, train, and return whether the prediction was wrong.
    /// `sched` marks the scheduler's coroutine-resume dispatch so its
    /// mispredicts are attributable separately from data-dependent
    /// indirect jumps.
    pub fn predict_and_update(&mut self, pc: Pc, actual: u64, sched: bool) -> bool {
        self.stat_lookups += 1;
        if sched {
            self.stat_sched_lookups += 1;
        }
        let mut pred = self.base[pc as usize & 1023];
        let mut provider: Option<usize> = None;
        for ti in (0..self.tables.len()).rev() {
            let (idx, tag) = self.fold(pc, self.hist_lens[ti]);
            let e = &self.tables[ti][idx];
            if e.tag == tag && e.conf >= 0 {
                pred = e.target;
                provider = Some(ti);
                break;
            }
        }
        let mispredict = pred != actual;
        if mispredict {
            self.stat_mispredicts += 1;
            if sched {
                self.stat_sched_mispredicts += 1;
            }
        }
        // Train.
        self.base[pc as usize & 1023] = actual;
        match provider {
            Some(ti) => {
                let (idx, _) = self.fold(pc, self.hist_lens[ti]);
                let e = &mut self.tables[ti][idx];
                if e.target == actual {
                    e.conf = (e.conf + 1).min(3);
                } else {
                    e.conf -= 1;
                    if e.conf < -1 {
                        e.target = actual;
                        e.conf = 0;
                    }
                }
            }
            None => {}
        }
        if mispredict {
            // Allocate with a longer history.
            let start = provider.map(|p| p + 1).unwrap_or(0);
            if start < self.tables.len() {
                let (idx, tag) = self.fold(pc, self.hist_lens[start]);
                let e = &mut self.tables[start][idx];
                if e.conf <= 0 {
                    *e = ItEntry { tag, target: actual, conf: 0 };
                }
            }
        }
        self.thist = (self.thist << 4) ^ actual ^ (self.thist >> 60);
        mispredict
    }
}

/// The 4-entry Bafin Predict Table. The oracle property (§IV-A): a bafin's
/// outcome is decided by the Finished-Queue state *at fetch time*, and the
/// BTQ delivers exactly that id to the front end, so prediction is always
/// correct. We model the structure (entries indexed by PC) so that programs
/// with more distinct bafin PCs than entries would lose coverage.
///
/// Coverage is additionally a property of the scheduler policy
/// (`sim::sched`): the BTQ forwards the id the AMU's *memory-guided*
/// resume order will pop. A software-imposed static order (the `Fifo`
/// policy) is not derivable from Finished-Queue state at fetch, so the
/// table is built unguided and every dispatching bafin mispredicts.
#[derive(Debug)]
pub struct BafinPredictTable {
    pcs: Vec<Pc>,
    cap: usize,
    /// Whether the active scheduler policy is memory-guided
    /// ([`crate::sim::sched::SchedPolicy::btq_guided`]).
    guided: bool,
    pub stat_lookups: u64,
    pub stat_mispredicts: u64,
}

impl BafinPredictTable {
    pub fn new(cfg: &BpuConfig, guided: bool) -> Self {
        BafinPredictTable {
            pcs: Vec::new(),
            cap: cfg.bpt_entries.max(1),
            guided,
            stat_lookups: 0,
            stat_mispredicts: 0,
        }
    }

    /// Returns true if this bafin PC is covered by the BPT (tracked or
    /// allocatable, under a memory-guided policy); uncovered bafins
    /// predict like a plain not-taken branch and mispredict whenever
    /// they dispatch a coroutine. Allocation/replacement runs regardless
    /// of guidance so the table's occupancy sequence is policy-blind.
    pub fn covered(&mut self, pc: Pc) -> bool {
        self.stat_lookups += 1;
        if self.pcs.contains(&pc) {
            return self.guided;
        }
        if self.pcs.len() < self.cap {
            self.pcs.push(pc);
            return self.guided;
        }
        // FIFO replacement on overflow.
        self.pcs.remove(0);
        self.pcs.push(pc);
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::util::rng::Rng;

    fn cfg() -> BpuConfig {
        SimConfig::nh_g().bpu
    }

    #[test]
    fn tage_learns_loop_branch() {
        let mut t = Tage::new(&cfg());
        // 9 taken, 1 not-taken, repeating (loop of 10 iterations).
        for i in 0..20_000u64 {
            t.predict_and_update(42, i % 10 != 9);
        }
        let rate = t.stat_mispredicts as f64 / t.stat_lookups as f64;
        assert!(rate < 0.05, "TAGE mispredict rate {rate} on periodic loop branch");
    }

    #[test]
    fn tage_fails_on_random_as_expected() {
        let mut t = Tage::new(&cfg());
        let mut rng = Rng::new(1);
        for _ in 0..20_000 {
            t.predict_and_update(42, rng.bool());
        }
        let rate = t.stat_mispredicts as f64 / t.stat_lookups as f64;
        assert!(rate > 0.35, "random branch should be near-chance, got {rate}");
    }

    #[test]
    fn ittage_learns_fixed_target() {
        let mut it = Ittage::new(&cfg());
        for _ in 0..10_000 {
            it.predict_and_update(7, 0x1234, false);
        }
        let rate = it.stat_mispredicts as f64 / it.stat_lookups as f64;
        assert!(rate < 0.01);
    }

    #[test]
    fn ittage_learns_short_cycle() {
        let mut it = Ittage::new(&cfg());
        let targets = [10u64, 20, 30, 40];
        for i in 0..40_000usize {
            it.predict_and_update(7, targets[i % 4], false);
        }
        let rate = it.stat_mispredicts as f64 / it.stat_lookups as f64;
        assert!(rate < 0.15, "periodic indirect pattern should be learnable, got {rate}");
    }

    #[test]
    fn ittage_near_chance_on_random_targets() {
        // The CoroAMU-D scheduler case: resume targets in memory-arrival
        // order are effectively random.
        let mut it = Ittage::new(&cfg());
        let mut rng = Rng::new(3);
        let targets: Vec<u64> = (0..16).map(|i| 100 + i * 10).collect();
        for _ in 0..40_000 {
            let t = targets[rng.below(16) as usize];
            it.predict_and_update(7, t, true);
        }
        let rate = it.stat_mispredicts as f64 / it.stat_lookups as f64;
        assert!(rate > 0.5, "random 16-target indirect jump should mispredict often, got {rate}");
    }

    #[test]
    fn bpt_covers_few_bafins() {
        let mut b = BafinPredictTable::new(&cfg(), true);
        assert!(b.covered(1));
        assert!(b.covered(1));
        for pc in 2..=4 {
            assert!(b.covered(pc));
        }
        // Fifth distinct PC overflows the 4-entry table.
        assert!(!b.covered(99));
    }
}
