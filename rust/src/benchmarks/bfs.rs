//! BFS (Graph500 representative): one level expansion over a CSR graph.
//! Remote structures: `graph` (vlist/elist) and `bfs_tree` (levels).
//! The frontier is local bookkeeping. Level marking is idempotent
//! (`levels[v] = L+1` always writes the same value), so the final levels
//! array is deterministic across coroutine interleavings even though the
//! next-frontier order (and possible duplicates) is not — exactly the
//! benign-race structure the paper relies on (§III-E).

use super::{BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, AluOp, Width};
use crate::sim::MemImage;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub struct Bfs;

fn bin(op: AluOp, a: Expr, b: Expr) -> Expr {
    Expr::Bin(BinOp::I(op), Box::new(a), Box::new(b))
}

pub fn kernel() -> Kernel {
    let mut kb = KernelBuilder::new("bfs");
    let vlist = kb.param_ptr("vlist", AddrSpace::Remote);
    let elist = kb.param_ptr("elist", AddrSpace::Remote);
    let levels = kb.param_ptr("bfs_tree", AddrSpace::Remote);
    let frontier = kb.param_ptr("frontier", AddrSpace::Local);
    let nextf = kb.param_ptr("next_frontier", AddrSpace::Local);
    let lvl = kb.param_val("next_level");
    let n = kb.param_val("frontier_len");
    kb.trip(n);
    kb.num_tasks(64);
    let u = kb.var("u");
    let off = kb.var("off");
    let end = kb.var("end");
    let v = kb.var("v");
    let lv = kb.var("lv");
    let tail = kb.var("tail");
    // `tail` is read in push addresses, so static analysis calls it
    // ambiguous; the push (store+increment) never spans a suspension, so
    // it is safe to share — the paper's pragma hint mechanism.
    kb.shared_var(tail);
    kb.build(vec![
        Stmt::Load {
            var: u,
            addr: Expr::add(Expr::Param(frontier), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))),
            width: Width::W8,
        },
        // vlist[u], vlist[u+1]: constant delta 8 -> coarse pair.
        Stmt::Load {
            var: off,
            addr: Expr::add(Expr::Param(vlist), Expr::shl(Expr::Var(u), Expr::Imm(3))),
            width: Width::W8,
        },
        Stmt::Load {
            var: end,
            addr: Expr::add(
                Expr::Param(vlist),
                Expr::add(Expr::shl(Expr::Var(u), Expr::Imm(3)), Expr::Imm(8)),
            ),
            width: Width::W8,
        },
        Stmt::While {
            cond: bin(AluOp::Slt, Expr::Var(off), Expr::Var(end)),
            body: vec![
                Stmt::Load {
                    var: v,
                    addr: Expr::add(Expr::Param(elist), Expr::shl(Expr::Var(off), Expr::Imm(3))),
                    width: Width::W8,
                },
                Stmt::Load {
                    var: lv,
                    addr: Expr::add(Expr::Param(levels), Expr::shl(Expr::Var(v), Expr::Imm(3))),
                    width: Width::W8,
                },
                Stmt::If {
                    cond: bin(AluOp::Seq, Expr::Var(lv), Expr::Imm(-1)),
                    then_: vec![
                        Stmt::Store {
                            val: Expr::Param(lvl),
                            addr: Expr::add(Expr::Param(levels), Expr::shl(Expr::Var(v), Expr::Imm(3))),
                            width: Width::W8,
                        },
                        Stmt::Store {
                            val: Expr::Var(v),
                            addr: Expr::add(Expr::Param(nextf), Expr::shl(Expr::Var(tail), Expr::Imm(3))),
                            width: Width::W8,
                        },
                        Stmt::Let { var: tail, expr: bin(AluOp::Add, Expr::Var(tail), Expr::Imm(1)) },
                    ],
                    else_: vec![],
                },
                Stmt::Let { var: off, expr: bin(AluOp::Add, Expr::Var(off), Expr::Imm(1)) },
            ],
        },
    ])
}

/// (nodes, edges)
pub fn sizes(scale: Scale) -> (u64, u64) {
    match scale {
        Scale::Tiny => (1 << 9, 1 << 11),
        Scale::Small => (1 << 11, 1 << 13),
        Scale::Full => (1 << 17, 1 << 20), // 8MB elist + 1MB levels
    }
}

/// Build a uniform random multigraph in CSR form + run native BFS.
pub struct GraphData {
    pub vlist: Vec<i64>,
    pub elist: Vec<i64>,
    pub levels: Vec<i64>,
    /// Frontier at the chosen level.
    pub frontier: Vec<i64>,
    pub next_level: i64,
}

pub fn gen_graph(nodes: u64, edges: u64, seed: u64) -> GraphData {
    let mut rng = Rng::new(seed);
    let mut adj: Vec<Vec<i64>> = vec![Vec::new(); nodes as usize];
    for _ in 0..edges {
        // Mild skew: square one endpoint draw toward low ids so the graph
        // has hubs (RMAT-ish degree skew).
        let u = (rng.below(nodes) * rng.below(nodes) / nodes.max(1)) as usize;
        let v = rng.below(nodes) as usize;
        adj[u].push(v as i64);
        adj[v].push(u as i64);
    }
    let mut vlist = Vec::with_capacity(nodes as usize + 1);
    let mut elist = Vec::new();
    vlist.push(0);
    for a in &adj {
        elist.extend_from_slice(a);
        vlist.push(elist.len() as i64);
    }
    // Native BFS from node 0.
    let mut levels = vec![-1i64; nodes as usize];
    levels[0] = 0;
    let mut frontiers: Vec<Vec<i64>> = vec![vec![0]];
    loop {
        let cur = frontiers.last().unwrap().clone();
        let mut next = Vec::new();
        let l = frontiers.len() as i64;
        for &u in &cur {
            for &v in &adj[u as usize] {
                if levels[v as usize] == -1 {
                    levels[v as usize] = l;
                    next.push(v);
                }
            }
        }
        if next.is_empty() {
            break;
        }
        frontiers.push(next);
    }
    // Pick the largest frontier; the kernel expands it one level.
    let (best, _) = frontiers
        .iter()
        .enumerate()
        .max_by_key(|(_, f)| f.len())
        .expect("nonempty");
    let frontier = frontiers[best].clone();
    let next_level = best as i64 + 1;
    // Roll `levels` back to the state before `next_level` was assigned.
    let mut pre_levels = levels.clone();
    for (v, l) in levels.iter().enumerate() {
        if *l >= next_level {
            pre_levels[v] = -1;
        }
    }
    GraphData { vlist, elist, levels: pre_levels, frontier, next_level }
}

impl Benchmark for Bfs {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "bfs", suite: "Graph500", remote: "graph, bfs_tree, vlist" }
    }

    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance> {
        let (nodes, edges) = sizes(scale);
        let g = gen_graph(nodes, edges, seed);
        let mut mem = MemImage::new();
        let vl = mem.alloc_init_i64("vlist", AddrSpace::Remote, &g.vlist);
        let el = mem.alloc_init_i64("elist", AddrSpace::Remote, &g.elist);
        let lv = mem.alloc_init_i64("bfs_tree", AddrSpace::Remote, &g.levels);
        let fr = mem.alloc_init_i64("frontier", AddrSpace::Local, &g.frontier);
        let nf = mem.alloc("next_frontier", AddrSpace::Local, (g.elist.len().max(1) as u64) * 8);
        // Expected: levels after expanding exactly one level natively.
        let mut expected = g.levels.clone();
        for &u in &g.frontier {
            let (s, e) = (g.vlist[u as usize], g.vlist[u as usize + 1]);
            for k in s..e {
                let v = g.elist[k as usize] as usize;
                if expected[v] == -1 {
                    expected[v] = g.next_level;
                }
            }
        }
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("bfs_tree").expect("bfs_tree region");
            for (j, want) in expected.iter().enumerate() {
                let got = m.read(r.base + (j as u64) * 8, Width::W8)?;
                ensure!(got == *want, "levels[{j}] = {got}, want {want}");
            }
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(),
            mem,
            params: vec![
                vl as i64,
                el as i64,
                lv as i64,
                fr as i64,
                nf as i64,
                g.next_level,
                g.frontier.len() as i64,
            ],
            check: std::sync::Arc::new(check),
            default_tasks: 64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;

    #[test]
    fn graph_is_consistent() {
        let g = gen_graph(256, 1024, 3);
        assert_eq!(g.vlist.len(), 257);
        assert_eq!(*g.vlist.last().unwrap() as usize, g.elist.len());
        assert!(!g.frontier.is_empty());
        assert!(g.next_level >= 1);
        for &v in &g.elist {
            assert!((v as usize) < 256);
        }
    }

    #[test]
    fn all_variants_pass_oracle_and_amu_wins() {
        let rs = run_all_variants(&Bfs);
        let serial = rs[0].1.cycles as f64;
        let full = rs[4].1.cycles as f64;
        assert!(serial / full > 1.3, "BFS Full speedup {:.2}", serial / full);
    }
}
