//! The paper's eight memory-bound benchmarks (Table II), written against
//! the compiler's kernel AST with their remote structures allocated in the
//! far-memory address space.
//!
//! | Suite        | Benchmark | Remote structures            |
//! |--------------|-----------|------------------------------|
//! | HPCC         | GUPS      | table                        |
//! | Binary Search| BS        | sorted_array                 |
//! | Graph500     | BFS       | graph (vlist/elist), bfs_tree|
//! | STREAM       | STREAM    | a, b, c                      |
//! | Hash Join    | HJ        | tuples, ht->buckets          |
//! | SPEC2017     | mcf       | net->nodes, net->arcs        |
//! | SPEC2017     | lbm       | srcGrid, dstGrid             |
//! | NPB          | IS        | keys, histogram              |
//!
//! mcf/lbm/IS are representative kernels of the SPEC/NPB originals (arc
//! price scan, 5-point stream-collide step, key histogram); `DESIGN.md` §1
//! (repo root) documents the substitution.

pub mod bfs;
pub mod bs;
pub mod gups;
pub mod hj;
pub mod is;
pub mod lbm;
pub mod mcf;
pub mod stream;

use crate::compiler::ast::Kernel;
use crate::sim::MemImage;
use anyhow::Result;
use std::sync::Arc;

/// Problem scale. `Tiny` uses the fixed shapes shared with the AOT JAX
/// oracle artifacts (see [`oracle_shapes`]); `Small` runs in unit tests;
/// `Full` is used by the figure harness (datasets exceed the LLC).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scale {
    Tiny,
    Small,
    Full,
}

/// Fixed shapes for the Python-side golden-model artifacts. The AOT HLO
/// is lowered once at these shapes; `Scale::Tiny` instances match them so
/// the PJRT runtime can cross-validate simulator memory.
pub mod oracle_shapes {
    pub const GUPS_TABLE: u64 = 4096;
    pub const GUPS_N: u64 = 512;
    pub const STREAM_N: u64 = 4096;
    pub const BS_KEYS: u64 = 4096;
    pub const BS_QUERIES: u64 = 256;
    pub const HJ_BUCKETS: u64 = 512;
    pub const HJ_TUPLES: u64 = 1024;
}

/// A fully materialized benchmark run: kernel + datasets + oracle.
///
/// The oracle is `Arc`-shared (and the memory image copy-on-write), so
/// the engine's dataset cache can hand out per-run instances without
/// regenerating datasets or recomputing expected results — see
/// `Engine::sweep`.
pub struct Instance {
    pub kernel: Kernel,
    pub mem: MemImage,
    pub params: Vec<i64>,
    /// Native oracle: validates the final memory image.
    pub check: Arc<dyn Fn(&MemImage) -> Result<()> + Send + Sync>,
    /// Default concurrency used by the paper for this workload.
    pub default_tasks: usize,
}

/// Static description (Table II row).
#[derive(Debug, Clone, Copy)]
pub struct BenchSpec {
    pub name: &'static str,
    pub suite: &'static str,
    pub remote: &'static str,
}

pub trait Benchmark: Sync {
    fn spec(&self) -> BenchSpec;
    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance>;
}

/// All eight benchmarks, in Table II order.
pub fn all() -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(gups::Gups),
        Box::new(bs::BinarySearch),
        Box::new(bfs::Bfs),
        Box::new(stream::Stream),
        Box::new(hj::HashJoin),
        Box::new(mcf::Mcf),
        Box::new(lbm::Lbm),
        Box::new(is::IntSort),
    ]
}

pub fn by_name(name: &str) -> Option<Box<dyn Benchmark>> {
    all().into_iter().find(|b| b.spec().name.eq_ignore_ascii_case(name))
}

/// Table II rendered from the registry.
pub fn table2() -> crate::util::table::Table {
    let mut t = crate::util::table::Table::new(
        "Table II: Benchmarks and transformed structures",
        &["Suite", "Benchmark", "Remote Structure"],
    );
    for b in all() {
        let s = b.spec();
        t.row(vec![s.suite.into(), s.name.into(), s.remote.into()]);
    }
    t
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::compiler::Variant;
    use crate::config::SimConfig;
    use crate::sim::RunStats;

    /// Run a benchmark at Small scale across all five variants through one
    /// engine session, checking the oracle each time; returns
    /// (variant, stats).
    pub fn run_all_variants(b: &dyn Benchmark) -> Vec<(Variant, RunStats)> {
        let engine = crate::engine::Engine::new(SimConfig::nh_g());
        Variant::ALL
            .iter()
            .map(|v| {
                let name = b.spec().name;
                let tasks = if v.needs_amu() { 96 } else { 16 };
                let req = crate::engine::RunRequest::new(name, *v).tasks(tasks).scale(Scale::Small);
                let r = engine
                    .run(req)
                    .unwrap_or_else(|e| panic!("{} under {}: {e:#}", name, v.label()));
                (*v, r.stats)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_eight_in_table2_order() {
        let names: Vec<&str> = all().iter().map(|b| b.spec().name).collect();
        assert_eq!(names, vec!["gups", "bs", "bfs", "stream", "hj", "mcf", "lbm", "is"]);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("GUPS").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn table2_renders() {
        let s = table2().render();
        assert!(s.contains("Graph500"));
        assert!(s.contains("sorted_array"));
    }
}
