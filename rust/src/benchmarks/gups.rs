//! GUPS (HPCC RandomAccess): read-modify-write updates to random slots of
//! a giant table. Remote structure: `table`. The update stream uses a
//! bijective multiplicative permutation so indices are collision-free —
//! the result is then independent of coroutine interleaving (HPCC itself
//! tolerates racy updates; we need exactness for oracle checking).

use super::{oracle_shapes, BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, AluOp, Width};
use crate::sim::MemImage;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub struct Gups;

pub const PERM: i64 = 0x9E37_79B9; // odd => bijective mod 2^k

pub fn kernel() -> Kernel {
    let mut kb = KernelBuilder::new("gups");
    let tab = kb.param_ptr("table", AddrSpace::Remote);
    let mask = kb.param_val("mask");
    let n = kb.param_val("num_updates");
    kb.trip(n);
    kb.num_tasks(64);
    let idx = kb.var("idx");
    let v = kb.var("v");
    let addr = Expr::add(Expr::Param(tab), Expr::shl(Expr::Var(idx), Expr::Imm(3)));
    kb.build(vec![
        Stmt::Let {
            var: idx,
            expr: Expr::and(Expr::mul(Expr::Var(ITER_VAR), Expr::Imm(PERM)), Expr::Param(mask)),
        },
        Stmt::Load { var: v, addr: addr.clone(), width: Width::W8 },
        Stmt::Store {
            val: Expr::Bin(
                BinOp::I(AluOp::Add),
                Box::new(Expr::Var(v)),
                Box::new(Expr::Bin(BinOp::I(AluOp::Or), Box::new(Expr::Var(idx)), Box::new(Expr::Imm(1)))),
            ),
            addr,
            width: Width::W8,
        },
    ])
}

pub fn sizes(scale: Scale) -> (u64, u64) {
    match scale {
        Scale::Tiny => (oracle_shapes::GUPS_TABLE, oracle_shapes::GUPS_N),
        Scale::Small => (1 << 13, 1200),
        Scale::Full => (1 << 21, 100_000), // 16 MB table >> LLC
    }
}

impl Benchmark for Gups {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "gups", suite: "HPCC", remote: "Table" }
    }

    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance> {
        let (words, n) = sizes(scale);
        let mut mem = MemImage::new();
        let mut rng = Rng::new(seed);
        let init: Vec<i64> = (0..words).map(|_| (rng.next_u64() >> 1) as i64).collect();
        let tab = mem.alloc_init_i64("table", AddrSpace::Remote, &init);
        // Native oracle.
        let mask = (words - 1) as i64;
        let mut expected = init;
        for i in 0..n as i64 {
            let idx = (i.wrapping_mul(PERM)) & mask;
            expected[idx as usize] = expected[idx as usize].wrapping_add(idx | 1);
        }
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("table").expect("table region");
            for (j, want) in expected.iter().enumerate() {
                let got = m.read(r.base + (j as u64) * 8, Width::W8)?;
                ensure!(got == *want, "table[{j}] = {got}, want {want}");
            }
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(),
            mem,
            params: vec![tab as i64, mask, n as i64],
            check: std::sync::Arc::new(check),
            default_tasks: 64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;

    #[test]
    fn all_variants_pass_oracle_and_amu_wins() {
        let rs = run_all_variants(&Gups);
        let serial = rs[0].1.cycles as f64;
        let full = rs[4].1.cycles as f64;
        assert!(serial / full > 1.5, "GUPS Full speedup {:.2}", serial / full);
    }

    #[test]
    fn indices_are_distinct() {
        let (words, n) = sizes(Scale::Small);
        let mask = (words - 1) as i64;
        let mut seen = std::collections::HashSet::new();
        for i in 0..n as i64 {
            assert!(seen.insert(i.wrapping_mul(PERM) & mask), "collision at {i}");
        }
    }
}
