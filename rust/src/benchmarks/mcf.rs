//! mcf (505.mcf_r representative kernel): reduced-cost scan over the arc
//! array. Remote structures: `net->nodes` (potentials), `net->arcs`. Each
//! arc record fetch is a coarse-merge candidate; the two node-potential
//! loads are independent random accesses that fuse under one `aset` id.

use super::{BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, AluOp, Width};
use crate::sim::MemImage;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub struct Mcf;

const ARC_BYTES: i64 = 32; // {tail, head, cost, pad}

fn bin(op: AluOp, a: Expr, b: Expr) -> Expr {
    Expr::Bin(BinOp::I(op), Box::new(a), Box::new(b))
}

pub fn kernel() -> Kernel {
    let mut kb = KernelBuilder::new("mcf");
    let arcs = kb.param_ptr("arcs", AddrSpace::Remote);
    let nodes = kb.param_ptr("nodes", AddrSpace::Remote);
    let res = kb.param_ptr("result", AddrSpace::Local);
    let n = kb.param_val("num_arcs");
    kb.trip(n);
    kb.num_tasks(64);
    let tail = kb.var("tail");
    let head = kb.var("head");
    let cost = kb.var("cost");
    let pt = kb.var("pt");
    let ph = kb.var("ph");
    let red = kb.var("red");
    let neg = kb.var("neg");
    kb.shared_var(neg);
    let arc_base = Expr::add(Expr::Param(arcs), Expr::mul(Expr::Var(ITER_VAR), Expr::Imm(ARC_BYTES)));
    kb.build(vec![
        // Arc record: three constant-delta loads -> one coarse fetch.
        Stmt::Load { var: tail, addr: arc_base.clone(), width: Width::W8 },
        Stmt::Load { var: head, addr: Expr::add(arc_base.clone(), Expr::Imm(8)), width: Width::W8 },
        Stmt::Load { var: cost, addr: Expr::add(arc_base, Expr::Imm(16)), width: Width::W8 },
        // Node potentials: independent random loads -> aset pair.
        Stmt::Load {
            var: pt,
            addr: Expr::add(Expr::Param(nodes), Expr::shl(Expr::Var(tail), Expr::Imm(3))),
            width: Width::W8,
        },
        Stmt::Load {
            var: ph,
            addr: Expr::add(Expr::Param(nodes), Expr::shl(Expr::Var(head), Expr::Imm(3))),
            width: Width::W8,
        },
        Stmt::Let {
            var: red,
            expr: bin(AluOp::Add, bin(AluOp::Sub, Expr::Var(cost), Expr::Var(pt)), Expr::Var(ph)),
        },
        Stmt::Let {
            var: neg,
            expr: bin(AluOp::Add, Expr::Var(neg), bin(AluOp::Slt, Expr::Var(red), Expr::Imm(0))),
        },
        Stmt::Store { val: Expr::Var(neg), addr: Expr::Param(res), width: Width::W8 },
    ])
}

/// (nodes, arcs)
pub fn sizes(scale: Scale) -> (u64, u64) {
    match scale {
        Scale::Tiny => (1 << 10, 1 << 11),
        Scale::Small => (1 << 12, 1500),
        Scale::Full => (1 << 18, 1 << 19), // 2MB nodes, 16MB arcs
    }
}

impl Benchmark for Mcf {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "mcf", suite: "SPEC2017 (505.mcf_r)", remote: "net->nodes, net->arcs" }
    }

    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance> {
        let (nnodes, narcs) = sizes(scale);
        let mut rng = Rng::new(seed);
        let mut mem = MemImage::new();
        let pi: Vec<i64> = (0..nnodes).map(|_| rng.range(0, 2000) as i64 - 1000).collect();
        let mut expected: i64 = 0;
        let mut arc_words = Vec::with_capacity(4 * narcs as usize);
        for _ in 0..narcs {
            let t = rng.below(nnodes) as i64;
            let h = rng.below(nnodes) as i64;
            let c = rng.range(0, 100) as i64 - 50;
            arc_words.extend_from_slice(&[t, h, c, 0]);
            if c - pi[t as usize] + pi[h as usize] < 0 {
                expected += 1;
            }
        }
        let arcs = mem.alloc_init_i64("arcs", AddrSpace::Remote, &arc_words);
        let nodes = mem.alloc_init_i64("nodes", AddrSpace::Remote, &pi);
        let res = mem.alloc("result", AddrSpace::Local, 8);
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("result").expect("result region");
            let got = m.read(r.base, Width::W8)?;
            ensure!(got == expected, "negative-reduced-cost count = {got}, want {expected}");
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(),
            mem,
            params: vec![arcs as i64, nodes as i64, res as i64, narcs as i64],
            check: std::sync::Arc::new(check),
            default_tasks: 64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;
    use crate::compiler::{analysis, coalesce};

    #[test]
    fn all_variants_pass_oracle() {
        let rs = run_all_variants(&Mcf);
        let serial = rs[0].1.cycles as f64;
        let full = rs[4].1.cycles as f64;
        assert!(serial / full > 1.2, "mcf Full speedup {:.2}", serial / full);
    }

    #[test]
    fn arc_record_coarse_and_potentials_grouped() {
        let an = analysis::analyze(&kernel()).unwrap();
        let plan = coalesce::plan(&an, 8, 4096);
        // Group 1: arc fields coarse (3 members); the potential loads
        // depend on the arc fields so they form their own group.
        assert!(plan.groups.len() >= 1);
        let g0 = &plan.groups[0];
        assert!(matches!(g0.kind, coalesce::GroupKind::Coarse { .. }));
        assert_eq!(g0.members.len(), 3);
    }
}
