//! STREAM triad: `a[i] = b[i] + s * c[i]` over f64 arrays. Remote
//! structures: `a`, `b`, `c`. Bandwidth-bound with perfect spatial
//! locality — the case where the paper observes serial+BOP competitive at
//! low latency and coalescing (`aset` on the two loads) helping CoroAMU.

use super::{oracle_shapes, BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, FaluOp, Width};
use crate::sim::MemImage;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub struct Stream;

pub const SCALAR: f64 = 3.0;

pub fn kernel() -> Kernel {
    let mut kb = KernelBuilder::new("stream");
    let a = kb.param_ptr("a", AddrSpace::Remote);
    let b = kb.param_ptr("b", AddrSpace::Remote);
    let c = kb.param_ptr("c", AddrSpace::Remote);
    let s = kb.param_val("scalar");
    let n = kb.param_val("n");
    kb.trip(n);
    kb.num_tasks(64);
    let x = kb.var("x");
    let y = kb.var("y");
    let t = kb.var("t");
    let off = Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3));
    kb.build(vec![
        Stmt::Load { var: x, addr: Expr::add(Expr::Param(b), off.clone()), width: Width::W8 },
        Stmt::Load { var: y, addr: Expr::add(Expr::Param(c), off.clone()), width: Width::W8 },
        Stmt::Let {
            var: t,
            expr: Expr::Bin(
                BinOp::F(FaluOp::FAdd),
                Box::new(Expr::Var(x)),
                Box::new(Expr::Bin(BinOp::F(FaluOp::FMul), Box::new(Expr::Param(s)), Box::new(Expr::Var(y)))),
            ),
        },
        Stmt::Store { val: Expr::Var(t), addr: Expr::add(Expr::Param(a), off), width: Width::W8 },
    ])
}

pub fn sizes(scale: Scale) -> u64 {
    match scale {
        Scale::Tiny => oracle_shapes::STREAM_N,
        Scale::Small => 1 << 12,
        Scale::Full => 1 << 19, // 3 x 4 MB >> LLC
    }
}

impl Benchmark for Stream {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "stream", suite: "STREAM", remote: "a, b, c" }
    }

    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance> {
        let n = sizes(scale);
        let mut mem = MemImage::new();
        let mut rng = Rng::new(seed);
        let bv: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let cv: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let expected: Vec<f64> = bv.iter().zip(&cv).map(|(b, c)| b + SCALAR * c).collect();
        let a = mem.alloc("a", AddrSpace::Remote, n * 8);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits() as i64).collect::<Vec<_>>();
        let b = mem.alloc_init_i64("b", AddrSpace::Remote, &bits(&bv));
        let c = mem.alloc_init_i64("c", AddrSpace::Remote, &bits(&cv));
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("a").expect("a region");
            for (j, want) in expected.iter().enumerate() {
                let got = f64::from_bits(m.read(r.base + (j as u64) * 8, Width::W8)? as u64);
                ensure!(got == *want, "a[{j}] = {got}, want {want}");
            }
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(),
            mem,
            params: vec![a as i64, b as i64, c as i64, SCALAR.to_bits() as i64, n as i64],
            check: std::sync::Arc::new(check),
            default_tasks: 64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;
    use crate::compiler::{coalesce, analysis};

    #[test]
    fn all_variants_pass_oracle() {
        let rs = run_all_variants(&Stream);
        // Bandwidth-bound: everyone must still be correct; AMU should not
        // be catastrophically slower than serial.
        let serial = rs[0].1.cycles as f64;
        let full = rs[4].1.cycles as f64;
        assert!(full < serial * 2.0, "STREAM Full {:.2}x slower than serial", full / serial);
    }

    #[test]
    fn triad_loads_coalesce_into_aset_group() {
        let an = analysis::analyze(&kernel()).unwrap();
        let plan = coalesce::plan(&an, 8, 4096);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].members.len(), 2, "b[i] and c[i] fuse under one aset id");
    }
}
