//! lbm (519.lbm_r representative kernel): a 5-point stream-collide step
//! over a W x H lattice, `dst[c] = omega * (src[c-W] + src[c-1] + src[c] +
//! src[c+1] + src[c+W])`. Remote structures: `srcGrid`, `dstGrid`.
//! Strong spatial locality: serial runs ride the BOP prefetcher, while the
//! row-distance offsets exceed the 4KB coarse-grain limit so CoroAMU falls
//! back to an `aset` group of five line fetches — reproducing the paper's
//! observation that bandwidth-bound stencils gain the least.

use super::{BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, FaluOp, Width};
use crate::sim::MemImage;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub struct Lbm;

pub const OMEGA: f64 = 0.2;

fn fadd(a: Expr, b: Expr) -> Expr {
    Expr::Bin(BinOp::F(FaluOp::FAdd), Box::new(a), Box::new(b))
}

/// Width is a compile-time constant per instance so offsets are constant
/// (as in the real lbm where the grid dimensions are macros).
pub fn kernel(w: i64) -> Kernel {
    let mut kb = KernelBuilder::new("lbm");
    let src = kb.param_ptr("srcGrid", AddrSpace::Remote);
    let dst = kb.param_ptr("dstGrid", AddrSpace::Remote);
    let n = kb.param_val("num_cells");
    kb.trip(n);
    kb.num_tasks(48);
    let c = kb.var("c");
    let up = kb.var("up");
    let left = kb.var("left");
    let mid = kb.var("mid");
    let right = kb.var("right");
    let down = kb.var("down");
    let acc = kb.var("acc");
    let at = |delta: i64| {
        Expr::add(
            Expr::Param(src),
            Expr::add(Expr::shl(Expr::Var(c), Expr::Imm(3)), Expr::Imm(delta * 8)),
        )
    };
    kb.build(vec![
        // Cell index skips the first row: c = i + W.
        Stmt::Let { var: c, expr: Expr::add(Expr::Var(ITER_VAR), Expr::Imm(w)) },
        Stmt::Load { var: up, addr: at(-w), width: Width::W8 },
        Stmt::Load { var: left, addr: at(-1), width: Width::W8 },
        Stmt::Load { var: mid, addr: at(0), width: Width::W8 },
        Stmt::Load { var: right, addr: at(1), width: Width::W8 },
        Stmt::Load { var: down, addr: at(w), width: Width::W8 },
        Stmt::Let {
            var: acc,
            expr: Expr::Bin(
                BinOp::F(FaluOp::FMul),
                Box::new(Expr::FImm(OMEGA)),
                Box::new(fadd(
                    fadd(fadd(Expr::Var(up), Expr::Var(left)), fadd(Expr::Var(mid), Expr::Var(right))),
                    Expr::Var(down),
                )),
            ),
        },
        Stmt::Store {
            val: Expr::Var(acc),
            addr: Expr::add(Expr::Param(dst), Expr::shl(Expr::Var(c), Expr::Imm(3))),
            width: Width::W8,
        },
    ])
}

/// (W, H): lattice dimensions.
pub fn sizes(scale: Scale) -> (i64, i64) {
    match scale {
        Scale::Tiny => (128, 8),
        Scale::Small => (256, 12),
        Scale::Full => (1024, 512), // 4 MB per grid
    }
}

impl Benchmark for Lbm {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "lbm", suite: "SPEC2017 (519.lbm_r)", remote: "srcGrid, dstGrid" }
    }

    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance> {
        let (w, h) = sizes(scale);
        let cells = (w * h) as u64;
        let trip = (w * (h - 2)) as u64;
        let mut rng = Rng::new(seed);
        let mut mem = MemImage::new();
        let grid: Vec<f64> = (0..cells).map(|_| rng.f64()).collect();
        let bits: Vec<i64> = grid.iter().map(|g| g.to_bits() as i64).collect();
        let src = mem.alloc_init_i64("srcGrid", AddrSpace::Remote, &bits);
        let dst = mem.alloc("dstGrid", AddrSpace::Remote, cells * 8);
        let mut expected = vec![0f64; cells as usize];
        for i in 0..trip as usize {
            let c = i + w as usize;
            // Same association as the kernel's expression tree:
            // ((up+left) + (mid+right)) + down.
            expected[c] = OMEGA
                * (((grid[c - w as usize] + grid[c - 1]) + (grid[c] + grid[c + 1]))
                    + grid[c + w as usize]);
        }
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("dstGrid").expect("dstGrid region");
            for (j, want) in expected.iter().enumerate() {
                let got = f64::from_bits(m.read(r.base + (j as u64) * 8, Width::W8)? as u64);
                ensure!(got == *want, "dst[{j}] = {got}, want {want}");
            }
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(w),
            mem,
            params: vec![src as i64, dst as i64, trip as i64],
            check: std::sync::Arc::new(check),
            default_tasks: 48,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;
    use crate::compiler::{analysis, coalesce};

    #[test]
    fn all_variants_pass_oracle() {
        let rs = run_all_variants(&Lbm);
        assert!(rs.iter().all(|(_, st)| st.cycles > 0));
    }

    #[test]
    fn wide_stencil_falls_back_to_aset_group() {
        // Full-scale W=1024: row offsets are 8KB apart -> no coarse merge,
        // one aset group of 5.
        let an = analysis::analyze(&kernel(1024)).unwrap();
        let plan = coalesce::plan(&an, 8, 4096);
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].members.len(), 5);
        assert!(matches!(plan.groups[0].kind, coalesce::GroupKind::Set));
    }

    #[test]
    fn narrow_stencil_merges_coarsely() {
        // W=64: span = 2*64*8 + 8 = 1032 bytes <= 4KB -> coarse.
        let an = analysis::analyze(&kernel(64)).unwrap();
        let plan = coalesce::plan(&an, 8, 4096);
        assert_eq!(plan.groups.len(), 1);
        assert!(matches!(plan.groups[0].kind, coalesce::GroupKind::Coarse { .. }));
    }
}
