//! HJ: hash-join probe (paper Listing 1). Remote structures:
//! `relation->tuples` and `ht->buckets`. Buckets are 64-byte records
//! `{cnt, next, k0..k3, pad}` chained by index; probing walks the chain
//! counting key matches into the `matches` accumulator — the paper's
//! `shared_var(matches)` pragma example. The six in-bucket field loads are
//! constant-delta within one line, so the coalescer fuses them into a
//! single coarse-grained fetch (§III-C case 1).

use super::{oracle_shapes, BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, AluOp, Width};
use crate::sim::{mix64, MemImage};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub struct HashJoin;

const BUCKET_BYTES: i64 = 64;
// Bucket field offsets.
const F_CNT: i64 = 0;
const F_NEXT: i64 = 8;
const F_KEYS: i64 = 16; // k0..k3

fn bin(op: AluOp, a: Expr, b: Expr) -> Expr {
    Expr::Bin(BinOp::I(op), Box::new(a), Box::new(b))
}

pub fn kernel() -> Kernel {
    let mut kb = KernelBuilder::new("hj");
    let tuples = kb.param_ptr("tuples", AddrSpace::Remote);
    let buckets = kb.param_ptr("buckets", AddrSpace::Remote);
    let res = kb.param_ptr("result", AddrSpace::Local);
    let bmask = kb.param_val("bmask");
    let n = kb.param_val("num_tuples");
    kb.trip(n);
    kb.num_tasks(64);
    let key = kb.var("key");
    let b = kb.var("b"); // current bucket index, -1 terminates
    let cnt = kb.var("cnt");
    let nxt = kb.var("nxt");
    let k0 = kb.var("k0");
    let k1 = kb.var("k1");
    let k2 = kb.var("k2");
    let k3 = kb.var("k3");
    let matches = kb.var("matches");
    kb.shared_var(matches);
    let bucket_addr = |field: i64| {
        Expr::add(
            Expr::Param(buckets),
            Expr::add(Expr::mul(Expr::Var(b), Expr::Imm(BUCKET_BYTES)), Expr::Imm(field)),
        )
    };
    // matches += (j < cnt) & (kj == key), unrolled j = 0..3.
    let tally = |kj: VarId, j: i64| Stmt::Let {
        var: matches,
        expr: bin(
            AluOp::Add,
            Expr::Var(matches),
            bin(
                AluOp::And,
                bin(AluOp::Slt, Expr::Imm(j), Expr::Var(cnt)),
                bin(AluOp::Seq, Expr::Var(kj), Expr::Var(key)),
            ),
        ),
    };
    kb.build(vec![
        Stmt::Load {
            var: key,
            addr: Expr::add(Expr::Param(tuples), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(4))),
            width: Width::W8,
        },
        Stmt::Let {
            var: b,
            expr: Expr::and(
                Expr::Bin(BinOp::I(AluOp::Hash), Box::new(Expr::Var(key)), Box::new(Expr::Imm(0))),
                Expr::Param(bmask),
            ),
        },
        Stmt::While {
            cond: bin(AluOp::Sne, Expr::Var(b), Expr::Imm(-1)),
            body: vec![
                // One 48-byte coarse fetch after coalescing.
                Stmt::Load { var: cnt, addr: bucket_addr(F_CNT), width: Width::W8 },
                Stmt::Load { var: nxt, addr: bucket_addr(F_NEXT), width: Width::W8 },
                Stmt::Load { var: k0, addr: bucket_addr(F_KEYS), width: Width::W8 },
                Stmt::Load { var: k1, addr: bucket_addr(F_KEYS + 8), width: Width::W8 },
                Stmt::Load { var: k2, addr: bucket_addr(F_KEYS + 16), width: Width::W8 },
                Stmt::Load { var: k3, addr: bucket_addr(F_KEYS + 24), width: Width::W8 },
                tally(k0, 0),
                tally(k1, 1),
                tally(k2, 2),
                tally(k3, 3),
                Stmt::Let { var: b, expr: Expr::Var(nxt) },
            ],
        },
        // Publish the running count; the final completion writes the total.
        Stmt::Store { val: Expr::Var(matches), addr: Expr::Param(res), width: Width::W8 },
    ])
}

/// (buckets, tuples). Overflow chain buckets live past `buckets`.
pub fn sizes(scale: Scale) -> (u64, u64) {
    match scale {
        Scale::Tiny => (oracle_shapes::HJ_BUCKETS, oracle_shapes::HJ_TUPLES),
        Scale::Small => (1 << 10, 1500),
        Scale::Full => (1 << 17, 1 << 18), // 8MB+ buckets, 4MB tuples
    }
}

/// Deterministic host-side hash-table build; returns flat bucket memory
/// (base region includes overflow area) and the expected match count.
pub fn build_table(nbuckets: u64, build_keys: &[i64]) -> (Vec<i64>, u64) {
    let words = (BUCKET_BYTES / 8) as usize;
    // Overflow pool: half again as many buckets.
    let total = nbuckets as usize + nbuckets as usize / 2 + 4;
    let mut flat = vec![0i64; total * words];
    for c in 0..total {
        flat[c * words + (F_NEXT / 8) as usize] = -1;
    }
    let mut next_free = nbuckets as usize;
    for &k in build_keys {
        let mut bi = (mix64(k as u64) & (nbuckets - 1)) as usize;
        loop {
            let cnt = flat[bi * words] as usize;
            if cnt < 4 {
                flat[bi * words + (F_KEYS / 8) as usize + cnt] = k;
                flat[bi * words] = (cnt + 1) as i64;
                break;
            }
            let nxt = flat[bi * words + 1];
            if nxt == -1 {
                assert!(next_free < total, "overflow pool exhausted");
                flat[bi * words + 1] = next_free as i64;
                bi = next_free;
                next_free += 1;
            } else {
                bi = nxt as usize;
            }
        }
    }
    (flat, next_free as u64)
}

impl Benchmark for HashJoin {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "hj", suite: "Hash Join", remote: "relation->tuples, ht->buckets" }
    }

    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance> {
        let (nbuckets, ntuples) = sizes(scale);
        let mut rng = Rng::new(seed);
        // Build side: nbuckets*2 keys drawn from a domain that overlaps the
        // probe side ~50%.
        let domain = (nbuckets * 4) as u64;
        let build_keys: Vec<i64> = (0..nbuckets * 2).map(|_| rng.below(domain) as i64).collect();
        let (flat, _) = build_table(nbuckets, &build_keys);

        let mut mem = MemImage::new();
        // Probe tuples + expected matches (native probe).
        let mut expected: u64 = 0;
        let words = (BUCKET_BYTES / 8) as usize;
        let mut tuple_words = Vec::with_capacity(2 * ntuples as usize);
        for i in 0..ntuples {
            let key = rng.below(domain) as i64;
            tuple_words.push(key);
            tuple_words.push(i as i64); // payload
            let mut bi = (mix64(key as u64) & (nbuckets - 1)) as i64;
            while bi != -1 {
                let cnt = flat[bi as usize * words];
                for j in 0..4 {
                    if (j as i64) < cnt && flat[bi as usize * words + 2 + j] == key {
                        expected += 1;
                    }
                }
                bi = flat[bi as usize * words + 1];
            }
        }
        let tuples = mem.alloc_init_i64("tuples", AddrSpace::Remote, &tuple_words);
        let buckets = mem.alloc_init_i64("buckets", AddrSpace::Remote, &flat);
        let res = mem.alloc("result", AddrSpace::Local, 8);
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("result").expect("result region");
            let got = m.read(r.base, Width::W8)? as u64;
            ensure!(got == expected, "matches = {got}, want {expected}");
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(),
            mem,
            params: vec![tuples as i64, buckets as i64, res as i64, (nbuckets - 1) as i64, ntuples as i64],
            check: std::sync::Arc::new(check),
            default_tasks: 64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;
    use crate::compiler::{analysis, coalesce};

    #[test]
    fn all_variants_pass_oracle() {
        let rs = run_all_variants(&HashJoin);
        assert!(rs.iter().all(|(_, st)| st.cycles > 0));
    }

    #[test]
    fn bucket_fields_fuse_into_coarse_fetch() {
        let an = analysis::analyze(&kernel()).unwrap();
        let plan = coalesce::plan(&an, 8, 4096);
        let coarse = plan
            .groups
            .iter()
            .find(|g| matches!(g.kind, coalesce::GroupKind::Coarse { .. }))
            .expect("bucket loads should merge coarsely");
        assert_eq!(coarse.members.len(), 6);
        match coarse.kind {
            coalesce::GroupKind::Coarse { span_bytes, .. } => assert_eq!(span_bytes, 48),
            _ => unreachable!(),
        }
    }

    #[test]
    fn build_table_counts_are_consistent() {
        let keys = vec![1, 2, 3, 1, 1, 2];
        let (flat, _) = build_table(8, &keys);
        let words = 8;
        let total_stored: i64 = (0..flat.len() / words).map(|b| flat[b * words].min(4)).sum();
        assert_eq!(total_stored, 6);
    }
}
