//! BS: batched binary search over a huge sorted array. Remote structure:
//! `sorted_array`. A dependent pointer-chase: each probe's address depends
//! on the previous comparison, so per-task MLP is 1 and all the win comes
//! from inter-task interleaving — the paper's canonical latency-bound case.

use super::{oracle_shapes, BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, AluOp, Width};
use crate::sim::MemImage;
use anyhow::{ensure, Result};

pub struct BinarySearch;

pub const QPERM: i64 = 0x5851_F42D; // odd

fn bin(op: AluOp, a: Expr, b: Expr) -> Expr {
    Expr::Bin(BinOp::I(op), Box::new(a), Box::new(b))
}

/// Queries q = (i*QPERM) & (K-1); array holds sorted[j] = 2j+1; search for
/// target = 2q+1 with classic lo/hi bisection; out[i] = final lo (== q).
pub fn kernel() -> Kernel {
    let mut kb = KernelBuilder::new("bs");
    let arr = kb.param_ptr("sorted_array", AddrSpace::Remote);
    let out = kb.param_ptr("out", AddrSpace::Local);
    let kmask = kb.param_val("kmask");
    let n = kb.param_val("num_queries");
    kb.trip(n);
    kb.num_tasks(64);
    let target = kb.var("target");
    let lo = kb.var("lo");
    let hi = kb.var("hi");
    let mid = kb.var("mid");
    let v = kb.var("v");
    kb.build(vec![
        Stmt::Let {
            var: target,
            expr: bin(
                AluOp::Add,
                Expr::shl(
                    Expr::and(Expr::mul(Expr::Var(ITER_VAR), Expr::Imm(QPERM)), Expr::Param(kmask)),
                    Expr::Imm(1),
                ),
                Expr::Imm(1),
            ),
        },
        Stmt::Let { var: lo, expr: Expr::Imm(0) },
        Stmt::Let { var: hi, expr: Expr::Param(kmask) },
        Stmt::While {
            cond: bin(AluOp::Slt, Expr::Var(lo), Expr::Var(hi)),
            body: vec![
                Stmt::Let {
                    var: mid,
                    expr: bin(AluOp::Shr, bin(AluOp::Add, Expr::Var(lo), Expr::Var(hi)), Expr::Imm(1)),
                },
                Stmt::Load {
                    var: v,
                    addr: Expr::add(Expr::Param(arr), Expr::shl(Expr::Var(mid), Expr::Imm(3))),
                    width: Width::W8,
                },
                Stmt::If {
                    cond: bin(AluOp::Slt, Expr::Var(v), Expr::Var(target)),
                    then_: vec![Stmt::Let { var: lo, expr: bin(AluOp::Add, Expr::Var(mid), Expr::Imm(1)) }],
                    else_: vec![Stmt::Let { var: hi, expr: Expr::Var(mid) }],
                },
            ],
        },
        Stmt::Store {
            val: Expr::Var(lo),
            addr: Expr::add(Expr::Param(out), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))),
            width: Width::W8,
        },
    ])
}

pub fn sizes(scale: Scale) -> (u64, u64) {
    match scale {
        Scale::Tiny => (oracle_shapes::BS_KEYS, oracle_shapes::BS_QUERIES),
        Scale::Small => (1 << 13, 300),
        Scale::Full => (1 << 21, 25_000), // 16 MB sorted array
    }
}

impl Benchmark for BinarySearch {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "bs", suite: "Binary Search", remote: "sorted_array" }
    }

    fn instance(&self, scale: Scale, _seed: u64) -> Result<Instance> {
        let (k, n) = sizes(scale);
        let mut mem = MemImage::new();
        let data: Vec<i64> = (0..k as i64).map(|j| 2 * j + 1).collect();
        let arr = mem.alloc_init_i64("sorted_array", AddrSpace::Remote, &data);
        let out = mem.alloc("out", AddrSpace::Local, n * 8);
        let kmask = (k - 1) as i64;
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("out").expect("out region");
            for i in 0..n as i64 {
                let want = i.wrapping_mul(QPERM) & kmask;
                let got = m.read(r.base + (i as u64) * 8, Width::W8)?;
                ensure!(got == want, "out[{i}] = {got}, want {want}");
            }
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(),
            mem,
            params: vec![arr as i64, out as i64, kmask, n as i64],
            check: std::sync::Arc::new(check),
            default_tasks: 64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;

    #[test]
    fn all_variants_pass_oracle_and_interleaving_wins() {
        let rs = run_all_variants(&BinarySearch);
        let serial = rs[0].1.cycles as f64;
        let full = rs[4].1.cycles as f64;
        assert!(
            serial / full > 2.0,
            "BS is a dependent chain; interleaving should win big, got {:.2}x",
            serial / full
        );
    }

    #[test]
    fn kernel_has_one_suspension_site_in_loop() {
        let an = crate::compiler::analysis::analyze(&kernel()).unwrap();
        assert_eq!(an.sites.len(), 1, "only sorted_array probes are remote");
    }
}
