//! IS (NPB Integer Sort representative kernel): the key-histogram phase.
//! Remote structures: `keys` (streamed) and `histogram` (random atomic
//! increments). Under dynamic AMU scheduling the remote atomic expands
//! into the §III-E await/asignal lock hand-off procedure — this benchmark
//! is the synchronization stress test.

use super::{BenchSpec, Benchmark, Instance, Scale};
use crate::compiler::ast::*;
use crate::ir::{AddrSpace, AluOp, Width};
use crate::sim::MemImage;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

pub struct IntSort;

pub fn kernel() -> Kernel {
    let mut kb = KernelBuilder::new("is");
    let keys = kb.param_ptr("keys", AddrSpace::Remote);
    let hist = kb.param_ptr("histogram", AddrSpace::Remote);
    let n = kb.param_val("num_keys");
    kb.trip(n);
    kb.num_tasks(48);
    let k = kb.var("k");
    kb.build(vec![
        Stmt::Load {
            var: k,
            addr: Expr::add(Expr::Param(keys), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))),
            width: Width::W8,
        },
        Stmt::AtomicRmw {
            op: AluOp::Add,
            old: None,
            addr: Expr::add(Expr::Param(hist), Expr::shl(Expr::Var(k), Expr::Imm(3))),
            val: Expr::Imm(1),
            width: Width::W8,
        },
    ])
}

/// (key_count, bucket_count)
pub fn sizes(scale: Scale) -> (u64, u64) {
    match scale {
        Scale::Tiny => (1 << 10, 1 << 8),
        Scale::Small => (1200, 1 << 10),
        Scale::Full => (1 << 18, 1 << 15), // 2MB keys, 256KB histogram
    }
}

impl Benchmark for IntSort {
    fn spec(&self) -> BenchSpec {
        BenchSpec { name: "is", suite: "NPB", remote: "keys, histogram (all of malloc())" }
    }

    fn instance(&self, scale: Scale, seed: u64) -> Result<Instance> {
        let (nkeys, nbuckets) = sizes(scale);
        let mut rng = Rng::new(seed);
        let mut mem = MemImage::new();
        let mut expected = vec![0i64; nbuckets as usize];
        let key_words: Vec<i64> = (0..nkeys)
            .map(|_| {
                let k = rng.below(nbuckets) as i64;
                expected[k as usize] += 1;
                k
            })
            .collect();
        let keys = mem.alloc_init_i64("keys", AddrSpace::Remote, &key_words);
        let hist = mem.alloc("histogram", AddrSpace::Remote, nbuckets * 8);
        let check = move |m: &MemImage| -> Result<()> {
            let r = m.region("histogram").expect("histogram region");
            for (j, want) in expected.iter().enumerate() {
                let got = m.read(r.base + (j as u64) * 8, Width::W8)?;
                ensure!(got == *want, "hist[{j}] = {got}, want {want}");
            }
            Ok(())
        };
        Ok(Instance {
            kernel: kernel(),
            mem,
            params: vec![keys as i64, hist as i64, nkeys as i64],
            check: std::sync::Arc::new(check),
            default_tasks: 48,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::testutil::run_all_variants;
    use crate::benchmarks::{execute, Scale};
    use crate::compiler::Variant;
    use crate::config::SimConfig;

    #[test]
    fn all_variants_pass_oracle_including_atomics() {
        let rs = run_all_variants(&IntSort);
        assert!(rs.iter().all(|(_, st)| st.cycles > 0));
    }

    #[test]
    fn dynamic_variant_exercises_await_asignal() {
        let cfg = SimConfig::nh_g();
        let inst = IntSort.instance(Scale::Small, 7).unwrap();
        let st = execute(&cfg, inst, Variant::CoroAmuFull, 96).unwrap();
        // Histogram contention must trigger at least a few lock waits.
        assert!(st.awaits > 0, "expected await/asignal activity, got none");
    }
}
