//! The execution engine: a session-style facade over the whole pipeline
//! (compile → link → simulate → oracle-check) with a compiled-kernel cache.
//!
//! The paper's pitch is a *simple interface* over latency-aware decoupled
//! operations; this module is that interface on the reproduction side.
//! Instead of hand-chaining `compiler::compile` → `sim::link` → `sim::run`,
//! callers open an [`Engine`] session over a [`SimConfig`] and issue
//! [`RunRequest`]s:
//!
//! ```no_run
//! use coroamu::benchmarks::Scale;
//! use coroamu::compiler::Variant;
//! use coroamu::config::SimConfig;
//! use coroamu::engine::{Engine, RunRequest};
//!
//! let engine = Engine::new(SimConfig::nh_g());
//! let report = engine
//!     .run(RunRequest::new("gups", Variant::CoroAmuFull)
//!         .scale(Scale::Small)
//!         .latency_ns(400.0))
//!     .unwrap();
//! println!("{}", report.render());
//! ```
//!
//! Compiled kernels are cached on (kernel fingerprint, codegen options,
//! AMU config), so a figure matrix that sweeps latencies and seeds compiles
//! each (benchmark, variant) kernel exactly once — the compile-once /
//! issue-many amortization the AMU line of work calls for. [`Engine::sweep`]
//! fans a request matrix across the worker pool.
//!
//! Datasets are cached the same way: the first run of a (bench, scale,
//! seed) triple materializes the benchmark instance — dataset synthesis
//! plus the oracle's expected-result computation — and every subsequent
//! run restores it from a copy-on-write [`MemImage`] snapshot instead of
//! regenerating it. A latency sweep therefore builds each dataset exactly
//! once (see [`Engine::dataset_stats`]), mirroring the kernel cache.
//!
//! With a persistent [`store::Store`] attached ([`Engine::with_store`],
//! or `COROAMU_STORE` via [`Engine::with_store_from_env`]),
//! [`Engine::sweep`] becomes a **planner**: each request reduces to a
//! canonical cell fingerprint ([`Engine::cell_fingerprint`]), the matrix
//! is partitioned into store hits (served without simulating, stats
//! bit-identical to a fresh run) and misses (simulated on the worker
//! pool, each written back atomically on completion), and a sweep killed
//! mid-grid resumes from the store across processes. Without a store,
//! behavior is unchanged.

pub mod store;

use crate::benchmarks::{self, Instance, Scale};
use crate::compiler::{compile, CodegenOpts, CompiledKernel, Variant};
use crate::config::SimConfig;
use crate::coordinator::pool;
use crate::sim::fabric::FabricKind;
use crate::sim::faults::FaultConfig;
use crate::sim::sched::SchedPolicyKind;
use crate::sim::service::ServiceConfig;
use crate::sim::trace::{Trace, TraceConfig};
use crate::sim::{self, MemImage, RunStats};
use anyhow::{anyhow, Result};
use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: identity of a compilation. The kernel is fingerprinted
/// structurally (not just by name) so a kernel whose AST ever depended on
/// scale or seed would simply miss rather than alias.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    kernel: String,
    kernel_fp: u64,
    opts_fp: u64,
    amu_fp: u64,
}

fn fingerprint<T: std::fmt::Debug>(t: &T) -> u64 {
    let mut h = DefaultHasher::new();
    format!("{t:?}").hash(&mut h);
    h.finish()
}

/// Dataset-cache key: one benchmark instance per (bench, scale, seed).
/// Latency, variant and codegen options are simulate-time knobs that do
/// not affect the dataset, so they are deliberately absent.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct DatasetKey {
    bench: String,
    scale: Scale,
    seed: u64,
}

/// A materialized benchmark instance held by the dataset cache: the
/// kernel AST, the pristine memory image (copy-on-write master), the
/// parameter bindings and the shared oracle.
struct DatasetTemplate {
    kernel: crate::compiler::ast::Kernel,
    mem: MemImage,
    params: Vec<i64>,
    check: Arc<dyn Fn(&MemImage) -> Result<()> + Send + Sync>,
    default_tasks: usize,
}

impl DatasetTemplate {
    /// Hand out a per-run instance: O(#regions) snapshot, no dataset
    /// regeneration, no oracle recomputation.
    fn instantiate(&self) -> Instance {
        Instance {
            kernel: self.kernel.clone(),
            mem: self.mem.snapshot(),
            params: self.params.clone(),
            check: self.check.clone(),
            default_tasks: self.default_tasks,
        }
    }
}

/// Per-key build cell: workers needing the same dataset serialize on the
/// cell's own mutex (each dataset is materialized exactly once), while
/// workers after *different* datasets never contend with a build.
type DatasetCell = Arc<Mutex<Option<Arc<DatasetTemplate>>>>;

/// Bound on retained dataset templates (FIFO eviction). Sized for the
/// harness's worst case — all eight benchmarks at two seeds live in one
/// figure sweep — while keeping Scale::Full memory bounded.
const DATASET_CACHE_CAP: usize = 16;

#[derive(Default)]
struct DatasetCache {
    map: HashMap<DatasetKey, DatasetCell>,
    /// Insertion order, for FIFO eviction once the cap is reached.
    order: VecDeque<DatasetKey>,
}

/// Hit/miss accounting for the compiled-kernel cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

/// A reusable handle to a compiled kernel, owned by the engine's cache.
#[derive(Debug, Clone)]
pub struct Prepared {
    /// Kernel name (benchmark kernels use the benchmark name).
    pub kernel: String,
    pub ck: Arc<CompiledKernel>,
    /// Whether this preparation was served from the cache.
    pub cache_hit: bool,
}

/// One simulation request: what to run and under which knobs. Builder
/// pattern; every field has a sensible default except bench + variant.
#[derive(Debug, Clone)]
pub struct RunRequest {
    pub bench: String,
    pub variant: Variant,
    /// Coroutine concurrency; 0 = the benchmark's default.
    pub tasks: usize,
    pub scale: Scale,
    pub seed: u64,
    /// Free-form key for grouping results in sweeps (e.g. the latency).
    pub key: String,
    /// Override the session config's far-memory latency for this run only.
    /// Does not affect compilation (latency is a link/simulate-time knob).
    pub latency_ns: Option<f64>,
    /// Override the session config's coroutine-scheduler policy for this
    /// run only (`sim::sched`). Simulate-time like latency: sweeping the
    /// policy axis never forks the compiled-kernel cache.
    pub sched_policy: Option<SchedPolicyKind>,
    /// Override the session config's far-memory fabric for this run only
    /// (`sim::fabric`). Simulate-time like latency and policy: sweeping
    /// the fabric axis never forks the compiled-kernel cache.
    pub fabric: Option<FabricKind>,
    /// Override the session config's cluster core count for this run only
    /// (`sim::cluster`). Simulate-time like latency/policy/fabric:
    /// sweeping the core-count axis never forks the compiled-kernel or
    /// dataset caches (each core runs the same compiled kernel over its
    /// own snapshot of the same dataset).
    pub cores: Option<u32>,
    /// Override the session config's fault-injection spec for this run
    /// only (`sim::faults`). Simulate-time like latency/policy/fabric:
    /// sweeping the chaos axis never forks the compiled-kernel or
    /// dataset caches.
    pub faults: Option<FaultConfig>,
    /// Override the session config's open-loop service spec for this run
    /// only (`sim::service`). Simulate-time like latency/policy/fabric:
    /// the service replay is driven by the batch run's calibrated cost
    /// and never forks the compiled-kernel or dataset caches.
    pub service: Option<ServiceConfig>,
    /// Override the session config's trace configuration for this run
    /// only (`sim::trace`, DESIGN.md §14). Simulate-time like
    /// latency/policy/fabric: enabling tracing never forks the
    /// compiled-kernel or dataset caches.
    pub trace: Option<TraceConfig>,
    /// Explicit codegen options (ablation figures); overrides `variant`'s
    /// canonical options when set.
    pub opts: Option<CodegenOpts>,
    /// Display label for an `opts` override (e.g. "D+bafin").
    pub label: Option<String>,
}

impl RunRequest {
    pub fn new(bench: impl Into<String>, variant: Variant) -> Self {
        RunRequest {
            bench: bench.into(),
            variant,
            tasks: 0,
            scale: Scale::Small,
            seed: 42,
            key: String::new(),
            latency_ns: None,
            sched_policy: None,
            fabric: None,
            cores: None,
            faults: None,
            service: None,
            trace: None,
            opts: None,
            label: None,
        }
    }

    pub fn tasks(mut self, n: usize) -> Self {
        self.tasks = n;
        self
    }

    pub fn scale(mut self, s: Scale) -> Self {
        self.scale = s;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn key(mut self, k: impl Into<String>) -> Self {
        self.key = k.into();
        self
    }

    pub fn latency_ns(mut self, ns: f64) -> Self {
        self.latency_ns = Some(ns);
        self
    }

    /// Run under an explicit coroutine-scheduler policy (the `sim::sched`
    /// sweep axis) instead of the session config's default.
    pub fn policy(mut self, p: SchedPolicyKind) -> Self {
        self.sched_policy = Some(p);
        self
    }

    /// Run under an explicit far-memory fabric (the `sim::fabric` sweep
    /// axis) instead of the session config's default.
    pub fn fabric(mut self, f: FabricKind) -> Self {
        self.fabric = Some(f);
        self
    }

    /// Run on an explicit cluster core count (the `sim::cluster` sweep
    /// axis) instead of the session config's default.
    pub fn cores(mut self, n: u32) -> Self {
        self.cores = Some(n);
        self
    }

    /// Run under an explicit fault-injection spec (the `sim::faults`
    /// chaos axis) instead of the session config's default.
    pub fn faults(mut self, f: FaultConfig) -> Self {
        self.faults = Some(f);
        self
    }

    /// Run under an explicit open-loop service spec (the `sim::service`
    /// overload axis) instead of the session config's default.
    pub fn service(mut self, s: ServiceConfig) -> Self {
        self.service = Some(s);
        self
    }

    /// Run under an explicit trace configuration (`sim::trace`,
    /// DESIGN.md §14) instead of the session config's default.
    pub fn trace(mut self, t: TraceConfig) -> Self {
        self.trace = Some(t);
        self
    }

    /// Run under explicit codegen options instead of the variant's
    /// canonical ones (the ablation figures toggle single optimizations).
    pub fn opts(mut self, opts: CodegenOpts, label: impl Into<String>) -> Self {
        self.opts = Some(opts);
        self.label = Some(label.into());
        self
    }

    /// Human-readable configuration label for reports.
    pub fn config_label(&self) -> String {
        self.label.clone().unwrap_or_else(|| self.variant.label().to_string())
    }
}

/// Stats plus provenance for one completed, oracle-checked run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub bench: String,
    pub variant: Variant,
    /// `variant.label()`, or the request's custom opts label.
    pub variant_label: String,
    /// Name of the session config the run executed under.
    pub cfg_name: String,
    /// Effective far-memory latency of the run, ns.
    pub far_latency_ns: f64,
    /// Effective coroutine-scheduler policy of the run.
    pub sched_policy: SchedPolicyKind,
    /// Effective far-memory fabric of the run.
    pub fabric: FabricKind,
    /// Effective cluster core count of the run (1 = single-core path).
    pub cores: u32,
    /// Effective fault-injection spec of the run (off by default).
    pub faults: FaultConfig,
    /// Effective open-loop service spec of the run (off by default).
    pub service: ServiceConfig,
    pub scale: Scale,
    pub seed: u64,
    pub key: String,
    /// Whether the kernel came from the compiled-kernel cache.
    pub cache_hit: bool,
    /// Whether the whole run was served from the persistent sweep store
    /// (no simulation happened in this process).
    pub store_hit: bool,
    pub stats: RunStats,
}

impl RunReport {
    /// The human-readable summary previously inlined in the CLI's `run`
    /// command; one line of provenance, then the stat block.
    pub fn render(&self) -> String {
        let st = &self.stats;
        let mut out = String::new();
        out.push_str(&format!(
            "bench={} variant={} cfg={} far={}ns fabric={} sched={}{}{}{} scale={:?} seed={}{}\n",
            self.bench,
            self.variant_label,
            self.cfg_name,
            self.far_latency_ns,
            self.fabric.label(),
            self.sched_policy.label(),
            if self.cores > 1 { format!(" cores={}", self.cores) } else { String::new() },
            if self.faults.enabled() { format!(" faults={}", self.faults.label()) } else { String::new() },
            if self.service.enabled() {
                format!(" service={}", self.service.label())
            } else {
                String::new()
            },
            self.scale,
            self.seed,
            if self.store_hit {
                " source=store"
            } else if self.cache_hit {
                " kernel=cached"
            } else {
                " kernel=compiled"
            },
        ));
        out.push_str(&format!("  cycles            {}\n", st.cycles));
        out.push_str(&format!("  dyn instrs        {} (ipc {:.2})\n", st.dyn_instrs, st.ipc()));
        out.push_str(&format!(
            "  switches          {} (ctx ops/switch {:.1})\n",
            st.switches,
            st.ctx_ops_per_switch()
        ));
        out.push_str(&format!(
            "  scheduler         {} (picks {} / holds {})\n",
            st.sched_policy, st.sched_picks, st.sched_holds
        ));
        out.push_str(&format!(
            "  cond branches     {} ({} mispredicted)\n",
            st.cond_branches, st.cond_mispredicts
        ));
        out.push_str(&format!(
            "  indirect jumps    {} ({} mispredicted)\n",
            st.indirect_jumps, st.indirect_mispredicts
        ));
        out.push_str(&format!(
            "  bafin             {} taken / {} fallthrough / {} mispredicted\n",
            st.bafins_taken, st.bafins_fallthrough, st.bafin_mispredicts
        ));
        out.push_str(&format!(
            "  aloads/astores    {}/{} (awaits {})\n",
            st.aloads, st.astores, st.awaits
        ));
        out.push_str(&format!(
            "  far MLP           {:.1} (busy {:.0}%)\n",
            st.far_mlp,
            st.far_busy_frac * 100.0
        ));
        out.push_str(&format!(
            "  far latency       p50 {} / p99 {} cycles ({} requests)\n",
            st.fabric_p50, st.fabric_p99, st.fabric_requests
        ));
        if st.fabric_queue_stalls > 0 || st.fabric_max_inflight > 0 {
            out.push_str(&format!(
                "  fabric queue      peak {} in flight, {} stall cycles\n",
                st.fabric_max_inflight, st.fabric_queue_stalls
            ));
        }
        if st.fabric_hot_hits + st.fabric_hot_misses > 0 {
            out.push_str(&format!(
                "  hot pages         {:.0}% hit ({} hits / {} misses, {} writebacks)\n",
                100.0 * st.fabric_hot_hits as f64
                    / (st.fabric_hot_hits + st.fabric_hot_misses) as f64,
                st.fabric_hot_hits,
                st.fabric_hot_misses,
                st.fabric_writebacks
            ));
        }
        if st.fault_nacks + st.fault_timeouts + st.fault_retries + st.fault_slow_path > 0
            || st.fault_degraded_cycles > 0
        {
            out.push_str(&format!(
                "  faults            {} ({} nacks, {} timeouts, {} degraded cycles)\n",
                st.faults, st.fault_nacks, st.fault_timeouts, st.fault_degraded_cycles
            ));
            out.push_str(&format!(
                "  resilience        {} retries ({} backoff cycles), {} slow-path, max stall {}\n",
                st.fault_retries, st.fault_retry_cycles, st.fault_slow_path, st.fault_max_stall
            ));
        }
        if !st.service.is_empty() {
            out.push_str(&format!(
                "  service           {} (knee cost {} cycles/request)\n",
                st.service, st.svc_capacity_cost
            ));
            out.push_str(&format!(
                "  requests          {} offered / {} accepted / {} rejected / {} shed in queue\n",
                st.svc_offered, st.svc_accepted, st.svc_rejected, st.svc_shed_expired
            ));
            out.push_str(&format!(
                "  goodput           {} of {} served ({} timed out)\n",
                st.svc_goodput, st.svc_served, st.svc_timed_out
            ));
            out.push_str(&format!(
                "  sojourn           p50 {} / p99 {} / p99.9 {} cycles (peak queue {})\n",
                st.svc_p50, st.svc_p99, st.svc_p999, st.svc_max_queue
            ));
            if st.svc_degraded_spells > 0 {
                out.push_str(&format!(
                    "  degraded mode     {} served across {} spells\n",
                    st.svc_degraded_served, st.svc_degraded_spells
                ));
            }
        }
        if st.cluster_cores > 1 {
            out.push_str(&format!(
                "  cluster           {} cores, makespan {} cycles, fairness {:.3}\n",
                st.cluster_cores, st.cycles, st.cluster_fairness
            ));
            for (i, c) in st.core_cycles.iter().enumerate() {
                out.push_str(&format!(
                    "    core {i}          {} cycles, {} far reqs (p50 {} / p99 {}), {} stall cycles{}\n",
                    c,
                    st.core_fabric_requests.get(i).copied().unwrap_or(0),
                    st.core_fabric_p50.get(i).copied().unwrap_or(0),
                    st.core_fabric_p99.get(i).copied().unwrap_or(0),
                    st.core_fabric_stalls.get(i).copied().unwrap_or(0),
                    match (
                        st.core_fault_retries.get(i).copied().unwrap_or(0),
                        st.core_fault_slow_path.get(i).copied().unwrap_or(0),
                    ) {
                        (0, 0) => String::new(),
                        (r, s) => format!(", {r} retries / {s} slow-path"),
                    },
                ));
            }
        }
        out.push_str(&format!("  l1 hits/misses    {}/{}\n", st.l1_hits, st.l1_misses));
        let brk = st.cycle_breakdown();
        let s: Vec<String> = brk.iter().map(|(n, v)| format!("{n} {:.0}%", v * 100.0)).collect();
        out.push_str(&format!("  breakdown         {}\n", s.join(", ")));
        out.push_str("  oracle            PASS");
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Result of running a caller-supplied [`Instance`] (memory image included,
/// for callers that inspect the final memory — oracles, tests).
pub struct InstanceRun {
    pub stats: RunStats,
    pub mem: MemImage,
    pub cache_hit: bool,
}

/// A sweep partitioned against the persistent store: which matrix cells
/// are already on disk and which still need simulating. Index vectors
/// refer into the planned matrix; `fingerprints[i]` is the canonical
/// cell fingerprint of `matrix[i]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SweepPlan {
    pub total: usize,
    pub hits: Vec<usize>,
    pub misses: Vec<usize>,
    /// Cells that are misses because their on-disk copy was quarantined
    /// as corrupt (a subset of `misses`): they will be re-simulated, but
    /// the operator should know the store lost data.
    pub corrupt: Vec<usize>,
    pub fingerprints: Vec<u64>,
}

impl SweepPlan {
    /// Machine-readable one-liner (`plan total=N hits=H misses=M corrupt=C`),
    /// printed by `coroamu sweep` and grepped by the CI resume smoke.
    pub fn summary(&self) -> String {
        format!(
            "plan total={} hits={} misses={} corrupt={}",
            self.total,
            self.hits.len(),
            self.misses.len(),
            self.corrupt.len()
        )
    }
}

/// Find the report for (bench, variant, key) in a sweep result.
pub fn lookup<'a>(
    rs: &'a [RunReport],
    bench: &str,
    variant: Variant,
    key: &str,
) -> Option<&'a RunReport> {
    rs.iter().find(|r| r.bench == bench && r.variant == variant && r.key == key)
}

/// A session over one simulator configuration, owning the full pipeline
/// and the compiled-kernel cache. `Engine` is `Sync`: sweeps share one
/// session (and one cache) across the worker pool.
pub struct Engine {
    cfg: SimConfig,
    cache: Mutex<HashMap<CacheKey, Arc<CompiledKernel>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    datasets: Mutex<DatasetCache>,
    ds_hits: AtomicU64,
    ds_misses: AtomicU64,
    /// Persistent sweep store; `None` (the default) keeps every code
    /// path bit-identical to the store-less engine.
    store: Option<store::Store>,
}

impl Engine {
    pub fn new(cfg: SimConfig) -> Engine {
        Engine {
            cfg,
            cache: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            datasets: Mutex::new(DatasetCache::default()),
            ds_hits: AtomicU64::new(0),
            ds_misses: AtomicU64::new(0),
            store: None,
        }
    }

    /// Attach a persistent sweep store: [`Engine::sweep`] then plans
    /// hits/misses against it and writes completed cells back.
    pub fn with_store(mut self, store: store::Store) -> Engine {
        self.store = Some(store);
        self
    }

    /// Attach the store named by `COROAMU_STORE` when set; otherwise the
    /// engine stays store-less. This is how the CLI and `harness::grid`
    /// opt every report into incremental sweeps.
    pub fn with_store_from_env(self) -> Result<Engine> {
        match store::Store::from_env()? {
            Some(s) => Ok(self.with_store(s)),
            None => Ok(self),
        }
    }

    /// The attached sweep store, if any.
    pub fn store(&self) -> Option<&store::Store> {
        self.store.as_ref()
    }

    /// The session's base configuration (requests may override latency).
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.cache.lock().unwrap().len(),
        }
    }

    /// Hit/miss accounting for the dataset cache: a miss is one full
    /// benchmark-instance materialization (dataset synthesis + oracle
    /// precomputation); a hit is a copy-on-write snapshot restore.
    pub fn dataset_stats(&self) -> CacheStats {
        CacheStats {
            hits: self.ds_hits.load(Ordering::Relaxed),
            misses: self.ds_misses.load(Ordering::Relaxed),
            entries: self.datasets.lock().unwrap().map.len(),
        }
    }

    /// Fetch (or build) the dataset template for a (bench, scale, seed)
    /// triple. The global map lock is only held to look up / insert the
    /// per-key cell; the (potentially expensive) materialization runs
    /// under that cell's own mutex, so each dataset is still built
    /// exactly once but a slow build never stalls workers hitting other,
    /// already-built datasets.
    fn dataset(&self, bench: &str, scale: Scale, seed: u64) -> Result<Arc<DatasetTemplate>> {
        let key = DatasetKey { bench: bench.to_ascii_lowercase(), scale, seed };
        let cell: DatasetCell = {
            let mut cache = self.datasets.lock().unwrap();
            match cache.map.get(&key) {
                Some(cell) => cell.clone(),
                None => {
                    if cache.map.len() >= DATASET_CACHE_CAP {
                        if let Some(old) = cache.order.pop_front() {
                            cache.map.remove(&old);
                        }
                    }
                    let cell: DatasetCell = Arc::new(Mutex::new(None));
                    cache.map.insert(key.clone(), cell.clone());
                    cache.order.push_back(key.clone());
                    cell
                }
            }
        };
        let mut slot = cell.lock().unwrap();
        if let Some(t) = slot.as_ref() {
            self.ds_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(t.clone());
        }
        let built = (|| -> Result<Arc<DatasetTemplate>> {
            let b =
                benchmarks::by_name(bench).ok_or_else(|| anyhow!("unknown benchmark {bench}"))?;
            let inst = b.instance(scale, seed)?;
            Ok(Arc::new(DatasetTemplate {
                kernel: inst.kernel,
                mem: inst.mem,
                params: inst.params,
                check: inst.check,
                default_tasks: inst.default_tasks,
            }))
        })();
        let t = match built {
            Ok(t) => t,
            Err(e) => {
                // Don't let a failed build squat in the bounded cache: a
                // never-built cell would consume a FIFO slot and inflate
                // the entries accounting.
                drop(slot);
                let mut cache = self.datasets.lock().unwrap();
                if cache.map.get(&key).map(|c| Arc::ptr_eq(c, &cell)).unwrap_or(false) {
                    cache.map.remove(&key);
                    cache.order.retain(|k| k != &key);
                }
                return Err(e);
            }
        };
        *slot = Some(t.clone());
        self.ds_misses.fetch_add(1, Ordering::Relaxed);
        Ok(t)
    }

    /// Compile (or fetch) the kernel of a registered benchmark under a
    /// variant's canonical options at the benchmark's default concurrency.
    ///
    /// Note: this resolves a full instance at the requested scale to
    /// obtain the kernel, because some kernel ASTs are scale-dependent
    /// (lbm bakes the lattice width in as constant offsets) — substituting
    /// a smaller scale here would compile the wrong kernel. The instance
    /// comes from the dataset cache, so repeated preparations only pay
    /// the materialization once.
    pub fn prepare(
        &self,
        bench: &str,
        variant: Variant,
        scale: Scale,
        seed: u64,
    ) -> Result<Prepared> {
        let tmpl = self.dataset(bench, scale, seed)?;
        self.prepare_kernel(&tmpl.kernel, &variant.opts(tmpl.default_tasks))
    }

    /// Compile (or fetch) an arbitrary kernel under explicit options.
    pub fn prepare_kernel(
        &self,
        kernel: &crate::compiler::ast::Kernel,
        opts: &CodegenOpts,
    ) -> Result<Prepared> {
        let (ck, cache_hit) = self.cached_compile(kernel, opts)?;
        Ok(Prepared { kernel: kernel.name.clone(), ck, cache_hit })
    }

    /// Execute one request end to end: resolve the benchmark instance,
    /// compile through the cache, link, simulate, and validate against the
    /// benchmark's native oracle.
    pub fn run(&self, req: RunRequest) -> Result<RunReport> {
        self.run_ref(&req)
    }

    /// [`Engine::run`] with the run's event trace, when the effective
    /// config enables tracing (`None` otherwise — the untraced path
    /// constructs no tracer and is bit-identical to [`Engine::run`]).
    pub fn run_traced(&self, req: RunRequest) -> Result<(RunReport, Option<Trace>)> {
        self.run_ref_traced(&req)
    }

    fn run_ref(&self, req: &RunRequest) -> Result<RunReport> {
        self.run_ref_traced(req).map(|(rep, _)| rep)
    }

    fn run_ref_traced(&self, req: &RunRequest) -> Result<(RunReport, Option<Trace>)> {
        let tmpl = self.dataset(&req.bench, req.scale, req.seed)?;
        let inst = tmpl.instantiate();
        let tasks = if req.tasks == 0 { inst.default_tasks } else { req.tasks };
        let opts = match &req.opts {
            Some(o) => o.clone(),
            None => req.variant.opts(tasks),
        };
        let cfg = self.effective_cfg(req);
        let (run, trace) = self.exec_traced(&cfg, inst, &opts)?;
        let report = RunReport {
            bench: req.bench.clone(),
            variant: req.variant,
            variant_label: req.config_label(),
            cfg_name: cfg.name.clone(),
            far_latency_ns: cfg.mem.far_latency_ns,
            sched_policy: cfg.sched_policy,
            fabric: cfg.mem.fabric.kind,
            cores: cfg.cluster.cores,
            faults: cfg.mem.fabric.faults,
            service: cfg.service,
            scale: req.scale,
            seed: req.seed,
            key: req.key.clone(),
            cache_hit: run.cache_hit,
            store_hit: false,
            stats: run.stats,
        };
        Ok((report, trace))
    }

    /// Run a caller-materialized [`Instance`] under explicit options,
    /// returning the stats and the final memory image. This is the
    /// primitive behind [`Engine::run`]; tests and the PJRT oracle use it
    /// directly for kernels outside the benchmark registry.
    pub fn run_instance(&self, inst: Instance, opts: &CodegenOpts) -> Result<InstanceRun> {
        self.exec(&self.cfg, inst, opts)
    }

    fn exec(&self, cfg: &SimConfig, inst: Instance, opts: &CodegenOpts) -> Result<InstanceRun> {
        self.exec_traced(cfg, inst, opts).map(|(run, _)| run)
    }

    fn exec_traced(
        &self,
        cfg: &SimConfig,
        inst: Instance,
        opts: &CodegenOpts,
    ) -> Result<(InstanceRun, Option<Trace>)> {
        let (ck, cache_hit) = self.cached_compile(&inst.kernel, opts)?;
        let n = cfg.cluster.cores.max(1) as usize;
        let (mut run, mut trace) = if n == 1 {
            // The pre-cluster path, untouched: cores=1 is bit-identical
            // to the single-core simulator by construction.
            let mut prog = sim::link(cfg, &ck, inst.mem, &inst.params);
            let (stats, trace) = sim::run_traced(cfg, &mut prog)?;
            (inst.check)(&prog.mem)?;
            (InstanceRun { stats, mem: prog.mem, cache_hit }, trace)
        } else {
            // Multi-core: every core links its own snapshot of the same
            // dataset (private compute node, shared far fabric). Each final
            // image must independently pass the benchmark oracle.
            let mut progs: Vec<sim::Program> =
                (0..n).map(|_| sim::link(cfg, &ck, inst.mem.snapshot(), &inst.params)).collect();
            let (stats, trace) = sim::cluster::run_cluster_traced(cfg, &mut progs)?;
            for p in &progs {
                (inst.check)(&p.mem)?;
            }
            let mem = progs.swap_remove(0).mem;
            (InstanceRun { stats, mem, cache_hit }, trace)
        };
        // The open-loop service replay rides on the completed batch run:
        // it calibrates per-request cost from the run's own stats, then
        // fills the `svc_*` fields. Off (the default) touches nothing —
        // this branch is what the differential suite pins.
        if cfg.service.enabled() {
            sim::service::simulate_traced(&cfg.service, &mut run.stats, trace.as_mut());
        }
        Ok((run, trace))
    }

    /// Fan a request matrix across `threads` workers, sharing this
    /// session's kernel cache; any failure aborts with the offending
    /// request named. Results come back in matrix order.
    ///
    /// With a store attached this is a planner: store hits are served
    /// without simulating (stats bit-identical to a fresh run, pinned by
    /// the differential suite) and each completed miss is written back
    /// atomically, so a killed sweep resumes across processes.
    pub fn sweep(&self, matrix: &[RunRequest], threads: usize) -> Result<Vec<RunReport>> {
        if self.store.is_none() {
            let results = pool::parallel_map(matrix.len(), threads, |i| {
                self.run_and_record(&matrix[i], None)
            });
            return results.into_iter().collect();
        }
        self.sweep_stored(matrix, threads)
    }

    /// Partition a matrix against the attached store: which cells are
    /// already present (hits) and which must be simulated (misses).
    /// Requires a store; computing fingerprints materializes datasets
    /// (kernel ASTs can be scale-dependent) but never simulates.
    pub fn plan(&self, matrix: &[RunRequest]) -> Result<SweepPlan> {
        let st = self.store.as_ref().ok_or_else(|| {
            anyhow!("no sweep store attached (set {} or use with_store)", store::STORE_ENV)
        })?;
        let mut plan = SweepPlan {
            total: matrix.len(),
            hits: Vec::new(),
            misses: Vec::new(),
            corrupt: Vec::new(),
            fingerprints: Vec::with_capacity(matrix.len()),
        };
        for (i, req) in matrix.iter().enumerate() {
            let fp = self.cell_fingerprint(req)?;
            plan.fingerprints.push(fp);
            if st.contains(fp) {
                plan.hits.push(i);
            } else {
                plan.misses.push(i);
                if st.quarantined_cell(fp) {
                    plan.corrupt.push(i);
                }
            }
        }
        Ok(plan)
    }

    /// Simulate (and persist) at most `limit` of the plan's missing
    /// cells, returning the pre-execution plan. This is the resumable
    /// unit `coroamu sweep` is built on; the differential suite uses a
    /// small `limit` to model a sweep killed mid-grid.
    pub fn populate(&self, matrix: &[RunRequest], threads: usize, limit: usize) -> Result<SweepPlan> {
        let plan = self.plan(matrix)?;
        let todo: Vec<usize> = plan.misses.iter().copied().take(limit).collect();
        let results = pool::parallel_map(todo.len(), threads, |j| {
            let i = todo[j];
            self.run_and_record(&matrix[i], Some(plan.fingerprints[i]))
        });
        for r in results {
            r?;
        }
        Ok(plan)
    }

    fn sweep_stored(&self, matrix: &[RunRequest], threads: usize) -> Result<Vec<RunReport>> {
        let plan = self.plan(matrix)?;
        let st = self.store.as_ref().expect("sweep_stored requires a store");
        let mut out: Vec<Option<RunReport>> = matrix.iter().map(|_| None).collect();
        // Serve hits from disk first. A cell that fails verification here
        // (corrupted since the plan) is quarantined by `get` and falls
        // through to the miss list — re-simulated, never trusted.
        let mut misses = plan.misses.clone();
        for &i in &plan.hits {
            match st.get(plan.fingerprints[i]) {
                Some(stats) => out[i] = Some(self.report_from_store(&matrix[i], stats)),
                None => misses.push(i),
            }
        }
        misses.sort_unstable();
        let results = pool::parallel_map(misses.len(), threads, |j| {
            let i = misses[j];
            self.run_and_record(&matrix[i], Some(plan.fingerprints[i]))
        });
        for (j, r) in results.into_iter().enumerate() {
            out[misses[j]] = Some(r?);
        }
        Ok(out.into_iter().map(|o| o.expect("every cell served or simulated")).collect())
    }

    /// Run one request, annotating failures with its identity; when `fp`
    /// is given, commit the result to the store before returning.
    fn run_and_record(&self, req: &RunRequest, fp: Option<u64>) -> Result<RunReport> {
        let rep = self.run_ref(req).map_err(|e| {
            anyhow!("{} [{} / {} / seed {}]: {e:#}", req.bench, req.config_label(), req.key, req.seed)
        })?;
        if let (Some(fp), Some(st)) = (fp, self.store.as_ref()) {
            let meta = store::CellMeta {
                bench: rep.bench.clone(),
                variant: rep.variant_label.clone(),
                key: rep.key.clone(),
                cfg: rep.cfg_name.clone(),
                scale: format!("{:?}", rep.scale),
                seed: rep.seed,
            };
            st.put(fp, &meta, &rep.stats)?;
        }
        Ok(rep)
    }

    /// Provenance for a store-served cell is recomputed from the request
    /// and the session config — only the stats come from disk.
    fn report_from_store(&self, req: &RunRequest, stats: RunStats) -> RunReport {
        let cfg = self.effective_cfg(req);
        RunReport {
            bench: req.bench.clone(),
            variant: req.variant,
            variant_label: req.config_label(),
            cfg_name: cfg.name.clone(),
            far_latency_ns: cfg.mem.far_latency_ns,
            sched_policy: cfg.sched_policy,
            fabric: cfg.mem.fabric.kind,
            cores: cfg.cluster.cores,
            faults: cfg.mem.fabric.faults,
            service: cfg.service,
            scale: req.scale,
            seed: req.seed,
            key: req.key.clone(),
            cache_hit: false,
            store_hit: true,
            stats,
        }
    }

    /// The canonical cell fingerprint of a request: a stable (FNV-1a,
    /// process-independent) hash over everything that determines the
    /// simulated output — kernel AST, effective codegen options, the
    /// full effective `SimConfig` (latency, policy, fabric, cores,
    /// faults, service, trace — every simulate-time override applied;
    /// a traced run's stats carry trace counters, so it must not alias
    /// an untraced cell), dataset
    /// identity (bench, scale, seed) and resolved concurrency. The
    /// request's `key`/`label` grouping strings are display-only and
    /// deliberately excluded.
    pub fn cell_fingerprint(&self, req: &RunRequest) -> Result<u64> {
        let tmpl = self.dataset(&req.bench, req.scale, req.seed)?;
        let tasks = if req.tasks == 0 { tmpl.default_tasks } else { req.tasks };
        let opts = match &req.opts {
            Some(o) => o.clone(),
            None => req.variant.opts(tasks),
        };
        let cfg = self.effective_cfg(req);
        let bench = req.bench.to_ascii_lowercase();
        let variant = req.config_label();
        Ok(store::cell_fingerprint(&store::CellKey {
            bench: &bench,
            variant: &variant,
            tasks,
            scale: req.scale,
            seed: req.seed,
            kernel_fp: store::stable_fingerprint(&tmpl.kernel),
            opts_fp: store::stable_fingerprint(&opts),
            cfg_fp: store::stable_fingerprint(&cfg),
        }))
    }

    /// The session config with the request's simulate-time overrides
    /// (far latency, scheduler policy, fabric) applied. None of the
    /// overrides touches compilation, so the kernel cache is shared
    /// across the whole sweep.
    fn effective_cfg(&self, req: &RunRequest) -> SimConfig {
        let mut cfg = self.cfg.clone();
        if let Some(ns) = req.latency_ns {
            cfg = cfg.with_far_latency_ns(ns);
        }
        if let Some(p) = req.sched_policy {
            cfg.sched_policy = p;
        }
        if let Some(f) = req.fabric {
            cfg.mem.fabric.kind = f;
        }
        if let Some(n) = req.cores {
            cfg.cluster.cores = n;
        }
        if let Some(f) = req.faults {
            cfg.mem.fabric.faults = f;
        }
        if let Some(s) = req.service {
            cfg.service = s;
        }
        if let Some(t) = req.trace {
            cfg.trace = t;
        }
        cfg
    }

    /// The cache proper. The lock is held across `compile` so concurrent
    /// sweep workers never compile the same kernel twice — compilation is
    /// microseconds against simulations that are seconds, and the "exactly
    /// one compilation per distinct kernel" accounting is part of the API
    /// contract (tested below and in the integration suite).
    fn cached_compile(
        &self,
        kernel: &crate::compiler::ast::Kernel,
        opts: &CodegenOpts,
    ) -> Result<(Arc<CompiledKernel>, bool)> {
        let key = CacheKey {
            kernel: kernel.name.clone(),
            kernel_fp: fingerprint(kernel),
            opts_fp: fingerprint(opts),
            amu_fp: fingerprint(&self.cfg.amu),
        };
        let mut map = self.cache.lock().unwrap();
        if let Some(ck) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((ck.clone(), true));
        }
        let ck = Arc::new(compile(kernel, opts, &self.cfg.amu)?);
        map.insert(key, ck.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok((ck, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builder_defaults() {
        let r = RunRequest::new("gups", Variant::CoroAmuFull);
        assert_eq!(r.bench, "gups");
        assert_eq!(r.variant, Variant::CoroAmuFull);
        assert_eq!(r.tasks, 0, "0 = benchmark default");
        assert_eq!(r.scale, Scale::Small);
        assert_eq!(r.seed, 42);
        assert_eq!(r.key, "");
        assert_eq!(r.latency_ns, None);
        assert_eq!(r.sched_policy, None, "default = session policy");
        assert_eq!(r.fabric, None, "default = session fabric");
        assert_eq!(r.cores, None, "default = session cluster shape");
        assert_eq!(r.faults, None, "default = session faults (off)");
        assert!(r.opts.is_none() && r.label.is_none());
        assert_eq!(r.config_label(), "CoroAMU-Full");
    }

    #[test]
    fn request_builder_setters() {
        let r = RunRequest::new("bs", Variant::Serial)
            .tasks(7)
            .scale(Scale::Tiny)
            .seed(9)
            .key("k")
            .latency_ns(800.0);
        assert_eq!((r.tasks, r.scale, r.seed), (7, Scale::Tiny, 9));
        assert_eq!(r.key, "k");
        assert_eq!(r.latency_ns, Some(800.0));
    }

    #[test]
    fn prepare_twice_hits_cache() {
        let engine = Engine::new(SimConfig::nh_g());
        let a = engine.prepare("gups", Variant::CoroAmuFull, Scale::Tiny, 42).unwrap();
        assert!(!a.cache_hit);
        // Different seed, same kernel AST: still a hit.
        let b = engine.prepare("gups", Variant::CoroAmuFull, Scale::Tiny, 7).unwrap();
        assert!(b.cache_hit);
        assert_eq!(a.ck.num_tasks, b.ck.num_tasks);
        let cs = engine.cache_stats();
        assert_eq!((cs.hits, cs.misses, cs.entries), (1, 1, 1));
    }

    #[test]
    fn distinct_opts_miss() {
        let engine = Engine::new(SimConfig::nh_g());
        engine.prepare("gups", Variant::Serial, Scale::Tiny, 1).unwrap();
        engine.prepare("gups", Variant::CoroAmuFull, Scale::Tiny, 1).unwrap();
        let cs = engine.cache_stats();
        assert_eq!((cs.hits, cs.misses, cs.entries), (0, 2, 2));
    }

    #[test]
    fn run_reports_provenance_and_latency_override() {
        let engine = Engine::new(SimConfig::nh_g());
        let r = engine
            .run(RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny).latency_ns(800.0))
            .unwrap();
        assert_eq!(r.bench, "gups");
        assert_eq!(r.far_latency_ns, 800.0);
        assert_eq!(r.cfg_name, "nh-g");
        assert!(!r.cache_hit, "first run compiles");
        assert!(r.stats.cycles > 0);
        let text = r.render();
        assert!(text.contains("bench=gups"), "{text}");
        assert!(text.contains("far=800ns"), "{text}");
        assert!(text.contains("oracle            PASS"), "{text}");
        // Same request again: served from cache, flagged as such.
        let r2 = engine
            .run(RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny).latency_ns(800.0))
            .unwrap();
        assert!(r2.cache_hit);
        assert!(r2.render().contains("kernel=cached"));
    }

    #[test]
    fn latency_override_does_not_fork_cache() {
        let engine = Engine::new(SimConfig::nh_g());
        for lat in [100.0, 200.0, 400.0] {
            engine
                .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).latency_ns(lat))
                .unwrap();
        }
        let cs = engine.cache_stats();
        assert_eq!(cs.misses, 1, "latency is link-time, not compile-time");
        assert_eq!(cs.hits, 2);
    }

    #[test]
    fn policy_sweep_completes_and_shares_the_kernel_cache() {
        // The acceptance matrix shape: policies x latencies, one compile.
        let engine = Engine::new(SimConfig::nh_g());
        let mut matrix = Vec::new();
        for p in SchedPolicyKind::ALL {
            for lat in [200.0, 800.0] {
                matrix.push(
                    RunRequest::new("gups", Variant::CoroAmuFull)
                        .scale(Scale::Tiny)
                        .latency_ns(lat)
                        .policy(p)
                        .key(format!("{lat}/{}", p.label())),
                );
            }
        }
        let rs = engine.sweep(&matrix, 4).unwrap();
        assert_eq!(rs.len(), 8);
        for (req, rep) in matrix.iter().zip(&rs) {
            assert_eq!(Some(rep.sched_policy), req.sched_policy);
            assert_eq!(rep.stats.sched_policy, rep.sched_policy.label());
            assert!(rep.stats.cycles > 0);
            assert!(rep.render().contains(&format!("sched={}", rep.sched_policy.label())));
        }
        let cs = engine.cache_stats();
        assert_eq!(cs.misses, 1, "policy/latency are simulate-time: one compile for 8 runs");
        assert_eq!(cs.hits, 7);
    }

    #[test]
    fn fabric_sweep_completes_and_shares_the_kernel_cache() {
        // The fabric acceptance-matrix shape: fabrics x latencies through
        // one engine session must compile the kernel exactly once.
        let engine = Engine::new(SimConfig::nh_g());
        let mut matrix = Vec::new();
        for f in FabricKind::ALL {
            for lat in [200.0, 800.0] {
                matrix.push(
                    RunRequest::new("gups", Variant::CoroAmuFull)
                        .scale(Scale::Tiny)
                        .latency_ns(lat)
                        .fabric(f)
                        .key(format!("{lat}/{}", f.label())),
                );
            }
        }
        let rs = engine.sweep(&matrix, 4).unwrap();
        assert_eq!(rs.len(), 8);
        for (req, rep) in matrix.iter().zip(&rs) {
            assert_eq!(Some(rep.fabric), req.fabric);
            assert_eq!(rep.stats.fabric, rep.fabric.label());
            assert!(rep.stats.cycles > 0);
            assert!(rep.render().contains(&format!("fabric={}", rep.fabric.label())));
        }
        let cs = engine.cache_stats();
        assert_eq!(cs.misses, 1, "fabric/latency are simulate-time: one compile for 8 runs");
        assert_eq!(cs.hits, 7);
        let ds = engine.dataset_stats();
        assert_eq!(ds.misses, 1, "one dataset build for the whole fabric matrix");
    }

    #[test]
    fn explicit_default_fabric_is_invisible() {
        let engine = Engine::new(SimConfig::nh_g());
        let base = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny))
            .unwrap();
        let explicit = engine
            .run(
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .fabric(FabricKind::FixedDelay),
            )
            .unwrap();
        assert_eq!(base.stats, explicit.stats, "explicit FixedDelay must not move a cycle");
        assert_eq!(base.fabric, FabricKind::FixedDelay);
    }

    #[test]
    fn cores_override_does_not_fork_caches() {
        // The cluster axis is simulate-time: a 1/2/4-core sweep compiles
        // the kernel once and builds the dataset once.
        let engine = Engine::new(SimConfig::nh_g());
        for n in [1u32, 2, 4] {
            let r = engine
                .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).cores(n))
                .unwrap();
            assert_eq!(r.cores, n);
            assert_eq!(r.stats.cluster_cores, if n == 1 { 0 } else { n });
        }
        let cs = engine.cache_stats();
        assert_eq!(cs.misses, 1, "cores is simulate-time, not compile-time");
        assert_eq!(cs.hits, 2);
        let ds = engine.dataset_stats();
        assert_eq!(ds.misses, 1, "cores must not fork the dataset cache");
        assert_eq!(ds.hits, 2);
    }

    #[test]
    fn explicit_cores_1_is_invisible() {
        // `.cores(1)` must take the plain single-core path bit-for-bit.
        let engine = Engine::new(SimConfig::nh_g());
        let base = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny))
            .unwrap();
        let explicit = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).cores(1))
            .unwrap();
        assert_eq!(base.stats, explicit.stats, "explicit cores=1 must not move a cycle");
        assert_eq!(explicit.cores, 1);
        assert!(!explicit.render().contains("cores="), "single-core provenance stays unchanged");
    }

    #[test]
    fn multi_core_runs_report_cluster_stats_and_pass_oracles() {
        let engine = Engine::new(SimConfig::nh_g().with_fabric(FabricKind::Queued { depth: 8 }));
        let solo = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).cores(1))
            .unwrap();
        let duo = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).cores(2))
            .unwrap();
        assert_eq!(duo.stats.cluster_cores, 2);
        assert_eq!(duo.stats.core_cycles.len(), 2);
        assert!(
            duo.stats.cycles > solo.stats.cycles,
            "two cores on one queued fabric must contend ({} vs {})",
            duo.stats.cycles,
            solo.stats.cycles
        );
        assert!(duo.stats.cluster_fairness > 0.0 && duo.stats.cluster_fairness <= 1.0);
        let text = duo.render();
        assert!(text.contains("cores=2"), "{text}");
        assert!(text.contains("cluster"), "{text}");
        assert!(text.contains("core 0"), "{text}");
        // The oracle ran on both cores' images (exec checks each one).
        assert!(text.contains("oracle            PASS"), "{text}");
    }

    #[test]
    fn explicit_faults_off_is_invisible() {
        // `.faults(off)` must take the bare-fabric path bit-for-bit; the
        // provenance line never mentions faults on fault-free runs.
        let engine = Engine::new(SimConfig::nh_g());
        let base = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny))
            .unwrap();
        let explicit = engine
            .run(
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .faults(FaultConfig::off()),
            )
            .unwrap();
        assert_eq!(base.stats, explicit.stats, "explicit faults=off must not move a cycle");
        assert_eq!(base.stats.faults, "");
        assert!(!base.render().contains("faults="), "fault-free provenance stays unchanged");
    }

    #[test]
    fn faults_override_does_not_fork_caches_and_reports() {
        // The chaos axis is simulate-time: an off/mild/heavy sweep
        // compiles the kernel once and builds the dataset once, and a
        // faulted run renders its resilience counters.
        let engine = Engine::new(SimConfig::nh_g());
        let mut last = None;
        for spec in [FaultConfig::off(), FaultConfig::mild(), FaultConfig::heavy()] {
            let r = engine
                .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).faults(spec))
                .unwrap();
            assert_eq!(r.faults, spec);
            last = Some(r);
        }
        let cs = engine.cache_stats();
        assert_eq!(cs.misses, 1, "faults is simulate-time, not compile-time");
        assert_eq!(cs.hits, 2);
        let ds = engine.dataset_stats();
        assert_eq!(ds.misses, 1, "faults must not fork the dataset cache");
        assert_eq!(ds.hits, 2);
        let heavy = last.unwrap();
        assert_eq!(heavy.stats.faults, "heavy");
        assert!(heavy.stats.fault_nacks > 0, "heavy chaos produced no NACKs");
        let text = heavy.render();
        assert!(text.contains("faults=heavy"), "{text}");
        assert!(text.contains("resilience"), "{text}");
        assert!(text.contains("oracle            PASS"), "{text}");
    }

    #[test]
    fn explicit_service_off_is_invisible() {
        // `.service(off)` must skip the queueing replay bit-for-bit; the
        // provenance line never mentions service on batch runs.
        let engine = Engine::new(SimConfig::nh_g());
        let base = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny))
            .unwrap();
        let explicit = engine
            .run(
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .service(ServiceConfig::off()),
            )
            .unwrap();
        assert_eq!(base.stats, explicit.stats, "explicit service=off must not move a cycle");
        assert_eq!(base.stats.service, "");
        assert_eq!(base.stats.svc_offered, 0);
        assert!(!base.render().contains("service="), "batch provenance stays unchanged");
    }

    #[test]
    fn service_override_does_not_fork_caches_and_reports() {
        // The overload axis is simulate-time: an off/steady/overload
        // sweep compiles the kernel once and builds the dataset once,
        // and a service run renders its goodput accounting.
        let engine = Engine::new(SimConfig::nh_g());
        let mut last = None;
        for spec in [ServiceConfig::off(), ServiceConfig::steady(), ServiceConfig::overload()] {
            let r = engine
                .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).service(spec))
                .unwrap();
            assert_eq!(r.service, spec);
            last = Some(r);
        }
        let cs = engine.cache_stats();
        assert_eq!(cs.misses, 1, "service is simulate-time, not compile-time");
        assert_eq!(cs.hits, 2);
        let ds = engine.dataset_stats();
        assert_eq!(ds.misses, 1, "service must not fork the dataset cache");
        assert_eq!(ds.hits, 2);
        let over = last.unwrap();
        assert_eq!(over.stats.service, "overload");
        assert!(over.stats.svc_capacity_cost > 0, "calibrated from the batch run");
        assert_eq!(
            over.stats.svc_offered,
            over.stats.svc_accepted + over.stats.svc_rejected,
            "admission accounting must conserve requests"
        );
        let text = over.render();
        assert!(text.contains("service=overload"), "{text}");
        assert!(text.contains("goodput"), "{text}");
        assert!(text.contains("sojourn"), "{text}");
        assert!(text.contains("oracle            PASS"), "{text}");
    }

    #[test]
    fn explicit_trace_off_is_invisible() {
        // `.trace(off)` must construct no tracer and not move a cycle;
        // the trace counters stay zero on untraced runs.
        let engine = Engine::new(SimConfig::nh_g());
        let base = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny))
            .unwrap();
        let (explicit, trace) = engine
            .run_traced(
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .trace(TraceConfig::off()),
            )
            .unwrap();
        assert!(trace.is_none(), "trace off must return no trace");
        assert_eq!(base.stats, explicit.stats, "explicit trace=off must not move a cycle");
        assert_eq!(base.stats.trace_events, 0);
        assert_eq!(base.stats.trace_dropped, 0);
    }

    #[test]
    fn trace_override_does_not_fork_caches_and_attributes_stalls() {
        let engine = Engine::new(SimConfig::nh_g());
        let base = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny))
            .unwrap();
        let (rep, trace) = engine
            .run_traced(
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .trace(TraceConfig::on()),
            )
            .unwrap();
        let trace = trace.expect("tracing on must return a trace");
        assert!(trace.total > 0, "a real run must observe events");
        assert_eq!(rep.stats.trace_events, trace.total);
        assert_eq!(rep.stats.trace_dropped, trace.dropped);
        // Tracing must not move a single timing stat: strip the trace
        // counters and the stats must equal the untraced run exactly.
        let mut stripped = rep.stats.clone();
        stripped.trace_events = 0;
        stripped.trace_dropped = 0;
        assert_eq!(stripped, base.stats, "tracing must not perturb the simulation");
        // The profile must attribute at least 95% of stall cycles.
        let s = &rep.stats.stalls;
        let total = s.remote_mem + s.local_mem + s.mispredict + s.backpressure;
        assert!(
            trace.stall_coverage(total) >= 0.95,
            "profile covers {:.1}% of stalls",
            trace.stall_coverage(total) * 100.0
        );
        let cs = engine.cache_stats();
        assert_eq!(cs.misses, 1, "trace is simulate-time, not compile-time");
        assert_eq!(cs.hits, 1);
        let ds = engine.dataset_stats();
        assert_eq!(ds.misses, 1, "trace must not fork the dataset cache");
        assert_eq!(ds.hits, 1);
    }

    #[test]
    fn explicit_default_policy_is_invisible() {
        let engine = Engine::new(SimConfig::nh_g());
        let base = engine
            .run(RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny))
            .unwrap();
        let explicit = engine
            .run(
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .policy(SchedPolicyKind::ArrivalOrder),
            )
            .unwrap();
        assert_eq!(base.stats, explicit.stats, "explicit ArrivalOrder must not move a cycle");
        assert_eq!(base.sched_policy, SchedPolicyKind::ArrivalOrder);
    }

    #[test]
    fn sweep_builds_each_dataset_exactly_once() {
        let engine = Engine::new(SimConfig::nh_g());
        let matrix: Vec<RunRequest> = [100.0, 200.0, 400.0, 800.0, 1600.0]
            .iter()
            .map(|lat| {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .latency_ns(*lat)
                    .key(format!("{lat}"))
            })
            .collect();
        let rs = engine.sweep(&matrix, 4).unwrap();
        assert_eq!(rs.len(), 5);
        for r in &rs {
            assert!(r.stats.cycles > 0);
        }
        let ds = engine.dataset_stats();
        assert_eq!(ds.misses, 1, "a 5-point sweep must build the dataset exactly once");
        assert_eq!(ds.hits, 4, "the other four points restore the snapshot");
        assert_eq!(ds.entries, 1);
        // The oracle ran on all five restored images (Engine::exec always
        // checks), so restore fidelity is covered by the sweep passing.
    }

    #[test]
    fn dataset_cache_forks_on_scale_and_seed() {
        let engine = Engine::new(SimConfig::nh_g());
        engine.run(RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny).seed(1)).unwrap();
        engine.run(RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny).seed(2)).unwrap();
        engine.run(RunRequest::new("gups", Variant::Serial).scale(Scale::Small).seed(1)).unwrap();
        let ds = engine.dataset_stats();
        assert_eq!((ds.hits, ds.misses, ds.entries), (0, 3, 3));
    }

    #[test]
    fn dataset_cache_is_bounded() {
        let engine = Engine::new(SimConfig::nh_g());
        for seed in 0..20u64 {
            engine
                .run(RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny).seed(seed))
                .unwrap();
        }
        let ds = engine.dataset_stats();
        assert_eq!(ds.misses, 20, "distinct seeds are distinct datasets");
        assert!(
            ds.entries <= super::DATASET_CACHE_CAP,
            "dataset cache must stay bounded, got {} entries",
            ds.entries
        );
    }

    #[test]
    fn dataset_restore_is_pure() {
        // The first run mutates its snapshot (GUPS updates the table);
        // the second must see the pristine dataset again and reproduce
        // the run bit-for-bit.
        let engine = Engine::new(SimConfig::nh_g());
        let req = || RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny).seed(9);
        let a = engine.run(req()).unwrap().stats;
        let b = engine.run(req()).unwrap().stats;
        assert_eq!(a, b, "restored dataset must reproduce the run exactly");
        let ds = engine.dataset_stats();
        assert_eq!((ds.hits, ds.misses), (1, 1));
    }

    #[test]
    fn unknown_bench_errors() {
        let engine = Engine::new(SimConfig::nh_g());
        assert!(engine.run(RunRequest::new("nope", Variant::Serial)).is_err());
        assert!(engine.prepare("nope", Variant::Serial, Scale::Tiny, 1).is_err());
        let ds = engine.dataset_stats();
        assert_eq!(ds.entries, 0, "failed builds must not occupy dataset-cache slots");
        assert_eq!(ds.misses, 0);
    }

    #[test]
    fn lookup_finds_by_bench_variant_key() {
        let engine = Engine::new(SimConfig::nh_g());
        let matrix = vec![
            RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny).key("a"),
            RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny).key("a"),
        ];
        let rs = engine.sweep(&matrix, 2).unwrap();
        assert_eq!(rs.len(), 2);
        assert!(lookup(&rs, "gups", Variant::Serial, "a").is_some());
        assert!(lookup(&rs, "gups", Variant::CoroAmuD, "a").is_none());
    }

    fn store_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("coroamu-engine-ut-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn cell_fingerprint_is_stable_and_keyed_on_every_knob() {
        // Two independent sessions (the in-process analogue of two
        // processes — the FNV primitive itself is pinned process-stable
        // in store::tests) must agree on every fingerprint.
        let a = Engine::new(SimConfig::nh_g());
        let b = Engine::new(SimConfig::nh_g());
        let base = || RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Tiny);
        let fp = a.cell_fingerprint(&base()).unwrap();
        assert_eq!(fp, b.cell_fingerprint(&base()).unwrap(), "fingerprints must not be session-local");

        // Display-only fields are NOT part of the key: the same physical
        // cell under a different grouping key must hit.
        assert_eq!(fp, a.cell_fingerprint(&base().key("800/arrival")).unwrap());

        // Flipping any single knob must move the fingerprint.
        let flips: Vec<RunRequest> = vec![
            RunRequest::new("bfs", Variant::CoroAmuFull).scale(Scale::Tiny),
            base().tasks(3),
            RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny),
            RunRequest::new("gups", Variant::CoroAmuFull).scale(Scale::Small),
            base().seed(7),
            base().latency_ns(800.0),
            base().policy(SchedPolicyKind::LatencyAware),
            base().fabric(FabricKind::Queued { depth: 16 }),
            base().cores(4),
            base().faults(FaultConfig::mild()),
            base().service(ServiceConfig::steady()),
            base().trace(TraceConfig::on()),
        ];
        for req in &flips {
            assert_ne!(
                fp,
                a.cell_fingerprint(req).unwrap(),
                "knob flip not captured by the fingerprint: {req:?}"
            );
        }
        // A session-config difference (not expressible as a request
        // override) must also fork the key.
        let c = Engine::new(SimConfig::skylake());
        assert_ne!(fp, c.cell_fingerprint(&base()).unwrap());
    }

    #[test]
    fn store_sweep_serves_second_session_without_simulating() {
        let dir = store_dir("second-pass");
        let matrix: Vec<RunRequest> = [200.0, 800.0]
            .iter()
            .map(|lat| {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .latency_ns(*lat)
                    .key(format!("{lat}"))
            })
            .collect();

        let e1 = Engine::new(SimConfig::nh_g()).with_store(store::Store::open(&dir).unwrap());
        let first = e1.sweep(&matrix, 2).unwrap();
        assert!(first.iter().all(|r| !r.store_hit), "cold store: everything simulates");
        assert_eq!(e1.store().unwrap().len(), 2, "every completed cell is persisted");

        // A brand-new session (fresh caches — a new process, effectively)
        // over the same store serves the whole matrix from disk.
        let e2 = Engine::new(SimConfig::nh_g()).with_store(store::Store::open(&dir).unwrap());
        let plan = e2.plan(&matrix).unwrap();
        assert_eq!((plan.hits.len(), plan.misses.len()), (2, 0));
        assert_eq!(plan.summary(), "plan total=2 hits=2 misses=0 corrupt=0");
        let second = e2.sweep(&matrix, 2).unwrap();
        assert!(second.iter().all(|r| r.store_hit));
        assert!(second[0].render().contains("source=store"));
        assert_eq!(e2.cache_stats().misses, 0, "zero compiles: nothing simulated");
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.stats, b.stats, "store-served stats must be bit-identical");
            assert_eq!(
                (a.far_latency_ns, a.sched_policy, a.fabric, a.cores),
                (b.far_latency_ns, b.sched_policy, b.fabric, b.cores),
                "recomputed provenance must match"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn killed_sweep_resumes_completing_only_remaining_cells() {
        let dir = store_dir("resume");
        let matrix: Vec<RunRequest> = [100.0, 200.0, 400.0, 800.0]
            .iter()
            .map(|lat| {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .latency_ns(*lat)
                    .key(format!("{lat}"))
            })
            .collect();

        // "Kill" the first sweep after two cells: populate with a limit,
        // then drop the engine (planner) on the floor.
        {
            let e = Engine::new(SimConfig::nh_g()).with_store(store::Store::open(&dir).unwrap());
            let plan = e.populate(&matrix, 2, 2).unwrap();
            assert_eq!((plan.hits.len(), plan.misses.len()), (0, 4));
            assert_eq!(e.store().unwrap().len(), 2, "two cells committed before the kill");
        }

        // The resuming session simulates exactly the remaining two.
        let e = Engine::new(SimConfig::nh_g()).with_store(store::Store::open(&dir).unwrap());
        let plan = e.plan(&matrix).unwrap();
        assert_eq!((plan.hits.len(), plan.misses.len()), (2, 2));
        let rs = e.sweep(&matrix, 2).unwrap();
        assert_eq!(rs.len(), 4);
        assert_eq!(rs.iter().filter(|r| r.store_hit).count(), 2);
        assert_eq!(e.cache_stats().misses, 1, "one compile for the two resumed cells");
        assert_eq!(e.plan(&matrix).unwrap().misses.len(), 0, "grid complete after resume");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_reports_quarantined_corrupt_cells() {
        let dir = store_dir("corrupt-plan");
        let matrix: Vec<RunRequest> = [200.0, 800.0]
            .iter()
            .map(|lat| {
                RunRequest::new("gups", Variant::CoroAmuFull)
                    .scale(Scale::Tiny)
                    .latency_ns(*lat)
                    .key(format!("{lat}"))
            })
            .collect();
        let e = Engine::new(SimConfig::nh_g()).with_store(store::Store::open(&dir).unwrap());
        e.sweep(&matrix, 2).unwrap();
        let plan = e.plan(&matrix).unwrap();
        assert_eq!(plan.summary(), "plan total=2 hits=2 misses=0 corrupt=0");
        // Damage one cell on disk; the next read quarantines it.
        let fp = plan.fingerprints[0];
        let path = dir.join(format!("{fp:016x}.cell"));
        assert!(path.exists());
        std::fs::write(&path, "garbage").unwrap();
        assert!(e.store().unwrap().get(fp).is_none(), "damaged cell must quarantine");
        let plan = e.plan(&matrix).unwrap();
        assert_eq!(plan.summary(), "plan total=2 hits=1 misses=1 corrupt=1");
        assert_eq!(plan.corrupt, plan.misses, "corrupt cells are a subset of misses");
        // Re-sweeping heals: the corrupt cell is re-simulated and rewritten.
        e.sweep(&matrix, 2).unwrap();
        let healed = e.plan(&matrix).unwrap();
        assert_eq!(healed.summary(), "plan total=2 hits=2 misses=0 corrupt=0");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_without_store_never_touches_disk_and_plan_requires_one() {
        let engine = Engine::new(SimConfig::nh_g());
        assert!(engine.store().is_none());
        let matrix = vec![RunRequest::new("gups", Variant::Serial).scale(Scale::Tiny)];
        let err = engine.plan(&matrix).unwrap_err();
        assert!(format!("{err:#}").contains("no sweep store"), "{err:#}");
        let rs = engine.sweep(&matrix, 1).unwrap();
        assert!(!rs[0].store_hit);
    }
}
