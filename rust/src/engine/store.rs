//! Persistent, content-addressed result store for [`Engine`] sweeps
//! (ROADMAP item 4).
//!
//! Every [`RunRequest`](super::RunRequest) reduces to a canonical **cell
//! fingerprint**: a stable 64-bit hash over everything that determines
//! the simulation's output — the kernel AST, the codegen options, and
//! the full *effective* [`SimConfig`](crate::config::SimConfig) (AMU
//! shape, far latency, scheduler policy, fabric, faults, cluster cores,
//! service load), plus the dataset identity (bench, scale, seed) and the
//! resolved concurrency. Display-only request fields (`key`, `label`,
//! sweep thread count) are deliberately **not** part of the fingerprint:
//! the same physical cell reached under two different grouping keys must
//! hit.
//!
//! The store is a flat directory (pointed at by `COROAMU_STORE` or
//! [`Store::open`]) with one file per cell, named by the fingerprint.
//! Each file is a line-oriented text record with a versioned header, the
//! fingerprint echoed back, human-readable provenance (`meta` lines), an
//! exhaustive field-by-field serialization of [`RunStats`] (floats as
//! `f64::to_bits` hex, so round-trips are bit-identical), and a trailing
//! FNV-1a checksum. Readers verify header, fingerprint, checksum and
//! full-field coverage; anything that fails — truncation, stale version,
//! unknown or missing fields — is **quarantined** (renamed to
//! `*.corrupt`) and treated as a miss, never trusted.
//!
//! Writes go through a temp file + `rename`, so a sweep killed mid-grid
//! leaves only whole cells behind and a later process resumes from them
//! (see [`Engine::plan`](super::Engine::plan)).
//!
//! Unlike the in-memory kernel cache (which hashes with the process-seeded
//! `DefaultHasher`), every hash here is FNV-1a over canonical strings —
//! stable across processes, platforms and rebuilds by construction.

use crate::benchmarks::Scale;
use crate::sim::RunStats;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Environment variable naming the store directory; when set, the CLI
/// and `harness::grid` attach it to every engine session.
pub const STORE_ENV: &str = "COROAMU_STORE";

/// Store format + semantics version. Bump whenever the cell file format
/// or the fingerprint composition changes; old cells then fail the
/// header check and are re-simulated rather than trusted.
pub const STORE_VERSION: u32 = 2;

fn header() -> String {
    format!("coroamu-store v{STORE_VERSION}")
}

/// FNV-1a 64-bit. Chosen over `DefaultHasher` because the result must be
/// identical across processes (resume) and builds (CI artifacts).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable fingerprint of any `Debug` value: FNV-1a over its debug
/// rendering. Derived `Debug` of plain data (no pointers, no iteration
/// over unordered maps) renders identically in every process.
pub fn stable_fingerprint<T: std::fmt::Debug>(t: &T) -> u64 {
    fnv1a(format!("{t:?}").as_bytes())
}

/// Everything that determines a cell's simulated output. Assembled by
/// [`Engine::cell_fingerprint`](super::Engine::cell_fingerprint); kept
/// as a struct so tests can flip one component at a time.
#[derive(Debug, Clone, Copy)]
pub struct CellKey<'a> {
    pub bench: &'a str,
    /// Variant (or opts-override) display label — distinct variants with
    /// identical codegen options stay distinct (conservative: a spurious
    /// miss re-simulates; a spurious hit would lie).
    pub variant: &'a str,
    /// Resolved concurrency (the benchmark default if the request said 0).
    pub tasks: usize,
    pub scale: Scale,
    pub seed: u64,
    /// [`stable_fingerprint`] of the kernel AST (scale-dependent kernels
    /// fork naturally, mirroring the in-memory kernel-cache key).
    pub kernel_fp: u64,
    /// [`stable_fingerprint`] of the effective [`CodegenOpts`](crate::compiler::CodegenOpts).
    pub opts_fp: u64,
    /// [`stable_fingerprint`] of the effective `SimConfig` — after the
    /// request's latency/policy/fabric/cores/faults/service overrides are
    /// applied, so every simulate-time knob is in the key.
    pub cfg_fp: u64,
}

/// The canonical cell fingerprint: FNV-1a over the composite identity
/// string. The version tag makes fingerprints from older store layouts
/// unreachable rather than wrong.
pub fn cell_fingerprint(k: &CellKey) -> u64 {
    fnv1a(
        format!(
            "coroamu-cell-v{STORE_VERSION}|{}|{}|tasks={}|{:?}|seed={}|kernel={:016x}|opts={:016x}|cfg={:016x}",
            k.bench, k.variant, k.tasks, k.scale, k.seed, k.kernel_fp, k.opts_fp, k.cfg_fp
        )
        .as_bytes(),
    )
}

/// Human-readable provenance stored next to the stats (`meta` lines).
/// Never parsed back into results — provenance for a store-served report
/// is recomputed from the request so it cannot drift.
#[derive(Debug, Clone, Default)]
pub struct CellMeta {
    pub bench: String,
    pub variant: String,
    pub key: String,
    pub cfg: String,
    pub scale: String,
    pub seed: u64,
}

/// A persistent fingerprint → [`RunStats`] map: one file per cell.
#[derive(Debug)]
pub struct Store {
    dir: PathBuf,
}

impl Store {
    /// Open (creating if needed) a store directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Store> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow!("cannot create store dir {}: {e}", dir.display()))?;
        Ok(Store { dir })
    }

    /// Open the store named by `COROAMU_STORE`, or `None` when unset.
    pub fn from_env() -> Result<Option<Store>> {
        match std::env::var(STORE_ENV) {
            Ok(dir) if !dir.trim().is_empty() => Ok(Some(Store::open(dir)?)),
            _ => Ok(None),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn cell_path(&self, fp: u64) -> PathBuf {
        self.dir.join(format!("{fp:016x}.cell"))
    }

    /// Fetch a cell's stats. Absent → `None`. Present but unreadable,
    /// truncated, checksum-damaged, stale-versioned or otherwise
    /// unparseable → quarantined to `*.corrupt` and `None`, so the
    /// planner re-simulates instead of trusting it.
    pub fn get(&self, fp: u64) -> Option<RunStats> {
        let path = self.cell_path(fp);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
            Err(_) => {
                self.quarantine(&path);
                return None;
            }
        };
        match decode(fp, &text) {
            Ok(stats) => Some(stats),
            Err(_) => {
                self.quarantine(&path);
                None
            }
        }
    }

    fn quarantine(&self, path: &Path) {
        // Best-effort: a failed rename leaves the bad cell in place, and
        // every future read keeps treating it as a miss.
        let _ = std::fs::rename(path, path.with_extension("corrupt"));
    }

    pub fn contains(&self, fp: u64) -> bool {
        self.cell_path(fp).exists()
    }

    /// Write a cell atomically: temp file in the same directory, then
    /// `rename` over the final name. A killed sweep therefore leaves only
    /// complete, checksummed cells.
    pub fn put(&self, fp: u64, meta: &CellMeta, stats: &RunStats) -> Result<()> {
        let text = encode(fp, meta, stats);
        let tmp = self.dir.join(format!("{fp:016x}.tmp{}", std::process::id()));
        std::fs::write(&tmp, text.as_bytes())
            .map_err(|e| anyhow!("store write {} failed: {e}", tmp.display()))?;
        std::fs::rename(&tmp, self.cell_path(fp)).map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            anyhow!("store commit {:016x} failed: {e}", fp)
        })
    }

    /// Number of committed cells.
    pub fn len(&self) -> usize {
        self.count_ext("cell")
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of quarantined (`*.corrupt`) cells.
    pub fn quarantined(&self) -> usize {
        self.count_ext("corrupt")
    }

    /// Has this specific cell been quarantined as corrupt?
    pub fn quarantined_cell(&self, fp: u64) -> bool {
        self.cell_path(fp).with_extension("corrupt").exists()
    }

    /// Probe that the store directory is actually writable (write + remove
    /// a temp file). `sweep --dry-run` calls this so an unwritable store
    /// fails the plan up front instead of mid-populate.
    pub fn check_writable(&self) -> Result<()> {
        let probe = self.dir.join(format!(".writable.{}", std::process::id()));
        std::fs::write(&probe, b"probe")
            .map_err(|e| anyhow!("store dir {} is not writable: {e}", self.dir.display()))?;
        let _ = std::fs::remove_file(&probe);
        Ok(())
    }

    fn count_ext(&self, ext: &str) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| e.path().extension().map(|x| x == ext).unwrap_or(false))
                    .count()
            })
            .unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Cell file encoding
// ---------------------------------------------------------------------------
//
// Line-oriented, order-insensitive for stat fields:
//
//   coroamu-store v1
//   cell 6bb5a3f2…            fingerprint echo (defends against renames)
//   meta bench gups           provenance, checksummed but never parsed back
//   u cycles 123              u64/u32/usize fields, decimal
//   f far_mlp 4010666…        f64 fields, to_bits hex (bit-identical)
//   s fabric queued:16        String fields ("-" = empty)
//   v core_cycles 1,2,3       Vec<u64>/[u64;N] fields ("-" = empty)
//   checksum 85944171…        FNV-1a over every preceding byte

/// Empty-value sentinel for `s`/`v` lines (no label or vector the
/// simulator produces is a bare `-`), avoiding trailing-space encodings
/// that do not survive casual inspection or editing.
const EMPTY: &str = "-";

fn join_u64(v: &[u64]) -> String {
    if v.is_empty() {
        EMPTY.to_string()
    } else {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    }
}

fn split_u64(s: &str) -> Result<Vec<u64>> {
    if s == EMPTY {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|x| x.parse::<u64>().map_err(|_| anyhow!("bad vector element '{x}'")))
        .collect()
}

fn encode(fp: u64, meta: &CellMeta, st: &RunStats) -> String {
    let mut out = String::with_capacity(4096);
    out.push_str(&header());
    out.push('\n');
    out.push_str(&format!("cell {fp:016x}\n"));
    out.push_str(&format!("meta bench {}\n", meta.bench));
    out.push_str(&format!("meta variant {}\n", meta.variant));
    out.push_str(&format!("meta key {}\n", meta.key));
    out.push_str(&format!("meta cfg {}\n", meta.cfg));
    out.push_str(&format!("meta scale {}\n", meta.scale));
    out.push_str(&format!("meta seed {}\n", meta.seed));

    macro_rules! wu {
        ($($f:ident)+) => { $( out.push_str(&format!("u {} {}\n", stringify!($f), st.$f)); )+ };
    }
    macro_rules! wf {
        ($($f:ident)+) => { $(
            out.push_str(&format!("f {} {:016x}\n", stringify!($f), st.$f.to_bits()));
        )+ };
    }
    macro_rules! ws {
        ($($f:ident)+) => { $(
            let v: &str = &st.$f;
            out.push_str(&format!("s {} {}\n", stringify!($f), if v.is_empty() { EMPTY } else { v }));
        )+ };
    }
    macro_rules! wv {
        ($($f:ident)+) => { $(
            out.push_str(&format!("v {} {}\n", stringify!($f), join_u64(&st.$f)));
        )+ };
    }

    wu!(cycles dyn_instrs cond_branches cond_mispredicts indirect_jumps indirect_mispredicts
        bafins_taken bafins_fallthrough bafin_mispredicts loads stores prefetches
        l1_hits l1_misses far_lines aloads astores amu_max_inflight awaits
        switches ctx_ops tasks_completed
        sched_polls sched_picks sched_holds sched_indirect_jumps sched_indirect_mispredicts
        fabric_requests fabric_max_inflight fabric_queue_stalls fabric_p50 fabric_p99
        fabric_hot_hits fabric_hot_misses fabric_writebacks cluster_cores
        fault_nacks fault_retries fault_retry_cycles fault_timeouts fault_degraded_cycles
        fault_slow_path fault_max_stall
        svc_capacity_cost svc_offered svc_accepted svc_rejected svc_shed_expired
        svc_served svc_goodput svc_timed_out svc_p50 svc_p99 svc_p999 svc_max_queue
        svc_degraded_served svc_degraded_spells
        trace_events trace_dropped);
    wf!(far_mlp far_busy_frac cluster_fairness);
    out.push_str(&format!("f stalls.remote_mem {:016x}\n", st.stalls.remote_mem.to_bits()));
    out.push_str(&format!("f stalls.local_mem {:016x}\n", st.stalls.local_mem.to_bits()));
    out.push_str(&format!("f stalls.mispredict {:016x}\n", st.stalls.mispredict.to_bits()));
    out.push_str(&format!("f stalls.backpressure {:016x}\n", st.stalls.backpressure.to_bits()));
    ws!(sched_policy fabric faults service);
    wv!(core_cycles core_instrs core_fabric_requests core_fabric_p50 core_fabric_p99
        core_fabric_stalls core_fault_retries core_fault_slow_path);
    out.push_str(&format!("v dyn_by_tag {}\n", join_u64(&st.dyn_by_tag)));

    let sum = fnv1a(out.as_bytes());
    out.push_str(&format!("checksum {sum:016x}\n"));
    out
}

fn parse_hex(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).map_err(|_| anyhow!("bad hex '{s}'"))
}

fn take(map: &mut BTreeMap<String, (char, String)>, tag: char, name: &str) -> Result<String> {
    match map.remove(name) {
        Some((t, v)) if t == tag => Ok(v),
        Some((t, _)) => bail!("field {name} has tag '{t}', expected '{tag}'"),
        None => bail!("missing field {name}"),
    }
}

fn decode(expect_fp: u64, text: &str) -> Result<RunStats> {
    let body = text.strip_suffix('\n').unwrap_or(text);
    let (payload, sum_line) = match body.rfind('\n') {
        Some(i) => (&body[..i + 1], &body[i + 1..]),
        None => bail!("truncated cell"),
    };
    let sum = sum_line.strip_prefix("checksum ").ok_or_else(|| anyhow!("missing checksum"))?;
    ensure!(parse_hex(sum.trim())? == fnv1a(payload.as_bytes()), "checksum mismatch");

    let mut lines = payload.lines();
    let head = lines.next().unwrap_or("");
    ensure!(head == header(), "stale or foreign store header '{head}'");
    let cell = lines
        .next()
        .and_then(|l| l.strip_prefix("cell "))
        .ok_or_else(|| anyhow!("missing cell line"))?;
    ensure!(parse_hex(cell)? == expect_fp, "cell fingerprint mismatch (renamed file?)");

    let mut map: BTreeMap<String, (char, String)> = BTreeMap::new();
    for line in lines {
        if line.starts_with("meta ") {
            continue;
        }
        let mut parts = line.splitn(3, ' ');
        let (tag, name, value) = (parts.next(), parts.next(), parts.next());
        match (tag, name, value) {
            (Some(t), Some(n), Some(v)) if t.len() == 1 => {
                let t = t.chars().next().unwrap();
                ensure!(
                    map.insert(n.to_string(), (t, v.to_string())).is_none(),
                    "duplicate field {n}"
                );
            }
            _ => bail!("malformed line '{line}'"),
        }
    }

    let mut st = RunStats::default();
    macro_rules! ru {
        ($($f:ident)+) => { $(
            st.$f = take(&mut map, 'u', stringify!($f))?
                .parse()
                .map_err(|_| anyhow!("bad integer for {}", stringify!($f)))?;
        )+ };
    }
    macro_rules! rf {
        ($($f:ident)+) => { $(
            st.$f = f64::from_bits(parse_hex(&take(&mut map, 'f', stringify!($f))?)?);
        )+ };
    }
    macro_rules! rs_ {
        ($($f:ident)+) => { $(
            let v = take(&mut map, 's', stringify!($f))?;
            st.$f = if v == EMPTY { String::new() } else { v };
        )+ };
    }
    macro_rules! rv {
        ($($f:ident)+) => { $(
            st.$f = split_u64(&take(&mut map, 'v', stringify!($f))?)?;
        )+ };
    }

    ru!(cycles dyn_instrs cond_branches cond_mispredicts indirect_jumps indirect_mispredicts
        bafins_taken bafins_fallthrough bafin_mispredicts loads stores prefetches
        l1_hits l1_misses far_lines aloads astores amu_max_inflight awaits
        switches ctx_ops tasks_completed
        sched_polls sched_picks sched_holds sched_indirect_jumps sched_indirect_mispredicts
        fabric_requests fabric_max_inflight fabric_queue_stalls fabric_p50 fabric_p99
        fabric_hot_hits fabric_hot_misses fabric_writebacks cluster_cores
        fault_nacks fault_retries fault_retry_cycles fault_timeouts fault_degraded_cycles
        fault_slow_path fault_max_stall
        svc_capacity_cost svc_offered svc_accepted svc_rejected svc_shed_expired
        svc_served svc_goodput svc_timed_out svc_p50 svc_p99 svc_p999 svc_max_queue
        svc_degraded_served svc_degraded_spells
        trace_events trace_dropped);
    rf!(far_mlp far_busy_frac cluster_fairness);
    st.stalls.remote_mem = f64::from_bits(parse_hex(&take(&mut map, 'f', "stalls.remote_mem")?)?);
    st.stalls.local_mem = f64::from_bits(parse_hex(&take(&mut map, 'f', "stalls.local_mem")?)?);
    st.stalls.mispredict = f64::from_bits(parse_hex(&take(&mut map, 'f', "stalls.mispredict")?)?);
    st.stalls.backpressure =
        f64::from_bits(parse_hex(&take(&mut map, 'f', "stalls.backpressure")?)?);
    rs_!(sched_policy fabric faults service);
    rv!(core_cycles core_instrs core_fabric_requests core_fabric_p50 core_fabric_p99
        core_fabric_stalls core_fault_retries core_fault_slow_path);
    let tags = split_u64(&take(&mut map, 'v', "dyn_by_tag")?)?;
    st.dyn_by_tag =
        tags.try_into().map_err(|v: Vec<u64>| anyhow!("dyn_by_tag has {} entries", v.len()))?;

    ensure!(
        map.is_empty(),
        "unknown fields in cell: {}",
        map.keys().cloned().collect::<Vec<_>>().join(", ")
    );
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::stats::StallBuckets;

    fn test_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("coroamu-store-ut-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Every `RunStats` field set to a distinct nonzero value, as an
    /// exhaustive struct literal (no `..Default::default()`): adding a
    /// field to `RunStats` breaks this test's compilation, forcing the
    /// serializer above to learn about it before the store can lie by
    /// omission.
    fn full_stats() -> RunStats {
        RunStats {
            cycles: 1,
            dyn_instrs: 2,
            dyn_by_tag: [3, 4, 5, 6, 7],
            stalls: StallBuckets {
                remote_mem: 8.5,
                local_mem: 9.25,
                mispredict: 10.125,
                backpressure: -0.0, // sign of zero must survive (to_bits round-trip)
            },
            cond_branches: 11,
            cond_mispredicts: 12,
            indirect_jumps: 13,
            indirect_mispredicts: 14,
            bafins_taken: 15,
            bafins_fallthrough: 16,
            bafin_mispredicts: 17,
            loads: 18,
            stores: 19,
            prefetches: 20,
            l1_hits: 21,
            l1_misses: 22,
            far_lines: 23,
            far_mlp: 24.75,
            far_busy_frac: 0.255,
            aloads: 26,
            astores: 27,
            amu_max_inflight: 28,
            awaits: 29,
            switches: 30,
            ctx_ops: 31,
            tasks_completed: 32,
            sched_policy: "batched:4".into(),
            sched_polls: 33,
            sched_picks: 34,
            sched_holds: 35,
            sched_indirect_jumps: 36,
            sched_indirect_mispredicts: 37,
            fabric: "queued:16".into(),
            fabric_requests: 38,
            fabric_max_inflight: 39,
            fabric_queue_stalls: 40,
            fabric_p50: 41,
            fabric_p99: 42,
            fabric_hot_hits: 43,
            fabric_hot_misses: 44,
            fabric_writebacks: 45,
            cluster_cores: 46,
            core_cycles: vec![47, 48],
            core_instrs: vec![49, 50],
            core_fabric_requests: vec![51, 52],
            core_fabric_p50: vec![53, 54],
            core_fabric_p99: vec![55, 56],
            core_fabric_stalls: vec![57, 58],
            cluster_fairness: 0.59,
            faults: "heavy".into(),
            fault_nacks: 60,
            fault_retries: 61,
            fault_retry_cycles: 62,
            fault_timeouts: 63,
            fault_degraded_cycles: 64,
            fault_slow_path: 65,
            fault_max_stall: 66,
            core_fault_retries: vec![67, 68],
            core_fault_slow_path: vec![69, 70],
            service: "overload".into(),
            svc_capacity_cost: 71,
            svc_offered: 72,
            svc_accepted: 73,
            svc_rejected: 74,
            svc_shed_expired: 75,
            svc_served: 76,
            svc_goodput: 77,
            svc_timed_out: 78,
            svc_p50: 79,
            svc_p99: 80,
            svc_p999: 81,
            svc_max_queue: 82,
            svc_degraded_served: 83,
            svc_degraded_spells: 84,
            trace_events: 85,
            trace_dropped: 86,
        }
    }

    #[test]
    fn fnv1a_matches_the_published_vectors() {
        // The reference FNV-1a 64 test vectors: the primitive must be the
        // standard function, i.e. process- and platform-independent.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn every_field_roundtrips_bit_identically() {
        let dir = test_dir("roundtrip");
        let store = Store::open(&dir).unwrap();
        let st = full_stats();
        store.put(7, &CellMeta::default(), &st).unwrap();
        let back = store.get(7).expect("cell just written");
        assert_eq!(back, st, "store round-trip must be bit-identical");
        // -0.0 == 0.0 under PartialEq; pin the bit pattern explicitly.
        assert_eq!(back.stalls.backpressure.to_bits(), (-0.0f64).to_bits());
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn default_stats_roundtrip_including_empty_strings_and_vecs() {
        let dir = test_dir("defaults");
        let store = Store::open(&dir).unwrap();
        let st = RunStats::default();
        store.put(9, &CellMeta::default(), &st).unwrap();
        assert_eq!(store.get(9).unwrap(), st);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_quarantines_instead_of_trusting() {
        let dir = test_dir("corrupt");
        let store = Store::open(&dir).unwrap();
        store.put(3, &CellMeta::default(), &full_stats()).unwrap();

        // Flip one digit of a stat value: checksum catches it.
        let path = dir.join(format!("{:016x}.cell", 3));
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replacen("u cycles 1\n", "u cycles 2\n", 1);
        std::fs::write(&path, text).unwrap();
        assert!(store.get(3).is_none(), "damaged cell must not be served");
        assert!(!path.exists(), "damaged cell must be quarantined");
        assert_eq!(store.quarantined(), 1);
        assert!(store.get(3).is_none(), "quarantined cell stays a miss");

        // Truncation (killed writer bypassing the tmp+rename protocol).
        store.put(4, &CellMeta::default(), &full_stats()).unwrap();
        let path = dir.join(format!("{:016x}.cell", 4));
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(store.get(4).is_none());
        assert_eq!(store.quarantined(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_version_and_renamed_cells_are_rejected() {
        let dir = test_dir("stale");
        let store = Store::open(&dir).unwrap();
        store.put(5, &CellMeta::default(), &full_stats()).unwrap();

        // A cell renamed to another fingerprint must not be served under it.
        std::fs::rename(dir.join(format!("{:016x}.cell", 5)), dir.join(format!("{:016x}.cell", 6)))
            .unwrap();
        assert!(store.get(6).is_none(), "fingerprint echo must catch renames");

        // A future/stale header version is re-simulated, not trusted.
        store.put(5, &CellMeta::default(), &full_stats()).unwrap();
        let path = dir.join(format!("{:016x}.cell", 5));
        let text = std::fs::read_to_string(&path).unwrap();
        let stale = text.replacen(&header(), "coroamu-store v0", 1);
        // Re-checksum so only the version check can reject it.
        let body = stale.rsplit_once("checksum ").unwrap().0.to_string();
        let sum = fnv1a(body.as_bytes());
        std::fs::write(&path, format!("{body}checksum {sum:016x}\n")).unwrap();
        assert!(store.get(5).is_none(), "stale store versions must be re-simulated");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cell_fingerprint_separates_every_component() {
        let base = CellKey {
            bench: "gups",
            variant: "CoroAMU-Full",
            tasks: 16,
            scale: Scale::Tiny,
            seed: 42,
            kernel_fp: 1,
            opts_fp: 2,
            cfg_fp: 3,
        };
        let fp = cell_fingerprint(&base);
        assert_eq!(fp, cell_fingerprint(&base.clone()), "pure function of the key");
        let flips = [
            CellKey { bench: "bfs", ..base },
            CellKey { variant: "Serial", ..base },
            CellKey { tasks: 8, ..base },
            CellKey { scale: Scale::Small, ..base },
            CellKey { seed: 43, ..base },
            CellKey { kernel_fp: 11, ..base },
            CellKey { opts_fp: 12, ..base },
            CellKey { cfg_fp: 13, ..base },
        ];
        for (i, k) in flips.iter().enumerate() {
            assert_ne!(fp, cell_fingerprint(k), "component {i} did not affect the fingerprint");
        }
    }

    #[test]
    fn put_overwrites_and_reports_len() {
        let dir = test_dir("overwrite");
        let store = Store::open(&dir).unwrap();
        assert!(store.is_empty());
        assert!(!store.contains(1));
        store.put(1, &CellMeta::default(), &RunStats::default()).unwrap();
        store.put(1, &CellMeta::default(), &full_stats()).unwrap();
        assert_eq!(store.len(), 1);
        assert!(store.contains(1));
        assert_eq!(store.get(1).unwrap(), full_stats(), "second put wins");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
