//! The evaluation coordinator: builds (benchmark x variant x config)
//! job matrices, fans them across a worker pool, validates every run
//! against its native oracle, and aggregates results for the figure
//! harness. This is the L3 "leader" of the reproduction: it owns process
//! topology, run lifecycle and metric collection.

pub mod pool;

use crate::benchmarks::{self, Scale};
use crate::compiler::Variant;
use crate::config::SimConfig;
use crate::sim::RunStats;
use anyhow::{anyhow, Result};

/// One simulation job.
#[derive(Debug, Clone)]
pub struct Job {
    pub bench: String,
    pub variant: Variant,
    /// Coroutine concurrency; 0 = the benchmark's default.
    pub tasks: usize,
    pub cfg: SimConfig,
    pub scale: Scale,
    pub seed: u64,
    /// Free-form key the harness uses to group results (e.g. latency).
    pub key: String,
}

#[derive(Debug, Clone)]
pub struct RunResult {
    pub job: Job,
    pub stats: RunStats,
}

/// Execute a single job (compile -> link -> simulate -> oracle-check).
pub fn run_job(job: &Job) -> Result<RunResult> {
    let bench = benchmarks::by_name(&job.bench)
        .ok_or_else(|| anyhow!("unknown benchmark {}", job.bench))?;
    let inst = bench.instance(job.scale, job.seed)?;
    let tasks = if job.tasks == 0 { inst.default_tasks } else { job.tasks };
    let stats = benchmarks::execute(&job.cfg, inst, job.variant, tasks)?;
    Ok(RunResult { job: job.clone(), stats })
}

/// Run a job matrix across the worker pool; any failure aborts with the
/// offending job named.
pub fn run_matrix(jobs: Vec<Job>, threads: usize) -> Result<Vec<RunResult>> {
    let results = pool::parallel_map(jobs.len(), threads, |i| {
        let j = &jobs[i];
        run_job(j).map_err(|e| anyhow!("{} [{} / {} / {}]: {e:#}", j.bench, j.variant.label(), j.key, j.cfg.name))
    });
    results.into_iter().collect()
}

/// Find the result for (bench, variant, key).
pub fn lookup<'a>(rs: &'a [RunResult], bench: &str, variant: Variant, key: &str) -> Option<&'a RunResult> {
    rs.iter().find(|r| r.job.bench == bench && r.job.variant == variant && r.job.key == key)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_job(bench: &str, variant: Variant) -> Job {
        Job {
            bench: bench.into(),
            variant,
            tasks: 0,
            cfg: SimConfig::nh_g(),
            scale: Scale::Tiny,
            seed: 1,
            key: "t".into(),
        }
    }

    #[test]
    fn run_job_smoke() {
        let r = run_job(&tiny_job("gups", Variant::Serial)).unwrap();
        assert!(r.stats.cycles > 0);
    }

    #[test]
    fn unknown_bench_errors() {
        assert!(run_job(&tiny_job("nope", Variant::Serial)).is_err());
    }

    #[test]
    fn matrix_runs_parallel_and_lookup_works() {
        let jobs: Vec<Job> =
            ["gups", "stream"].iter().flat_map(|b| {
                [Variant::Serial, Variant::CoroAmuFull].iter().map(|v| tiny_job(b, *v)).collect::<Vec<_>>()
            }).collect();
        let rs = run_matrix(jobs, 4).unwrap();
        assert_eq!(rs.len(), 4);
        assert!(lookup(&rs, "gups", Variant::CoroAmuFull, "t").is_some());
        assert!(lookup(&rs, "gups", Variant::CoroAmuD, "t").is_none());
    }
}
