//! Legacy evaluation coordinator, now a thin compatibility layer over
//! [`crate::engine`]. The [`pool`] worker pool still lives here (the
//! engine's sweep fans out over it), but job execution is delegated to an
//! [`Engine`] session: new code should construct an `Engine` and call
//! [`Engine::run`] / [`Engine::sweep`] directly, which additionally shares
//! one compiled-kernel cache across the whole matrix.

pub mod pool;

use crate::benchmarks::Scale;
use crate::compiler::Variant;
use crate::config::SimConfig;
use crate::engine::{Engine, RunRequest};
use crate::sim::RunStats;
use anyhow::Result;

/// One simulation job (legacy shape; [`RunRequest`] is the engine-native
/// equivalent).
#[derive(Debug, Clone)]
pub struct Job {
    pub bench: String,
    pub variant: Variant,
    /// Coroutine concurrency; 0 = the benchmark's default.
    pub tasks: usize,
    pub cfg: SimConfig,
    pub scale: Scale,
    pub seed: u64,
    /// Free-form key the harness uses to group results (e.g. latency).
    pub key: String,
}

impl Job {
    /// The engine-native form of this job. The job's `cfg` becomes the
    /// engine session config, so no latency override is needed.
    pub fn to_request(&self) -> RunRequest {
        RunRequest::new(self.bench.clone(), self.variant)
            .tasks(self.tasks)
            .scale(self.scale)
            .seed(self.seed)
            .key(self.key.clone())
    }
}

#[derive(Debug, Clone)]
pub struct RunResult {
    pub job: Job,
    pub stats: RunStats,
}

/// Execute a single job (compile -> link -> simulate -> oracle-check)
/// through a throwaway engine session.
pub fn run_job(job: &Job) -> Result<RunResult> {
    let engine = Engine::new(job.cfg.clone());
    let report = engine.run(job.to_request())?;
    Ok(RunResult { job: job.clone(), stats: report.stats })
}

/// Run a job matrix across the worker pool; any failure aborts with the
/// offending job named. Jobs may carry heterogeneous configs, so each gets
/// its own engine session — prefer [`Engine::sweep`], which shares one
/// session (and one kernel cache) across the matrix.
pub fn run_matrix(jobs: Vec<Job>, threads: usize) -> Result<Vec<RunResult>> {
    let results = pool::parallel_map(jobs.len(), threads, |i| {
        let j = &jobs[i];
        run_job(j).map_err(|e| {
            anyhow::anyhow!("{} [{} / {} / {}]: {e:#}", j.bench, j.variant.label(), j.key, j.cfg.name)
        })
    });
    results.into_iter().collect()
}

/// Find the result for (bench, variant, key).
pub fn lookup<'a>(rs: &'a [RunResult], bench: &str, variant: Variant, key: &str) -> Option<&'a RunResult> {
    rs.iter().find(|r| r.job.bench == bench && r.job.variant == variant && r.job.key == key)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_job(bench: &str, variant: Variant) -> Job {
        Job {
            bench: bench.into(),
            variant,
            tasks: 0,
            cfg: SimConfig::nh_g(),
            scale: Scale::Tiny,
            seed: 1,
            key: "t".into(),
        }
    }

    #[test]
    fn run_job_smoke() {
        let r = run_job(&tiny_job("gups", Variant::Serial)).unwrap();
        assert!(r.stats.cycles > 0);
    }

    #[test]
    fn unknown_bench_errors() {
        assert!(run_job(&tiny_job("nope", Variant::Serial)).is_err());
    }

    #[test]
    fn job_converts_to_request() {
        let j = tiny_job("gups", Variant::CoroAmuD);
        let r = j.to_request();
        assert_eq!(r.bench, "gups");
        assert_eq!(r.variant, Variant::CoroAmuD);
        assert_eq!(r.scale, Scale::Tiny);
        assert_eq!((r.seed, r.key.as_str()), (1, "t"));
        assert_eq!(r.latency_ns, None, "job cfg is the session cfg");
    }

    #[test]
    fn matrix_runs_parallel_and_lookup_works() {
        let jobs: Vec<Job> =
            ["gups", "stream"].iter().flat_map(|b| {
                [Variant::Serial, Variant::CoroAmuFull].iter().map(|v| tiny_job(b, *v)).collect::<Vec<_>>()
            }).collect();
        let rs = run_matrix(jobs, 4).unwrap();
        assert_eq!(rs.len(), 4);
        assert!(lookup(&rs, "gups", Variant::CoroAmuFull, "t").is_some());
        assert!(lookup(&rs, "gups", Variant::CoroAmuD, "t").is_none());
    }
}
