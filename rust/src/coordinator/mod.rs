//! Worker-pool plumbing for parallel sweeps. The evaluation entry point
//! is [`crate::engine::Engine`] — construct a session and call
//! [`crate::engine::Engine::run`] / [`crate::engine::Engine::sweep`],
//! which shares one compiled-kernel cache (and, when a store is
//! attached, the persistent result store) across the whole matrix.
//! The engine's sweep fans out over [`pool::parallel_map`].
//!
//! The PR 1 `Job`/`run_job`/`run_matrix` compatibility layer is gone:
//! every run is keyed and recorded as an engine `RunRequest`, so nothing
//! can bypass the store's cell fingerprinting.

pub mod pool;
