//! Worker-thread pool for fanning simulation jobs across cores (no tokio
//! in the offline environment; simulations are CPU-bound anyway).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// One pre-allocated result slot. Workers write slots lock-free: the
/// atomic work cursor hands each index to exactly one worker, so every
/// slot has exactly one writer, and the `join` at the end of the scope
/// publishes the writes to the collecting thread.
struct Slot<T>(UnsafeCell<Option<T>>);

// SAFETY: slots are shared across worker threads, but the index
// uniqueness invariant above guarantees no slot is ever written by two
// threads (and never read until all writers have been joined).
unsafe impl<T: Send> Sync for Slot<T> {}

impl<T> Slot<T> {
    fn new() -> Self {
        Slot(UnsafeCell::new(None))
    }

    /// Write the slot's value.
    ///
    /// SAFETY: the caller must be the unique writer of this slot, and no
    /// reads may occur before the writer thread is joined.
    unsafe fn set(&self, v: T) {
        *self.0.get() = Some(v);
    }

    fn into_inner(self) -> Option<T> {
        self.0.into_inner()
    }
}

/// Evaluate `f(0..n)` across `threads` workers (work-stealing via an
/// atomic cursor); results are returned in index order. Panics in workers
/// propagate.
///
/// Results land lock-free in per-index slots — there is no shared results
/// mutex for completed items to serialize on, so high-thread sweeps of
/// short jobs scale with the worker count.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Slot<T>> = (0..n).map(|_| Slot::new()).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i);
                    // SAFETY: `fetch_add` returned `i` to this worker
                    // alone, and the main thread only reads after join.
                    unsafe { slots[i].set(r) };
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    slots.into_iter().map(|s| s.into_inner().expect("missing result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn high_thread_stress_fills_every_slot() {
        // Many short jobs over many workers: the pre-change global mutex
        // serialized exactly this shape. Every slot must come back, in
        // order, with no loss under contention.
        for _ in 0..8 {
            let out = parallel_map(1000, 16, |i| i * 3);
            assert_eq!(out.len(), 1000);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 3);
            }
        }
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn worker_panic_propagates() {
        parallel_map(4, 2, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}
