//! Worker-thread pool for fanning simulation jobs across cores (no tokio
//! in the offline environment; simulations are CPU-bound anyway).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Evaluate `f(0..n)` across `threads` workers (work-stealing via an
/// atomic cursor); results are returned in index order. Panics in workers
/// propagate.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i);
                    results.lock().unwrap()[i] = Some(r);
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    results.into_inner().unwrap().into_iter().map(|r| r.expect("missing result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn worker_panic_propagates() {
        parallel_map(4, 2, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}
