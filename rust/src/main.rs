//! `coroamu` — CLI for the CoroAMU reproduction. All verbs route through
//! the [`coroamu::engine::Engine`] session facade.
//!
//! ```text
//! coroamu report [--fig N | --all | --sched | --fabric [KIND] | --service [SPEC]] [--scale tiny|small|full] [--only a,b]
//! coroamu run --bench gups --variant full [--latency 200] [--policy arrival] [--fabric queued:16] [--service overload] [--tasks 96]
//! coroamu report --table1 | --table2
//! coroamu oracle            # PJRT cross-check against artifacts/
//! coroamu dump --bench gups --variant full   # CoroIR disassembly
//! ```
//!
//! Report modes are mutually exclusive: `--sched --fabric` (or any other
//! combination) is rejected with a nonzero exit rather than silently
//! running only one of them.

use anyhow::{bail, Context, Result};
use coroamu::benchmarks::{self, Scale};
use coroamu::compiler::Variant;
use coroamu::config::SimConfig;
use coroamu::engine::{Engine, RunRequest};
use coroamu::harness::{self, FigOpts};
use coroamu::ir::printer;
use coroamu::runtime;
use coroamu::sim::fabric::FabricKind;
use coroamu::sim::faults::FaultConfig;
use coroamu::sim::sched::SchedPolicyKind;
use coroamu::sim::service::ServiceConfig;
use coroamu::sim::trace::TraceConfig;
use coroamu::util::benchkit;
use coroamu::util::cli::Args;
use coroamu::util::table::Table;

fn parse_scale(s: &str) -> Result<Scale> {
    Ok(match s {
        "tiny" => Scale::Tiny,
        "small" => Scale::Small,
        "full" => Scale::Full,
        other => bail!("unknown scale {other} (tiny|small|full)"),
    })
}

fn fig_opts(args: &Args) -> Result<FigOpts> {
    let mut o = FigOpts::default();
    if let Some(s) = args.get("scale") {
        o.scale = parse_scale(s)?;
    }
    if let Some(t) = args.get_usize("threads") {
        o.threads = t;
    }
    if let Some(s) = args.get_u64("seed") {
        o.seed = s;
    }
    if let Some(list) = args.get_list("only") {
        o.only = list;
    }
    Ok(o)
}

fn cfg_from(args: &Args) -> Result<SimConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SimConfig::load_file(path)?,
        None => SimConfig::preset(args.get_or("preset", "nh-g"))?,
    };
    if let Some(lat) = args.get_f64("latency") {
        // `!(lat > 0.0)` rather than `lat <= 0.0`: also rejects NaN.
        if !(lat > 0.0) {
            bail!("--latency must be positive (got {lat})");
        }
        cfg = cfg.with_far_latency_ns(lat);
    }
    if let Some(p) = args.get("policy") {
        cfg = cfg.with_sched_policy(SchedPolicyKind::parse(p)?);
    }
    if let Some(f) = args.get("fabric") {
        cfg = cfg.with_fabric(FabricKind::parse(f)?);
    }
    if let Some(f) = args.get("faults") {
        cfg = cfg.with_faults(FaultConfig::parse(f)?);
    }
    if let Some(c) = args.get("cores") {
        // Manual parse rather than `get_u64` (which conflates absent and
        // unparseable): `--cores x` must fail loudly, not run single-core.
        let n: u32 = match c.parse() {
            Ok(v) if v > 0 => v,
            _ => bail!("--cores must be a positive integer (got '{c}')"),
        };
        cfg = cfg.with_cores(n);
    }
    if let Some(s) = args.get("service") {
        cfg = cfg.with_service(ServiceConfig::parse(s)?);
    }
    if let Some(l) = args.get("load") {
        // `--load N` alone enables service mode on the steady baseline;
        // on top of `--service` it overrides just the offered load.
        let pct: u32 = match l.parse() {
            Ok(v) if v > 0 => v,
            _ => bail!("--load must be a positive percent of capacity (got '{l}')"),
        };
        let mut s = if cfg.service.enabled() { cfg.service } else { ServiceConfig::steady() };
        s.load_pct = pct;
        cfg = cfg.with_service(s);
    }
    if let Some(d) = args.get("deadline") {
        if !cfg.service.enabled() {
            bail!("--deadline only applies to service mode (add --service or --load)");
        }
        let mult: u32 = match d.parse() {
            Ok(v) if v > 0 => v,
            _ => bail!("--deadline must be a positive cost multiple (got '{d}')"),
        };
        cfg.service.deadline_mult = mult;
    }
    if args.get("service").is_some() || args.get("load").is_some() {
        cfg.service.validate()?;
    }
    Ok(cfg)
}

/// Print report tables as aligned text, or as one JSON array when
/// `--json` is set (machine-readable, `util::benchkit::to_json`).
fn emit_tables(args: &Args, tables: &[Table]) {
    if args.flag("json") {
        print!("{}", benchkit::to_json(tables));
    } else {
        for t in tables {
            t.print();
        }
    }
}

/// The report modes selected on the command line. `report` accepts
/// exactly one; naming them all in the error keeps `--sched --fabric`
/// from silently dropping a flag.
fn selected_report_modes(args: &Args) -> Vec<&'static str> {
    let mut modes = Vec::new();
    for m in ["table1", "table2", "sched", "fabric", "cluster", "faults", "service", "all"] {
        if args.flag(m) {
            modes.push(m);
        }
    }
    if args.get("fig").is_some() {
        modes.push("fig");
    }
    if args.flag("grid") {
        modes.push("grid");
    }
    modes
}

fn cmd_report(args: &Args) -> Result<()> {
    let opts = fig_opts(args)?;
    let modes = selected_report_modes(args);
    if modes.len() > 1 {
        bail!(
            "conflicting report modes --{}: pick exactly one",
            modes.join(" --")
        );
    }
    if args.flag("table1") {
        emit_tables(args, &[cfg_from(args)?.table1()]);
        return Ok(());
    }
    if args.flag("table2") {
        emit_tables(args, &[benchmarks::table2()]);
        return Ok(());
    }
    if args.flag("sched") {
        eprintln!(
            "[coroamu] generating scheduler-policy sweep (scale {:?}, {} threads)...",
            opts.scale, opts.threads
        );
        emit_tables(args, &harness::fig_sched::run(&opts)?);
        return Ok(());
    }
    if args.flag("fabric") {
        // `--fabric` sweeps all backends; `--fabric queued:8` restricts
        // the axis to one (the value is honored, never ignored).
        let only = match args.get("fabric") {
            Some(v) => Some(FabricKind::parse(v)?),
            None => None,
        };
        eprintln!(
            "[coroamu] generating far-fabric sweep (scale {:?}, {} threads)...",
            opts.scale, opts.threads
        );
        emit_tables(args, &harness::fig_fabric::run(&opts, only)?);
        return Ok(());
    }
    if args.flag("cluster") {
        eprintln!(
            "[coroamu] generating cluster scaling sweep (scale {:?}, {} threads)...",
            opts.scale, opts.threads
        );
        emit_tables(args, &harness::fig_cluster::run(&opts)?);
        return Ok(());
    }
    if args.flag("faults") {
        // `--faults` sweeps the chaos intensities; `--faults heavy`
        // restricts the axis to one spec (the value is honored).
        let only = match args.get("faults") {
            Some(v) => Some(FaultConfig::parse(v)?),
            None => None,
        };
        eprintln!(
            "[coroamu] generating fault-injection sweep (scale {:?}, {} threads)...",
            opts.scale, opts.threads
        );
        emit_tables(args, &harness::fig_faults::run(&opts, only)?);
        return Ok(());
    }
    if args.flag("service") {
        // `--service` sweeps the offered-load axis; `--service overload`
        // restricts it to one spec (the value is honored).
        let only = match args.get("service") {
            Some(v) => Some(ServiceConfig::parse(v)?),
            None => None,
        };
        eprintln!(
            "[coroamu] generating service overload sweep (scale {:?}, {} threads)...",
            opts.scale, opts.threads
        );
        emit_tables(args, &harness::fig_service::run(&opts, only)?);
        return Ok(());
    }
    if args.flag("grid") {
        // Free-form query: `--grid "bench=gups,bfs;latency=200,800"`.
        let spec = args
            .get("grid")
            .context("--grid needs an axes spec like \"bench=gups;latency=200,800\"")?;
        let q = harness::grid::GridQuery::parse(spec)?;
        eprintln!(
            "[coroamu] running grid query (scale {:?}, {} threads)...",
            opts.scale, opts.threads
        );
        emit_tables(args, &q.run(&opts)?);
        return Ok(());
    }
    let figs: Vec<u32> = if args.flag("all") {
        harness::ALL_FIGURES.to_vec()
    } else if let Some(n) = args.get_u64("fig") {
        vec![n as u32]
    } else {
        bail!("report needs --fig N, --all, --sched, --fabric, --cluster, --faults, --service, --table1 or --table2");
    };
    let mut tables = Vec::new();
    for f in figs {
        eprintln!("[coroamu] generating figure {f} (scale {:?}, {} threads)...", opts.scale, opts.threads);
        tables.extend(harness::figure(f, &opts)?);
    }
    emit_tables(args, &tables);
    Ok(())
}

/// The sweep grids (`name`, session config, matrix) selected on the
/// `sweep` command line. Each mirrors the matrix its report mode runs,
/// so populating here makes the report a pure store read.
fn sweep_targets(args: &Args, opts: &FigOpts) -> Result<Vec<(String, SimConfig, Vec<RunRequest>)>> {
    let mut targets = Vec::new();
    let all = args.flag("all");
    if args.flag("grid") {
        let spec = args
            .get("grid")
            .context("--grid needs an axes spec like \"bench=gups;latency=200,800\"")?;
        let q = harness::grid::GridQuery::parse(spec)?;
        targets.push((format!("grid {spec}"), SimConfig::nh_g(), q.requests(opts)));
    }
    if all || args.flag("sched") {
        targets.push(("sched".into(), SimConfig::nh_g(), harness::fig_sched::requests(opts)));
    }
    if all || args.flag("fabric") {
        let fabs = harness::fig_fabric::fabrics(None);
        targets.push((
            "fabric".into(),
            SimConfig::nh_g(),
            harness::fig_fabric::requests(opts, &fabs),
        ));
    }
    if all || args.flag("faults") {
        let specs = harness::fig_faults::intensities(None);
        targets.push((
            "faults".into(),
            SimConfig::nh_g(),
            harness::fig_faults::requests(opts, &specs),
        ));
    }
    if all || args.flag("cluster") {
        targets.push((
            "cluster".into(),
            harness::fig_cluster::session_cfg(),
            harness::fig_cluster::requests(opts),
        ));
    }
    if all || args.flag("service") {
        let specs = harness::fig_service::loads(None);
        targets.push((
            "service".into(),
            SimConfig::nh_g(),
            harness::fig_service::requests(opts, &specs),
        ));
    }
    Ok(targets)
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let opts = fig_opts(args)?;
    let dir: std::path::PathBuf = match args.get("store") {
        Some(d) => d.into(),
        None => match std::env::var_os(coroamu::engine::store::STORE_ENV) {
            Some(d) if !d.is_empty() => d.into(),
            _ => bail!(
                "sweep needs a store: pass --store DIR or set {}",
                coroamu::engine::store::STORE_ENV
            ),
        },
    };
    let targets = sweep_targets(args, &opts)?;
    if targets.is_empty() {
        bail!("sweep needs --grid AXES, --sched, --fabric, --faults, --cluster, --service or --all");
    }
    let dry = args.flag("dry-run");
    // Probe writability up front so a read-only store dir fails the
    // dry-run audit with a nonzero exit instead of passing the plan and
    // crashing mid-populate.
    coroamu::engine::store::Store::open(dir.clone())?.check_writable()?;
    let mut out = Table::new("sweep plan", &["target", "phase", "total", "hits", "misses", "corrupt"]);
    {
        let mut emit = |name: &str, phase: &str, p: &coroamu::engine::SweepPlan| {
            if args.flag("json") {
                out.row(vec![
                    name.to_string(),
                    phase.to_string(),
                    p.total.to_string(),
                    p.hits.len().to_string(),
                    p.misses.len().to_string(),
                    p.corrupt.len().to_string(),
                ]);
            } else if phase == "plan" {
                // Machine-readable: CI greps `plan total=N hits=H misses=M`.
                println!("[sweep {name}] {}", p.summary());
            } else {
                println!("[sweep {name}] done: {}", p.summary());
            }
        };
        for (name, cfg, matrix) in targets {
            let engine =
                Engine::new(cfg).with_store(coroamu::engine::store::Store::open(dir.clone())?);
            let plan = engine.plan(&matrix)?;
            emit(&name, "plan", &plan);
            if dry {
                continue;
            }
            engine.populate(&matrix, opts.threads, usize::MAX)?;
            let done = engine.plan(&matrix)?;
            emit(&name, "done", &done);
        }
    }
    if args.flag("json") {
        print!("{}", benchkit::to_json(&[out]));
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let bench = args.get("bench").context("--bench required")?.to_string();
    let variant = Variant::parse(args.get_or("variant", "full")).context("bad --variant")?;
    let cfg = cfg_from(args)?;
    // `--trace [FILE]` forces tracing on even under an untraced preset;
    // a `[trace]`-enabled config file traces without the flag (and keeps
    // its own sampling knobs).
    let cfg_traced = cfg.trace.enabled;
    let traced = args.flag("trace") || cfg_traced;
    let engine = Engine::new(cfg);
    let mut req = RunRequest::new(bench, variant)
        .tasks(args.get_usize("tasks").unwrap_or(0))
        .scale(parse_scale(args.get_or("scale", "small"))?)
        .seed(args.get_u64("seed").unwrap_or(42));
    if !traced {
        engine.run(req)?.print();
        return Ok(());
    }
    if !cfg_traced {
        req = req.trace(TraceConfig::on());
    }
    let (rep, trace) = engine.run_traced(req)?;
    rep.print();
    let trace = trace.context("tracing enabled but the run produced no trace")?;
    if let Some(file) = args.get("trace") {
        coroamu::sim::trace::write_chrome_json(&trace, std::path::Path::new(file))?;
        eprintln!(
            "[coroamu] wrote Chrome trace JSON to {file} ({} of {} events retained, {} dropped)",
            trace.events.len(),
            trace.total,
            trace.dropped
        );
    }
    print!("{}", coroamu::sim::trace::render_profile(&trace));
    Ok(())
}

/// `coroamu trace`: one traced run end to end — simulate with tracing
/// forced on, export the Chrome trace-event JSON (Perfetto-loadable),
/// and print the stall-attribution profile. Equivalent to
/// `run --trace FILE` but with an always-written `--out` (default
/// `trace.json`) so CI and quick profiling need no flag juggling.
fn cmd_trace(args: &Args) -> Result<()> {
    let bench = args.get("bench").context("--bench required")?.to_string();
    let variant = Variant::parse(args.get_or("variant", "full")).context("bad --variant")?;
    let out = args.get_or("out", "trace.json").to_string();
    let mut cfg = cfg_from(args)?;
    if !cfg.trace.enabled {
        cfg.trace = TraceConfig::on();
    }
    let engine = Engine::new(cfg);
    let req = RunRequest::new(bench, variant)
        .tasks(args.get_usize("tasks").unwrap_or(0))
        .scale(parse_scale(args.get_or("scale", "small"))?)
        .seed(args.get_u64("seed").unwrap_or(42));
    let (rep, trace) = engine.run_traced(req)?;
    rep.print();
    let trace = trace.context("tracing enabled but the run produced no trace")?;
    coroamu::sim::trace::write_chrome_json(&trace, std::path::Path::new(&out))?;
    println!(
        "[coroamu] wrote Chrome trace JSON to {out} ({} of {} events retained, {} dropped)",
        trace.events.len(),
        trace.total,
        trace.dropped
    );
    print!("{}", coroamu::sim::trace::render_profile(&trace));
    Ok(())
}

fn cmd_dump(args: &Args) -> Result<()> {
    let bench = args.get("bench").context("--bench required")?;
    let variant = Variant::parse(args.get_or("variant", "full")).context("bad --variant")?;
    let engine = Engine::new(cfg_from(args)?);
    let b = benchmarks::by_name(bench).context("unknown benchmark")?;
    let inst = b.instance(Scale::Tiny, 42)?;
    let tasks = args.get_usize("tasks").unwrap_or(inst.default_tasks);
    let prep = engine.prepare_kernel(&inst.kernel, &variant.opts(tasks))?;
    let ck = &prep.ck;
    println!("{}", printer::function_to_string(&ck.func));
    println!(
        "// tasks={} ctx={}B spm_slot={}B sites={} groups={}",
        ck.num_tasks, ck.ctx_bytes, ck.spm_slot_bytes, ck.nsites, ck.ngroups
    );
    Ok(())
}

fn cmd_oracle(_args: &Args) -> Result<()> {
    if !runtime::artifacts_available() {
        bail!("artifacts/ not built — run `make artifacts` first");
    }
    let rt = runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    for b in runtime::oracle::GOLDEN_BENCHES {
        for v in [Variant::Serial, Variant::CoroAmuFull] {
            runtime::oracle::check_against_artifact(&rt, b, v)?;
            println!("  {b:<8} {:<13} simulator == AOT golden model  OK", v.label());
        }
    }
    Ok(())
}

const USAGE: &str = "usage: coroamu <report|sweep|run|trace|dump|oracle> [options]
  report --fig N | --all | --sched | --fabric [KIND] | --cluster | --faults [SPEC] | --service [SPEC] | --grid AXES | --table1 | --table2  [--scale tiny|small|full] [--only b1,b2] [--threads N] [--json]
         (report modes are mutually exclusive; AXES is `axis=v1,v2;axis=v` over bench,variant,latency,policy,fabric,faults,cores,service,seed,tasks,scale; --json prints the tables as one JSON array)
  sweep  --grid AXES | --sched | --fabric | --faults | --cluster | --service | --all  [--dry-run] [--store DIR] [--scale ...] [--threads N] [--only b1,b2] [--json]
         populate/resume the persistent result store (COROAMU_STORE or --store); --dry-run prints the hit/miss plan only
  run    --bench NAME [--variant serial|hand|s|d|full] [--preset nh-g|skylake] [--latency NS] [--policy fifo|arrival|batched[:N]|latency] [--fabric fixed|queued[:N]|dist[:uniform|bimodal]|tiered[:N]] [--faults off|mild|heavy|degrade|blackout|nack:PCT|spike:PCT] [--service off|steady|knee|overload|burst|load:PCT] [--load PCT] [--deadline MULT] [--cores N] [--tasks N] [--scale ...] [--trace [FILE]]
         --trace turns on cycle-level tracing and prints the stall-attribution profile; with FILE it also exports Chrome trace-event JSON (load in Perfetto)
  trace  --bench NAME [--out FILE] [run options]   traced run: simulate, export Chrome JSON (default trace.json), print profile
  dump   --bench NAME [--variant ...]     print generated CoroIR
  oracle                                  cross-check simulator vs PJRT artifacts
  help | --help                           print this message";

fn main() {
    let args = Args::from_env();
    // `--help` anywhere (or the `help` verb) prints usage and succeeds.
    if args.flag("help") || args.subcommand.as_deref() == Some("help") {
        println!("{USAGE}");
        return;
    }
    let r = match args.subcommand.as_deref() {
        Some("report") => cmd_report(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("run") => cmd_run(&args),
        Some("trace") => cmd_trace(&args),
        Some("dump") => cmd_dump(&args),
        Some("oracle") => cmd_oracle(&args),
        Some(other) => {
            eprintln!("error: unknown subcommand '{other}'\n{USAGE}");
            std::process::exit(1);
        }
        None => {
            eprintln!("{USAGE}");
            std::process::exit(1);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn report_modes_are_detected_individually() {
        assert_eq!(selected_report_modes(&parse(&["report", "--sched"])), vec!["sched"]);
        assert_eq!(selected_report_modes(&parse(&["report", "--fabric"])), vec!["fabric"]);
        // A fabric restriction value is still the fabric mode, not a
        // second mode and not silently dropped.
        assert_eq!(
            selected_report_modes(&parse(&["report", "--fabric", "queued:8"])),
            vec!["fabric"]
        );
        assert_eq!(selected_report_modes(&parse(&["report", "--fig", "12"])), vec!["fig"]);
        assert_eq!(selected_report_modes(&parse(&["report", "--all"])), vec!["all"]);
        assert_eq!(selected_report_modes(&parse(&["report", "--cluster"])), vec!["cluster"]);
        assert_eq!(selected_report_modes(&parse(&["report", "--faults"])), vec!["faults"]);
        // A chaos restriction value is still the faults mode.
        assert_eq!(
            selected_report_modes(&parse(&["report", "--faults", "heavy"])),
            vec!["faults"]
        );
        assert!(selected_report_modes(&parse(&["report"])).is_empty());
    }

    #[test]
    fn conflicting_report_modes_are_rejected() {
        // The satellite bugfix: --fabric and --sched must not compose by
        // silently ignoring one of them.
        let both = parse(&["report", "--fabric", "--sched"]);
        assert_eq!(selected_report_modes(&both), vec!["sched", "fabric"]);
        let err = cmd_report(&both).unwrap_err().to_string();
        assert!(err.contains("conflicting report modes"), "{err}");
        assert!(err.contains("sched") && err.contains("fabric"), "{err}");
        // Any other pair conflicts too.
        let err = cmd_report(&parse(&["report", "--table1", "--fig", "12"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("conflicting report modes"), "{err}");
        // A single mode passes the audit (table2 needs no simulation).
        assert!(cmd_report(&parse(&["report", "--table2"])).is_ok());
    }

    #[test]
    fn cluster_mode_conflicts_with_every_other_mode() {
        // The satellite bugfix: --cluster must join the mutual-exclusion
        // audit rather than silently losing to whichever mode runs first.
        for other in ["--fabric", "--sched", "--table1"] {
            let both = parse(&["report", "--cluster", other]);
            assert_eq!(selected_report_modes(&both).len(), 2, "{other}");
            let err = cmd_report(&both).unwrap_err().to_string();
            assert!(err.contains("conflicting report modes"), "{other}: {err}");
            assert!(err.contains("cluster"), "{other}: {err}");
        }
        let both = parse(&["report", "--cluster", "--fig", "12"]);
        let err = cmd_report(&both).unwrap_err().to_string();
        assert!(err.contains("conflicting report modes"), "{err}");
        assert!(err.contains("cluster") && err.contains("fig"), "{err}");
    }

    #[test]
    fn faults_mode_conflicts_with_every_other_mode() {
        // The new chaos report joins the mutual-exclusion audit.
        for other in ["--fabric", "--sched", "--cluster", "--table1"] {
            let both = parse(&["report", "--faults", other]);
            assert_eq!(selected_report_modes(&both).len(), 2, "{other}");
            let err = cmd_report(&both).unwrap_err().to_string();
            assert!(err.contains("conflicting report modes"), "{other}: {err}");
            assert!(err.contains("faults"), "{other}: {err}");
        }
        // A bad restriction spec fails loudly rather than sweeping.
        let err = cmd_report(&parse(&["report", "--faults", "storm"])).unwrap_err().to_string();
        assert!(err.contains("unknown fault spec"), "{err}");
    }

    #[test]
    fn service_mode_conflicts_with_every_other_mode() {
        // The overload report joins the mutual-exclusion audit.
        for other in ["--fabric", "--sched", "--cluster", "--faults", "--table1"] {
            let both = parse(&["report", "--service", other]);
            assert_eq!(selected_report_modes(&both).len(), 2, "{other}");
            let err = cmd_report(&both).unwrap_err().to_string();
            assert!(err.contains("conflicting report modes"), "{other}: {err}");
            assert!(err.contains("service"), "{other}: {err}");
        }
        // A load restriction value is still the service mode.
        assert_eq!(
            selected_report_modes(&parse(&["report", "--service", "overload"])),
            vec!["service"]
        );
        // A bad restriction spec fails loudly rather than sweeping.
        let err = cmd_report(&parse(&["report", "--service", "storm"])).unwrap_err().to_string();
        assert!(err.contains("unknown service spec"), "{err}");
    }

    #[test]
    fn grid_mode_joins_the_mutual_exclusion_audit() {
        assert_eq!(selected_report_modes(&parse(&["report", "--grid", "bench=gups"])), vec!["grid"]);
        let err = cmd_report(&parse(&["report", "--grid", "bench=gups", "--sched"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("conflicting report modes"), "{err}");
        assert!(err.contains("grid") && err.contains("sched"), "{err}");
        // A bad axis fails loudly with the uniform keyed dialect.
        let err = cmd_report(&parse(&["report", "--grid", "warp=9"])).unwrap_err().to_string();
        assert!(err.contains("unknown grid axis `warp`"), "{err}");
        // Bare --grid (no spec) is a mode but still an error.
        let err = format!("{:#}", cmd_report(&parse(&["report", "--grid"])).unwrap_err());
        assert!(err.contains("--grid needs an axes spec"), "{err}");
    }

    #[test]
    fn sweep_selects_the_report_matrices() {
        let opts = FigOpts::quick();
        let t = sweep_targets(&parse(&["sweep", "--sched", "--cluster"]), &opts).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, "sched");
        assert_eq!(t[0].2.len(), harness::fig_sched::requests(&opts).len());
        assert_eq!(t[1].0, "cluster");
        assert_eq!(t[1].2.len(), harness::fig_cluster::requests(&opts).len());
        // --all selects every sweep family.
        let t = sweep_targets(&parse(&["sweep", "--all"]), &opts).unwrap();
        assert_eq!(t.len(), 5);
        // --grid contributes its cartesian product.
        let t = sweep_targets(&parse(&["sweep", "--grid", "bench=gups;latency=200,800"]), &opts)
            .unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].2.len(), 2);
        // No mode selected: cmd_sweep refuses before touching any store.
        let err = cmd_sweep(&parse(&["sweep", "--store", "unused-dir"])).unwrap_err().to_string();
        assert!(err.contains("sweep needs --grid"), "{err}");
        assert!(!std::path::Path::new("unused-dir").exists());
    }

    #[test]
    fn run_config_accepts_and_validates_service() {
        let cfg = cfg_from(&parse(&["run", "--service", "overload"])).unwrap();
        assert_eq!(cfg.service, ServiceConfig::overload());
        // --load alone enables service mode on the steady baseline...
        let cfg = cfg_from(&parse(&["run", "--load", "150"])).unwrap();
        assert!(cfg.service.enabled());
        assert_eq!(cfg.service.load_pct, 150);
        assert_eq!(cfg.service.label(), "load:150");
        // ...and composes with --service and --deadline.
        let cfg =
            cfg_from(&parse(&["run", "--service", "burst", "--load", "120", "--deadline", "8"]))
                .unwrap();
        assert_eq!(cfg.service.load_pct, 120);
        assert_eq!(cfg.service.burst_factor, ServiceConfig::burst().burst_factor);
        assert_eq!(cfg.service.deadline_mult, 8);
        // No flag leaves service off (the bit-identical default).
        let cfg = cfg_from(&parse(&["run", "--bench", "gups"])).unwrap();
        assert!(!cfg.service.enabled());
        // Bad specs fail loudly instead of silently running batch mode.
        assert!(cfg_from(&parse(&["run", "--service", "storm"])).is_err());
        assert!(cfg_from(&parse(&["run", "--service", "load:0"])).is_err());
        assert!(cfg_from(&parse(&["run", "--load", "nope"])).is_err());
        assert!(cfg_from(&parse(&["run", "--load", "20000"])).is_err());
        let err = cfg_from(&parse(&["run", "--deadline", "4"])).unwrap_err().to_string();
        assert!(err.contains("--deadline"), "{err}");
    }

    #[test]
    fn run_config_accepts_and_validates_cores() {
        let cfg = cfg_from(&parse(&["run", "--cores", "4"])).unwrap();
        assert_eq!(cfg.cluster.cores, 4);
        // Degenerate and unparseable counts fail loudly (nonzero exit via
        // main's error path) instead of silently running single-core.
        let err = cfg_from(&parse(&["run", "--cores", "0"])).unwrap_err().to_string();
        assert!(err.contains("--cores"), "{err}");
        let err = cfg_from(&parse(&["run", "--cores", "many"])).unwrap_err().to_string();
        assert!(err.contains("--cores"), "{err}");
        assert!(cfg_from(&parse(&["run", "--cores", "-3"])).is_err());
    }

    #[test]
    fn run_config_accepts_fabric_and_policy_knobs() {
        let cfg = cfg_from(&parse(&["run", "--fabric", "tiered:32", "--policy", "latency"]))
            .unwrap();
        assert_eq!(cfg.mem.fabric.kind, FabricKind::Tiered { pages: 32 });
        assert_eq!(cfg.sched_policy, SchedPolicyKind::LatencyAware);
        assert!(cfg_from(&parse(&["run", "--fabric", "warp"])).is_err());
    }

    #[test]
    fn trace_flag_forms_and_json_flag() {
        // Bare `--trace` is a boolean flag (profile only, no export).
        let a = parse(&["run", "--bench", "gups", "--trace"]);
        assert!(a.flag("trace"));
        assert_eq!(a.get("trace"), None);
        // `--trace FILE` is the same switch plus a Chrome-JSON path.
        let a = parse(&["run", "--bench", "gups", "--trace", "out.json"]);
        assert!(a.flag("trace"));
        assert_eq!(a.get("trace"), Some("out.json"));
        // `--json` selects the machine-readable table sink.
        assert!(parse(&["report", "--table2", "--json"]).flag("json"));
        assert!(!parse(&["report", "--table2"]).flag("json"));
        // --json composes with a report mode (table2 needs no simulation).
        assert!(cmd_report(&parse(&["report", "--table2", "--json"])).is_ok());
        // The trace verb refuses to run without a benchmark.
        let err = cmd_trace(&parse(&["trace"])).unwrap_err().to_string();
        assert!(err.contains("--bench"), "{err}");
    }

    #[test]
    fn run_config_accepts_and_validates_faults() {
        let cfg = cfg_from(&parse(&["run", "--faults", "heavy"])).unwrap();
        assert_eq!(cfg.mem.fabric.faults, FaultConfig::heavy());
        let cfg = cfg_from(&parse(&["run", "--faults", "nack:5"])).unwrap();
        assert_eq!(cfg.mem.fabric.faults.nack_pct, 0.05);
        // No --faults flag leaves faults off (the bit-identical default).
        let cfg = cfg_from(&parse(&["run", "--bench", "gups"])).unwrap();
        assert!(!cfg.mem.fabric.faults.enabled());
        // Bad specs fail loudly instead of silently running fault-free.
        assert!(cfg_from(&parse(&["run", "--faults", "storm"])).is_err());
        assert!(cfg_from(&parse(&["run", "--faults", "nack:200"])).is_err());
    }
}
