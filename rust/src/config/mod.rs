//! Simulator configuration: the NH-G core of Table I, the Skylake-like
//! preset used for the paper's Intel-server experiments (Figs 2/3/11), and a
//! TOML-subset loader with CLI overrides.

use crate::sim::fabric::{Dist, FabricKind};
use crate::sim::faults::FaultConfig;
use crate::sim::sched::SchedPolicyKind;
use crate::sim::service::ServiceConfig;
use crate::sim::trace::{TraceClasses, TraceConfig};
use crate::util::minitoml::{self, Doc};
use anyhow::{bail, Context, Result};

/// Core pipeline parameters (paper Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct CoreConfig {
    pub freq_ghz: f64,
    /// Decode width = rename width (instructions/cycle into the backend).
    pub dispatch_width: usize,
    /// Issue width (max instructions beginning execution per cycle).
    pub issue_width: usize,
    /// Retire width (instructions leaving the ROB per cycle).
    pub retire_width: usize,
    pub rob_entries: usize,
    pub load_queue: usize,
    pub store_queue: usize,
    /// Front-end redirect penalty on a branch misprediction, in cycles.
    pub mispredict_penalty: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CacheLevelConfig {
    pub size_kb: usize,
    pub ways: usize,
    pub line_bytes: usize,
    pub latency_cycles: u64,
    pub mshrs: usize,
}

impl CacheLevelConfig {
    pub fn sets(&self) -> usize {
        (self.size_kb * 1024) / (self.ways * self.line_bytes)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct BpuConfig {
    pub btb_entries: usize,
    /// log2 of TAGE tagged-table entries (per table).
    pub tage_log_entries: usize,
    pub tage_tables: usize,
    /// log2 of ITTAGE table entries.
    pub ittage_log_entries: usize,
    pub ras_depth: usize,
    /// Bafin Predict Table entries (paper: 4).
    pub bpt_entries: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct AmuConfig {
    /// Whether the core has an AMU at all (the Skylake preset does not).
    pub enabled: bool,
    /// Issue-side request queue entries (Table I: 16).
    pub req_queue: usize,
    /// Finished Queue entries (Table I: 16).
    pub fin_queue: usize,
    /// SPM carved out of L2, in KB (paper: 32KB = 1 of 8 ways).
    pub spm_kb: usize,
    /// Request Table capacity = SPM lines (paper: 512 concurrent coroutines).
    pub request_table: usize,
    /// Bafin Target Queue entries (front-end side).
    pub btq_entries: usize,
    /// Whether the `bafin`/BPT/BTQ extension is present (CoroAMU-Full) or
    /// only plain `getfin` polling (original AMU, CoroAMU-D).
    pub bafin: bool,
    /// Max requests aggregatable under one `aset` group (hardware counter
    /// width constraint, §IV-B).
    pub max_group: usize,
    /// Max coarse-grained transfer per aload/astore, bytes (§III-C: 4KB).
    pub max_coarse_bytes: usize,
}

impl AmuConfig {
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            req_queue: 0,
            fin_queue: 0,
            spm_kb: 0,
            request_table: 0,
            btq_entries: 0,
            bafin: false,
            max_group: 0,
            max_coarse_bytes: 0,
        }
    }
}

/// Far-memory fabric selection (`sim::fabric`), the `[mem.fabric]` TOML
/// table. A simulate-time knob like the far latency: it never forks the
/// compiled-kernel cache.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    /// Which backend serves the far tier. The default (`FixedDelay`)
    /// reproduces the paper's delayer + bandwidth-regulator rig
    /// bit-for-bit (pinned by the differential suite).
    pub kind: FabricKind,
    /// Seed for the `dist` backend's deterministic latency draws.
    pub seed: u64,
    /// Deterministic fault injection on the fabric (`sim::faults`), the
    /// `[mem.fabric.faults]` sub-table. Defaults to off, which never
    /// constructs the decorator — bit-identical to a fault-free build.
    pub faults: FaultConfig,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig { kind: FabricKind::FixedDelay, seed: 0xFA_B71C, faults: FaultConfig::off() }
    }
}

/// Multi-core cluster shape (`sim::cluster`), the `[cluster]` TOML table.
/// A simulate-time knob like the far latency or the fabric: it never
/// forks the compiled-kernel or dataset caches. `cores = 1` (the
/// default) bypasses the cluster entirely and is bit-identical to the
/// single-core simulator (pinned by the differential suite).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of Core+AMU pairs contending on ONE shared far fabric.
    pub cores: u32,
    /// Optional per-core scheduler policies (heterogeneous cluster).
    /// When set, its length must equal `cores`; when absent, every core
    /// runs the global `sched_policy`.
    pub policies: Option<Vec<SchedPolicyKind>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { cores: 1, policies: None }
    }
}

/// Memory-system parameters. The far tier defaults to the paper's FPGA
/// delayer + bandwidth regulator in front of HBM; `fabric` swaps in the
/// congestion / variance / tiering models.
#[derive(Debug, Clone, PartialEq)]
pub struct MemConfig {
    pub local_latency_ns: f64,
    pub far_latency_ns: f64,
    /// Far-memory bandwidth in bytes/cycle at core frequency (paper:
    /// 1-32 B/cycle = 3-96 GB/s at 3 GHz).
    pub far_bw_bytes_per_cycle: f64,
    pub local_bw_bytes_per_cycle: f64,
    /// Far-tier fabric model (`sim::fabric`, `[mem.fabric]` in TOML).
    pub fabric: FabricConfig,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    pub name: String,
    pub core: CoreConfig,
    pub l1d: CacheLevelConfig,
    pub l2: CacheLevelConfig,
    pub l3: CacheLevelConfig,
    pub bpu: BpuConfig,
    pub amu: AmuConfig,
    pub mem: MemConfig,
    /// Enable the L2 Best-Offset prefetcher (Table I).
    pub l2_bop: bool,
    /// Simulator-implementation knob (not a modelled-hardware parameter):
    /// enable the decode-time superop fusion peephole. Timing-transparent
    /// — cycles/stats/memory are bit-identical either way (pinned by the
    /// differential suite); off exists so fused vs unfused interpreter
    /// throughput stays measurable.
    pub fuse_superops: bool,
    /// Coroutine-resume policy over the AMU's Finished Queue
    /// (`sim::sched`). A simulate-time knob like far latency: it never
    /// forks the compiled-kernel cache. The default (`ArrivalOrder`)
    /// reproduces the pre-subsystem behavior bit-for-bit.
    pub sched_policy: SchedPolicyKind,
    /// Multi-core cluster shape (`sim::cluster`, `[cluster]` in TOML).
    pub cluster: ClusterConfig,
    /// Open-loop service mode (`sim::service`, `[service]` in TOML). A
    /// simulate-time knob like the far latency: it never forks the
    /// compiled-kernel or dataset caches. The default (`off`) skips the
    /// queueing replay entirely and is bit-identical to the batch
    /// simulator (pinned by the differential suite).
    pub service: ServiceConfig,
    /// Cycle-level event tracing (`sim::trace`, `[trace]` in TOML). A
    /// simulate-time knob like the far latency: it never forks the
    /// compiled-kernel or dataset caches. The default (off) constructs
    /// no tracer at all and is bit-identical to an untraced build
    /// (pinned by the differential suite).
    pub trace: TraceConfig,
}

impl SimConfig {
    /// NH-G: FPGA-tailored XiangShan NANHU (paper Table I), emulating a
    /// 3 GHz core.
    pub fn nh_g() -> Self {
        SimConfig {
            name: "nh-g".into(),
            core: CoreConfig {
                freq_ghz: 3.0,
                dispatch_width: 4,
                issue_width: 8,
                retire_width: 4,
                rob_entries: 96,
                load_queue: 32,
                store_queue: 16,
                mispredict_penalty: 12,
            },
            l1d: CacheLevelConfig { size_kb: 32, ways: 8, line_bytes: 64, latency_cycles: 3, mshrs: 16 },
            l2: CacheLevelConfig { size_kb: 1024, ways: 8, line_bytes: 64, latency_cycles: 14, mshrs: 56 },
            l3: CacheLevelConfig { size_kb: 6144, ways: 6, line_bytes: 64, latency_cycles: 42, mshrs: 56 },
            bpu: BpuConfig {
                btb_entries: 2048,
                tage_log_entries: 10,
                tage_tables: 4,
                ittage_log_entries: 9,
                ras_depth: 16,
                bpt_entries: 4,
            },
            amu: AmuConfig {
                enabled: true,
                req_queue: 16,
                fin_queue: 16,
                spm_kb: 32,
                request_table: 512,
                btq_entries: 8,
                bafin: true,
                max_group: 8,
                max_coarse_bytes: 4096,
            },
            mem: MemConfig {
                local_latency_ns: 100.0,
                far_latency_ns: 200.0,
                far_bw_bytes_per_cycle: 16.0,
                local_bw_bytes_per_cycle: 32.0,
                fabric: FabricConfig::default(),
            },
            l2_bop: true,
            fuse_superops: true,
            sched_policy: SchedPolicyKind::ArrivalOrder,
            cluster: ClusterConfig::default(),
            service: ServiceConfig::off(),
            trace: TraceConfig::off(),
        }
    }

    /// Skylake-like preset for the Intel Xeon Gold 6130 compiler
    /// experiments (Figs 2, 3, 11). No AMU; prefetch-only ISA. The "far"
    /// tier models the cross-NUMA hop (~130 ns); local is ~90 ns.
    pub fn skylake() -> Self {
        SimConfig {
            name: "skylake".into(),
            core: CoreConfig {
                freq_ghz: 2.1,
                dispatch_width: 4,
                issue_width: 8,
                retire_width: 4,
                rob_entries: 224,
                load_queue: 72,
                store_queue: 56,
                mispredict_penalty: 16,
            },
            l1d: CacheLevelConfig { size_kb: 32, ways: 8, line_bytes: 64, latency_cycles: 4, mshrs: 10 },
            l2: CacheLevelConfig { size_kb: 1024, ways: 16, line_bytes: 64, latency_cycles: 14, mshrs: 32 },
            l3: CacheLevelConfig { size_kb: 22528, ways: 11, line_bytes: 64, latency_cycles: 44, mshrs: 48 },
            bpu: BpuConfig {
                btb_entries: 4096,
                tage_log_entries: 11,
                tage_tables: 5,
                ittage_log_entries: 10,
                ras_depth: 32,
                bpt_entries: 0,
            },
            amu: AmuConfig::disabled(),
            mem: MemConfig {
                local_latency_ns: 90.0,
                far_latency_ns: 130.0,
                far_bw_bytes_per_cycle: 24.0,
                local_bw_bytes_per_cycle: 32.0,
                fabric: FabricConfig::default(),
            },
            l2_bop: false,
            fuse_superops: true,
            sched_policy: SchedPolicyKind::ArrivalOrder,
            cluster: ClusterConfig::default(),
            service: ServiceConfig::off(),
            trace: TraceConfig::off(),
        }
    }

    pub fn preset(name: &str) -> Result<Self> {
        match name {
            "nh-g" | "nhg" | "nh_g" => Ok(Self::nh_g()),
            "skylake" | "xeon" => Ok(Self::skylake()),
            other => bail!("unknown preset '{other}' (try nh-g or skylake)"),
        }
    }

    /// Convert nanoseconds to core cycles.
    pub fn ns_to_cycles(&self, ns: f64) -> u64 {
        (ns * self.core.freq_ghz).round() as u64
    }

    pub fn local_latency_cycles(&self) -> u64 {
        self.ns_to_cycles(self.mem.local_latency_ns)
    }

    pub fn far_latency_cycles(&self) -> u64 {
        self.ns_to_cycles(self.mem.far_latency_ns)
    }

    /// Set the emulated far-memory latency (the paper's delayer knob).
    pub fn with_far_latency_ns(mut self, ns: f64) -> Self {
        self.mem.far_latency_ns = ns;
        self
    }

    /// Toggle the decode-time superop fusion peephole (timing-transparent
    /// interpreter optimization; see `sim::decode::decode_with`).
    pub fn with_fuse(mut self, on: bool) -> Self {
        self.fuse_superops = on;
        self
    }

    /// Select the coroutine-scheduler policy (the `sim::sched` sweep
    /// axis; see `SchedPolicyKind`).
    pub fn with_sched_policy(mut self, policy: SchedPolicyKind) -> Self {
        self.sched_policy = policy;
        self
    }

    /// Select the far-memory fabric backend (the `sim::fabric` sweep
    /// axis; see `FabricKind`). Simulate-time like far latency.
    pub fn with_fabric(mut self, kind: FabricKind) -> Self {
        self.mem.fabric.kind = kind;
        self
    }

    /// Set the cluster core count (the `sim::cluster` sweep axis; see
    /// `ClusterConfig`). Simulate-time like far latency.
    pub fn with_cores(mut self, cores: u32) -> Self {
        self.cluster.cores = cores;
        self
    }

    /// Select the fault-injection spec (the `sim::faults` chaos axis;
    /// see `FaultConfig`). Simulate-time like far latency.
    pub fn with_faults(mut self, faults: FaultConfig) -> Self {
        self.mem.fabric.faults = faults;
        self
    }

    /// Select the open-loop service spec (the `sim::service` overload
    /// axis; see `ServiceConfig`). Simulate-time like far latency.
    pub fn with_service(mut self, service: ServiceConfig) -> Self {
        self.service = service;
        self
    }

    /// Select the tracing configuration (`sim::trace`, DESIGN.md §14).
    /// Simulate-time like far latency.
    pub fn with_trace(mut self, trace: TraceConfig) -> Self {
        self.trace = trace;
        self
    }

    /// Effective scheduler policy for one cluster core: the per-core
    /// `[cluster] policies` entry when configured, else the global
    /// `sched_policy`.
    pub fn core_policy(&self, core: usize) -> SchedPolicyKind {
        match &self.cluster.policies {
            Some(ps) => ps.get(core).copied().unwrap_or(self.sched_policy),
            None => self.sched_policy,
        }
    }

    /// Apply overrides from a parsed minitoml document. Keys mirror the
    /// struct layout, e.g. `core.rob_entries = 128`.
    pub fn apply_doc(&mut self, doc: &Doc) -> Result<()> {
        if let Some(v) = doc.str("name") {
            self.name = v.to_string();
        }
        macro_rules! ov {
            ($key:expr, $field:expr, i64) => {
                if let Some(v) = doc.i64($key) {
                    $field = v as _;
                }
            };
            ($key:expr, $field:expr, f64) => {
                if let Some(v) = doc.f64($key) {
                    $field = v;
                }
            };
            ($key:expr, $field:expr, bool) => {
                if let Some(v) = doc.bool($key) {
                    $field = v;
                }
            };
        }
        ov!("core.freq_ghz", self.core.freq_ghz, f64);
        ov!("core.dispatch_width", self.core.dispatch_width, i64);
        ov!("core.issue_width", self.core.issue_width, i64);
        ov!("core.retire_width", self.core.retire_width, i64);
        ov!("core.rob_entries", self.core.rob_entries, i64);
        ov!("core.load_queue", self.core.load_queue, i64);
        ov!("core.store_queue", self.core.store_queue, i64);
        ov!("core.mispredict_penalty", self.core.mispredict_penalty, i64);
        ov!("l1d.size_kb", self.l1d.size_kb, i64);
        ov!("l1d.ways", self.l1d.ways, i64);
        ov!("l1d.latency_cycles", self.l1d.latency_cycles, i64);
        ov!("l1d.mshrs", self.l1d.mshrs, i64);
        ov!("l2.size_kb", self.l2.size_kb, i64);
        ov!("l2.ways", self.l2.ways, i64);
        ov!("l2.latency_cycles", self.l2.latency_cycles, i64);
        ov!("l2.mshrs", self.l2.mshrs, i64);
        ov!("l3.size_kb", self.l3.size_kb, i64);
        ov!("l3.ways", self.l3.ways, i64);
        ov!("l3.latency_cycles", self.l3.latency_cycles, i64);
        ov!("l3.mshrs", self.l3.mshrs, i64);
        ov!("amu.enabled", self.amu.enabled, bool);
        ov!("amu.req_queue", self.amu.req_queue, i64);
        ov!("amu.fin_queue", self.amu.fin_queue, i64);
        ov!("amu.request_table", self.amu.request_table, i64);
        ov!("amu.bafin", self.amu.bafin, bool);
        ov!("amu.max_group", self.amu.max_group, i64);
        ov!("mem.local_latency_ns", self.mem.local_latency_ns, f64);
        ov!("mem.far_latency_ns", self.mem.far_latency_ns, f64);
        ov!("mem.far_bw_bytes_per_cycle", self.mem.far_bw_bytes_per_cycle, f64);
        ov!("l2_bop", self.l2_bop, bool);
        ov!("fuse_superops", self.fuse_superops, bool);
        if let Some(v) = doc.str("sched.policy") {
            self.sched_policy = SchedPolicyKind::parse(v)?;
        }
        self.apply_fabric_doc(doc)?;
        self.apply_cluster_doc(doc)?;
        self.apply_service_doc(doc)?;
        self.apply_trace_doc(doc)?;
        self.validate()
    }

    /// Apply the `[trace]` table (`sim::trace`, DESIGN.md §14). Unknown
    /// keys are rejected with the full key path (same discipline as
    /// `[mem.fabric]`), so a typo cannot silently leave tracing off.
    fn apply_trace_doc(&mut self, doc: &Doc) -> Result<()> {
        const KNOWN: [&str; 4] = ["enabled", "sample_every", "ring_cap", "classes"];
        for key in doc.keys_with_prefix("trace.") {
            let leaf = &key["trace.".len()..];
            if !KNOWN.contains(&leaf) {
                bail!("unknown [trace] key '{leaf}' (known keys: {})", KNOWN.join(", "));
            }
        }
        if let Some(v) = doc.bool("trace.enabled") {
            self.trace.enabled = v;
        }
        if let Some(v) = doc.i64("trace.sample_every") {
            anyhow::ensure!(v > 0, "trace.sample_every must be positive, got {v}");
            self.trace.sample_every = v as u64;
        }
        if let Some(v) = doc.i64("trace.ring_cap") {
            anyhow::ensure!(v > 0, "trace.ring_cap must be positive, got {v}");
            self.trace.ring_cap = v as usize;
        }
        if let Some(v) = doc.str("trace.classes") {
            self.trace.classes = TraceClasses::parse(v)
                .with_context(|| format!("trace.classes = \"{v}\""))?;
        }
        Ok(())
    }

    /// Apply the `[service]` table. A `preset` key (any `--service`
    /// spec) establishes the baseline; individual keys then override
    /// single fields on top of it. Unknown keys are rejected with the
    /// full key path (same discipline as `[mem.fabric.faults]`).
    fn apply_service_doc(&mut self, doc: &Doc) -> Result<()> {
        const KNOWN: [&str; 18] = [
            "preset", "load", "requests", "queue_cap", "deadline", "fanout", "shed",
            "burst_factor", "burst_duty", "burst_period", "keys", "theta", "keyspace",
            "hot_keys", "degrade_hi", "degrade_lo", "hysteresis", "seed",
        ];
        for key in doc.keys_with_prefix("service.") {
            let leaf = &key["service.".len()..];
            if !KNOWN.contains(&leaf) {
                bail!("unknown [service] key '{leaf}' (known keys: {})", KNOWN.join(", "));
            }
        }
        if let Some(v) = doc.str("service.preset") {
            self.service = ServiceConfig::parse(v)
                .with_context(|| format!("service.preset = \"{v}\""))?;
        }
        let s = &mut self.service;
        macro_rules! ovu {
            ($key:expr, $field:expr) => {
                if let Some(v) = doc.i64(concat!("service.", $key)) {
                    anyhow::ensure!(v >= 0, "service.{} must be >= 0, got {v}", $key);
                    $field = v as _;
                }
            };
        }
        ovu!("load", s.load_pct);
        ovu!("requests", s.requests);
        ovu!("queue_cap", s.queue_cap);
        ovu!("deadline", s.deadline_mult);
        ovu!("fanout", s.fanout);
        ovu!("burst_factor", s.burst_factor);
        ovu!("burst_duty", s.burst_duty_pct);
        ovu!("burst_period", s.burst_period);
        ovu!("keys", s.keys);
        ovu!("keyspace", s.keyspace);
        ovu!("hot_keys", s.hot_keys);
        ovu!("degrade_hi", s.degrade_hi_pct);
        ovu!("degrade_lo", s.degrade_lo_pct);
        ovu!("hysteresis", s.hysteresis);
        ovu!("seed", s.seed);
        if let Some(v) = doc.f64("service.theta") {
            s.theta = v;
        }
        if let Some(v) = doc.bool("service.shed") {
            s.shed = v;
        }
        Ok(())
    }

    /// Apply the `[cluster]` table. Unknown keys are rejected with the
    /// full key path (same discipline as `[mem.fabric]`). `policies` is a
    /// comma-separated list (minitoml has no arrays), one entry per core,
    /// e.g. `policies = "arrival, latency, fifo, batched:8"`.
    fn apply_cluster_doc(&mut self, doc: &Doc) -> Result<()> {
        const KNOWN: [&str; 2] = ["cores", "policies"];
        for key in doc.keys_with_prefix("cluster.") {
            let leaf = &key["cluster.".len()..];
            if !KNOWN.contains(&leaf) {
                bail!("unknown [cluster] key '{leaf}' (known keys: {})", KNOWN.join(", "));
            }
        }
        if let Some(v) = doc.i64("cluster.cores") {
            if v <= 0 {
                bail!("cluster.cores must be positive, got {v}");
            }
            self.cluster.cores = v as u32;
        }
        if let Some(v) = doc.str("cluster.policies") {
            let ps: Vec<SchedPolicyKind> = v
                .split(',')
                .map(SchedPolicyKind::parse)
                .collect::<Result<_>>()
                .with_context(|| format!("cluster.policies = \"{v}\""))?;
            self.cluster.policies = Some(ps);
        }
        Ok(())
    }

    /// Apply the nested `[mem.fabric]` table. Unknown keys are rejected
    /// with the full key path, so a typo cannot silently leave the
    /// paper's fixed-delay rig in place.
    fn apply_fabric_doc(&mut self, doc: &Doc) -> Result<()> {
        const KNOWN: [&str; 5] = ["model", "depth", "pages", "dist", "seed"];
        for key in doc.keys_with_prefix("mem.fabric.") {
            let leaf = &key["mem.fabric.".len()..];
            // The nested [mem.fabric.faults] sub-table has its own known
            // set and its own full-path rejection below.
            if leaf.starts_with("faults.") {
                continue;
            }
            if !KNOWN.contains(&leaf) {
                bail!(
                    "unknown [mem.fabric] key '{leaf}' (known keys: {})",
                    KNOWN.join(", ")
                );
            }
        }
        self.apply_faults_doc(doc)?;
        if let Some(v) = doc.str("mem.fabric.model") {
            self.mem.fabric.kind = FabricKind::parse(v)?;
        }
        if let Some(v) = doc.i64("mem.fabric.depth") {
            match &mut self.mem.fabric.kind {
                FabricKind::Queued { depth } if v > 0 => *depth = v as u32,
                FabricKind::Queued { .. } => bail!("mem.fabric.depth must be positive, got {v}"),
                other => bail!(
                    "mem.fabric.depth only applies to the queued fabric (model is '{}')",
                    other.label()
                ),
            }
        }
        if let Some(v) = doc.i64("mem.fabric.pages") {
            match &mut self.mem.fabric.kind {
                FabricKind::Tiered { pages } if v > 0 => *pages = v as u32,
                FabricKind::Tiered { .. } => bail!("mem.fabric.pages must be positive, got {v}"),
                other => bail!(
                    "mem.fabric.pages only applies to the tiered fabric (model is '{}')",
                    other.label()
                ),
            }
        }
        if let Some(v) = doc.str("mem.fabric.dist") {
            match &mut self.mem.fabric.kind {
                FabricKind::Distributed { dist } => *dist = Dist::parse(v)?,
                other => bail!(
                    "mem.fabric.dist only applies to the distributed fabric (model is '{}')",
                    other.label()
                ),
            }
        }
        if let Some(v) = doc.i64("mem.fabric.seed") {
            self.mem.fabric.seed = v as u64;
        }
        Ok(())
    }

    /// Apply the nested `[mem.fabric.faults]` table. A `preset` key
    /// (any `--faults` spec) establishes the baseline; individual keys
    /// then override single fields on top of it. Unknown keys are
    /// rejected with the full key path like the parent table.
    fn apply_faults_doc(&mut self, doc: &Doc) -> Result<()> {
        const KNOWN: [&str; 15] = [
            "preset", "nack", "spike", "spike_mult", "degrade_period", "degrade_len",
            "degrade_factor", "blackout_period", "blackout_len", "timeout", "retries",
            "backoff", "slow_path", "strict", "seed",
        ];
        for key in doc.keys_with_prefix("mem.fabric.faults.") {
            let leaf = &key["mem.fabric.faults.".len()..];
            if !KNOWN.contains(&leaf) {
                bail!(
                    "unknown [mem.fabric.faults] key '{leaf}' (known keys: {})",
                    KNOWN.join(", ")
                );
            }
        }
        if let Some(v) = doc.str("mem.fabric.faults.preset") {
            self.mem.fabric.faults = FaultConfig::parse(v)
                .with_context(|| format!("mem.fabric.faults.preset = \"{v}\""))?;
        }
        let f = &mut self.mem.fabric.faults;
        // Probabilities are fractions here (TOML is config, not CLI
        // shorthand): `nack = 0.05` means 5%.
        if let Some(v) = doc.f64("mem.fabric.faults.nack") {
            f.nack_pct = v;
        }
        if let Some(v) = doc.f64("mem.fabric.faults.spike") {
            f.spike_pct = v;
        }
        macro_rules! ovu {
            ($key:expr, $field:expr) => {
                if let Some(v) = doc.i64(concat!("mem.fabric.faults.", $key)) {
                    anyhow::ensure!(v >= 0, "mem.fabric.faults.{} must be >= 0, got {v}", $key);
                    $field = v as _;
                }
            };
        }
        ovu!("spike_mult", f.spike_mult);
        ovu!("degrade_period", f.degrade_period);
        ovu!("degrade_len", f.degrade_len);
        ovu!("degrade_factor", f.degrade_factor);
        ovu!("blackout_period", f.blackout_period);
        ovu!("blackout_len", f.blackout_len);
        ovu!("timeout", f.timeout);
        ovu!("retries", f.retries);
        ovu!("backoff", f.backoff);
        ovu!("slow_path", f.slow_path);
        ovu!("seed", f.seed);
        if let Some(v) = doc.bool("mem.fabric.faults.strict") {
            f.strict = v;
        }
        Ok(())
    }

    pub fn load_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        let doc = minitoml::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let mut cfg = match doc.str("preset") {
            Some(p) => Self::preset(p)?,
            None => Self::nh_g(),
        };
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.core.dispatch_width == 0 || self.core.rob_entries == 0 {
            bail!("core widths/rob must be nonzero");
        }
        for (n, c) in [("l1d", &self.l1d), ("l2", &self.l2), ("l3", &self.l3)] {
            if c.sets() == 0 || !c.sets().is_power_of_two() {
                bail!("{n}: sets ({}) must be a nonzero power of two", c.sets());
            }
            if c.mshrs == 0 {
                bail!("{n}: mshrs must be nonzero");
            }
        }
        if self.amu.enabled && self.amu.request_table == 0 {
            bail!("amu enabled but request_table is 0");
        }
        match self.mem.fabric.kind {
            FabricKind::Queued { depth: 0 } => bail!("queued fabric needs a nonzero depth"),
            FabricKind::Tiered { pages: 0 } => bail!("tiered fabric needs a nonzero page count"),
            _ => {}
        }
        self.mem.fabric.faults.validate()?;
        if self.cluster.cores == 0 {
            bail!("cluster.cores must be nonzero");
        }
        if let Some(ps) = &self.cluster.policies {
            if ps.len() != self.cluster.cores as usize {
                bail!(
                    "cluster.policies lists {} policies but cluster.cores = {} (one per core)",
                    ps.len(),
                    self.cluster.cores
                );
            }
        }
        self.service.validate()?;
        self.trace.validate()?;
        Ok(())
    }

    /// Render paper Table I for this configuration.
    pub fn table1(&self) -> crate::util::table::Table {
        use crate::util::table::Table;
        let mut t = Table::new(
            format!("Table I: Core microarchitecture configuration ({})", self.name),
            &["Core Configuration", "Parameter"],
        );
        let c = &self.core;
        t.row(vec!["Frequency (emulated)".into(), format!("{} GHz", c.freq_ghz)]);
        t.row(vec!["Decode/Rename/Issue Width".into(), format!("{}/{}/{}", c.dispatch_width, c.dispatch_width, c.issue_width)]);
        t.row(vec!["ROB Entries".into(), format!("{}", c.rob_entries)]);
        t.row(vec!["Load/Store Queue Entries".into(), format!("{}/{}", c.load_queue, c.store_queue)]);
        t.row(vec!["Branch Predictor".into(), "BTB + RAS + TAGE + ITTAGE".into()]);
        if self.amu.enabled {
            t.row(vec!["AMU Req/Finish Queue Entries".into(), format!("{}/{}", self.amu.req_queue, self.amu.fin_queue)]);
            t.row(vec!["AMU SPM (from L2)".into(), format!("{} KB ({} coroutines)", self.amu.spm_kb, self.amu.request_table)]);
        }
        t.row(vec!["L1 D-Cache".into(), format!("{}-way {}KB, {} MSHRs", self.l1d.ways, self.l1d.size_kb, self.l1d.mshrs)]);
        t.row(vec![
            "L2 Cache".into(),
            format!("{}-way {}KB, {} MSHRs{}", self.l2.ways, self.l2.size_kb, self.l2.mshrs, if self.l2_bop { ", BOP prefetcher" } else { "" }),
        ]);
        t.row(vec!["L3 Cache (LLC)".into(), format!("{}-way {}KB, {} MSHRs", self.l3.ways, self.l3.size_kb, self.l3.mshrs)]);
        t.row(vec!["Local memory latency".into(), format!("{} ns", self.mem.local_latency_ns)]);
        t.row(vec!["Far memory latency".into(), format!("{} ns", self.mem.far_latency_ns)]);
        t.row(vec!["Far fabric model".into(), self.mem.fabric.kind.label()]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        SimConfig::nh_g().validate().unwrap();
        SimConfig::skylake().validate().unwrap();
    }

    #[test]
    fn nh_g_matches_table1() {
        let c = SimConfig::nh_g();
        assert_eq!(c.core.rob_entries, 96);
        assert_eq!(c.core.dispatch_width, 4);
        assert_eq!(c.core.issue_width, 8);
        assert_eq!(c.l1d.mshrs, 16);
        assert_eq!(c.amu.req_queue, 16);
        assert_eq!(c.amu.request_table, 512);
        assert!(c.l2_bop);
    }

    #[test]
    fn skylake_has_no_amu() {
        let c = SimConfig::skylake();
        assert!(!c.amu.enabled);
        assert_eq!(c.bpu.bpt_entries, 0);
    }

    #[test]
    fn ns_conversion() {
        let c = SimConfig::nh_g();
        assert_eq!(c.ns_to_cycles(200.0), 600);
        assert_eq!(c.far_latency_cycles(), 600);
    }

    #[test]
    fn doc_overrides() {
        let doc = crate::util::minitoml::parse(
            "[core]\nrob_entries = 128\n[mem]\nfar_latency_ns = 800\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.core.rob_entries, 128);
        assert_eq!(c.mem.far_latency_ns, 800.0);
    }

    #[test]
    fn sched_policy_defaults_and_overrides() {
        let c = SimConfig::nh_g();
        assert_eq!(c.sched_policy, SchedPolicyKind::ArrivalOrder, "default must stay compatible");
        let c = c.with_sched_policy(SchedPolicyKind::LatencyAware);
        assert_eq!(c.sched_policy, SchedPolicyKind::LatencyAware);
        let doc = crate::util::minitoml::parse("[sched]\npolicy = \"batched:8\"\n").unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.sched_policy, SchedPolicyKind::BatchedWakeup(8));
        let bad = crate::util::minitoml::parse("[sched]\npolicy = \"round-robin\"\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
    }

    #[test]
    fn fabric_defaults_and_toml_overrides() {
        let c = SimConfig::nh_g();
        assert_eq!(c.mem.fabric.kind, FabricKind::FixedDelay, "default must stay compatible");
        let c = c.with_fabric(FabricKind::Queued { depth: 8 });
        assert_eq!(c.mem.fabric.kind, FabricKind::Queued { depth: 8 });
        // Nested [mem.fabric] table: model spelling plus knob overrides.
        let doc = crate::util::minitoml::parse(
            "[mem.fabric]\nmodel = \"queued\"\ndepth = 24\nseed = 9\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.mem.fabric.kind, FabricKind::Queued { depth: 24 });
        assert_eq!(c.mem.fabric.seed, 9);
        let doc = crate::util::minitoml::parse(
            "[mem.fabric]\nmodel = \"dist\"\ndist = \"uniform\"\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.mem.fabric.kind, FabricKind::Distributed { dist: Dist::Uniform });
        let doc =
            crate::util::minitoml::parse("[mem.fabric]\nmodel = \"tiered:128\"\n").unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.mem.fabric.kind, FabricKind::Tiered { pages: 128 });
    }

    #[test]
    fn fabric_toml_rejects_unknown_and_misapplied_keys() {
        // Unknown key: clear error naming the key and the valid set.
        let bad = crate::util::minitoml::parse("[mem.fabric]\nmodle = \"queued\"\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown [mem.fabric] key 'modle'"), "{err}");
        assert!(err.contains("model"), "error must list the known keys: {err}");
        // Knob for the wrong backend.
        let bad = crate::util::minitoml::parse("[mem.fabric]\ndepth = 8\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("only applies to the queued fabric"), "{err}");
        let bad =
            crate::util::minitoml::parse("[mem.fabric]\nmodel = \"queued\"\npages = 4\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        // Bad values.
        let bad =
            crate::util::minitoml::parse("[mem.fabric]\nmodel = \"queued\"\ndepth = 0\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        let bad = crate::util::minitoml::parse("[mem.fabric]\nmodel = \"warp-drive\"\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
    }

    #[test]
    fn cluster_defaults_and_toml_round_trip() {
        let c = SimConfig::nh_g();
        assert_eq!(c.cluster, ClusterConfig::default(), "default must stay single-core");
        assert_eq!(c.cluster.cores, 1);
        assert_eq!(c.cluster.policies, None);
        assert_eq!(c.core_policy(0), SchedPolicyKind::ArrivalOrder);
        let c = c.with_cores(8);
        assert_eq!(c.cluster.cores, 8);
        // Full [cluster] table: cores + a heterogeneous policy list.
        let doc = crate::util::minitoml::parse(
            "[cluster]\ncores = 4\npolicies = \"arrival, latency, fifo, batched:8\"\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.cluster.cores, 4);
        assert_eq!(
            c.cluster.policies,
            Some(vec![
                SchedPolicyKind::ArrivalOrder,
                SchedPolicyKind::LatencyAware,
                SchedPolicyKind::Fifo,
                SchedPolicyKind::BatchedWakeup(8),
            ])
        );
        assert_eq!(c.core_policy(1), SchedPolicyKind::LatencyAware);
        assert_eq!(c.core_policy(3), SchedPolicyKind::BatchedWakeup(8));
    }

    #[test]
    fn cluster_toml_rejects_unknown_keys_and_bad_shapes() {
        // Unknown key: clear error naming the key and the valid set.
        let bad = crate::util::minitoml::parse("[cluster]\ncors = 4\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown [cluster] key 'cors'"), "{err}");
        assert!(err.contains("cores"), "error must list the known keys: {err}");
        // Policy list length must match the core count, named by full path.
        let bad = crate::util::minitoml::parse(
            "[cluster]\ncores = 4\npolicies = \"arrival, latency\"\n",
        )
        .unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("cluster.policies"), "{err}");
        assert!(err.contains("cluster.cores"), "{err}");
        // Degenerate or unparsable values.
        let bad = crate::util::minitoml::parse("[cluster]\ncores = 0\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        let bad = crate::util::minitoml::parse("[cluster]\ncores = -2\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        let bad = crate::util::minitoml::parse(
            "[cluster]\ncores = 2\npolicies = \"arrival, round-robin\"\n",
        )
        .unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("cluster.policies"), "{err}");
        // validate() itself guards direct struct construction too.
        let mut c = SimConfig::nh_g();
        c.cluster.cores = 0;
        assert!(c.validate().is_err());
        let mut c = SimConfig::nh_g().with_cores(3);
        c.cluster.policies = Some(vec![SchedPolicyKind::Fifo]);
        assert!(c.validate().is_err());
    }

    #[test]
    fn faults_default_off_and_toml_round_trip() {
        let c = SimConfig::nh_g();
        assert_eq!(c.mem.fabric.faults, FaultConfig::off(), "faults must default off");
        assert!(!c.mem.fabric.faults.enabled());
        let c = c.with_faults(FaultConfig::mild());
        assert_eq!(c.mem.fabric.faults.label(), "mild");
        // Preset baseline + per-key overrides on top of it.
        let doc = crate::util::minitoml::parse(
            "[mem.fabric]\nmodel = \"queued\"\ndepth = 8\n\
             [mem.fabric.faults]\npreset = \"mild\"\nnack = 0.02\nstrict = true\nseed = 42\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.mem.fabric.kind, FabricKind::Queued { depth: 8 });
        let f = c.mem.fabric.faults;
        assert_eq!(f.nack_pct, 0.02, "per-key override wins over the preset");
        assert_eq!(f.spike_pct, FaultConfig::mild().spike_pct, "preset fields survive");
        assert!(f.strict);
        assert_eq!(f.seed, 42);
        c.validate().unwrap();
        // A config assembled entirely key-by-key, no preset.
        let doc = crate::util::minitoml::parse(
            "[mem.fabric.faults]\ndegrade_period = 4096\ndegrade_len = 1024\ndegrade_factor = 2\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert!(c.mem.fabric.faults.enabled());
        assert_eq!(c.mem.fabric.faults.degrade_period, 4096);
        assert_eq!(c.mem.fabric.faults.label(), "custom");
        c.validate().unwrap();
    }

    #[test]
    fn faults_toml_rejects_unknown_keys_and_bad_values() {
        // Unknown key under the sub-table: full-path rejection naming
        // the valid set — and it must NOT fall through to the parent
        // [mem.fabric] error.
        let bad = crate::util::minitoml::parse("[mem.fabric.faults]\nnak = 0.1\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown [mem.fabric.faults] key 'nak'"), "{err}");
        assert!(err.contains("nack"), "error must list the known keys: {err}");
        // Unknown preset.
        let bad =
            crate::util::minitoml::parse("[mem.fabric.faults]\npreset = \"storm\"\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("mem.fabric.faults.preset"), "{err}");
        // Negative counters rejected at apply time, degenerate shapes at
        // validate time.
        let bad = crate::util::minitoml::parse("[mem.fabric.faults]\nretries = -1\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        let doc = crate::util::minitoml::parse(
            "[mem.fabric.faults]\nnack = 1.5\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("mem.fabric.faults.nack"), "{err}");
        // Parent-table unknown-key rejection is unaffected.
        let bad = crate::util::minitoml::parse("[mem.fabric]\nfaultz = 1\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown [mem.fabric] key 'faultz'"), "{err}");
    }

    #[test]
    fn service_default_off_and_toml_round_trip() {
        let c = SimConfig::nh_g();
        assert_eq!(c.service, ServiceConfig::off(), "service must default off");
        assert!(!c.service.enabled());
        let c = c.with_service(ServiceConfig::overload());
        assert_eq!(c.service.label(), "overload");
        // Preset baseline + per-key overrides on top of it.
        let doc = crate::util::minitoml::parse(
            "[service]\npreset = \"steady\"\nload = 150\nqueue_cap = 32\nshed = false\nseed = 7\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        let s = c.service;
        assert_eq!(s.load_pct, 150, "per-key override wins over the preset");
        assert_eq!(s.queue_cap, 32);
        assert!(!s.shed);
        assert_eq!(s.seed, 7);
        assert_eq!(s.requests, ServiceConfig::steady().requests, "preset fields survive");
        c.validate().unwrap();
        // A config assembled entirely key-by-key, no preset.
        let doc = crate::util::minitoml::parse(
            "[service]\nload = 90\ndeadline = 8\ntheta = 1.2\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert!(c.service.enabled());
        assert_eq!(c.service.load_pct, 90);
        assert_eq!(c.service.deadline_mult, 8);
        assert_eq!(c.service.theta, 1.2);
        c.validate().unwrap();
    }

    #[test]
    fn service_toml_rejects_unknown_keys_and_bad_values() {
        // Unknown key: full-path rejection naming the valid set.
        let bad = crate::util::minitoml::parse("[service]\nlod = 100\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown [service] key 'lod'"), "{err}");
        assert!(err.contains("load"), "error must list the known keys: {err}");
        // Unknown preset.
        let bad = crate::util::minitoml::parse("[service]\npreset = \"meltdown\"\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("service.preset"), "{err}");
        // Negative counters rejected at apply time, degenerate shapes at
        // validate time (with the full key path).
        let bad = crate::util::minitoml::parse("[service]\nload = -5\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        let bad = crate::util::minitoml::parse("[service]\nload = 100\nqueue_cap = 0\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("service.queue_cap"), "{err}");
        let bad = crate::util::minitoml::parse(
            "[service]\npreset = \"steady\"\ndegrade_lo = 80\n",
        )
        .unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("service.degrade_lo"), "{err}");
    }

    #[test]
    fn trace_default_off_and_toml_round_trip() {
        let c = SimConfig::nh_g();
        assert_eq!(c.trace, TraceConfig::off(), "trace must default off");
        assert!(!c.trace.enabled);
        let c = c.with_trace(TraceConfig::on());
        assert!(c.trace.enabled);
        // Full [trace] table, all keys.
        let doc = crate::util::minitoml::parse(
            "[trace]\nenabled = true\nsample_every = 1024\nring_cap = 4096\nclasses = \"coro,amu\"\n",
        )
        .unwrap();
        let mut c = SimConfig::nh_g();
        c.apply_doc(&doc).unwrap();
        assert!(c.trace.enabled);
        assert_eq!(c.trace.sample_every, 1024);
        assert_eq!(c.trace.ring_cap, 4096);
        assert!(c.trace.classes.has(TraceClasses::CORO));
        assert!(c.trace.classes.has(TraceClasses::AMU));
        assert!(!c.trace.classes.has(TraceClasses::FABRIC));
        c.validate().unwrap();
    }

    #[test]
    fn trace_toml_rejects_unknown_keys_and_bad_values() {
        // Unknown key: full-path rejection naming the valid set.
        let bad = crate::util::minitoml::parse("[trace]\nenabld = true\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown [trace] key 'enabld'"), "{err}");
        assert!(err.contains("enabled"), "error must list the known keys: {err}");
        // Bad values at apply time.
        let bad = crate::util::minitoml::parse("[trace]\nsample_every = 0\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        let bad = crate::util::minitoml::parse("[trace]\nring_cap = -4\n").unwrap();
        assert!(SimConfig::nh_g().apply_doc(&bad).is_err());
        // Unknown class name, reported with the full key path.
        let bad = crate::util::minitoml::parse("[trace]\nclasses = \"coro,warp\"\n").unwrap();
        let err = SimConfig::nh_g().apply_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("trace.classes"), "{err}");
        // validate() guards direct struct construction too.
        let mut c = SimConfig::nh_g();
        c.trace.ring_cap = 1 << 30;
        assert!(c.validate().is_err());
    }

    #[test]
    fn fabric_validation_rejects_degenerate_shapes() {
        let mut c = SimConfig::nh_g();
        c.mem.fabric.kind = FabricKind::Queued { depth: 0 };
        assert!(c.validate().is_err());
        c.mem.fabric.kind = FabricKind::Tiered { pages: 0 };
        assert!(c.validate().is_err());
        c.mem.fabric.kind = FabricKind::Tiered { pages: 1 };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn bad_cache_geometry_rejected() {
        let mut c = SimConfig::nh_g();
        c.l1d.size_kb = 33; // 33KB/8way/64B = non-power-of-two sets
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(SimConfig::preset("a64fx").is_err());
    }

    #[test]
    fn table1_renders() {
        let t = SimConfig::nh_g().table1();
        let s = t.render();
        assert!(s.contains("ROB Entries"));
        assert!(s.contains("96"));
    }
}
