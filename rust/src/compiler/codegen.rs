//! AsyncSplitPass — CoroIR code generation (paper §III, Fig. 6).
//!
//! Lowers an analyzed kernel into a single self-contained CoroIR function
//! holding both the coroutine runtime and the task bodies ("consolidating
//! runtime and actual tasks within a single function", §III-A):
//!
//! * **Alloca/Init block** — configures the AMU, initializes the handler
//!   free list, lock table and scheduler queues.
//! * **Schedule block** — static FIFO + software prefetch, dynamic
//!   `getfin` + indirect jump, or dynamic `bafin` (Fig. 7).
//! * **Return block** — recycles handlers, starts subsequent iterations,
//!   applies sequential-variable updates.
//! * **Loop phases** — the original body, split at every suspension site
//!   with context save/restore generated from the liveness analysis.
//!
//! Also implements the §III-E atomics procedure (await/asignal lock
//! hand-off) and §III-F nested coroutines with derived ids.

use super::analysis::{self, vs_iter, Analysis, SiteKind, VarSet};
use super::ast::*;
use super::coalesce::{self, CoalescePlan, GroupKind, Role};
use crate::config::AmuConfig;
use crate::ir::builder::FuncBuilder;
use crate::ir::Operand::{Imm, Reg as R};
use crate::ir::*;
use anyhow::{bail, Result};

/// Scheduler flavour — selects the paper's evaluation variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    /// Plain loop, blocking remote accesses (baseline "Serial").
    Serial,
    /// Software-prefetch + FIFO static scheduler (Coroutine / CoroAMU-S).
    StaticFifo,
    /// Original-AMU dynamic scheduler: `getfin` + indirect jump (CoroAMU-D).
    Getfin,
    /// Enhanced-AMU dynamic scheduler: `bafin` (CoroAMU-Full).
    Bafin,
}

impl SchedKind {
    pub fn uses_amu(self) -> bool {
        matches!(self, SchedKind::Getfin | SchedKind::Bafin)
    }
}

#[derive(Debug, Clone)]
pub struct CodegenOpts {
    pub sched: SchedKind,
    /// §III-B selective context preservation.
    pub context_opt: bool,
    /// §III-C request coalescing.
    pub coalesce: bool,
    /// Emulate hand-written C++20-framework coroutines: full-frame spills
    /// plus per-switch promise/frame management overhead (§II-B, Fig. 3).
    pub generic_frame: bool,
    /// Concurrency (tasks in flight); clamped by SPM capacity for AMU.
    pub num_tasks: usize,
}

impl CodegenOpts {
    pub fn serial() -> Self {
        CodegenOpts { sched: SchedKind::Serial, context_opt: false, coalesce: false, generic_frame: false, num_tasks: 1 }
    }
    /// Hand-written C++20-style coroutine (paper's "Coroutine" baseline).
    pub fn hand_coroutine(n: usize) -> Self {
        CodegenOpts { sched: SchedKind::StaticFifo, context_opt: false, coalesce: false, generic_frame: true, num_tasks: n }
    }
    /// CoroAMU-S: compiler basic codegen, static prefetch scheduling.
    pub fn coroamu_s(n: usize) -> Self {
        CodegenOpts { sched: SchedKind::StaticFifo, context_opt: false, coalesce: false, generic_frame: false, num_tasks: n }
    }
    /// CoroAMU-D: basic codegen + original AMU (getfin).
    pub fn coroamu_d(n: usize) -> Self {
        CodegenOpts { sched: SchedKind::Getfin, context_opt: false, coalesce: false, generic_frame: false, num_tasks: n }
    }
    /// CoroAMU-Full: bafin + context selection + coalescing.
    pub fn coroamu_full(n: usize) -> Self {
        CodegenOpts { sched: SchedKind::Bafin, context_opt: true, coalesce: true, generic_frame: false, num_tasks: n }
    }
}

/// A runtime memory area the harness must allocate (local memory), whose
/// base address is bound to `reg` before execution.
#[derive(Debug, Clone)]
pub struct Area {
    pub name: String,
    pub bytes: u64,
    pub reg: Reg,
}

#[derive(Debug, Clone)]
pub struct CompiledKernel {
    pub func: Function,
    /// Kernel param p is bound to register `param_regs[p]`.
    pub param_regs: Vec<Reg>,
    /// Local runtime areas to allocate + bind.
    pub areas: Vec<Area>,
    /// SPM base register (AMU variants only).
    pub spm_base_reg: Option<Reg>,
    /// Per-id SPM slot footprint in bytes.
    pub spm_slot_bytes: u32,
    /// Final concurrency after SPM capacity clamping.
    pub num_tasks: usize,
    pub ctx_bytes: u32,
    /// Suspension sites found by AsyncMark.
    pub nsites: usize,
    /// Coalesce groups formed (0 when coalescing disabled).
    pub ngroups: usize,
    /// Ids used: num_tasks, or 2*num_tasks when nested coroutines exist.
    pub ids_used: usize,
}

// Context slot layout (per handler):
const CTX_RESUME: i64 = 0; // resume block id (static/getfin)
const CTX_ADDR: i64 = 8; // saved address temp
const CTX_VAL: i64 = 16; // saved value temp / nested return slot
const CTX_VARS: i64 = 24; // 8-byte slot per variable / nested arg

const FREE_SENTINEL: i64 = -1;

struct Lower<'a> {
    kernel: &'a Kernel,
    an: Analysis,
    plan: CoalescePlan,
    opts: &'a CodegenOpts,
    #[allow(dead_code)]
    amu: &'a AmuConfig,
    b: FuncBuilder,
    // Variable/parameter registers.
    var_reg: Vec<Reg>,
    param_regs: Vec<Reg>,
    // Runtime registers.
    cur_id: Reg,
    ctx: Reg,
    next_iter: Reg,
    active: Reg,
    free_top: Reg,
    fifo_head: Reg,
    fifo_tail: Reg,
    // Area base registers.
    handler_base: Reg,
    spm_base: Reg,
    free_base: Reg,
    fifo_base: Reg,
    lock_base: Reg,
    waiters_base: Reg,
    // Key blocks.
    sched_bb: BlockId,
    launch_bb: BlockId,
    finish_bb: BlockId,
    done_bb: BlockId,
    // Site cursor (must mirror analysis DFS order).
    next_site: usize,
    // Derived sizes.
    ctx_bytes: u32,
    num_tasks: usize,
    slot_bytes: u32,
    fifo_mask: i64,
    lock_entries: u64,
    has_nested: bool,
    /// Basic codegen frames the (read-only) parameters: stored at launch,
    /// reloaded at every resume (§III-B case 0 inefficiency).
    spill_params: bool,
    // Callee lowering state: when Some, we are lowering a nested callee
    // and params/vars resolve to these registers instead.
    callee_params: Option<Vec<Reg>>,
    callee_vars: Option<Vec<Reg>>,
    callee_kernel: Option<usize>,
    /// Entry block per callee (nested coroutine dispatch target).
    callee_entries: Vec<BlockId>,
    /// Conservative live set spilled around each call site.
    call_live_sets: Vec<VarSet>,
}

pub fn compile(kernel: &Kernel, opts: &CodegenOpts, amu: &AmuConfig) -> Result<CompiledKernel> {
    // Inline nested calls when the scheduler cannot express them (or the
    // callee has no remote access — §III-F "most of them are inlined").
    let kernel = inline_calls(kernel, opts.sched)?;
    let an = analysis::analyze(&kernel)?;
    let plan = if opts.coalesce && opts.sched.uses_amu() {
        coalesce::plan(&an, amu.max_group.max(1), amu.max_coarse_bytes.max(64) as u32)
    } else if opts.coalesce && opts.sched == SchedKind::StaticFifo {
        // Prefetch coalescing is always safe (§III-C: "straightforward for
        // software prefetching").
        coalesce::plan(&an, 8, 4096)
    } else if opts.sched == SchedKind::Serial {
        CoalescePlan::disabled(an.sites.len())
    } else {
        // Basic codegen still suspends at *object* granularity: field
        // loads of one 64B record share a single prefetch/aload + yield
        // (what any practical coroutine runtime emits). §III-C extends
        // this to 4KB coarse grains and cross-object aset groups.
        coalesce::plan_line_granular(&an)
    };

    if opts.sched == SchedKind::Serial {
        return lower_serial(&kernel, &an);
    }

    let has_nested = kernel.body.iter().any(|s| stmt_has_call(s)) && opts.sched.uses_amu();
    let slot_bytes = plan.max_slot_bytes().next_power_of_two();
    let mut num_tasks = opts.num_tasks.max(1);
    if opts.sched.uses_amu() {
        let spm_bytes = (amu.spm_kb * 1024) as u32;
        let mut cap = (spm_bytes / slot_bytes) as usize;
        if has_nested {
            cap /= 2;
        }
        let cap = cap.min(amu.request_table);
        if cap == 0 {
            bail!("SPM cannot hold a single slot of {slot_bytes} bytes");
        }
        num_tasks = num_tasks.min(cap);
    }

    // Context: resume + addr/val temps + one slot per var (+ param slots
    // under basic codegen, which frames captured values like stock LLVM
    // lowering does + callee arg/var slots).
    let spill_params = analysis::Analysis::spills_params(opts.context_opt && !opts.generic_frame);
    let max_callee = kernel.callees.iter().map(|c| c.params.len() as u32 + c.nvars).max().unwrap_or(0);
    let param_slots = if spill_params { kernel.params.len() as u32 } else { 0 };
    let slots = (kernel.nvars + param_slots).max(max_callee);
    let ctx_bytes = ((CTX_VARS as u32 + 8 * slots + 15) / 16) * 16;

    let mut b = FuncBuilder::new(format!("{}_{:?}", kernel.name, opts.sched));
    let param_regs: Vec<Reg> = kernel.params.iter().map(|_| b.reg()).collect();
    let var_reg: Vec<Reg> = (0..kernel.nvars).map(|_| b.reg()).collect();

    let mut lw = Lower {
        kernel: &kernel,
        an,
        plan,
        opts,
        amu,
        cur_id: 0,
        ctx: 0,
        next_iter: 0,
        active: 0,
        free_top: 0,
        fifo_head: 0,
        fifo_tail: 0,
        handler_base: 0,
        spm_base: 0,
        free_base: 0,
        fifo_base: 0,
        lock_base: 0,
        waiters_base: 0,
        sched_bb: 0,
        launch_bb: 0,
        finish_bb: 0,
        done_bb: 0,
        next_site: 0,
        ctx_bytes,
        num_tasks,
        slot_bytes,
        fifo_mask: ((2 * num_tasks).next_power_of_two() - 1) as i64,
        lock_entries: 256,
        has_nested,
        spill_params,
        callee_params: None,
        callee_vars: None,
        callee_kernel: None,
        callee_entries: Vec::new(),
        call_live_sets: Vec::new(),
        var_reg,
        param_regs,
        b,
    };
    lw.cur_id = lw.b.reg();
    lw.ctx = lw.b.reg();
    lw.next_iter = lw.b.reg();
    lw.active = lw.b.reg();
    lw.free_top = lw.b.reg();
    lw.fifo_head = lw.b.reg();
    lw.fifo_tail = lw.b.reg();
    lw.handler_base = lw.b.reg();
    lw.spm_base = lw.b.reg();
    lw.free_base = lw.b.reg();
    lw.fifo_base = lw.b.reg();
    lw.lock_base = lw.b.reg();
    lw.waiters_base = lw.b.reg();
    lw.emit_coroutine()
}

fn stmt_has_call(s: &Stmt) -> bool {
    match s {
        Stmt::Call { .. } => true,
        Stmt::If { then_, else_, .. } => then_.iter().any(stmt_has_call) || else_.iter().any(stmt_has_call),
        Stmt::While { body, .. } => body.iter().any(stmt_has_call),
        _ => false,
    }
}

fn callee_has_remote(f: &NestedFn) -> bool {
    fn any_remote(stmts: &[Stmt], params: &[Param]) -> bool {
        stmts.iter().any(|s| match s {
            Stmt::Load { addr, .. } | Stmt::Store { addr, .. } | Stmt::AtomicRmw { addr, .. } => {
                matches!(analysis::stmt_space(addr, params), Ok((AddrSpace::Remote, _)))
            }
            Stmt::If { then_, else_, .. } => any_remote(then_, params) || any_remote(else_, params),
            Stmt::While { body, .. } => any_remote(body, params),
            _ => false,
        })
    }
    any_remote(&f.body, &f.params)
}

/// Substitute caller argument expressions for callee params and remap
/// callee variables into fresh caller variable ids.
fn substitute(e: &Expr, args: &[Expr], var_off: u32) -> Expr {
    match e {
        Expr::Param(p) => args[*p as usize].clone(),
        Expr::Var(v) => Expr::Var(v + var_off),
        Expr::Bin(op, a, b) => Expr::Bin(*op, Box::new(substitute(a, args, var_off)), Box::new(substitute(b, args, var_off))),
        other => other.clone(),
    }
}

fn inline_body(stmts: &[Stmt], args: &[Expr], var_off: u32) -> Vec<Stmt> {
    stmts
        .iter()
        .map(|s| match s {
            Stmt::Let { var, expr } => Stmt::Let { var: var + var_off, expr: substitute(expr, args, var_off) },
            Stmt::Load { var, addr, width } => {
                Stmt::Load { var: var + var_off, addr: substitute(addr, args, var_off), width: *width }
            }
            Stmt::Store { val, addr, width } => Stmt::Store {
                val: substitute(val, args, var_off),
                addr: substitute(addr, args, var_off),
                width: *width,
            },
            Stmt::AtomicRmw { op, old, addr, val, width } => Stmt::AtomicRmw {
                op: *op,
                old: old.map(|v| v + var_off),
                addr: substitute(addr, args, var_off),
                val: substitute(val, args, var_off),
                width: *width,
            },
            Stmt::If { cond, then_, else_ } => Stmt::If {
                cond: substitute(cond, args, var_off),
                then_: inline_body(then_, args, var_off),
                else_: inline_body(else_, args, var_off),
            },
            Stmt::While { cond, body } => Stmt::While {
                cond: substitute(cond, args, var_off),
                body: inline_body(body, args, var_off),
            },
            Stmt::Call { .. } => panic!("nested Call inside callee unsupported"),
        })
        .collect()
}

/// Inline `Stmt::Call` sites. Under serial/static scheduling every call is
/// inlined; under AMU scheduling only remote-free callees are inlined
/// (remote callees become true nested coroutines).
fn inline_calls(kernel: &Kernel, sched: SchedKind) -> Result<Kernel> {
    if kernel.callees.is_empty() {
        return Ok(kernel.clone());
    }
    let mut k = kernel.clone();
    let mut nvars = k.nvars;
    fn rewrite(
        stmts: &[Stmt],
        k: &Kernel,
        sched: SchedKind,
        nvars: &mut u32,
        names: &mut Vec<String>,
    ) -> Result<Vec<Stmt>> {
        let mut out = Vec::new();
        for s in stmts {
            match s {
                Stmt::Call { callee, args, ret } => {
                    let f = &k.callees[*callee];
                    let do_inline = !sched.uses_amu() || !callee_has_remote(f);
                    if do_inline {
                        let off = *nvars;
                        *nvars += f.nvars;
                        for v in 0..f.nvars {
                            names.push(format!("{}.v{}", f.name, v));
                        }
                        out.extend(inline_body(&f.body, args, off));
                        if let (Some(rv), Some(fr)) = (ret, f.ret_var) {
                            out.push(Stmt::Let { var: *rv, expr: Expr::Var(fr + off) });
                        }
                    } else {
                        out.push(s.clone());
                    }
                }
                Stmt::If { cond, then_, else_ } => out.push(Stmt::If {
                    cond: cond.clone(),
                    then_: rewrite(then_, k, sched, nvars, names)?,
                    else_: rewrite(else_, k, sched, nvars, names)?,
                }),
                Stmt::While { cond, body } => out.push(Stmt::While {
                    cond: cond.clone(),
                    body: rewrite(body, k, sched, nvars, names)?,
                }),
                other => out.push(other.clone()),
            }
        }
        Ok(out)
    }
    let mut names = k.var_names.clone();
    k.body = rewrite(&kernel.body, kernel, sched, &mut nvars, &mut names)?;
    k.nvars = nvars;
    k.var_names = names;
    Ok(k)
}

// ---------------------------------------------------------------------
// Serial lowering
// ---------------------------------------------------------------------

fn lower_serial(kernel: &Kernel, an: &Analysis) -> Result<CompiledKernel> {
    let mut b = FuncBuilder::new(format!("{}_serial", kernel.name));
    let param_regs: Vec<Reg> = kernel.params.iter().map(|_| b.reg()).collect();
    let var_reg: Vec<Reg> = (0..kernel.nvars).map(|_| b.reg()).collect();
    let mut lw = SerialLower { kernel, b, param_regs, var_reg };

    let head = lw.b.new_block("head", CodeTag::Compute);
    let body = lw.b.new_block("body", CodeTag::Compute);
    let done = lw.b.new_block("done", CodeTag::Compute);
    // entry: i = 0
    lw.b.mov(lw.var_reg[ITER_VAR as usize], Imm(0));
    lw.b.jmp(head);
    lw.b.switch_to(head);
    let total = lw.param_regs[kernel.trip_param as usize];
    let c = lw.b.alu(AluOp::Slt, R(lw.var_reg[ITER_VAR as usize]), R(total));
    lw.b.br(R(c), body, done);
    lw.b.switch_to(body);
    lw.stmts(&kernel.body)?;
    let iv = lw.var_reg[ITER_VAR as usize];
    lw.b.alu_into(iv, AluOp::Add, R(iv), Imm(1));
    lw.b.jmp(head);
    lw.b.switch_to(done);
    lw.b.halt();

    let func = lw.b.build();
    crate::ir::verify::verify(&func)?;
    Ok(CompiledKernel {
        func,
        param_regs: lw.param_regs,
        areas: vec![],
        spm_base_reg: None,
        spm_slot_bytes: 0,
        num_tasks: 1,
        ctx_bytes: 0,
        nsites: an.sites.len(),
        ngroups: 0,
        ids_used: 0,
    })
}

struct SerialLower<'a> {
    kernel: &'a Kernel,
    b: FuncBuilder,
    param_regs: Vec<Reg>,
    var_reg: Vec<Reg>,
}

impl<'a> SerialLower<'a> {
    fn expr(&mut self, e: &Expr) -> Operand {
        match e {
            Expr::Imm(v) => Imm(*v),
            Expr::FImm(f) => Imm(f.to_bits() as i64),
            Expr::Var(v) => R(self.var_reg[*v as usize]),
            Expr::Param(p) => R(self.param_regs[*p as usize]),
            Expr::Bin(op, a, b) => {
                let ra = self.expr(a);
                let rb = self.expr(b);
                let dst = match op {
                    BinOp::I(o) => self.b.alu(*o, ra, rb),
                    BinOp::F(o) => self.b.falu(*o, ra, rb),
                };
                R(dst)
            }
        }
    }

    fn space_of(&self, addr: &Expr) -> AddrSpace {
        analysis::stmt_space(addr, &self.kernel.params).map(|(s, _)| s).unwrap_or(AddrSpace::Local)
    }

    fn stmts(&mut self, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            match s {
                Stmt::Let { var, expr } => {
                    let v = self.expr(expr);
                    self.b.mov(self.var_reg[*var as usize], v);
                }
                Stmt::Load { var, addr, width } => {
                    let sp = self.space_of(addr);
                    let a = self.expr(addr);
                    self.b.load_into(self.var_reg[*var as usize], a, 0, *width, sp);
                }
                Stmt::Store { val, addr, width } => {
                    let sp = self.space_of(addr);
                    let v = self.expr(val);
                    let a = self.expr(addr);
                    self.b.store(v, a, 0, *width, sp);
                }
                Stmt::AtomicRmw { op, old, addr, val, width } => {
                    let sp = self.space_of(addr);
                    let v = self.expr(val);
                    let a = self.expr(addr);
                    let dst = old.map(|o| self.var_reg[o as usize]).unwrap_or_else(|| self.b.reg());
                    self.b.push(Inst::AtomicRmw { op: *op, dst, val: v, base: a, off: 0, width: *width, space: sp });
                }
                Stmt::If { cond, then_, else_ } => {
                    let c = self.expr(cond);
                    let tb = self.b.new_block("if.then", CodeTag::Compute);
                    let eb = self.b.new_block("if.else", CodeTag::Compute);
                    let jb = self.b.new_block("if.join", CodeTag::Compute);
                    self.b.br(c, tb, eb);
                    self.b.switch_to(tb);
                    self.stmts(then_)?;
                    self.b.jmp(jb);
                    self.b.switch_to(eb);
                    self.stmts(else_)?;
                    self.b.jmp(jb);
                    self.b.switch_to(jb);
                }
                Stmt::While { cond, body } => {
                    let hb = self.b.new_block("wh.head", CodeTag::Compute);
                    let bb = self.b.new_block("wh.body", CodeTag::Compute);
                    let xb = self.b.new_block("wh.exit", CodeTag::Compute);
                    self.b.jmp(hb);
                    self.b.switch_to(hb);
                    let c = self.expr(cond);
                    self.b.br(c, bb, xb);
                    self.b.switch_to(bb);
                    self.stmts(body)?;
                    self.b.jmp(hb);
                    self.b.switch_to(xb);
                }
                Stmt::Call { .. } => bail!("Call must be inlined before serial lowering"),
            }
        }
        Ok(())
    }
}

// The coroutine lowering lives in codegen_coro.rs (same module family) to
// keep file sizes manageable.
include!("codegen_coro.rs");

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::AddrSpace::Remote;

    fn gups_kernel() -> Kernel {
        let mut kb = KernelBuilder::new("gups");
        let tab = kb.param_ptr("tab", Remote);
        let mask = kb.param_val("mask");
        let n = kb.param_val("n");
        kb.trip(n);
        let idx = kb.var("idx");
        let v = kb.var("v");
        let addr = Expr::add(Expr::Param(tab), Expr::shl(Expr::Var(idx), Expr::Imm(3)));
        kb.build(vec![
            Stmt::Let {
                var: idx,
                expr: Expr::and(
                    Expr::Bin(BinOp::I(AluOp::Hash), Box::new(Expr::Var(ITER_VAR)), Box::new(Expr::Imm(17))),
                    Expr::Param(mask),
                ),
            },
            Stmt::Load { var: v, addr: addr.clone(), width: Width::W8 },
            Stmt::Store {
                val: Expr::Bin(BinOp::I(AluOp::Xor), Box::new(Expr::Var(v)), Box::new(Expr::Var(idx))),
                addr,
                width: Width::W8,
            },
        ])
    }

    #[test]
    fn serial_compiles_and_verifies() {
        let k = gups_kernel();
        let c = compile(&k, &CodegenOpts::serial(), &AmuConfig::disabled()).unwrap();
        assert!(c.areas.is_empty());
        assert_eq!(c.num_tasks, 1);
        assert!(c.func.blocks.len() >= 4);
    }

    #[test]
    fn static_fifo_compiles() {
        let k = gups_kernel();
        let c = compile(&k, &CodegenOpts::coroamu_s(16), &AmuConfig::disabled()).unwrap();
        assert_eq!(c.num_tasks, 16);
        assert!(c.areas.iter().any(|a| a.name == "handler"));
        assert!(c.areas.iter().any(|a| a.name == "fifo"));
        // Static scheduling must emit prefetches and indirect jumps.
        let has_prefetch = c.func.blocks.iter().any(|b| b.insts.iter().any(|i| matches!(i, Inst::Prefetch { .. })));
        let has_ijmp = c.func.blocks.iter().any(|b| matches!(b.term, Term::IndirectJmp { .. }));
        assert!(has_prefetch && has_ijmp);
    }

    #[test]
    fn getfin_compiles_with_amu_ops() {
        let k = gups_kernel();
        let amu = crate::config::SimConfig::nh_g().amu;
        let c = compile(&k, &CodegenOpts::coroamu_d(96), &amu).unwrap();
        assert_eq!(c.num_tasks, 96);
        assert!(c.spm_base_reg.is_some());
        let has_aload = c.func.blocks.iter().any(|b| b.insts.iter().any(|i| matches!(i, Inst::Aload { .. })));
        let has_getfin = c.func.blocks.iter().any(|b| b.insts.iter().any(|i| matches!(i, Inst::Getfin { .. })));
        assert!(has_aload && has_getfin);
    }

    #[test]
    fn bafin_compiles_with_bafin_term() {
        let k = gups_kernel();
        let amu = crate::config::SimConfig::nh_g().amu;
        let c = compile(&k, &CodegenOpts::coroamu_full(96), &amu).unwrap();
        let has_bafin = c.func.blocks.iter().any(|b| matches!(b.term, Term::Bafin { .. }));
        let has_getfin = c.func.blocks.iter().any(|b| b.insts.iter().any(|i| matches!(i, Inst::Getfin { .. })));
        assert!(has_bafin && !has_getfin);
    }

    #[test]
    fn full_codegen_is_leaner_than_basic() {
        // §III-B/§III-D: context selection + bafin shrink the generated
        // code relative to getfin+full-spill.
        let k = gups_kernel();
        let amu = crate::config::SimConfig::nh_g().amu;
        let d = compile(&k, &CodegenOpts::coroamu_d(96), &amu).unwrap();
        let f = compile(&k, &CodegenOpts::coroamu_full(96), &amu).unwrap();
        assert!(
            f.func.static_len() <= d.func.static_len(),
            "full ({}) should not exceed basic ({})",
            f.func.static_len(),
            d.func.static_len()
        );
    }

    #[test]
    fn hand_coroutine_has_more_overhead_than_coroamu_s() {
        let k = gups_kernel();
        let hand = compile(&k, &CodegenOpts::hand_coroutine(16), &AmuConfig::disabled()).unwrap();
        let s = compile(&k, &CodegenOpts::coroamu_s(16), &AmuConfig::disabled()).unwrap();
        assert!(hand.func.static_len() > s.func.static_len());
    }

    #[test]
    fn spm_capacity_clamps_tasks() {
        let k = gups_kernel();
        let mut amu = crate::config::SimConfig::nh_g().amu;
        amu.spm_kb = 1; // 1 KB SPM, 64B slots -> 16 ids
        let c = compile(&k, &CodegenOpts::coroamu_d(96), &amu).unwrap();
        assert_eq!(c.num_tasks, 16);
    }
}
