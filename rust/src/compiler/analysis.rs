//! AsyncMarkPass — analysis over the kernel AST (paper §III-A/§III-B).
//!
//! Produces, without modifying the kernel:
//!  * the ordered list of **suspension sites** (remote loads/stores/atomics)
//!    together with conservative live-after variable sets,
//!  * the **variable classification** into private / shared / sequential
//!    (§III-B), combining static analysis with pragma hints,
//!  * straight-line **run ids** used by the request coalescer (§III-C finds
//!    merge candidates only within a basic block).
//!
//! Variable sets are u64 bitmasks; kernels (including inlined callees) are
//! limited to 64 variables, which all eight benchmarks satisfy easily.

use super::ast::*;
use crate::ir::{AddrSpace, AluOp, Width};
use anyhow::{bail, Result};

pub type VarSet = u64;

pub fn vs_contains(s: VarSet, v: VarId) -> bool {
    s & (1u64 << v) != 0
}

pub fn vs_insert(s: &mut VarSet, v: VarId) {
    *s |= 1u64 << v;
}

pub fn vs_iter(s: VarSet) -> impl Iterator<Item = VarId> {
    (0..64).filter(move |v| s & (1u64 << v) != 0)
}

pub fn vs_len(s: VarSet) -> usize {
    s.count_ones() as usize
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteKind {
    LoadRemote,
    StoreRemote,
    AtomicRemote,
}

/// One suspension site: a remote-memory access the coroutine transform
/// splits the task at.
#[derive(Debug, Clone)]
pub struct Site {
    pub id: usize,
    pub kind: SiteKind,
    pub width: Width,
    /// Variables that must survive across the suspension (conservative).
    pub live_after: VarSet,
    /// Straight-line run (basic-block equivalent) this site belongs to.
    pub run: usize,
    /// Variables the site's address expression (transitively) depends on.
    pub addr_deps: VarSet,
    /// The variable defined by this site (load destination), if any.
    pub def: Option<VarId>,
    /// Pointer-root parameter of the address.
    pub root: ParamId,
    /// Variables written between this site and the next site in program
    /// order (used by the coalescer's dependence check).
    pub defs_after: VarSet,
    /// Whether a memory side-effect (store/atomic/call) occurs between this
    /// site and the next one — a coalescing barrier (§III-C).
    pub barrier_after: bool,
    /// The address expression (cloned) — the coalescer matches structure
    /// to find constant-delta (coarse-grain) merge candidates.
    pub addr: Expr,
}

#[derive(Debug, Clone)]
pub struct Analysis {
    pub sites: Vec<Site>,
    pub classes: Vec<VarClass>,
    /// Vars ever read in the body (params excluded).
    pub read_vars: VarSet,
    /// Vars ever written in the body.
    pub written_vars: VarSet,
    /// Total number of variables (incl. inlined callee remaps).
    pub nvars: u32,
}

impl Analysis {
    pub fn class(&self, v: VarId) -> VarClass {
        self.classes[v as usize]
    }

    /// Variables to save at `site` under the given context policy.
    /// `optimized` = §III-B context selection (only private variables).
    /// Basic codegen (stock LLVM coroutine lowering) additionally spills
    /// read-only values — harmless but wasteful, the paper's case 0.
    /// Shared *accumulators* are never spilled in either mode: in the
    /// consolidated single-function runtime they live outside the frame
    /// (a per-frame copy would lose other tasks' updates).
    pub fn saved_vars(&self, site: &Site, optimized: bool) -> VarSet {
        let mut s = 0u64;
        for v in vs_iter(site.live_after) {
            let keep = match self.classes[v as usize] {
                VarClass::Private => true,
                VarClass::Sequential => false,
                VarClass::Shared => !optimized && !vs_contains(self.written_vars, v),
            };
            if keep {
                vs_insert(&mut s, v);
            }
        }
        s
    }

    /// Does basic codegen spill the (read-only) parameters into the frame
    /// as well? Stock LLVM coroutine lowering puts every captured value in
    /// the frame; §III-B's context selection lets them bypass it.
    pub fn spills_params(optimized: bool) -> bool {
        !optimized
    }
}

/// Address space of a memory statement, inferred from the pointer root
/// (§III-G: each pointer's characteristics are static).
pub fn stmt_space(addr: &Expr, params: &[Param]) -> Result<(AddrSpace, ParamId)> {
    match addr.pointer_root(params) {
        Some(p) => match params[p as usize].kind {
            ParamKind::Ptr(sp) => Ok((sp, p)),
            ParamKind::Value => bail!("address rooted at non-pointer param {p}"),
        },
        None => bail!("address expression has no unique pointer root: {addr:?}"),
    }
}

fn expr_reads(e: &Expr) -> VarSet {
    let mut vs = Vec::new();
    e.vars(&mut vs);
    let mut s = 0u64;
    for v in vs {
        vs_insert(&mut s, v);
    }
    s
}

/// All variables read anywhere in `stmts` (no kill — used as the
/// conservative loop-carried component of liveness).
fn reads_in(stmts: &[Stmt], kernels: &Kernel) -> VarSet {
    let mut s = 0u64;
    for st in stmts {
        match st {
            Stmt::Let { expr, .. } => s |= expr_reads(expr),
            Stmt::Load { addr, .. } => s |= expr_reads(addr),
            Stmt::Store { val, addr, .. } => s |= expr_reads(val) | expr_reads(addr),
            Stmt::AtomicRmw { addr, val, .. } => s |= expr_reads(addr) | expr_reads(val),
            Stmt::If { cond, then_, else_ } => {
                s |= expr_reads(cond) | reads_in(then_, kernels) | reads_in(else_, kernels)
            }
            Stmt::While { cond, body } => s |= expr_reads(cond) | reads_in(body, kernels),
            Stmt::Call { args, .. } => {
                for a in args {
                    s |= expr_reads(a);
                }
            }
        }
    }
    s
}

fn writes_in(stmts: &[Stmt]) -> VarSet {
    let mut s = 0u64;
    for st in stmts {
        match st {
            Stmt::Let { var, .. } | Stmt::Load { var, .. } => vs_insert(&mut s, *var),
            Stmt::AtomicRmw { old: Some(v), .. } => vs_insert(&mut s, *v),
            Stmt::If { then_, else_, .. } => s |= writes_in(then_) | writes_in(else_),
            Stmt::While { body, .. } => s |= writes_in(body),
            Stmt::Call { ret: Some(v), .. } => vs_insert(&mut s, *v),
            _ => {}
        }
    }
    s
}

struct Walker<'a> {
    kernel: &'a Kernel,
    sites: Vec<Site>,
    next_run: usize,
    /// Defs accumulated (walking backward) since the last recorded site.
    defs_acc: VarSet,
    /// Side-effect barrier accumulated since the last recorded site.
    barrier_acc: bool,
}

impl<'a> Walker<'a> {
    /// Backward walk over `stmts`. `live` is the live-after set at the end
    /// of the list; `loop_reads` is everything read by enclosing loops
    /// (conservative loop-carried liveness); `run` is the current
    /// straight-line run id. Sites are recorded in reverse order (the
    /// caller reverses + renumbers at the end). Returns the live-before
    /// set of the list.
    fn walk(&mut self, stmts: &[Stmt], mut live: VarSet, loop_reads: VarSet, run: usize) -> VarSet {
        for st in stmts.iter().rev() {
            match st {
                Stmt::Let { var, expr } => {
                    live &= !(1u64 << var);
                    live |= expr_reads(expr);
                    vs_insert(&mut self.defs_acc, *var);
                }
                Stmt::Load { var, addr, width } => {
                    let (space, root) = stmt_space(addr, &self.kernel.params).expect("typed addr");
                    // live-after the load (before the kill of `var`, after
                    // the load completes): `var` holds the loaded value and
                    // is live if read later.
                    if space == AddrSpace::Remote {
                        self.record(SiteKind::LoadRemote, *width, live | loop_reads, run, addr, Some(*var), root);
                    }
                    live &= !(1u64 << var);
                    live |= expr_reads(addr);
                    vs_insert(&mut self.defs_acc, *var);
                }
                Stmt::Store { val, addr, width } => {
                    let (space, root) = stmt_space(addr, &self.kernel.params).expect("typed addr");
                    if space == AddrSpace::Remote {
                        self.record(SiteKind::StoreRemote, *width, live | loop_reads, run, addr, None, root);
                    }
                    live |= expr_reads(val) | expr_reads(addr);
                    self.barrier_acc = true;
                }
                Stmt::AtomicRmw { old, addr, val, width, .. } => {
                    let (space, root) = stmt_space(addr, &self.kernel.params).expect("typed addr");
                    if space == AddrSpace::Remote {
                        self.record(SiteKind::AtomicRemote, *width, live | loop_reads, run, addr, *old, root);
                    }
                    if let Some(v) = old {
                        live &= !(1u64 << v);
                        vs_insert(&mut self.defs_acc, *v);
                    }
                    live |= expr_reads(val) | expr_reads(addr);
                    self.barrier_acc = true;
                }
                Stmt::If { cond, then_, else_ } => {
                    // Reverse of forward order (then, else): walk else first.
                    let run_else = self.fresh_run();
                    let le = self.walk(else_, live, loop_reads, run_else);
                    let run_then = self.fresh_run();
                    let lt = self.walk(then_, live, loop_reads, run_then);
                    live = lt | le | expr_reads(cond);
                    // Conservative for the outer run: the If's effects
                    // block coalescing across it.
                    self.defs_acc |= writes_in(then_) | writes_in(else_);
                    self.barrier_acc = true;
                }
                Stmt::While { cond, body } => {
                    // Conservative: everything read in the loop (or after
                    // it) is live throughout the loop.
                    let body_reads = reads_in(body, self.kernel) | expr_reads(cond);
                    let run_body = self.fresh_run();
                    let lb = self.walk(body, live | body_reads, loop_reads | body_reads | live, run_body);
                    live = live | lb | body_reads;
                    self.defs_acc |= writes_in(body);
                    self.barrier_acc = true;
                }
                Stmt::Call { callee, args, ret } => {
                    // Calls are analyzed at their lowering; for caller-side
                    // liveness the callee behaves like `ret = f(args)`.
                    let _ = callee;
                    if let Some(v) = ret {
                        live &= !(1u64 << v);
                        vs_insert(&mut self.defs_acc, *v);
                    }
                    for a in args {
                        live |= expr_reads(a);
                    }
                    self.barrier_acc = true;
                }
            }
        }
        live
    }

    fn fresh_run(&mut self) -> usize {
        self.next_run += 1;
        self.next_run
    }

    fn record(
        &mut self,
        kind: SiteKind,
        width: Width,
        live_after: VarSet,
        run: usize,
        addr: &Expr,
        def: Option<VarId>,
        root: ParamId,
    ) {
        self.sites.push(Site {
            id: 0, // renumbered after reversal
            kind,
            width,
            live_after,
            run,
            addr_deps: expr_reads(addr),
            def,
            root,
            // Walking backward: what accumulated since the previously
            // recorded site is exactly what lies *after* this site.
            defs_after: self.defs_acc,
            barrier_after: self.barrier_acc,
            addr: addr.clone(),
        });
        self.defs_acc = 0;
        self.barrier_acc = false;
    }
}

/// Commutative self-update detection: `v = v op expr` where `op` is
/// commutative+associative and `expr` does not read `v`.
fn is_commutative_update(var: VarId, expr: &Expr) -> bool {
    const COMM: &[AluOp] = &[AluOp::Add, AluOp::Xor, AluOp::And, AluOp::Or, AluOp::Min, AluOp::Max];
    if let Expr::Bin(BinOp::I(op), a, b) = expr {
        if !COMM.contains(op) {
            return false;
        }
        let (va, vb) = (expr_reads(a), expr_reads(b));
        let vbit = 1u64 << var;
        // v on exactly one side, other side independent of v.
        return (matches!(**a, Expr::Var(x) if x == var) && vb & vbit == 0)
            || (matches!(**b, Expr::Var(x) if x == var) && va & vbit == 0);
    }
    false
}

/// Does `stmts` contain any non-commutative write to `var`?
fn has_non_commutative_write(stmts: &[Stmt], var: VarId) -> bool {
    stmts.iter().any(|st| match st {
        Stmt::Let { var: v, expr } => *v == var && !is_commutative_update(var, expr),
        Stmt::Load { var: v, .. } => *v == var,
        Stmt::AtomicRmw { old: Some(v), .. } => *v == var,
        Stmt::If { then_, else_, .. } => {
            has_non_commutative_write(then_, var) || has_non_commutative_write(else_, var)
        }
        Stmt::While { body, .. } => has_non_commutative_write(body, var),
        Stmt::Call { ret: Some(v), .. } => *v == var,
        _ => false,
    })
}

/// Is `var` read anywhere outside its own commutative updates?
fn read_outside_update(stmts: &[Stmt], var: VarId) -> bool {
    let vbit = 1u64 << var;
    stmts.iter().any(|st| match st {
        Stmt::Let { var: v, expr } => {
            if *v == var && is_commutative_update(var, expr) {
                false
            } else {
                expr_reads(expr) & vbit != 0
            }
        }
        Stmt::Load { addr, .. } => expr_reads(addr) & vbit != 0,
        Stmt::Store { val, addr, .. } => (expr_reads(val) | expr_reads(addr)) & vbit != 0,
        Stmt::AtomicRmw { addr, val, .. } => (expr_reads(addr) | expr_reads(val)) & vbit != 0,
        Stmt::If { cond, then_, else_ } => {
            expr_reads(cond) & vbit != 0 || read_outside_update(then_, var) || read_outside_update(else_, var)
        }
        Stmt::While { cond, body } => expr_reads(cond) & vbit != 0 || read_outside_update(body, var),
        Stmt::Call { args, .. } => args.iter().any(|a| expr_reads(a) & vbit != 0),
    })
}

/// Run the full analysis (§III-A marking + §III-B classification).
pub fn analyze(kernel: &Kernel) -> Result<Analysis> {
    if kernel.nvars > 64 {
        bail!("kernel {} has {} vars; analysis supports <= 64", kernel.name, kernel.nvars);
    }
    // Suspension sites + liveness.
    let mut w = Walker { kernel, sites: Vec::new(), next_run: 0, defs_acc: 0, barrier_acc: false };
    w.walk(&kernel.body, 0, 0, 0);
    let mut sites = w.sites;
    sites.reverse();
    for (i, s) in sites.iter_mut().enumerate() {
        s.id = i;
    }

    // Variable classification.
    let read = reads_in(&kernel.body, kernel);
    let written = writes_in(&kernel.body);
    let mut classes = vec![VarClass::Private; kernel.nvars as usize];
    for v in 0..kernel.nvars {
        let cls = if kernel.pragma.sequential_vars.contains(&v) {
            VarClass::Sequential
        } else if kernel.pragma.shared_vars.contains(&v) {
            VarClass::Shared
        } else if v == ITER_VAR {
            // The induction variable identifies the task: always private.
            VarClass::Private
        } else if !vs_contains(written, v) {
            // Read-only: bypass context entirely (§III-B case 0).
            VarClass::Shared
        } else if !has_non_commutative_write(&kernel.body, v) && !read_outside_update(&kernel.body, v) {
            // Pure commutative accumulator (§III-B case 2).
            VarClass::Shared
        } else {
            // §III-B case 1 (context-dependent) and case 3 (ambiguous) both
            // stay per-coroutine; truly ambiguous loop-carried patterns
            // must be pragma-marked sequential by the programmer, exactly
            // as the paper requires hints for imprecise cases.
            VarClass::Private
        };
        classes[v as usize] = cls;
    }

    Ok(Analysis { sites, classes, read_vars: read, written_vars: written, nvars: kernel.nvars })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::AddrSpace::*;

    /// GUPS-like kernel: idx = hash(i); v = tab[idx]; tab[idx] = v ^ idx;
    /// acc += v (commutative accumulator).
    fn gups_like() -> Kernel {
        let mut kb = KernelBuilder::new("gups_like");
        let tab = kb.param_ptr("tab", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let idx = kb.var("idx");
        let v = kb.var("v");
        let acc = kb.var("acc");
        let addr = |idx_v: VarId, tab_p: ParamId| {
            Expr::add(Expr::Param(tab_p), Expr::shl(Expr::Var(idx_v), Expr::Imm(3)))
        };
        kb.build(vec![
            Stmt::Let {
                var: idx,
                expr: Expr::Bin(BinOp::I(AluOp::Hash), Box::new(Expr::Var(ITER_VAR)), Box::new(Expr::Imm(0xFFFF))),
            },
            Stmt::Load { var: v, addr: addr(idx, tab), width: Width::W8 },
            Stmt::Store {
                val: Expr::Bin(BinOp::I(AluOp::Xor), Box::new(Expr::Var(v)), Box::new(Expr::Var(idx))),
                addr: addr(idx, tab),
                width: Width::W8,
            },
            Stmt::Let {
                var: acc,
                expr: Expr::Bin(BinOp::I(AluOp::Add), Box::new(Expr::Var(acc)), Box::new(Expr::Var(v))),
            },
        ])
    }

    #[test]
    fn finds_sites_in_order() {
        let k = gups_like();
        let a = analyze(&k).unwrap();
        assert_eq!(a.sites.len(), 2);
        assert_eq!(a.sites[0].kind, SiteKind::LoadRemote);
        assert_eq!(a.sites[1].kind, SiteKind::StoreRemote);
        assert_eq!(a.sites[0].id, 0);
        // After the load, idx (for the store address) and v are live.
        let live = a.sites[0].live_after;
        assert!(vs_contains(live, k_var(&k, "idx")));
        assert!(vs_contains(live, k_var(&k, "v")));
    }

    fn k_var(k: &Kernel, name: &str) -> VarId {
        k.var_names.iter().position(|n| n == name).unwrap() as VarId
    }

    #[test]
    fn classification() {
        let k = gups_like();
        let a = analyze(&k).unwrap();
        assert_eq!(a.class(ITER_VAR), VarClass::Private);
        assert_eq!(a.class(k_var(&k, "idx")), VarClass::Private);
        assert_eq!(a.class(k_var(&k, "v")), VarClass::Private);
        // acc only ever updated commutatively: shared.
        assert_eq!(a.class(k_var(&k, "acc")), VarClass::Shared);
    }

    #[test]
    fn context_selection_reduces_saves() {
        let k = gups_like();
        let a = analyze(&k).unwrap();
        let basic = a.saved_vars(&a.sites[0], false);
        let opt = a.saved_vars(&a.sites[0], true);
        assert!(vs_len(opt) <= vs_len(basic));
        assert!(!vs_contains(opt, k_var(&k, "acc")), "shared accumulator must not be saved");
    }

    #[test]
    fn while_loop_liveness_is_loop_carried() {
        // b = head; while (b != 0) { x = load b->next(remote); b = x }
        let mut kb = KernelBuilder::new("chase");
        let heads = kb.param_ptr("heads", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let b = kb.var("b");
        let x = kb.var("x");
        let k = kb.build(vec![
            Stmt::Let { var: b, expr: Expr::add(Expr::Param(heads), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))) },
            Stmt::While {
                cond: Expr::Bin(BinOp::I(AluOp::Sne), Box::new(Expr::Var(b)), Box::new(Expr::Imm(0))),
                body: vec![
                    Stmt::Load { var: x, addr: Expr::Var(b), width: Width::W8 },
                    Stmt::Let { var: b, expr: Expr::Var(x) },
                ],
            },
        ]);
        // Wait: Expr::Var(b) as address has no pointer root. Use
        // heads+offset form instead; this test only checks liveness, so
        // rebuild with a rooted address.
        let _ = k;
        let mut kb = KernelBuilder::new("chase2");
        let heads = kb.param_ptr("heads", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let off = kb.var("off");
        let x = kb.var("x");
        let k = kb.build(vec![
            Stmt::Let { var: off, expr: Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3)) },
            Stmt::While {
                cond: Expr::Bin(BinOp::I(AluOp::Sne), Box::new(Expr::Var(off)), Box::new(Expr::Imm(0))),
                body: vec![
                    Stmt::Load { var: x, addr: Expr::add(Expr::Param(heads), Expr::Var(off)), width: Width::W8 },
                    Stmt::Let { var: off, expr: Expr::Var(x) },
                ],
            },
        ]);
        let a = analyze(&k).unwrap();
        assert_eq!(a.sites.len(), 1);
        // off is loop-carried: must be live across the suspension.
        assert!(vs_contains(a.sites[0].live_after, off));
        let _ = heads;
    }

    #[test]
    fn too_many_vars_rejected() {
        let mut kb = KernelBuilder::new("big");
        let n = kb.param_val("n");
        kb.trip(n);
        for i in 0..70 {
            kb.var(&format!("v{i}"));
        }
        let k = kb.build(vec![]);
        assert!(analyze(&k).is_err());
    }

    #[test]
    fn runs_split_at_control_flow() {
        let mut kb = KernelBuilder::new("runs");
        let p = kb.param_ptr("p", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let a = kb.var("a");
        let b = kb.var("b");
        let addr = |v| Expr::add(Expr::Param(p), Expr::shl(Expr::Var(v), Expr::Imm(3)));
        let k = kb.build(vec![
            Stmt::Load { var: a, addr: addr(ITER_VAR), width: Width::W8 },
            Stmt::If {
                cond: Expr::Var(a),
                then_: vec![Stmt::Load { var: b, addr: addr(a), width: Width::W8 }],
                else_: vec![],
            },
        ]);
        let an = analyze(&k).unwrap();
        assert_eq!(an.sites.len(), 2);
        assert_ne!(an.sites[0].run, an.sites[1].run, "sites in different basic blocks");
    }
}
