//! Request coalescing (paper §III-C).
//!
//! Finds, inside each straight-line run ("basic block" in the paper's
//! terms), groups of remote loads that can be issued together before a
//! single yield:
//!
//!  1. **Coarse-grained**: accesses at constant address deltas within one
//!     region merge into a single wide `aload` (up to 4 KB, granularity in
//!     the high address bits).
//!  2. **Independent (`aset`)**: loads with no data dependence are issued
//!     back-to-back and bound to one id with `aset id, n`; the id
//!     completes only when all constituents have.
//!
//! The merge must preserve data dependencies, memory consistency and
//! side-effect barriers, and respect the hardware group-size limit — a
//! greedy per-run scan, exactly the "simple greedy algorithm inside each
//! basic block" the paper describes.

use super::analysis::{Analysis, SiteKind, VarSet};
use super::ast::{BinOp, Expr};
use crate::ir::AluOp;

pub const LINE: u32 = 64;

#[derive(Debug, Clone, PartialEq)]
pub enum GroupKind {
    /// One wide aload covering `span_bytes` starting `base_delta` bytes
    /// from the leader's address (base_delta <= 0).
    Coarse { span_bytes: u32, base_delta: i64 },
    /// `aset`-bound independent requests, one per member.
    Set,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Group {
    pub kind: GroupKind,
    /// Site ids, in program order; `members[0]` is the leader.
    pub members: Vec<usize>,
    /// SPM byte offset of each member's data within the id's slot.
    pub spm_offs: Vec<u32>,
    /// Total SPM slot footprint for this group, line-aligned.
    pub slot_bytes: u32,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Role {
    /// Not coalesced: one request, one yield.
    Single,
    /// First site of a group: issues all requests, yields once.
    Leader(usize),
    /// Later member: data already in SPM, no request, no yield.
    Member { group: usize, index: usize },
}

#[derive(Debug, Clone, Default)]
pub struct CoalescePlan {
    pub roles: Vec<Role>,
    pub groups: Vec<Group>,
}

impl CoalescePlan {
    /// Plan with no coalescing (basic codegen / CoroAMU-S & -D).
    pub fn disabled(nsites: usize) -> Self {
        CoalescePlan { roles: vec![Role::Single; nsites], groups: Vec::new() }
    }

    /// Max SPM slot bytes any site group requires (>= one line).
    pub fn max_slot_bytes(&self) -> u32 {
        self.groups.iter().map(|g| g.slot_bytes).max().unwrap_or(LINE).max(LINE)
    }

    /// Number of yields removed relative to one-yield-per-site.
    pub fn switches_saved(&self) -> usize {
        self.groups.iter().map(|g| g.members.len() - 1).sum()
    }
}

/// Decompose an expression into (sorted canonical non-constant terms,
/// constant sum) over `+`. Two addresses merge coarsely iff their
/// non-constant parts match.
fn split_const(e: &Expr, terms: &mut Vec<String>, konst: &mut i64) {
    match e {
        Expr::Imm(v) => *konst += v,
        Expr::Bin(BinOp::I(AluOp::Add), a, b) => {
            split_const(a, terms, konst);
            split_const(b, terms, konst);
        }
        other => terms.push(format!("{other:?}")),
    }
}

/// If `a` and `b` differ only by an additive constant, return `delta(b - a)`.
pub fn const_delta(a: &Expr, b: &Expr) -> Option<i64> {
    let (mut ta, mut ka) = (Vec::new(), 0i64);
    let (mut tb, mut kb) = (Vec::new(), 0i64);
    split_const(a, &mut ta, &mut ka);
    split_const(b, &mut tb, &mut kb);
    ta.sort();
    tb.sort();
    (ta == tb).then_some(kb - ka)
}

fn align_up(x: u32, a: u32) -> u32 {
    x.div_ceil(a) * a
}

/// Full §III-C planning: coarse merges up to the 4 KB hardware granularity
/// plus cross-object `aset` groups.
pub fn plan(analysis: &Analysis, max_group: usize, max_coarse_bytes: u32) -> CoalescePlan {
    plan_impl(analysis, max_group, max_coarse_bytes, true)
}

/// Object/line-granular grouping only: adjacent constant-delta loads within
/// one cache line suspend once. This is NOT the §III-C optimization — it is
/// the baseline suspension granularity every practical coroutine runtime
/// has (a 64B record is one prefetch/aload, its field loads are plain) and
/// applies to basic codegen of all variants.
pub fn plan_line_granular(analysis: &Analysis) -> CoalescePlan {
    plan_impl(analysis, 8, LINE, false)
}

fn plan_impl(analysis: &Analysis, max_group: usize, max_coarse_bytes: u32, allow_set: bool) -> CoalescePlan {
    let sites = &analysis.sites;
    let mut roles = vec![Role::Single; sites.len()];
    let mut groups: Vec<Group> = Vec::new();
    if max_group < 2 {
        return CoalescePlan { roles, groups };
    }

    let mut i = 0;
    while i < sites.len() {
        let leader = &sites[i];
        if leader.kind != SiteKind::LoadRemote {
            i += 1;
            continue;
        }
        // Extend greedily.
        let mut members = vec![i];
        let mut blockers: VarSet = leader.def.map(|v| 1u64 << v).unwrap_or(0) | leader.defs_after;
        let mut barrier = leader.barrier_after;
        // Candidate deltas for coarse mode (relative to leader).
        let mut deltas: Vec<Option<i64>> = vec![Some(0)];
        let mut j = i + 1;
        while j < sites.len() && members.len() < max_group {
            let cand = &sites[j];
            let ok = cand.kind == SiteKind::LoadRemote
                && cand.run == leader.run
                && !barrier
                && cand.addr_deps & blockers == 0;
            if !ok {
                break;
            }
            let delta = const_delta(&leader.addr, &cand.addr);
            if !allow_set {
                // Line-granular mode: only same-object constant deltas
                // whose span stays within one line extend the group.
                let within = match delta {
                    Some(d) => {
                        let lo = deltas.iter().flatten().chain([&d]).min().copied().unwrap_or(0);
                        let hi = deltas.iter().flatten().chain([&d]).max().copied().unwrap_or(0);
                        (hi + cand.width.bytes() as i64 - lo) <= max_coarse_bytes as i64
                    }
                    None => false,
                };
                if !within {
                    break;
                }
            }
            deltas.push(delta);
            members.push(j);
            blockers |= cand.def.map(|v| 1u64 << v).unwrap_or(0) | cand.defs_after;
            barrier |= cand.barrier_after;
            j += 1;
        }
        if members.len() < 2 {
            i += 1;
            continue;
        }
        // Coarse if every member has a constant delta to the leader and the
        // span fits the hardware granularity limit.
        let coarse = if deltas.iter().all(|d| d.is_some()) {
            let ds: Vec<i64> = deltas.iter().map(|d| d.unwrap()).collect();
            let min_d = *ds.iter().min().unwrap();
            let max_idx = ds
                .iter()
                .enumerate()
                .max_by_key(|(_, d)| **d)
                .map(|(k, _)| k)
                .unwrap();
            let max_end = ds[max_idx] + sites[members[max_idx]].width.bytes() as i64;
            let span = (max_end - min_d) as u32;
            (span <= max_coarse_bytes).then_some((ds, min_d, span))
        } else {
            None
        };
        let gid = groups.len();
        let group = match coarse {
            Some((ds, min_d, span)) => {
                let spm_offs: Vec<u32> = ds.iter().map(|d| (d - min_d) as u32).collect();
                Group {
                    kind: GroupKind::Coarse { span_bytes: span, base_delta: min_d },
                    members: members.clone(),
                    spm_offs,
                    slot_bytes: align_up(span, LINE),
                }
            }
            None => {
                let spm_offs: Vec<u32> = (0..members.len() as u32).map(|k| k * LINE).collect();
                Group {
                    kind: GroupKind::Set,
                    members: members.clone(),
                    spm_offs,
                    slot_bytes: members.len() as u32 * LINE,
                }
            }
        };
        roles[members[0]] = Role::Leader(gid);
        for (idx, m) in members.iter().enumerate().skip(1) {
            roles[*m] = Role::Member { group: gid, index: idx };
        }
        groups.push(group);
        i = j;
    }
    CoalescePlan { roles, groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::analysis::analyze;
    use crate::compiler::ast::*;
    use crate::ir::{AddrSpace::*, Width};

    fn e_add(a: Expr, b: Expr) -> Expr {
        Expr::add(a, b)
    }

    /// tuples[i].key and tuples[i].payload: constant delta 8 -> coarse.
    fn coarse_kernel() -> Kernel {
        let mut kb = KernelBuilder::new("coarse");
        let t = kb.param_ptr("tuples", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let k = kb.var("k");
        let p = kb.var("p");
        let s = kb.var("s");
        let base = e_add(Expr::Param(t), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(4)));
        kb.build(vec![
            Stmt::Load { var: k, addr: base.clone(), width: Width::W8 },
            Stmt::Load { var: p, addr: e_add(base, Expr::Imm(8)), width: Width::W8 },
            Stmt::Let { var: s, expr: e_add(Expr::Var(k), Expr::Var(p)) },
            Stmt::Store { val: Expr::Var(s), addr: e_add(Expr::Param(t), Expr::Imm(0)), width: Width::W8 },
        ])
    }

    #[test]
    fn coarse_merge_found() {
        let k = coarse_kernel();
        let a = analyze(&k).unwrap();
        let p = plan(&a, 8, 4096);
        assert_eq!(p.groups.len(), 1);
        let g = &p.groups[0];
        assert_eq!(g.members, vec![0, 1]);
        match g.kind {
            GroupKind::Coarse { span_bytes, base_delta } => {
                assert_eq!(span_bytes, 16);
                assert_eq!(base_delta, 0);
            }
            _ => panic!("expected coarse, got {:?}", g.kind),
        }
        assert_eq!(g.spm_offs, vec![0, 8]);
        assert_eq!(p.roles[0], Role::Leader(0));
        assert_eq!(p.roles[1], Role::Member { group: 0, index: 1 });
        assert_eq!(p.switches_saved(), 1);
    }

    /// b[i] and c[i]: different pointer roots, independent -> aset group.
    fn set_kernel() -> Kernel {
        let mut kb = KernelBuilder::new("setk");
        let bp = kb.param_ptr("b", Remote);
        let cp = kb.param_ptr("c", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let x = kb.var("x");
        let y = kb.var("y");
        let z = kb.var("z");
        let idx = Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3));
        kb.build(vec![
            Stmt::Load { var: x, addr: e_add(Expr::Param(bp), idx.clone()), width: Width::W8 },
            Stmt::Load { var: y, addr: e_add(Expr::Param(cp), idx), width: Width::W8 },
            Stmt::Let { var: z, expr: e_add(Expr::Var(x), Expr::Var(y)) },
        ])
    }

    #[test]
    fn independent_loads_form_aset_group() {
        let k = set_kernel();
        let a = analyze(&k).unwrap();
        let p = plan(&a, 8, 4096);
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].kind, GroupKind::Set);
        assert_eq!(p.groups[0].slot_bytes, 128);
        assert_eq!(p.groups[0].spm_offs, vec![0, 64]);
    }

    /// ht[hash(key)] depends on loaded key: must NOT merge.
    #[test]
    fn dependent_loads_not_merged() {
        let mut kb = KernelBuilder::new("dep");
        let t = kb.param_ptr("t", Remote);
        let h = kb.param_ptr("h", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let key = kb.var("key");
        let v = kb.var("v");
        let k = kb.build(vec![
            Stmt::Load {
                var: key,
                addr: e_add(Expr::Param(t), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3))),
                width: Width::W8,
            },
            Stmt::Load {
                var: v,
                addr: e_add(Expr::Param(h), Expr::shl(Expr::Var(key), Expr::Imm(3))),
                width: Width::W8,
            },
        ]);
        let a = analyze(&k).unwrap();
        let p = plan(&a, 8, 4096);
        assert!(p.groups.is_empty(), "dependent loads merged: {:?}", p.groups);
    }

    #[test]
    fn group_size_respects_hardware_limit() {
        let mut kb = KernelBuilder::new("many");
        let ps: Vec<_> = (0..6).map(|i| kb.param_ptr(&format!("p{i}"), Remote)).collect();
        let n = kb.param_val("n");
        kb.trip(n);
        let vs: Vec<_> = (0..6).map(|i| kb.var(&format!("v{i}"))).collect();
        let idx = || Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3));
        let body: Vec<Stmt> = (0..6)
            .map(|i| Stmt::Load { var: vs[i], addr: e_add(Expr::Param(ps[i]), idx()), width: Width::W8 })
            .collect();
        let k = kb.build(body);
        let a = analyze(&k).unwrap();
        let p = plan(&a, 4, 4096);
        assert_eq!(p.groups.len(), 2, "6 loads with max_group=4 -> groups of 4 and 2");
        assert_eq!(p.groups[0].members.len(), 4);
        assert_eq!(p.groups[1].members.len(), 2);
    }

    #[test]
    fn coarse_span_limit_falls_back_to_set() {
        let mut kb = KernelBuilder::new("far_apart");
        let t = kb.param_ptr("t", Remote);
        let n = kb.param_val("n");
        kb.trip(n);
        let x = kb.var("x");
        let y = kb.var("y");
        let base = e_add(Expr::Param(t), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3)));
        let k = kb.build(vec![
            Stmt::Load { var: x, addr: base.clone(), width: Width::W8 },
            Stmt::Load { var: y, addr: e_add(base, Expr::Imm(1 << 20)), width: Width::W8 },
        ]);
        let a = analyze(&k).unwrap();
        let p = plan(&a, 8, 4096);
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].kind, GroupKind::Set, "1MB apart cannot be a coarse fetch");
    }

    #[test]
    fn const_delta_matches_structure() {
        let a = e_add(Expr::Param(0), Expr::Var(1));
        let b = e_add(e_add(Expr::Param(0), Expr::Imm(24)), Expr::Var(1));
        assert_eq!(const_delta(&a, &b), Some(24));
        let c = e_add(Expr::Param(1), Expr::Var(1));
        assert_eq!(const_delta(&a, &c), None);
    }

    #[test]
    fn disabled_plan_is_all_single() {
        let p = CoalescePlan::disabled(5);
        assert_eq!(p.roles.len(), 5);
        assert!(p.roles.iter().all(|r| *r == Role::Single));
        assert_eq!(p.max_slot_bytes(), LINE);
    }
}
