// Coroutine lowering — the body of AsyncSplitPass. This file is
// `include!`d by codegen.rs (same scope); it holds the `Lower` impl that
// emits the Fig. 6 runtime skeleton plus the per-variant schedulers.

impl<'a> Lower<'a> {
    fn slot_of_var(&self, v: VarId) -> i64 {
        let base = match &self.callee_params {
            Some(ps) => CTX_VARS + 8 * ps.len() as i64,
            None => CTX_VARS,
        };
        base + 8 * v as i64
    }

    /// Frame slot of kernel parameter `p` (basic codegen only).
    fn slot_of_param(&self, p: usize) -> i64 {
        CTX_VARS + 8 * (self.kernel.nvars as i64 + p as i64)
    }

    fn reg_of_var(&self, v: VarId) -> Reg {
        match &self.callee_vars {
            Some(vs) => vs[v as usize],
            None => self.var_reg[v as usize],
        }
    }

    fn reg_of_param(&self, p: ParamId) -> Reg {
        match &self.callee_params {
            Some(ps) => ps[p as usize],
            None => self.param_regs[p as usize],
        }
    }

    fn params(&self) -> &[Param] {
        match self.callee_kernel {
            Some(ck) => &self.kernel.callees[ck].params,
            None => &self.kernel.params,
        }
    }

    fn expr(&mut self, e: &Expr) -> Operand {
        match e {
            Expr::Imm(v) => Imm(*v),
            Expr::FImm(f) => Imm(f.to_bits() as i64),
            Expr::Var(v) => R(self.reg_of_var(*v)),
            Expr::Param(p) => R(self.reg_of_param(*p)),
            Expr::Bin(op, a, b) => {
                let ra = self.expr(a);
                let rb = self.expr(b);
                let dst = match op {
                    BinOp::I(o) => self.b.alu(*o, ra, rb),
                    BinOp::F(o) => self.b.falu(*o, ra, rb),
                };
                R(dst)
            }
        }
    }

    /// Materialize an expression into a register (immediates too).
    fn expr_reg(&mut self, e: &Expr) -> Reg {
        match self.expr(e) {
            R(r) => r,
            v @ Imm(_) => {
                let r = self.b.reg();
                self.b.mov(r, v);
                r
            }
        }
    }

    /// spm slot address for the current id: spm_base + cur_id * slot_bytes.
    fn spm_slot_addr(&mut self) -> Reg {
        let off = self.b.alu(AluOp::Mul, R(self.cur_id), Imm(self.slot_bytes as i64));
        self.b.alu(AluOp::Add, R(self.spm_base), R(off))
    }

    /// Emit the context save / request issue / reschedule / restore
    /// sequence around one suspension. `save` is the variable set to
    /// spill; `temps` are (ctx-slot, reg) pairs saved and restored in
    /// place. `issue` emits the decoupled request(s), given the resume
    /// block. Control continues in a fresh Compute block on return.
    fn yield_site(
        &mut self,
        what: &str,
        save: VarSet,
        temps: &[(i64, Reg)],
        issue: impl FnOnce(&mut Self, BlockId),
    ) {
        let save_bb = self.b.new_block(format!("{what}.save"), CodeTag::CtxSwitch);
        let resume_bb = self.b.new_block(format!("{what}.resume"), CodeTag::CtxSwitch);
        let cont_bb = self.b.new_block(format!("{what}.cont"), CodeTag::Compute);
        self.b.jmp(save_bb);
        self.b.switch_to(save_bb);
        // Spill live variables into the handler context.
        for v in vs_iter(save) {
            let slot = self.slot_of_var(v);
            let r = self.reg_of_var(v);
            self.b.store(R(r), R(self.ctx), slot, Width::W8, AddrSpace::Local);
        }
        for (slot, r) in temps {
            self.b.store(R(*r), R(self.ctx), *slot, Width::W8, AddrSpace::Local);
        }
        if self.opts.generic_frame {
            // C++20-framework frame bookkeeping: promise state + frame ptr.
            let fs = self.ctx_bytes as i64 - 16;
            self.b.store(Imm(1), R(self.ctx), fs, Width::W8, AddrSpace::Local);
            self.b.store(R(self.cur_id), R(self.ctx), fs + 8, Width::W8, AddrSpace::Local);
            let t = self.b.alu(AluOp::Add, R(self.ctx), Imm(64));
            let _ = self.b.alu(AluOp::And, R(t), Imm(-64));
        }
        if matches!(self.opts.sched, SchedKind::StaticFifo | SchedKind::Getfin) {
            // Software-maintained resumption target (§III-D: bafin removes
            // this store — the target rides in the request instead).
            self.b.store(Imm(resume_bb as i64), R(self.ctx), CTX_RESUME, Width::W8, AddrSpace::Local);
        }
        issue(self, resume_bb);
        if self.opts.sched == SchedKind::StaticFifo {
            // FIFO push: queue[tail & mask] = cur_id; tail += 1.
            let idx = self.b.alu(AluOp::And, R(self.fifo_tail), Imm(self.fifo_mask));
            let off = self.b.alu(AluOp::Shl, R(idx), Imm(3));
            let slot = self.b.alu(AluOp::Add, R(self.fifo_base), R(off));
            self.b.store(R(self.cur_id), R(slot), 0, Width::W8, AddrSpace::Local);
            self.b.alu_into(self.fifo_tail, AluOp::Add, R(self.fifo_tail), Imm(1));
            // Static scheduling launches breadth-first: go through the
            // launch block so all tasks start before the first resume
            // (prefetch distance = concurrency).
            self.b.jmp(self.launch_bb);
        } else {
            // Dynamic scheduling: poll immediately; the scheduler falls
            // through to the launch/drain logic only when idle (Fig. 7).
            self.b.jmp(self.sched_bb);
        }

        // Resume path: reload the context.
        self.b.switch_to(resume_bb);
        for v in vs_iter(save) {
            let slot = self.slot_of_var(v);
            let r = self.reg_of_var(v);
            self.b.load_into(r, R(self.ctx), slot, Width::W8, AddrSpace::Local);
        }
        for (slot, r) in temps {
            self.b.load_into(*r, R(self.ctx), *slot, Width::W8, AddrSpace::Local);
        }
        if let Some(ps) = &self.callee_params {
            // Nested coroutine: argument registers are clobbered by other
            // tasks; reload them from the child's arg slots.
            let ps = ps.clone();
            for (k, pr) in ps.iter().enumerate() {
                self.b.load_into(*pr, R(self.ctx), CTX_VARS + 8 * k as i64, Width::W8, AddrSpace::Local);
            }
        } else if self.spill_params {
            // Basic codegen keeps captured values in the frame: reload the
            // parameters it framed at launch (context selection removes
            // these loads entirely, Fig. 15).
            for p in 0..self.param_regs.len() {
                let slot = self.slot_of_param(p);
                self.b.load_into(self.param_regs[p], R(self.ctx), slot, Width::W8, AddrSpace::Local);
            }
        }
        if self.opts.generic_frame {
            let fs = self.ctx_bytes as i64 - 16;
            let a = self.b.load(R(self.ctx), fs, Width::W8, AddrSpace::Local);
            let b2 = self.b.load(R(self.ctx), fs + 8, Width::W8, AddrSpace::Local);
            let _ = self.b.alu(AluOp::Add, R(a), R(b2));
        }
        self.b.jmp(cont_bb);
        self.b.switch_to(cont_bb);
    }

    /// Saved-variable set for a site under the active context policy.
    fn save_set(&self, site_idx: usize) -> VarSet {
        let site = &self.an.sites[site_idx];
        self.an.saved_vars(site, self.opts.context_opt && !self.opts.generic_frame)
    }

    // -----------------------------------------------------------------
    // Site lowering
    // -----------------------------------------------------------------

    fn lower_load_site(&mut self, var: VarId, addr: &Expr, width: Width) {
        let site_idx = self.next_site;
        self.next_site += 1;
        let role = self.plan.roles.get(site_idx).cloned().unwrap_or(Role::Single);
        let save = self.save_set(site_idx);
        let dst = self.reg_of_var(var);
        match (self.opts.sched, role) {
            (SchedKind::StaticFifo, Role::Single) => {
                let a = self.expr_reg(addr);
                self.b.push(Inst::Prefetch { base: R(a), off: 0, space: AddrSpace::Remote });
                self.yield_site("ld", save, &[(CTX_ADDR, a)], |_, _| {});
                self.b.load_into(dst, R(a), 0, width, AddrSpace::Remote);
            }
            (SchedKind::StaticFifo, Role::Leader(g)) => {
                // Prefetch the whole group, one yield.
                let a = self.expr_reg(addr);
                let group = self.plan.groups[g].clone();
                match group.kind {
                    GroupKind::Coarse { span_bytes, base_delta } => {
                        let mut off = base_delta;
                        while off < base_delta + span_bytes as i64 {
                            self.b.push(Inst::Prefetch { base: R(a), off, space: AddrSpace::Remote });
                            off += coalesce::LINE as i64;
                        }
                    }
                    GroupKind::Set => {
                        // Member addresses are group-safe: evaluate now.
                        let member_addrs: Vec<Expr> = group.members[1..]
                            .iter()
                            .map(|m| self.an.sites[*m].addr.clone())
                            .collect();
                        self.b.push(Inst::Prefetch { base: R(a), off: 0, space: AddrSpace::Remote });
                        for ma in &member_addrs {
                            let mr = self.expr_reg(ma);
                            self.b.push(Inst::Prefetch { base: R(mr), off: 0, space: AddrSpace::Remote });
                        }
                    }
                }
                self.yield_site("ldg", save, &[(CTX_ADDR, a)], |_, _| {});
                self.b.load_into(dst, R(a), 0, width, AddrSpace::Remote);
            }
            (SchedKind::StaticFifo, Role::Member { .. }) => {
                // Demand access; the leader already prefetched it.
                let a = self.expr_reg(addr);
                self.b.load_into(dst, R(a), 0, width, AddrSpace::Remote);
            }
            (_, Role::Single) => {
                let a = self.expr_reg(addr);
                let cur = self.cur_id;
                self.yield_site("ld", save, &[], move |lw, resume| {
                    lw.b.push(Inst::Aload {
                        id: R(cur),
                        base: R(a),
                        off: 0,
                        bytes: width.bytes(),
                        spm_off: 0,
                        resume,
                    });
                });
                let sa = self.spm_slot_addr();
                self.b.load_into(dst, R(sa), 0, width, AddrSpace::Spm);
            }
            (_, Role::Leader(g)) => {
                let a = self.expr_reg(addr);
                let group = self.plan.groups[g].clone();
                let cur = self.cur_id;
                match group.kind {
                    GroupKind::Coarse { span_bytes, base_delta } => {
                        self.yield_site("ldc", save, &[], move |lw, resume| {
                            lw.b.push(Inst::Aload {
                                id: R(cur),
                                base: R(a),
                                off: base_delta,
                                bytes: span_bytes,
                                spm_off: 0,
                                resume,
                            });
                        });
                    }
                    GroupKind::Set => {
                        let member_addrs: Vec<(Reg, u32, u32)> = group.members[1..]
                            .iter()
                            .zip(group.spm_offs[1..].iter())
                            .map(|(m, so)| {
                                let site = self.an.sites[*m].clone();
                                let r = self.expr_reg(&site.addr);
                                (r, site.width.bytes(), *so)
                            })
                            .collect();
                        let n = group.members.len() as i64;
                        self.b.push(Inst::Aset { id: R(cur), n: Imm(n) });
                        self.yield_site("lds", save, &[], move |lw, resume| {
                            lw.b.push(Inst::Aload {
                                id: R(cur),
                                base: R(a),
                                off: 0,
                                bytes: width.bytes(),
                                spm_off: 0,
                                resume,
                            });
                            for (mr, mb, so) in member_addrs {
                                lw.b.push(Inst::Aload {
                                    id: R(cur),
                                    base: R(mr),
                                    off: 0,
                                    bytes: mb,
                                    spm_off: so,
                                    resume,
                                });
                            }
                        });
                    }
                }
                let sa = self.spm_slot_addr();
                self.b.load_into(dst, R(sa), group.spm_offs[0] as i64, width, AddrSpace::Spm);
            }
            (_, Role::Member { group, index }) => {
                // Data already fetched by the leader: read straight out of
                // the SPM slot, no request, no switch.
                let off = self.plan.groups[group].spm_offs[index] as i64;
                let sa = self.spm_slot_addr();
                self.b.load_into(dst, R(sa), off, width, AddrSpace::Spm);
            }
        }
    }

    fn lower_store_site(&mut self, val: &Expr, addr: &Expr, width: Width) {
        let site_idx = self.next_site;
        self.next_site += 1;
        match self.opts.sched {
            SchedKind::StaticFifo => {
                // Remote stores drain through the write buffer; static
                // coroutines do not yield on them.
                let v = self.expr(val);
                let a = self.expr(addr);
                self.b.store(v, a, 0, width, AddrSpace::Remote);
            }
            _ => {
                let save = self.save_set(site_idx);
                let v = self.expr(val);
                let a = self.expr_reg(addr);
                let sa = self.spm_slot_addr();
                self.b.store(v, R(sa), 0, width, AddrSpace::Spm);
                let cur = self.cur_id;
                self.yield_site("st", save, &[], move |lw, resume| {
                    lw.b.push(Inst::Astore {
                        id: R(cur),
                        base: R(a),
                        off: 0,
                        bytes: width.bytes(),
                        spm_off: 0,
                        resume,
                    });
                });
            }
        }
    }

    /// §III-E: remote atomics under dynamic scheduling become an
    /// await/asignal lock hand-off procedure (Fig. 8).
    fn lower_atomic_site(&mut self, op: AluOp, old: Option<VarId>, addr: &Expr, val: &Expr, width: Width) {
        let site_idx = self.next_site;
        self.next_site += 1;
        let save = self.save_set(site_idx);
        match self.opts.sched {
            SchedKind::StaticFifo => {
                let a = self.expr_reg(addr);
                let v = self.expr_reg(val);
                self.b.push(Inst::Prefetch { base: R(a), off: 0, space: AddrSpace::Remote });
                self.yield_site("at", save, &[(CTX_ADDR, a), (CTX_VAL, v)], |_, _| {});
                let dst = old.map(|o| self.reg_of_var(o)).unwrap_or_else(|| self.b.reg());
                self.b.push(Inst::AtomicRmw { op, dst, val: R(v), base: R(a), off: 0, width, space: AddrSpace::Remote });
            }
            _ => {
                let a = self.expr_reg(addr);
                let v = self.expr_reg(val);
                // --- acquire ---
                let h0 = self.b.alu(AluOp::Hash, R(a), Imm(0));
                let h = self.b.alu(AluOp::And, R(h0), Imm(self.lock_entries as i64 - 1));
                let hoff = self.b.alu(AluOp::Shl, R(h), Imm(4));
                let le = self.b.alu(AluOp::Add, R(self.lock_base), R(hoff));
                let owned = self.b.load(R(le), 0, Width::W8, AddrSpace::Local);
                let take_bb = self.b.new_block("at.take", CodeTag::Lifecycle);
                let wait_bb = self.b.new_block("at.wait", CodeTag::Lifecycle);
                let locked_bb = self.b.new_block("at.locked", CodeTag::Lifecycle);
                let free = self.b.alu(AluOp::Seq, R(owned), Imm(0));
                self.b.br(R(free), take_bb, wait_bb);
                self.b.switch_to(take_bb);
                self.b.store(Imm(1), R(le), 0, Width::W8, AddrSpace::Local);
                self.b.jmp(locked_bb);
                // wait: push self on the LIFO waiter stack, sleep via await.
                self.b.switch_to(wait_bb);
                let sh = self.b.load(R(le), 8, Width::W8, AddrSpace::Local);
                let woff = self.b.alu(AluOp::Shl, R(self.cur_id), Imm(3));
                let wslot = self.b.alu(AluOp::Add, R(self.waiters_base), R(woff));
                self.b.store(R(sh), R(wslot), 0, Width::W8, AddrSpace::Local);
                self.b.store(R(self.cur_id), R(le), 8, Width::W8, AddrSpace::Local);
                let cur = self.cur_id;
                self.yield_site("at.acq", save, &[(CTX_ADDR, a), (CTX_VAL, v)], move |lw, resume| {
                    lw.b.push(Inst::Await { id: R(cur), resume });
                });
                // Ownership was handed off to us by asignal.
                self.b.jmp(locked_bb);
                self.b.switch_to(locked_bb);
                // --- critical section: aload, modify in SPM, astore ---
                self.yield_site("at.ld", save, &[(CTX_ADDR, a), (CTX_VAL, v)], move |lw, resume| {
                    lw.b.push(Inst::Aload { id: R(cur), base: R(a), off: 0, bytes: width.bytes(), spm_off: 0, resume });
                });
                let sa = self.spm_slot_addr();
                let oldr = old.map(|o| self.reg_of_var(o)).unwrap_or_else(|| self.b.reg());
                self.b.load_into(oldr, R(sa), 0, width, AddrSpace::Spm);
                let nv = self.b.alu(op, R(oldr), R(v));
                self.b.store(R(nv), R(sa), 0, width, AddrSpace::Spm);
                let mut save2 = save;
                if let Some(o) = old {
                    analysis::vs_insert(&mut save2, o);
                }
                self.yield_site("at.st", save2, &[(CTX_ADDR, a)], move |lw, resume| {
                    lw.b.push(Inst::Astore { id: R(cur), base: R(a), off: 0, bytes: width.bytes(), spm_off: 0, resume });
                });
                // --- release: hand off or unlock ---
                let h0b = self.b.alu(AluOp::Hash, R(a), Imm(0));
                let hb = self.b.alu(AluOp::And, R(h0b), Imm(self.lock_entries as i64 - 1));
                let hoffb = self.b.alu(AluOp::Shl, R(hb), Imm(4));
                let leb = self.b.alu(AluOp::Add, R(self.lock_base), R(hoffb));
                let w = self.b.load(R(leb), 8, Width::W8, AddrSpace::Local);
                let handoff_bb = self.b.new_block("at.handoff", CodeTag::Lifecycle);
                let unlock_bb = self.b.new_block("at.unlock", CodeTag::Lifecycle);
                let after_bb = self.b.new_block("at.after", CodeTag::Compute);
                let none = self.b.alu(AluOp::Seq, R(w), Imm(FREE_SENTINEL));
                self.b.br(R(none), unlock_bb, handoff_bb);
                self.b.switch_to(handoff_bb);
                let woff2 = self.b.alu(AluOp::Shl, R(w), Imm(3));
                let wslot2 = self.b.alu(AluOp::Add, R(self.waiters_base), R(woff2));
                let nw = self.b.load(R(wslot2), 0, Width::W8, AddrSpace::Local);
                self.b.store(R(nw), R(leb), 8, Width::W8, AddrSpace::Local);
                self.b.push(Inst::Asignal { id: R(w) });
                self.b.jmp(after_bb);
                self.b.switch_to(unlock_bb);
                self.b.store(Imm(0), R(leb), 0, Width::W8, AddrSpace::Local);
                self.b.jmp(after_bb);
                self.b.switch_to(after_bb);
            }
        }
    }

    /// §III-F nested coroutine call (non-inlined, AMU schedulers only).
    fn lower_call_site(&mut self, callee: usize, args: &[Expr], ret: Option<VarId>) {
        assert!(self.opts.sched.uses_amu(), "nested calls require AMU scheduling");
        assert!(self.callee_params.is_none(), "only one nesting level supported");
        let entry = self.callee_entries[callee];
        // Evaluate arguments, then store them into the child's arg slots.
        let argv: Vec<Reg> = args.iter().map(|a| self.expr_reg(a)).collect();
        let child = self.b.alu(AluOp::Add, R(self.cur_id), Imm(self.num_tasks as i64));
        let coff = self.b.alu(AluOp::Mul, R(child), Imm(self.ctx_bytes as i64));
        let cctx = self.b.alu(AluOp::Add, R(self.handler_base), R(coff));
        for (k, ar) in argv.iter().enumerate() {
            self.b.store(R(*ar), R(cctx), CTX_VARS + 8 * k as i64, Width::W8, AddrSpace::Local);
        }
        if self.opts.sched == SchedKind::Getfin {
            // Software resume target for the child's first dispatch.
            self.b.store(Imm(entry as i64), R(cctx), CTX_RESUME, Width::W8, AddrSpace::Local);
        }
        // Caller hangs; child registered + signalled ready.
        let live = self.call_live_sets[callee];
        let cur = self.cur_id;
        let childr = child;
        self.yield_site("call", live, &[], move |lw, resume| {
            lw.b.push(Inst::Await { id: R(cur), resume });
            lw.b.push(Inst::Await { id: R(childr), resume: entry });
            lw.b.push(Inst::Asignal { id: R(childr) });
        });
        // Caller resumed: fetch the return value from the child context.
        if let Some(rv) = ret {
            let coff2 = self.b.alu(AluOp::Add, R(self.cur_id), Imm(self.num_tasks as i64));
            let coff3 = self.b.alu(AluOp::Mul, R(coff2), Imm(self.ctx_bytes as i64));
            let cctx2 = self.b.alu(AluOp::Add, R(self.handler_base), R(coff3));
            self.b.load_into(self.reg_of_var(rv), R(cctx2), CTX_VAL, Width::W8, AddrSpace::Local);
        }
    }

    // -----------------------------------------------------------------
    // Statement walk (must mirror the analysis DFS order exactly)
    // -----------------------------------------------------------------

    fn space_of(&self, addr: &Expr) -> AddrSpace {
        analysis::stmt_space(addr, self.params()).map(|(s, _)| s).unwrap_or(AddrSpace::Local)
    }

    fn stmts(&mut self, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            match s {
                Stmt::Let { var, expr } => {
                    let v = self.expr(expr);
                    let r = self.reg_of_var(*var);
                    self.b.mov(r, v);
                }
                Stmt::Load { var, addr, width } => {
                    if self.space_of(addr) == AddrSpace::Remote {
                        self.lower_load_site(*var, addr, *width);
                    } else {
                        let a = self.expr(addr);
                        let r = self.reg_of_var(*var);
                        self.b.load_into(r, a, 0, *width, AddrSpace::Local);
                    }
                }
                Stmt::Store { val, addr, width } => {
                    if self.space_of(addr) == AddrSpace::Remote {
                        self.lower_store_site(val, addr, *width);
                    } else {
                        let v = self.expr(val);
                        let a = self.expr(addr);
                        self.b.store(v, a, 0, *width, AddrSpace::Local);
                    }
                }
                Stmt::AtomicRmw { op, old, addr, val, width } => {
                    if self.space_of(addr) == AddrSpace::Remote {
                        self.lower_atomic_site(*op, *old, addr, val, *width);
                    } else {
                        let v = self.expr(val);
                        let a = self.expr(addr);
                        let dst = old.map(|o| self.reg_of_var(o)).unwrap_or_else(|| self.b.reg());
                        self.b.push(Inst::AtomicRmw { op: *op, dst, val: v, base: a, off: 0, width: *width, space: AddrSpace::Local });
                    }
                }
                Stmt::If { cond, then_, else_ } => {
                    let c = self.expr(cond);
                    let tb = self.b.new_block("if.then", CodeTag::Compute);
                    let eb = self.b.new_block("if.else", CodeTag::Compute);
                    let jb = self.b.new_block("if.join", CodeTag::Compute);
                    self.b.br(c, tb, eb);
                    self.b.switch_to(tb);
                    self.stmts(then_)?;
                    self.b.jmp(jb);
                    self.b.switch_to(eb);
                    self.stmts(else_)?;
                    self.b.jmp(jb);
                    self.b.switch_to(jb);
                }
                Stmt::While { cond, body } => {
                    let hb = self.b.new_block("wh.head", CodeTag::Compute);
                    let bb = self.b.new_block("wh.body", CodeTag::Compute);
                    let xb = self.b.new_block("wh.exit", CodeTag::Compute);
                    self.b.jmp(hb);
                    self.b.switch_to(hb);
                    let c = self.expr(cond);
                    self.b.br(c, bb, xb);
                    self.b.switch_to(bb);
                    self.stmts(body)?;
                    self.b.jmp(hb);
                    self.b.switch_to(xb);
                }
                Stmt::Call { callee, args, ret } => {
                    self.lower_call_site(*callee, args, *ret);
                }
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Runtime skeleton
    // -----------------------------------------------------------------

    fn emit_coroutine(mut self) -> Result<CompiledKernel> {
        let kernel = self.kernel;
        let uses_amu = self.opts.sched.uses_amu();
        let has_atomics = !self.an.sites.is_empty()
            && self.an.sites.iter().any(|s| s.kind == SiteKind::AtomicRemote);
        if self.opts.generic_frame {
            self.ctx_bytes += 16; // frame/promise slots
        }

        // Split off trailing sequential-variable updates (§III-B case 3):
        // they run serialized in the Return block.
        let mut main_body = kernel.body.clone();
        let mut seq_tail: Vec<Stmt> = Vec::new();
        loop {
            let is_seq = match main_body.last() {
                Some(Stmt::Let { var, .. }) => self.an.class(*var) == VarClass::Sequential,
                _ => false,
            };
            if !is_seq {
                break;
            }
            seq_tail.insert(0, main_body.pop().unwrap());
        }
        for v in 0..kernel.nvars {
            if self.an.class(v) == VarClass::Sequential {
                let written_in_main = {
                    fn writes(stmts: &[Stmt], v: VarId) -> bool {
                        stmts.iter().any(|s| match s {
                            Stmt::Let { var, .. } | Stmt::Load { var, .. } => *var == v,
                            Stmt::AtomicRmw { old: Some(o), .. } => *o == v,
                            Stmt::If { then_, else_, .. } => writes(then_, v) || writes(else_, v),
                            Stmt::While { body, .. } => writes(body, v),
                            Stmt::Call { ret: Some(r), .. } => *r == v,
                            _ => false,
                        })
                    }
                    writes(&main_body, v)
                };
                if written_in_main {
                    bail!(
                        "sequential variable {} is written outside the trailing update tail; \
                         hoisting arbitrary updates is not supported (mark it private or restructure)",
                        kernel.var_names.get(v as usize).cloned().unwrap_or_else(|| format!("v{v}"))
                    );
                }
            }
        }

        // Key blocks (forward references).
        self.launch_bb = self.b.new_block("launch", CodeTag::Lifecycle);
        self.sched_bb = self.b.new_block("sched", CodeTag::Scheduler);
        self.finish_bb = self.b.new_block("finish", CodeTag::Lifecycle);
        self.done_bb = self.b.new_block("done", CodeTag::Lifecycle);
        let body_entry = self.b.new_block("body", CodeTag::Compute);
        // Nested callee entry blocks.
        self.callee_entries = kernel
            .callees
            .iter()
            .map(|_| self.b.new_block("child.entry", CodeTag::CtxSwitch))
            .collect();
        // Live sets at call sites (conservative: every private var).
        let mut call_live: VarSet = 0;
        for v in 0..kernel.nvars {
            if self.an.class(v) == VarClass::Private {
                analysis::vs_insert(&mut call_live, v);
            }
        }
        self.call_live_sets = vec![call_live; kernel.callees.len().max(1)];

        // ---- entry / init (Fig. 6 Alloca + Init blocks) ----
        if uses_amu {
            self.b.push(Inst::Aconfig { base: R(self.handler_base), size: Imm(self.ctx_bytes as i64) });
        }
        self.b.mov(self.next_iter, Imm(0));
        self.b.mov(self.active, Imm(0));
        self.b.mov(self.free_top, Imm(self.num_tasks as i64));
        self.b.mov(self.fifo_head, Imm(0));
        self.b.mov(self.fifo_tail, Imm(0));
        let t = self.b.imm(0);
        let init_loop = self.b.new_block("init.loop", CodeTag::Init);
        let init_body = self.b.new_block("init.body", CodeTag::Init);
        let init_next = self.b.new_block("init.next", CodeTag::Init);
        self.b.jmp(init_loop);
        self.b.switch_to(init_loop);
        let c = self.b.alu(AluOp::Slt, R(t), Imm(self.num_tasks as i64));
        self.b.br(R(c), init_body, init_next);
        self.b.switch_to(init_body);
        let off = self.b.alu(AluOp::Shl, R(t), Imm(3));
        let slot = self.b.alu(AluOp::Add, R(self.free_base), R(off));
        self.b.store(R(t), R(slot), 0, Width::W8, AddrSpace::Local);
        if self.opts.generic_frame {
            // Frame "allocation" touch per task.
            let coff = self.b.alu(AluOp::Mul, R(t), Imm(self.ctx_bytes as i64));
            let cb = self.b.alu(AluOp::Add, R(self.handler_base), R(coff));
            for k in 0..4 {
                self.b.store(Imm(0), R(cb), 8 * k, Width::W8, AddrSpace::Local);
            }
        }
        self.b.alu_into(t, AluOp::Add, R(t), Imm(1));
        self.b.jmp(init_loop);
        self.b.switch_to(init_next);
        if has_atomics && uses_amu {
            let l = self.b.imm(0);
            let lk_loop = self.b.new_block("init.locks", CodeTag::Init);
            let lk_body = self.b.new_block("init.locks.body", CodeTag::Init);
            let lk_done = self.b.new_block("init.locks.done", CodeTag::Init);
            self.b.jmp(lk_loop);
            self.b.switch_to(lk_loop);
            let c2 = self.b.alu(AluOp::Slt, R(l), Imm(self.lock_entries as i64));
            self.b.br(R(c2), lk_body, lk_done);
            self.b.switch_to(lk_body);
            let lo = self.b.alu(AluOp::Shl, R(l), Imm(4));
            let ls = self.b.alu(AluOp::Add, R(self.lock_base), R(lo));
            self.b.store(Imm(0), R(ls), 0, Width::W8, AddrSpace::Local);
            self.b.store(Imm(FREE_SENTINEL), R(ls), 8, Width::W8, AddrSpace::Local);
            self.b.alu_into(l, AluOp::Add, R(l), Imm(1));
            self.b.jmp(lk_loop);
            self.b.switch_to(lk_done);
            self.b.jmp(self.launch_bb);
        } else {
            self.b.jmp(self.launch_bb);
        }

        // ---- launch / drain (Fig. 6 Return block: spawning + recycling) ----
        self.b.switch_to(self.launch_bb);
        let total = self.param_regs[kernel.trip_param as usize];
        let more = self.b.alu(AluOp::Slt, R(self.next_iter), R(total));
        let chk_free = self.b.new_block("launch.free", CodeTag::Lifecycle);
        let do_launch = self.b.new_block("launch.do", CodeTag::Lifecycle);
        let drain = self.b.new_block("drain", CodeTag::Lifecycle);
        self.b.br(R(more), chk_free, drain);
        self.b.switch_to(chk_free);
        let have = self.b.alu(AluOp::Slt, Imm(0), R(self.free_top));
        self.b.br(R(have), do_launch, self.sched_bb);
        self.b.switch_to(do_launch);
        self.b.alu_into(self.free_top, AluOp::Sub, R(self.free_top), Imm(1));
        let foff = self.b.alu(AluOp::Shl, R(self.free_top), Imm(3));
        let fslot = self.b.alu(AluOp::Add, R(self.free_base), R(foff));
        self.b.load_into(self.cur_id, R(fslot), 0, Width::W8, AddrSpace::Local);
        let coff = self.b.alu(AluOp::Mul, R(self.cur_id), Imm(self.ctx_bytes as i64));
        self.b.alu_into(self.ctx, AluOp::Add, R(self.handler_base), R(coff));
        self.b.mov(self.var_reg[ITER_VAR as usize], R(self.next_iter));
        self.b.alu_into(self.next_iter, AluOp::Add, R(self.next_iter), Imm(1));
        self.b.alu_into(self.active, AluOp::Add, R(self.active), Imm(1));
        if self.spill_params {
            // Frame the captured values once per task (stock lowering).
            for p in 0..self.param_regs.len() {
                let slot = self.slot_of_param(p);
                self.b.store(R(self.param_regs[p]), R(self.ctx), slot, Width::W8, AddrSpace::Local);
            }
        }
        self.b.jmp(body_entry);
        self.b.switch_to(drain);
        let empty = self.b.alu(AluOp::Seq, R(self.active), Imm(0));
        self.b.br(R(empty), self.done_bb, self.sched_bb);

        // ---- scheduler ----
        self.b.switch_to(self.sched_bb);
        match self.opts.sched {
            SchedKind::StaticFifo => {
                let pop = self.b.new_block("sched.pop", CodeTag::Scheduler);
                let emptyq = self.b.alu(AluOp::Seq, R(self.fifo_head), R(self.fifo_tail));
                // Empty queue: either drain to done or spin via launch.
                self.b.br(R(emptyq), drain, pop);
                self.b.switch_to(pop);
                let idx = self.b.alu(AluOp::And, R(self.fifo_head), Imm(self.fifo_mask));
                let qoff = self.b.alu(AluOp::Shl, R(idx), Imm(3));
                let qslot = self.b.alu(AluOp::Add, R(self.fifo_base), R(qoff));
                self.b.load_into(self.cur_id, R(qslot), 0, Width::W8, AddrSpace::Local);
                self.b.alu_into(self.fifo_head, AluOp::Add, R(self.fifo_head), Imm(1));
                let hoff = self.b.alu(AluOp::Mul, R(self.cur_id), Imm(self.ctx_bytes as i64));
                self.b.alu_into(self.ctx, AluOp::Add, R(self.handler_base), R(hoff));
                if self.opts.generic_frame {
                    let x = self.b.load(R(self.ctx), self.ctx_bytes as i64 - 16, Width::W8, AddrSpace::Local);
                    let y = self.b.alu(AluOp::Add, R(x), Imm(1));
                    let _ = self.b.alu(AluOp::And, R(y), Imm(7));
                }
                let resume = self.b.load(R(self.ctx), CTX_RESUME, Width::W8, AddrSpace::Local);
                self.b.terminate(Term::IndirectJmp { target: R(resume) });
            }
            SchedKind::Getfin => {
                let got = self.b.new_block("sched.got", CodeTag::Scheduler);
                let id = self.b.reg();
                self.b.push(Inst::Getfin { dst: id });
                let none = self.b.alu(AluOp::Slt, R(id), Imm(0));
                self.b.br(R(none), self.launch_bb, got);
                self.b.switch_to(got);
                self.b.mov(self.cur_id, R(id));
                let hoff = self.b.alu(AluOp::Mul, R(self.cur_id), Imm(self.ctx_bytes as i64));
                self.b.alu_into(self.ctx, AluOp::Add, R(self.handler_base), R(hoff));
                let resume = self.b.load(R(self.ctx), CTX_RESUME, Width::W8, AddrSpace::Local);
                self.b.terminate(Term::IndirectJmp { target: R(resume) });
            }
            SchedKind::Bafin => {
                // Single-instruction poll-and-dispatch: handler address and
                // id come from hardware; jump target from the BTQ (§IV-A).
                self.b.terminate(Term::Bafin {
                    handler_dst: self.ctx,
                    id_dst: self.cur_id,
                    fallthrough: self.launch_bb,
                });
            }
            SchedKind::Serial => unreachable!(),
        }

        // ---- body ----
        self.b.switch_to(body_entry);
        self.stmts(&main_body)?;
        self.b.jmp(self.finish_bb);

        // ---- finish (Return block) ----
        self.b.switch_to(self.finish_bb);
        self.stmts(&seq_tail)?;
        let foff2 = self.b.alu(AluOp::Shl, R(self.free_top), Imm(3));
        let fslot2 = self.b.alu(AluOp::Add, R(self.free_base), R(foff2));
        self.b.store(R(self.cur_id), R(fslot2), 0, Width::W8, AddrSpace::Local);
        self.b.alu_into(self.free_top, AluOp::Add, R(self.free_top), Imm(1));
        self.b.alu_into(self.active, AluOp::Sub, R(self.active), Imm(1));
        self.b.jmp(self.launch_bb);

        self.b.switch_to(self.done_bb);
        self.b.halt();

        // ---- nested callees ----
        let callees: Vec<usize> = (0..kernel.callees.len()).collect();
        for ci in callees {
            if !callee_has_remote(&kernel.callees[ci]) {
                // Was inlined; entry block still needs a terminator.
                self.b.switch_to(self.callee_entries[ci]);
                self.b.halt();
                continue;
            }
            self.emit_callee(ci)?;
        }

        // ---- package ----
        let num_tasks = self.num_tasks;
        let ids_used = if self.has_nested { 2 * num_tasks } else { num_tasks };
        let mut areas = vec![
            Area { name: "handler".into(), bytes: ids_used as u64 * self.ctx_bytes as u64, reg: self.handler_base },
            Area { name: "free".into(), bytes: num_tasks as u64 * 8, reg: self.free_base },
        ];
        if self.opts.sched == SchedKind::StaticFifo {
            areas.push(Area { name: "fifo".into(), bytes: (self.fifo_mask as u64 + 1) * 8, reg: self.fifo_base });
        }
        if has_atomics && uses_amu {
            areas.push(Area { name: "locks".into(), bytes: self.lock_entries * 16, reg: self.lock_base });
            areas.push(Area { name: "waiters".into(), bytes: ids_used as u64 * 8, reg: self.waiters_base });
        }
        let spm_base_reg = uses_amu.then_some(self.spm_base);
        let func = self.b.build();
        crate::ir::verify::verify(&func)?;
        Ok(CompiledKernel {
            func,
            param_regs: self.param_regs,
            areas,
            spm_base_reg,
            spm_slot_bytes: if uses_amu { self.slot_bytes } else { 0 },
            num_tasks,
            ctx_bytes: self.ctx_bytes,
            nsites: self.an.sites.len(),
            ngroups: self.plan.groups.len(),
            ids_used,
        })
    }

    /// Lower a nested callee's body once; all call sites share it.
    fn emit_callee(&mut self, ci: usize) -> Result<()> {
        let f = self.kernel.callees[ci].clone();
        // Build a pseudo-kernel for analysis.
        let pseudo = Kernel {
            name: f.name.clone(),
            params: f.params.clone(),
            trip_param: 0,
            body: f.body.clone(),
            pragma: Pragma::default(),
            nvars: f.nvars,
            var_names: (0..f.nvars).map(|v| format!("{}.v{}", f.name, v)).collect(),
            callees: vec![],
        };
        let callee_an = analysis::analyze(&pseudo)?;
        let callee_plan = CoalescePlan::disabled(callee_an.sites.len());
        // Swap analysis context.
        let saved_an = std::mem::replace(&mut self.an, callee_an);
        let saved_plan = std::mem::replace(&mut self.plan, callee_plan);
        let saved_site = std::mem::replace(&mut self.next_site, 0);
        let param_regs: Vec<Reg> = f.params.iter().map(|_| self.b.reg()).collect();
        let var_regs: Vec<Reg> = (0..f.nvars).map(|_| self.b.reg()).collect();
        self.callee_params = Some(param_regs.clone());
        self.callee_vars = Some(var_regs);
        self.callee_kernel = Some(ci);

        let entry = self.callee_entries[ci];
        self.b.switch_to(entry);
        // child_entry: load arguments from the child's ctx arg slots.
        for (k, pr) in param_regs.iter().enumerate() {
            self.b.load_into(*pr, R(self.ctx), CTX_VARS + 8 * k as i64, Width::W8, AddrSpace::Local);
        }
        let body_bb = self.b.new_block("child.body", CodeTag::Compute);
        self.b.jmp(body_bb);
        self.b.switch_to(body_bb);
        let body = f.body.clone();
        self.stmts(&body)?;
        // child return: stash ret value, wake the parent, park this id.
        if let Some(rv) = f.ret_var {
            let r = self.reg_of_var(rv);
            self.b.store(R(r), R(self.ctx), CTX_VAL, Width::W8, AddrSpace::Local);
        }
        let parent = self.b.alu(AluOp::Sub, R(self.cur_id), Imm(self.num_tasks as i64));
        self.b.push(Inst::Asignal { id: R(parent) });
        self.b.jmp(self.launch_bb);

        self.callee_params = None;
        self.callee_vars = None;
        self.callee_kernel = None;
        self.an = saved_an;
        self.plan = saved_plan;
        self.next_site = saved_site;
        Ok(())
    }
}
