//! Kernel AST — the compiler front end.
//!
//! In the paper, the input is a C/C++ `for` loop annotated with
//! `#pragma asyncmem` and remote-pointer builtins (Listing 1). Here the
//! same information is captured as a small structured AST: a loop kernel
//! with typed parameters (remote/local pointers, scalars), an iteration
//! body of statements, and pragma hints. Benchmarks construct these with
//! [`KernelBuilder`]; the passes in this module's siblings analyze and
//! lower them to CoroIR.

use crate::ir::{AddrSpace, AluOp, FaluOp, Width};

/// Index of a named local variable within a kernel.
pub type VarId = u32;
/// Index of a kernel parameter.
pub type ParamId = u32;

/// The implicit induction variable `i` of the pragma'd loop.
pub const ITER_VAR: VarId = 0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    /// Pointer into an address space (the paper's `remote_alloc` /
    /// `_builtin_is_remote` annotations become `Ptr(Remote)`).
    Ptr(AddrSpace),
    /// Scalar runtime constant (sizes, masks, seeds).
    Value,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Param {
    pub name: String,
    pub kind: ParamKind,
}

/// Binary operators usable in expressions. Integer ops mirror
/// [`AluOp`]; float ops mirror [`FaluOp`] over f64 bit patterns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    I(AluOp),
    F(FaluOp),
}

/// Pure expressions (no memory access — loads are statements, which keeps
/// suspension-point analysis simple and mirrors how the LLVM passes see
/// memory operations as distinct instructions).
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Imm(i64),
    /// f64 immediate (stored as bits).
    FImm(f64),
    Var(VarId),
    Param(ParamId),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn add(a: Expr, b: Expr) -> Expr {
        Expr::Bin(BinOp::I(AluOp::Add), Box::new(a), Box::new(b))
    }
    pub fn mul(a: Expr, b: Expr) -> Expr {
        Expr::Bin(BinOp::I(AluOp::Mul), Box::new(a), Box::new(b))
    }
    pub fn shl(a: Expr, b: Expr) -> Expr {
        Expr::Bin(BinOp::I(AluOp::Shl), Box::new(a), Box::new(b))
    }
    pub fn and(a: Expr, b: Expr) -> Expr {
        Expr::Bin(BinOp::I(AluOp::And), Box::new(a), Box::new(b))
    }
    pub fn or(a: Expr, b: Expr) -> Expr {
        Expr::Bin(BinOp::I(AluOp::Or), Box::new(a), Box::new(b))
    }
    pub fn xor(a: Expr, b: Expr) -> Expr {
        Expr::Bin(BinOp::I(AluOp::Xor), Box::new(a), Box::new(b))
    }

    /// Collect variables read by this expression.
    pub fn vars(&self, out: &mut Vec<VarId>) {
        match self {
            Expr::Var(v) => out.push(*v),
            Expr::Bin(_, a, b) => {
                a.vars(out);
                b.vars(out);
            }
            _ => {}
        }
    }

    /// The single pointer-parameter root of an address expression, if any.
    /// Address-space inference (§III-G strict typing) requires each address
    /// to be based on exactly one pointer parameter.
    pub fn pointer_root(&self, params: &[Param]) -> Option<ParamId> {
        let mut roots = Vec::new();
        self.collect_pointer_roots(params, &mut roots);
        match roots.as_slice() {
            [one] => Some(*one),
            _ => None,
        }
    }

    fn collect_pointer_roots(&self, params: &[Param], out: &mut Vec<ParamId>) {
        match self {
            Expr::Param(p) => {
                if matches!(params[*p as usize].kind, ParamKind::Ptr(_)) {
                    out.push(*p);
                }
            }
            Expr::Bin(_, a, b) => {
                a.collect_pointer_roots(params, out);
                b.collect_pointer_roots(params, out);
            }
            _ => {}
        }
    }
}

/// Statements of the loop body.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `var = expr`
    Let { var: VarId, expr: Expr },
    /// `var = *(width*)(addr)` — address space inferred from the pointer
    /// root of `addr`.
    Load { var: VarId, addr: Expr, width: Width },
    /// `*(width*)(addr) = val`
    Store { val: Expr, addr: Expr, width: Width },
    /// Atomic read-modify-write `old = atomic_op(addr, val)`; `old` may be
    /// discarded. Transformed by the atomics pass (§III-E) under dynamic
    /// scheduling.
    AtomicRmw { op: AluOp, old: Option<VarId>, addr: Expr, val: Expr, width: Width },
    If { cond: Expr, then_: Vec<Stmt>, else_: Vec<Stmt> },
    While { cond: Expr, body: Vec<Stmt> },
    /// Call a nested kernel function (§III-F). The callee runs with
    /// arguments bound to the caller's expressions; if it contains remote
    /// accesses it is either inlined or lowered as a nested coroutine.
    Call { callee: usize, args: Vec<Expr>, ret: Option<VarId> },
}

/// How a variable behaves across suspension points (§III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarClass {
    /// Must be saved/restored in the coroutine context.
    Private,
    /// Read-only or commutative-update: lives in a shared register, never
    /// saved.
    Shared,
    /// Ambiguous update pattern: hoisted to a serialized update at
    /// coroutine completion (Return block).
    Sequential,
}

/// The paper's `#pragma asyncmem` directives (Listing 1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Pragma {
    /// Suggested number of concurrent coroutine tasks (`num_task(64)`).
    pub num_tasks: Option<usize>,
    /// Programmer hints: variables safe to share (commutative updates),
    /// e.g. `shared_var(matches)`.
    pub shared_vars: Vec<VarId>,
    /// Programmer hints: variables requiring serialized update.
    pub sequential_vars: Vec<VarId>,
    /// Coarse-grained access hint in bytes for specific remote loads (the
    /// granularity encoding of §III-C); keyed by load ordinal. Empty means
    /// "let the coalescer decide".
    pub coarse_hints: Vec<(usize, u32)>,
}

/// A nested callee function (§III-F): a straight-line/structured body with
/// its own params; may contain remote accesses.
#[derive(Debug, Clone, PartialEq)]
pub struct NestedFn {
    pub name: String,
    pub params: Vec<Param>,
    pub body: Vec<Stmt>,
    /// Variable returned to the caller, if any.
    pub ret_var: Option<VarId>,
    pub nvars: u32,
}

/// A pragma-annotated memory-intensive loop: the compiler's unit of work.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub params: Vec<Param>,
    /// Parameter holding the trip count (`num_tuples` in Listing 1).
    pub trip_param: ParamId,
    pub body: Vec<Stmt>,
    pub pragma: Pragma,
    /// Total number of VarIds used (ITER_VAR included).
    pub nvars: u32,
    /// Human-readable variable names (debugging / reports).
    pub var_names: Vec<String>,
    /// Nested callees referenced by `Stmt::Call`.
    pub callees: Vec<NestedFn>,
}

/// Convenience builder so benchmark definitions read like the paper's
/// Listing 1. Statements can be accumulated fluently ([`KernelBuilder::let_`],
/// [`KernelBuilder::load`], [`KernelBuilder::store`], …) and sealed with
/// [`KernelBuilder::finish`], or passed wholesale to
/// [`KernelBuilder::build`]; mixing both appends the `build` body after the
/// fluent one.
pub struct KernelBuilder {
    name: String,
    params: Vec<Param>,
    trip_param: Option<ParamId>,
    pragma: Pragma,
    vars: Vec<String>,
    callees: Vec<NestedFn>,
    body: Vec<Stmt>,
}

impl KernelBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            params: Vec::new(),
            trip_param: None,
            pragma: Pragma::default(),
            vars: vec!["i".to_string()], // ITER_VAR
            callees: Vec::new(),
            body: Vec::new(),
        }
    }

    pub fn param_ptr(&mut self, name: &str, space: AddrSpace) -> ParamId {
        self.params.push(Param { name: name.into(), kind: ParamKind::Ptr(space) });
        (self.params.len() - 1) as ParamId
    }

    pub fn param_val(&mut self, name: &str) -> ParamId {
        self.params.push(Param { name: name.into(), kind: ParamKind::Value });
        (self.params.len() - 1) as ParamId
    }

    pub fn trip(&mut self, p: ParamId) {
        self.trip_param = Some(p);
    }

    pub fn var(&mut self, name: &str) -> VarId {
        self.vars.push(name.into());
        (self.vars.len() - 1) as VarId
    }

    pub fn num_tasks(&mut self, n: usize) {
        self.pragma.num_tasks = Some(n);
    }

    pub fn shared_var(&mut self, v: VarId) {
        self.pragma.shared_vars.push(v);
    }

    pub fn sequential_var(&mut self, v: VarId) {
        self.pragma.sequential_vars.push(v);
    }

    pub fn callee(&mut self, f: NestedFn) -> usize {
        self.callees.push(f);
        self.callees.len() - 1
    }

    // --- Fluent statement helpers: the loop body reads top-to-bottom like
    // --- the paper's pragma-annotated C (Listing 1).

    /// Append an arbitrary statement.
    pub fn push(&mut self, s: Stmt) -> &mut Self {
        self.body.push(s);
        self
    }

    /// `var = expr`
    pub fn let_(&mut self, var: VarId, expr: Expr) -> &mut Self {
        self.push(Stmt::Let { var, expr })
    }

    /// `var = *(width*)addr`
    pub fn load(&mut self, var: VarId, addr: Expr, width: Width) -> &mut Self {
        self.push(Stmt::Load { var, addr, width })
    }

    /// `*(width*)addr = val`
    pub fn store(&mut self, val: Expr, addr: Expr, width: Width) -> &mut Self {
        self.push(Stmt::Store { val, addr, width })
    }

    /// `atomic_op(addr, val)` with the old value discarded.
    pub fn atomic_rmw(&mut self, op: AluOp, addr: Expr, val: Expr, width: Width) -> &mut Self {
        self.push(Stmt::AtomicRmw { op, old: None, addr, val, width })
    }

    /// `if (cond) { then_ } else { else_ }`
    pub fn if_(&mut self, cond: Expr, then_: Vec<Stmt>, else_: Vec<Stmt>) -> &mut Self {
        self.push(Stmt::If { cond, then_, else_ })
    }

    /// `while (cond) { body }`
    pub fn while_(&mut self, cond: Expr, body: Vec<Stmt>) -> &mut Self {
        self.push(Stmt::While { cond, body })
    }

    /// Seal a fluently-built kernel.
    pub fn finish(self) -> Kernel {
        self.build(Vec::new())
    }

    pub fn build(mut self, body: Vec<Stmt>) -> Kernel {
        self.body.extend(body);
        Kernel {
            name: self.name,
            trip_param: self.trip_param.expect("trip count parameter not set"),
            params: self.params,
            body: self.body,
            pragma: self.pragma,
            nvars: self.vars.len() as u32,
            var_names: self.vars,
            callees: self.callees,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointer_root_inference() {
        let params = vec![
            Param { name: "tab".into(), kind: ParamKind::Ptr(AddrSpace::Remote) },
            Param { name: "n".into(), kind: ParamKind::Value },
        ];
        let addr = Expr::add(Expr::Param(0), Expr::mul(Expr::Var(ITER_VAR), Expr::Imm(8)));
        assert_eq!(addr.pointer_root(&params), Some(0));
        // Scalar-only expression has no pointer root.
        let scalar = Expr::add(Expr::Param(1), Expr::Imm(1));
        assert_eq!(scalar.pointer_root(&params), None);
        // Two pointer roots is ambiguous -> None.
        let both = Expr::add(Expr::Param(0), Expr::Param(0));
        assert_eq!(both.pointer_root(&params), None);
    }

    #[test]
    fn expr_vars() {
        let e = Expr::add(Expr::Var(1), Expr::mul(Expr::Var(2), Expr::Var(1)));
        let mut vs = vec![];
        e.vars(&mut vs);
        vs.sort_unstable();
        assert_eq!(vs, vec![1, 1, 2]);
    }

    #[test]
    fn builder_roundtrip() {
        let mut kb = KernelBuilder::new("gups");
        let tab = kb.param_ptr("table", AddrSpace::Remote);
        let n = kb.param_val("num_updates");
        kb.trip(n);
        let v = kb.var("val");
        kb.num_tasks(64);
        let k = kb.build(vec![
            Stmt::Load { var: v, addr: Expr::add(Expr::Param(tab), Expr::Var(ITER_VAR)), width: Width::W8 },
            Stmt::Store {
                val: Expr::Var(v),
                addr: Expr::add(Expr::Param(tab), Expr::Var(ITER_VAR)),
                width: Width::W8,
            },
        ]);
        assert_eq!(k.nvars, 2);
        assert_eq!(k.trip_param, n);
        assert_eq!(k.pragma.num_tasks, Some(64));
        assert_eq!(k.var_names[ITER_VAR as usize], "i");
    }

    #[test]
    #[should_panic(expected = "trip count")]
    fn missing_trip_panics() {
        KernelBuilder::new("x").build(vec![]);
    }

    #[test]
    fn fluent_builder_matches_explicit_body() {
        // The same GUPS-ish loop, written both ways, must produce
        // identical kernels.
        let explicit = {
            let mut kb = KernelBuilder::new("fluent");
            let tab = kb.param_ptr("table", AddrSpace::Remote);
            let n = kb.param_val("n");
            kb.trip(n);
            kb.num_tasks(32);
            let v = kb.var("val");
            let addr = Expr::add(Expr::Param(tab), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3)));
            kb.build(vec![
                Stmt::Load { var: v, addr: addr.clone(), width: Width::W8 },
                Stmt::Store { val: Expr::xor(Expr::Var(v), Expr::Var(ITER_VAR)), addr, width: Width::W8 },
            ])
        };
        let fluent = {
            let mut kb = KernelBuilder::new("fluent");
            let tab = kb.param_ptr("table", AddrSpace::Remote);
            let n = kb.param_val("n");
            kb.trip(n);
            kb.num_tasks(32);
            let v = kb.var("val");
            let addr = Expr::add(Expr::Param(tab), Expr::shl(Expr::Var(ITER_VAR), Expr::Imm(3)));
            kb.load(v, addr.clone(), Width::W8)
                .store(Expr::xor(Expr::Var(v), Expr::Var(ITER_VAR)), addr, Width::W8);
            kb.finish()
        };
        assert_eq!(explicit, fluent);
    }

    #[test]
    fn build_appends_after_fluent_body() {
        let mut kb = KernelBuilder::new("mix");
        let n = kb.param_val("n");
        kb.trip(n);
        let a = kb.var("a");
        let b = kb.var("b");
        kb.let_(a, Expr::Imm(1));
        let k = kb.build(vec![Stmt::Let { var: b, expr: Expr::Imm(2) }]);
        assert_eq!(k.body.len(), 2);
        assert_eq!(k.body[0], Stmt::Let { var: a, expr: Expr::Imm(1) });
        assert_eq!(k.body[1], Stmt::Let { var: b, expr: Expr::Imm(2) });
    }
}
