//! The CoroAMU compiler (paper §III).
//!
//! Pipeline: [`ast`] (pragma-annotated loop kernels) → [`analysis`]
//! (AsyncMarkPass: suspension sites, liveness, §III-B variable
//! classification) → [`coalesce`] (§III-C request aggregation) →
//! [`codegen`] (AsyncSplitPass: Fig. 6 runtime skeleton + per-variant
//! schedulers of Fig. 7, §III-E atomics, §III-F nested coroutines).

pub mod analysis;
pub mod ast;
pub mod coalesce;
pub mod codegen;

pub use codegen::{compile, CodegenOpts, CompiledKernel, SchedKind};

/// The paper's five evaluation configurations (§VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Unmodified application on the baseline processor.
    Serial,
    /// Hand-written coroutines, prefetch + static scheduling [23].
    Coroutine,
    /// CoroAMU compiler, static prefetch scheduler.
    CoroAmuS,
    /// CoroAMU compiler, original-AMU dynamic scheduler (getfin).
    CoroAmuD,
    /// CoroAMU compiler + enhanced AMU (bafin) + all optimizations.
    CoroAmuFull,
}

impl Variant {
    pub const ALL: [Variant; 5] =
        [Variant::Serial, Variant::Coroutine, Variant::CoroAmuS, Variant::CoroAmuD, Variant::CoroAmuFull];

    pub fn label(self) -> &'static str {
        match self {
            Variant::Serial => "Serial",
            Variant::Coroutine => "Coroutine",
            Variant::CoroAmuS => "CoroAMU-S",
            Variant::CoroAmuD => "CoroAMU-D",
            Variant::CoroAmuFull => "CoroAMU-Full",
        }
    }

    pub fn needs_amu(self) -> bool {
        matches!(self, Variant::CoroAmuD | Variant::CoroAmuFull)
    }

    /// Codegen options for this variant at a given concurrency.
    pub fn opts(self, num_tasks: usize) -> CodegenOpts {
        match self {
            Variant::Serial => CodegenOpts::serial(),
            Variant::Coroutine => CodegenOpts::hand_coroutine(num_tasks),
            Variant::CoroAmuS => CodegenOpts::coroamu_s(num_tasks),
            Variant::CoroAmuD => CodegenOpts::coroamu_d(num_tasks),
            Variant::CoroAmuFull => CodegenOpts::coroamu_full(num_tasks),
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Some(Variant::Serial),
            "coroutine" | "hand" => Some(Variant::Coroutine),
            "coroamu-s" | "s" | "static" => Some(Variant::CoroAmuS),
            "coroamu-d" | "d" | "getfin" => Some(Variant::CoroAmuD),
            "coroamu-full" | "full" | "bafin" => Some(Variant::CoroAmuFull),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::parse(v.label()), Some(v));
        }
        assert_eq!(Variant::parse("nope"), None);
    }

    #[test]
    fn variant_opts_match_paper_configs() {
        assert_eq!(Variant::Serial.opts(8).sched, SchedKind::Serial);
        let hand = Variant::Coroutine.opts(8);
        assert!(hand.generic_frame && hand.sched == SchedKind::StaticFifo);
        let s = Variant::CoroAmuS.opts(8);
        assert!(!s.generic_frame && s.sched == SchedKind::StaticFifo && !s.context_opt);
        let d = Variant::CoroAmuD.opts(8);
        assert!(d.sched == SchedKind::Getfin && !d.coalesce);
        let f = Variant::CoroAmuFull.opts(8);
        assert!(f.sched == SchedKind::Bafin && f.context_opt && f.coalesce);
    }
}
