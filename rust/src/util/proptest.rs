//! Property-testing mini-framework (no `proptest` crate offline).
//!
//! Provides seeded random generators, a `check` runner that searches for a
//! failing input, and greedy shrinking for integers and vectors. Used by the
//! coordinator/compiler/simulator invariant tests.

use super::rng::Rng;

/// A generation context handed to strategies.
pub struct Gen {
    pub rng: Rng,
    /// Size hint: strategies should scale collection sizes by this.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Rng::new(seed), size }
    }

    pub fn u64_below(&mut self, bound: u64) -> u64 {
        self.rng.below(bound.max(1))
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        lo + self.rng.below((hi - lo) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        if hi <= lo {
            return lo;
        }
        lo + self.rng.below((hi - lo) as u64) as i64
    }

    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len.min(self.size.max(1)) + 1);
        (0..len).map(|_| f(self)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum CheckResult<T> {
    Pass { cases: usize },
    Fail { seed: u64, case: usize, input: T, message: String },
}

/// Configuration for the runner.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Seed from env for reproducibility: COROAMU_PT_SEED=123.
        let seed = std::env::var("COROAMU_PT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0F0_AA11);
        Self { cases: env_cases(128), seed, max_shrink_iters: 400 }
    }
}

/// Case count from the `PROPTEST_CASES` env var, else `default`. The
/// nightly CI workflow cranks this to 2048; interactive runs keep the
/// suite fast with the per-test defaults.
pub fn env_cases(default: usize) -> usize {
    std::env::var("PROPTEST_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Anything that can propose "smaller" versions of itself.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - self.signum()]
        }
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl<T: Clone + Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink elements.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        for i in 0..self.len().min(8) {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        for i in 0..self.len().min(4) {
            for smaller in self[i].shrink() {
                let mut v = self.clone();
                v[i] = smaller;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` against `cases` random inputs drawn by `gen_input`; on failure,
/// greedily shrink to a minimal failing input and panic with a reproducer.
pub fn check<T, G, P>(cfg: Config, mut gen_input: G, mut prop: P)
where
    T: Clone + std::fmt::Debug + Shrink,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // Derive a per-case seed so failures reproduce standalone.
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(case_seed, 1 + case % 50);
        let input = gen_input(&mut g);
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut iters = 0;
            'outer: loop {
                for cand in best.shrink() {
                    iters += 1;
                    if iters > cfg.max_shrink_iters {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}, set COROAMU_PT_SEED={seed} to reproduce)\n  minimal input: {best:?}\n  error: {best_msg}",
                seed = cfg.seed,
            );
        }
    }
}

/// Convenience wrapper with default config.
pub fn quickcheck<T, G, P>(gen_input: G, prop: P)
where
    T: Clone + std::fmt::Debug + Shrink,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(Config::default(), gen_input, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quickcheck(
            |g| g.vec(16, |g| g.u64_below(100)),
            |v: &Vec<u64>| {
                let mut s = v.clone();
                s.sort_unstable();
                if s.len() == v.len() {
                    Ok(())
                } else {
                    Err("sort changed length".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_shrinks_and_panics() {
        quickcheck(
            |g| g.vec(32, |g| g.u64_below(1000)),
            |v: &Vec<u64>| {
                if v.iter().any(|&x| x >= 500) {
                    Err("found large element".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrink_u64_monotone() {
        for s in 17u64.shrink() {
            assert!(s < 17);
        }
    }

    #[test]
    fn shrink_vec_produces_smaller_or_equal() {
        let v = vec![5u64, 9, 200];
        for s in v.shrink() {
            assert!(s.len() <= v.len());
        }
    }
}
