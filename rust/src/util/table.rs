//! Plain-text table rendering for figure/table reproduction output.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a ratio like the paper's speedup annotations: `3.39x`.
pub fn speedup(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format a percentage with one decimal.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

/// Geometric mean of a slice (paper averages are geomeans over benchmarks).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["bench", "speedup"]);
        t.row(vec!["gups".into(), "29.00x".into()]);
        t.row(vec!["bs".into(), "3.10x".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| gups"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(speedup(3.391), "3.39x");
        assert_eq!(pct(0.153), "15.3%");
    }
}
