//! One parse/label/ALL surface for every enumerated CLI/TOML knob.
//!
//! The scheduler policy, far-fabric model, fault preset, service preset
//! and report mode each grew their own hand-rolled `parse`/`label` pair
//! with its own error dialect. [`Keyed`] pins them to a single contract:
//!
//! * `parse` accepts every spelling the CLI and TOML layers document
//!   (including parameterized forms like `batched:8` or `nack:25`),
//! * `label` renders the canonical spelling back (round-trips through
//!   `parse`),
//! * `all` enumerates the canonical members for docs and grid axes,
//! * unknown spellings fail with the uniform message built by
//!   [`unknown`]: ``unknown <axis> `<got>`; expected one of: <forms>``.
//!
//! `harness::grid` axis parsing is generic over this trait, so adding a
//! new knob to the grid costs one `impl Keyed` — not a sixth dialect.

use anyhow::{Error, Result};

/// An enumerated knob with a canonical string form.
pub trait Keyed: Sized {
    /// Axis noun used in error messages (`"fabric"`, `"fault spec"`, …).
    const AXIS: &'static str;
    /// Human list of accepted forms for error messages
    /// (`"fixed, queued[:N], …"`).
    const EXPECTED: &'static str;

    /// Parse any accepted spelling; errors use [`unknown`]'s format.
    fn parse_keyed(s: &str) -> Result<Self>;

    /// Canonical spelling; `parse_keyed(label_keyed(x)) == x`.
    fn label_keyed(&self) -> String;

    /// Canonical members, for docs, grids and exhaustive sweeps.
    fn all_keyed() -> Vec<Self>;
}

/// The uniform unknown-spelling error every [`Keyed`] surface emits.
pub fn unknown(axis: &str, got: &str, expected: &str) -> Error {
    anyhow::anyhow!("unknown {axis} `{got}`; expected one of: {expected}")
}

/// `unknown` specialised to a `Keyed` implementor.
pub fn unknown_key<T: Keyed>(got: &str) -> Error {
    unknown(T::AXIS, got, T::EXPECTED)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fabric::FabricKind;
    use crate::sim::faults::FaultConfig;
    use crate::sim::sched::SchedPolicyKind;
    use crate::sim::service::ServiceConfig;

    fn roundtrip<T: Keyed + PartialEq + std::fmt::Debug>() {
        let all = T::all_keyed();
        assert!(!all.is_empty(), "{} has no canonical members", T::AXIS);
        for k in all {
            let back = T::parse_keyed(&k.label_keyed()).unwrap();
            assert_eq!(back, k, "{} label does not round-trip", T::AXIS);
        }
    }

    #[test]
    fn all_surfaces_roundtrip_through_the_trait() {
        roundtrip::<SchedPolicyKind>();
        roundtrip::<FabricKind>();
        roundtrip::<FaultConfig>();
        roundtrip::<ServiceConfig>();
    }

    #[test]
    fn unknown_spellings_share_one_error_dialect() {
        let cases: [(&str, Result<()>); 4] = [
            ("scheduler policy", SchedPolicyKind::parse_keyed("quewed").map(|_| ())),
            ("fabric", FabricKind::parse_keyed("quewed").map(|_| ())),
            ("fault spec", FaultConfig::parse_keyed("quewed").map(|_| ())),
            ("service spec", ServiceConfig::parse_keyed("quewed").map(|_| ())),
        ];
        for (axis, r) in cases {
            let msg = format!("{:#}", r.unwrap_err());
            assert!(
                msg.contains(&format!("unknown {axis} `quewed`; expected one of: ")),
                "non-uniform error for {axis}: {msg}"
            );
        }
    }
}
