//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so the generators the
//! reproduction needs (dataset synthesis, Zipf-skewed key draws, property
//! testing) are implemented here. All generators are seedable and
//! deterministic so every experiment in EXPERIMENTS.md is exactly
//! reproducible.

/// SplitMix64 — used for seeding and as a cheap standalone generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independently-seeded generator (for worker threads).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Zipf-distributed sampler over `[0, n)` with exponent `theta`, using the
/// rejection-inversion method of Hörmann & Derflinger. Used for skewed key
/// distributions in the hash-join and GUPS workload generators.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    // Precomputed constants for rejection-inversion.
    hx0: f64,
    hxm: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        assert!(theta > 0.0 && (theta - 1.0).abs() > 1e-9, "theta==1 unsupported");
        let h = |x: f64| ((1.0 + x).powf(1.0 - theta) - 1.0) / (1.0 - theta);
        let h_inv_arg_max = h(n as f64 - 0.5);
        let hx0 = h(0.5) - 1.0;
        let s = 1.0 - Self::h_inv_static(theta, h(1.5) - 1.0);
        Self { n, theta, hx0, hxm: h_inv_arg_max, s }
    }

    fn h_inv_static(theta: f64, x: f64) -> f64 {
        (1.0 + x * (1.0 - theta)).powf(1.0 / (1.0 - theta)) - 1.0
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.hx0 + rng.f64() * (self.hxm - self.hx0);
            let x = Self::h_inv_static(self.theta, u);
            let k = (x + 0.5).floor();
            let h = |x: f64| ((1.0 + x).powf(1.0 - self.theta) - 1.0) / (1.0 - self.theta);
            if k - x <= self.s || u >= h(k + 0.5) - (1.0 + k).powf(-self.theta) {
                let k = k as i64;
                return k.clamp(0, self.n as i64 - 1) as u64;
            }
        }
    }
}

/// Exponential inter-arrival sampler with mean `mean` (time unit is the
/// caller's — the service simulator counts cycles), via the inverse-CDF
/// transform `-ln(1-u) * mean`. `u` comes from [`Rng::f64`], so
/// `1 - u` is in `(0, 1]`: the log argument is never zero and every
/// sample is finite and non-negative. Used by `sim::service` as the
/// open-loop Poisson arrival process.
#[derive(Debug, Clone)]
pub struct Exp {
    mean: f64,
}

impl Exp {
    pub fn new(mean: f64) -> Self {
        assert!(mean > 0.0 && mean.is_finite(), "Exp mean must be positive and finite");
        Self { mean }
    }

    /// Draw one inter-arrival gap.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        -(1.0 - rng.f64()).ln() * self.mean
    }
}

/// Bursty on/off modulator over an exponential base process (a
/// deterministic-phase Markov-modulated Poisson process): each period of
/// length `period` opens with an "on" window covering `duty` of it, and
/// gaps drawn inside the window shrink by `factor` — arrivals come
/// `factor`× faster during bursts and at the base rate outside them.
/// The phase is a pure function of the caller's clock, so the stream
/// stays a deterministic replay function of (seed, clock sequence).
#[derive(Debug, Clone)]
pub struct BurstyExp {
    base: Exp,
    period: f64,
    on_len: f64,
    factor: f64,
}

impl BurstyExp {
    pub fn new(mean: f64, period: f64, duty: f64, factor: f64) -> Self {
        assert!(period > 0.0 && period.is_finite(), "BurstyExp period must be positive");
        assert!((0.0..1.0).contains(&duty) && duty > 0.0, "BurstyExp duty must be in (0, 1)");
        assert!(factor >= 1.0 && factor.is_finite(), "BurstyExp factor must be >= 1");
        Self { base: Exp::new(mean), period, on_len: period * duty, factor }
    }

    /// Next inter-arrival gap given the current clock `now`.
    #[inline]
    pub fn sample(&self, now: f64, rng: &mut Rng) -> f64 {
        let gap = self.base.sample(rng);
        if now.rem_euclid(self.period) < self.on_len {
            gap / self.factor
        } else {
            gap
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.below(17);
            assert!(x < 17);
        }
    }

    #[test]
    fn xoshiro_range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(5, 11);
            assert!((5..11).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_small_keys() {
        let z = Zipf::new(1000, 0.99);
        let mut r = Rng::new(5);
        let mut low = 0usize;
        let mut n = 0usize;
        for _ in 0..20_000 {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k < 100 {
                low += 1;
            }
            n += 1;
        }
        // Zipf(0.99): the first 10% of keys should take far more than 10%
        // of the mass.
        assert!(low as f64 / n as f64 > 0.4, "low frac {}", low as f64 / n as f64);
    }

    #[test]
    fn exp_same_seed_bitwise_identical() {
        let e = Exp::new(100.0);
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        for _ in 0..1000 {
            // Pinned arithmetic: the inverse-CDF transform is a pure
            // function of the u64 draw, so equal seeds give bit-equal
            // f64 gaps, not merely close ones.
            assert_eq!(e.sample(&mut a).to_bits(), e.sample(&mut b).to_bits());
        }
    }

    #[test]
    fn exp_samples_finite_nonnegative() {
        let e = Exp::new(3.5);
        let mut r = Rng::new(23);
        for _ in 0..20_000 {
            let x = e.sample(&mut r);
            assert!(x.is_finite() && x >= 0.0, "bad exp sample {x}");
        }
    }

    #[test]
    fn exp_mean_converges() {
        let e = Exp::new(200.0);
        let mut r = Rng::new(7);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| e.sample(&mut r)).sum();
        let mean = sum / n as f64;
        assert!((mean - 200.0).abs() / 200.0 < 0.05, "mean={mean}");
    }

    #[test]
    fn bursty_same_seed_bitwise_identical() {
        let m = BurstyExp::new(100.0, 1000.0, 0.25, 4.0);
        let mut a = Rng::new(31);
        let mut b = Rng::new(31);
        let mut ta = 0.0f64;
        let mut tb = 0.0f64;
        for _ in 0..1000 {
            let ga = m.sample(ta, &mut a);
            let gb = m.sample(tb, &mut b);
            assert_eq!(ga.to_bits(), gb.to_bits());
            ta += ga;
            tb += gb;
        }
    }

    #[test]
    fn bursty_bursts_faster_inside_window() {
        // Period 1000, duty 0.25, factor 4: gaps drawn inside [0, 250)
        // average ~mean/4, gaps outside average ~mean.
        let m = BurstyExp::new(100.0, 1000.0, 0.25, 4.0);
        let mut r = Rng::new(41);
        let n = 20_000;
        let on: f64 = (0..n).map(|_| m.sample(10.0, &mut r)).sum::<f64>() / n as f64;
        let off: f64 = (0..n).map(|_| m.sample(500.0, &mut r)).sum::<f64>() / n as f64;
        assert!((on - 25.0).abs() / 25.0 < 0.07, "on-window mean {on}");
        assert!((off - 100.0).abs() / 100.0 < 0.07, "off-window mean {off}");
        // The phase wraps: one full period later is the on-window again.
        let wrapped: f64 = (0..n).map(|_| m.sample(1010.0, &mut r)).sum::<f64>() / n as f64;
        assert!((wrapped - 25.0).abs() / 25.0 < 0.07, "wrapped mean {wrapped}");
    }

    #[test]
    fn split_generators_diverge() {
        let mut r = Rng::new(123);
        let mut a = r.split();
        let mut b = r.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
