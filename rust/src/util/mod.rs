//! In-repo substrates replacing unavailable crates (offline build):
//! PRNGs, TOML-subset parsing, CLI parsing, property testing, bench harness,
//! and table rendering.

pub mod benchkit;
pub mod cli;
pub mod keyed;
pub mod minitoml;
pub mod proptest;
pub mod rng;
pub mod table;
