//! Tiny command-line argument parser (no `clap` in the offline env).
//!
//! Model: `prog <subcommand> [--flag] [--key value|--key=value] [positional]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Is this token a negative numeric literal (`-1`, `-2.5`) rather than a
/// short flag (`-v`)? Negative values must be consumable as option values:
/// `--offset -1`.
fn is_negative_number(s: &str) -> bool {
    s.len() > 1 && s.starts_with('-') && s[1..].parse::<f64>().is_ok()
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        // First non-dashed token is the subcommand.
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                out.subcommand = iter.next();
            }
        }
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.options.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else {
                    // `--key value` if the next token is a value (anything
                    // not dash-prefixed, or a negative number like `-1`),
                    // else a boolean flag.
                    match iter.peek() {
                        Some(next) if !next.starts_with('-') || is_negative_number(next) => {
                            let val = iter.next().unwrap();
                            out.options.insert(body.to_string(), val);
                        }
                        _ => out.flags.push(body.to_string()),
                    }
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_i64(&self, name: &str) -> Option<i64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Comma-separated list option, e.g. `--latencies 100,200,800`.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["report", "--fig", "12", "--preset=nh-g", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("report"));
        assert_eq!(a.get("fig"), Some("12"));
        assert_eq!(a.get("preset"), Some("nh-g"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_and_space_forms() {
        let a = parse(&["run", "--n=5", "--m", "7"]);
        assert_eq!(a.get_u64("n"), Some(5));
        assert_eq!(a.get_u64("m"), Some(7));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["run", "gups", "--lat", "200", "bs"]);
        assert_eq!(a.positional, vec!["gups".to_string(), "bs".to_string()]);
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--lats", "100, 200,800"]);
        assert_eq!(
            a.get_list("lats"),
            Some(vec!["100".into(), "200".into(), "800".into()])
        );
    }

    #[test]
    fn no_subcommand_when_first_is_option() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse(&["run", "--latency", "-1", "--offset", "-2.5"]);
        assert_eq!(a.get_f64("latency"), Some(-1.0));
        assert_eq!(a.get_i64("latency"), Some(-1));
        assert_eq!(a.get_f64("offset"), Some(-2.5));
        assert!(a.flags.is_empty(), "negative values must not become flags: {:?}", a.flags);
    }

    #[test]
    fn negative_number_in_equals_form() {
        let a = parse(&["run", "--latency=-800"]);
        assert_eq!(a.get_f64("latency"), Some(-800.0));
    }

    #[test]
    fn short_dash_token_is_not_a_value() {
        // `-x` is not numeric, so `--verbose` stays a flag and `-x` falls
        // through to positionals.
        let a = parse(&["run", "--verbose", "-x"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("verbose"), None);
        assert_eq!(a.positional, vec!["-x".to_string()]);
    }
}
