//! Micro-benchmark harness for `cargo bench` (no `criterion` offline).
//!
//! Benches are plain binaries with `harness = false`; they construct a
//! [`Bench`] runner which handles warm-up, repetition, robust statistics and
//! the `cargo bench -- <filter>` convention.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Optional domain metric, e.g. simulated dynamic instructions/sec.
    pub throughput: Option<(f64, &'static str)>,
}

pub struct Bench {
    filter: Option<String>,
    pub warmup_iters: u32,
    pub measure_iters: u32,
    pub samples: Vec<Sample>,
}

impl Bench {
    /// Build from `std::env::args`, honouring `cargo bench -- <filter>` and
    /// ignoring libtest-style flags like `--bench`.
    pub fn from_env() -> Self {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--"));
        let fast = std::env::var("COROAMU_BENCH_FAST").is_ok();
        Self {
            filter,
            warmup_iters: if fast { 1 } else { 2 },
            measure_iters: if fast { 3 } else { 10 },
            samples: Vec::new(),
        }
    }

    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Time `f`, which returns an optional work amount for throughput
    /// reporting (e.g. instructions simulated).
    pub fn run<F>(&mut self, name: &str, unit: &'static str, mut f: F)
    where
        F: FnMut() -> f64,
    {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.measure_iters as usize);
        let mut work_total = 0.0;
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            let work = std::hint::black_box(f());
            times.push(t0.elapsed().as_nanos() as f64);
            work_total += work;
        }
        sort_times(&mut times);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let median = times[times.len() / 2];
        let work_per_iter = work_total / self.measure_iters as f64;
        let throughput = if work_per_iter > 0.0 {
            Some((work_per_iter / (mean / 1e9), unit))
        } else {
            None
        };
        let sample = Sample {
            name: name.to_string(),
            iters: self.measure_iters,
            mean_ns: mean,
            median_ns: median,
            min_ns: times[0],
            max_ns: *times.last().unwrap(),
            throughput,
        };
        println!("{}", format_sample(&sample));
        self.samples.push(sample);
    }

    pub fn finish(&self) {
        println!("\n{} benchmarks complete", self.samples.len());
    }

    /// An empty unfiltered runner, for callers that measure externally
    /// and push [`Sample`]s directly (e.g. the test-suite throughput
    /// smoke) so every producer of bench JSON shares one schema.
    pub fn for_recording() -> Bench {
        Bench { filter: None, warmup_iters: 0, measure_iters: 0, samples: Vec::new() }
    }

    /// A runner holding only the samples whose name starts with `prefix`
    /// (to serialize one group's results, e.g. `sim_mips/`).
    pub fn subset(&self, prefix: &str) -> Bench {
        Bench {
            filter: None,
            warmup_iters: self.warmup_iters,
            measure_iters: self.measure_iters,
            samples: self.samples.iter().filter(|s| s.name.starts_with(prefix)).cloned().collect(),
        }
    }

    /// Serialize the recorded samples as JSON (hand-rolled — no `serde`
    /// in the offline environment). Used by the simulator-throughput
    /// bench to record the perf trajectory in `BENCH_sim.json`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"mode\": \"{}\",\n", build_mode()));
        out.push_str("  \"samples\": [\n");
        for (i, s) in self.samples.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"name\": \"{}\", ", json_escape(&s.name)));
            out.push_str(&format!("\"iters\": {}, ", s.iters));
            out.push_str(&format!("\"mean_ns\": {:.1}, ", s.mean_ns));
            out.push_str(&format!("\"median_ns\": {:.1}, ", s.median_ns));
            out.push_str(&format!("\"min_ns\": {:.1}, ", s.min_ns));
            out.push_str(&format!("\"max_ns\": {:.1}", s.max_ns));
            if let Some((rate, unit)) = s.throughput {
                out.push_str(&format!(
                    ", \"rate_per_s\": {:.1}, \"unit\": \"{}\", \"mrate\": {:.3}",
                    rate,
                    json_escape(unit),
                    rate / 1e6
                ));
            }
            out.push('}');
            if i + 1 < self.samples.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write [`Bench::to_json`] to `path`.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Sort timing samples under a *total* order: a NaN sample (a poisoned
/// or overflowed measurement) sorts to the end of the array instead of
/// panicking the whole bench run inside `partial_cmp(..).unwrap()`.
fn sort_times(times: &mut [f64]) {
    times.sort_by(|a, b| a.total_cmp(b));
}

/// Build profile tag recorded alongside throughput numbers, so debug-mode
/// smoke runs are never mistaken for release measurements.
pub fn build_mode() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Serialize report tables as a JSON array of `{title, headers, rows}`
/// objects (hand-rolled — no `serde` in the offline environment). Used
/// by `coroamu report --json` and `coroamu sweep --json` so scripted
/// consumers get the same cells the text renderer aligns.
pub fn to_json(tables: &[crate::util::table::Table]) -> String {
    let cells = |row: &[String]| -> String {
        let quoted: Vec<String> = row.iter().map(|c| format!("\"{}\"", json_escape(c))).collect();
        format!("[{}]", quoted.join(", "))
    };
    let mut out = String::from("[\n");
    for (i, t) in tables.iter().enumerate() {
        out.push_str("  {\n");
        out.push_str(&format!("    \"title\": \"{}\",\n", json_escape(&t.title)));
        out.push_str(&format!("    \"headers\": {},\n", cells(&t.headers)));
        out.push_str("    \"rows\": [\n");
        for (j, r) in t.rows.iter().enumerate() {
            out.push_str(&format!(
                "      {}{}\n",
                cells(r),
                if j + 1 < t.rows.len() { "," } else { "" }
            ));
        }
        out.push_str("    ]\n");
        out.push_str(&format!("  }}{}\n", if i + 1 < tables.len() { "," } else { "" }));
    }
    out.push_str("]\n");
    out
}

pub fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn format_sample(s: &Sample) -> String {
    let mut line = format!(
        "bench {:<46} median {:>10}  mean {:>10}  (min {}, max {}, n={})",
        s.name,
        human_ns(s.median_ns),
        human_ns(s.mean_ns),
        human_ns(s.min_ns),
        human_ns(s.max_ns),
        s.iters
    );
    if let Some((rate, unit)) = s.throughput {
        line.push_str(&format!("  [{:.2} M{}/s]", rate / 1e6, unit));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_ns_ranges() {
        assert_eq!(human_ns(500.0), "500 ns");
        assert_eq!(human_ns(1500.0), "1.50 µs");
        assert_eq!(human_ns(2.5e6), "2.50 ms");
        assert_eq!(human_ns(3.2e9), "3.200 s");
    }

    #[test]
    fn run_records_sample() {
        let mut b = Bench {
            filter: None,
            warmup_iters: 0,
            measure_iters: 3,
            samples: Vec::new(),
        };
        b.run("smoke", "ops", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
            1000.0
        });
        assert_eq!(b.samples.len(), 1);
        assert!(b.samples[0].throughput.is_some());
    }

    /// Regression: the percentile sort used `partial_cmp(..).unwrap()`,
    /// which panics the moment a NaN timing sample appears. The total
    /// order must instead sort NaN to the end and leave the finite
    /// prefix correctly ordered, so median/min stay meaningful.
    #[test]
    fn nan_samples_sort_instead_of_panicking() {
        let mut t = vec![3.0, f64::NAN, 1.0, 2.0];
        sort_times(&mut t);
        assert_eq!(&t[..3], &[1.0, 2.0, 3.0]);
        assert!(t[3].is_nan(), "NaN must sort last under total_cmp");
        // All-NaN input is equally non-panicking.
        let mut all = vec![f64::NAN, f64::NAN];
        sort_times(&mut all);
        assert!(all.iter().all(|x| x.is_nan()));
    }

    #[test]
    fn json_serializes_samples() {
        let mut b = Bench { filter: None, warmup_iters: 0, measure_iters: 1, samples: Vec::new() };
        b.run("sim_mips/gups/decoded", "instr", || 1000.0);
        let j = b.to_json();
        assert!(j.contains("\"name\": \"sim_mips/gups/decoded\""), "{j}");
        assert!(j.contains("\"mode\": "), "{j}");
        assert!(j.contains("\"mrate\": "), "{j}");
        assert!(j.contains("\"samples\": ["), "{j}");
    }

    #[test]
    fn table_json_is_balanced_and_escaped() {
        let mut t = crate::util::table::Table::new("Fig \"12\"", &["bench", "speedup"]);
        t.row(vec!["gups".into(), "29.00x".into()]);
        let j = to_json(&[t.clone(), t]);
        assert!(j.contains("\"title\": \"Fig \\\"12\\\"\""), "{j}");
        assert!(j.contains("\"headers\": [\"bench\", \"speedup\"]"), "{j}");
        assert!(j.contains("[\"gups\", \"29.00x\"]"), "{j}");
        for (open, close) in [('{', '}'), ('[', ']')] {
            let o = j.matches(open).count();
            let c = j.matches(close).count();
            assert_eq!(o, c, "unbalanced {open}{close} in {j}");
        }
        assert_eq!(to_json(&[]), "[\n]\n", "empty table list is a valid empty array");
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench {
            filter: Some("fig12".into()),
            warmup_iters: 0,
            measure_iters: 1,
            samples: Vec::new(),
        };
        b.run("fig11/gups", "ops", || 1.0);
        assert!(b.samples.is_empty());
        b.run("fig12/gups", "ops", || 1.0);
        assert_eq!(b.samples.len(), 1);
    }
}
