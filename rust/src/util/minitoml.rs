//! Minimal TOML-subset parser for the config system.
//!
//! The offline environment has no `serde`/`toml`, so configuration files in
//! `configs/` are parsed by this module. Supported subset: `[section]`
//! headers — including nested (dotted) tables like `[mem.fabric]`, whose
//! keys flatten to `mem.fabric.key` — `key = value` with integer, float,
//! boolean and quoted-string values, `#` comments, and blank lines. This
//! covers everything the NH-G / Skylake presets and the fabric/scheduler
//! tables need. Schema checks (which keys exist under a table) belong to
//! the consumer; [`Doc::keys_with_prefix`] supports auditing a nested
//! table for unknown keys.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "minitoml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: map from `"section.key"` (or bare `"key"` for the
/// top-level table) to value.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }
    pub fn i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_i64)
    }
    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }
    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }
    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    /// Full keys under a dotted prefix, e.g.
    /// `keys_with_prefix("mem.fabric.")` — the consumer-side audit hook
    /// for rejecting unknown keys in a nested table.
    pub fn keys_with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        self.entries.keys().filter(move |k| k.starts_with(prefix)).map(|k| k.as_str())
    }
}

fn parse_value(raw: &str, line: usize) -> Result<Value, ParseError> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(ParseError { line, msg: "empty value".into() });
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            return Err(ParseError { line, msg: format!("unterminated string: {raw}") });
        };
        return Ok(Value::Str(inner.to_string()));
    }
    // Allow numeric separators as in TOML.
    let cleaned: String = raw.chars().filter(|c| *c != '_').collect();
    if let Ok(v) = cleaned.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(ParseError { line, msg: format!("cannot parse value: {raw}") })
}

pub fn parse(text: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        // Strip comments, but not inside strings (strings here never
        // contain '#' in practice; keep it simple and documented).
        let line = match raw_line.find('#') {
            Some(pos) if !raw_line[..pos].contains('"') => &raw_line[..pos],
            _ => raw_line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let Some(name) = inner.strip_suffix(']') else {
                return Err(ParseError { line: line_no, msg: format!("bad section header: {line}") });
            };
            section = name.trim().to_string();
            if section.is_empty() {
                return Err(ParseError { line: line_no, msg: "empty section name".into() });
            }
            // Nested (dotted) tables like [mem.fabric]: every segment
            // must be nonempty, or key lookups would silently miss.
            if section.split('.').any(|seg| seg.trim().is_empty()) {
                return Err(ParseError {
                    line: line_no,
                    msg: format!("empty table-name segment in [{section}]"),
                });
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(ParseError { line: line_no, msg: format!("expected key = value: {line}") });
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError { line: line_no, msg: "empty key".into() });
        }
        let value = parse_value(&line[eq + 1..], line_no)?;
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(full_key, value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# global
name = "nh-g"
[core]
rob = 96
freq_ghz = 3.0
ooo = true
[mem]
far_latency_ns = 200
"#,
        )
        .unwrap();
        assert_eq!(doc.str("name"), Some("nh-g"));
        assert_eq!(doc.i64("core.rob"), Some(96));
        assert_eq!(doc.f64("core.freq_ghz"), Some(3.0));
        assert_eq!(doc.bool("core.ooo"), Some(true));
        assert_eq!(doc.i64("mem.far_latency_ns"), Some(200));
    }

    #[test]
    fn int_reads_as_f64_too() {
        let doc = parse("x = 4").unwrap();
        assert_eq!(doc.f64("x"), Some(4.0));
    }

    #[test]
    fn numeric_underscores() {
        let doc = parse("big = 1_000_000").unwrap();
        assert_eq!(doc.i64("big"), Some(1_000_000));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("key value").is_err());
        assert!(parse("[unterminated").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"open").is_err());
    }

    /// Nested-table round trip: a `[mem.fabric]` header flattens its keys
    /// under the dotted prefix, merges across repeated headers, and
    /// coexists with the parent `[mem]` table.
    #[test]
    fn nested_tables_round_trip() {
        let doc = parse(
            r#"
[mem]
far_latency_ns = 200
[mem.fabric]
model = "queued"
depth = 24
[a.b.c]
deep = true
[mem.fabric]
seed = 7
"#,
        )
        .unwrap();
        assert_eq!(doc.i64("mem.far_latency_ns"), Some(200));
        assert_eq!(doc.str("mem.fabric.model"), Some("queued"));
        assert_eq!(doc.i64("mem.fabric.depth"), Some(24));
        assert_eq!(doc.i64("mem.fabric.seed"), Some(7), "repeated nested headers merge");
        assert_eq!(doc.bool("a.b.c.deep"), Some(true), "arbitrary nesting depth");
        // The parent table does not swallow the nested table's keys.
        assert_eq!(doc.i64("mem.depth"), None);
    }

    #[test]
    fn keys_with_prefix_audits_a_nested_table() {
        let doc = parse("[mem.fabric]\nmodel = \"dist\"\nseed = 1\n[mem]\nfar_latency_ns = 9\n")
            .unwrap();
        let keys: Vec<&str> = doc.keys_with_prefix("mem.fabric.").collect();
        assert_eq!(keys, vec!["mem.fabric.model", "mem.fabric.seed"]);
        assert_eq!(doc.keys_with_prefix("sched.").count(), 0);
    }

    #[test]
    fn rejects_empty_nested_segments() {
        assert!(parse("[mem.]\nk = 1").is_err());
        assert!(parse("[.fabric]\nk = 1").is_err());
        assert!(parse("[mem..fabric]\nk = 1").is_err());
        // A well-formed dotted header still parses.
        assert!(parse("[mem.fabric]\nk = 1").is_ok());
    }

    #[test]
    fn comment_stripping() {
        let doc = parse("a = 1 # trailing\n# full line\nb = 2").unwrap();
        assert_eq!(doc.i64("a"), Some(1));
        assert_eq!(doc.i64("b"), Some(2));
    }
}
