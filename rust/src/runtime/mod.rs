//! PJRT runtime: loads the AOT-compiled JAX/Pallas golden models from
//! `artifacts/*.hlo.txt` and executes them on the CPU PJRT client to
//! cross-validate the simulator's functional results.
//!
//! Layer boundaries: Python runs only at build time (`make artifacts`);
//! this module consumes HLO **text** (not serialized protos — xla_extension
//! 0.5.1 rejects jax>=0.5's 64-bit instruction ids; the text parser
//! reassigns ids). See /opt/xla-example/README.md.
//!
//! Offline builds (the default) use [`xla_stub`], which mirrors the xla-rs
//! API and reports "PJRT unavailable" at the first entry point. Enabling
//! the `pjrt` feature raises a `compile_error!` with wiring instructions
//! (the real bindings cannot be vendored); see DESIGN.md §5.

pub mod oracle;

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the real xla-rs bindings, which are not \
     vendored: add the `xla` crate to rust/Cargo.toml, install \
     XLA_EXTENSION, and replace this compile_error + the stub alias below \
     with `use ::xla;` (see DESIGN.md §5)"
);
mod xla_stub;
use xla_stub as xla;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

/// Process-local override for the artifacts directory. Tests and embedders
/// use this instead of mutating `COROAMU_ARTIFACTS`: `std::env::set_var`
/// is unsynchronized with respect to concurrent readers, so flipping the
/// variable mid-run could corrupt any parallel test resolving the dir.
fn override_slot() -> &'static Mutex<Option<PathBuf>> {
    static SLOT: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Set (or with `None`, clear) a process-local artifacts-dir override that
/// takes precedence over `COROAMU_ARTIFACTS` and the cwd walk.
pub fn set_artifacts_dir_override(dir: Option<PathBuf>) {
    *override_slot().lock().unwrap() = dir;
}

/// Default artifact directory (relative to the repo root). Resolution
/// order: process-local override, `COROAMU_ARTIFACTS` (read-only), then a
/// walk up from cwd looking for `artifacts/`.
pub fn artifacts_dir() -> PathBuf {
    resolve_artifacts_dir(override_slot().lock().unwrap().clone())
}

/// The pure resolution logic, parameterized on the override so it can be
/// exercised without mutating process-global state.
fn resolve_artifacts_dir(override_dir: Option<PathBuf>) -> PathBuf {
    if let Some(d) = override_dir {
        return d;
    }
    if let Ok(d) = std::env::var("COROAMU_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// A compiled golden-model executable.
pub struct Golden {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// PJRT CPU client + loaded artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Golden> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?;
        Ok(Golden { exe, name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned() })
    }

    /// Load artifact by short name from the artifacts dir
    /// (`load_named("gups")` -> `artifacts/gups.hlo.txt`).
    pub fn load_named(&self, name: &str) -> Result<Golden> {
        self.load(&artifacts_dir().join(format!("{name}.hlo.txt")))
    }
}

impl Golden {
    /// Execute with i64 inputs and return the flattened i64 outputs of the
    /// result tuple (artifacts are lowered with `return_tuple=True`).
    pub fn run_i64(&self, inputs: &[Vec<i64>]) -> Result<Vec<Vec<i64>>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        self.run_literals(&lits)?.iter().map(|l| l.to_vec::<i64>().context("i64 out")).collect()
    }

    /// Execute with f64 inputs and return f64 outputs.
    pub fn run_f64(&self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        self.run_literals(&lits)?.iter().map(|l| l.to_vec::<f64>().context("f64 out")).collect()
    }

    fn run_literals(&self, lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut out = self.exe.execute::<xla::Literal>(lits).context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        out.decompose_tuple().context("decompose tuple")
    }
}

/// True when the artifact bundle exists (tests skip gracefully otherwise,
/// since artifacts are built by `make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("model.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_override_resolution() {
        // The pure resolver, not the global slot: parallel tests resolving
        // the artifacts dir concurrently must never observe test-local
        // overrides (that shared-state corruption is the bug this
        // replaced).
        assert_eq!(
            resolve_artifacts_dir(Some(PathBuf::from("/tmp/xyz_artifacts"))),
            PathBuf::from("/tmp/xyz_artifacts")
        );
        // Without an override, resolution falls back to env/cwd walk.
        let _ = resolve_artifacts_dir(None);
    }

    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::cpu().err().expect("stub must not create a client");
        let msg = format!("{err:#}");
        assert!(msg.contains("PJRT"), "{msg}");
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
