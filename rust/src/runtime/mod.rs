//! PJRT runtime: loads the AOT-compiled JAX/Pallas golden models from
//! `artifacts/*.hlo.txt` and executes them on the CPU PJRT client to
//! cross-validate the simulator's functional results.
//!
//! Layer boundaries: Python runs only at build time (`make artifacts`);
//! this module consumes HLO **text** (not serialized protos — xla_extension
//! 0.5.1 rejects jax>=0.5's 64-bit instruction ids; the text parser
//! reassigns ids). See /opt/xla-example/README.md.

pub mod oracle;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Default artifact directory (relative to the repo root).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("COROAMU_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Walk up from cwd looking for `artifacts/`.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// A compiled golden-model executable.
pub struct Golden {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// PJRT CPU client + loaded artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Golden> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?;
        Ok(Golden { exe, name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned() })
    }

    /// Load artifact by short name from the artifacts dir
    /// (`load_named("gups")` -> `artifacts/gups.hlo.txt`).
    pub fn load_named(&self, name: &str) -> Result<Golden> {
        self.load(&artifacts_dir().join(format!("{name}.hlo.txt")))
    }
}

impl Golden {
    /// Execute with i64 inputs and return the flattened i64 outputs of the
    /// result tuple (artifacts are lowered with `return_tuple=True`).
    pub fn run_i64(&self, inputs: &[Vec<i64>]) -> Result<Vec<Vec<i64>>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        self.run_literals(&lits)?.iter().map(|l| l.to_vec::<i64>().context("i64 out")).collect()
    }

    /// Execute with f64 inputs and return f64 outputs.
    pub fn run_f64(&self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        self.run_literals(&lits)?.iter().map(|l| l.to_vec::<f64>().context("f64 out")).collect()
    }

    fn run_literals(&self, lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut out = self.exe.execute::<xla::Literal>(lits).context("execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        out.decompose_tuple().context("decompose tuple")
    }
}

/// True when the artifact bundle exists (tests skip gracefully otherwise,
/// since artifacts are built by `make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("model.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("COROAMU_ARTIFACTS", "/tmp/xyz_artifacts");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/xyz_artifacts"));
        std::env::remove_var("COROAMU_ARTIFACTS");
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }
}
