//! Cross-validation of the simulator against the AOT JAX/Pallas golden
//! models: run a `Scale::Tiny` benchmark instance through the full
//! compiler+simulator stack, then run the corresponding `artifacts/*.hlo.txt`
//! executable on the same inputs via PJRT and compare memory images.
//! This is the end-to-end proof that all three layers compose.

use super::Runtime;
use crate::benchmarks::{self, Benchmark, Scale};
use crate::compiler::Variant;
use crate::config::SimConfig;
use crate::engine::Engine;
use crate::ir::Width;
use crate::sim::MemImage;
use anyhow::{bail, ensure, Context, Result};

fn region_i64(mem: &MemImage, name: &str) -> Result<Vec<i64>> {
    let r = mem.region(name).with_context(|| format!("region {name}"))?;
    (0..r.data.len() as u64 / 8).map(|j| mem.read(r.base + j * 8, Width::W8)).collect()
}

fn region_f64(mem: &MemImage, name: &str) -> Result<Vec<f64>> {
    Ok(region_i64(mem, name)?.into_iter().map(|v| f64::from_bits(v as u64)).collect())
}

/// Run `bench` at Tiny scale under `variant` through an [`Engine`] session
/// (oracle-checked) and return the memory image before and after
/// simulation.
fn simulate(bench: &dyn Benchmark, variant: Variant) -> Result<(MemImage, MemImage)> {
    let engine = Engine::new(SimConfig::nh_g());
    let inst = bench.instance(Scale::Tiny, 42)?;
    // Snapshot inputs by building a second identical instance.
    let before = bench.instance(Scale::Tiny, 42)?.mem;
    let run = engine.run_instance(inst, &variant.opts(64))?;
    Ok((before, run.mem))
}

/// Cross-check one benchmark against its artifact. Supported: gups,
/// stream, bs, hj (the four golden-model kernels).
pub fn check_against_artifact(rt: &Runtime, name: &str, variant: Variant) -> Result<()> {
    let bench = benchmarks::by_name(name).with_context(|| format!("benchmark {name}"))?;
    let (before, after) = simulate(bench.as_ref(), variant)?;
    let golden = rt.load_named(name)?;
    match name {
        "gups" => {
            let table_in = region_i64(&before, "table")?;
            let out = golden.run_i64(&[table_in])?;
            let table_sim = region_i64(&after, "table")?;
            ensure!(out[0] == table_sim, "gups: PJRT golden model and simulator disagree");
        }
        "stream" => {
            let b = region_f64(&before, "b")?;
            let c = region_f64(&before, "c")?;
            let out = golden.run_f64(&[b, c])?;
            let a_sim = region_f64(&after, "a")?;
            for (j, (g, s)) in out[0].iter().zip(a_sim.iter()).enumerate() {
                ensure!((g - s).abs() <= 1e-12 * g.abs().max(1.0), "stream a[{j}]: golden {g} vs sim {s}");
            }
        }
        "bs" => {
            let sorted = region_i64(&before, "sorted_array")?;
            let out = golden.run_i64(&[sorted])?;
            let found = region_i64(&after, "out")?;
            ensure!(out[0] == found, "bs: PJRT golden model and simulator disagree");
        }
        "hj" => {
            let buckets = region_i64(&before, "buckets")?;
            let keys: Vec<i64> = {
                let t = region_i64(&before, "tuples")?;
                t.chunks(2).map(|kp| kp[0]).collect()
            };
            let out = golden.run_i64(&[buckets, keys])?;
            let matches = region_i64(&after, "result")?[0];
            ensure!(
                out[0][0] == matches,
                "hj: golden matches {} vs simulator {}",
                out[0][0],
                matches
            );
        }
        other => bail!("no golden artifact for benchmark {other}"),
    }
    Ok(())
}

/// Benchmarks with golden artifacts.
pub const GOLDEN_BENCHES: [&str; 4] = ["gups", "stream", "bs", "hj"];

#[cfg(test)]
mod tests {
    use super::*;

    /// Full three-layer integration — skipped when `make artifacts` has
    /// not been run yet, or when the build carries the PJRT stub (the
    /// default): artifacts can exist on disk while the runtime is
    /// unavailable, and that must skip, not fail.
    #[test]
    fn simulator_matches_pjrt_golden_models() {
        if !super::super::artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
        let rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: {e:#}");
                return;
            }
        };
        for b in GOLDEN_BENCHES {
            for v in [Variant::Serial, Variant::CoroAmuFull] {
                check_against_artifact(&rt, b, v)
                    .unwrap_or_else(|e| panic!("{b} under {}: {e:#}", v.label()));
            }
        }
    }
}
