//! Offline stand-in for the `xla` (xla-rs) PJRT bindings.
//!
//! The build environment has no XLA extension library, so the real
//! bindings cannot link. This stub mirrors exactly the API surface
//! `runtime` uses — same types, same signatures — and fails gracefully at
//! the first entry point (`PjRtClient::cpu`), so everything downstream
//! typechecks but reports "PJRT unavailable" at runtime. Build with
//! `--features pjrt` (after adding the `xla` crate and an
//! XLA_EXTENSION install) to swap in the real bindings; see DESIGN.md §5.

use std::fmt;

/// Error produced by every stub entry point.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT unavailable: coroamu was built without XLA bindings \
         (enable the `pjrt` feature and provide xla-rs + XLA_EXTENSION)"
            .to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }
}
