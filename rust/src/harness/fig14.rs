//! Fig. 14: execution-cycle breakdown at 200 ns for (1) serial code,
//! (2) CoroAMU-D (getfin + indirect jump), (3) CoroAMU-D with bafin.
//! Paper: scheduler branch mispredictions cost >15% in (2); bafin
//! eliminates them in (3).

use super::FigOpts;
use crate::compiler::codegen::{CodegenOpts, SchedKind};
use crate::compiler::Variant;
use crate::config::SimConfig;
use super::grid;
use crate::engine::RunRequest;
use crate::util::table::{pct, Table};
use anyhow::Result;

/// "CoroAMU-D with bafin": basic codegen, bafin scheduler, no context /
/// coalescing optimizations — isolating the §IV-A mechanism.
pub fn d_with_bafin(tasks: usize) -> CodegenOpts {
    CodegenOpts { sched: SchedKind::Bafin, context_opt: false, coalesce: false, generic_frame: false, num_tasks: tasks }
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let benches = opts.bench_names();
    let configs: Vec<(&str, Variant, CodegenOpts)> = vec![
        ("serial", Variant::Serial, CodegenOpts::serial()),
        ("CoroAMU-D", Variant::CoroAmuD, CodegenOpts::coroamu_d(96)),
        ("D+bafin", Variant::CoroAmuD, d_with_bafin(96)),
    ];
    // Explicit-opts requests; sweep preserves matrix order, so results are
    // consumed positionally (bench-major, config-minor).
    let matrix: Vec<RunRequest> = benches
        .iter()
        .flat_map(|b| {
            configs.iter().map(move |(cname, v, co)| {
                RunRequest::new(b.clone(), *v)
                    .scale(opts.scale)
                    .seed(opts.seed)
                    .key(cname.to_string())
                    .opts(co.clone(), cname.to_string())
            })
        })
        .collect();
    let rs = grid::fetch(SimConfig::nh_g().with_far_latency_ns(200.0), &matrix, opts.threads)?;
    let mut t = Table::new(
        "Fig 14: cycle breakdown @200ns — serial / CoroAMU-D / D+bafin",
        &["bench", "config", "compute", "local/ctx", "remote", "scheduler", "mispredict"],
    );
    for r in &rs {
        let brk = r.stats.cycle_breakdown();
        t.row(vec![
            r.bench.clone(),
            r.variant_label.clone(),
            pct(brk[0].1),
            pct(brk[1].1),
            pct(brk[2].1),
            pct(brk[3].1),
            pct(brk[4].1),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn bafin_removes_mispredict_share() {
        let opts = FigOpts { scale: Scale::Small, only: vec!["bs".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        let s = ts[0].render();
        assert!(s.contains("D+bafin"), "{s}");
    }
}
