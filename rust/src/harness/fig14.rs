//! Fig. 14: execution-cycle breakdown at 200 ns for (1) serial code,
//! (2) CoroAMU-D (getfin + indirect jump), (3) CoroAMU-D with bafin.
//! Paper: scheduler branch mispredictions cost >15% in (2); bafin
//! eliminates them in (3).

use super::FigOpts;
use crate::benchmarks::{self};
use crate::compiler::codegen::{CodegenOpts, SchedKind};
use crate::config::SimConfig;
use crate::coordinator::pool;
use crate::util::table::{pct, Table};
use anyhow::Result;

/// "CoroAMU-D with bafin": basic codegen, bafin scheduler, no context /
/// coalescing optimizations — isolating the §IV-A mechanism.
pub fn d_with_bafin(tasks: usize) -> CodegenOpts {
    CodegenOpts { sched: SchedKind::Bafin, context_opt: false, coalesce: false, generic_frame: false, num_tasks: tasks }
}

pub fn run(opts: &FigOpts) -> Result<Vec<Table>> {
    let cfg = SimConfig::nh_g().with_far_latency_ns(200.0);
    let benches = opts.bench_names();
    let configs: Vec<(&str, CodegenOpts)> = vec![
        ("serial", CodegenOpts::serial()),
        ("CoroAMU-D", CodegenOpts::coroamu_d(96)),
        ("D+bafin", d_with_bafin(96)),
    ];
    let cells: Vec<(String, String)> = benches
        .iter()
        .flat_map(|b| configs.iter().map(move |(n, _)| (b.clone(), n.to_string())))
        .collect();
    let stats = pool::parallel_map(cells.len(), opts.threads, |i| {
        let (b, cname) = &cells[i];
        let co = &configs.iter().find(|(n, _)| n == cname).unwrap().1;
        let inst = benchmarks::by_name(b).unwrap().instance(opts.scale, opts.seed).unwrap();
        benchmarks::execute_opts(&cfg, inst, co)
            .unwrap_or_else(|e| panic!("fig14 {b}/{cname}: {e:#}"))
    });
    let mut t = Table::new(
        "Fig 14: cycle breakdown @200ns — serial / CoroAMU-D / D+bafin",
        &["bench", "config", "compute", "local/ctx", "remote", "scheduler", "mispredict"],
    );
    for (i, (b, cname)) in cells.iter().enumerate() {
        let brk = stats[i].cycle_breakdown();
        t.row(vec![
            b.clone(),
            cname.clone(),
            pct(brk[0].1),
            pct(brk[1].1),
            pct(brk[2].1),
            pct(brk[3].1),
            pct(brk[4].1),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchmarks::Scale;

    #[test]
    fn bafin_removes_mispredict_share() {
        let opts = FigOpts { scale: Scale::Small, only: vec!["bs".into()], ..FigOpts::quick() };
        let ts = run(&opts).unwrap();
        let s = ts[0].render();
        assert!(s.contains("D+bafin"), "{s}");
    }
}
