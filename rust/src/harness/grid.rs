//! The shared query layer every report goes through: axes →
//! `Vec<RunRequest>` → store-backed fetch → table render.
//!
//! [`fetch`] is the single choke point between the figure harnesses and
//! the engine: it opens a session, attaches the persistent sweep store
//! when `COROAMU_STORE` is set (see `engine::store`), and sweeps the
//! matrix — so *every* `coroamu report` mode becomes incremental for
//! free, and a second run against a populated store simulates nothing.
//!
//! [`GridQuery`] is the free-form side (`coroamu report --grid AXES`,
//! `coroamu sweep --grid AXES`): a `;`-separated list of `axis=v1,v2`
//! clauses whose cartesian product is the request matrix. Axis values
//! parse through the same `util::keyed` surfaces as the rest of the CLI
//! — one spelling, one error dialect, no sixth parser.

use super::FigOpts;
use crate::benchmarks::{self, Scale};
use crate::compiler::Variant;
use crate::config::SimConfig;
use crate::engine::{Engine, RunReport, RunRequest};
use crate::sim::fabric::FabricKind;
use crate::sim::faults::FaultConfig;
use crate::sim::sched::SchedPolicyKind;
use crate::sim::service::ServiceConfig;
use crate::util::keyed::{unknown, Keyed};
use crate::util::table::Table;
use anyhow::{bail, ensure, Result};

/// Open an engine session over `cfg` (attaching the `COROAMU_STORE`
/// sweep store when set) and sweep the matrix. Every figure harness
/// routes through this, so the store serves all of them.
pub fn fetch(cfg: SimConfig, matrix: &[RunRequest], threads: usize) -> Result<Vec<RunReport>> {
    Engine::new(cfg).with_store_from_env()?.sweep(matrix, threads)
}

/// A declarative sweep grid: one value list per axis, cartesian product
/// as the matrix. Unspecified axes stay at the session default (`None`),
/// which keeps the cells bit-identical to un-overridden runs.
#[derive(Debug, Clone)]
pub struct GridQuery {
    /// Original spec string, for table titles.
    pub spec: String,
    pub benches: Vec<String>,
    pub variants: Vec<Variant>,
    pub latencies: Vec<Option<f64>>,
    pub policies: Vec<Option<SchedPolicyKind>>,
    pub fabrics: Vec<Option<FabricKind>>,
    pub cores: Vec<Option<u32>>,
    pub faults: Vec<Option<FaultConfig>>,
    pub services: Vec<Option<ServiceConfig>>,
    pub seeds: Vec<Option<u64>>,
    pub tasks: Vec<Option<usize>>,
    /// Overrides `FigOpts::scale` when set via `scale=`.
    pub scale: Option<Scale>,
}

impl Default for GridQuery {
    fn default() -> Self {
        GridQuery {
            spec: String::new(),
            benches: vec!["gups".into()],
            variants: vec![Variant::CoroAmuFull],
            latencies: vec![None],
            policies: vec![None],
            fabrics: vec![None],
            cores: vec![None],
            faults: vec![None],
            services: vec![None],
            seeds: vec![None],
            tasks: vec![None],
            scale: None,
        }
    }
}

const AXES: &str =
    "bench, variant, latency, policy, fabric, faults, cores, service, seed, tasks, scale";

fn parse_axis<T: Keyed>(vals: &[&str]) -> Result<Vec<Option<T>>> {
    vals.iter().map(|v| T::parse_keyed(v).map(Some)).collect()
}

impl GridQuery {
    /// Parse `"bench=gups,bfs;latency=200,800;fabric=queued:16"`.
    pub fn parse(spec: &str) -> Result<GridQuery> {
        let mut q = GridQuery { spec: spec.to_string(), ..GridQuery::default() };
        let mut seen: Vec<String> = Vec::new();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (axis, list) = clause
                .split_once('=')
                .ok_or_else(|| unknown("grid clause", clause, "axis=v1,v2 pairs"))?;
            let axis = axis.trim().to_ascii_lowercase();
            ensure!(!seen.contains(&axis), "duplicate grid axis `{axis}`");
            seen.push(axis.clone());
            let vals: Vec<&str> = list.split(',').map(str::trim).filter(|v| !v.is_empty()).collect();
            ensure!(!vals.is_empty(), "grid axis `{axis}` needs at least one value");
            match axis.as_str() {
                "bench" => {
                    for v in &vals {
                        if benchmarks::by_name(v).is_none() {
                            let names: Vec<&str> =
                                benchmarks::all().iter().map(|b| b.spec().name).collect();
                            return Err(unknown("benchmark", v, &names.join(", ")));
                        }
                    }
                    q.benches = vals.iter().map(|v| v.to_ascii_lowercase()).collect();
                }
                "variant" => {
                    q.variants = vals
                        .iter()
                        .map(|v| {
                            Variant::parse(v).ok_or_else(|| {
                                unknown(
                                    "variant",
                                    v,
                                    "serial, coroutine, coroamu-s, coroamu-d, coroamu-full",
                                )
                            })
                        })
                        .collect::<Result<_>>()?;
                }
                "latency" => {
                    q.latencies = vals
                        .iter()
                        .map(|v| match v.parse::<f64>() {
                            Ok(ns) if ns.is_finite() && ns > 0.0 => Ok(Some(ns)),
                            _ => bail!("grid latency must be a positive ns value, got `{v}`"),
                        })
                        .collect::<Result<_>>()?;
                }
                "policy" => q.policies = parse_axis::<SchedPolicyKind>(&vals)?,
                "fabric" => q.fabrics = parse_axis::<FabricKind>(&vals)?,
                "faults" => q.faults = parse_axis::<FaultConfig>(&vals)?,
                "service" => q.services = parse_axis::<ServiceConfig>(&vals)?,
                "cores" => {
                    q.cores = vals
                        .iter()
                        .map(|v| match v.parse::<u32>() {
                            Ok(n) if n > 0 => Ok(Some(n)),
                            _ => bail!("grid cores must be a positive integer, got `{v}`"),
                        })
                        .collect::<Result<_>>()?;
                }
                "seed" => {
                    q.seeds = vals
                        .iter()
                        .map(|v| {
                            v.parse::<u64>()
                                .map(Some)
                                .map_err(|_| anyhow::anyhow!("bad grid seed `{v}`"))
                        })
                        .collect::<Result<_>>()?;
                }
                "tasks" => {
                    q.tasks = vals
                        .iter()
                        .map(|v| match v.parse::<usize>() {
                            Ok(n) if n > 0 => Ok(Some(n)),
                            _ => bail!("grid tasks must be a positive integer, got `{v}`"),
                        })
                        .collect::<Result<_>>()?;
                }
                "scale" => {
                    ensure!(vals.len() == 1, "grid scale takes exactly one value");
                    q.scale = Some(match vals[0] {
                        "tiny" => Scale::Tiny,
                        "small" => Scale::Small,
                        "full" => Scale::Full,
                        other => return Err(unknown("scale", other, "tiny, small, full")),
                    });
                }
                other => return Err(unknown("grid axis", other, AXES)),
            }
        }
        Ok(q)
    }

    /// The cartesian product as engine requests, in a deterministic
    /// axis-major order. `key` is the joined axis labels (display only —
    /// the store fingerprints the physical cell, not the key).
    pub fn requests(&self, opts: &FigOpts) -> Vec<RunRequest> {
        let mut matrix = Vec::new();
        let scale = self.scale.unwrap_or(opts.scale);
        for b in &self.benches {
            for &v in &self.variants {
                for &lat in &self.latencies {
                    for &p in &self.policies {
                        for &f in &self.fabrics {
                            for &n in &self.cores {
                                for fl in &self.faults {
                                    for sv in &self.services {
                                        for &seed in &self.seeds {
                                            for &tasks in &self.tasks {
                                                let mut r = RunRequest::new(b.clone(), v)
                                                    .scale(scale)
                                                    .seed(seed.unwrap_or(opts.seed));
                                                let mut key = Vec::new();
                                                if let Some(ns) = lat {
                                                    r = r.latency_ns(ns);
                                                    key.push(format!("{ns}"));
                                                }
                                                if let Some(p) = p {
                                                    r = r.policy(p);
                                                    key.push(p.label());
                                                }
                                                if let Some(f) = f {
                                                    r = r.fabric(f);
                                                    key.push(f.label());
                                                }
                                                if let Some(n) = n {
                                                    r = r.cores(n);
                                                    key.push(format!("{n}c"));
                                                }
                                                if let Some(fl) = fl {
                                                    r = r.faults(*fl);
                                                    key.push(fl.label());
                                                }
                                                if let Some(sv) = sv {
                                                    r = r.service(*sv);
                                                    key.push(sv.label());
                                                }
                                                if let Some(t) = tasks {
                                                    r = r.tasks(t);
                                                    key.push(format!("t{t}"));
                                                }
                                                matrix.push(r.key(key.join("/")));
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        matrix
    }

    /// Execute the grid (store-backed via [`fetch`]) and render one row
    /// per cell. The `source` column says whether the cell was simulated
    /// in this process (`sim`) or served from the store (`store`).
    pub fn run(&self, opts: &FigOpts) -> Result<Vec<Table>> {
        let matrix = self.requests(opts);
        let rs = fetch(SimConfig::nh_g(), &matrix, opts.threads)?;
        let title = if self.spec.is_empty() {
            "Grid query".to_string()
        } else {
            format!("Grid query: {}", self.spec)
        };
        let mut t = Table::new(
            title,
            &[
                "bench", "variant", "cell", "cycles", "ipc", "far p50", "far p99", "switches",
                "source",
            ],
        );
        for r in &rs {
            let st = &r.stats;
            t.row(vec![
                r.bench.clone(),
                r.variant_label.clone(),
                if r.key.is_empty() { "-".into() } else { r.key.clone() },
                st.cycles.to_string(),
                format!("{:.2}", st.ipc()),
                st.fabric_p50.to_string(),
                st.fabric_p99.to_string(),
                st.switches.to_string(),
                if r.store_hit { "store".into() } else { "sim".into() },
            ]);
        }
        Ok(vec![t])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_parse_builds_the_cartesian_product() {
        let q = GridQuery::parse("bench=gups,bfs;latency=200,800;policy=arrival,latency").unwrap();
        let m = q.requests(&FigOpts::quick());
        assert_eq!(m.len(), 8, "2 benches x 2 latencies x 2 policies");
        assert!(m.iter().all(|r| r.fabric.is_none() && r.faults.is_none()));
        assert_eq!(m[0].key, "200/arrival");
        // Axis-major determinism: same spec, same order.
        let again = GridQuery::parse("bench=gups,bfs;latency=200,800;policy=arrival,latency")
            .unwrap()
            .requests(&FigOpts::quick());
        assert_eq!(m.len(), again.len());
        assert!(m.iter().zip(&again).all(|(a, b)| a.key == b.key && a.bench == b.bench));
    }

    #[test]
    fn grid_axis_errors_reuse_the_keyed_dialect() {
        for (spec, needle) in [
            ("fabric=quewed", "unknown fabric `quewed`; expected one of: "),
            ("policy=roundrobin", "unknown scheduler policy `roundrobin`"),
            ("faults=storm", "unknown fault spec `storm`"),
            ("service=flood", "unknown service spec `flood`"),
            ("warp=9", "unknown grid axis `warp`"),
            ("bench=nope", "unknown benchmark `nope`"),
            ("variant=best", "unknown variant `best`"),
            ("scale=huge", "unknown scale `huge`"),
        ] {
            let err = format!("{:#}", GridQuery::parse(spec).unwrap_err());
            assert!(err.contains(needle), "spec {spec}: {err}");
        }
        assert!(GridQuery::parse("latency=200;latency=800").is_err(), "duplicate axis");
        assert!(GridQuery::parse("latency=").is_err(), "empty value list");
        assert!(GridQuery::parse("gups").is_err(), "clause without =");
    }

    #[test]
    fn grid_run_renders_one_row_per_cell() {
        let q = GridQuery::parse("bench=gups;variant=serial,full;latency=200").unwrap();
        let mut opts = FigOpts::quick();
        opts.scale = Scale::Tiny;
        opts.threads = 2;
        let tables = q.run(&opts).unwrap();
        assert_eq!(tables.len(), 1);
        let text = tables[0].render();
        assert!(text.contains("Serial") && text.contains("CoroAMU-Full"), "{text}");
        assert_eq!(tables[0].rows.len(), 2);
    }
}
